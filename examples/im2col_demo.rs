//! Figures 2 & 3 of the paper, reproduced numerically: a 2-D convolution
//! with a 2x2 filter, stride 1, padding 0, expressed as im2col + GeMM.
//!
//! ```sh
//! cargo run --release --example im2col_demo
//! ```

use phast_caffe::ops::im2col::{im2col, Conv2dGeom};
use phast_caffe::ops::{gemm, Trans};

fn print_mat(name: &str, m: &[f32], rows: usize, cols: usize) {
    println!("{name} ({rows}x{cols}):");
    for r in 0..rows {
        let row: Vec<String> = m[r * cols..(r + 1) * cols]
            .iter()
            .map(|v| format!("{v:5.1}"))
            .collect();
        println!("  [{}]", row.join(" "));
    }
}

fn main() {
    // Fig. 2: a 4-wide, 3-tall input and a 2x2 filter, stride 1, pad 0.
    let input: Vec<f32> = (0..12).map(|v| v as f32).collect();
    let filter = [1.0f32, 2.0, 3.0, 4.0];
    let (h, w) = (3usize, 4usize);
    print_mat("input", &input, h, w);
    print_mat("filter", &filter, 2, 2);

    // Direct sliding-window convolution (the left side of Fig. 2).
    let (oh, ow) = (h - 1, w - 1);
    let mut direct = vec![0.0f32; oh * ow];
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0.0;
            for i in 0..2 {
                for j in 0..2 {
                    acc += filter[i * 2 + j] * input[(oy + i) * w + ox + j];
                }
            }
            direct[oy * ow + ox] = acc;
        }
    }
    print_mat("direct convolution", &direct, oh, ow);

    // Fig. 3: the same convolution as a GeMM over the im2col matrix.
    let g = Conv2dGeom { kh: 2, kw: 2, sh: 1, sw: 1, ph: 0, pw: 0 };
    let mut cols = vec![0.0f32; 4 * oh * ow];
    im2col(&input, 1, h, w, g, &mut cols);
    print_mat("im2col matrix (Fig. 3)", &cols, 4, oh * ow);

    let mut as_gemm = vec![0.0f32; oh * ow];
    gemm(Trans::No, Trans::No, 1, oh * ow, 4, 1.0, &filter, &cols, 0.0, &mut as_gemm);
    print_mat("filter-row x im2col = GeMM convolution", &as_gemm, oh, ow);

    assert_eq!(direct, as_gemm, "Fig. 2 and Fig. 3 must agree");
    println!("direct convolution == im2col+GeMM  ✓ (the paper's §3.1 identity)");
}
