//! The paper's §4.3 analysis as a runnable experiment: sweep the
//! incremental-porting frontier from "nothing ported" to "everything
//! ported", measuring boundary crossings, relayout bytes and wall time —
//! then ablate the layout conversion the paper suspects is the largest
//! contributor to the gap.
//!
//! ```sh
//! make artifacts && cargo run --release --example partial_port_analysis
//! ```

use phast_caffe::experiments::{measure_placement, porting_sweep, render_transfers};
use phast_caffe::phast::Placement;
use phast_caffe::proto::{presets, NetConfig};
use phast_caffe::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    for net in ["mnist", "cifar"] {
        println!("==== {net}: incremental porting sweep (fwd+bwd per iteration) ====");
        let sweep = porting_sweep(&engine, net, 3)?;
        print!("{}", render_transfers(&sweep));

        let cfg = NetConfig::from_text(presets::net_by_name(net).unwrap())?;
        let with = measure_placement(
            &engine,
            net,
            "paper placement + layout conv",
            Placement::paper_partial(&cfg),
            true,
            3,
        )?;
        let without = measure_placement(
            &engine,
            net,
            "paper placement, no layout conv",
            Placement::paper_partial(&cfg),
            false,
            3,
        )?;
        println!("\nlayout-conversion ablation (paper: 'the biggest quote in the gap'):");
        print!("{}", render_transfers(&[with, without]));
        println!();
    }
    println!("paper estimates ~10 (MNIST) / ~30 (CIFAR) unnecessary transfers per");
    println!("inference pass; this sweep reproduces the mechanism and lets you");
    println!("watch the crossings collapse as the ported set grows.");
    Ok(())
}
