//! Quickstart: build LeNet from its prototxt, run one forward/backward in
//! both domains, and compare the results — the 60-second tour of the
//! public API.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use phast_caffe::net::Net;
use phast_caffe::phast::{BoundaryOptions, Placement, PortedNet};
use phast_caffe::proto::{presets, NetConfig};
use phast_caffe::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. Parse the net description (Caffe prototxt subset).
    let config = NetConfig::from_text(presets::LENET_MNIST)?;
    println!("net '{}' with {} layers", config.name, config.layers.len());

    // 2. Build + run the native baseline ("original Caffe").
    let mut native = Net::from_config(config.clone(), /*seed=*/ 1)?;
    let loss = native.forward()?.unwrap();
    native.backward()?;
    println!("native   forward loss = {loss:.4}");

    // 3. Same net, every layer executed from the single-source AOT
    //    artifacts through PJRT ("the PHAST port").  Without artifacts
    //    (e.g. the CI smoke job) the native half above is the smoke test;
    //    skip the ported half instead of failing.  Only *absent* artifacts
    //    skip — a present-but-broken artifact set still fails loudly.
    let manifest = phast_caffe::runtime::artifacts_dir().join("manifest.txt");
    if !manifest.exists() {
        println!(
            "skipping ported-domain half: no artifacts at {} (run `make artifacts`)",
            manifest.display()
        );
        return Ok(());
    }
    let engine = Engine::open_default()?;
    let mut ported = PortedNet::new(
        Net::from_config(config, 1)?, // same seed -> same weights, same batch
        &engine,
        Placement::phast_all(),
        BoundaryOptions::default(),
    )?;
    let loss_p = ported.forward()?.unwrap();
    ported.backward()?;
    println!("ported   forward loss = {loss_p:.4}");

    // 4. The paper's validation: intermediate tensors agree across domains.
    for blob in ["conv1", "pool1", "conv2", "pool2", "ip1", "ip2"] {
        let d = native
            .blob(blob)
            .unwrap()
            .data()
            .max_abs_diff(ported.net.blob(blob).unwrap().data());
        println!("  intermediate {blob:6} max|diff| = {d:.2e}");
    }
    println!(
        "boundary crossings in the fully-ported run: {} (entry/exit only)",
        ported.stats.crossings
    );
    Ok(())
}
