//! Serving driver: LeNet / synthetic-MNIST behind the async inference
//! engine — bounded intake queue, deadline-aware dynamic batching, and
//! zero-copy response views (see `docs/SERVING.md`).
//!
//! ```sh
//! PHAST_SERVE_BATCH=8 cargo run --release --example serve_lenet -- 32
//! ```
//!
//! Arguments: an optional request count (default 32).  Engine knobs come
//! from `PHAST_SERVE_BATCH` / `PHAST_SERVE_DELAY_US` /
//! `PHAST_SERVE_QUEUE`; set `PHAST_SNAPSHOT_DIR` to serve the newest
//! valid `.pcss` checkpoint from that directory (hot-reload capable)
//! instead of seed weights.
//!
//! Every response is checked **bitwise** against a single-request
//! reference forward of the same input on an identically constructed
//! model — however the batcher coalesced the requests.  The run ends
//! with machine-checkable lines:
//!
//! ```text
//! served=32
//! mismatches=0
//! batches=7
//! steady_repacks=0
//! argmax_hash=0x1a2b3c4d
//! ```
//!
//! `argmax_hash` is a CRC32 over the predicted class of every request in
//! submission order; it is a pure function of the weights and the
//! deterministic synthetic inputs, so the CI smoke job asserts it is
//! identical across `PHAST_NUM_THREADS` settings.  The process exits
//! nonzero on any mismatch or failed request.

use std::sync::Arc;
use std::time::Instant;

use phast_caffe::runtime::{Model, ModelRegistry, ServeConfig, ServeEngine, SubmitError};
use phast_caffe::solver::crc32;

const SAMPLE_IN: usize = 28 * 28;
const DEFAULT_REQUESTS: usize = 32;

/// Deterministic synthetic input sample (splitmix64 over the seed).
fn sample(seed: u64) -> Vec<f32> {
    let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..SAMPLE_IN)
        .map(|_| {
            x ^= x >> 30;
            x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            x ^= x >> 27;
            ((x >> 40) as f32) / ((1u64 << 24) as f32)
        })
        .collect()
}

fn build_model(batch: usize) -> anyhow::Result<Model> {
    let mut m = Model::lenet(batch, 42)?;
    if let Ok(dir) = std::env::var("PHAST_SNAPSHOT_DIR") {
        match m.load_latest(std::path::Path::new(&dir))? {
            Some(p) => println!("loaded snapshot {p:?}"),
            None => println!("no valid snapshot in {dir:?}: serving seed weights"),
        }
    }
    Ok(m)
}

fn main() -> anyhow::Result<()> {
    let n: usize = match std::env::args().nth(1) {
        Some(arg) => arg
            .parse()
            .map_err(|e| anyhow::anyhow!("bad request count argument '{arg}': {e}"))?,
        None => DEFAULT_REQUESTS,
    };

    let cfg = ServeConfig::from_env();
    println!(
        "== serving: LeNet / synthetic-MNIST, {n} requests ==\n\
         max_batch {}, max_delay {}us, queue {}",
        cfg.max_batch, cfg.max_delay_us, cfg.queue_cap
    );

    // Single-request references from an identically constructed model —
    // the bitwise yardstick for whatever batches the engine forms.
    let mut reference = build_model(cfg.max_batch)?;
    let width = reference.sample_out();
    let inputs: Vec<Vec<f32>> = (0..n).map(|i| sample(7000 + i as u64)).collect();
    let refs: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| Ok(reference.forward_batch(x, 1)?.as_slice()[..width].to_vec()))
        .collect::<anyhow::Result<_>>()?;

    let registry = Arc::new(ModelRegistry::new());
    registry.register_fixed("lenet", build_model(cfg.max_batch)?);
    let engine = ServeEngine::start(Arc::clone(&registry), "lenet", cfg)?;

    // Submit everything up front (QueueFull is backpressure: retry), then
    // collect — this is what actually exercises the batcher's coalescing.
    let t0 = Instant::now();
    let mut pendings = Vec::with_capacity(n);
    for x in &inputs {
        let p = loop {
            match engine.submit(x.clone()) {
                Ok(p) => break p,
                Err(SubmitError::QueueFull) => std::thread::yield_now(),
                Err(e) => return Err(e.into()),
            }
        };
        pendings.push(p);
    }
    let mut mismatches = 0usize;
    let mut argmaxes = Vec::with_capacity(n);
    for (i, p) in pendings.into_iter().enumerate() {
        let resp = p.wait()?;
        if resp.scores() != refs[i].as_slice() {
            eprintln!("request {i}: served scores differ from single-request reference");
            mismatches += 1;
        }
        argmaxes.push(resp.argmax(0) as u8);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = engine.stats();

    println!(
        "done: {n} requests in {:.1} ms ({:.1} req/s), {} batches ({:.2} rows/batch)",
        wall * 1e3,
        n as f64 / wall,
        stats.batches,
        stats.rows as f64 / stats.batches.max(1) as f64
    );
    println!("served={}", stats.requests);
    println!("mismatches={mismatches}");
    println!("batches={}", stats.batches);
    println!("steady_repacks={}", stats.steady_repacks);
    println!("argmax_hash={:#010x}", crc32(&argmaxes));

    if mismatches > 0 {
        anyhow::bail!("{mismatches} served responses mismatched the reference");
    }
    Ok(())
}
