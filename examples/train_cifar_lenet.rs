//! The paper's second workload: cifar10-quick (3 conv, 3 pool, 2 ip) on the
//! synthetic CIFAR-10 analog, trained natively with periodic evaluation.
//!
//! ```sh
//! cargo run --release --example train_cifar_lenet
//! ```

use std::time::Instant;

use phast_caffe::experiments::preset_net;
use phast_caffe::proto::{presets, SolverConfig};
use phast_caffe::solver::Solver;

const ITERS: usize = 120;

fn main() -> anyhow::Result<()> {
    let mut cfg = SolverConfig::from_text(presets::CIFAR_SOLVER)?;
    cfg.display = 0;
    cfg.max_iter = ITERS;
    let mut solver = Solver::new(cfg, preset_net("cifar", 7)?);
    println!("== cifar10-quick / synthetic-CIFAR10, {ITERS} iters, batch 64 ==");
    println!(
        "net: {} params across {} layers",
        solver.net.num_params(),
        solver.net.num_layers()
    );
    let t0 = Instant::now();
    for i in 0..ITERS {
        let loss = solver.step()?;
        if (i + 1) % 20 == 0 {
            let (tl, ta) = solver.test(2)?;
            println!(
                "iter {:>4}  train-loss {:.4}  test-loss {:.4}  test-acc {:.3}",
                i + 1,
                loss,
                tl,
                ta
            );
        }
    }
    let (floss, facc) = solver.test(6)?;
    println!(
        "done in {:.1}s: final test-loss {floss:.4}, test-acc {facc:.3}",
        t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(facc > 0.5, "cifar run failed to learn ({facc})");
    Ok(())
}
