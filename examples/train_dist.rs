//! Elastic data-parallel training driver: LeNet / synthetic-MNIST
//! across N worker processes under the [`runtime::dist`] coordinator —
//! fixed-rank-order gradient all-reduce, shared crash-safe checkpoints,
//! and rollback-all recovery when a worker dies
//! (see `docs/FAULT_TOLERANCE.md`, "Multi-worker elasticity").
//!
//! ```sh
//! cargo run --release --example train_dist -- --ranks 3 --iters 12
//! ```
//!
//! Flags: `--ranks N` (default 2), `--iters N` (default 12),
//! `--dir PATH` (checkpoint dir, default `target/dist-snapshots`),
//! `--budget N` (worker losses absorbed before aborting, default 2).
//! Chaos comes from the environment: `PHAST_FAULT=worker_exit@iter=7`
//! makes the rank selected by `PHAST_DIST_FAULT_RANK` (default 1) kill
//! itself mid-run, which is how the CI dist-chaos job exercises
//! recovery.  `PHAST_DIST_ABORT_ITER=N` crashes the *coordinator*
//! instead (exit 3); rerunning with the same `--dir` resumes.
//!
//! The run ends with machine-checkable lines:
//!
//! ```text
//! ranks=3
//! recoveries=1
//! final_iter=12
//! final_weights_hash=0x1a2b3c4d
//! ```
//!
//! Training is bitwise deterministic at a fixed rank count and thread
//! count, so a run that lost (and respawned) a worker must print the
//! same `final_weights_hash` as an undisturbed one — the property the
//! CI job asserts.

use phast_caffe::runtime::dist::{self, DistConfig};

const DEFAULT_ITERS: usize = 12;
const DEFAULT_RANKS: usize = 2;

fn main() -> anyhow::Result<()> {
    // Worker role check before ANYTHING writes to stdout: a dist
    // worker's stdout carries wire frames, not text.
    dist::exec_worker_if_env();

    let mut ranks = DEFAULT_RANKS;
    let mut iters = DEFAULT_ITERS;
    let mut budget = 2usize;
    let mut dir = String::from("target/dist-snapshots");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        let mut take = |name: &str| {
            args.next().ok_or_else(|| anyhow::anyhow!("{name} needs a value"))
        };
        match arg.as_str() {
            "--ranks" => ranks = take("--ranks")?.parse()?,
            "--iters" => iters = take("--iters")?.parse()?,
            "--budget" => budget = take("--budget")?.parse()?,
            "--dir" => dir = take("--dir")?,
            other => anyhow::bail!("unknown argument '{other}'"),
        }
    }

    let exe = std::env::current_exe()?;
    let mut cfg = DistConfig::new(exe, &dir);
    cfg.ranks = ranks;
    cfg.iters = iters;
    cfg.recover_budget = budget;
    println!(
        "== dist training: LeNet / synthetic-MNIST, {} ranks x {} iters ==\n\
         checkpoints: every {} iters, dir {:?}, recovery budget {}",
        cfg.ranks, cfg.iters, cfg.snapshot_every, cfg.dir, cfg.recover_budget
    );
    if let Some(spec) = &cfg.fault_spec {
        println!("fault plan (rank {}): {spec}", cfg.fault_rank.min(cfg.ranks - 1));
    }

    let summary = dist::train_dist(cfg)?;
    if let Some(it) = summary.resumed_from {
        println!("resumed from iter {it}");
    }
    println!(
        "done: crc_nacks={} nacks_served={}",
        summary.crc_nacks, summary.nacks_served
    );
    println!("ranks={}", summary.ranks);
    println!("recoveries={}", summary.recoveries);
    println!("final_iter={}", summary.final_iter);
    println!("final_weights_hash={:#010x}", summary.weights_hash);
    Ok(())
}
