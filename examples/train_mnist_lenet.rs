//! End-to-end driver: train LeNet on the synthetic MNIST workload for a few
//! hundred steps in BOTH backends, logging the loss curves — the proof that
//! all three layers of the stack (Pallas kernels -> JAX graphs -> Rust
//! coordinator) compose into a training system that learns.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_mnist_lenet
//! ```
//!
//! An optional first argument overrides the iteration count (the CI smoke
//! job runs `-- 2`); the learned-the-task assertions only apply to full
//! runs, and without AOT artifacts the PJRT half skips gracefully.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use phast_caffe::experiments::{preset_net, sample_batch};
use phast_caffe::phast::FusedRunner;
use phast_caffe::proto::{presets, SolverConfig};
use phast_caffe::runtime::Engine;
use phast_caffe::solver::Solver;

const DEFAULT_ITERS: usize = 300;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()
        .map_err(|e| anyhow::anyhow!("bad iteration count argument: {e}"))?
        .unwrap_or(DEFAULT_ITERS);
    // Smoke runs (few iterations) exercise the entry points; only a full
    // run is expected to actually learn the task.
    let full_run = iters >= DEFAULT_ITERS;

    // ---------------- native backend ----------------
    let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER)?;
    cfg.display = 0;
    cfg.max_iter = iters;
    let mut solver = Solver::new(cfg.clone(), preset_net("mnist", 42)?);
    println!("== native backend: LeNet / synthetic-MNIST, {iters} iters, batch 64 ==");
    let t0 = Instant::now();
    for i in 0..iters {
        let loss = solver.step()?;
        if (i + 1) % 25 == 0 {
            let (tl, ta) = solver.test(4)?;
            println!(
                "iter {:>4}  train-loss {:.4}  test-loss {:.4}  test-acc {:.3}  lr {:.5}",
                i + 1,
                loss,
                tl,
                ta,
                solver.lr()
            );
        }
    }
    let native_s = t0.elapsed().as_secs_f64();
    let (final_loss, final_acc) = solver.test(8)?;
    println!(
        "native: {native_s:.1}s, final test-loss {final_loss:.4}, test-acc {final_acc:.3}\n"
    );

    // ---------------- fused PJRT backend ----------------
    // Only *absent* artifacts skip this half (the CI smoke job);
    // present-but-broken artifacts still fail loudly.
    let manifest = phast_caffe::runtime::artifacts_dir().join("manifest.txt");
    if !manifest.exists() {
        println!(
            "skipping fused PJRT half: no artifacts at {} (run `make artifacts`)",
            manifest.display()
        );
        if full_run {
            anyhow::ensure!(final_acc > 0.85, "native run failed to learn ({final_acc})");
        }
        println!("\nnative backend ran the task ✓");
        return Ok(());
    }
    let engine = Engine::open_default()?;
    let mut feeder = preset_net("mnist", 42)?;
    let mut fused = FusedRunner::from_net(&engine, &feeder)?;
    println!("== fused PJRT backend: same net, same data, {iters} iters ==");
    let t0 = Instant::now();
    for i in 0..iters {
        let (x, labels) = sample_batch(&mut feeder)?;
        let lr = cfg.lr_policy.lr_at(cfg.base_lr, i);
        let loss = fused.step(x, labels, lr)?;
        if (i + 1) % 50 == 0 {
            println!("iter {:>4}  train-loss {loss:.4}  lr {lr:.5}", i + 1);
        }
    }
    let fused_s = t0.elapsed().as_secs_f64();
    let (x, labels) = sample_batch(&mut feeder)?;
    let (eloss, eacc, _) = fused.eval(x, labels)?;
    println!("fused: {fused_s:.1}s, final eval-loss {eloss:.4}, eval-acc {eacc:.3}");

    if full_run {
        anyhow::ensure!(final_acc > 0.85, "native run failed to learn ({final_acc})");
        anyhow::ensure!(eacc > 0.85, "fused run failed to learn ({eacc})");
        println!("\nboth backends learned the task ✓");
    } else {
        println!("\nsmoke run complete ({iters} iters) ✓");
    }
    Ok(())
}
