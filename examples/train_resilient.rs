//! Fault-tolerant training driver: LeNet / synthetic-MNIST under
//! [`TrainDriver`] — periodic crash-safe checkpoints, automatic resume
//! from the newest valid snapshot, and rollback recovery for worker
//! panics and non-finite losses (see `docs/FAULT_TOLERANCE.md`).
//!
//! ```sh
//! PHAST_SNAPSHOT_DIR=/tmp/lenet_ckpt cargo run --release --example train_resilient -- 60
//! ```
//!
//! Arguments: an optional iteration count (default 60) and an optional
//! `--budget N` (rollbacks absorbed before aborting; default 2 —
//! `--budget 0` makes any recoverable fault fatal, which is how the CI
//! kill-and-resume job simulates a crashing process).  Checkpoint policy
//! comes from `PHAST_SNAPSHOT_EVERY` / `PHAST_SNAPSHOT_KEEP` /
//! `PHAST_SNAPSHOT_DIR`; faults are injected via `PHAST_FAULT`.
//!
//! The run ends with two machine-checkable lines:
//!
//! ```text
//! final_iter=60
//! final_weights_hash=0x1a2b3c4d
//! ```
//!
//! Training is bitwise deterministic at a fixed thread count, so a
//! crashed-and-resumed run must print the same `final_weights_hash` as an
//! uninterrupted one — the property the CI job asserts.

use phast_caffe::net::Net;
use phast_caffe::proto::{presets, NetConfig, SolverConfig};
use phast_caffe::solver::{crc32, DriverConfig, Solver, TrainDriver};

const DEFAULT_ITERS: usize = 60;

fn main() -> anyhow::Result<()> {
    let mut iters = DEFAULT_ITERS;
    let mut budget = 2usize;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        if arg == "--budget" {
            let v = args
                .next()
                .ok_or_else(|| anyhow::anyhow!("--budget needs a value"))?;
            budget = v
                .parse()
                .map_err(|e| anyhow::anyhow!("bad --budget value '{v}': {e}"))?;
        } else {
            iters = arg
                .parse()
                .map_err(|e| anyhow::anyhow!("bad iteration count argument '{arg}': {e}"))?;
        }
    }

    let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER)?;
    cfg.display = 0;
    cfg.max_iter = iters;
    let net = Net::from_config(NetConfig::from_text(presets::LENET_MNIST)?, 42)?;
    let solver = Solver::new(cfg, net);

    let mut dcfg = DriverConfig::from_env("target/snapshots");
    dcfg.recover_budget = budget;
    println!(
        "== resilient training: LeNet / synthetic-MNIST, {iters} iters ==\n\
         checkpoints: every {} iters, keep {}, dir {:?}, recovery budget {budget}",
        dcfg.snapshot_every, dcfg.keep, dcfg.dir
    );

    let mut driver = TrainDriver::new(solver, dcfg);
    match driver.resume()? {
        Some(path) => println!("resumed from {path:?} at iter {}", driver.solver.iter()),
        None => println!("no usable snapshot found: starting fresh"),
    }

    driver.run(iters)?;

    let last = driver.solver.log.last().map(|e| e.loss).unwrap_or(f32::NAN);
    println!(
        "done: iter {}, last loss {last:.4}, rollbacks absorbed {}",
        driver.solver.iter(),
        driver.rollbacks()
    );

    // Deterministic fingerprint of the final parameters, for the CI
    // kill-and-resume equality check.
    let mut bytes = Vec::new();
    for p in driver.solver.net.params() {
        for v in p.data().as_slice() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    println!("final_iter={}", driver.solver.iter());
    println!("final_weights_hash={:#010x}", crc32(&bytes));
    Ok(())
}
