"""AOT compiler: lower the artifact catalog to HLO text + manifest.

This is the only place Python touches the build: ``make artifacts`` runs
``python -m compile.aot --out-dir ../artifacts`` once; the Rust coordinator
then loads ``artifacts/*.hlo.txt`` through the PJRT C API and Python never
appears on the train/serve path again.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

The catalog has two halves, mirroring the paper's two worlds:

* **per-layer artifacts** (``mnist.conv1.fwd``, ...) — one executable per
  layer direction, used by the *partially ported* configuration where the
  Rust coordinator hops between the native domain and the PHAST domain
  layer by layer (paper §4.3's transfer analysis);
* **fused artifacts** (``mnist.step`` / ``grads`` / ``eval`` / ``infer``) —
  the whole net in a single executable, the paper's predicted end state
  ("once we have ported the entire set of layers").

Manifest format (``artifacts/manifest.txt``), parsed by
``rust/src/runtime/manifest.rs``::

    artifact mnist.conv1.fwd
    file mnist.conv1.fwd.hlo.txt
    in f32 64,1,28,28
    in f32 20,1,5,5
    in f32 20
    out f32 64,20,24,24
    end
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import kernels as K

BATCH = 64

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(fn, arg_specs) -> str:
    """jit-lower ``fn`` at ``arg_specs`` and convert to XLA HLO text.

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``constant({...})``, which the text parser then
    reads back as *zeros* — the AVE-pooling divisor table silently became 0
    and zeroed everything downstream before this flag was set.
    """
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


# ---------------------------------------------------------------------------
# Catalog construction
# ---------------------------------------------------------------------------

def _tupled(fn):
    """Wrap an op so its output is always a tuple (uniform Rust unwrap)."""

    def wrapped(*a):
        out = fn(*a)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def per_layer_entries(net: M.NetDef, tag: str):
    """(name, fn, arg_specs) for every layer instance of ``net``."""
    entries = []
    b = BATCH
    c, h, w = net.in_shape
    ncls = net.num_classes
    for st in net.stages:
        if isinstance(st, M.ConvSpec):
            s, p = (st.stride, st.stride), (st.pad, st.pad)
            gh = K.common.conv_geom(h, st.kernel, st.stride, st.pad)
            gw = K.common.conv_geom(w, st.kernel, st.stride, st.pad)
            xs = spec((b, c, h, w))
            ws = spec((st.out_channels, c, st.kernel, st.kernel))
            bs = spec((st.out_channels,))
            ys = spec((b, st.out_channels, gh.out, gw.out))
            entries.append((f"{tag}.{st.name}.fwd",
                            _tupled(lambda x, wt, bi, _s=s, _p=p: M.conv2d_fwd(x, wt, bi, _s, _p)),
                            [xs, ws, bs]))
            entries.append((f"{tag}.{st.name}.bwd",
                            _tupled(lambda x, wt, dy, _s=s, _p=p: M.conv2d_bwd(x, wt, dy, _s, _p)),
                            [xs, ws, ys]))
            c, h, w = st.out_channels, gh.out, gw.out
        elif isinstance(st, M.PoolSpec):
            k, s, p = (st.kernel, st.kernel), (st.stride, st.stride), (st.pad, st.pad)
            gh = K.common.pool_geom(h, st.kernel, st.stride, st.pad)
            gw = K.common.pool_geom(w, st.kernel, st.stride, st.pad)
            xs = spec((b, c, h, w))
            ys = spec((b, c, gh.out, gw.out))
            args_ = spec((b, c, gh.out, gw.out), I32)
            size = (h, w)
            if st.method == "max":
                entries.append((f"{tag}.{st.name}.fwd",
                                _tupled(lambda x, _k=k, _s=s, _p=p: M.maxpool_fwd(x, _k, _s, _p)),
                                [xs]))
                entries.append((f"{tag}.{st.name}.bwd",
                                _tupled(lambda dy, arg, _sz=size, _k=k, _s=s, _p=p:
                                        M.maxpool_bwd(dy, arg, _sz, _k, _s, _p)),
                                [ys, args_]))
            else:
                entries.append((f"{tag}.{st.name}.fwd",
                                _tupled(lambda x, _k=k, _s=s, _p=p: M.avepool_fwd(x, _k, _s, _p)),
                                [xs]))
                entries.append((f"{tag}.{st.name}.bwd",
                                _tupled(lambda dy, _sz=size, _k=k, _s=s, _p=p:
                                        M.avepool_bwd(dy, _sz, _k, _s, _p)),
                                [ys]))
            h, w = gh.out, gw.out
        elif isinstance(st, M.IpSpec):
            kdim = c * h * w
            xs = spec((b, kdim))
            ws = spec((st.num_output, kdim))
            bs = spec((st.num_output,))
            ys = spec((b, st.num_output))
            entries.append((f"{tag}.{st.name}.fwd", _tupled(M.ip_fwd), [xs, ws, bs]))
            entries.append((f"{tag}.{st.name}.bwd", _tupled(M.ip_bwd), [xs, ws, ys]))
            c, h, w = st.num_output, 1, 1
        elif isinstance(st, M.ReluSpec):
            shape = (b, c) if h == 1 and w == 1 else (b, c, h, w)
            xs = spec(shape)
            entries.append((f"{tag}.{st.name}.fwd",
                            _tupled(lambda x, _a=st.alpha: K.leaky_relu(x, _a)), [xs]))
            entries.append((f"{tag}.{st.name}.bwd",
                            _tupled(lambda x, dy, _a=st.alpha: K.leaky_relu_bwd(x, dy, _a)),
                            [xs, xs]))
    logit = spec((b, ncls))
    lbl = spec((b,), I32)
    entries.append((f"{tag}.loss.fwd", _tupled(K.softmax_xent), [logit, lbl]))
    entries.append((f"{tag}.loss.bwd", _tupled(K.softmax_xent_bwd), [logit, lbl]))
    entries.append((f"{tag}.softmax.fwd", _tupled(K.softmax), [logit]))
    entries.append((f"{tag}.accuracy.fwd", _tupled(K.accuracy), [logit, lbl]))
    return entries


def fused_entries(net: M.NetDef, tag: str):
    b = BATCH
    c, h, w = net.in_shape
    xs = spec((b, c, h, w))
    lbl = spec((b,), I32)
    ps = [spec(s) for _, s in M.param_shapes(net)]
    lr = spec(())
    return [
        (f"{tag}.step", M.make_step_fn(net), [xs, lbl, lr] + ps + ps),
        (f"{tag}.grads", M.make_grads_fn(net), [xs, lbl] + ps),
        (f"{tag}.eval", M.make_eval_fn(net), [xs, lbl] + ps),
        (f"{tag}.infer", M.make_infer_fn(net), [xs] + ps),
    ]


def catalog():
    entries = []
    for net, tag in ((M.LENET_MNIST, "mnist"), (M.CIFAR10_QUICK, "cifar")):
        entries.extend(per_layer_entries(net, tag))
        entries.extend(fused_entries(net, tag))
    return entries


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _dtype_tag(dt) -> str:
    if dt == jnp.float32:
        return "f32"
    if dt == jnp.int32:
        return "i32"
    raise ValueError(f"unsupported artifact dtype {dt}")


def _abstract_outputs(fn, arg_specs):
    out = jax.eval_shape(fn, *arg_specs)
    return list(out) if isinstance(out, (tuple, list)) else [out]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name substrings to rebuild")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = [f"# generated by compile.aot; batch={BATCH}"]
    entries = catalog()
    only = args.only.split(",") if args.only else None
    t_start = time.time()
    for i, (name, fn, arg_specs) in enumerate(entries):
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        skip = only is not None and not any(s in name for s in only)
        if not skip:
            t0 = time.time()
            text = to_hlo_text(fn, arg_specs)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:12]
            print(f"[{i + 1:2}/{len(entries)}] {name:28} {len(text) / 1e3:9.1f} kB "
                  f"{time.time() - t0:5.1f}s {digest}", flush=True)
        manifest_lines.append(f"artifact {name}")
        manifest_lines.append(f"file {name}.hlo.txt")
        for s in arg_specs:
            dims = ",".join(str(d) for d in s.shape) or "scalar"
            manifest_lines.append(f"in {_dtype_tag(s.dtype)} {dims}")
        for o in _abstract_outputs(fn, arg_specs):
            dims = ",".join(str(d) for d in o.shape) or "scalar"
            manifest_lines.append(f"out {_dtype_tag(o.dtype)} {dims}")
        manifest_lines.append("end")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(entries)} artifacts + manifest in {time.time() - t_start:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
