"""L1 — single-source Pallas kernels for the ported Caffe blocks.

One definition per op, used by both the per-layer artifacts and the fused
whole-net artifacts (see ``compile.aot``).  ``ref.py`` is the pure-jnp
oracle; ``python/tests/test_kernels.py`` sweeps every kernel against it.

Ops the paper did NOT port stay unimplemented here on purpose (dilation,
grouped / N-D convolution, top-k accuracy): Table 1's pass/fail structure
depends on their absence.  See ``check_conv_supported``.
"""

from . import common, ref
from .gemm import gemm, bgemm, bgemm_reduce, bias_rows, inner_product
from .im2col import im2col, col2im
from .pool import maxpool, maxpool_bwd, avepool, avepool_bwd
from .activations import (
    leaky_relu,
    leaky_relu_bwd,
    softmax,
    softmax_xent,
    softmax_xent_bwd,
    accuracy,
)


class Unported(NotImplementedError):
    """Raised for Caffe features outside the ported subset (paper §3/§4.2)."""


def check_conv_supported(*, num_spatial_axes: int = 2, dilation: tuple = (1, 1),
                         group: int = 1) -> None:
    """The port covers exactly what LeNet needs: dense 2-D convolution.

    Caffe's ConvolutionLayer also supports N-D convolution, dilation and
    grouped filters; the paper ported none of these (hence Conv passing only
    3/15 upstream tests).  Keeping the gate explicit lets the Rust
    conformance suite reproduce Table 1 honestly.
    """
    if num_spatial_axes != 2:
        raise Unported(f"N-D convolution (num_spatial_axes={num_spatial_axes}) not ported")
    if tuple(dilation) != (1, 1):
        raise Unported(f"dilated convolution (dilation={dilation}) not ported")
    if group != 1:
        raise Unported(f"grouped convolution (group={group}) not ported")


__all__ = [
    "common", "ref",
    "gemm", "bgemm", "bgemm_reduce", "bias_rows", "inner_product",
    "im2col", "col2im",
    "maxpool", "maxpool_bwd", "avepool", "avepool_bwd",
    "leaky_relu", "leaky_relu_bwd",
    "softmax", "softmax_xent", "softmax_xent_bwd", "accuracy",
    "Unported", "check_conv_supported",
]
