"""Elementwise / row-wise Pallas kernels: leaky ReLU, SoftMax, SoftMax-with-
loss, and the Accuracy reduction.

In the paper these are the *cheap* layers whose un-ported status causes the
domain-crossing traffic analysed in §4.3 — porting them is what removes the
unnecessary transfers.  Each is a trivially-parallel functor in PHAST terms;
here each is a single VPU-friendly Pallas program over the whole (padded)
block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


# ---------------------------------------------------------------------------
# Leaky ReLU (Caffe ReLULayer with negative_slope)
# ---------------------------------------------------------------------------

def _relu_kernel(x_ref, o_ref, *, alpha):
    x = x_ref[...]
    o_ref[...] = jnp.where(x > 0, x, alpha * x)


@functools.partial(jax.jit, static_argnames=("alpha",))
def leaky_relu(x: jnp.ndarray, alpha: float = 0.0) -> jnp.ndarray:
    return pl.pallas_call(
        functools.partial(_relu_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=common.INTERPRET,
    )(x)


def _relu_bwd_kernel(x_ref, dy_ref, o_ref, *, alpha):
    x = x_ref[...]
    dy = dy_ref[...]
    o_ref[...] = jnp.where(x > 0, dy, alpha * dy)


@functools.partial(jax.jit, static_argnames=("alpha",))
def leaky_relu_bwd(x: jnp.ndarray, dy: jnp.ndarray, alpha: float = 0.0) -> jnp.ndarray:
    return pl.pallas_call(
        functools.partial(_relu_bwd_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=common.INTERPRET,
    )(x, dy)


# ---------------------------------------------------------------------------
# SoftMax over rows of (N, C)
# ---------------------------------------------------------------------------

def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@jax.jit
def softmax(x: jnp.ndarray) -> jnp.ndarray:
    return pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=common.INTERPRET,
    )(x)


# ---------------------------------------------------------------------------
# SoftMax with loss (fwd: loss + probs; bwd: (p - onehot)/N)
# ---------------------------------------------------------------------------

def _softmax_xent_kernel(x_ref, lbl_ref, loss_ref, p_ref):
    x = x_ref[...]
    n, c = x.shape
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    p_ref[...] = p
    onehot = (lbl_ref[...][:, None] == jax.lax.iota(jnp.int32, c)[None, :])
    picked = jnp.sum(jnp.where(onehot, p, 0.0), axis=-1)
    tiny = jnp.finfo(x.dtype).tiny
    loss_ref[0] = -jnp.mean(jnp.log(jnp.maximum(picked, tiny)))


@jax.jit
def softmax_xent(x: jnp.ndarray, labels: jnp.ndarray):
    """(loss (1,), probs (N, C)) — Caffe SoftmaxWithLossLayer forward."""
    n, c = x.shape
    loss, p = pl.pallas_call(
        _softmax_xent_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((n, c), x.dtype),
        ),
        interpret=common.INTERPRET,
    )(x, labels)
    return loss, p


def _softmax_xent_bwd_kernel(p_ref, lbl_ref, o_ref):
    p = p_ref[...]
    n, c = p.shape
    onehot = (lbl_ref[...][:, None] == jax.lax.iota(jnp.int32, c)[None, :])
    o_ref[...] = (p - onehot.astype(p.dtype)) / n


@jax.jit
def softmax_xent_bwd(probs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return pl.pallas_call(
        _softmax_xent_bwd_kernel,
        out_shape=jax.ShapeDtypeStruct(probs.shape, probs.dtype),
        interpret=common.INTERPRET,
    )(probs, labels)


# ---------------------------------------------------------------------------
# Accuracy (top-1 only — the paper's port left top-k unimplemented, which is
# exactly why 3 of Caffe's 12 Accuracy tests fail in Table 1)
# ---------------------------------------------------------------------------

def _accuracy_kernel(x_ref, lbl_ref, o_ref):
    x = x_ref[...]
    pred = jnp.argmax(x, axis=-1).astype(jnp.int32)
    hit = (pred == lbl_ref[...]).astype(x.dtype)
    o_ref[0] = jnp.mean(hit)


@jax.jit
def accuracy(x: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Top-1 accuracy over rows of (N, C); returns shape (1,)."""
    return pl.pallas_call(
        _accuracy_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=common.INTERPRET,
    )(x, labels)
