"""Shared helpers for the Pallas kernel suite.

All kernels in this package are written once, in Pallas, and lowered by the
L2 model code (``compile.model``) into HLO artifacts consumed by the Rust
coordinator.  This mirrors the paper's PHAST premise: a single high-level
source, retargeted by changing the compilation process (CPU ``interpret=True``
today, real TPU by flipping ``INTERPRET`` to False and compiling with a TPU
PJRT plugin).

The helpers here deal with the two recurring chores:

* rounding shapes up to MXU/VPU-friendly tile multiples (and padding /
  cropping around ``pallas_call``), and
* computing Caffe's sliding-window output geometry (Caffe uses *ceil* mode
  for pooling and *floor* mode for convolution).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

# Single switch for the whole kernel suite.  interpret=True lowers every
# pallas_call to plain HLO ops so the CPU PJRT client (and the Rust `xla`
# crate) can execute the artifacts.  On a real TPU deployment this becomes
# False and the same sources emit Mosaic kernels.
INTERPRET = True

# MXU systolic array is 128x128; VPU lanes are 8x128.  We tile matmuls to
# multiples of these so the same BlockSpecs are valid on hardware.
MXU_TILE = 128
SUBLANE = 8


def round_up(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m``."""
    return ((x + m - 1) // m) * m


def pick_block(dim: int, target: int = MXU_TILE, mult: int = SUBLANE) -> int:
    """Pick a block size for a dimension: the full (sublane-rounded) dim for
    small sizes, otherwise the MXU tile."""
    if dim >= target:
        return target
    return round_up(max(dim, 1), mult)


# GeMM tile caps.  LeNet-scale panels are small, so we let a tile cover the
# whole dimension up to these caps (multiples of the MXU tile); the VMEM
# working set stays well under budget (see vmem_bytes) while the grid-step
# count — the dominant dispatch overhead both in interpret mode and on
# hardware — drops to a handful.  Measured on the fused MNIST step:
# 128/128/128 tiles -> 1.67 s, 256/512/512 caps -> see EXPERIMENTS.md §Perf.
GEMM_BM_CAP = 256
GEMM_BN_CAP = 512
GEMM_BK_CAP = 512


def gemm_blocks(m: int, n: int, k: int) -> tuple[int, int, int]:
    """(bm, bn, bk) for a tiled matmul: cover small dims whole (rounded to
    sublane/MXU alignment), cap large dims."""
    bm = min(round_up(m, SUBLANE), GEMM_BM_CAP)
    bn = min(round_up(n, MXU_TILE), GEMM_BN_CAP)
    bk = min(round_up(k, SUBLANE), GEMM_BK_CAP)
    return bm, bn, bk


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Per-step VMEM working set of a (bm, bk) x (bk, bn) -> (bm, bn) tile."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def pad2d(x: jnp.ndarray, pad_h: int, pad_w: int, value: float = 0.0) -> jnp.ndarray:
    """Symmetrically zero/value-pad the trailing two axes of ``x``."""
    if pad_h == 0 and pad_w == 0:
        return x
    cfg = [(0, 0)] * (x.ndim - 2) + [(pad_h, pad_h), (pad_w, pad_w)]
    return jnp.pad(x, cfg, constant_values=value)


def pad_to(x: jnp.ndarray, shape: tuple[int, ...], value: float = 0.0) -> jnp.ndarray:
    """Pad ``x`` (at the end of every axis) out to ``shape``."""
    if tuple(x.shape) == tuple(shape):
        return x
    cfg = [(0, t - s) for s, t in zip(x.shape, shape)]
    return jnp.pad(x, cfg, constant_values=value)


@dataclasses.dataclass(frozen=True)
class WindowGeom:
    """Sliding-window geometry for one spatial axis."""

    size: int        # input extent (pre-padding)
    pad: int         # symmetric padding
    kernel: int      # window extent
    stride: int
    out: int         # number of window positions
    padded: int      # extent after symmetric padding
    slab: int        # extent required so every strided slab fits: k-1 + out*stride
    extra: int       # extra one-sided padding to reach ``slab``

    @property
    def total(self) -> int:
        return self.padded + self.extra


def conv_geom(size: int, kernel: int, stride: int, pad: int) -> WindowGeom:
    """Caffe convolution geometry (floor mode)."""
    out = (size + 2 * pad - kernel) // stride + 1
    if out <= 0:
        raise ValueError(f"convolution output collapsed: size={size} k={kernel} s={stride} p={pad}")
    padded = size + 2 * pad
    slab = kernel - 1 + out * stride
    return WindowGeom(size, pad, kernel, stride, out, padded, slab, max(0, slab - padded))


def pool_geom(size: int, kernel: int, stride: int, pad: int) -> WindowGeom:
    """Caffe pooling geometry (*ceil* mode, with the Caffe border clip: the
    last window must start inside the padded input)."""
    out = int(math.ceil((size + 2 * pad - kernel) / stride)) + 1
    if pad > 0 and (out - 1) * stride >= size + pad:
        out -= 1
    padded = size + 2 * pad
    slab = kernel - 1 + out * stride
    return WindowGeom(size, pad, kernel, stride, out, padded, slab, max(0, slab - padded))


def place_strided(plane: jnp.ndarray, i: int, j: int, sh: int, sw: int,
                  canvas_shape: tuple[int, ...]) -> jnp.ndarray:
    """Inverse of two nested :func:`strided_view`s: embed ``plane``
    (N, C, OH, OW) at offset (i, j) with strides (sh, sw) into a zero canvas
    of ``canvas_shape`` — built from pads/reshapes only (no scatter, which
    Pallas kernels cannot capture constants for)."""
    n, c, oh, ow = plane.shape
    _, _, ht, wt = canvas_shape
    blk = plane[:, :, :, None, :, None]
    blk = jnp.pad(blk, ((0, 0), (0, 0), (0, 0), (0, sh - 1), (0, 0), (0, sw - 1)))
    blk = blk.reshape(n, c, oh * sh, ow * sw)
    return jnp.pad(blk, ((0, 0), (0, 0), (i, ht - i - oh * sh),
                         (j, wt - j - ow * sw)))


def strided_view(slab: jnp.ndarray, out: int, stride: int, axis: int) -> jnp.ndarray:
    """Subsample ``slab`` with ``stride`` along ``axis`` without strided
    indexing (which Pallas refs do not support): reshape to (out, stride) and
    take phase 0.  ``slab`` must have extent ``out * stride`` along ``axis``."""
    shape = list(slab.shape)
    assert shape[axis] == out * stride, (shape, axis, out, stride)
    shape[axis : axis + 1] = [out, stride]
    v = slab.reshape(shape)
    idx = [slice(None)] * v.ndim
    idx[axis + 1] = 0
    return v[tuple(idx)]
