"""Tiled GeMM Pallas kernels — the hot spot of both Convolution and
InnerProduct (the paper maps *everything* onto GeMM, following Caffe).

TPU mapping of the paper's GPU reasoning (DESIGN.md §Hardware-Adaptation):
Caffe's GPU path tiles the GeMM over threadblocks with shared-memory
staging; here the BlockSpec grid stages operand panels through VMEM and
feeds the MXU.  Contraction grid axes (K, and the batch axis of the
reducing variant) are innermost/sequential so the f32 accumulator tile
stays resident across the whole reduction — the classic Pallas matmul
schedule.

Tile-size policy (EXPERIMENTS.md §Perf has the measurements):

* **Minimal padding.**  A dimension is split into the fewest blocks that
  respect the cap, each rounded to the 8-wide sublane; padding a 25-row
  panel to 128 quintuples the carried bytes, and in interpret mode (and in
  any loop-carried XLA while) every grid step pays for the whole padded
  buffer.
* **Whole-batch staging.**  The batched variants stage as many samples per
  grid step as fit the VMEM budget (``bb``), computing a small batched
  ``einsum`` per step.  LeNet-scale panels usually fit entirely, collapsing
  the grid to a handful of steps.

Three entry points, one kernel body:

* :func:`gemm`         — C = op(A) @ op(B), 2-D.
* :func:`bgemm`        — batched: either operand may be 2-D (broadcast) or
                         3-D (per-sample panel); out is (B, M, N).
* :func:`bgemm_reduce` — sum_b op(A_b) @ op(B_b) -> (M, N); the conv weight
                         gradient in one pass, no (B, M, N) intermediate.

``ta``/``tb`` transpose *tiles inside the kernel* (the BlockSpec fetches the
transposed panel), so backward passes never materialize a transposed copy —
the optimization the paper postponed ("reducing the number of copies made
at each operation", §5).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

# Per-step working-set budget (bytes).  On a real TPU this must fit the
# 16 MiB per-core VMEM (8 MiB leaves double-buffering headroom); under the
# CPU interpret path larger staged batches amortize the XLA grid loop, and
# 32 MiB measured fastest (1016 -> 911 ms on the CIFAR fused step; see
# EXPERIMENTS.md section Perf).  Flip to 8 MiB when INTERPRET is False.
VMEM_BUDGET = (32 if common.INTERPRET else 8) * 1024 * 1024

M_CAP, N_CAP, K_CAP = 256, 1024, 1024


def _split(dim: int, cap: int) -> int:
    """Smallest sublane-rounded block covering ``dim`` in ceil(dim/cap)
    pieces — minimal padding under the cap."""
    t = math.ceil(dim / cap)
    return common.round_up(math.ceil(dim / t), common.SUBLANE)


def _dims(shape, trans):
    """(rows, cols) of op(X) given the stored shape of X's last two axes."""
    r, c = shape[-2], shape[-1]
    return (c, r) if trans else (r, c)


def _mm_kernel(a_ref, b_ref, o_ref, *, ta, tb, k_axes, reduce_batch):
    """One output tile; accumulates over the grid axes in ``k_axes``."""
    first = jnp.bool_(True)
    for ax in k_axes:
        first = first & (pl.program_id(ax) == 0)

    @pl.when(first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    if ta:
        a = jnp.swapaxes(a, -1, -2)
    if tb:
        b = jnp.swapaxes(b, -1, -2)
    if a.ndim == 2 and b.ndim == 2:
        o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)
        return
    # Batched step: a and/or b carry a leading batch-block axis.
    spec_a = "zmk" if a.ndim == 3 else "mk"
    spec_b = "zkn" if b.ndim == 3 else "kn"
    spec_o = "mn" if reduce_batch else "zmn"
    prod = jnp.einsum(f"{spec_a},{spec_b}->{spec_o}", a, b,
                      preferred_element_type=jnp.float32)
    o_ref[...] += prod


def _pad_mat(x, trans, br, bc):
    """Zero-pad the trailing two axes so op(x) tiles evenly by (br, bc)."""
    rows = common.round_up(x.shape[-2], bc if trans else br)
    cols = common.round_up(x.shape[-1], br if trans else bc)
    return common.pad_to(x, (*x.shape[:-2], rows, cols))


@functools.partial(jax.jit, static_argnames=("ta", "tb"))
def gemm(a: jnp.ndarray, b: jnp.ndarray, *, ta: bool = False,
         tb: bool = False) -> jnp.ndarray:
    """C = op(A) @ op(B) for 2-D operands."""
    m, k = _dims(a.shape, ta)
    k2, n = _dims(b.shape, tb)
    assert k == k2, (a.shape, b.shape, ta, tb)
    bm, bn, bk = _split(m, M_CAP), _split(n, N_CAP), _split(k, K_CAP)
    assert common.vmem_bytes(bm, bn, bk) < 2 * VMEM_BUDGET, (bm, bn, bk)
    ap = _pad_mat(a, ta, bm, bk)
    bp = _pad_mat(b, tb, bk, bn)
    mp, np_, kp = common.round_up(m, bm), common.round_up(n, bn), common.round_up(k, bk)

    a_spec = (pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)) if ta
              else pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)))
    b_spec = (pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)) if tb
              else pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
    out = pl.pallas_call(
        functools.partial(_mm_kernel, ta=ta, tb=tb, k_axes=(2,), reduce_batch=False),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[a_spec, b_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=common.INTERPRET,
    )(ap, bp)
    return out[:m, :n]


def _batch_block(bsz, per_sample_bytes, fixed_bytes):
    """Samples per grid step under the VMEM budget."""
    room = max(VMEM_BUDGET - fixed_bytes, per_sample_bytes)
    return max(1, min(bsz, room // max(per_sample_bytes, 1)))


def _prep_batched(a, b, ta, tb, reduce_batch):
    """Shared shape/tiling logic for bgemm / bgemm_reduce."""
    a_batched = a.ndim == 3
    b_batched = b.ndim == 3
    bsz = a.shape[0] if a_batched else b.shape[0]
    m, k = _dims(a.shape, ta)
    k2, n = _dims(b.shape, tb)
    assert k == k2, (a.shape, b.shape, ta, tb)
    bm, bn, bk = _split(m, M_CAP), _split(n, N_CAP), _split(k, K_CAP)
    per_sample = 4 * ((bm * bk if a_batched else 0) + (bk * bn if b_batched else 0)
                      + (0 if reduce_batch else bm * bn))
    fixed = 4 * ((0 if a_batched else bm * bk) + (0 if b_batched else bk * bn)
                 + (bm * bn if reduce_batch else 0))
    bb = _batch_block(bsz, per_sample, fixed)
    bt = math.ceil(bsz / bb)
    ap = _pad_mat(a, ta, bm, bk)
    bp = _pad_mat(b, tb, bk, bn)
    if a_batched:
        ap = common.pad_to(ap, (bb * bt, *ap.shape[1:]))
    if b_batched:
        bp = common.pad_to(bp, (bb * bt, *bp.shape[1:]))
    mp = common.round_up(m, bm)
    np_ = common.round_up(n, bn)
    kp = common.round_up(k, bk)
    return (a_batched, b_batched, bsz, m, n, k, bm, bn, bk, bb, bt,
            ap, bp, mp, np_, kp)


@functools.partial(jax.jit, static_argnames=("ta", "tb"))
def bgemm(a: jnp.ndarray, b: jnp.ndarray, *, ta: bool = False,
          tb: bool = False) -> jnp.ndarray:
    """Batched matmul: out[n] = op(A[n] or A) @ op(B[n] or B).

    A 2-D operand is broadcast across the batch (e.g. the conv weight
    panel); a 3-D operand supplies one panel per sample.  Grid layout
    (Bt, Mt, Nt, Kt) with ``bb`` samples staged per step."""
    (a_b, b_b, bsz, m, n, k, bm, bn, bk, bb, bt,
     ap, bp, mp, np_, kp) = _prep_batched(a, b, ta, tb, False)

    if a_b:
        a_spec = (pl.BlockSpec((bb, bk, bm), lambda z, i, j, kk: (z, kk, i)) if ta
                  else pl.BlockSpec((bb, bm, bk), lambda z, i, j, kk: (z, i, kk)))
    else:
        a_spec = (pl.BlockSpec((bk, bm), lambda z, i, j, kk: (kk, i)) if ta
                  else pl.BlockSpec((bm, bk), lambda z, i, j, kk: (i, kk)))
    if b_b:
        b_spec = (pl.BlockSpec((bb, bn, bk), lambda z, i, j, kk: (z, j, kk)) if tb
                  else pl.BlockSpec((bb, bk, bn), lambda z, i, j, kk: (z, kk, j)))
    else:
        b_spec = (pl.BlockSpec((bn, bk), lambda z, i, j, kk: (j, kk)) if tb
                  else pl.BlockSpec((bk, bn), lambda z, i, j, kk: (kk, j)))

    out = pl.pallas_call(
        functools.partial(_mm_kernel, ta=ta, tb=tb, k_axes=(3,), reduce_batch=False),
        grid=(bt, mp // bm, np_ // bn, kp // bk),
        in_specs=[a_spec, b_spec],
        out_specs=pl.BlockSpec((bb, bm, bn), lambda z, i, j, kk: (z, i, j)),
        out_shape=jax.ShapeDtypeStruct((bb * bt, mp, np_), jnp.float32),
        interpret=common.INTERPRET,
    )(ap, bp)
    return out[:bsz, :m, :n]


@functools.partial(jax.jit, static_argnames=("ta", "tb"))
def bgemm_reduce(a: jnp.ndarray, b: jnp.ndarray, *, ta: bool = False,
                 tb: bool = False) -> jnp.ndarray:
    """sum_n op(A[n]) @ op(B[n]) -> (M, N).

    The batch axis is a *contraction* grid axis (innermost together with K),
    so the output tile accumulates in place — this computes conv dW without
    a (B, M, N) intermediate.  Grid layout (Mt, Nt, Bt, Kt)."""
    assert a.ndim == 3 and b.ndim == 3 and a.shape[0] == b.shape[0]
    (_, _, bsz, m, n, k, bm, bn, bk, bb, bt,
     ap, bp, mp, np_, kp) = _prep_batched(a, b, ta, tb, True)

    a_spec = (pl.BlockSpec((bb, bk, bm), lambda i, j, z, kk: (z, kk, i)) if ta
              else pl.BlockSpec((bb, bm, bk), lambda i, j, z, kk: (z, i, kk)))
    b_spec = (pl.BlockSpec((bb, bn, bk), lambda i, j, z, kk: (z, j, kk)) if tb
              else pl.BlockSpec((bb, bk, bn), lambda i, j, z, kk: (z, kk, j)))

    out = pl.pallas_call(
        functools.partial(_mm_kernel, ta=ta, tb=tb, k_axes=(2, 3), reduce_batch=True),
        grid=(mp // bm, np_ // bn, bt, kp // bk),
        in_specs=[a_spec, b_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, z, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=common.INTERPRET,
    )(ap, bp)
    return out[:m, :n]


def _bias_rows_kernel(m_ref, v_ref, o_ref):
    # The paper's matrixPlusVectorRows functor, lst. 1.2: one vector add per
    # matrix row; rows are the parallel axis.
    o_ref[...] = m_ref[...] + v_ref[...][None, :]


def bias_rows(m: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Add ``v`` to every row of ``m`` — Pallas functor analog."""
    return pl.pallas_call(
        _bias_rows_kernel,
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        interpret=common.INTERPRET,
    )(m, v)


def inner_product(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Caffe InnerProduct forward (lst. 1.2): x (M,K) @ w(N,K)^T + bias."""
    y = gemm(x, w, tb=True)
    return bias_rows(y, b)
