"""im2col / col2im as Pallas kernels (batch-aware, phase-unrolled).

The paper (§3.1) observes that Caffe's im2col is a penta-loop with
loop-carried index arithmetic, and that the PHAST port *merged the loops and
parameterized by a single index* so every element is independent.  The
Pallas formulation takes the same idea to its limit: **all** loops are
merged into a single program — the kh*kw window phases are statically
unrolled in the kernel body, and each phase moves the slab for every sample
and channel at once as one strided view.

Two earlier schedules are kept in the §Perf log (EXPERIMENTS.md):
 * grid (C, kh, kw) + vmap over batch  — the naive port, ~20x slower;
 * grid (kh, kw) with batched slabs    — faster, but the grid becomes an
   XLA while-loop whose carried buffers are copied every step on CPU.
The unrolled single-program version eliminates the loop entirely; on a real
TPU it is a single VMEM-resident program doing kh*kw vector moves.

Layout matches Caffe exactly: cols[n, (c*kh + i)*kw + j, oh*OW + ow].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _im2col_kernel(x_ref, o_ref, *, kh, kw, sh, sw, oh, ow):
    x = x_ref[...]                     # (N, C, Hp, Wp), VMEM-resident
    n, c = x.shape[0], x.shape[1]
    parts = []
    for i in range(kh):                # static unroll: kh*kw strided views
        for j in range(kw):
            slab = x[:, :, i : i + oh * sh, j : j + ow * sw]
            plane = common.strided_view(common.strided_view(slab, oh, sh, 2), ow, sw, 3)
            parts.append(plane.reshape(n, c, 1, oh * ow))
    o_ref[...] = jnp.concatenate(parts, axis=2)


@functools.partial(jax.jit, static_argnames=("kernel", "stride", "pad"))
def im2col(x: jnp.ndarray, kernel: tuple[int, int], stride: tuple[int, int],
           pad: tuple[int, int]) -> jnp.ndarray:
    """x: (N, C, H, W) -> (N, C*kh*kw, OH*OW), Caffe column layout."""
    n, c, h, w = x.shape
    kh, kw = kernel
    sh, sw = stride
    gh = common.conv_geom(h, kh, sh, pad[0])
    gw = common.conv_geom(w, kw, sw, pad[1])
    # Pad symmetrically for the convolution, then a touch more on the
    # bottom/right so every (i, j) slab of extent (OH*sh, OW*sw) is in-bounds.
    xp = jnp.pad(x, ((0, 0), (0, 0), (gh.pad, gh.pad + gh.extra),
                     (gw.pad, gw.pad + gw.extra)))
    kern = functools.partial(_im2col_kernel, kh=kh, kw=kw, sh=sh, sw=sw,
                             oh=gh.out, ow=gw.out)
    cols = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, c, kh * kw, gh.out * gw.out), x.dtype),
        interpret=common.INTERPRET,
    )(xp)
    return cols.reshape(n, c * kh * kw, gh.out * gw.out)


def _col2im_kernel(c_ref, o_ref, *, kh, kw, sh, sw, oh, ow):
    cc = c_ref[...]                    # (N, C, KK, OHW)
    n, c = cc.shape[0], cc.shape[1]
    out = jnp.zeros(o_ref.shape, cc.dtype)
    for i in range(kh):                # static unroll: pad-placed adds
        for j in range(kw):
            plane = cc[:, :, i * kw + j, :].reshape(n, c, oh, ow)
            out = out + common.place_strided(plane, i, j, sh, sw, out.shape)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("channels", "size", "kernel", "stride", "pad"))
def col2im(cols: jnp.ndarray, channels: int, size: tuple[int, int],
           kernel: tuple[int, int], stride: tuple[int, int],
           pad: tuple[int, int]) -> jnp.ndarray:
    """Adjoint of :func:`im2col`: (N, C*kh*kw, OH*OW) -> (N, C, H, W)."""
    n = cols.shape[0]
    h, w = size
    kh, kw = kernel
    sh, sw = stride
    gh = common.conv_geom(h, kh, sh, pad[0])
    gw = common.conv_geom(w, kw, sw, pad[1])
    kern = functools.partial(_col2im_kernel, kh=kh, kw=kw, sh=sh, sw=sw,
                             oh=gh.out, ow=gw.out)
    canvas = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, channels, gh.total, gw.total), cols.dtype),
        interpret=common.INTERPRET,
    )(cols.reshape(n, channels, kh * kw, gh.out * gw.out))
    return canvas[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w]
