"""Max / average pooling as Pallas kernels (Caffe ceil-mode geometry,
batch-aware, phase-unrolled).

Same "merge all the loops" structure as im2col (§3.1/§3.3 of the paper):
each kernel is a single program whose kh*kw window phases are statically
unrolled over (N, C, OH, OW) strided views.  Caffe's MAX pooling records the
winning element for the backward scatter — we record the *window phase*
i*kw + j, which routes identically and keeps the argmax tensor the same
shape as the output.

The paper's §3.3 notes that their Pooling port parallelized only the outer
loop because merging all loops was not verified to be safe; the Pallas
formulation is the merged version, and the property tests
(python/tests/test_kernels.py, rust propcheck pooling suite) supply the
verification the paper deferred.

All scaling (the AVE divisor) happens *outside* the kernels: pure
accumulation bodies survive the HLO-text interchange, and the divisor table
is a trace-time constant (printed in full — see aot.to_hlo_text).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common, ref


def _phase_view(x, i, j, sh, sw, oh, ow):
    slab = x[:, :, i : i + oh * sh, j : j + ow * sw]
    return common.strided_view(common.strided_view(slab, oh, sh, 2), ow, sw, 3)


def _maxpool_kernel(x_ref, v_ref, a_ref, *, kh, kw, sh, sw, oh, ow):
    x = x_ref[...]
    best = _phase_view(x, 0, 0, sh, sw, oh, ow)
    arg = jnp.zeros(best.shape, jnp.int32)
    for i in range(kh):
        for j in range(kw):
            if i == 0 and j == 0:
                continue
            plane = _phase_view(x, i, j, sh, sw, oh, ow)
            take = plane > best
            best = jnp.where(take, plane, best)
            arg = jnp.where(take, i * kw + j, arg)
    v_ref[...] = best
    a_ref[...] = arg


@functools.partial(jax.jit, static_argnames=("kernel", "stride", "pad"))
def maxpool(x: jnp.ndarray, kernel, stride, pad):
    """x: (N,C,H,W) -> (vals (N,C,OH,OW), argmax phase (N,C,OH,OW) i32)."""
    n, c, h, w = x.shape
    kh, kw = kernel
    gh = common.pool_geom(h, kh, stride[0], pad[0])
    gw = common.pool_geom(w, kw, stride[1], pad[1])
    neg = jnp.asarray(-jnp.inf, x.dtype)
    xp = jnp.full((n, c, gh.total, gw.total), neg, x.dtype)
    xp = xp.at[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w].set(x)
    kern = functools.partial(_maxpool_kernel, kh=kh, kw=kw, sh=gh.stride,
                             sw=gw.stride, oh=gh.out, ow=gw.out)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((n, c, gh.out, gw.out), x.dtype),
            jax.ShapeDtypeStruct((n, c, gh.out, gw.out), jnp.int32),
        ),
        interpret=common.INTERPRET,
    )(xp)


def _scatter_phases_kernel(contribs, o_ref, kh, kw, sh, sw, oh, ow):
    """Sum per-phase contributions into the strided canvas positions
    (pad-placement, no scatter — see common.place_strided)."""
    out = jnp.zeros(o_ref.shape, o_ref.dtype)
    for i in range(kh):
        for j in range(kw):
            out = out + common.place_strided(contribs(i, j), i, j, sh, sw, out.shape)
    o_ref[...] = out


def _maxpool_bwd_kernel(dy_ref, a_ref, o_ref, *, kh, kw, sh, sw, oh, ow):
    dy = dy_ref[...]
    arg = a_ref[...]
    _scatter_phases_kernel(
        lambda i, j: jnp.where(arg == i * kw + j, dy, 0.0),
        o_ref, kh, kw, sh, sw, oh, ow,
    )


@functools.partial(jax.jit, static_argnames=("size", "kernel", "stride", "pad"))
def maxpool_bwd(dy: jnp.ndarray, arg: jnp.ndarray, size, kernel, stride, pad):
    """Scatter pooled gradients back to (N,C,H,W) through the argmax phases."""
    h, w = size
    n, c = dy.shape[0], dy.shape[1]
    kh, kw = kernel
    gh = common.pool_geom(h, kh, stride[0], pad[0])
    gw = common.pool_geom(w, kw, stride[1], pad[1])
    kern = functools.partial(_maxpool_bwd_kernel, kh=kh, kw=kw, sh=gh.stride,
                             sw=gw.stride, oh=gh.out, ow=gw.out)
    canvas = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, c, gh.total, gw.total), dy.dtype),
        interpret=common.INTERPRET,
    )(dy, arg)
    return canvas[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w]


def _sumpool_kernel(x_ref, v_ref, *, kh, kw, sh, sw, oh, ow):
    x = x_ref[...]
    acc = _phase_view(x, 0, 0, sh, sw, oh, ow)
    for i in range(kh):
        for j in range(kw):
            if i == 0 and j == 0:
                continue
            acc = acc + _phase_view(x, i, j, sh, sw, oh, ow)
    v_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("kernel", "stride", "pad"))
def avepool(x: jnp.ndarray, kernel, stride, pad):
    """Caffe AVE pooling: windowed sum / clipped window area (the divisor is
    a trace-time constant — geometry only)."""
    n, c, h, w = x.shape
    kh, kw = kernel
    gh = common.pool_geom(h, kh, stride[0], pad[0])
    gw = common.pool_geom(w, kw, stride[1], pad[1])
    xp = jnp.zeros((n, c, gh.total, gw.total), x.dtype)
    xp = xp.at[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w].set(x)
    inv_div = jnp.asarray(1.0 / ref.ave_divisor(h, w, kernel, stride, pad))
    kern = functools.partial(_sumpool_kernel, kh=kh, kw=kw, sh=gh.stride,
                             sw=gw.stride, oh=gh.out, ow=gw.out)
    sums = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, c, gh.out, gw.out), x.dtype),
        interpret=common.INTERPRET,
    )(xp)
    return sums * inv_div[None, None, :, :]


def _avepool_bwd_kernel(dy_ref, o_ref, *, kh, kw, sh, sw, oh, ow):
    dy = dy_ref[...]  # pre-scaled by the inverse divisor
    _scatter_phases_kernel(lambda _i, _j: dy, o_ref, kh, kw, sh, sw, oh, ow)


@functools.partial(jax.jit, static_argnames=("size", "kernel", "stride", "pad"))
def avepool_bwd(dy: jnp.ndarray, size, kernel, stride, pad):
    h, w = size
    n, c = dy.shape[0], dy.shape[1]
    kh, kw = kernel
    gh = common.pool_geom(h, kh, stride[0], pad[0])
    gw = common.pool_geom(w, kw, stride[1], pad[1])
    inv_div = jnp.asarray(1.0 / ref.ave_divisor(h, w, kernel, stride, pad))
    scaled = dy * inv_div[None, None, :, :]
    kern = functools.partial(_avepool_bwd_kernel, kh=kh, kw=kw, sh=gh.stride,
                             sw=gw.stride, oh=gh.out, ow=gw.out)
    canvas = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, c, gh.total, gw.total), dy.dtype),
        interpret=common.INTERPRET,
    )(scaled)
    return canvas[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w]
