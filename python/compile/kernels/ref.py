"""Pure-jnp reference oracle for every Pallas kernel in this package.

These are deliberately written with plain ``jax.numpy`` (no Pallas, no
clever tiling) in Caffe semantics so that ``pytest python/tests`` can assert
``kernels.<op>(...) == ref.<op>(...)`` over hypothesis-generated shapes.
The Rust native baseline (``rust/src/ops``) implements the same semantics a
third time; the integration tests close the triangle.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import common


# ---------------------------------------------------------------------------
# GeMM / InnerProduct
# ---------------------------------------------------------------------------

def gemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B, f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def inner_product(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Caffe InnerProduct forward: y[m, n] = sum_k x[m, k] w[n, k] + b[n].

    ``w`` is stored Caffe-style as (num_output, K)."""
    return jnp.matmul(x, w.T, preferred_element_type=jnp.float32) + b[None, :]


def bias_rows(m: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """The paper's ``matrixPlusVectorRows`` functor: add ``v`` to every row."""
    return m + v[None, :]


# ---------------------------------------------------------------------------
# im2col / col2im (Caffe layout: row = c*kh*kw + i*kw + j, col = oh*OW + ow)
# ---------------------------------------------------------------------------

def im2col(x: jnp.ndarray, kernel: tuple[int, int], stride: tuple[int, int],
           pad: tuple[int, int]) -> jnp.ndarray:
    """x: (N, C, H, W) -> cols: (N, C*kh*kw, OH*OW)."""
    n, c, h, w = x.shape
    kh, kw = kernel
    sh, sw = stride
    ph, pw = pad
    gh = common.conv_geom(h, kh, sh, ph)
    gw = common.conv_geom(w, kw, sw, pw)
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    rows = []
    for i in range(kh):
        for j in range(kw):
            sl = xp[:, :, i : i + (gh.out - 1) * sh + 1 : sh,
                    j : j + (gw.out - 1) * sw + 1 : sw]
            rows.append(sl.reshape(n, c, gh.out * gw.out))
    # rows is kh*kw entries of (N, C, OHW); want (N, C*kh*kw, OHW) with
    # row-major (c, i, j) ordering.
    stacked = jnp.stack(rows, axis=2)  # (N, C, kh*kw, OHW)
    return stacked.reshape(n, c * kh * kw, gh.out * gw.out)


def col2im(cols: jnp.ndarray, channels: int, size: tuple[int, int],
           kernel: tuple[int, int], stride: tuple[int, int],
           pad: tuple[int, int]) -> jnp.ndarray:
    """Adjoint of :func:`im2col` — scatter-add columns back to (N, C, H, W)."""
    h, w = size
    kh, kw = kernel
    sh, sw = stride
    ph, pw = pad
    gh = common.conv_geom(h, kh, sh, ph)
    gw = common.conv_geom(w, kw, sw, pw)
    n = cols.shape[0]
    out = jnp.zeros((n, channels, h + 2 * ph, w + 2 * pw), dtype=cols.dtype)
    cc = cols.reshape(n, channels, kh * kw, gh.out, gw.out)
    for i in range(kh):
        for j in range(kw):
            out = out.at[:, :, i : i + (gh.out - 1) * sh + 1 : sh,
                         j : j + (gw.out - 1) * sw + 1 : sw].add(cc[:, :, i * kw + j])
    return out[:, :, ph : ph + h, pw : pw + w]


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
           stride: tuple[int, int], pad: tuple[int, int]) -> jnp.ndarray:
    """Caffe Convolution forward.

    x: (N, C, H, W); w: (Cout, C, kh, kw); b: (Cout,) -> (N, Cout, OH, OW)."""
    n = x.shape[0]
    cout, cin, kh, kw = w.shape
    gh = common.conv_geom(x.shape[2], kh, stride[0], pad[0])
    gw = common.conv_geom(x.shape[3], kw, stride[1], pad[1])
    cols = im2col(x, (kh, kw), stride, pad)
    wmat = w.reshape(cout, cin * kh * kw)
    y = jnp.einsum("ok,nkp->nop", wmat, cols) + b[None, :, None]
    return y.reshape(n, cout, gh.out, gw.out)


# ---------------------------------------------------------------------------
# Pooling (Caffe ceil mode with border clip)
# ---------------------------------------------------------------------------

def _pool_windows(h, w, kernel, stride, pad):
    kh, kw = kernel
    sh, sw = stride
    ph, pw = pad
    gh = common.pool_geom(h, kh, sh, ph)
    gw = common.pool_geom(w, kw, sw, pw)
    return gh, gw


def maxpool(x: jnp.ndarray, kernel, stride, pad):
    """x: (N, C, H, W) -> (vals, argmax i32), both (N, C, OH, OW).

    argmax is the *window phase* index i*kw + j of the winning element, ties
    resolved in scan order (first wins), matching Caffe's h-then-w scan."""
    n, c, h, w = x.shape
    kh, kw = kernel
    gh, gw = _pool_windows(h, w, kernel, stride, pad)
    neg = jnp.asarray(-jnp.inf, x.dtype)
    xp = jnp.full((n, c, gh.total, gw.total), neg, x.dtype)
    xp = xp.at[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w].set(x)
    best = jnp.full((n, c, gh.out, gw.out), neg, x.dtype)
    arg = jnp.zeros((n, c, gh.out, gw.out), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            sl = xp[:, :, i : i + (gh.out - 1) * gh.stride + 1 : gh.stride,
                    j : j + (gw.out - 1) * gw.stride + 1 : gw.stride]
            take = sl > best
            arg = jnp.where(take, i * kw + j, arg)
            best = jnp.where(take, sl, best)
    return best, arg


def maxpool_bwd(dy: jnp.ndarray, arg: jnp.ndarray, size, kernel, stride, pad):
    """Route pooled gradients back through the recorded argmax phases."""
    h, w = size
    n, c = dy.shape[0], dy.shape[1]
    kh, kw = kernel
    gh, gw = _pool_windows(h, w, kernel, stride, pad)
    out = jnp.zeros((n, c, gh.total, gw.total), dy.dtype)
    for i in range(kh):
        for j in range(kw):
            contrib = jnp.where(arg == i * kw + j, dy, 0.0)
            out = out.at[:, :, i : i + (gh.out - 1) * gh.stride + 1 : gh.stride,
                         j : j + (gw.out - 1) * gw.stride + 1 : gw.stride].add(contrib)
    return out[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w]


def ave_divisor(h, w, kernel, stride, pad) -> np.ndarray:
    """Caffe AVE pooling divisor: window area clipped to the padded canvas
    (padding cells count, overhang beyond pad does not)."""
    kh, kw = kernel
    gh, gw = _pool_windows(h, w, kernel, stride, pad)
    div = np.zeros((gh.out, gw.out), np.float32)
    for a in range(gh.out):
        hs = a * gh.stride - gh.pad
        he = min(hs + kh, h + gh.pad)
        hs = max(hs, -gh.pad)
        for b in range(gw.out):
            ws = b * gw.stride - gw.pad
            we = min(ws + kw, w + gw.pad)
            ws = max(ws, -gw.pad)
            div[a, b] = (he - hs) * (we - ws)
    return div


def avepool(x: jnp.ndarray, kernel, stride, pad):
    """Caffe AVE pooling: sum of real elements / clipped window area."""
    n, c, h, w = x.shape
    kh, kw = kernel
    gh, gw = _pool_windows(h, w, kernel, stride, pad)
    xp = jnp.zeros((n, c, gh.total, gw.total), x.dtype)
    xp = xp.at[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w].set(x)
    acc = jnp.zeros((n, c, gh.out, gw.out), x.dtype)
    for i in range(kh):
        for j in range(kw):
            acc = acc + xp[:, :, i : i + (gh.out - 1) * gh.stride + 1 : gh.stride,
                           j : j + (gw.out - 1) * gw.stride + 1 : gw.stride]
    return acc / jnp.asarray(ave_divisor(h, w, kernel, stride, pad))


def avepool_bwd(dy: jnp.ndarray, size, kernel, stride, pad):
    h, w = size
    n, c = dy.shape[0], dy.shape[1]
    kh, kw = kernel
    gh, gw = _pool_windows(h, w, kernel, stride, pad)
    scaled = dy / jnp.asarray(ave_divisor(h, w, kernel, stride, pad))
    out = jnp.zeros((n, c, gh.total, gw.total), dy.dtype)
    for i in range(kh):
        for j in range(kw):
            out = out.at[:, :, i : i + (gh.out - 1) * gh.stride + 1 : gh.stride,
                         j : j + (gw.out - 1) * gw.stride + 1 : gw.stride].add(scaled)
    return out[:, :, gh.pad : gh.pad + h, gw.pad : gw.pad + w]


# ---------------------------------------------------------------------------
# Activations / classification heads
# ---------------------------------------------------------------------------

def leaky_relu(x: jnp.ndarray, alpha: float) -> jnp.ndarray:
    return jnp.where(x > 0, x, alpha * x)


def leaky_relu_bwd(x: jnp.ndarray, dy: jnp.ndarray, alpha: float) -> jnp.ndarray:
    return jnp.where(x > 0, dy, alpha * dy)


def softmax(x: jnp.ndarray) -> jnp.ndarray:
    """Row-wise softmax over the class axis of (N, C)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_xent(x: jnp.ndarray, labels: jnp.ndarray):
    """(loss scalar, probs) — mean cross-entropy, Caffe SoftmaxWithLoss."""
    p = softmax(x)
    n = x.shape[0]
    picked = p[jnp.arange(n), labels]
    loss = -jnp.mean(jnp.log(jnp.maximum(picked, jnp.finfo(x.dtype).tiny)))
    return loss, p


def softmax_xent_bwd(probs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    n, c = probs.shape
    onehot = jnp.zeros_like(probs).at[jnp.arange(n), labels].set(1.0)
    return (probs - onehot) / n


def accuracy(x: jnp.ndarray, labels: jnp.ndarray, top_k: int = 1) -> jnp.ndarray:
    """Fraction of rows whose label is within the top-k scores."""
    idx = jnp.argsort(-x, axis=-1)[:, :top_k]
    hit = jnp.any(idx == labels[:, None], axis=-1)
    return jnp.mean(hit.astype(jnp.float32))
