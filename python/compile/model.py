"""L2 — the JAX compute graphs of the two paper networks, built *only* from
the L1 Pallas kernels in ``compile.kernels``.

The paper trains two LeNet-style nets with Caffe:

* ``lenet-mnist`` — 2x Convolution, 2x MAX Pooling, 2x InnerProduct + ReLU,
  SoftmaxWithLoss / Accuracy, on 28x28x1 inputs (Caffe's examples/mnist).
* ``cifar10-quick`` — 3x Convolution, 1x MAX + 2x AVE Pooling, ReLUs,
  2x InnerProduct, SoftmaxWithLoss / Accuracy, on 32x32x3 inputs.

Backward passes are composed *manually* from the backward kernels (exactly
like Caffe's handwritten ``Backward_cpu``), not with jax autodiff — every
gradient that flows is the product of the same single-source kernels the
forward pass uses.  ``python/tests/test_model.py`` checks them against
``jax.grad`` of the pure-jnp reference model.

Everything here is traced once by ``compile.aot`` and shipped to the Rust
coordinator as HLO text; nothing in this file runs at serving/training time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from . import kernels as K


# ---------------------------------------------------------------------------
# Batched layer ops (vmap over the per-sample Pallas kernels)
# ---------------------------------------------------------------------------

def conv2d_fwd(x, w, b, stride, pad, *, with_cols: bool = False):
    """x (N,C,H,W), w (Cout,C,kh,kw), b (Cout,) -> y (N,Cout,OH,OW).

    Caffe schedule: im2col + GeMM; the weight panel (Cout, C*kh*kw) is
    broadcast across the batch inside the batched GeMM kernel.  With
    ``with_cols=True`` also returns the column tensor so the backward pass
    can reuse it (Caffe shares ``col_buffer_`` the same way)."""
    K.check_conv_supported()
    n = x.shape[0]
    cout, cin, kh, kw = w.shape
    wmat = w.reshape(cout, cin * kh * kw)
    gh = K.common.conv_geom(x.shape[2], kh, stride[0], pad[0])
    gw = K.common.conv_geom(x.shape[3], kw, stride[1], pad[1])
    cols = K.im2col(x, (kh, kw), stride, pad)      # (N, CKK, OHW)
    y = K.bgemm(wmat, cols)                        # (N, Cout, OHW)
    y = (y + b[None, :, None]).reshape(n, cout, gh.out, gw.out)
    if with_cols:
        return y, cols
    return y


def conv2d_bwd(x, w, dy, stride, pad, cols=None):
    """Backward of :func:`conv2d_fwd` -> (dx, dw, db).

    All three products are batched GeMMs over the stashed column tensor:
        dW    = sum_n dY_n · cols_n^T      (bgemm_reduce, tb)
        dcols =        W^T · dY_n          (bgemm, ta)
        dX    = col2im(dcols)
    """
    K.check_conv_supported()
    n = x.shape[0]
    cout, cin, kh, kw = w.shape
    wmat = w.reshape(cout, cin * kh * kw)
    h, w_sz = x.shape[2], x.shape[3]
    if cols is None:
        cols = K.im2col(x, (kh, kw), stride, pad)
    dymat = dy.reshape(n, cout, -1)                          # (N, Cout, OHW)
    dw = K.bgemm_reduce(dymat, cols, tb=True)                # (Cout, CKK)
    dcols = K.bgemm(wmat, dymat, ta=True)                    # (N, CKK, OHW)
    dx = K.col2im(dcols, cin, (h, w_sz), (kh, kw), stride, pad)
    db = dy.sum(axis=(0, 2, 3))
    return dx, dw.reshape(w.shape), db


def maxpool_fwd(x, kernel, stride, pad):
    return K.maxpool(x, kernel, stride, pad)


def maxpool_bwd(dy, arg, size, kernel, stride, pad):
    return K.maxpool_bwd(dy, arg, size, kernel, stride, pad)


def avepool_fwd(x, kernel, stride, pad):
    return K.avepool(x, kernel, stride, pad)


def avepool_bwd(dy, size, kernel, stride, pad):
    return K.avepool_bwd(dy, size, kernel, stride, pad)


def ip_fwd(x, w, b):
    """x (N,K) @ w (Nout,K)^T + b."""
    return K.inner_product(x, w, b)


def ip_bwd(x, w, dy):
    """-> (dx, dw, db).  All three are GeMMs — the Caffe trick the paper
    quotes: 'its creators have mapped all possible operations to matrix
    multiplications'.  Transposes happen inside the kernel BlockSpecs, no
    copies."""
    dw = K.gemm(dy, x, ta=True)   # (Nout, K)
    dx = K.gemm(dy, w)            # (N, K)
    db = dy.sum(axis=0)
    return dx, dw, db


# ---------------------------------------------------------------------------
# Net definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    out_channels: int
    kernel: int
    stride: int = 1
    pad: int = 0


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    name: str
    method: str       # "max" | "ave"
    kernel: int
    stride: int
    pad: int = 0


@dataclasses.dataclass(frozen=True)
class IpSpec:
    name: str
    num_output: int


@dataclasses.dataclass(frozen=True)
class ReluSpec:
    name: str
    alpha: float = 0.0


@dataclasses.dataclass(frozen=True)
class NetDef:
    name: str
    in_shape: tuple[int, int, int]   # (C, H, W)
    num_classes: int
    stages: tuple


LENET_MNIST = NetDef(
    name="lenet-mnist",
    in_shape=(1, 28, 28),
    num_classes=10,
    stages=(
        ConvSpec("conv1", 20, 5),
        PoolSpec("pool1", "max", 2, 2),
        ConvSpec("conv2", 50, 5),
        PoolSpec("pool2", "max", 2, 2),
        IpSpec("ip1", 500),
        ReluSpec("relu1"),
        IpSpec("ip2", 10),
    ),
)

CIFAR10_QUICK = NetDef(
    name="cifar10-quick",
    in_shape=(3, 32, 32),
    num_classes=10,
    stages=(
        ConvSpec("conv1", 32, 5, 1, 2),
        PoolSpec("pool1", "max", 3, 2),
        ReluSpec("relu1"),
        ConvSpec("conv2", 32, 5, 1, 2),
        ReluSpec("relu2"),
        PoolSpec("pool2", "ave", 3, 2),
        ConvSpec("conv3", 64, 5, 1, 2),
        ReluSpec("relu3"),
        PoolSpec("pool3", "ave", 3, 2),
        IpSpec("ip1", 64),
        IpSpec("ip2", 10),
    ),
)

NETS = {n.name: n for n in (LENET_MNIST, CIFAR10_QUICK)}


def stage_shapes(net: NetDef) -> list[tuple[str, tuple[int, ...]]]:
    """Per-stage (C, H, W) (or flat (K,)) activation shapes, pre-batch."""
    c, h, w = net.in_shape
    flat = None
    out = [("data", (c, h, w))]
    for st in net.stages:
        if isinstance(st, ConvSpec):
            gh = K.common.conv_geom(h, st.kernel, st.stride, st.pad)
            gw = K.common.conv_geom(w, st.kernel, st.stride, st.pad)
            c, h, w = st.out_channels, gh.out, gw.out
            out.append((st.name, (c, h, w)))
        elif isinstance(st, PoolSpec):
            gh = K.common.pool_geom(h, st.kernel, st.stride, st.pad)
            gw = K.common.pool_geom(w, st.kernel, st.stride, st.pad)
            h, w = gh.out, gw.out
            out.append((st.name, (c, h, w)))
        elif isinstance(st, IpSpec):
            flat = st.num_output
            out.append((st.name, (flat,)))
            c, h, w = flat, 1, 1
        elif isinstance(st, ReluSpec):
            out.append((st.name, out[-1][1]))
    return out


def param_shapes(net: NetDef) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) of every learnable blob, Caffe order
    (weight then bias per layer)."""
    c, h, w = net.in_shape
    shapes = []
    for st in net.stages:
        if isinstance(st, ConvSpec):
            shapes.append((f"{st.name}.w", (st.out_channels, c, st.kernel, st.kernel)))
            shapes.append((f"{st.name}.b", (st.out_channels,)))
            gh = K.common.conv_geom(h, st.kernel, st.stride, st.pad)
            gw = K.common.conv_geom(w, st.kernel, st.stride, st.pad)
            c, h, w = st.out_channels, gh.out, gw.out
        elif isinstance(st, PoolSpec):
            gh = K.common.pool_geom(h, st.kernel, st.stride, st.pad)
            gw = K.common.pool_geom(w, st.kernel, st.stride, st.pad)
            h, w = gh.out, gw.out
        elif isinstance(st, IpSpec):
            k = c * h * w
            shapes.append((f"{st.name}.w", (st.num_output, k)))
            shapes.append((f"{st.name}.b", (st.num_output,)))
            c, h, w = st.num_output, 1, 1
    return shapes


def init_params(net: NetDef, seed: int = 0) -> list[jnp.ndarray]:
    """Xavier(weight)/zero(bias) init — mirrors the Caffe prototxts; used by
    the python tests (the Rust coordinator owns the real initialization)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_shapes(net):
        if name.endswith(".b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            scale = (3.0 / fan_in) ** 0.5
            key, sub = jax.random.split(key)
            params.append(jax.random.uniform(sub, shape, jnp.float32, -scale, scale))
    return params


# ---------------------------------------------------------------------------
# Forward / backward composition
# ---------------------------------------------------------------------------

def net_forward(net: NetDef, x, params, *, stash: bool = False):
    """Run the net body to logits.  With ``stash=True`` also return the
    per-stage tensors the manual backward needs."""
    saved = []
    p = iter(params)
    cur = x
    c, h, w = net.in_shape
    for st in net.stages:
        if isinstance(st, ConvSpec):
            wgt, b = next(p), next(p)
            x_in = cur
            cur, cols = conv2d_fwd(cur, wgt, b, (st.stride, st.stride),
                                   (st.pad, st.pad), with_cols=True)
            saved.append(("conv", st, (x_in, cols), wgt))
            c, h, w = cur.shape[1], cur.shape[2], cur.shape[3]
        elif isinstance(st, PoolSpec):
            if st.method == "max":
                cur, arg = maxpool_fwd(cur, (st.kernel, st.kernel),
                                       (st.stride, st.stride), (st.pad, st.pad))
                saved.append(("maxpool", st, (h, w), arg))
            else:
                saved.append(("avepool", st, (h, w), None))
                cur = avepool_fwd(cur, (st.kernel, st.kernel),
                                  (st.stride, st.stride), (st.pad, st.pad))
            h, w = cur.shape[2], cur.shape[3]
        elif isinstance(st, IpSpec):
            wgt, b = next(p), next(p)
            flat = cur.reshape(cur.shape[0], -1)
            saved.append(("ip", st, (flat, cur.shape), wgt))
            cur = ip_fwd(flat, wgt, b)
            c, h, w = st.num_output, 1, 1
        elif isinstance(st, ReluSpec):
            saved.append(("relu", st, cur, None))
            if cur.ndim == 2:
                cur = K.leaky_relu(cur, st.alpha)
            else:
                n = cur.shape[0]
                cur = K.leaky_relu(cur.reshape(n, -1), st.alpha).reshape(cur.shape)
    if stash:
        return cur, saved
    return cur


def net_backward(net: NetDef, saved, dlogits):
    """Manual reverse sweep; returns grads in ``param_shapes`` order."""
    grads: list = []
    cur = dlogits
    for kind, st, aux, extra in reversed(saved):
        if kind == "ip":
            flat, orig_shape = aux
            dx, dw, db = ip_bwd(flat, extra, cur)
            grads.append(db)
            grads.append(dw)
            cur = dx.reshape(orig_shape)
        elif kind == "relu":
            x = aux
            if cur.ndim == 2:
                cur = K.leaky_relu_bwd(x, cur, st.alpha)
            else:
                n = cur.shape[0]
                cur = K.leaky_relu_bwd(
                    x.reshape(n, -1), cur.reshape(n, -1), st.alpha
                ).reshape(cur.shape)
        elif kind == "maxpool":
            size = aux
            cur = maxpool_bwd(cur, extra, size, (st.kernel, st.kernel),
                              (st.stride, st.stride), (st.pad, st.pad))
        elif kind == "avepool":
            size = aux
            cur = avepool_bwd(cur, size, (st.kernel, st.kernel),
                              (st.stride, st.stride), (st.pad, st.pad))
        elif kind == "conv":
            (x, cols), wgt = aux, extra
            dx, dw, db = conv2d_bwd(x, wgt, cur, (st.stride, st.stride),
                                    (st.pad, st.pad), cols=cols)
            grads.append(db)
            grads.append(dw)
            cur = dx
    grads.reverse()
    return grads, cur


def net_loss_grads(net: NetDef, x, labels, params):
    """Forward + SoftmaxWithLoss + full manual backward.

    Returns (loss (1,), probs, grads list in param order)."""
    logits, saved = net_forward(net, x, params, stash=True)
    loss, probs = K.softmax_xent(logits, labels)
    dlogits = K.softmax_xent_bwd(probs, labels)
    grads, _ = net_backward(net, saved, dlogits)
    return loss, probs, grads


# ---------------------------------------------------------------------------
# AOT entry points (what aot.py lowers)
# ---------------------------------------------------------------------------

def make_infer_fn(net: NetDef) -> Callable:
    """x, *params -> (probs,)"""

    def infer(x, *params):
        logits = net_forward(net, x, list(params))
        return (K.softmax(logits),)

    return infer


def make_eval_fn(net: NetDef) -> Callable:
    """x, labels, *params -> (loss, accuracy, probs)"""

    def evaluate(x, labels, *params):
        logits = net_forward(net, x, list(params))
        loss, probs = K.softmax_xent(logits, labels)
        acc = K.accuracy(logits, labels)
        return (loss, acc, probs)

    return evaluate


def make_grads_fn(net: NetDef) -> Callable:
    """x, labels, *params -> (loss, *grads) — solver stays outside."""

    def grads_fn(x, labels, *params):
        loss, _probs, grads = net_loss_grads(net, x, labels, list(params))
        return (loss,) + tuple(grads)

    return grads_fn


def make_step_fn(net: NetDef) -> Callable:
    """The fully-fused train step — the paper's end state where every layer
    lives in one domain and no boundary is crossed:

        x, labels, lr, *(params + velocities)
            -> (loss, *(new_params + new_velocities))

    SGD with momentum/weight-decay exactly as Caffe's SGDSolver:
        v = momentum * v + lr * (grad + weight_decay * w);  w = w - v
    Momentum and weight decay are baked at trace time (solver constants);
    lr is a runtime scalar so the Rust solver can apply its lr policy.
    """
    momentum = 0.9
    weight_decay = 0.0005
    n_params = len(param_shapes(net))

    def step(x, labels, lr, *pv):
        params = list(pv[:n_params])
        vels = list(pv[n_params:])
        loss, _probs, grads = net_loss_grads(net, x, labels, params)
        new_p, new_v = [], []
        for w, v, g in zip(params, vels, grads):
            v2 = momentum * v + lr * (g + weight_decay * w)
            new_p.append(w - v2)
            new_v.append(v2)
        return (loss,) + tuple(new_p) + tuple(new_v)

    return step
