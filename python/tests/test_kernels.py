"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes/strides/pads; interpret-mode Pallas is slow, so
example counts are kept moderate and deadlines disabled.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st, HealthCheck

from compile import kernels as K
from compile.kernels import ref, common

SET = settings(max_examples=12, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def f32(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# GeMM family
# ---------------------------------------------------------------------------

@SET
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.booleans(), st.booleans(), st.integers(0, 2**31 - 1))
def test_gemm_vs_ref(m, k, n, ta, tb, seed):
    rng = np.random.default_rng(seed)
    a = f32(rng, *( (k, m) if ta else (m, k) ))
    b = f32(rng, *( (n, k) if tb else (k, n) ))
    want = (a.T if ta else a) @ (b.T if tb else b)
    got = K.gemm(a, b, ta=ta, tb=tb)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(st.integers(1, 6), st.integers(1, 24), st.integers(1, 24),
       st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_bgemm_broadcast_lhs(bsz, m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = f32(rng, m, k)
    b = f32(rng, bsz, k, n)
    np.testing.assert_allclose(K.bgemm(a, b), np.einsum("mk,bkn->bmn", a, b),
                               rtol=1e-4, atol=1e-4)


@SET
@given(st.integers(1, 6), st.integers(1, 24), st.integers(1, 24),
       st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_bgemm_batched_lhs_trans(bsz, m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = f32(rng, m, k)          # broadcast, transposed in-kernel
    dy = f32(rng, bsz, m, n)
    np.testing.assert_allclose(K.bgemm(a, dy, ta=True),
                               np.einsum("mk,bmn->bkn", a, dy),
                               rtol=1e-4, atol=1e-4)


@SET
@given(st.integers(1, 6), st.integers(1, 24), st.integers(1, 24),
       st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_bgemm_reduce(bsz, m, k, n, seed):
    rng = np.random.default_rng(seed)
    dy = f32(rng, bsz, m, n)
    cols = f32(rng, bsz, k, n)
    np.testing.assert_allclose(K.bgemm_reduce(dy, cols, tb=True),
                               np.einsum("bmn,bkn->mk", dy, cols),
                               rtol=1e-4, atol=1e-4)


def test_gemm_identity():
    eye = jnp.eye(17, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    a = f32(rng, 17, 17)
    np.testing.assert_allclose(K.gemm(a, eye), a, atol=1e-6)


def test_inner_product_bias():
    rng = np.random.default_rng(1)
    x, w, b = f32(rng, 9, 13), f32(rng, 5, 13), f32(rng, 5)
    np.testing.assert_allclose(K.inner_product(x, w, b),
                               ref.inner_product(x, w, b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# im2col / col2im
# ---------------------------------------------------------------------------

window = st.tuples(st.integers(1, 4), st.integers(1, 3), st.integers(0, 2))


@SET
@given(st.integers(1, 3), st.integers(1, 4), st.integers(5, 14),
       st.integers(5, 14), window, window, st.integers(0, 2**31 - 1))
def test_im2col_vs_ref(n, c, h, w, wh, ww, seed):
    (kh, sh, ph), (kw, sw, pw) = wh, ww
    if h + 2 * ph < kh or w + 2 * pw < kw:
        return
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c, h, w)
    got = K.im2col(x, (kh, kw), (sh, sw), (ph, pw))
    want = ref.im2col(x, (kh, kw), (sh, sw), (ph, pw))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@SET
@given(st.integers(1, 3), st.integers(1, 4), st.integers(5, 14),
       st.integers(5, 14), window, window, st.integers(0, 2**31 - 1))
def test_col2im_vs_ref(n, c, h, w, wh, ww, seed):
    (kh, sh, ph), (kw, sw, pw) = wh, ww
    if h + 2 * ph < kh or w + 2 * pw < kw:
        return
    rng = np.random.default_rng(seed)
    gh = common.conv_geom(h, kh, sh, ph)
    gw = common.conv_geom(w, kw, sw, pw)
    cols = f32(rng, n, c * kh * kw, gh.out * gw.out)
    got = K.col2im(cols, c, (h, w), (kh, kw), (sh, sw), (ph, pw))
    want = ref.col2im(cols, c, (h, w), (kh, kw), (sh, sw), (ph, pw))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@SET
@given(st.integers(1, 2), st.integers(1, 3), st.integers(5, 10),
       st.integers(5, 10), window, st.integers(0, 2**31 - 1))
def test_im2col_col2im_adjoint(n, c, h, w, wgeom, seed):
    """<im2col(x), y> == <x, col2im(y)> — the defining adjointness property
    the convolution backward pass relies on."""
    (k, s, p) = wgeom
    if h + 2 * p < k or w + 2 * p < k:
        return
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c, h, w)
    cols_x = K.im2col(x, (k, k), (s, s), (p, p))
    y = f32(rng, *cols_x.shape)
    lhs = float(jnp.vdot(cols_x, y))
    rhs = float(jnp.vdot(x, K.col2im(y, c, (h, w), (k, k), (s, s), (p, p))))
    assert abs(lhs - rhs) <= 1e-3 * max(1.0, abs(lhs))


def test_im2col_figure2_example():
    """Fig. 2/3 of the paper: 2x2 filter, stride 1, pad 0 over a 4x3 input
    (we use the transposed 3x4 reading so OH*OW = 2*3 = 6 columns)."""
    x = jnp.arange(12, dtype=jnp.float32).reshape(1, 1, 3, 4)
    cols = K.im2col(x, (2, 2), (1, 1), (0, 0))
    assert cols.shape == (1, 4, 6)
    np.testing.assert_array_equal(
        np.asarray(cols[0]),
        np.array([[0, 1, 2, 4, 5, 6],
                  [1, 2, 3, 5, 6, 7],
                  [4, 5, 6, 8, 9, 10],
                  [5, 6, 7, 9, 10, 11]], np.float32))


# ---------------------------------------------------------------------------
# Pooling
# ---------------------------------------------------------------------------

pool_geo = st.tuples(st.integers(2, 4), st.integers(1, 3), st.integers(0, 1))


@SET
@given(st.integers(1, 3), st.integers(1, 4), st.integers(6, 14),
       st.integers(6, 14), pool_geo, st.integers(0, 2**31 - 1))
def test_maxpool_fwd_bwd_vs_ref(n, c, h, w, geo, seed):
    k, s, p = geo
    if p >= k:
        return
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c, h, w)
    v1, a1 = K.maxpool(x, (k, k), (s, s), (p, p))
    v2, a2 = ref.maxpool(x, (k, k), (s, s), (p, p))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    dy = f32(rng, *v1.shape)
    g1 = K.maxpool_bwd(dy, a1, (h, w), (k, k), (s, s), (p, p))
    g2 = ref.maxpool_bwd(dy, a2, (h, w), (k, k), (s, s), (p, p))
    np.testing.assert_allclose(g1, g2, rtol=1e-6)


@SET
@given(st.integers(1, 3), st.integers(1, 4), st.integers(6, 14),
       st.integers(6, 14), pool_geo, st.integers(0, 2**31 - 1))
def test_avepool_fwd_bwd_vs_ref(n, c, h, w, geo, seed):
    k, s, p = geo
    if p >= k:
        return
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c, h, w)
    np.testing.assert_allclose(K.avepool(x, (k, k), (s, s), (p, p)),
                               ref.avepool(x, (k, k), (s, s), (p, p)),
                               rtol=1e-5, atol=1e-6)
    gh = common.pool_geom(h, k, s, p)
    gw = common.pool_geom(w, k, s, p)
    dy = f32(rng, n, c, gh.out, gw.out)
    np.testing.assert_allclose(
        K.avepool_bwd(dy, (h, w), (k, k), (s, s), (p, p)),
        ref.avepool_bwd(dy, (h, w), (k, k), (s, s), (p, p)),
        rtol=1e-5, atol=1e-6)


def test_maxpool_routes_gradient_to_argmax():
    x = jnp.zeros((1, 1, 4, 4), jnp.float32).at[0, 0, 1, 2].set(9.0)
    v, a = K.maxpool(x, (2, 2), (2, 2), (0, 0))
    assert float(v[0, 0, 0, 1]) == 9.0
    dy = jnp.ones((1, 1, 2, 2), jnp.float32)
    g = K.maxpool_bwd(dy, a, (4, 4), (2, 2), (2, 2), (0, 0))
    assert float(g[0, 0, 1, 2]) == 1.0
    # every window routes exactly one unit of gradient
    assert float(jnp.sum(g)) == 4.0


def test_pool_ceil_mode_geometry():
    """cifar10-quick pool1: 3x3 stride 2 on 32 -> ceil((32-3)/2)+1 = 16."""
    g = common.pool_geom(32, 3, 2, 0)
    assert g.out == 16
    # Caffe clip rule with padding: last window must start inside input+pad
    g2 = common.pool_geom(7, 3, 2, 1)
    assert g2.out == 4


# ---------------------------------------------------------------------------
# Activations / heads
# ---------------------------------------------------------------------------

@SET
@given(st.integers(1, 32), st.integers(1, 32),
       st.floats(0.0, 0.5), st.integers(0, 2**31 - 1))
def test_leaky_relu(n, c, alpha, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c)
    dy = f32(rng, n, c)
    np.testing.assert_allclose(K.leaky_relu(x, alpha), ref.leaky_relu(x, alpha))
    np.testing.assert_allclose(K.leaky_relu_bwd(x, dy, alpha),
                               ref.leaky_relu_bwd(x, dy, alpha))


@SET
@given(st.integers(1, 32), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_softmax_simplex(n, c, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c) * 10.0
    p = K.softmax(x)
    np.testing.assert_allclose(p, ref.softmax(x), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.sum(p, axis=-1)), np.ones(n),
                               rtol=1e-5)
    assert float(jnp.min(p)) >= 0.0


@SET
@given(st.integers(1, 32), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_softmax_xent_and_bwd(n, c, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c) * 3.0
    labels = jnp.asarray(rng.integers(0, c, size=n).astype(np.int32))
    loss, p = K.softmax_xent(x, labels)
    loss_r, p_r = ref.softmax_xent(x, labels)
    np.testing.assert_allclose(float(loss[0]), float(loss_r), rtol=1e-5)
    np.testing.assert_allclose(p, p_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(K.softmax_xent_bwd(p, labels),
                               ref.softmax_xent_bwd(p_r, labels),
                               rtol=1e-5, atol=1e-6)
    # gradient rows sum to zero (probability simplex tangent)
    g = np.asarray(K.softmax_xent_bwd(p, labels))
    np.testing.assert_allclose(g.sum(axis=1), np.zeros(n), atol=1e-6)


@SET
@given(st.integers(1, 48), st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_accuracy_top1(n, c, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng, n, c)
    labels = jnp.asarray(rng.integers(0, c, size=n).astype(np.int32))
    got = float(K.accuracy(x, labels)[0])
    want = float(ref.accuracy(x, labels, top_k=1))
    assert abs(got - want) < 1e-6


def test_softmax_xent_perfect_prediction():
    x = jnp.asarray([[100.0, 0.0], [0.0, 100.0]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    loss, _ = K.softmax_xent(x, labels)
    assert float(loss[0]) < 1e-6


# ---------------------------------------------------------------------------
# Unported-feature gate (Table 1 structure)
# ---------------------------------------------------------------------------

def test_conv_gate_rejects_unported():
    with pytest.raises(K.Unported):
        K.check_conv_supported(num_spatial_axes=3)
    with pytest.raises(K.Unported):
        K.check_conv_supported(dilation=(2, 2))
    with pytest.raises(K.Unported):
        K.check_conv_supported(group=2)
    K.check_conv_supported()  # the LeNet configuration passes
