"""L2 correctness: the manually-composed backward pass of each net against
``jax.grad`` of a pure-jnp reference model built from ``ref`` ops.

This is the python-side analog of the paper's validation methodology
(§4.2): compare losses, outputs and *intermediate tensors* between the
ported implementation and the reference.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

BATCH = 8


def ref_forward(net: M.NetDef, x, params):
    """Reference forward in plain jnp (autodiff-friendly)."""
    p = iter(params)
    cur = x
    for st in net.stages:
        if isinstance(st, M.ConvSpec):
            w, b = next(p), next(p)
            cur = ref.conv2d(cur, w, b, (st.stride, st.stride), (st.pad, st.pad))
        elif isinstance(st, M.PoolSpec):
            if st.method == "max":
                cur, _ = ref.maxpool(cur, (st.kernel, st.kernel),
                                     (st.stride, st.stride), (st.pad, st.pad))
            else:
                cur = ref.avepool(cur, (st.kernel, st.kernel),
                                  (st.stride, st.stride), (st.pad, st.pad))
        elif isinstance(st, M.IpSpec):
            w, b = next(p), next(p)
            cur = ref.inner_product(cur.reshape(cur.shape[0], -1), w, b)
        elif isinstance(st, M.ReluSpec):
            cur = ref.leaky_relu(cur, st.alpha)
    return cur


def ref_loss(net, x, labels, params):
    logits = ref_forward(net, x, params)
    loss, _ = ref.softmax_xent(logits, labels)
    return loss


def make_batch(net, seed=0):
    rng = np.random.default_rng(seed)
    c, h, w = net.in_shape
    x = jnp.asarray(rng.normal(size=(BATCH, c, h, w)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, net.num_classes, size=BATCH).astype(np.int32))
    return x, labels


@pytest.mark.parametrize("net", [M.LENET_MNIST, M.CIFAR10_QUICK],
                         ids=lambda n: n.name)
def test_forward_matches_reference(net):
    x, _ = make_batch(net)
    params = M.init_params(net, seed=3)
    got = M.net_forward(net, x, params)
    want = ref_forward(net, x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("net", [M.LENET_MNIST, M.CIFAR10_QUICK],
                         ids=lambda n: n.name)
def test_manual_grads_match_autodiff(net):
    """Every parameter gradient of the manual backward == jax.grad of the
    reference model (same math, independent derivation)."""
    x, labels = make_batch(net, seed=7)
    params = M.init_params(net, seed=11)
    loss, _probs, grads = M.net_loss_grads(net, x, labels, params)
    ref_val, ref_grads = jax.value_and_grad(
        lambda ps: ref_loss(net, x, labels, ps))(params)
    np.testing.assert_allclose(float(loss[0]), float(ref_val), rtol=1e-5)
    names = [n for n, _ in M.param_shapes(net)]
    for name, g, rg in zip(names, grads, ref_grads):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(rg), rtol=2e-3, atol=2e-5,
            err_msg=f"gradient mismatch for {net.name}:{name}")


def test_param_shapes_mnist():
    shapes = dict(M.param_shapes(M.LENET_MNIST))
    assert shapes["conv1.w"] == (20, 1, 5, 5)
    assert shapes["conv2.w"] == (50, 20, 5, 5)
    assert shapes["ip1.w"] == (500, 800)
    assert shapes["ip2.w"] == (10, 500)


def test_param_shapes_cifar():
    shapes = dict(M.param_shapes(M.CIFAR10_QUICK))
    assert shapes["conv1.w"] == (32, 3, 5, 5)
    assert shapes["conv3.w"] == (64, 32, 5, 5)
    assert shapes["ip1.w"] == (64, 1024)     # 64 * 4 * 4
    assert shapes["ip2.w"] == (10, 64)


def test_stage_shapes_mnist():
    shapes = dict(M.stage_shapes(M.LENET_MNIST))
    assert shapes["conv1"] == (20, 24, 24)
    assert shapes["pool1"] == (20, 12, 12)
    assert shapes["conv2"] == (50, 8, 8)
    assert shapes["pool2"] == (50, 4, 4)
    assert shapes["ip2"] == (10,)


def test_stage_shapes_cifar_ceil_mode():
    shapes = dict(M.stage_shapes(M.CIFAR10_QUICK))
    assert shapes["conv1"] == (32, 32, 32)   # pad 2 keeps 32
    assert shapes["pool1"] == (32, 16, 16)   # ceil((32-3)/2)+1 = 16
    assert shapes["pool2"] == (32, 8, 8)
    assert shapes["pool3"] == (64, 4, 4)


def test_step_fn_decreases_loss():
    """A few fused SGD steps on a fixed batch must reduce the loss."""
    net = M.LENET_MNIST
    x, labels = make_batch(net, seed=5)
    params = M.init_params(net, seed=5)
    vels = [jnp.zeros_like(p) for p in params]
    step = jax.jit(M.make_step_fn(net))
    losses = []
    for _ in range(10):
        out = step(x, labels, jnp.float32(0.01), *params, *vels)
        losses.append(float(out[0][0]))
        n = len(params)
        params = list(out[1 : 1 + n])
        vels = list(out[1 + n :])
    assert min(losses[5:]) < losses[0], losses


def test_eval_fn_outputs():
    net = M.LENET_MNIST
    x, labels = make_batch(net, seed=9)
    params = M.init_params(net, seed=9)
    loss, acc, probs = M.make_eval_fn(net)(x, labels, *params)
    assert loss.shape == (1,) and acc.shape == (1,)
    assert probs.shape == (BATCH, 10)
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1),
                               np.ones(BATCH), rtol=1e-5)
    assert 0.0 <= float(acc[0]) <= 1.0
