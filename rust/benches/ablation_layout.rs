//! Bench: §4.3 layout-conversion ablation — the paper suspects the
//! row-major/column-major relayout paid at every domain crossing is "the
//! biggest quote in the current gap breakdown".  Measure the paper
//! placement with and without the conversion.
//!
//! `cargo bench --bench ablation_layout`

use phast_caffe::experiments::{measure_placement, render_transfers};
use phast_caffe::phast::Placement;
use phast_caffe::proto::{presets, NetConfig};
use phast_caffe::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    for net in ["mnist", "cifar"] {
        let cfg = NetConfig::from_text(presets::net_by_name(net).unwrap())?;
        let reps = 5;
        let with = measure_placement(
            &engine,
            net,
            "with layout conversion",
            Placement::paper_partial(&cfg),
            true,
            reps,
        )?;
        let without = measure_placement(
            &engine,
            net,
            "without layout conversion",
            Placement::paper_partial(&cfg),
            false,
            reps,
        )?;
        println!("==== {net} (paper placement, {reps} reps) ====");
        print!("{}", render_transfers(&[with.clone(), without.clone()]));
        println!(
            "conversion cost: {:.2} ms/iter ({:.1}% of the partial-port time)\n",
            with.conversion_ms,
            100.0 * with.conversion_ms / with.mean_ms
        );
    }
    Ok(())
}
