//! Bench: §4.3 ablation — incremental-porting sweep.  Regenerates the
//! paper's transfer-count analysis: time and crossings as a function of the
//! ported layer set.
//!
//! `cargo bench --bench ablation_partial`

use phast_caffe::experiments::{porting_sweep, render_transfers};
use phast_caffe::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    for net in ["mnist", "cifar"] {
        println!("==== {net}: porting sweep (3 reps each) ====");
        let sweep = porting_sweep(&engine, net, 3)?;
        print!("{}", render_transfers(&sweep));
        println!();
    }
    println!("paper: ~10 (MNIST) / ~30 (CIFAR) unnecessary transfers per inference");
    Ok(())
}
