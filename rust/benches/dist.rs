//! Bench: elastic data-parallel training — what the dist coordinator
//! charges per all-reduced step, and the recovery-exactness flags the
//! CI gate pins.
//!
//! Entries merge-updated into `BENCH_threads.json` under the `dist` key
//! (see `metrics::bench_json`; `tools/check_bench.sh` gates them
//! against `BENCH_baseline.json`):
//!
//! * `ranks` / `iters` — deterministic workload shape (ranks gated
//!   exact: the workload must not change without a baseline update);
//! * `recoveries` — rollback-all recoveries in the chaos run (gated
//!   exactly at 1: the injected `worker_exit` must cost exactly one);
//! * `hash_match` — 1 iff the chaos run's final weights hash equals the
//!   clean run's (gated exactly at 1 — the elasticity acceptance pin:
//!   losing and respawning a worker is bitwise-invisible);
//! * `us_per_step` — clean-run wall clock per all-reduced iteration,
//!   including the pipe-framed gradient exchange (gated as a generous
//!   ceiling; CI runners vary wildly).
//!
//! `cargo bench --bench dist`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::metrics::bench_json;
use phast_caffe::runtime::dist::{self, DistConfig};

const RANKS: usize = 2;
const ITERS: usize = 8;
const BATCH: usize = 16;

fn cfg(tag: &str) -> anyhow::Result<DistConfig> {
    let dir = std::env::temp_dir().join(format!("phast_dist_bench_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut c = DistConfig::new(std::env::current_exe()?, dir);
    c.ranks = RANKS;
    c.iters = ITERS;
    c.batch = Some(BATCH);
    c.snapshot_every = 4;
    c.fault_spec = None; // the bench injects its own chaos below
    c.worker_env = vec![("PHAST_NUM_THREADS".into(), "1".into())];
    Ok(c)
}

fn main() -> anyhow::Result<()> {
    // This bench binary doubles as the worker executable: a child
    // spawned with PHAST_DIST_ROLE=worker never reaches the code below.
    dist::exec_worker_if_env();

    // Clean run: per-step cost of the coordinated loop (forward + fused
    // backward on each rank's shard, pipe-framed all-reduce, SGD step).
    let t0 = Instant::now();
    let clean = dist::train_dist(cfg("clean")?)?;
    let us_per_step = t0.elapsed().as_secs_f64() * 1e6 / ITERS as f64;

    // Chaos run: same shape, but rank 1 kills itself at iteration 3
    // (between the iter-0 and iter-4 checkpoints, so recovery replays
    // real steps).  Exactness = the hashes agree.
    let mut chaos_cfg = cfg("chaos")?;
    chaos_cfg.fault_spec = Some("worker_exit@iter=3".into());
    chaos_cfg.fault_rank = 1;
    let chaos = dist::train_dist(chaos_cfg)?;
    let hash_match = usize::from(chaos.weights_hash == clean.weights_hash);

    println!("dist: LeNet-MNIST, {RANKS} ranks x {ITERS} iters, global batch {BATCH}");
    println!("  clean: {us_per_step:.0} us/step (incl. pipe all-reduce)");
    println!(
        "  chaos (worker_exit@iter=3): recoveries={} hash_match={hash_match}",
        chaos.recoveries
    );
    println!(
        "  hashes: clean {:#010x} / chaos {:#010x}",
        clean.weights_hash, chaos.weights_hash
    );

    let mut entry = String::from("{\n");
    let _ = writeln!(entry, "    \"net\": \"lenet-mnist\",");
    let _ = writeln!(entry, "    \"ranks\": {RANKS},");
    let _ = writeln!(entry, "    \"iters\": {ITERS},");
    let _ = writeln!(entry, "    \"recoveries\": {},", chaos.recoveries);
    let _ = writeln!(entry, "    \"hash_match\": {hash_match},");
    let _ = writeln!(entry, "    \"us_per_step\": {us_per_step:.1}");
    entry.push_str("  }");

    bench_json::merge_entries(std::path::Path::new("BENCH_threads.json"), &[("dist", entry)])?;
    println!("\nmerged dist into BENCH_threads.json");
    Ok(())
}
