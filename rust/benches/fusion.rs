//! Bench: fused-vs-unfused region structure — how many parallel regions
//! (and how much wall-clock) one solver step and one forward sweep pay
//! under each fusion mode.
//!
//! Entries merge-updated into `BENCH_threads.json` (see
//! `metrics::bench_json`; the `threads_scaling` bench owns the other
//! keys, and `tools/check_bench.sh` gates both against
//! `BENCH_baseline.json`):
//!
//! * **`fused_sgd_step`** — the solver's SGD update over LeNet's 8
//!   parameter blobs: the unfused path issues three BLAS-1 regions per
//!   blob, the fused path one three-stage region per blob
//!   (`region_ratio` = unfused/fused regions, the 3→1 collapse), and
//!   `PHAST_FUSE_STEP`'s flat mode a single region for the whole step.
//! * **`fused_layers`** — full forward sweeps with the net's bias-add →
//!   activation fusion plan on vs off.
//!
//! `cargo bench --bench fusion`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::experiments::preset_net;
use phast_caffe::metrics::bench_json;
use phast_caffe::ops::par;
use phast_caffe::solver::{apply_sgd_update_mode, StepFusion};

/// Regions issued and mean µs per SGD update under `mode`.
fn measure_update(
    net: &mut phast_caffe::net::Net,
    history: &mut [Vec<f32>],
    mode: StepFusion,
    iters: usize,
) -> (u64, f64) {
    let (lr, momentum, decay) = (0.01f32, 0.9f32, 0.0005f32);
    // Warm once (grows the pool, faults in scratch).
    apply_sgd_update_mode(net.params_mut(), history, lr, momentum, decay, mode);
    let r0 = par::region_count();
    apply_sgd_update_mode(net.params_mut(), history, lr, momentum, decay, mode);
    let regions = par::region_count() - r0;
    let t0 = Instant::now();
    for _ in 0..iters {
        apply_sgd_update_mode(net.params_mut(), history, lr, momentum, decay, mode);
    }
    let us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    (regions, us)
}

/// Regions issued and mean ms per forward sweep with layer fusion on/off.
fn measure_forward(net: &mut phast_caffe::net::Net, fused: bool, iters: usize) -> (u64, f64) {
    net.set_layer_fusion(fused);
    net.forward().expect("forward");
    let r0 = par::region_count();
    net.forward().expect("forward");
    let regions = par::region_count() - r0;
    let t0 = Instant::now();
    for _ in 0..iters {
        net.forward().expect("forward");
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    (regions, ms)
}

fn main() -> anyhow::Result<()> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut net = preset_net("mnist", 17)?;
    let nblobs = net.params().len();
    // Real gradients so the update arithmetic is representative.
    net.zero_param_diffs();
    net.forward()?;
    net.backward()?;
    let mut history: Vec<Vec<f32>> =
        net.params().iter().map(|p| vec![0.0f32; p.count()]).collect();

    println!("fusion: LeNet-MNIST, {nblobs} param blobs, {hw} hw threads");
    let iters = 200usize;
    let (unfused_regions, unfused_us) =
        measure_update(&mut net, &mut history, StepFusion::Unfused, iters);
    let (fused_regions, fused_us) =
        measure_update(&mut net, &mut history, StepFusion::PerBlob, iters);
    let (flat_regions, flat_us) = measure_update(&mut net, &mut history, StepFusion::Flat, iters);
    let region_ratio = unfused_regions as f64 / fused_regions.max(1) as f64;
    println!("  sgd step regions: unfused {unfused_regions}, fused/blob {fused_regions}, flat {flat_regions}  ({region_ratio:.1}x fewer dispatches fused)");
    println!("  sgd step time:    unfused {unfused_us:.1} us, fused/blob {fused_us:.1} us, flat {flat_us:.1} us");

    // Layer fusion on CIFAR-quick: two conv→relu pairs in the plan, so
    // the fused forward issues measurably fewer regions per sweep.
    let mut cifar = preset_net("cifar", 17)?;
    let fwd_iters = 8usize;
    let (fwd_plain_regions, fwd_plain_ms) = measure_forward(&mut cifar, false, fwd_iters);
    let (fwd_fused_regions, fwd_fused_ms) = measure_forward(&mut cifar, true, fwd_iters);
    println!("  cifar forward regions: plain {fwd_plain_regions}, fused {fwd_fused_regions}");
    println!("  cifar forward time:    plain {fwd_plain_ms:.2} ms, fused {fwd_fused_ms:.2} ms");

    // Stage-barrier cost — the ROADMAP's `stage_unsynced` measure-first
    // item: a trivial 3-stage fused region vs a trivial 1-stage region at
    // the same width differ by exactly two stage-barrier crossings (the
    // pool dispatch itself is identical), so half the difference is the
    // per-stage barrier price a `stage_unsynced` variant could recover on
    // pointwise chains like the SGD stages.
    let workers = hw.max(2);
    let bar_tune = par::Tuning { threads: workers, grain: 1 };
    let sink = std::sync::atomic::AtomicUsize::new(0);
    let body = |r: std::ops::Range<usize>| {
        sink.fetch_add(r.end - r.start, std::sync::atomic::Ordering::Relaxed);
    };
    for _ in 0..16 {
        par::parallel_for(workers, bar_tune, body);
        par::parallel_regions(workers, 3, bar_tune, |_, r| body(r));
    }
    let bar_iters = 2000usize;
    let t0 = Instant::now();
    for _ in 0..bar_iters {
        par::parallel_for(workers, bar_tune, body);
    }
    let single_us = t0.elapsed().as_secs_f64() * 1e6 / bar_iters as f64;
    let t0 = Instant::now();
    for _ in 0..bar_iters {
        par::parallel_regions(workers, 3, bar_tune, |_, r| body(r));
    }
    let three_us = t0.elapsed().as_secs_f64() * 1e6 / bar_iters as f64;
    std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));
    let barrier_us = ((three_us - single_us) / 2.0).max(0.0);
    println!(
        "  stage barrier ({workers} workers): 1-stage {single_us:.2} us, 3-stage {three_us:.2} us \
         -> ~{barrier_us:.2} us per barrier"
    );

    let mut sgd = String::from("{\n");
    let _ = writeln!(sgd, "    \"param_blobs\": {nblobs},");
    let _ = writeln!(sgd, "    \"iters\": {iters},");
    let _ = writeln!(sgd, "    \"regions_unfused\": {unfused_regions},");
    let _ = writeln!(sgd, "    \"regions_fused_per_blob\": {fused_regions},");
    let _ = writeln!(sgd, "    \"regions_flat\": {flat_regions},");
    let _ = writeln!(sgd, "    \"region_ratio\": {region_ratio:.2},");
    let _ = writeln!(sgd, "    \"unfused_us_per_step\": {unfused_us:.1},");
    let _ = writeln!(sgd, "    \"fused_us_per_step\": {fused_us:.1},");
    let _ = writeln!(sgd, "    \"flat_us_per_step\": {flat_us:.1}");
    sgd.push_str("  }");

    let mut layers = String::from("{\n");
    let _ = writeln!(layers, "    \"net\": \"cifar10-quick\",");
    let _ = writeln!(layers, "    \"iters\": {fwd_iters},");
    let _ = writeln!(layers, "    \"regions_plain\": {fwd_plain_regions},");
    let _ = writeln!(layers, "    \"regions_fused\": {fwd_fused_regions},");
    let _ = writeln!(layers, "    \"plain_ms_per_fwd\": {fwd_plain_ms:.3},");
    let _ = writeln!(layers, "    \"fused_ms_per_fwd\": {fwd_fused_ms:.3}");
    layers.push_str("  }");

    let mut barrier = String::from("{\n");
    let _ = writeln!(barrier, "    \"workers\": {workers},");
    let _ = writeln!(barrier, "    \"iters\": {bar_iters},");
    let _ = writeln!(barrier, "    \"single_stage_us\": {single_us:.3},");
    let _ = writeln!(barrier, "    \"three_stage_us\": {three_us:.3},");
    let _ = writeln!(barrier, "    \"barrier_us_per_stage\": {barrier_us:.3},");
    let _ = writeln!(
        barrier,
        "    \"note\": \"stage_unsynced candidate (ROADMAP measure-first item): a barrier-free \
         variant for pointwise stage chains would save ~2x barrier_us_per_stage per fused 3-stage \
         region; act only if this rivals the pool's per-dispatch cost\""
    );
    barrier.push_str("  }");

    bench_json::merge_entries(
        std::path::Path::new("BENCH_threads.json"),
        &[("fused_sgd_step", sgd), ("fused_layers", layers), ("stage_barrier", barrier)],
    )?;
    println!("\nmerged fused_sgd_step + fused_layers + stage_barrier into BENCH_threads.json");
    Ok(())
}
