//! Bench: fused-vs-unfused region structure — how many parallel regions
//! (and how much wall-clock) one solver step and one forward sweep pay
//! under each fusion mode.
//!
//! Entries merge-updated into `BENCH_threads.json` (see
//! `metrics::bench_json`; the `threads_scaling` bench owns the other
//! keys, and `tools/check_bench.sh` gates both against
//! `BENCH_baseline.json`):
//!
//! * **`fused_sgd_step`** — the solver's SGD update over LeNet's 8
//!   parameter blobs: the unfused path issues three BLAS-1 regions per
//!   blob, the fused path one three-stage region per blob
//!   (`region_ratio` = unfused/fused regions, the 3→1 collapse), and
//!   `PHAST_FUSE_STEP`'s flat mode a single region for the whole step;
//!   plus the `stage_unsynced` route (`PHAST_FUSE_UNSYNC`) — same
//!   structure, no inter-stage barriers.
//! * **`fused_layers`** — full forward sweeps with the net's bias-add →
//!   activation fusion plan on vs off.
//! * **`fused_backward`** — LeNet backward sweeps at a pinned 4-thread
//!   width: the fused conv gradient region (one dispatch: gemm stages +
//!   col2im + deterministic merge) vs the dispatch-then-serial-merge
//!   reference (`PHAST_FUSE_BWD`); region counts gated exactly.
//! * **`planned_backward`** — the graph-level execution plan
//!   (`PHAST_PLAN`) vs the per-layer schedule at the same pinned width:
//!   the planned sweep fuses each pool scatter into its conv's gradient
//!   region and reports the plan's analytic scratch-arena peak
//!   (`peak_scratch_bytes`); planned region count and the scratch
//!   ceiling gated exactly.
//! * **`check_overhead`** — the access sanitizer (`PHAST_CHECK`) priced
//!   on the fused LeNet backward at the same pinned width: a reference
//!   pass and an "off" pass (sanitizer forced off) establish the
//!   zero-cost-off claim (`regions_delta` pinned exactly 0,
//!   `off_over_ref` gated at <= 1.05x), and an "on" pass reports what
//!   checked mode actually costs (informational — checked runs are a
//!   debugging tool, not a production mode).
//!
//! `cargo bench --bench fusion`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::experiments::preset_net;
use phast_caffe::metrics::bench_json;
use phast_caffe::ops::par;
use phast_caffe::solver::{apply_sgd_update_sync, StepFusion, StepSync};

/// Regions issued and mean µs per SGD update under `mode` + `sync`.
fn measure_update(
    net: &mut phast_caffe::net::Net,
    history: &mut [Vec<f32>],
    mode: StepFusion,
    sync: StepSync,
    iters: usize,
) -> (u64, f64) {
    let (lr, momentum, decay) = (0.01f32, 0.9f32, 0.0005f32);
    // Warm once (grows the pool, faults in scratch).
    apply_sgd_update_sync(net.params_mut(), history, lr, momentum, decay, mode, sync);
    let r0 = par::region_count();
    apply_sgd_update_sync(net.params_mut(), history, lr, momentum, decay, mode, sync);
    let regions = par::region_count() - r0;
    let t0 = Instant::now();
    for _ in 0..iters {
        apply_sgd_update_sync(net.params_mut(), history, lr, momentum, decay, mode, sync);
    }
    let us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    (regions, us)
}

/// Regions issued and mean ms per LeNet backward sweep with the fused
/// gradient regions on (one dispatch: gemm stages + col2im + merge) or
/// off (dispatch + serial merge reference).  Runs at a pinned thread
/// count so the region counts are machine-independent (they depend only
/// on the pass structure and the serial/parallel thresholds at that
/// width) and CI can gate them exactly.
fn measure_backward(net: &mut phast_caffe::net::Net, fused: bool, iters: usize) -> (u64, f64) {
    par::with_threads(4, || {
        // Pin the pre-planner schedule: this entry measures the per-layer
        // fusion knob alone; the graph-level plan gets its own entry
        // (`planned_backward`) below.
        net.set_plan(false);
        net.set_backward_fusion(fused);
        // Pin the pack-cache mode identically in both arms (the explicit
        // override also captures from this measurement's own forward, not
        // lazily), so the A/B isolates the *fusion* effect — otherwise
        // call ordering would hand the fused arm the packing win too.
        net.set_backward_packing(true);
        net.zero_param_diffs();
        net.forward().expect("forward");
        net.backward().expect("backward"); // warm
        let r0 = par::region_count();
        net.backward().expect("backward");
        let regions = par::region_count() - r0;
        let t0 = Instant::now();
        for _ in 0..iters {
            net.backward().expect("backward");
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        (regions, ms)
    })
}

/// Regions issued and mean ms per LeNet backward sweep with the
/// graph-level execution plan (`PHAST_PLAN`) on or off, backward fusion
/// on in both arms: the plan's own win over the per-layer schedule is
/// the fused pool→conv backward region (two pool dispatches absorbed on
/// LeNet) plus the shared scratch arena.
fn measure_planned(net: &mut phast_caffe::net::Net, plan: bool, iters: usize) -> (u64, f64) {
    par::with_threads(4, || {
        net.set_plan(plan);
        net.set_backward_fusion(true);
        net.set_backward_packing(true);
        net.zero_param_diffs();
        net.forward().expect("forward");
        net.backward().expect("backward"); // warm
        let r0 = par::region_count();
        net.backward().expect("backward");
        let regions = par::region_count() - r0;
        let t0 = Instant::now();
        for _ in 0..iters {
            net.backward().expect("backward");
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        (regions, ms)
    })
}

/// Min-of-`reps` fused-backward timing (plus the region count of the
/// last rep, identical across reps).  Min damps scheduler noise so the
/// off/reference ratio in the `check_overhead` entry can carry a tight
/// 1.05x gate even on loaded CI runners.
fn measure_backward_min(
    net: &mut phast_caffe::net::Net,
    iters: usize,
    reps: usize,
) -> (u64, f64) {
    let mut best = f64::INFINITY;
    let mut regions = 0u64;
    for _ in 0..reps {
        let (r, ms) = measure_backward(net, true, iters);
        regions = r;
        best = best.min(ms);
    }
    (regions, best)
}

/// Regions issued and mean ms per forward sweep with layer fusion on/off.
fn measure_forward(net: &mut phast_caffe::net::Net, fused: bool, iters: usize) -> (u64, f64) {
    net.set_layer_fusion(fused);
    net.forward().expect("forward");
    let r0 = par::region_count();
    net.forward().expect("forward");
    let regions = par::region_count() - r0;
    let t0 = Instant::now();
    for _ in 0..iters {
        net.forward().expect("forward");
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    (regions, ms)
}

fn main() -> anyhow::Result<()> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut net = preset_net("mnist", 17)?;
    let nblobs = net.params().len();
    // Real gradients so the update arithmetic is representative.
    net.zero_param_diffs();
    net.forward()?;
    net.backward()?;
    let mut history: Vec<Vec<f32>> =
        net.params().iter().map(|p| vec![0.0f32; p.count()]).collect();

    println!("fusion: LeNet-MNIST, {nblobs} param blobs, {hw} hw threads");
    let iters = 200usize;
    let (unfused_regions, unfused_us) =
        measure_update(&mut net, &mut history, StepFusion::Unfused, StepSync::Barrier, iters);
    let (fused_regions, fused_us) =
        measure_update(&mut net, &mut history, StepFusion::PerBlob, StepSync::Barrier, iters);
    let (flat_regions, flat_us) =
        measure_update(&mut net, &mut history, StepFusion::Flat, StepSync::Barrier, iters);
    // stage_unsynced routing (ISSUE 5): same dispatch structure, no
    // inter-stage barriers — the per-step saving is the barrier price the
    // `stage_barrier` entry below measures, times two barriers per blob.
    let (unsynced_regions, unsynced_us) =
        measure_update(&mut net, &mut history, StepFusion::PerBlob, StepSync::Unsynced, iters);
    let region_ratio = unfused_regions as f64 / fused_regions.max(1) as f64;
    println!("  sgd step regions: unfused {unfused_regions}, fused/blob {fused_regions}, flat {flat_regions}, unsynced/blob {unsynced_regions}  ({region_ratio:.1}x fewer dispatches fused)");
    println!("  sgd step time:    unfused {unfused_us:.1} us, fused/blob {fused_us:.1} us, flat {flat_us:.1} us, unsynced/blob {unsynced_us:.1} us");

    // Layer fusion on CIFAR-quick: two conv→relu pairs in the plan, so
    // the fused forward issues measurably fewer regions per sweep.
    let mut cifar = preset_net("cifar", 17)?;
    let fwd_iters = 8usize;
    let (fwd_plain_regions, fwd_plain_ms) = measure_forward(&mut cifar, false, fwd_iters);
    let (fwd_fused_regions, fwd_fused_ms) = measure_forward(&mut cifar, true, fwd_iters);
    println!("  cifar forward regions: plain {fwd_plain_regions}, fused {fwd_fused_regions}");
    println!("  cifar forward time:    plain {fwd_plain_ms:.2} ms, fused {fwd_fused_ms:.2} ms");

    // Backward fusion on LeNet: one two-stage region per conv layer
    // (gradient work + deterministic merge) vs the reference dispatch
    // plus serial merge — the region counts match by construction (the
    // merge never was a dispatch), so the gate pins the fused count
    // exactly and the win shows up as wall-clock.
    let mut lenet_bwd = preset_net("mnist", 29)?;
    let bwd_iters = 8usize;
    let (bwd_ref_regions, bwd_ref_ms) = measure_backward(&mut lenet_bwd, false, bwd_iters);
    let (bwd_fused_regions, bwd_fused_ms) = measure_backward(&mut lenet_bwd, true, bwd_iters);
    println!("  lenet backward regions (4 threads): reference {bwd_ref_regions}, fused {bwd_fused_regions}");
    println!("  lenet backward time:   reference {bwd_ref_ms:.2} ms, fused {bwd_fused_ms:.2} ms");

    // Graph-level plan on LeNet: the planned backward fuses each pool
    // scatter into its conv's gradient region (12 -> 10 dispatches) and
    // carves the fused regions' scratch from the shared arena, whose
    // peak the plan prices analytically at the pinned 4-thread width.
    let mut lenet_plan = preset_net("mnist", 31)?;
    let (plan_off_regions, plan_off_ms) = measure_planned(&mut lenet_plan, false, bwd_iters);
    let (plan_on_regions, plan_on_ms) = measure_planned(&mut lenet_plan, true, bwd_iters);
    let peak_scratch = lenet_plan.plan().peak_scratch_bytes(4);
    let grow_scratch = lenet_plan.plan().grow_only_scratch_bytes(4);
    println!("  lenet planned backward regions (4 threads): unplanned {plan_off_regions}, planned {plan_on_regions}");
    println!("  lenet planned backward time:   unplanned {plan_off_ms:.2} ms, planned {plan_on_ms:.2} ms");
    println!("  lenet plan scratch @4 workers: peak {peak_scratch} bytes (grow-only {grow_scratch})");

    // Stage-barrier cost: a trivial 3-stage fused region vs a trivial
    // 1-stage region at the same width differ by exactly two
    // stage-barrier crossings (the pool dispatch itself is identical),
    // so half the difference is the per-stage barrier price the
    // `stage_unsynced` route (measured above as `unsynced_us_per_step`)
    // recovers on pointwise chains like the SGD stages.
    let workers = hw.max(2);
    let bar_tune = par::Tuning { threads: workers, grain: 1 };
    let sink = std::sync::atomic::AtomicUsize::new(0);
    let body = |r: std::ops::Range<usize>| {
        sink.fetch_add(r.end - r.start, std::sync::atomic::Ordering::Relaxed);
    };
    for _ in 0..16 {
        par::parallel_for(workers, bar_tune, body);
        par::parallel_regions(workers, 3, bar_tune, |_, r| body(r));
    }
    let bar_iters = 2000usize;
    let t0 = Instant::now();
    for _ in 0..bar_iters {
        par::parallel_for(workers, bar_tune, body);
    }
    let single_us = t0.elapsed().as_secs_f64() * 1e6 / bar_iters as f64;
    let t0 = Instant::now();
    for _ in 0..bar_iters {
        par::parallel_regions(workers, 3, bar_tune, |_, r| body(r));
    }
    let three_us = t0.elapsed().as_secs_f64() * 1e6 / bar_iters as f64;
    std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));
    let barrier_us = ((three_us - single_us) / 2.0).max(0.0);
    println!(
        "  stage barrier ({workers} workers): 1-stage {single_us:.2} us, 3-stage {three_us:.2} us \
         -> ~{barrier_us:.2} us per barrier"
    );

    // Sanitizer overhead (ISSUE 10): the access sanitizer must be
    // zero-cost when off — its only off-path work is one relaxed atomic
    // load per region dispatch plus a suspended-TLS check per
    // FusedSlice view.  Price the fused LeNet backward three ways in
    // one run: a reference pass and an "off" pass, both with the
    // sanitizer forced off (so off_over_ref compares byte-identical
    // code on the same machine — any spread is pure noise), then an
    // "on" pass for the informational checked-mode cost.  regions_delta
    // (off minus reference) is deterministic and pinned at exactly 0;
    // checked mode must not change the dispatch structure either, so
    // regions_on is pinned to regions_off by the gate too.
    let mut lenet_chk = preset_net("mnist", 37)?;
    let chk_iters = 8usize;
    let chk_reps = 3usize;
    par::check::set_override(Some(false));
    let (chk_ref_regions, chk_ref_ms) = measure_backward_min(&mut lenet_chk, chk_iters, chk_reps);
    let (chk_off_regions, chk_off_ms) = measure_backward_min(&mut lenet_chk, chk_iters, chk_reps);
    par::check::set_override(Some(true));
    let (chk_on_regions, chk_on_ms) = measure_backward_min(&mut lenet_chk, chk_iters, chk_reps);
    par::check::set_override(None);
    let chk_regions_delta = chk_off_regions as i64 - chk_ref_regions as i64;
    let off_over_ref = chk_off_ms / chk_ref_ms.max(1e-9);
    let on_over_off = chk_on_ms / chk_off_ms.max(1e-9);
    println!(
        "  check overhead (4 threads): off {chk_off_ms:.3} ms vs reference {chk_ref_ms:.3} ms \
         (x{off_over_ref:.3}, regions delta {chk_regions_delta}); checked {chk_on_ms:.3} ms \
         (x{on_over_off:.2} over off, {chk_on_regions} regions)"
    );

    let mut sgd = String::from("{\n");
    let _ = writeln!(sgd, "    \"param_blobs\": {nblobs},");
    let _ = writeln!(sgd, "    \"iters\": {iters},");
    let _ = writeln!(sgd, "    \"regions_unfused\": {unfused_regions},");
    let _ = writeln!(sgd, "    \"regions_fused_per_blob\": {fused_regions},");
    let _ = writeln!(sgd, "    \"regions_flat\": {flat_regions},");
    let _ = writeln!(sgd, "    \"regions_unsynced_per_blob\": {unsynced_regions},");
    let _ = writeln!(sgd, "    \"region_ratio\": {region_ratio:.2},");
    let _ = writeln!(sgd, "    \"unfused_us_per_step\": {unfused_us:.1},");
    let _ = writeln!(sgd, "    \"fused_us_per_step\": {fused_us:.1},");
    let _ = writeln!(sgd, "    \"flat_us_per_step\": {flat_us:.1},");
    let _ = writeln!(sgd, "    \"unsynced_us_per_step\": {unsynced_us:.1}");
    sgd.push_str("  }");

    let mut bwd = String::from("{\n");
    let _ = writeln!(bwd, "    \"net\": \"lenet-mnist\",");
    let _ = writeln!(bwd, "    \"threads\": 4,");
    let _ = writeln!(bwd, "    \"iters\": {bwd_iters},");
    let _ = writeln!(bwd, "    \"regions_reference\": {bwd_ref_regions},");
    let _ = writeln!(bwd, "    \"regions_fused\": {bwd_fused_regions},");
    let _ = writeln!(bwd, "    \"reference_ms_per_bwd\": {bwd_ref_ms:.3},");
    let _ = writeln!(bwd, "    \"fused_ms_per_bwd\": {bwd_fused_ms:.3}");
    bwd.push_str("  }");

    let mut planned = String::from("{\n");
    let _ = writeln!(planned, "    \"net\": \"lenet-mnist\",");
    let _ = writeln!(planned, "    \"threads\": 4,");
    let _ = writeln!(planned, "    \"iters\": {bwd_iters},");
    let _ = writeln!(planned, "    \"regions_planned\": {plan_on_regions},");
    let _ = writeln!(planned, "    \"regions_unplanned\": {plan_off_regions},");
    let _ = writeln!(planned, "    \"planned_ms_per_bwd\": {plan_on_ms:.3},");
    let _ = writeln!(planned, "    \"unplanned_ms_per_bwd\": {plan_off_ms:.3},");
    let _ = writeln!(planned, "    \"peak_scratch_bytes\": {peak_scratch},");
    let _ = writeln!(planned, "    \"grow_only_scratch_bytes\": {grow_scratch}");
    planned.push_str("  }");

    let mut layers = String::from("{\n");
    let _ = writeln!(layers, "    \"net\": \"cifar10-quick\",");
    let _ = writeln!(layers, "    \"iters\": {fwd_iters},");
    let _ = writeln!(layers, "    \"regions_plain\": {fwd_plain_regions},");
    let _ = writeln!(layers, "    \"regions_fused\": {fwd_fused_regions},");
    let _ = writeln!(layers, "    \"plain_ms_per_fwd\": {fwd_plain_ms:.3},");
    let _ = writeln!(layers, "    \"fused_ms_per_fwd\": {fwd_fused_ms:.3}");
    layers.push_str("  }");

    let mut check = String::from("{\n");
    let _ = writeln!(check, "    \"net\": \"lenet-mnist\",");
    let _ = writeln!(check, "    \"threads\": 4,");
    let _ = writeln!(check, "    \"iters\": {chk_iters},");
    let _ = writeln!(check, "    \"reps\": {chk_reps},");
    let _ = writeln!(check, "    \"regions_reference\": {chk_ref_regions},");
    let _ = writeln!(check, "    \"regions_off\": {chk_off_regions},");
    let _ = writeln!(check, "    \"regions_delta\": {chk_regions_delta},");
    let _ = writeln!(check, "    \"regions_on\": {chk_on_regions},");
    let _ = writeln!(check, "    \"ref_ms_per_bwd\": {chk_ref_ms:.3},");
    let _ = writeln!(check, "    \"off_ms_per_bwd\": {chk_off_ms:.3},");
    let _ = writeln!(check, "    \"on_ms_per_bwd\": {chk_on_ms:.3},");
    let _ = writeln!(check, "    \"off_over_ref\": {off_over_ref:.4},");
    let _ = writeln!(check, "    \"on_over_off\": {on_over_off:.4}");
    check.push_str("  }");

    let mut barrier = String::from("{\n");
    let _ = writeln!(barrier, "    \"workers\": {workers},");
    let _ = writeln!(barrier, "    \"iters\": {bar_iters},");
    let _ = writeln!(barrier, "    \"single_stage_us\": {single_us:.3},");
    let _ = writeln!(barrier, "    \"three_stage_us\": {three_us:.3},");
    let _ = writeln!(barrier, "    \"barrier_us_per_stage\": {barrier_us:.3},");
    let _ = writeln!(
        barrier,
        "    \"note\": \"per-barrier price the stage_unsynced route (PHAST_FUSE_UNSYNC, default \
         on) saves on pointwise chains: ~2x barrier_us_per_stage per fused 3-stage region; see \
         fused_sgd_step.unsynced_us_per_step for the end-to-end effect\""
    );
    barrier.push_str("  }");

    bench_json::merge_entries(
        std::path::Path::new("BENCH_threads.json"),
        &[
            ("fused_sgd_step", sgd),
            ("fused_layers", layers),
            ("fused_backward", bwd),
            ("planned_backward", planned),
            ("check_overhead", check),
            ("stage_barrier", barrier),
        ],
    )?;
    println!(
        "\nmerged fused_sgd_step + fused_layers + fused_backward + planned_backward + check_overhead + stage_barrier into BENCH_threads.json"
    );
    Ok(())
}
