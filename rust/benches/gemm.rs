//! Bench: the packed register-tiled GeMM engine vs the pre-packing
//! baseline (`gemm_unpacked`) on the Table-2-shaped products — LeNet's
//! conv and ip dimensions, each with the transpose pattern its layer
//! actually issues — plus the persistent-packing repack-rate metric.
//!
//! Entries merge-updated into `BENCH_threads.json` (keyed top-level
//! entries via `metrics::bench_json`, coexisting with `threads_scaling`
//! and `fusion`; `tools/check_bench.sh` gates the result against
//! `BENCH_baseline.json`):
//!
//! * **`gemm_packed`** — per-shape GFLOP/s packed vs unpacked at 4
//!   threads, the `packed_over_naive` ratio on the ip1 forward shape
//!   (64×500×800, the hottest weight-transposing GeMM — gated `>= 1.0`),
//!   and the repack rates: `packs_per_forward` / `packs_per_backward`,
//!   `PackedMat` repacks per LeNet forward / backward sweep with frozen
//!   weights, both of which must be exactly **0** after the first
//!   iteration (gated exactly — the whole point of the version-stamped
//!   weight caches plus the forward-captured im2col panels).
//!
//! `cargo bench --bench gemm`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::experiments::preset_net;
use phast_caffe::metrics::bench_json;
use phast_caffe::ops::{self, gemm::Trans, par};
use phast_caffe::propcheck::Rng;

/// Thread count for the GFLOP/s comparison (the acceptance shape is
/// pinned at 4; oversubscribed runners still measure both engines under
/// identical conditions, so the ratio stays meaningful).
const THREADS: usize = 4;

struct ShapeSpec {
    name: &'static str,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
}

/// Mean GFLOP/s of `f`, whose one call performs `flops2` floating-point
/// operations (2·m·n·k for a GeMM).  One warm call precedes timing.
fn measure(mut f: impl FnMut(), flops2: f64, iters: usize) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per_call = t0.elapsed().as_secs_f64() / iters as f64;
    flops2 / per_call / 1e9
}

fn main() -> anyhow::Result<()> {
    // LeNet-MNIST (batch 64) GeMM shapes with their layer-true transpose
    // patterns: ip forwards pay Trans::Yes on W (the per-iteration
    // transpose the packed caches remove), conv samples are No/No, the
    // ip1 weight gradient is Yes/No.
    let shapes = [
        ShapeSpec { name: "conv1_sample", ta: Trans::No, tb: Trans::No, m: 20, n: 576, k: 25 },
        ShapeSpec { name: "conv2_sample", ta: Trans::No, tb: Trans::No, m: 50, n: 64, k: 500 },
        ShapeSpec { name: "ip1_fwd", ta: Trans::No, tb: Trans::Yes, m: 64, n: 500, k: 800 },
        ShapeSpec { name: "ip1_dw", ta: Trans::Yes, tb: Trans::No, m: 500, n: 800, k: 64 },
        ShapeSpec { name: "ip2_fwd", ta: Trans::No, tb: Trans::Yes, m: 64, n: 10, k: 500 },
    ];

    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("gemm: packed engine vs unpacked baseline, {THREADS} threads ({hw} hw threads)");
    println!("{:>14} {:>13} {:>15} {:>9}", "shape", "packed GF/s", "unpacked GF/s", "speedup");

    let mut shape_rows = String::new();
    let mut packed_over_naive = 0.0f64;
    for (si, spec) in shapes.iter().enumerate() {
        let mut rng = Rng::new(0x9e37 + si as u64);
        let a = rng.normal_vec(spec.m * spec.k);
        let b = rng.normal_vec(spec.k * spec.n);
        let mut c = vec![0.0f32; spec.m * spec.n];
        let flops2 = 2.0 * (spec.m * spec.n * spec.k) as f64;
        let iters = ((2e8 / flops2) as usize).clamp(4, 400);

        let packed = par::with_threads(THREADS, || {
            measure(
                || ops::gemm(spec.ta, spec.tb, spec.m, spec.n, spec.k, 1.0, &a, &b, 0.0, &mut c),
                flops2,
                iters,
            )
        });
        let unpacked = par::with_threads(THREADS, || {
            measure(
                || {
                    ops::gemm::gemm_unpacked(
                        spec.ta, spec.tb, spec.m, spec.n, spec.k, 1.0, &a, &b, 0.0, &mut c,
                    )
                },
                flops2,
                iters,
            )
        });
        std::hint::black_box(&c);
        let speedup = packed / unpacked;
        if spec.name == "ip1_fwd" {
            packed_over_naive = speedup;
        }
        println!("{:>14} {packed:>13.2} {unpacked:>15.2} {speedup:>8.2}x", spec.name);
        let comma = if si + 1 < shapes.len() { "," } else { "" };
        let _ = writeln!(
            shape_rows,
            "      {{\"name\": \"{}\", \"m\": {}, \"n\": {}, \"k\": {}, \
             \"packed_gflops\": {packed:.2}, \"unpacked_gflops\": {unpacked:.2}, \
             \"speedup\": {speedup:.3}}}{comma}",
            spec.name, spec.m, spec.n, spec.k
        );
    }

    // Repack rate: after one cold forward (which packs every cached
    // weight orientation once), further forwards with frozen weights
    // must never repack — `packs_per_forward == 0` is the persistent-
    // packing contract the baseline pins exactly.
    let mut net = preset_net("mnist", 23)?;
    net.forward()?;
    let warm_packs = ops::gemm::repack_count();
    let reps = 5u64;
    for _ in 0..reps {
        net.forward()?;
    }
    let packs_per_forward = (ops::gemm::repack_count() - warm_packs) as f64 / reps as f64;
    println!(
        "\npersistent packing: {warm_packs} cold pack(s), {packs_per_forward:.1} repacks/forward \
         over {reps} frozen-weight forwards"
    );

    // Backward repack rate: the gradient sweep must likewise never
    // repack with frozen weights — the backward weight orientations are
    // version-stamped caches and the conv `dW` GeMM consumes im2col
    // panels captured by the forward pass (caller-managed, never counted
    // as repacks) — `packs_per_backward == 0`, pinned exactly.
    net.zero_param_diffs();
    net.forward()?;
    net.backward()?; // warm: packs the backward orientations once
    let mut bwd_packs = 0u64;
    for _ in 0..reps {
        net.zero_param_diffs();
        net.forward()?;
        let before_bwd = ops::gemm::repack_count();
        net.backward()?;
        bwd_packs += ops::gemm::repack_count() - before_bwd;
    }
    let packs_per_backward = bwd_packs as f64 / reps as f64;
    println!(
        "persistent packing: {packs_per_backward:.1} repacks/backward over {reps} frozen-weight \
         iterations"
    );

    let mut entry = String::from("{\n");
    let _ = writeln!(entry, "    \"threads\": {THREADS},");
    let _ = writeln!(entry, "    \"shapes\": [");
    entry.push_str(&shape_rows);
    let _ = writeln!(entry, "    ],");
    let _ = writeln!(entry, "    \"packed_over_naive\": {packed_over_naive:.3},");
    let _ = writeln!(entry, "    \"cold_packs\": {warm_packs},");
    let _ = writeln!(entry, "    \"packs_per_forward\": {packs_per_forward:.1},");
    let _ = writeln!(entry, "    \"packs_per_backward\": {packs_per_backward:.1}");
    entry.push_str("  }");

    bench_json::merge_entries(
        std::path::Path::new("BENCH_threads.json"),
        &[("gemm_packed", entry)],
    )?;
    println!("merged gemm_packed into BENCH_threads.json");
    Ok(())
}
