//! Bench: kernel micro-benchmarks — native ops vs their PJRT artifacts at
//! the LeNet shapes.  This is the per-layer breakdown behind Table 2 and
//! the input to the §Perf optimization log.
//!
//! `cargo bench --bench kernels_micro`

use std::time::Instant;

use phast_caffe::ops::{self, gemm::Trans, im2col::Conv2dGeom, pool::Pool2dGeom};
use phast_caffe::propcheck::Rng;
use phast_caffe::runtime::{Engine, Value};
use phast_caffe::tensor::{Shape, Tensor};

fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..3 {
        f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ms = t0.elapsed().as_secs_f64() * 1000.0 / iters as f64;
    println!("{name:<44} {ms:>9.3} ms");
    ms
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);
    println!("{:<44} {:>12}", "kernel (batch 64, LeNet shapes)", "mean");

    // GeMM: conv2-as-GeMM shape, per batch of 64 samples
    let a = rng.normal_vec(50 * 500);
    let b = rng.normal_vec(500 * 64);
    let mut c = vec![0.0f32; 50 * 64];
    bench("native gemm 50x500x64 (x64 samples)", 10, || {
        for _ in 0..64 {
            ops::gemm(Trans::No, Trans::No, 50, 64, 500, 1.0, &a, &b, 0.0, &mut c);
        }
    });

    // im2col at conv2 shape
    let x2 = rng.normal_vec(20 * 12 * 12);
    let g2 = Conv2dGeom { kh: 5, kw: 5, sh: 1, sw: 1, ph: 0, pw: 0 };
    let mut cols = vec![0.0f32; 500 * 64];
    bench("native im2col conv2 (x64 samples)", 10, || {
        for _ in 0..64 {
            ops::im2col(&x2, 20, 12, 12, g2, &mut cols);
        }
    });

    // maxpool at pool1 shape
    let xp = rng.normal_vec(20 * 24 * 24);
    let gp = Pool2dGeom { kh: 2, kw: 2, sh: 2, sw: 2, ph: 0, pw: 0 };
    let mut pout = vec![0.0f32; 20 * 12 * 12];
    let mut parg = vec![0i32; 20 * 12 * 12];
    bench("native maxpool pool1 (x64 samples)", 10, || {
        for _ in 0..64 {
            ops::maxpool(&xp, 20, 24, 24, gp, &mut pout, &mut parg);
        }
    });

    // softmax-xent at head shape
    let logits = rng.normal_vec(64 * 10);
    let labels: Vec<i32> = (0..64).map(|i| (i % 10) as i32).collect();
    let mut probs = vec![0.0f32; 640];
    bench("native softmax_xent 64x10", 100, || {
        ops::softmax_xent(&logits, &labels, 64, 10, &mut probs);
    });

    // PJRT artifacts at the same shapes (includes the H2D/D2H transfers a
    // per-layer domain hop pays — the honest partial-port cost).
    let engine = Engine::open_default()?;
    let shape = Shape::nchw(64, 20, 12, 12);
    let x = Tensor::from_vec(shape.clone(), rng.normal_vec(shape.count()));
    let w = Tensor::from_vec(Shape::new(&[50, 20, 5, 5]), rng.normal_vec(50 * 500));
    let bias = Tensor::from_vec(Shape::new(&[50]), rng.normal_vec(50));
    engine.warmup(&["mnist.conv2.fwd", "mnist.pool1.fwd", "mnist.ip1.fwd"])?;
    bench("pjrt  mnist.conv2.fwd (im2col+gemm+bias)", 10, || {
        engine
            .run(
                "mnist.conv2.fwd",
                &[Value::F32(x.clone()), Value::F32(w.clone()), Value::F32(bias.clone())],
            )
            .unwrap();
    });

    let xp1 = Tensor::from_vec(Shape::nchw(64, 20, 24, 24), rng.normal_vec(64 * 20 * 576));
    bench("pjrt  mnist.pool1.fwd", 10, || {
        engine.run("mnist.pool1.fwd", &[Value::F32(xp1.clone())]).unwrap();
    });

    let xip = Tensor::from_vec(Shape::new(&[64, 800]), rng.normal_vec(64 * 800));
    let wip = Tensor::from_vec(Shape::new(&[500, 800]), rng.normal_vec(500 * 800));
    let bip = Tensor::from_vec(Shape::new(&[500]), rng.normal_vec(500));
    bench("pjrt  mnist.ip1.fwd", 10, || {
        engine
            .run(
                "mnist.ip1.fwd",
                &[Value::F32(xip.clone()), Value::F32(wip.clone()), Value::F32(bip.clone())],
            )
            .unwrap();
    });

    let st = engine.stats();
    println!(
        "\npjrt transfer totals: {} executions, {:.1} MiB H2D, {:.1} MiB D2H",
        st.executions,
        st.h2d_bytes as f64 / (1 << 20) as f64,
        st.d2h_bytes as f64 / (1 << 20) as f64
    );
    Ok(())
}
