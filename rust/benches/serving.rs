//! Bench: serving-engine latency and throughput — what the dynamic
//! batcher buys over request-at-a-time inference, and the deterministic
//! correctness flags the CI gate pins.
//!
//! Entries merge-updated into `BENCH_threads.json` under the `serving`
//! key (see `metrics::bench_json`; `tools/check_bench.sh` gates them
//! against `BENCH_baseline.json`):
//!
//! * `requests` — closed-loop requests issued (gated exact: the workload
//!   itself is deterministic);
//! * `responses_ok` — 1 iff every request got a response (gated exact);
//! * `bitwise_match` — 1 iff every served response equals its
//!   single-request reference forward bitwise, however the batcher
//!   coalesced it (gated exactly at 1 — the serving acceptance pin);
//! * `p50_us_b8` / `p95_us_b8` / `p99_us_b8` — enqueue-to-response
//!   latency percentiles at max_batch=8 (p99 gated as a generous
//!   ceiling; CI runners vary wildly);
//! * `rps_b1` / `rps_b8` — closed-loop throughput at max_batch 1 vs 8
//!   (rps_b8 gated as a floor);
//! * `batch_speedup` — rps_b8 / rps_b1, the dispatch amortization the
//!   batcher exists for (gated as a floor).
//!
//! `cargo bench --bench serving`

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use phast_caffe::metrics::bench_json;
use phast_caffe::runtime::{Model, ModelRegistry, ServeConfig, ServeEngine, SubmitError};

const SAMPLE_IN: usize = 28 * 28;
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 16;

fn sample(seed: u64) -> Vec<f32> {
    let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..SAMPLE_IN)
        .map(|_| {
            x ^= x >> 30;
            x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            x ^= x >> 27;
            ((x >> 40) as f32) / ((1u64 << 24) as f32)
        })
        .collect()
}

struct RunResult {
    rps: f64,
    latencies_us: Vec<f64>,
    all_ok: bool,
    all_match: bool,
}

/// Closed-loop run: CLIENTS threads, each submitting REQS_PER_CLIENT
/// requests back to back, every response checked bitwise against the
/// precomputed single-request reference for its input.
fn run(
    max_batch: usize,
    inputs: &Arc<Vec<Vec<f32>>>,
    refs: &Arc<Vec<Vec<f32>>>,
) -> anyhow::Result<RunResult> {
    let registry = Arc::new(ModelRegistry::new());
    registry.register_fixed("lenet", Model::lenet(max_batch, 42)?);
    let cfg = ServeConfig {
        max_batch,
        max_delay_us: 500,
        queue_cap: 256,
        threads: None,
        timeout_us: 0,
    };
    let engine = Arc::new(ServeEngine::start(registry, "lenet", cfg)?);
    // Warm-up batch: packs weights, so the timed run is steady state.
    engine
        .submit(inputs[0].clone())
        .expect("warmup submit")
        .wait()?;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let engine = Arc::clone(&engine);
        let inputs = Arc::clone(inputs);
        let refs = Arc::clone(refs);
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::with_capacity(REQS_PER_CLIENT);
            let mut ok = true;
            let mut matched = true;
            for i in 0..REQS_PER_CLIENT {
                let k = (c * REQS_PER_CLIENT + i) % inputs.len();
                // Backpressure surfaces as QueueFull: retry, it is part
                // of the closed-loop cost.
                let pending = loop {
                    match engine.submit(inputs[k].clone()) {
                        Ok(p) => break Some(p),
                        Err(SubmitError::QueueFull) => std::thread::yield_now(),
                        Err(_) => break None,
                    }
                };
                match pending.map(|p| p.wait()) {
                    Some(Ok(resp)) => {
                        lat.push(resp.latency().as_secs_f64() * 1e6);
                        matched &= resp.scores() == refs[k].as_slice();
                    }
                    _ => ok = false,
                }
            }
            (lat, ok, matched)
        }));
    }
    let mut latencies_us = Vec::new();
    let mut all_ok = true;
    let mut all_match = true;
    for h in handles {
        let (lat, ok, matched) = h.join().expect("client thread");
        latencies_us.extend(lat);
        all_ok &= ok;
        all_match &= matched;
    }
    let wall = t0.elapsed().as_secs_f64();
    let rps = (CLIENTS * REQS_PER_CLIENT) as f64 / wall;
    Ok(RunResult { rps, latencies_us, all_ok, all_match })
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() -> anyhow::Result<()> {
    // Distinct inputs cycled by the clients, plus their single-request
    // reference outputs (the bitwise yardstick for every batch shape).
    let inputs: Arc<Vec<Vec<f32>>> = Arc::new((0..8).map(|i| sample(9000 + i)).collect());
    let mut reference = Model::lenet(8, 42)?;
    let width = reference.sample_out();
    let refs: Arc<Vec<Vec<f32>>> = Arc::new(
        inputs
            .iter()
            .map(|x| {
                let out = reference.forward_batch(x, 1)?;
                Ok(out.as_slice()[..width].to_vec())
            })
            .collect::<anyhow::Result<_>>()?,
    );

    let b1 = run(1, &inputs, &refs)?;
    let b8 = run(8, &inputs, &refs)?;

    let mut lat = b8.latencies_us.clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p95, p99) =
        (percentile(&lat, 0.50), percentile(&lat, 0.95), percentile(&lat, 0.99));
    let requests = CLIENTS * REQS_PER_CLIENT;
    let responses_ok = usize::from(b1.all_ok && b8.all_ok);
    let bitwise_match = usize::from(b1.all_match && b8.all_match);
    let speedup = b8.rps / b1.rps;

    println!("serving: LeNet-MNIST, {CLIENTS} clients x {REQS_PER_CLIENT} reqs, delay 500us");
    println!("  max_batch=1: {:.1} req/s", b1.rps);
    println!("  max_batch=8: {:.1} req/s ({speedup:.2}x)", b8.rps);
    println!("  latency @8: p50 {p50:.0} us / p95 {p95:.0} us / p99 {p99:.0} us");
    println!("  responses_ok={responses_ok} bitwise_match={bitwise_match}");

    let mut entry = String::from("{\n");
    let _ = writeln!(entry, "    \"net\": \"lenet-mnist\",");
    let _ = writeln!(entry, "    \"clients\": {CLIENTS},");
    let _ = writeln!(entry, "    \"requests\": {requests},");
    let _ = writeln!(entry, "    \"responses_ok\": {responses_ok},");
    let _ = writeln!(entry, "    \"bitwise_match\": {bitwise_match},");
    let _ = writeln!(entry, "    \"p50_us_b8\": {p50:.1},");
    let _ = writeln!(entry, "    \"p95_us_b8\": {p95:.1},");
    let _ = writeln!(entry, "    \"p99_us_b8\": {p99:.1},");
    let _ = writeln!(entry, "    \"rps_b1\": {:.2},", b1.rps);
    let _ = writeln!(entry, "    \"rps_b8\": {:.2},", b8.rps);
    let _ = writeln!(entry, "    \"batch_speedup\": {speedup:.3}");
    entry.push_str("  }");

    bench_json::merge_entries(std::path::Path::new("BENCH_threads.json"), &[("serving", entry)])?;
    println!("\nmerged serving into BENCH_threads.json");
    Ok(())
}
