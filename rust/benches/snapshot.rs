//! Bench: crash-safe snapshot cost — what the fault-tolerance layer
//! charges per checkpoint (serialize + fsync + atomic rename) and per
//! restore (read + CRC verify + apply), plus the deterministic
//! roundtrip-exactness flag the CI gate pins.
//!
//! Entries merge-updated into `BENCH_threads.json` under the `snapshot`
//! key (see `metrics::bench_json`; `tools/check_bench.sh` gates them
//! against `BENCH_baseline.json`):
//!
//! * `param_blobs` / `snapshot_bytes` — deterministic shape of the LeNet
//!   snapshot (gated as exact count / size ceiling);
//! * `roundtrip_exact` — 1 iff a save → load roundtrip restores every
//!   parameter, momentum entry, the iteration counter, and the data
//!   cursors **bitwise** (gated exactly at 1);
//! * `snapshot_save_ms` / `snapshot_restore_ms` — mean wall-clock per
//!   checkpoint and per restore (gated with the generous timing
//!   tolerance: fsync cost varies wildly across CI runners).
//!
//! `cargo bench --bench snapshot`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::metrics::bench_json;
use phast_caffe::net::Net;
use phast_caffe::proto::{presets, NetConfig, SolverConfig};
use phast_caffe::solver::{load_snapshot, save_snapshot, Solver};

fn lenet_solver() -> anyhow::Result<Solver> {
    let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER)?;
    cfg.display = 0;
    let net = Net::from_config(NetConfig::from_text(presets::LENET_MNIST)?, 33)?;
    Ok(Solver::new(cfg, net))
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("phast_caffe_snap_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("bench.pcss");

    // A trained-for-a-few-steps solver, so the snapshot carries real
    // weights, momentum, and a mid-epoch data cursor.
    let mut a = lenet_solver()?;
    for _ in 0..3 {
        a.step()?;
    }
    let nblobs = a.net.params().len();

    // Warm once (creates the file; later saves measure the steady state:
    // serialize + write + fsync + rename over an existing snapshot).
    save_snapshot(&mut a, &path)?;
    let bytes = std::fs::metadata(&path)?.len();

    let save_iters = 10usize;
    let t0 = Instant::now();
    for _ in 0..save_iters {
        save_snapshot(&mut a, &path)?;
    }
    let save_ms = t0.elapsed().as_secs_f64() * 1e3 / save_iters as f64;

    let mut b = lenet_solver()?;
    load_snapshot(&mut b, &path)?; // warm
    let restore_iters = 10usize;
    let t0 = Instant::now();
    for _ in 0..restore_iters {
        load_snapshot(&mut b, &path)?;
    }
    let restore_ms = t0.elapsed().as_secs_f64() * 1e3 / restore_iters as f64;

    // Bitwise roundtrip check: every float, the iteration counter, and
    // the data cursors must come back exactly.
    let mut exact = b.iter() == a.iter() && b.net.data_cursors() == a.net.data_cursors();
    for (pa, pb) in a.net.params_mut().iter().zip(b.net.params_mut().iter()) {
        exact &= pa.data().as_slice() == pb.data().as_slice();
    }
    for (ha, hb) in a.history().iter().zip(b.history().iter()) {
        exact &= ha == hb;
    }
    let roundtrip_exact = usize::from(exact);

    println!("snapshot: LeNet-MNIST, {nblobs} param blobs, {bytes} bytes on disk");
    println!("  save (serialize + fsync + rename): {save_ms:.2} ms over {save_iters} iters");
    println!("  restore (read + CRC + apply):      {restore_ms:.2} ms over {restore_iters} iters");
    println!("  roundtrip bitwise exact:           {roundtrip_exact}");

    let mut entry = String::from("{\n");
    let _ = writeln!(entry, "    \"net\": \"lenet-mnist\",");
    let _ = writeln!(entry, "    \"param_blobs\": {nblobs},");
    let _ = writeln!(entry, "    \"snapshot_bytes\": {bytes},");
    let _ = writeln!(entry, "    \"save_iters\": {save_iters},");
    let _ = writeln!(entry, "    \"snapshot_save_ms\": {save_ms:.3},");
    let _ = writeln!(entry, "    \"snapshot_restore_ms\": {restore_ms:.3},");
    let _ = writeln!(entry, "    \"roundtrip_exact\": {roundtrip_exact}");
    entry.push_str("  }");

    bench_json::merge_entries(std::path::Path::new("BENCH_threads.json"), &[("snapshot", entry)])?;
    println!("\nmerged snapshot into BENCH_threads.json");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
