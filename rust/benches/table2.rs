//! Bench: **Table 2** — average forward-backward execution time (ms) for
//! LeNet-MNIST and cifar10-quick under the three configurations
//! (native baseline / paper-partial PHAST port / fused whole-net artifact).
//!
//! `cargo bench --bench table2`

use phast_caffe::experiments::{render_table2, run_table2};
use phast_caffe::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let (warmup, reps) = (3, 20);
    eprintln!("table2: {reps} reps after {warmup} warmup, batch 64");
    let mnist = run_table2(&engine, "mnist", warmup, reps)?;
    let cifar = run_table2(&engine, "cifar", warmup, reps)?;
    print!("{}", render_table2(&mnist, &cifar));
    println!(
        "\npaper Table 2 (ms): MNIST Caffe 71.42 / PHAST 198.60 (2.8x);  \
         CIFAR Caffe 399.50 / PHAST 1113.71 (2.8x)  [CPU column]"
    );
    Ok(())
}
