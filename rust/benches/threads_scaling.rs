//! Bench: serial-vs-parallel scaling of the native backend — the
//! multi-core honesty check behind the Table 2 "Caffe" baseline.
//!
//! Runs full forward+backward iterations of LeNet-MNIST (batch 64, the
//! paper's workload) at increasing thread counts via the
//! `ops::par::with_threads` knob, prints the scaling table, and records
//! it to `BENCH_threads.json` for the CI artifact.
//!
//! `cargo bench --bench threads_scaling`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::experiments::preset_net;
use phast_caffe::ops::par;

/// Mean forward+backward ms over `iters` iterations at `threads`.
fn fwd_bwd_ms(threads: usize, warmup: usize, iters: usize) -> anyhow::Result<f64> {
    par::with_threads(threads, || -> anyhow::Result<f64> {
        let mut net = preset_net("mnist", 11)?;
        for _ in 0..warmup {
            net.zero_param_diffs();
            net.forward()?;
            net.backward()?;
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            net.zero_param_diffs();
            net.forward()?;
            net.backward()?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1000.0 / iters as f64)
    })
}

fn main() -> anyhow::Result<()> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts = vec![1usize, 2, 4];
    if !counts.contains(&hw) {
        counts.push(hw);
    }
    counts.sort_unstable();
    counts.dedup();

    let (warmup, iters) = (2usize, 8usize);
    println!("threads_scaling: LeNet-MNIST fwd+bwd, batch 64, {iters} iters ({hw} hw threads)");
    println!("{:>8} {:>12} {:>9}", "threads", "fwd+bwd ms", "speedup");

    let mut rows = Vec::new();
    let mut serial_ms = None;
    for &t in &counts {
        let ms = fwd_bwd_ms(t, warmup, iters)?;
        let base = *serial_ms.get_or_insert(ms);
        let speedup = base / ms;
        println!("{t:>8} {ms:>12.2} {speedup:>8.2}x");
        rows.push((t, ms, speedup));
    }

    // Hand-rolled JSON (no serde in the dependency-free build).
    let mut json = String::from("{\n  \"bench\": \"threads_scaling\",\n");
    let _ = writeln!(json, "  \"net\": \"lenet-mnist\",\n  \"batch\": 64,");
    let _ = writeln!(json, "  \"iters\": {iters},\n  \"hw_threads\": {hw},");
    json.push_str("  \"results\": [\n");
    for (i, (t, ms, speedup)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"threads\": {t}, \"fwd_bwd_ms\": {ms:.3}, \"speedup\": {speedup:.3}}}{comma}"
        );
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_threads.json", &json)?;
    println!("\nwrote BENCH_threads.json");
    Ok(())
}
