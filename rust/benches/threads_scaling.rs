//! Bench: serial-vs-parallel scaling of the native backend — the
//! multi-core honesty check behind the Table 2 "Caffe" baseline.
//!
//! Two sections, both **merge-updated** into `BENCH_threads.json` (keyed
//! top-level entries via `metrics::bench_json`, so this bench and the
//! `fusion` bench coexist in one record; the CI perf gate
//! `tools/check_bench.sh` compares the merged file against
//! `BENCH_baseline.json`):
//!
//! 1. **`scaling`** — full forward+backward iterations of LeNet-MNIST
//!    (batch 64, the paper's workload) at increasing thread counts via
//!    the `ops::par::with_threads` knob.
//! 2. **`small_op_dispatch`** — per-dispatch overhead of the persistent
//!    worker pool vs the pre-pool scoped-spawn path
//!    (`par::parallel_for_spawn`), measured on a trivial parallel region.
//!    This is the many-small-op regime (CIFAR-quick head layers) the
//!    pool exists for: the spawn path pays thread creation per call, the
//!    pool only a channel send + latch join.
//!
//! `cargo bench --bench threads_scaling`

use std::fmt::Write as _;
use std::time::Instant;

use phast_caffe::experiments::preset_net;
use phast_caffe::metrics::bench_json;
use phast_caffe::ops::par;

/// Mean forward+backward ms over `iters` iterations at `threads`.
fn fwd_bwd_ms(threads: usize, warmup: usize, iters: usize) -> anyhow::Result<f64> {
    par::with_threads(threads, || -> anyhow::Result<f64> {
        let mut net = preset_net("mnist", 11)?;
        for _ in 0..warmup {
            net.zero_param_diffs();
            net.forward()?;
            net.backward()?;
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            net.zero_param_diffs();
            net.forward()?;
            net.backward()?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1000.0 / iters as f64)
    })
}

/// Mean ns per dispatch of a tiny parallel region (`threads` worker
/// ranges, trivial body) through either the persistent pool or the
/// scoped-spawn baseline.
fn dispatch_ns(pool: bool, threads: usize, iters: usize) -> f64 {
    let tune = par::Tuning { threads, grain: 1 };
    let sink = std::sync::atomic::AtomicUsize::new(0);
    let body = |r: std::ops::Range<usize>| {
        // Just enough work to keep the region from being optimized out.
        sink.fetch_add(r.end - r.start, std::sync::atomic::Ordering::Relaxed);
    };
    // Warm (grows the pool / faults in thread stacks).
    for _ in 0..16 {
        if pool {
            par::parallel_for(threads, tune, body);
        } else {
            par::parallel_for_spawn(threads, tune, body);
        }
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        if pool {
            par::parallel_for(threads, tune, body);
        } else {
            par::parallel_for_spawn(threads, tune, body);
        }
    }
    let ns = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;
    std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));
    ns
}

fn main() -> anyhow::Result<()> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts = vec![1usize, 2, 4];
    if !counts.contains(&hw) {
        counts.push(hw);
    }
    counts.sort_unstable();
    counts.dedup();

    let (warmup, iters) = (2usize, 8usize);
    println!("threads_scaling: LeNet-MNIST fwd+bwd, batch 64, {iters} iters ({hw} hw threads)");
    println!("{:>8} {:>12} {:>9}", "threads", "fwd+bwd ms", "speedup");

    let mut rows = Vec::new();
    let mut serial_ms = None;
    for &t in &counts {
        let ms = fwd_bwd_ms(t, warmup, iters)?;
        let base = *serial_ms.get_or_insert(ms);
        let speedup = base / ms;
        println!("{t:>8} {ms:>12.2} {speedup:>8.2}x");
        rows.push((t, ms, speedup));
    }

    // Small-op dispatch overhead: pool vs per-call spawn at the widest
    // measured thread count.
    let t = *counts.last().unwrap();
    let micro_iters = 2000usize;
    let pool_ns = dispatch_ns(true, t.max(2), micro_iters);
    let spawn_ns = dispatch_ns(false, t.max(2), micro_iters);
    let ratio = spawn_ns / pool_ns;
    println!("\nsmall-op dispatch ({} workers, {micro_iters} iters):", t.max(2));
    println!("  pool  {pool_ns:>10.0} ns/dispatch");
    println!("  spawn {spawn_ns:>10.0} ns/dispatch  ({ratio:.1}x slower)");

    // Hand-rolled JSON (no serde in the dependency-free build), merged
    // into BENCH_threads.json by key so other benches' entries survive.
    let max_speedup = rows.iter().map(|&(_, _, s)| s).fold(0.0f64, f64::max);
    let mut scaling = String::from("{\n");
    let _ = writeln!(scaling, "    \"net\": \"lenet-mnist\",\n    \"batch\": 64,");
    let _ = writeln!(scaling, "    \"iters\": {iters},\n    \"hw_threads\": {hw},");
    let _ = writeln!(scaling, "    \"max_speedup\": {max_speedup:.3},");
    scaling.push_str("    \"results\": [\n");
    for (i, (t, ms, speedup)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            scaling,
            "      {{\"threads\": {t}, \"fwd_bwd_ms\": {ms:.3}, \"speedup\": {speedup:.3}}}{comma}"
        );
    }
    scaling.push_str("    ]\n  }");

    let mut dispatch = String::from("{\n");
    let _ = writeln!(dispatch, "    \"workers\": {},", t.max(2));
    let _ = writeln!(dispatch, "    \"iters\": {micro_iters},");
    let _ = writeln!(dispatch, "    \"pool_ns_per_dispatch\": {pool_ns:.0},");
    let _ = writeln!(dispatch, "    \"spawn_ns_per_dispatch\": {spawn_ns:.0},");
    let _ = writeln!(dispatch, "    \"spawn_over_pool\": {ratio:.2}");
    dispatch.push_str("  }");

    bench_json::merge_entries(
        std::path::Path::new("BENCH_threads.json"),
        &[("scaling", scaling), ("small_op_dispatch", dispatch)],
    )?;
    println!("\nmerged scaling + small_op_dispatch into BENCH_threads.json");
    Ok(())
}
