//! `phast_lint` — the repo's PHAST-specific source lint (rules L1–L4).
//!
//! A deliberately dependency-free line scanner enforcing the contracts
//! that `clippy` cannot see (documented in `docs/CHECKING.md`):
//!
//! - **L1 `safety-comment`** — every `unsafe {` block and `unsafe impl`
//!   carries a `// SAFETY:` comment within the few lines above it.
//!   Scope: `src`, `tests`, `benches`, `../examples`.
//! - **L2 `thread-spawn`** — no `std::thread` spawns outside
//!   `src/ops/par.rs` (the PHAST pool owns threading) unless annotated
//!   `// LINT-ALLOW: thread-spawn`.  Scope: `src`.
//! - **L3 `env-read`** — `PHAST_*` environment variables are read only
//!   through the one-shot knob idiom (`get_or_init`) or under an
//!   explicit `// LINT-ALLOW: env-read`.  Scope: `src`.
//! - **L4 `kernel-time`** — no `Instant::now`/`SystemTime::now` inside
//!   `src/ops` (kernels must be time-independent so checked and
//!   unchecked runs stay bitwise identical).  No allow marker.
//!
//! Exit status is the violation count (0 = clean); `tools/lint.sh`
//! wires it into the CI lint job.

use std::path::{Path, PathBuf};

struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

/// Lines searched upward for a `SAFETY:` / `LINT-ALLOW:` marker.
const LOOKBACK: usize = 6;

fn is_comment(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//") || t.starts_with("*")
}

/// True when any of the `LOOKBACK` lines above index `i` contains `needle`.
fn marker_above(lines: &[&str], i: usize, needle: &str) -> bool {
    lines[i.saturating_sub(LOOKBACK)..i].iter().any(|l| l.contains(needle))
}

fn lint_file(root: &Path, path: &Path, out: &mut Vec<Violation>) {
    let Ok(text) = std::fs::read_to_string(path) else {
        return;
    };
    let rel = path.strip_prefix(root).unwrap_or(path);
    let rel_s = rel.to_string_lossy().replace('\\', "/");
    let in_src = rel_s.starts_with("src/");
    let in_ops = rel_s.starts_with("src/ops");
    let is_pool = rel_s == "src/ops/par.rs";
    let lines: Vec<&str> = text.lines().collect();

    for (i, raw) in lines.iter().enumerate() {
        if is_comment(raw) {
            continue;
        }
        let n = i + 1;

        // L1: unsafe blocks / impls need a SAFETY comment above.
        if (raw.contains("unsafe {") || raw.contains("unsafe impl "))
            && !raw.contains("SAFETY")
            && !marker_above(&lines, i, "SAFETY:")
        {
            out.push(Violation {
                file: rel_s.clone(),
                line: n,
                rule: "L1 safety-comment",
                msg: "unsafe block without a `// SAFETY:` comment above it".into(),
            });
        }

        // L2: thread spawns live in the PHAST pool only.
        if in_src
            && !is_pool
            && (raw.contains("thread::spawn") || raw.contains("thread::Builder"))
            && !marker_above(&lines, i, "LINT-ALLOW: thread-spawn")
        {
            out.push(Violation {
                file: rel_s.clone(),
                line: n,
                rule: "L2 thread-spawn",
                msg: "thread spawn outside ops::par without `// LINT-ALLOW: thread-spawn`"
                    .into(),
            });
        }

        // L3: PHAST_* knob reads use the one-shot idiom.
        if in_src && raw.contains("env::var(\"PHAST_") {
            let one_shot = raw.contains("get_or_init")
                || lines[i.saturating_sub(3)..i].iter().any(|l| l.contains("get_or_init"));
            if !one_shot && !marker_above(&lines, i, "LINT-ALLOW: env-read") {
                out.push(Violation {
                    file: rel_s.clone(),
                    line: n,
                    rule: "L3 env-read",
                    msg: "PHAST_* env read outside the knob surface (use OnceLock \
                          get_or_init or `// LINT-ALLOW: env-read`)"
                        .into(),
                });
            }
        }

        // L4: kernels are time-independent.
        if in_ops && (raw.contains("Instant::now") || raw.contains("SystemTime::now")) {
            out.push(Violation {
                file: rel_s.clone(),
                line: n,
                rule: "L4 kernel-time",
                msg: "time-dependent call inside src/ops (kernels must be deterministic)"
                    .into(),
            });
        }
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

fn main() {
    // Run from the crate root (tools/lint.sh does `cd rust`); fall back
    // to a `rust/` child so a repo-root invocation also works.
    let root = if Path::new("src").is_dir() {
        PathBuf::from(".")
    } else {
        PathBuf::from("rust")
    };
    let me = root.join("src/bin/phast_lint.rs");
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches", "../examples"] {
        walk(&root.join(sub), &mut files);
    }
    files.sort();

    let mut violations = Vec::new();
    for f in &files {
        if f.canonicalize().ok() == me.canonicalize().ok() {
            continue; // the rule patterns above are not violations
        }
        lint_file(&root, f, &mut violations);
    }

    for v in &violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    println!(
        "phast_lint: {} file(s) scanned, {} violation(s)",
        files.len(),
        violations.len()
    );
    if !violations.is_empty() {
        std::process::exit(1);
    }
}
