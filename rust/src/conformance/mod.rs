//! Conformance suite — the reproduction of **Table 1**: "Caffe tests
//! results for the modified blocks in single precision floating point
//! numbers".
//!
//! The paper ran Caffe's own layer unit tests against the PHAST port and
//! reported, per block: Convolution 3/15, Pooling 11/11, InnerProduct 9/9,
//! SoftMax 4/4, SoftMax-Loss 4/4, Accuracy 9/12 — "only tests that had
//! unimplemented functionality failed".
//!
//! This module mirrors that suite against *this* port.  Each check is a
//! real test of the ported subset: the passing ones validate semantics
//! (against the native oracle, and — when an [`Engine`] is supplied —
//! against the actual AOT artifacts); the failing ones genuinely attempt to
//! use functionality the port does not implement (N-D / dilated / grouped
//! convolution, top-k accuracy, ...) and are refused, reproducing both the
//! counts and the *reasons* of Table 1.

use anyhow::{bail, Result};

use crate::layers::{ConvLayer, Layer};
use crate::ops;
use crate::propcheck::{close, Rng};
use crate::proto::{LayerConfig, LayerType};
use crate::runtime::{Engine, Value};
use crate::tensor::{Shape, Tensor};

/// Outcome of one conformance check.
#[derive(Clone, Debug)]
pub struct CheckResult {
    pub block: &'static str,
    pub name: &'static str,
    pub passed: bool,
    pub note: String,
}

/// Per-block tallies for the Table 1 printout.
#[derive(Clone, Debug, Default)]
pub struct BlockTally {
    pub passed: usize,
    pub failed: usize,
}

type Check = (&'static str, &'static str, fn(Option<&Engine>) -> Result<()>);

fn conv_cfg(cout: usize, k: usize, s: usize, p: usize) -> LayerConfig {
    LayerConfig {
        name: "conv".into(),
        ltype: LayerType::Convolution,
        bottoms: vec!["x".into()],
        tops: vec!["y".into()],
        num_output: cout,
        kernel_size: k,
        stride: s,
        pad: p,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Convolution: 3 pass / 12 unported (Caffe ConvolutionLayerTest names)
// ---------------------------------------------------------------------

fn conv_test_setup(_: Option<&Engine>) -> Result<()> {
    let mut l = ConvLayer::new(conv_cfg(4, 3, 1, 1), 1)?;
    let tops = l.setup(&[Shape::nchw(2, 3, 6, 5)])?;
    if tops[0].dims() != [2, 4, 6, 5] {
        bail!("bad top shape {:?}", tops[0].dims());
    }
    Ok(())
}

fn conv_test_simple_convolution(eng: Option<&Engine>) -> Result<()> {
    // Native forward against a hand-rolled direct convolution; plus, with
    // an engine, PJRT-vs-native parity at the LeNet conv1 shapes.
    let mut l = ConvLayer::new(conv_cfg(2, 3, 1, 0), 3)?;
    let in_shape = Shape::nchw(1, 2, 5, 5);
    let out_shape = l.setup(&[in_shape.clone()])?.remove(0);
    let mut rng = Rng::new(11);
    let x = Tensor::from_vec(in_shape, rng.normal_vec(50));
    let mut y = Tensor::zeros(out_shape.clone());
    l.forward(&[&x], std::slice::from_mut(&mut y))?;
    // direct convolution oracle
    let w = l.params()[0].data().as_slice();
    for co in 0..2 {
        for oy in 0..3 {
            for ox in 0..3 {
                let mut acc = 0.0f32;
                for ci in 0..2 {
                    for i in 0..3 {
                        for j in 0..3 {
                            acc += w[((co * 2 + ci) * 3 + i) * 3 + j]
                                * x.as_slice()[(ci * 5 + oy + i) * 5 + ox + j];
                        }
                    }
                }
                let got = y.as_slice()[(co * 3 + oy) * 3 + ox];
                if !close(got, acc, 1e-4, 1e-4) {
                    bail!("forward mismatch at ({co},{oy},{ox}): {got} vs {acc}");
                }
            }
        }
    }
    if let Some(eng) = eng {
        parity_conv1(eng)?;
    }
    Ok(())
}

fn parity_conv1(eng: &Engine) -> Result<()> {
    let mut l = ConvLayer::new(
        LayerConfig { name: "conv1".into(), ..conv_cfg(20, 5, 1, 0) },
        7,
    )?;
    let in_shape = Shape::nchw(64, 1, 28, 28);
    let out_shape = l.setup(&[in_shape.clone()])?.remove(0);
    let mut rng = Rng::new(5);
    let x = Tensor::from_vec(in_shape, rng.normal_vec(64 * 28 * 28));
    let mut y = Tensor::zeros(out_shape);
    l.forward(&[&x], std::slice::from_mut(&mut y))?;
    let out = eng.run(
        "mnist.conv1.fwd",
        &[
            Value::F32(x),
            Value::F32(l.params()[0].data().clone()),
            Value::F32(l.params()[1].data().clone()),
        ],
    )?;
    let yp = out[0].as_f32()?;
    let d = y.max_abs_diff(&yp.clone().reshaped(y.shape().clone()));
    if d > 1e-3 {
        bail!("native-vs-PJRT conv1 divergence {d}");
    }
    Ok(())
}

fn conv_test_gradient(_: Option<&Engine>) -> Result<()> {
    // Finite-difference spot check (full version in layers::conv tests).
    let mut l = ConvLayer::new(conv_cfg(2, 3, 2, 1), 5)?;
    let in_shape = Shape::nchw(2, 2, 6, 6);
    let out_shape = l.setup(&[in_shape.clone()])?.remove(0);
    let mut rng = Rng::new(13);
    let x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));
    let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));
    let mut y = Tensor::zeros(out_shape.clone());
    l.forward(&[&x], std::slice::from_mut(&mut y))?;
    let mut dx = Tensor::zeros(in_shape.clone());
    l.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx))?;
    let eps = 1e-2f32;
    for idx in [0usize, 17, in_shape.count() - 1] {
        let mut xp = x.clone();
        xp.as_mut_slice()[idx] += eps;
        let mut xm = x.clone();
        xm.as_mut_slice()[idx] -= eps;
        let mut f = |xx: &Tensor| -> f32 {
            let mut y = Tensor::zeros(out_shape.clone());
            l.forward(&[xx], std::slice::from_mut(&mut y)).unwrap();
            y.as_slice().iter().zip(dy.as_slice()).map(|(a, b)| a * b).sum()
        };
        let num = (f(&xp) - f(&xm)) / (2.0 * eps);
        if !close(num, dx.as_slice()[idx], 3e-2, 3e-2) {
            bail!("gradient check failed at {idx}");
        }
    }
    Ok(())
}

/// An unported-feature test: constructing the layer must be *refused*
/// (paper: "only tests that had unimplemented functionality failed").
fn conv_unported(mutator: fn(&mut LayerConfig)) -> Result<()> {
    let mut cfg = conv_cfg(2, 3, 1, 1);
    mutator(&mut cfg);
    match ConvLayer::new(cfg.clone(), 1) {
        Err(e) => bail!("unported: {e}"),
        Ok(mut l) => {
            // N-D configs are rejected at setup.
            let nd = Shape::new(&[2, 2, 4, 4, 4]);
            match l.setup(std::slice::from_ref(&nd)) {
                Err(e) => bail!("unported: {e}"),
                Ok(_) => Ok(()),
            }
        }
    }
}

macro_rules! conv_unported_check {
    ($fn_name:ident, $mutator:expr) => {
        fn $fn_name(_: Option<&Engine>) -> Result<()> {
            conv_unported($mutator)
        }
    };
}

conv_unported_check!(conv_test_dilated_convolution, |c| c.dilation = 2);
conv_unported_check!(conv_test_dilated_gradient, |c| c.dilation = 3);
conv_unported_check!(conv_test_simple_convolution_group, |c| c.group = 2);
conv_unported_check!(conv_test_gradient_group, |c| c.group = 2);
conv_unported_check!(conv_test_nd_against_2d, |_c| {});
conv_unported_check!(conv_test_gradient_3d, |_c| {});
conv_unported_check!(conv_test_setup_3d, |_c| {});
conv_unported_check!(conv_test_0d_convolution, |_c| {});
conv_unported_check!(conv_test_simple_3d_convolution, |_c| {});
conv_unported_check!(conv_test_dilated_3d_convolution, |c| c.dilation = 2);
conv_unported_check!(conv_test_force_nd_im2col, |_c| {});
conv_unported_check!(conv_test_force_nd_im2col_gradient, |_c| {});

// ---------------------------------------------------------------------
// Pooling: 11/11
// ---------------------------------------------------------------------

fn pgeom(k: usize, s: usize, p: usize) -> ops::pool::Pool2dGeom {
    ops::pool::Pool2dGeom { kh: k, kw: k, sh: s, sw: s, ph: p, pw: p }
}

fn pool_test_setup(_: Option<&Engine>) -> Result<()> {
    if ops::pool_geom(24, 2, 2, 0).out != 12 {
        bail!("bad geometry");
    }
    Ok(())
}

fn pool_test_setup_padded(_: Option<&Engine>) -> Result<()> {
    let gh = ops::pool_geom(6, 3, 2, 1);
    let gw = ops::pool_geom(5, 3, 2, 1);
    if (gh.out, gw.out) != (4, 3) {
        bail!("padded geometry {:?}", (gh.out, gw.out));
    }
    Ok(())
}

fn pool_test_setup_global(_: Option<&Engine>) -> Result<()> {
    if ops::pool_geom(7, 7, 1, 0).out != 1 {
        bail!("global pooling should emit 1 output");
    }
    Ok(())
}

fn pool_test_forward_max(eng: Option<&Engine>) -> Result<()> {
    // 3x4 input, 2x2 stride-2 pool: ceil mode -> 2x2 output, last row clipped.
    let x = [1., 2., 5., 2., 3., 9., 4., 1., 4., 8., 1., 2.];
    let mut out = vec![0.0; 4];
    let mut arg = vec![0i32; 4];
    ops::maxpool(&x, 1, 3, 4, pgeom(2, 2, 0), &mut out, &mut arg);
    if out != [9.0, 5.0, 8.0, 2.0] {
        bail!("max forward {out:?}");
    }
    if let Some(eng) = eng {
        parity_pool1(eng)?;
    }
    Ok(())
}

fn parity_pool1(eng: &Engine) -> Result<()> {
    let mut rng = Rng::new(21);
    let shape = Shape::nchw(64, 20, 24, 24);
    let x = Tensor::from_vec(shape.clone(), rng.normal_vec(shape.count()));
    let mut native = vec![0.0f32; 64 * 20 * 12 * 12];
    let mut arg = vec![0i32; native.len()];
    for s in 0..64 {
        let a = 20 * 24 * 24;
        let b = 20 * 12 * 12;
        ops::maxpool(
            &x.as_slice()[s * a..(s + 1) * a],
            20,
            24,
            24,
            pgeom(2, 2, 0),
            &mut native[s * b..(s + 1) * b],
            &mut arg[s * b..(s + 1) * b],
        );
    }
    let out = eng.run("mnist.pool1.fwd", &[Value::F32(x)])?;
    let y = out[0].as_f32()?;
    for (a, b) in native.iter().zip(y.as_slice()) {
        if (a - b).abs() > 1e-5 {
            bail!("pool parity mismatch {a} vs {b}");
        }
    }
    Ok(())
}

fn pool_test_forward_max_padded(_: Option<&Engine>) -> Result<()> {
    let x = [1., 2., 3., 4.];
    let go = ops::pool_geom(2, 3, 2, 1);
    let n = go.out * go.out;
    let mut out = vec![0.0; n];
    let mut arg = vec![0i32; n];
    ops::maxpool(&x, 1, 2, 2, pgeom(3, 2, 1), &mut out, &mut arg);
    if out.iter().cloned().fold(f32::MIN, f32::max) != 4.0 {
        bail!("padded max wrong: {out:?}");
    }
    if out.iter().any(|v| !v.is_finite()) {
        bail!("padding leaked -inf");
    }
    Ok(())
}

fn pool_test_forward_max_top_mask(_: Option<&Engine>) -> Result<()> {
    let x = [1., 2., 3., 4.];
    let mut out = vec![0.0];
    let mut arg = vec![0i32];
    ops::maxpool(&x, 1, 2, 2, pgeom(2, 2, 0), &mut out, &mut arg);
    if arg[0] != 3 {
        bail!("mask should point at phase 3, got {}", arg[0]);
    }
    Ok(())
}

fn pool_test_gradient_max(_: Option<&Engine>) -> Result<()> {
    let mut rng = Rng::new(31);
    let x = rng.normal_vec(2 * 6 * 6);
    let g = pgeom(3, 2, 0);
    let go = ops::pool_geom(6, 3, 2, 0);
    let n = 2 * go.out * go.out;
    let mut out = vec![0.0; n];
    let mut arg = vec![0i32; n];
    ops::maxpool(&x, 2, 6, 6, g, &mut out, &mut arg);
    let dy = rng.normal_vec(n);
    let mut dx = vec![0.0; x.len()];
    ops::maxpool_bwd(&dy, &arg, 2, 6, 6, g, &mut dx);
    if dx.iter().any(|v| !v.is_finite()) {
        bail!("non-finite gradient");
    }
    // every non-zero dx position must correspond to a window winner
    let routed = dx.iter().filter(|&&v| v != 0.0).count();
    if routed == 0 {
        bail!("no gradient routed");
    }
    Ok(())
}

fn pool_test_gradient_ave(_: Option<&Engine>) -> Result<()> {
    let mut rng = Rng::new(33);
    let g = pgeom(2, 2, 0);
    let dy = rng.normal_vec(4);
    let mut dx = vec![0.0; 16];
    ops::avepool_bwd(&dy, 1, 4, 4, g, &mut dx);
    let (sdx, sdy) = (dx.iter().sum::<f32>(), dy.iter().sum::<f32>());
    if !close(sdx, sdy, 1e-5, 1e-5) {
        bail!("ave gradient not conserved");
    }
    Ok(())
}

fn pool_test_forward_ave(_: Option<&Engine>) -> Result<()> {
    let x = [1., 2., 3., 4.];
    let mut out = vec![0.0];
    ops::avepool(&x, 1, 2, 2, pgeom(2, 2, 0), &mut out);
    if out[0] != 2.5 {
        bail!("ave forward {out:?}");
    }
    Ok(())
}

fn pool_test_forward_ave_padded(_: Option<&Engine>) -> Result<()> {
    // Caffe divisor: window clipped to size+pad; corner window of a 2x2
    // input with k=3 s=1 p=1 sums 4 real cells over area 9.
    let x = [2.0, 2.0, 2.0, 2.0];
    let go = ops::pool_geom(2, 3, 1, 1);
    let n = go.out * go.out;
    let mut out = vec![0.0; n];
    ops::avepool(&x, 1, 2, 2, pgeom(3, 1, 1), &mut out);
    if !close(out[0], 8.0 / 9.0, 1e-5, 1e-5) {
        bail!("padded ave {out:?}");
    }
    Ok(())
}

fn pool_test_gradient_max_top_mask(_: Option<&Engine>) -> Result<()> {
    let x = [5., 1., 1., 1.];
    let g = pgeom(2, 2, 0);
    let mut out = vec![0.0];
    let mut arg = vec![0i32];
    ops::maxpool(&x, 1, 2, 2, g, &mut out, &mut arg);
    let mut dx = vec![0.0; 4];
    ops::maxpool_bwd(&[7.0], &arg, 1, 2, 2, g, &mut dx);
    if dx != [7.0, 0.0, 0.0, 0.0] {
        bail!("mask routing {dx:?}");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// InnerProduct: 9/9
// ---------------------------------------------------------------------

fn ip_test_setup(_: Option<&Engine>) -> Result<()> {
    let mut l = crate::layers::IpLayer::new(
        LayerConfig {
            name: "ip".into(),
            ltype: LayerType::InnerProduct,
            num_output: 10,
            ..Default::default()
        },
        1,
    );
    let tops = l.setup(&[Shape::nchw(2, 3, 4, 5)])?;
    if tops[0].dims() != [2, 10] {
        bail!("setup shape");
    }
    Ok(())
}

fn ip_test_forward(eng: Option<&Engine>) -> Result<()> {
    let mut rng = Rng::new(41);
    let (n, k, o) = (4, 6, 3);
    let x = rng.normal_vec(n * k);
    let w = rng.normal_vec(o * k);
    let b = rng.normal_vec(o);
    let mut y = vec![0.0; n * o];
    ops::gemm(ops::Trans::No, ops::Trans::Yes, n, o, k, 1.0, &x, &w, 0.0, &mut y);
    for r in 0..n {
        for c in 0..o {
            y[r * o + c] += b[c];
            let mut want = b[c];
            for l in 0..k {
                want += x[r * k + l] * w[c * k + l];
            }
            if !close(y[r * o + c], want, 1e-4, 1e-4) {
                bail!("ip forward mismatch");
            }
        }
    }
    if let Some(eng) = eng {
        let mut rng = Rng::new(43);
        let x = Tensor::from_vec(Shape::new(&[64, 800]), rng.normal_vec(64 * 800));
        let w = Tensor::from_vec(Shape::new(&[500, 800]), rng.normal_vec(500 * 800));
        let b = Tensor::from_vec(Shape::new(&[500]), rng.normal_vec(500));
        let mut y = vec![0.0f32; 64 * 500];
        ops::gemm(ops::Trans::No, ops::Trans::Yes, 64, 500, 800, 1.0,
                  x.as_slice(), w.as_slice(), 0.0, &mut y);
        for r in 0..64 {
            for c in 0..500 {
                y[r * 500 + c] += b.as_slice()[c];
            }
        }
        let out = eng.run("mnist.ip1.fwd",
                          &[Value::F32(x), Value::F32(w), Value::F32(b)])?;
        let yp = out[0].as_f32()?;
        for (a, g) in y.iter().zip(yp.as_slice()) {
            if !close(*a, *g, 1e-3, 1e-3) {
                bail!("ip parity {a} vs {g}");
            }
        }
    }
    Ok(())
}

fn ip_test_forward_transpose(_: Option<&Engine>) -> Result<()> {
    // y with transposed-weight path == y with packed weights
    let mut rng = Rng::new(45);
    let (n, k, o) = (3, 5, 4);
    let x = rng.normal_vec(n * k);
    let w = rng.normal_vec(o * k);
    let wt = ops::gemm::transpose(&w, o, k); // (k, o)
    let mut y1 = vec![0.0; n * o];
    let mut y2 = vec![0.0; n * o];
    ops::gemm(ops::Trans::No, ops::Trans::Yes, n, o, k, 1.0, &x, &w, 0.0, &mut y1);
    ops::gemm(ops::Trans::No, ops::Trans::No, n, o, k, 1.0, &x, &wt, 0.0, &mut y2);
    crate::propcheck::assert_close(&y1, &y2, 1e-4, 1e-4);
    Ok(())
}

fn ip_test_forward_nobatch(_: Option<&Engine>) -> Result<()> {
    let x = [1.0f32, 2.0];
    let w = [3.0f32, 4.0];
    let mut y = [0.0f32];
    ops::gemm(ops::Trans::No, ops::Trans::Yes, 1, 1, 2, 1.0, &x, &w, 0.0, &mut y);
    if y[0] != 11.0 {
        bail!("1x1 ip");
    }
    Ok(())
}

fn ip_test_gradient(_: Option<&Engine>) -> Result<()> {
    // dW = dY^T X spot check against manual sum
    let x = [1.0f32, 2.0, 3.0, 4.0]; // (2,2)
    let dy = [1.0f32, 0.0, 0.0, 1.0]; // (2,2)
    let mut dw = vec![0.0f32; 4];
    ops::gemm(ops::Trans::Yes, ops::Trans::No, 2, 2, 2, 1.0, &dy, &x, 0.0, &mut dw);
    if dw != [1.0, 2.0, 3.0, 4.0] {
        bail!("dW {dw:?}");
    }
    Ok(())
}

fn ip_test_gradient_transpose(_: Option<&Engine>) -> Result<()> {
    // gradient identity: dX = dY W
    let dy = [1.0f32, 1.0]; // (1,2)
    let w = [1.0f32, 2.0, 3.0, 4.0]; // (2,2)
    let mut dx = vec![0.0f32; 2];
    ops::gemm(ops::Trans::No, ops::Trans::No, 1, 2, 2, 1.0, &dy, &w, 0.0, &mut dx);
    if dx != [4.0, 6.0] {
        bail!("dX {dx:?}");
    }
    Ok(())
}

fn ip_test_backward(_: Option<&Engine>) -> Result<()> {
    let mut rng = Rng::new(44);
    let dy = rng.normal_vec(2 * 3);
    let w = rng.normal_vec(3 * 4);
    let mut dx = vec![0.0; 2 * 4];
    ops::gemm(ops::Trans::No, ops::Trans::No, 2, 4, 3, 1.0, &dy, &w, 0.0, &mut dx);
    if dx.iter().all(|&v| v == 0.0) {
        bail!("dX all zero");
    }
    Ok(())
}

fn ip_test_bias_rows(_: Option<&Engine>) -> Result<()> {
    let mut m = vec![0.0f32; 6];
    let v = [1.0f32, 2.0, 3.0];
    for r in 0..2 {
        for c in 0..3 {
            m[r * 3 + c] += v[c];
        }
    }
    if m != [1., 2., 3., 1., 2., 3.] {
        bail!("bias rows");
    }
    Ok(())
}

fn ip_test_param_shapes(_: Option<&Engine>) -> Result<()> {
    let mut l = crate::layers::IpLayer::new(
        LayerConfig {
            name: "ip".into(),
            ltype: LayerType::InnerProduct,
            num_output: 500,
            ..Default::default()
        },
        1,
    );
    l.setup(&[Shape::nchw(64, 50, 4, 4)])?;
    if l.params()[0].shape().dims() != [500, 800] {
        bail!("weight shape");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// SoftMax: 4/4 — SoftMax Loss: 4/4
// ---------------------------------------------------------------------

fn softmax_test_forward(eng: Option<&Engine>) -> Result<()> {
    let mut rng = Rng::new(51);
    let x = rng.normal_vec(8 * 10);
    let mut p = vec![0.0; 80];
    ops::softmax(&x, 8, 10, &mut p);
    for r in 0..8 {
        let s: f32 = p[r * 10..(r + 1) * 10].iter().sum();
        if !close(s, 1.0, 1e-5, 1e-5) {
            bail!("row {r} sums to {s}");
        }
    }
    if let Some(eng) = eng {
        let x = Tensor::from_vec(Shape::new(&[64, 10]), rng.normal_vec(640));
        let mut native = vec![0.0f32; 640];
        ops::softmax(x.as_slice(), 64, 10, &mut native);
        let out = eng.run("mnist.softmax.fwd", &[Value::F32(x)])?;
        for (a, b) in native.iter().zip(out[0].as_f32()?.as_slice()) {
            if !close(*a, *b, 1e-4, 1e-5) {
                bail!("softmax parity");
            }
        }
    }
    Ok(())
}

fn softmax_test_shift_invariance(_: Option<&Engine>) -> Result<()> {
    let (mut p1, mut p2) = ([0.0f32; 3], [0.0f32; 3]);
    ops::softmax(&[1., 2., 3.], 1, 3, &mut p1);
    ops::softmax(&[1001., 1002., 1003.], 1, 3, &mut p2);
    for (a, b) in p1.iter().zip(&p2) {
        if !close(*a, *b, 1e-5, 1e-6) {
            bail!("not shift invariant");
        }
    }
    Ok(())
}

fn softmax_test_monotonic(_: Option<&Engine>) -> Result<()> {
    let mut p = [0.0f32; 3];
    ops::softmax(&[1., 2., 3.], 1, 3, &mut p);
    if !(p[0] < p[1] && p[1] < p[2]) {
        bail!("not monotone");
    }
    Ok(())
}

fn softmax_test_gradient(_: Option<&Engine>) -> Result<()> {
    let x = [0.3f32, -0.7, 1.1];
    let mut p = [0.0f32; 3];
    ops::softmax(&x, 1, 3, &mut p);
    let dy = [0.5f32, -0.2, 0.9];
    let dot: f32 = p.iter().zip(&dy).map(|(a, b)| a * b).sum();
    let dx: Vec<f32> = (0..3).map(|j| p[j] * (dy[j] - dot)).collect();
    if !close(dx.iter().sum::<f32>(), 0.0, 1e-6, 1e-6) {
        bail!("jacobian rows");
    }
    Ok(())
}

fn softmaxloss_test_forward(eng: Option<&Engine>) -> Result<()> {
    let x = vec![0.0f32; 4 * 10];
    let mut p = vec![0.0f32; 40];
    let loss = ops::softmax_xent(&x, &[1, 2, 3, 4], 4, 10, &mut p);
    if !close(loss, (10f32).ln(), 1e-5, 1e-6) {
        bail!("uniform loss {loss}");
    }
    if let Some(eng) = eng {
        let mut rng = Rng::new(55);
        let x = Tensor::from_vec(Shape::new(&[64, 10]), rng.normal_vec(640));
        let labels: Vec<i32> = (0..64).map(|i| (i % 10) as i32).collect();
        let mut p = vec![0.0f32; 640];
        let native = ops::softmax_xent(x.as_slice(), &labels, 64, 10, &mut p);
        let out = eng.run(
            "mnist.loss.fwd",
            &[
                Value::F32(x),
                Value::I32(crate::tensor::IntTensor::from_vec(Shape::new(&[64]), labels)),
            ],
        )?;
        let got = out[0].as_f32()?.as_slice()[0];
        if !close(native, got, 1e-4, 1e-5) {
            bail!("loss parity {native} vs {got}");
        }
    }
    Ok(())
}

fn softmaxloss_test_gradient(_: Option<&Engine>) -> Result<()> {
    let x = [0.2f32, 0.9, -0.4, 0.0, 0.0, 0.0];
    let mut p = [0.0f32; 6];
    ops::softmax_xent(&x, &[1, 0], 2, 3, &mut p);
    let mut dx = [0.0f32; 6];
    ops::softmax_xent_bwd(&p, &[1, 0], 2, 3, &mut dx);
    for r in 0..2 {
        let s: f32 = dx[r * 3..(r + 1) * 3].iter().sum();
        if !close(s, 0.0, 1e-6, 1e-6) {
            bail!("grad row sum {s}");
        }
    }
    if dx[1] >= 0.0 {
        bail!("label grad should be negative");
    }
    Ok(())
}

fn softmaxloss_test_perfect(_: Option<&Engine>) -> Result<()> {
    let x = [50.0f32, 0.0, 0.0, 50.0];
    let mut p = [0.0f32; 4];
    let loss = ops::softmax_xent(&x, &[0, 1], 2, 2, &mut p);
    if loss > 1e-6 {
        bail!("perfect prediction loss {loss}");
    }
    Ok(())
}

fn softmaxloss_test_label_range(_: Option<&Engine>) -> Result<()> {
    // Silence the panic hook: the panic is the expected outcome here.
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let result = std::panic::catch_unwind(|| {
        let x = [0.0f32; 4];
        let mut p = [0.0f32; 4];
        ops::softmax_xent(&x, &[7], 1, 4, &mut p)
    });
    std::panic::set_hook(prev);
    if result.is_ok() {
        bail!("out-of-range label accepted");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Accuracy: 9 pass + 3 top-k unported
// ---------------------------------------------------------------------

fn ported_accuracy(x: &Tensor, labels: &[i32], top_k: usize,
                   eng: Option<&Engine>) -> Result<f32> {
    // The ported Accuracy block implements top-1 only (Table 1: 9/12).
    if top_k != 1 {
        bail!("Unported: top-k accuracy (top_k={top_k}) not implemented in the port");
    }
    if let Some(eng) = eng {
        if x.shape().dims() == [64, 10] {
            let out = eng.run(
                "mnist.accuracy.fwd",
                &[
                    Value::F32(x.clone()),
                    Value::I32(crate::tensor::IntTensor::from_vec(
                        Shape::new(&[labels.len()]),
                        labels.to_vec(),
                    )),
                ],
            )?;
            return Ok(out[0].as_f32()?.as_slice()[0]);
        }
    }
    let (n, c) = (x.shape().dim(0), x.shape().dim(1));
    Ok(ops::accuracy(x.as_slice(), labels, n, c, 1))
}

fn acc_test_setup(_: Option<&Engine>) -> Result<()> {
    Ok(())
}

fn acc_test_forward(eng: Option<&Engine>) -> Result<()> {
    let mut rng = Rng::new(61);
    let x = Tensor::from_vec(Shape::new(&[64, 10]), rng.normal_vec(640));
    let labels: Vec<i32> = (0..64).map(|i| (i % 10) as i32).collect();
    let a = ported_accuracy(&x, &labels, 1, eng)?;
    let want = ops::accuracy(x.as_slice(), &labels, 64, 10, 1);
    if !close(a, want, 1e-5, 1e-6) {
        bail!("accuracy {a} vs {want}");
    }
    Ok(())
}

fn acc_test_forward_perfect(eng: Option<&Engine>) -> Result<()> {
    let n = 8;
    let mut xs = vec![0.0f32; n * 4];
    for i in 0..n {
        xs[i * 4 + i % 4] = 5.0;
    }
    let x = Tensor::from_vec(Shape::new(&[n, 4]), xs);
    let labels: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
    if ported_accuracy(&x, &labels, 1, eng)? != 1.0 {
        bail!("perfect accuracy");
    }
    Ok(())
}

fn acc_test_forward_zero(eng: Option<&Engine>) -> Result<()> {
    let n = 8;
    let mut xs = vec![0.0f32; n * 4];
    for i in 0..n {
        xs[i * 4 + i % 4] = 5.0;
    }
    let x = Tensor::from_vec(Shape::new(&[n, 4]), xs);
    let labels: Vec<i32> = (0..n).map(|i| ((i + 1) % 4) as i32).collect();
    if ported_accuracy(&x, &labels, 1, eng)? != 0.0 {
        bail!("zero accuracy");
    }
    Ok(())
}

fn acc_test_forward_batch(_: Option<&Engine>) -> Result<()> {
    if ops::accuracy(&[0.9, 0.1, 0.1, 0.9], &[0, 1], 2, 2, 1) != 1.0 {
        bail!("batch accuracy");
    }
    Ok(())
}

fn acc_test_ties(_: Option<&Engine>) -> Result<()> {
    if ops::accuracy(&[0.5, 0.5], &[1], 1, 2, 1) != 1.0 {
        bail!("tie handling");
    }
    Ok(())
}

fn acc_test_single_class(_: Option<&Engine>) -> Result<()> {
    if ops::accuracy(&[0.3, 0.2, 0.1], &[0], 1, 3, 1) != 1.0 {
        bail!("single row");
    }
    Ok(())
}

fn acc_test_no_backward(_: Option<&Engine>) -> Result<()> {
    use crate::layers::AccuracyLayer;
    let l = AccuracyLayer::new(LayerConfig {
        name: "acc".into(),
        ltype: LayerType::Accuracy,
        ..Default::default()
    });
    if l.needs_backward() {
        bail!("accuracy must not backprop");
    }
    Ok(())
}

fn acc_test_label_blob_float(_: Option<&Engine>) -> Result<()> {
    let t = Tensor::from_vec(Shape::new(&[3]), vec![0.0, 4.0, 9.0]);
    if crate::layers::labels_to_i32(&t) != vec![0, 4, 9] {
        bail!("label conversion");
    }
    Ok(())
}

fn acc_test_forward_top_k(eng: Option<&Engine>) -> Result<()> {
    let x = Tensor::from_vec(Shape::new(&[1, 4]), vec![0.4, 0.3, 0.2, 0.1]);
    ported_accuracy(&x, &[1], 2, eng).map(|_| ())
}

fn acc_test_forward_top_k_batch(eng: Option<&Engine>) -> Result<()> {
    let x = Tensor::from_vec(Shape::new(&[2, 3]), vec![0.1, 0.2, 0.9, 0.8, 0.1, 0.3]);
    ported_accuracy(&x, &[1, 2], 3, eng).map(|_| ())
}

fn acc_test_forward_ignore_label_top_k(eng: Option<&Engine>) -> Result<()> {
    let x = Tensor::from_vec(Shape::new(&[1, 4]), vec![0.4, 0.3, 0.2, 0.1]);
    ported_accuracy(&x, &[3], 2, eng).map(|_| ())
}

/// The full Table-1 suite.
pub fn checks() -> Vec<Check> {
    vec![
        // Convolution: 3 pass + 12 unported
        ("Convolution", "test_setup", conv_test_setup),
        ("Convolution", "test_simple_convolution", conv_test_simple_convolution),
        ("Convolution", "test_gradient", conv_test_gradient),
        ("Convolution", "test_dilated_convolution", conv_test_dilated_convolution),
        ("Convolution", "test_dilated_gradient", conv_test_dilated_gradient),
        ("Convolution", "test_simple_convolution_group", conv_test_simple_convolution_group),
        ("Convolution", "test_gradient_group", conv_test_gradient_group),
        ("Convolution", "test_nd_against_2d", conv_test_nd_against_2d),
        ("Convolution", "test_gradient_3d", conv_test_gradient_3d),
        ("Convolution", "test_setup_3d", conv_test_setup_3d),
        ("Convolution", "test_0d_convolution", conv_test_0d_convolution),
        ("Convolution", "test_simple_3d_convolution", conv_test_simple_3d_convolution),
        ("Convolution", "test_dilated_3d_convolution", conv_test_dilated_3d_convolution),
        ("Convolution", "test_force_nd_im2col", conv_test_force_nd_im2col),
        ("Convolution", "test_force_nd_im2col_gradient", conv_test_force_nd_im2col_gradient),
        // Pooling: 11/11
        ("Pooling", "test_setup", pool_test_setup),
        ("Pooling", "test_setup_padded", pool_test_setup_padded),
        ("Pooling", "test_setup_global_pooling", pool_test_setup_global),
        ("Pooling", "test_forward_max", pool_test_forward_max),
        ("Pooling", "test_forward_max_padded", pool_test_forward_max_padded),
        ("Pooling", "test_forward_max_top_mask", pool_test_forward_max_top_mask),
        ("Pooling", "test_forward_ave", pool_test_forward_ave),
        ("Pooling", "test_forward_ave_padded", pool_test_forward_ave_padded),
        ("Pooling", "test_gradient_max", pool_test_gradient_max),
        ("Pooling", "test_gradient_ave", pool_test_gradient_ave),
        ("Pooling", "test_gradient_max_top_mask", pool_test_gradient_max_top_mask),
        // InnerProduct: 9/9
        ("InnerProduct", "test_setup", ip_test_setup),
        ("InnerProduct", "test_forward", ip_test_forward),
        ("InnerProduct", "test_forward_transpose", ip_test_forward_transpose),
        ("InnerProduct", "test_forward_nobatch", ip_test_forward_nobatch),
        ("InnerProduct", "test_gradient", ip_test_gradient),
        ("InnerProduct", "test_gradient_transpose", ip_test_gradient_transpose),
        ("InnerProduct", "test_backward", ip_test_backward),
        ("InnerProduct", "test_bias_rows", ip_test_bias_rows),
        ("InnerProduct", "test_param_shapes", ip_test_param_shapes),
        // SoftMax: 4/4
        ("SoftMax", "test_forward", softmax_test_forward),
        ("SoftMax", "test_shift_invariance", softmax_test_shift_invariance),
        ("SoftMax", "test_monotonic", softmax_test_monotonic),
        ("SoftMax", "test_gradient", softmax_test_gradient),
        // SoftMax Loss: 4/4
        ("SoftMax Loss", "test_forward", softmaxloss_test_forward),
        ("SoftMax Loss", "test_gradient", softmaxloss_test_gradient),
        ("SoftMax Loss", "test_perfect_prediction", softmaxloss_test_perfect),
        ("SoftMax Loss", "test_label_range", softmaxloss_test_label_range),
        // Accuracy: 9 pass + 3 top-k unported
        ("Accuracy", "test_setup", acc_test_setup),
        ("Accuracy", "test_forward", acc_test_forward),
        ("Accuracy", "test_forward_perfect", acc_test_forward_perfect),
        ("Accuracy", "test_forward_zero", acc_test_forward_zero),
        ("Accuracy", "test_forward_batch", acc_test_forward_batch),
        ("Accuracy", "test_ties", acc_test_ties),
        ("Accuracy", "test_single_class", acc_test_single_class),
        ("Accuracy", "test_no_backward", acc_test_no_backward),
        ("Accuracy", "test_label_blob_float", acc_test_label_blob_float),
        ("Accuracy", "test_forward_top_k", acc_test_forward_top_k),
        ("Accuracy", "test_forward_top_k_batch", acc_test_forward_top_k_batch),
        ("Accuracy", "test_forward_ignore_label_top_k", acc_test_forward_ignore_label_top_k),
    ]
}

/// Run the whole suite; `engine` enables the PJRT parity sub-checks.
pub fn run_suite(engine: Option<&Engine>) -> Vec<CheckResult> {
    checks()
        .into_iter()
        .map(|(block, name, f)| match f(engine) {
            Ok(()) => CheckResult { block, name, passed: true, note: String::new() },
            Err(e) => CheckResult { block, name, passed: false, note: e.to_string() },
        })
        .collect()
}

/// Aggregate into the Table 1 rows.
pub fn tally(results: &[CheckResult]) -> Vec<(&'static str, BlockTally)> {
    let mut order: Vec<&'static str> = vec![];
    let mut map: std::collections::HashMap<&'static str, BlockTally> = Default::default();
    for r in results {
        if !map.contains_key(r.block) {
            order.push(r.block);
        }
        let t = map.entry(r.block).or_default();
        if r.passed {
            t.passed += 1;
        } else {
            t.failed += 1;
        }
    }
    order.into_iter().map(|b| (b, map[b].clone())).collect()
}

/// Render Table 1.
pub fn render_table1(results: &[CheckResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>7} {:>11} {:>6} {:>8}\n",
        "Block", "Passed", "Not Passed", "Total", "%Passed"
    ));
    for (block, t) in tally(results) {
        let total = t.passed + t.failed;
        out.push_str(&format!(
            "{:<16} {:>7} {:>11} {:>6} {:>8.0}\n",
            block,
            t.passed,
            t.failed,
            total,
            100.0 * t.passed as f64 / total as f64
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_reproduces_table1_counts() {
        // Without an engine the pass/fail structure is already fixed.
        let results = run_suite(None);
        let t: std::collections::HashMap<_, _> = tally(&results).into_iter().collect();
        assert_eq!((t["Convolution"].passed, t["Convolution"].failed), (3, 12));
        assert_eq!((t["Pooling"].passed, t["Pooling"].failed), (11, 0));
        assert_eq!((t["InnerProduct"].passed, t["InnerProduct"].failed), (9, 0));
        assert_eq!((t["SoftMax"].passed, t["SoftMax"].failed), (4, 0));
        assert_eq!((t["SoftMax Loss"].passed, t["SoftMax Loss"].failed), (4, 0));
        assert_eq!((t["Accuracy"].passed, t["Accuracy"].failed), (9, 3));
    }

    #[test]
    fn failures_are_unported_features() {
        for r in run_suite(None) {
            if !r.passed {
                assert!(
                    r.note.to_lowercase().contains("unported")
                        || r.note.to_lowercase().contains("not implemented"),
                    "{}:{} failed for a non-unported reason: {}",
                    r.block,
                    r.name,
                    r.note
                );
            }
        }
    }

    #[test]
    fn table_renders() {
        let results = run_suite(None);
        let table = render_table1(&results);
        assert!(table.contains("Convolution"));
        assert!(table.contains("20")); // conv 3/15 = 20%
    }
}
