//! Batch iterator: epoch shuffling + NCHW batch assembly for the Data layer.

use crate::propcheck::Rng;
use crate::tensor::{IntTensor, Shape, Tensor};

use super::synthetic::Dataset;

/// Cycles over a dataset in shuffled epochs, emitting fixed-size batches.
pub struct BatchIterator {
    ds: Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    epoch: usize,
}

impl BatchIterator {
    pub fn new(ds: Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch > 0 && ds.len() >= batch, "dataset smaller than batch");
        let mut it = BatchIterator {
            order: (0..ds.len()).collect(),
            ds,
            batch,
            cursor: 0,
            rng: Rng::new(seed ^ 0xBA7C4),
            epoch: 0,
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Batch shape (N, C, H, W).
    pub fn batch_shape(&self) -> Shape {
        let s = self.ds.spec.sample_shape();
        Shape::nchw(self.batch, s.dim(0), s.dim(1), s.dim(2))
    }

    /// Next (images, labels) batch; wraps and reshuffles at epoch end.
    pub fn next_batch(&mut self) -> (Tensor, IntTensor) {
        let n = self.ds.sample_len();
        let mut data = Vec::with_capacity(self.batch * n);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            data.extend_from_slice(self.ds.image(idx));
            labels.push(self.ds.labels[idx]);
        }
        (
            Tensor::from_vec(self.batch_shape(), data),
            IntTensor::from_vec(Shape::new(&[self.batch]), labels),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    #[test]
    fn batches_have_right_shape() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 100, 1);
        let mut it = BatchIterator::new(ds, 16, 9);
        let (x, y) = it.next_batch();
        assert_eq!(x.shape().dims(), &[16, 1, 28, 28]);
        assert_eq!(y.len(), 16);
    }

    #[test]
    fn epoch_advances_and_covers_dataset() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 32, 2);
        let mut it = BatchIterator::new(ds, 16, 3);
        assert_eq!(it.epoch(), 0);
        it.next_batch();
        it.next_batch();
        it.next_batch(); // wraps
        assert_eq!(it.epoch(), 1);
    }

    #[test]
    fn deterministic_for_seed() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 64, 2);
        let mut a = BatchIterator::new(ds.clone(), 8, 5);
        let mut b = BatchIterator::new(ds, 8, 5);
        let (xa, ya) = a.next_batch();
        let (xb, yb) = b.next_batch();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }
}
