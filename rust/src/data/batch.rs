//! Batch iterator: epoch shuffling + NCHW batch assembly for the Data layer.
//!
//! Index draws stay serial (the epoch RNG owns sequential state — the
//! shuffle order is part of the training trajectory), but the sample
//! *copies* are embarrassingly parallel: each sample's image is gathered
//! into its own contiguous block of the batch through
//! [`ops::par`](crate::ops::par).  Knobs: `PHAST_NUM_THREADS` +
//! `PHAST_DATA_GRAIN` (samples per worker).  Results are byte-identical
//! to the serial gather under any thread count (pure disjoint copies).

use crate::ops::par;
use crate::propcheck::Rng;
use crate::tensor::{IntTensor, Shape, Tensor};

use super::synthetic::Dataset;

/// Minimum samples per worker for batch assembly (`PHAST_DATA_GRAIN`).
static DATA_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_DATA_GRAIN", 8);

/// Cycles over a dataset in shuffled epochs, emitting fixed-size batches.
pub struct BatchIterator {
    ds: Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    epoch: usize,
    /// The seed `new` was called with, kept so [`seek`](Self::seek) can
    /// replay the shuffle history exactly.
    seed: u64,
}

impl BatchIterator {
    pub fn new(ds: Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch > 0 && ds.len() >= batch, "dataset smaller than batch");
        let mut it = BatchIterator {
            order: (0..ds.len()).collect(),
            ds,
            batch,
            cursor: 0,
            rng: Rng::new(seed ^ 0xBA7C4),
            epoch: 0,
            seed,
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The data-pipeline position as `(epoch, position-in-epoch)` — with
    /// the construction seed, this pair fully determines the iterator
    /// state (the RNG is consumed only by the per-epoch shuffles).
    pub fn cursor(&self) -> (usize, usize) {
        (self.epoch, self.cursor)
    }

    /// Restore the iterator to `(epoch, pos)` by replaying the shuffle
    /// history from the construction seed: the order at epoch `E` is
    /// `E + 1` cumulative shuffles of the identity permutation.  After
    /// `seek(it.cursor())` the iterator emits exactly the batches an
    /// uninterrupted run would emit — the exact-resume substrate.
    pub fn seek(&mut self, epoch: usize, pos: usize) {
        assert!(pos <= self.order.len(), "seek position past epoch end");
        self.rng = Rng::new(self.seed ^ 0xBA7C4);
        self.order = (0..self.ds.len()).collect();
        for _ in 0..=epoch {
            self.rng.shuffle(&mut self.order);
        }
        self.epoch = epoch;
        self.cursor = pos;
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Batch shape (N, C, H, W).
    pub fn batch_shape(&self) -> Shape {
        let s = self.ds.spec.sample_shape();
        Shape::nchw(self.batch, s.dim(0), s.dim(1), s.dim(2))
    }

    /// Draw the next batch's sample indices (serial: the RNG is
    /// sequential state shared across epochs).
    fn draw_indices(&mut self) -> Vec<usize> {
        let mut picks = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            picks.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        picks
    }

    /// Assemble the next batch directly into caller storage — the Data
    /// layer's top blobs — with labels widened to f32 (Caffe stores
    /// labels in float blobs).  The per-sample image copies run parallel
    /// over contiguous sample blocks; no intermediate tensor is built.
    pub fn next_batch_into(&mut self, data: &mut [f32], labels: &mut [f32]) {
        let batch = self.batch;
        self.next_shard_into(data, labels, 0..batch);
    }

    /// [`next_batch_into`](Self::next_batch_into) for a data-parallel
    /// rank: draw the **full** batch's index stream (the cursor advances
    /// by `batch_size` — every rank sees identical global cursor
    /// semantics, so snapshots stay interchangeable with single-process
    /// runs) but materialize only the contiguous sample range `shard`
    /// into `data`/`labels`.  `shard == 0..batch_size` is byte-identical
    /// to `next_batch_into`.
    pub fn next_shard_into(
        &mut self,
        data: &mut [f32],
        labels: &mut [f32],
        shard: std::ops::Range<usize>,
    ) {
        assert!(
            shard.start <= shard.end && shard.end <= self.batch,
            "shard {shard:?} out of bounds for batch {}",
            self.batch
        );
        let n = self.ds.sample_len();
        assert_eq!(data.len(), shard.len() * n, "data blob size");
        assert_eq!(labels.len(), shard.len(), "label blob size");
        let picks = self.draw_indices();
        let picks = &picks[shard];
        for (dst, &idx) in labels.iter_mut().zip(picks) {
            *dst = self.ds.labels[idx] as f32;
        }
        let ds = &self.ds;
        let tune = par::Tuning::new(DATA_GRAIN.get());
        par::parallel_chunks_mut(data, n, tune, |samples, block| {
            for (bi, s) in samples.enumerate() {
                block[bi * n..(bi + 1) * n].copy_from_slice(ds.image(picks[s]));
            }
        });
    }

    /// Next (images, labels) batch; wraps and reshuffles at epoch end.
    pub fn next_batch(&mut self) -> (Tensor, IntTensor) {
        let n = self.ds.sample_len();
        let mut data = vec![0.0f32; self.batch * n];
        let mut labf = vec![0.0f32; self.batch];
        self.next_batch_into(&mut data, &mut labf);
        let labels: Vec<i32> = labf.iter().map(|&v| v as i32).collect();
        (
            Tensor::from_vec(self.batch_shape(), data),
            IntTensor::from_vec(Shape::new(&[self.batch]), labels),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    #[test]
    fn batches_have_right_shape() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 100, 1);
        let mut it = BatchIterator::new(ds, 16, 9);
        let (x, y) = it.next_batch();
        assert_eq!(x.shape().dims(), &[16, 1, 28, 28]);
        assert_eq!(y.len(), 16);
    }

    #[test]
    fn epoch_advances_and_covers_dataset() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 32, 2);
        let mut it = BatchIterator::new(ds, 16, 3);
        assert_eq!(it.epoch(), 0);
        it.next_batch();
        it.next_batch();
        it.next_batch(); // wraps
        assert_eq!(it.epoch(), 1);
    }

    #[test]
    fn deterministic_for_seed() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 64, 2);
        let mut a = BatchIterator::new(ds.clone(), 8, 5);
        let mut b = BatchIterator::new(ds, 8, 5);
        let (xa, ya) = a.next_batch();
        let (xb, yb) = b.next_batch();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn assembly_invariant_to_thread_count() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 64, 7);
        let mut serial = BatchIterator::new(ds.clone(), 32, 5);
        let (want_x, want_y) = par::with_threads(1, || serial.next_batch());
        for t in [2usize, 5, 16] {
            let mut it = BatchIterator::new(ds.clone(), 32, 5);
            let (x, y) = par::with_threads(t, || it.next_batch());
            assert_eq!(want_x, x, "batch data diverged at {t} threads");
            assert_eq!(want_y, y, "batch labels diverged at {t} threads");
        }
    }

    #[test]
    fn seek_restores_exact_position() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 48, 3);
        let mut a = BatchIterator::new(ds.clone(), 16, 9);
        // Advance past an epoch boundary (48/16 = 3 batches per epoch).
        for _ in 0..5 {
            a.next_batch();
        }
        let (epoch, pos) = a.cursor();
        assert_eq!(epoch, 1);
        let mut b = BatchIterator::new(ds, 16, 9);
        b.seek(epoch, pos);
        // The restored iterator emits exactly the uninterrupted stream,
        // including across the next epoch wrap.
        for _ in 0..4 {
            let (xa, ya) = a.next_batch();
            let (xb, yb) = b.next_batch();
            assert_eq!(xa, xb);
            assert_eq!(ya, yb);
        }
        assert_eq!(a.cursor(), b.cursor());
    }

    #[test]
    fn seek_to_start_matches_fresh_iterator() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 32, 2);
        let mut a = BatchIterator::new(ds.clone(), 8, 5);
        for _ in 0..7 {
            a.next_batch();
        }
        a.seek(0, 0);
        let mut fresh = BatchIterator::new(ds, 8, 5);
        let (xa, ya) = a.next_batch();
        let (xf, yf) = fresh.next_batch();
        assert_eq!(xa, xf);
        assert_eq!(ya, yf);
    }

    #[test]
    fn shards_concatenate_to_the_full_batch_with_identical_cursors() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 48, 3);
        let mut full = BatchIterator::new(ds.clone(), 16, 9);
        let (x, y) = full.next_batch();
        let n = x.len() / 16;

        // Three contiguous shards per ops::par partition rules
        // (16 into 3 → 6, 5, 5), drawn by independent iterators.
        let parts = crate::ops::par::partition(16, 3);
        let mut got_x = Vec::new();
        let mut got_y = Vec::new();
        let mut cursors = Vec::new();
        for r in parts {
            let mut it = BatchIterator::new(ds.clone(), 16, 9);
            let mut data = vec![0.0f32; r.len() * n];
            let mut labels = vec![0.0f32; r.len()];
            it.next_shard_into(&mut data, &mut labels, r);
            got_x.extend_from_slice(&data);
            got_y.extend_from_slice(&labels);
            cursors.push(it.cursor());
        }
        assert_eq!(x.as_slice(), &got_x[..]);
        for (want, got) in y.as_slice().iter().zip(&got_y) {
            assert_eq!(*want as f32, *got);
        }
        // Every rank advanced by the FULL batch: global cursor semantics.
        for c in cursors {
            assert_eq!(c, full.cursor());
        }
    }

    #[test]
    fn next_batch_into_matches_next_batch() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 48, 3);
        let mut a = BatchIterator::new(ds.clone(), 16, 9);
        let mut b = BatchIterator::new(ds, 16, 9);
        let (x, y) = a.next_batch();
        let n = x.len() / 16;
        let mut data = vec![0.0f32; 16 * n];
        let mut labels = vec![0.0f32; 16];
        b.next_batch_into(&mut data, &mut labels);
        assert_eq!(x.as_slice(), &data[..]);
        for (want, got) in y.as_slice().iter().zip(&labels) {
            assert_eq!(*want as f32, *got);
        }
    }
}
