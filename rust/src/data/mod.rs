//! Data pipeline: synthetic datasets standing in for MNIST / CIFAR-10, a
//! binary record format (the LMDB stand-in Caffe reads from), and the batch
//! iterator the Data layer consumes.
//!
//! The paper's evaluation needs image sets only as a workload — its metrics
//! are forward-backward time and block-level conformance — but the E2E
//! example must genuinely *learn*, so the generators produce structured,
//! separable classes: per-class stroke templates (MNIST analog) and
//! color/texture patterns (CIFAR analog) with translation jitter and noise.

mod synthetic;
mod records;
mod batch;

pub use synthetic::{Dataset, SyntheticSpec};
pub use records::{read_records, write_records};
pub use batch::BatchIterator;
