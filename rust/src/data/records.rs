//! On-disk record format — the LMDB stand-in.
//!
//! Layout (little-endian):
//!   magic "PCRF" | u32 version | u32 count | u32 sample_len | u8 spec_tag
//!   then per record: i32 label, sample_len * f32 pixels.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::synthetic::{Dataset, SyntheticSpec};

const MAGIC: &[u8; 4] = b"PCRF";
const VERSION: u32 = 1;

fn spec_tag(spec: SyntheticSpec) -> u8 {
    match spec {
        SyntheticSpec::Mnist => 1,
        SyntheticSpec::Cifar10 => 2,
    }
}

fn tag_spec(tag: u8) -> Result<SyntheticSpec> {
    Ok(match tag {
        1 => SyntheticSpec::Mnist,
        2 => SyntheticSpec::Cifar10,
        t => bail!("unknown dataset tag {t}"),
    })
}

/// Serialize a dataset to `path`.
pub fn write_records(path: &Path, ds: &Dataset) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&(ds.sample_len() as u32).to_le_bytes())?;
    w.write_all(&[spec_tag(ds.spec)])?;
    for i in 0..ds.len() {
        w.write_all(&ds.labels[i].to_le_bytes())?;
        for v in ds.image(i) {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Load a dataset from `path`.
pub fn read_records(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not a phast-caffe record file");
    }
    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION {
        bail!("unsupported record version {version}");
    }
    r.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    r.read_exact(&mut u32buf)?;
    let sample_len = u32::from_le_bytes(u32buf) as usize;
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let spec = tag_spec(tag[0])?;
    if spec.sample_shape().count() != sample_len {
        bail!("sample_len {sample_len} inconsistent with {spec:?}");
    }
    let mut labels = Vec::with_capacity(count);
    let mut images = Vec::with_capacity(count * sample_len);
    let mut fbuf = vec![0u8; sample_len * 4];
    for _ in 0..count {
        r.read_exact(&mut u32buf)?;
        labels.push(i32::from_le_bytes(u32buf));
        r.read_exact(&mut fbuf)?;
        for ch in fbuf.chunks_exact(4) {
            images.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
        }
    }
    Ok(Dataset { spec, images, labels })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ds = Dataset::generate(SyntheticSpec::Mnist, 10, 5);
        let dir = std::env::temp_dir().join("phast_caffe_test_records");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mnist.pcrf");
        write_records(&path, &ds).unwrap();
        let back = read_records(&path).unwrap();
        assert_eq!(back.spec, ds.spec);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.images, ds.images);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("phast_caffe_test_records");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.pcrf");
        std::fs::write(&path, b"not a record file at all").unwrap();
        assert!(read_records(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
