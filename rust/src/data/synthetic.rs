//! Procedural datasets with the exact shapes of the paper's workloads
//! (28x28x1 / 10 classes, 32x32x3 / 10 classes) and enough class structure
//! that LeNet reaches high accuracy within a few hundred steps.

use crate::propcheck::Rng;
use crate::tensor::Shape;

/// What to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyntheticSpec {
    /// 28x28 grayscale digit-like strokes, 10 classes.
    Mnist,
    /// 32x32 RGB color/texture patterns, 10 classes.
    Cifar10,
}

impl SyntheticSpec {
    pub fn from_source(source: &str) -> Option<Self> {
        match source {
            "synthetic-mnist" | "mnist" => Some(SyntheticSpec::Mnist),
            "synthetic-cifar10" | "cifar10" | "cifar" => Some(SyntheticSpec::Cifar10),
            _ => None,
        }
    }

    pub fn sample_shape(&self) -> Shape {
        match self {
            SyntheticSpec::Mnist => Shape::new(&[1, 28, 28]),
            SyntheticSpec::Cifar10 => Shape::new(&[3, 32, 32]),
        }
    }

    pub fn num_classes(&self) -> usize {
        10
    }
}

/// An in-memory labelled dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub spec: SyntheticSpec,
    /// Flattened samples, each `sample_len` long, pixel range [0, 1].
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn sample_len(&self) -> usize {
        self.spec.sample_shape().count()
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.sample_len();
        &self.images[i * n..(i + 1) * n]
    }

    /// Generate `count` samples with a deterministic seed.
    pub fn generate(spec: SyntheticSpec, count: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
        let n = spec.sample_shape().count();
        let mut images = Vec::with_capacity(count * n);
        let mut labels = Vec::with_capacity(count);
        for _ in 0..count {
            let label = rng.range(0, spec.num_classes() - 1);
            labels.push(label as i32);
            match spec {
                SyntheticSpec::Mnist => gen_mnist_like(&mut rng, label, &mut images),
                SyntheticSpec::Cifar10 => gen_cifar_like(&mut rng, label, &mut images),
            }
        }
        Dataset { spec, images, labels }
    }
}

/// Draw an anti-aliased line segment into a 28x28 canvas.
fn draw_line(img: &mut [f32], x0: f32, y0: f32, x1: f32, y1: f32) {
    let steps = 40;
    for t in 0..=steps {
        let f = t as f32 / steps as f32;
        let x = x0 + (x1 - x0) * f;
        let y = y0 + (y1 - y0) * f;
        for dy in -1..=1i32 {
            for dx in -1..=1i32 {
                let xi = (x + dx as f32).round() as i32;
                let yi = (y + dy as f32).round() as i32;
                if (0..28).contains(&xi) && (0..28).contains(&yi) {
                    let d2 = (x - xi as f32).powi(2) + (y - yi as f32).powi(2);
                    let v = (1.2 - d2).clamp(0.0, 1.0);
                    let idx = (yi * 28 + xi) as usize;
                    img[idx] = img[idx].max(v);
                }
            }
        }
    }
}

/// Stroke templates per class (digit-like glyphs), jittered and noised.
fn gen_mnist_like(rng: &mut Rng, label: usize, out: &mut Vec<f32>) {
    let mut img = vec![0.0f32; 28 * 28];
    let jx = rng.range_f32(-2.0, 2.0);
    let jy = rng.range_f32(-2.0, 2.0);
    let s = rng.range_f32(0.85, 1.15); // scale jitter
    let strokes: &[(f32, f32, f32, f32)] = match label {
        0 => &[(9., 7., 19., 7.), (19., 7., 19., 21.), (19., 21., 9., 21.), (9., 21., 9., 7.)],
        1 => &[(14., 5., 14., 23.), (11., 8., 14., 5.)],
        2 => &[(9., 8., 19., 8.), (19., 8., 19., 14.), (19., 14., 9., 21.), (9., 21., 19., 21.)],
        3 => &[(9., 7., 19., 7.), (19., 7., 13., 14.), (13., 14., 19., 21.), (19., 21., 9., 21.)],
        4 => &[(17., 5., 9., 16.), (9., 16., 20., 16.), (17., 5., 17., 23.)],
        5 => &[(19., 7., 9., 7.), (9., 7., 9., 14.), (9., 14., 19., 14.), (19., 14., 19., 21.), (19., 21., 9., 21.)],
        6 => &[(17., 6., 10., 14.), (10., 14., 10., 21.), (10., 21., 18., 21.), (18., 21., 18., 15.), (18., 15., 10., 15.)],
        7 => &[(9., 7., 19., 7.), (19., 7., 12., 23.)],
        8 => &[(10., 7., 18., 7.), (18., 7., 18., 21.), (18., 21., 10., 21.), (10., 21., 10., 7.), (10., 14., 18., 14.)],
        _ => &[(10., 7., 18., 7.), (18., 7., 18., 14.), (18., 14., 10., 14.), (10., 14., 10., 7.), (18., 14., 18., 22.)],
    };
    let cx = 14.0;
    let cy = 14.0;
    for &(x0, y0, x1, y1) in strokes {
        draw_line(
            &mut img,
            cx + (x0 - cx) * s + jx,
            cy + (y0 - cy) * s + jy,
            cx + (x1 - cx) * s + jx,
            cy + (y1 - cy) * s + jy,
        );
    }
    for v in img.iter_mut() {
        *v = (*v + 0.08 * rng.normal()).clamp(0.0, 1.0);
    }
    out.extend_from_slice(&img);
}

/// CIFAR analog: class = (dominant hue, spatial pattern) pair.
fn gen_cifar_like(rng: &mut Rng, label: usize, out: &mut Vec<f32>) {
    const W: usize = 32;
    let hue = label % 5;
    let pattern = label / 5; // 0: radial blob, 1: diagonal stripes
    let base = [
        [0.9, 0.2, 0.2],
        [0.2, 0.9, 0.2],
        [0.2, 0.3, 0.9],
        [0.9, 0.8, 0.2],
        [0.8, 0.2, 0.9],
    ][hue];
    let cx = rng.range_f32(12.0, 20.0);
    let cy = rng.range_f32(12.0, 20.0);
    let phase = rng.range_f32(0.0, 8.0);
    let mut img = vec![0.0f32; 3 * W * W];
    for y in 0..W {
        for x in 0..W {
            let m = match pattern {
                0 => {
                    let d = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)).sqrt();
                    (1.0 - d / 16.0).clamp(0.0, 1.0)
                }
                _ => {
                    let v = ((x + y) as f32 + phase) / 6.0;
                    if (v as i64) % 2 == 0 { 0.85 } else { 0.15 }
                }
            };
            for c in 0..3 {
                let noise = 0.06 * rng.normal();
                img[c * W * W + y * W + x] =
                    (base[c] * m + 0.1 + noise).clamp(0.0, 1.0);
            }
        }
    }
    out.extend_from_slice(&img);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        for spec in [SyntheticSpec::Mnist, SyntheticSpec::Cifar10] {
            let ds = Dataset::generate(spec, 32, 7);
            assert_eq!(ds.len(), 32);
            assert_eq!(ds.images.len(), 32 * ds.sample_len());
            assert!(ds.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(ds.labels.iter().all(|&l| (0..10).contains(&l)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Dataset::generate(SyntheticSpec::Mnist, 8, 42);
        let b = Dataset::generate(SyntheticSpec::Mnist, 8, 42);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::generate(SyntheticSpec::Mnist, 8, 43);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of two different classes should differ much more than
        // two draws of the same class — the separability the E2E training
        // example relies on.
        let ds = Dataset::generate(SyntheticSpec::Mnist, 400, 3);
        let n = ds.sample_len();
        let mean_of = |cls: i32| -> Vec<f32> {
            let mut acc = vec![0.0f32; n];
            let mut cnt = 0;
            for i in 0..ds.len() {
                if ds.labels[i] == cls {
                    for (a, v) in acc.iter_mut().zip(ds.image(i)) {
                        *a += v;
                    }
                    cnt += 1;
                }
            }
            acc.iter_mut().for_each(|a| *a /= cnt.max(1) as f32);
            acc
        };
        let m0 = mean_of(0);
        let m1 = mean_of(1);
        let dist: f32 = m0.iter().zip(&m1).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 10.0, "class means too close: {dist}");
    }

    #[test]
    fn source_lookup() {
        assert_eq!(SyntheticSpec::from_source("synthetic-mnist"),
                   Some(SyntheticSpec::Mnist));
        assert_eq!(SyntheticSpec::from_source("synthetic-cifar10"),
                   Some(SyntheticSpec::Cifar10));
        assert_eq!(SyntheticSpec::from_source("imagenet"), None);
    }
}
