//! Experiment drivers shared by the CLI, the examples and the benches —
//! one function per paper artifact (DESIGN.md §4 experiment index).

use std::time::Instant;

use anyhow::Result;

use crate::net::Net;
use crate::phast::{BoundaryOptions, FusedRunner, Placement, PortedNet};
use crate::proto::{presets, LayerType, NetConfig};
use crate::runtime::Engine;
use crate::tensor::{IntTensor, Shape, Tensor};

/// Build a preset net by short name ("mnist" | "cifar").
pub fn preset_net(name: &str, seed: u64) -> Result<Net> {
    let src = presets::net_by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown net '{name}' (mnist|cifar)"))?;
    Net::from_config(NetConfig::from_text(src)?, seed)
}

/// Grab one (x, labels) batch by running a net's data layer.
pub fn sample_batch(net: &mut Net) -> Result<(Tensor, IntTensor)> {
    net.forward_layer(0)?;
    let x = net.blob("data").unwrap().data().clone();
    let lf = net.blob("label").unwrap().data();
    let labels = IntTensor::from_vec(
        Shape::new(&[lf.len()]),
        lf.as_slice().iter().map(|&v| v as i32).collect(),
    );
    Ok((x, labels))
}

/// One Table 2 measurement: mean forward-backward wall time in ms.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub mean_ms: f64,
    pub reps: usize,
}

fn time_loop(reps: usize, mut f: impl FnMut() -> Result<()>) -> Result<Timing> {
    let t0 = Instant::now();
    for _ in 0..reps {
        f()?;
    }
    Ok(Timing { mean_ms: t0.elapsed().as_secs_f64() * 1000.0 / reps as f64, reps })
}

/// Table 2 rows for one net: native baseline ("Caffe"), the paper's partial
/// placement ("Caffe (PHAST)") and the fused whole-net artifact (the
/// paper's predicted fully-ported end state).
#[derive(Clone, Copy, Debug)]
pub struct Table2Row {
    pub native: Timing,
    pub partial: Timing,
    pub fused: Timing,
}

/// Run the Table 2 measurement for `name` ("mnist" | "cifar").
pub fn run_table2(engine: &Engine, name: &str, warmup: usize, reps: usize) -> Result<Table2Row> {
    // --- native baseline (original Caffe) ---
    let mut native = preset_net(name, 1)?;
    let mut native_fb = || -> Result<()> {
        native.zero_param_diffs();
        native.forward()?;
        native.backward()?;
        Ok(())
    };
    for _ in 0..warmup {
        native_fb()?;
    }
    let t_native = time_loop(reps, native_fb)?;

    // --- paper partial placement ---
    let cfg = NetConfig::from_text(presets::net_by_name(name).unwrap())?;
    let placement = Placement::paper_partial(&cfg);
    let mut partial = PortedNet::new(
        preset_net(name, 1)?,
        engine,
        placement,
        BoundaryOptions::default(),
    )?;
    for _ in 0..warmup {
        partial.forward_backward()?;
    }
    let t_partial = time_loop(reps, || partial.forward_backward().map(|_| ()))?;

    // --- fused whole-net artifact ---
    let mut feeder = preset_net(name, 1)?;
    let fused = FusedRunner::from_net(engine, &feeder)?;
    let (x, labels) = sample_batch(&mut feeder)?;
    for _ in 0..warmup {
        fused.grads(x.clone(), labels.clone())?;
    }
    let t_fused = time_loop(reps, || fused.grads(x.clone(), labels.clone()).map(|_| ()))?;

    Ok(Table2Row { native: t_native, partial: t_partial, fused: t_fused })
}

/// Render the Table 2 comparison (paper numbers alongside ours).
pub fn render_table2(mnist: &Table2Row, cifar: &Table2Row) -> String {
    let mut s = String::new();
    s.push_str("Average Forward-Backward execution time (ms), batch 64\n");
    s.push_str(&format!(
        "{:<26} {:>12} {:>12}\n",
        "configuration", "MNIST", "CIFAR-10"
    ));
    s.push_str(&format!(
        "{:<26} {:>12.2} {:>12.2}\n",
        "Caffe (native baseline)", mnist.native.mean_ms, cifar.native.mean_ms
    ));
    s.push_str(&format!(
        "{:<26} {:>12.2} {:>12.2}\n",
        "Caffe (PHAST, partial)", mnist.partial.mean_ms, cifar.partial.mean_ms
    ));
    s.push_str(&format!(
        "{:<26} {:>12.2} {:>12.2}\n",
        "Caffe (PHAST, fused)", mnist.fused.mean_ms, cifar.fused.mean_ms
    ));
    s.push_str(&format!(
        "{:<26} {:>12.2} {:>12.2}\n",
        "slowdown partial/native",
        mnist.partial.mean_ms / mnist.native.mean_ms,
        cifar.partial.mean_ms / cifar.native.mean_ms
    ));
    s.push_str(&format!(
        "{:<26} {:>12.2} {:>12.2}\n",
        "slowdown fused/native",
        mnist.fused.mean_ms / mnist.native.mean_ms,
        cifar.fused.mean_ms / cifar.native.mean_ms
    ));
    s.push_str("paper (i9-9900K/RTX2080):  CPU 2.8x, GPU 4.0x partial-port slowdown;\n");
    s.push_str("full porting predicted to remove most of the gap (paper section 4.3)\n");
    s
}

/// §4.3 transfer accounting for one placement.
#[derive(Clone, Debug)]
pub struct TransferReport {
    pub label: String,
    pub ported_layers: usize,
    pub crossings_fwd: u64,
    pub crossings_bwd: u64,
    pub conversion_bytes: u64,
    pub conversion_ms: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub executions: u64,
    pub mean_ms: f64,
}

/// Measure one placement's crossings + physical traffic over `reps`
/// forward-backward iterations.
pub fn measure_placement(
    engine: &Engine,
    name: &str,
    label: &str,
    placement: Placement,
    layout_conversion: bool,
    reps: usize,
) -> Result<TransferReport> {
    let cfg = NetConfig::from_text(presets::net_by_name(name).unwrap())?;
    let ported_layers = placement.ported_count(&cfg);
    let mut pnet = PortedNet::new(
        preset_net(name, 1)?,
        engine,
        placement,
        BoundaryOptions { layout_conversion },
    )?;
    pnet.forward_backward()?; // warmup (compiles artifacts)
    pnet.reset_stats();
    let t = time_loop(reps, || pnet.forward_backward().map(|_| ()))?;
    let st = pnet.stats;
    let es = engine.stats();
    Ok(TransferReport {
        label: label.to_string(),
        ported_layers,
        crossings_fwd: st.crossings_fwd / reps as u64,
        crossings_bwd: st.crossings_bwd / reps as u64,
        conversion_bytes: st.conversion_bytes / reps as u64,
        conversion_ms: st.conversion_time.as_secs_f64() * 1000.0 / reps as f64,
        h2d_bytes: es.h2d_bytes / reps as u64,
        d2h_bytes: es.d2h_bytes / reps as u64,
        executions: es.executions / reps as u64,
        mean_ms: t.mean_ms,
    })
}

/// The §4.3 analysis: incremental-porting sweep from nothing ported to the
/// paper placement to everything ported.
pub fn porting_sweep(engine: &Engine, name: &str, reps: usize) -> Result<Vec<TransferReport>> {
    let cfg = NetConfig::from_text(presets::net_by_name(name).unwrap())?;
    let heavy: Vec<&str> = cfg
        .layers
        .iter()
        .filter(|l| {
            matches!(
                l.ltype,
                LayerType::Convolution | LayerType::Pooling | LayerType::InnerProduct
            )
        })
        .map(|l| l.name.as_str())
        .collect();
    let mut out = vec![measure_placement(
        engine,
        name,
        "nothing ported (native)",
        Placement::native_all(),
        true,
        reps,
    )?];
    for k in 1..=heavy.len() {
        let label = format!("ported: {}", heavy[..k].join(","));
        out.push(measure_placement(
            engine,
            name,
            &label,
            Placement::ported_set(&heavy[..k]),
            true,
            reps,
        )?);
    }
    out.push(measure_placement(
        engine,
        name,
        "everything ported (per-layer)",
        Placement::phast_all(),
        true,
        reps,
    )?);
    Ok(out)
}

/// Render transfer reports as a table.
pub fn render_transfers(reports: &[TransferReport]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<36} {:>6} {:>8} {:>8} {:>10} {:>9} {:>9}\n",
        "placement", "ported", "xings.f", "xings.b", "conv.KB", "exec/it", "ms/iter"
    ));
    for r in reports {
        s.push_str(&format!(
            "{:<36} {:>6} {:>8} {:>8} {:>10.1} {:>9} {:>9.2}\n",
            r.label,
            r.ported_layers,
            r.crossings_fwd,
            r.crossings_bwd,
            r.conversion_bytes as f64 / 1024.0,
            r.executions,
            r.mean_ms
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_net_resolves() {
        assert!(preset_net("mnist", 1).is_ok());
        assert!(preset_net("cifar", 1).is_ok());
        assert!(preset_net("alexnet", 1).is_err());
    }

    #[test]
    fn sample_batch_shapes() {
        let mut net = preset_net("mnist", 1).unwrap();
        let (x, y) = sample_batch(&mut net).unwrap();
        assert_eq!(x.shape().dims(), &[64, 1, 28, 28]);
        assert_eq!(y.len(), 64);
    }
}
