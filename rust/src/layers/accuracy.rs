//! Accuracy layer — "not a real layer" (paper §3): evaluation-only top-k
//! classification accuracy, no backward.

use anyhow::{bail, Result};

use crate::ops;
use crate::proto::LayerConfig;
use crate::tensor::{Shape, Tensor};

use super::{labels_to_i32, Layer};

pub struct AccuracyLayer {
    cfg: LayerConfig,
    n: usize,
    c: usize,
}

impl AccuracyLayer {
    pub fn new(cfg: LayerConfig) -> Self {
        AccuracyLayer { cfg, n: 0, c: 0 }
    }
}

impl Layer for AccuracyLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if bottom_shapes.len() != 2 {
            bail!("Accuracy expects (logits, labels)");
        }
        let bs = &bottom_shapes[0];
        self.n = bs.num();
        self.c = bs.count_from(1);
        if self.cfg.top_k > self.c {
            bail!("top_k {} exceeds class count {}", self.cfg.top_k, self.c);
        }
        Ok(vec![Shape::new(&[1])])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        let labels = labels_to_i32(bottoms[1]);
        tops[0].as_mut_slice()[0] =
            ops::accuracy(bottoms[0].as_slice(), &labels, self.n, self.c, self.cfg.top_k);
        Ok(())
    }

    fn backward(
        &mut self,
        _top_diffs: &[&Tensor],
        _bottom_datas: &[&Tensor],
        _bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        Ok(()) // evaluation only
    }

    fn needs_backward(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LayerType;

    #[test]
    fn top1_and_topk() {
        let mut cfg = LayerConfig {
            name: "acc".into(),
            ltype: LayerType::Accuracy,
            ..Default::default()
        };
        cfg.top_k = 1;
        let mut l = AccuracyLayer::new(cfg);
        let logits = Shape::new(&[2, 3]);
        l.setup(&[logits.clone(), Shape::new(&[2])]).unwrap();
        let x = Tensor::from_vec(logits, vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1]);
        let y = Tensor::from_vec(Shape::new(&[2]), vec![1.0, 2.0]);
        let mut top = Tensor::zeros(Shape::new(&[1]));
        l.forward(&[&x, &y], std::slice::from_mut(&mut top)).unwrap();
        assert_eq!(top.as_slice()[0], 0.5);
    }

    #[test]
    fn rejects_oversized_topk() {
        let cfg = LayerConfig {
            name: "acc".into(),
            ltype: LayerType::Accuracy,
            top_k: 11,
            ..Default::default()
        };
        let mut l = AccuracyLayer::new(cfg);
        assert!(l.setup(&[Shape::new(&[2, 10]), Shape::new(&[2])]).is_err());
    }
}
