//! Convolution layer — im2col + GeMM per sample, Caffe's CPU schedule
//! (paper §3.1), parallelized over batch samples.
//!
//! Forward and backward split the batch into contiguous sample ranges,
//! one scoped worker each ([`ops::par`]); every worker owns its own
//! column scratch (Caffe's shared `col_buffer_` becomes per-thread
//! scratch, the refactor batch-parallelism forces).  The per-sample
//! GeMMs inside workers stay serial (nested regions collapse).  Knobs:
//! `PHAST_NUM_THREADS` + `PHAST_CONV_GRAIN` (samples per worker).
//!
//! When the net's fusion plan pairs this layer with an adjacent ReLU
//! (`Net::from_config`), `forward_fused_relu` computes the activation
//! inside the same batch-parallel region — conv + bias + ReLU in one
//! dispatch, bitwise-equal to the separate passes.
//!
//! The weight matrix is the GeMM **A** operand of every per-sample
//! product, so the layer keeps it pre-packed in both orientations — W
//! panels for the forward `W · cols` and Wᵀ panels for the backward
//! `Wᵀ · dY` — as [`ops::PackedMat`] caches keyed by the blob's
//! `data_version()`.  The packs are refreshed once on the dispatching
//! thread before each batch-parallel region and shared read-only by
//! every worker, replacing the old engine's per-sample transpose of W in
//! backward (two transposed packs per sample) with one repack per solver
//! step.
//!
//! # Fused backward (`PHAST_FUSE_BWD`, default on)
//!
//! The gradient sweep used to be dispatch-then-serial-merge: one
//! batch-parallel region accumulating per-worker `dW`/`db` partials,
//! then a serial worker-order merge on the dispatching thread.  By
//! default it now runs as **one** two-stage fused region
//! ([`par::parallel_regions`]): stage 0 is the per-sample gradient work
//! (`dW` GeMM, `db` row sums, `Wᵀ·dY` GeMM, col2im into the disjoint
//! `dX` planes), and stage 1 — after the region barrier — merges the
//! partials with every worker owning a contiguous slice of `dW`
//! elements, each element accumulated in worker order.  The per-element
//! addition order is identical to the serial merge, so the fused
//! backward is **bitwise equal** to the reference at any fixed thread
//! count (across thread counts the partial grouping still moves, the
//! usual tier-3 conv-reduction tolerance).
//!
//! # Persistent im2col packing (`PHAST_CONV_PACK`, default on)
//!
//! The backward `dW += dY · colsᵀ` was the one hot GeMM still packing
//! its B operand per call (and Caffe re-runs im2col in backward to
//! rebuild `cols` first).  The forward already materializes each
//! sample's column buffer, so it now also captures the **packed** colsᵀ
//! panels ([`ops::pack_b_slice`]) into a per-layer cache, stamped by the
//! bottom buffer identity, batch size, and O(1) content sentinels
//! (see `ColsPackCache` in this file).  Backward consumes the cache
//! through [`ops::gemm_packed_b_slice`] — skipping both the im2col
//! recompute and the per-call pack — and falls back to the
//! recompute-and-pack reference whenever the best-effort stamp detects a
//! mismatch (the binding contract stays the Caffe layer contract:
//! backward consumes the bottoms of the immediately preceding forward).
//! Under the env default the capture starts only after the layer's first
//! backward, so inference-only forwards pay neither the pack nor the
//! cache memory.  Packed panels are byte-identical to the ones
//! the raw GeMM packs on the fly, so results are bitwise unchanged, and
//! the capture never touches [`ops::gemm::repack_count`] (the
//! `packs_per_forward` / `packs_per_backward` metrics stay pinned at 0
//! for frozen weights).

use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::ops::im2col::Conv2dGeom;
use crate::ops::{self, gemm::Trans, par, PackSide, PackedMat};
use crate::propcheck::Rng;
use crate::proto::LayerConfig;
use crate::tensor::{Blob, Shape, Tensor};

use super::{xavier_fill, Layer};

/// Minimum samples per worker (`PHAST_CONV_GRAIN` overrides).
static CONV_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_CONV_GRAIN", 1);

/// `PHAST_FUSE_BWD`, parsed once: `0` selects the dispatch-then-serial-
/// merge reference backward; anything else (or unset) the fused
/// two-stage gradient region.  Both are bitwise equal at a fixed thread
/// count.
fn bwd_fusion_default() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("PHAST_FUSE_BWD").map(|v| v.trim() != "0").unwrap_or(true))
}

/// `PHAST_CONV_PACK`, parsed once: `0` disables the forward-pass capture
/// of packed im2col panels for the backward `dW` product (backward then
/// re-runs im2col and packs per call, the reference).  Both are bitwise
/// equal.  Inference-only runs need no opt-out: under the env default
/// the capture starts only after the layer's first backward.
fn cols_pack_default() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("PHAST_CONV_PACK").map(|v| v.trim() != "0").unwrap_or(true))
}

/// Per-layer cache of packed colsᵀ panels (one [`ops::pack_b_slice`]
/// block per sample), captured by the forward pass for the backward
/// `dW += dY · colsᵀ` product.
///
/// Contract (the Caffe layer contract, made explicit): backward consumes
/// the panels captured by the **immediately preceding forward** over the
/// same bottoms.  The stamp — `n`, `per_sample`, source pointer + length,
/// plus three content sentinels (first/middle/last element bit patterns)
/// — is a best-effort guard that catches a *different* bottom tensor and
/// the common in-place-rewrite cases (a reused buffer whose contents
/// moved); on any mismatch backward falls back to the recompute path.
/// `valid` is set only after a complete fill.
#[derive(Default)]
struct ColsPackCache {
    buf: Vec<f32>,
    per_sample: usize,
    n: usize,
    src_ptr: usize,
    src_len: usize,
    src_sentinels: [u32; 3],
    valid: bool,
}

/// First/middle/last element bit patterns of `xs` — the O(1) content
/// fingerprint the pack-cache stamp carries besides buffer identity.
fn sentinels(xs: &[f32]) -> [u32; 3] {
    if xs.is_empty() {
        return [0; 3];
    }
    [
        xs[0].to_bits(),
        xs[xs.len() / 2].to_bits(),
        xs[xs.len() - 1].to_bits(),
    ]
}

pub struct ConvLayer {
    cfg: LayerConfig,
    params: Vec<Blob>, // [weight (Cout, Cin, kh, kw), bias (Cout,)]
    // cached geometry
    cin: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    /// Persistent column scratch (C*kh*kw, OH*OW) for the single-worker
    /// paths (Caffe's `col_buffer_`); parallel workers allocate their own.
    cols: Vec<f32>,
    /// W packed as GeMM A panels (forward), stamped by the weight blob.
    packed_w: PackedMat,
    /// Wᵀ packed as GeMM A panels (backward dcols), stamped likewise.
    packed_wt: PackedMat,
    /// Packed colsᵀ panels captured by forward for the backward dW GeMM.
    cols_cache: ColsPackCache,
    /// Persistent per-worker dW partial scratch for the fused backward
    /// (grow-only; each worker zeroes its own slot inside stage 0, so
    /// neither the allocation nor the memset runs serially per call).
    bwd_dw_parts: Vec<f32>,
    /// Persistent per-worker db partial scratch (same lifecycle).
    bwd_db_parts: Vec<f32>,
    /// Per-layer override of the `PHAST_FUSE_BWD` knob (tests/benches).
    bwd_fused: Option<bool>,
    /// Per-layer override of the `PHAST_CONV_PACK` knob (tests/benches).
    bwd_packed: Option<bool>,
    /// Set by the first backward pass.  Under the env default, forward
    /// captures im2col packs only once this is true, so inference-only
    /// workloads (never backward) pay neither the per-sample pack nor
    /// the cache memory; the training loop pays one recompute backward
    /// on its first iteration (bitwise-equal reference path) and hits
    /// the cache from the second on.  An explicit
    /// [`Layer::set_backward_packing`] override captures immediately.
    seen_backward: bool,
    seed: u64,
}

impl ConvLayer {
    pub fn new(cfg: LayerConfig, seed: u64) -> Result<Self> {
        // The ported subset gate — N-D / dilated / grouped convolution were
        // NOT ported (paper §3.1, Table 1).
        if cfg.dilation != 1 {
            bail!("Unported: dilated convolution (dilation={})", cfg.dilation);
        }
        if cfg.group != 1 {
            bail!("Unported: grouped convolution (group={})", cfg.group);
        }
        Ok(ConvLayer {
            cfg,
            params: vec![],
            cin: 0,
            h: 0,
            w: 0,
            oh: 0,
            ow: 0,
            cols: vec![],
            packed_w: PackedMat::new(PackSide::A),
            packed_wt: PackedMat::new(PackSide::A),
            cols_cache: ColsPackCache::default(),
            bwd_dw_parts: vec![],
            bwd_db_parts: vec![],
            bwd_fused: None,
            bwd_packed: None,
            seen_backward: false,
            seed,
        })
    }

    fn geom(&self) -> Conv2dGeom {
        Conv2dGeom {
            kh: self.cfg.kernel_size,
            kw: self.cfg.kernel_size,
            sh: self.cfg.stride,
            sw: self.cfg.stride,
            ph: self.cfg.pad,
            pw: self.cfg.pad,
        }
    }

    fn ckk(&self) -> usize {
        self.cin * self.cfg.kernel_size * self.cfg.kernel_size
    }

    fn backward_fusion_enabled(&self) -> bool {
        self.bwd_fused.unwrap_or_else(bwd_fusion_default)
    }

    fn backward_packing_enabled(&self) -> bool {
        self.bwd_packed.unwrap_or_else(cols_pack_default)
    }

    /// Whether this forward should capture im2col packs: the knob must be
    /// on, and — under the env default — the layer must actually be in a
    /// training loop (a backward has run); an explicit per-layer override
    /// captures from the first forward on.
    fn capture_enabled(&self) -> bool {
        self.backward_packing_enabled() && (self.bwd_packed.is_some() || self.seen_backward)
    }

    /// Forward body shared by the plain and fused paths.  With
    /// `fused = Some((act, slope))` the leaky-ReLU of each just-computed
    /// output plane is written into `act` inside the **same** parallel
    /// region (one dispatch for conv + bias + activation); the arithmetic
    /// is identical to `forward` followed by `ops::leaky_relu`, so both
    /// paths are bitwise equal.  When backward packing is enabled, each
    /// sample's colsᵀ panels are captured into the pack cache while the
    /// column buffer is hot.
    fn forward_body(&mut self, x: &Tensor, top: &mut [f32], fused: Option<(&mut [f32], f32)>) {
        // Refresh the shared W pack once, on this thread, before any
        // dispatch; every per-sample GeMM below reads it in place.
        let (cout, ckk) = (self.cfg.num_output, self.ckk());
        let wv = self.params[0].data_version();
        self.packed_w.ensure(self.params[0].data().as_slice(), Trans::No, cout, ckk, wv);
        let capture = self.capture_enabled();
        let ohw = self.oh * self.ow;
        let item = cout * ohw;
        let n = top.len() / item.max(1);
        let psz = ops::packed_b_len(ohw, ckk);

        // Re-stamp the backward pack cache for this forward; it becomes
        // valid only after the fill below completes.
        self.cols_cache.valid = false;
        if capture {
            let need = n * psz;
            if self.cols_cache.buf.len() < need {
                self.cols_cache.buf.resize(need, 0.0);
            }
            self.cols_cache.per_sample = psz;
            self.cols_cache.n = n;
            self.cols_cache.src_ptr = x.as_slice().as_ptr() as usize;
            self.cols_cache.src_len = x.as_slice().len();
            self.cols_cache.src_sentinels = sentinels(x.as_slice());
        }

        let ctx = SampleCtx {
            xs: x.as_slice(),
            wpack: &self.packed_w,
            bias: self.params[1].data().as_slice(),
            cin: self.cin,
            h: self.h,
            w: self.w,
            g: self.geom(),
            cout,
            ohw,
            ckk,
            sample: self.cin * self.h * self.w,
        };
        let tune = par::Tuning::new(CONV_GRAIN.get());
        let scratch = ckk * ohw;
        let cache_buf = &mut self.cols_cache.buf;

        match fused {
            None => {
                // Single worker: reuse the persistent column scratch — no
                // per-call allocation, the seed's serial cost profile.
                if tune.workers(n) <= 1 {
                    let cols = &mut self.cols;
                    for s in 0..n {
                        let pack = if capture {
                            Some(&mut cache_buf[s * psz..(s + 1) * psz])
                        } else {
                            None
                        };
                        run_sample(&ctx, s, cols, &mut top[s * item..(s + 1) * item], None, pack);
                    }
                } else {
                    // One contiguous sample range per worker; each worker
                    // owns its column scratch, allocated once for its whole
                    // range, and writes only its own samples' cache slots.
                    let cache = if capture {
                        Some(par::FusedSlice::new(&mut cache_buf[..n * psz]))
                    } else {
                        None
                    };
                    par::parallel_chunks_mut(top, item, tune, |samples, block| {
                        let mut cols = vec![0.0f32; scratch];
                        for (bi, s) in samples.enumerate() {
                            // SAFETY: sample s belongs to exactly one worker.
                            let pack = cache
                                .as_ref()
                                .map(|v| unsafe { v.slice_mut(s * psz..(s + 1) * psz) });
                            let out = &mut block[bi * item..(bi + 1) * item];
                            run_sample(&ctx, s, &mut cols, out, None, pack);
                        }
                    });
                }
            }
            Some((act, slope)) => {
                debug_assert_eq!(act.len(), top.len());
                if tune.workers(n) <= 1 {
                    let cols = &mut self.cols;
                    for s in 0..n {
                        let (lo, hi) = (s * item, (s + 1) * item);
                        let a = &mut act[lo..hi];
                        let pack = if capture {
                            Some(&mut cache_buf[s * psz..(s + 1) * psz])
                        } else {
                            None
                        };
                        run_sample(&ctx, s, cols, &mut top[lo..hi], Some((a, slope)), pack);
                    }
                } else {
                    // Same sample partition, two disjoint output streams: the
                    // conv top and the fused activation — still one dispatch.
                    let cache = if capture {
                        Some(par::FusedSlice::new(&mut cache_buf[..n * psz]))
                    } else {
                        None
                    };
                    par::parallel_chunks2_mut(top, item, act, item, tune, |samples, block, ablock| {
                        let mut cols = vec![0.0f32; scratch];
                        for (bi, s) in samples.enumerate() {
                            let (lo, hi) = (bi * item, (bi + 1) * item);
                            let a = &mut ablock[lo..hi];
                            // SAFETY: sample s belongs to exactly one worker.
                            let pack = cache
                                .as_ref()
                                .map(|v| unsafe { v.slice_mut(s * psz..(s + 1) * psz) });
                            let out = &mut block[lo..hi];
                            run_sample(&ctx, s, &mut cols, out, Some((a, slope)), pack);
                        }
                    });
                }
            }
        }
        self.cols_cache.valid = capture;
    }

    /// The planner's fused pool→conv backward (plan rule R2): the
    /// adjacent pooling layer's gradient scatter and this layer's whole
    /// gradient sweep in **one** three-stage region —
    ///
    /// * stage 0: scatter `dy_pool` through `pg` into `mid` (the conv's
    ///   top diff), workers owning contiguous (sample, channel) plane
    ///   ranges; the stage barrier orders these writes before stage 1,
    ///   where a worker's samples may span planes another worker wrote;
    /// * stage 1: the per-sample conv gradient work of the fused
    ///   backward (dW GeMM, db row sums, Wᵀ·dY, col2im into disjoint
    ///   `dx` planes) reading `mid`, partials per worker;
    /// * stage 2: the deterministic worker-order merge.
    ///
    /// Stages 1–2 are arithmetic- and partition-identical to the
    /// two-stage fused [`Layer::backward`], and stage 0 is per-plane
    /// identical to the batched pool backward, so the fused pair is
    /// bitwise-equal to the separate passes at any fixed thread count.
    ///
    /// Per-worker partials and column scratch are carved from `ext` —
    /// the plan's shared arena slot — instead of per-layer buffers.
    /// Returns `Ok(false)` without touching anything when this call
    /// must fall back to the separate per-layer passes: at one worker
    /// (the serial conv backward accumulates directly into the blob
    /// diffs, a different — also bitwise-pinned — path) or with the
    /// backward-fusion knob off (the reference path is the contract).
    pub(crate) fn backward_fused_pool(
        &mut self,
        pg: &super::PoolBwdCtx<'_>,
        dy_pool: &[f32],
        mid: &mut [f32],
        x: &[f32],
        dx: &mut [f32],
        ext: &mut Vec<f32>,
    ) -> Result<bool> {
        let cout = self.cfg.num_output;
        let (ckk, ohw) = (self.ckk(), self.oh * self.ow);
        let sample = self.cin * self.h * self.w;
        let n = dx.len() / sample.max(1);
        let tune = par::Tuning::new(CONV_GRAIN.get());
        let workers = tune.workers(n);
        if workers <= 1 || !self.backward_fusion_enabled() {
            return Ok(false);
        }
        debug_assert_eq!(pg.c, cout);
        debug_assert_eq!(pg.h * pg.w, ohw);
        let pohw = pg.oh * pg.ow;
        debug_assert_eq!(dy_pool.len(), n * cout * pohw);
        debug_assert_eq!(mid.len(), n * cout * ohw);
        self.seen_backward = true;

        let wv = self.params[0].data_version();
        self.packed_wt.ensure(self.params[0].data().as_slice(), Trans::Yes, ckk, cout, wv);
        let psz = ops::packed_b_len(ohw, ckk);
        let cache_ok = self.cols_cache.valid
            && self.cols_cache.n == n
            && self.cols_cache.per_sample == psz
            && self.cols_cache.src_ptr == x.as_ptr() as usize
            && self.cols_cache.src_len == x.len()
            && self.cols_cache.src_sentinels == sentinels(x);
        let cache_buf: &[f32] = if cache_ok { &self.cols_cache.buf[..n * psz] } else { &[] };

        let ctx = SampleCtx {
            xs: x,
            wpack: &self.packed_w, // unused by backward_sample, kept for the shared ctx
            bias: &[],
            cin: self.cin,
            h: self.h,
            w: self.w,
            g: self.geom(),
            cout,
            ohw,
            ckk,
            sample,
        };
        let wtp = &self.packed_wt;
        let (wblob, bblob) = self.params.split_at_mut(1);
        let dw = wblob[0].diff_mut().as_mut_slice();
        let db = bblob[0].diff_mut().as_mut_slice();

        let dwlen = cout * ckk;
        let plane_ranges = par::partition(n * cout, workers);
        let sample_ranges = par::partition(n, workers);
        let merge_ranges = par::partition(dwlen, workers);
        // Arena layout: [dW parts | db parts | dcols | cols], each a
        // per-worker segment.  Grow-only, shared across every fused conv
        // backward assigned this slot; each worker zeroes/overwrites its
        // own windows, so stale contents from another layer are inert.
        let need = workers * (dwlen + cout + 2 * ckk * ohw);
        if ext.len() < need {
            ext.resize(need, 0.0);
        }
        let (dwp, rest) = ext[..need].split_at_mut(workers * dwlen);
        let (dbp, rest) = rest.split_at_mut(workers * cout);
        let (dcols_buf, cols_buf) = rest.split_at_mut(workers * ckk * ohw);
        {
            let midv = par::FusedSlice::new(mid);
            let dxv = par::FusedSlice::new(dx);
            let dwpv = par::FusedSlice::new(dwp);
            let dbpv = par::FusedSlice::new(dbp);
            let dcolsv = par::FusedSlice::new(dcols_buf);
            let colsv = par::FusedSlice::new(cols_buf);
            let dwv = par::FusedSlice::new(dw);
            let dbv = par::FusedSlice::new(db);
            let region_tune = par::Tuning { threads: workers, grain: 1 };
            par::check::label_region(|| format!("{}.bwd+pool", self.cfg.name));
            par::parallel_regions(workers, 3, region_tune, |stage, wr| {
                for wi in wr {
                    match stage {
                        0 => {
                            // SAFETY: worker wi exclusively owns its
                            // contiguous range of mid planes.
                            for p in plane_ranges[wi].clone() {
                                let dst = unsafe { midv.slice_mut(p * ohw..(p + 1) * ohw) };
                                let dyp = &dy_pool[p * pohw..(p + 1) * pohw];
                                match pg.method {
                                    crate::proto::PoolMethod::Max => ops::maxpool_bwd_plane(
                                        dyp,
                                        &pg.arg[p * pohw..(p + 1) * pohw],
                                        pg.h,
                                        pg.w,
                                        pg.g,
                                        pg.oh,
                                        pg.ow,
                                        dst,
                                    ),
                                    crate::proto::PoolMethod::Ave => ops::avepool_bwd_plane(
                                        dyp, pg.h, pg.w, pg.g, pg.oh, pg.ow, dst,
                                    ),
                                }
                            }
                        }
                        1 => {
                            // SAFETY: worker wi exclusively owns partial slot wi.
                            let dw_loc = unsafe { dwpv.slice_mut(wi * dwlen..(wi + 1) * dwlen) };
                            // SAFETY: worker wi exclusively owns partial slot wi.
                            let db_loc = unsafe { dbpv.slice_mut(wi * cout..(wi + 1) * cout) };
                            dw_loc.fill(0.0);
                            db_loc.fill(0.0);
                            // SAFETY: worker wi exclusively owns its scratch window.
                            let dcols =
                                unsafe { dcolsv.slice_mut(wi * ckk * ohw..(wi + 1) * ckk * ohw) };
                            // SAFETY: worker wi exclusively owns its scratch window.
                            let cols =
                                unsafe { colsv.slice_mut(wi * ckk * ohw..(wi + 1) * ckk * ohw) };
                            for s in sample_ranges[wi].clone() {
                                // SAFETY: mid planes were written in stage 0; the
                                // region barrier orders those writes before this
                                // read, and no stage-1 worker writes mid.
                                let dys =
                                    unsafe { midv.slice(s * cout * ohw..(s + 1) * cout * ohw) };
                                // SAFETY: sample s belongs to exactly one worker,
                                // so its dX plane has exactly one writer.
                                let dx_plane =
                                    unsafe { dxv.slice_mut(s * sample..(s + 1) * sample) };
                                backward_sample(
                                    &ctx,
                                    wtp,
                                    cache_slice(cache_buf, cache_ok, psz, s),
                                    s,
                                    dys,
                                    cols,
                                    dcols,
                                    dw_loc,
                                    db_loc,
                                    dx_plane,
                                );
                            }
                        }
                        _ => {
                            // SAFETY: cross-worker reads of the stage-1
                            // partials are ordered by the region barrier;
                            // merge ranges are disjoint per worker.
                            let parts: Vec<&[f32]> = (0..workers)
                                .map(|p| unsafe { dwpv.slice(p * dwlen..(p + 1) * dwlen) })
                                .collect();
                            let r = if wi < merge_ranges.len() {
                                merge_ranges[wi].clone()
                            } else {
                                0..0
                            };
                            // SAFETY: merge ranges are disjoint per worker.
                            let dwm = unsafe { dwv.slice_mut(r.clone()) };
                            for (off, d) in dwm.iter_mut().enumerate() {
                                let i = r.start + off;
                                let mut acc = *d;
                                for p in &parts {
                                    acc += p[i];
                                }
                                *d = acc;
                            }
                            if wi == 0 {
                                // SAFETY: worker 0 is db's only writer in
                                // this stage.
                                let dbm = unsafe { dbv.slice_mut(0..cout) };
                                for p in 0..workers {
                                    // SAFETY: stage-1 writes of the db
                                    // partials are ordered by the barrier;
                                    // nobody writes them in this stage.
                                    let part = unsafe { dbpv.slice(p * cout..(p + 1) * cout) };
                                    for (d, s) in dbm.iter_mut().zip(part) {
                                        *d += s;
                                    }
                                }
                            }
                        }
                    }
                }
            });
        }
        Ok(true)
    }
}

/// Borrowed per-forward invariants for [`run_sample`] (weights, bias,
/// input, geometry) — one struct so the helper stays a plain function
/// with properly universal lifetimes instead of a closure.
struct SampleCtx<'a> {
    xs: &'a [f32],
    /// The shared pre-packed W panels (GeMM A operand), refreshed by the
    /// dispatching thread before the region.
    wpack: &'a PackedMat,
    bias: &'a [f32],
    cin: usize,
    h: usize,
    w: usize,
    g: Conv2dGeom,
    cout: usize,
    ohw: usize,
    ckk: usize,
    sample: usize,
}

/// One sample's im2col + GeMM + bias into `out`, then (fused path only)
/// its leaky-ReLU into `act` — the same element order as the unfused
/// forward followed by `ops::leaky_relu`, hence bitwise-equal.  With
/// `bwd_pack = Some(dst)`, the sample's colsᵀ panels are additionally
/// packed into `dst` for the backward `dW` product (byte-identical to
/// the panels backward's raw GeMM would pack on the fly).
fn run_sample(
    ctx: &SampleCtx<'_>,
    s: usize,
    cols: &mut [f32],
    out: &mut [f32],
    act: Option<(&mut [f32], f32)>,
    bwd_pack: Option<&mut [f32]>,
) {
    let x = &ctx.xs[s * ctx.sample..(s + 1) * ctx.sample];
    ops::im2col(x, ctx.cin, ctx.h, ctx.w, ctx.g, cols);
    if let Some(dst) = bwd_pack {
        ops::pack_b_slice(cols, Trans::Yes, ctx.ohw, ctx.ckk, dst);
    }
    ops::gemm_packed_a(ctx.cout, ctx.ohw, ctx.ckk, 1.0, ctx.wpack, cols, Trans::No, 0.0, out);
    for (c, b) in ctx.bias.iter().enumerate() {
        for v in &mut out[c * ctx.ohw..(c + 1) * ctx.ohw] {
            *v += b;
        }
    }
    if let Some((act_out, slope)) = act {
        for (av, ov) in act_out.iter_mut().zip(out.iter()) {
            *av = if *ov > 0.0 { *ov } else { slope * *ov };
        }
    }
}

/// Sample `s`'s captured pack from the cols cache, or `None` when the
/// cache is not usable for this backward (fall back to recompute).
fn cache_slice(cache_buf: &[f32], cache_ok: bool, psz: usize, s: usize) -> Option<&[f32]> {
    if cache_ok {
        Some(&cache_buf[s * psz..(s + 1) * psz])
    } else {
        None
    }
}

/// One sample's gradient work shared by every backward path: `dW` GeMM
/// (from the captured pack when available, else recompute im2col and let
/// the raw GeMM pack on the fly — bitwise-identical panels either way),
/// `db` row sums, the `Wᵀ·dY` product, and col2im into the sample's
/// `dX` plane.
#[allow(clippy::too_many_arguments)]
fn backward_sample(
    ctx: &SampleCtx<'_>,
    wtp: &PackedMat,
    cache: Option<&[f32]>,
    s: usize,
    dys: &[f32],
    cols: &mut [f32],
    dcols: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx_plane: &mut [f32],
) {
    let (cout, ckk, ohw) = (ctx.cout, ctx.ckk, ctx.ohw);
    match cache {
        Some(pack) => {
            // dW += dY_s (Cout, OHW) * colsᵀ (OHW, CKK), panels pre-packed
            // by the forward pass.
            ops::gemm_packed_b_slice(cout, ckk, ohw, 1.0, dys, Trans::No, pack, 1.0, dw);
        }
        None => {
            // Recompute the column buffer (Caffe re-runs im2col in
            // backward) and pack per call.
            let x = &ctx.xs[s * ctx.sample..(s + 1) * ctx.sample];
            ops::im2col(x, ctx.cin, ctx.h, ctx.w, ctx.g, cols);
            ops::gemm(Trans::No, Trans::Yes, cout, ckk, ohw, 1.0, dys, cols, 1.0, dw);
        }
    }
    // db += row sums of dY_s
    for c in 0..cout {
        db[c] += dys[c * ohw..(c + 1) * ohw].iter().sum::<f32>();
    }
    // dcols = W^T (CKK, Cout) * dY_s (Cout, OHW), Wᵀ pre-packed
    ops::gemm_packed_a(ckk, ohw, cout, 1.0, wtp, dys, Trans::No, 0.0, dcols);
    ops::col2im(dcols, ctx.cin, ctx.h, ctx.w, ctx.g, dx_plane);
}

impl Layer for ConvLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if bottom_shapes.len() != 1 {
            bail!("Convolution expects 1 bottom");
        }
        let bs = &bottom_shapes[0];
        if bs.ndim() != 4 {
            bail!("Unported: N-D convolution (input is {}-D)", bs.ndim());
        }
        self.cin = bs.channels();
        self.h = bs.height();
        self.w = bs.width();
        let k = self.cfg.kernel_size;
        let gh = ops::conv_geom(self.h, k, self.cfg.stride, self.cfg.pad);
        let gw = ops::conv_geom(self.w, k, self.cfg.stride, self.cfg.pad);
        self.oh = gh.out;
        self.ow = gw.out;
        let cout = self.cfg.num_output;

        if self.params.is_empty() {
            let mut weight = Blob::new(
                format!("{}.w", self.cfg.name),
                Shape::new(&[cout, self.cin, k, k]),
            );
            let mut rng = Rng::new(self.seed ^ fxhash(&self.cfg.name));
            let fan_in = self.cin * k * k;
            xavier_fill(weight.data_mut(), fan_in, &mut rng);
            let bias = Blob::new(format!("{}.b", self.cfg.name), Shape::new(&[cout]));
            self.params = vec![weight, bias];
        }
        self.cols = vec![0.0; self.ckk() * self.oh * self.ow];
        self.cols_cache = ColsPackCache::default();
        Ok(vec![Shape::nchw(bs.num(), cout, self.oh, self.ow)])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        let x = bottoms[0];
        let top = tops[0].as_mut_slice();
        self.forward_body(x, top, None);
        Ok(())
    }

    fn forward_fused_relu(
        &mut self,
        bottoms: &[&Tensor],
        tops: &mut [Tensor],
        act: &mut Tensor,
        slope: f32,
    ) -> Result<bool> {
        let x = bottoms[0];
        let top = tops[0].as_mut_slice();
        self.forward_body(x, top, Some((act.as_mut_slice(), slope)));
        Ok(true)
    }

    fn set_backward_fusion(&mut self, on: bool) {
        self.bwd_fused = Some(on);
    }

    fn set_backward_packing(&mut self, on: bool) {
        self.bwd_packed = Some(on);
        self.cols_cache.valid = false;
        if !on {
            self.cols_cache.buf = Vec::new();
        }
    }

    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        let dy = top_diffs[0];
        let x = bottom_datas[0];
        let cout = self.cfg.num_output;
        let (ckk, ohw) = (self.ckk(), self.oh * self.ow);
        let sample = self.cin * self.h * self.w;
        let fuse = self.backward_fusion_enabled();
        // From now on, env-default forwards capture im2col packs (see
        // `capture_enabled`); this first backward takes the recompute
        // path with an unfilled cache, which is bitwise-equal.
        self.seen_backward = true;

        // Refresh the shared Wᵀ panel cache on this thread (a no-op while
        // the solver hasn't moved the weights), then borrow only the
        // diffs — `diff_mut` leaves the data stamp alone, so gradient
        // accumulation never invalidates the packs.
        let wv = self.params[0].data_version();
        self.packed_wt.ensure(self.params[0].data().as_slice(), Trans::Yes, ckk, cout, wv);

        let xs = x.as_slice();
        let dx = bottom_diffs[0].as_mut_slice();
        let n = dx.len() / sample.max(1);
        let psz = ops::packed_b_len(ohw, ckk);
        // The forward-pass pack cache is consumed only when its stamp
        // (buffer identity + content sentinels) matches the bottoms this
        // backward was handed — see the `ColsPackCache` contract.
        let cache_ok = self.cols_cache.valid
            && self.cols_cache.n == n
            && self.cols_cache.per_sample == psz
            && self.cols_cache.src_ptr == xs.as_ptr() as usize
            && self.cols_cache.src_len == xs.len()
            && self.cols_cache.src_sentinels == sentinels(xs);
        let cache_buf: &[f32] = if cache_ok { &self.cols_cache.buf[..n * psz] } else { &[] };

        let ctx = SampleCtx {
            xs,
            wpack: &self.packed_w, // unused by backward_sample, kept for the shared ctx
            bias: &[],
            cin: self.cin,
            h: self.h,
            w: self.w,
            g: self.geom(),
            cout,
            ohw,
            ckk,
            sample,
        };
        let wtp = &self.packed_wt;
        let (wblob, bblob) = self.params.split_at_mut(1);
        let wdiff = wblob[0].diff_mut();
        let dys_all = dy.as_slice();
        let tune = par::Tuning::new(CONV_GRAIN.get());
        let workers = tune.workers(n);

        // Serial path (one worker): accumulate straight into the blob
        // diffs — no local dW/db, no merge pass, matching the seed's
        // serial cost profile.
        if workers <= 1 {
            let dw = wdiff.as_mut_slice();
            let db = bblob[0].diff_mut().as_mut_slice();
            let cols = &mut self.cols; // persistent scratch, like the seed
            let mut dcols = vec![0.0f32; ckk * ohw];
            for s in 0..n {
                let dys = &dys_all[s * cout * ohw..(s + 1) * cout * ohw];
                backward_sample(
                    &ctx,
                    wtp,
                    cache_slice(cache_buf, cache_ok, psz, s),
                    s,
                    dys,
                    cols,
                    &mut dcols,
                    dw,
                    db,
                    &mut dx[s * sample..(s + 1) * sample],
                );
            }
            return Ok(());
        }

        if fuse {
            // Fused backward: one two-stage region.  Stage 0 — per-sample
            // gradient work into per-worker dW/db partials and the
            // disjoint dX planes; stage 1 (across the region barrier) —
            // deterministic merge, each worker owning a contiguous slice
            // of dW elements, every element accumulated in worker order
            // (the exact addition order of the serial reference merge).
            let dwlen = cout * ckk;
            let sample_ranges = par::partition(n, workers);
            let merge_ranges = par::partition(dwlen, workers);
            // Persistent partial scratch: grown (rarely) here, but zeroed
            // by each worker inside stage 0 — no serial memset per call.
            let need_dw = workers * dwlen;
            if self.bwd_dw_parts.len() < need_dw {
                self.bwd_dw_parts.resize(need_dw, 0.0);
            }
            let need_db = workers * cout;
            if self.bwd_db_parts.len() < need_db {
                self.bwd_db_parts.resize(need_db, 0.0);
            }
            let dw = wdiff.as_mut_slice();
            let db = bblob[0].diff_mut().as_mut_slice();
            {
                let dxv = par::FusedSlice::new(dx);
                let dwpv = par::FusedSlice::new(&mut self.bwd_dw_parts[..need_dw]);
                let dbpv = par::FusedSlice::new(&mut self.bwd_db_parts[..need_db]);
                let dwv = par::FusedSlice::new(dw);
                let dbv = par::FusedSlice::new(db);
                let region_tune = par::Tuning { threads: workers, grain: 1 };
                par::check::label_region(|| format!("{}.bwd", self.cfg.name));
                par::parallel_regions(workers, 2, region_tune, |stage, wr| {
                    for wi in wr {
                        if stage == 0 {
                            // SAFETY: worker wi exclusively owns partial slot wi.
                            let dw_loc = unsafe { dwpv.slice_mut(wi * dwlen..(wi + 1) * dwlen) };
                            // SAFETY: worker wi exclusively owns partial slot wi.
                            let db_loc = unsafe { dbpv.slice_mut(wi * cout..(wi + 1) * cout) };
                            // The scratch persists across calls: clear our
                            // slot before accumulating into it.
                            dw_loc.fill(0.0);
                            db_loc.fill(0.0);
                            let mut cols =
                                if cache_ok { Vec::new() } else { vec![0.0f32; ckk * ohw] };
                            let mut dcols = vec![0.0f32; ckk * ohw];
                            for s in sample_ranges[wi].clone() {
                                let dys = &dys_all[s * cout * ohw..(s + 1) * cout * ohw];
                                // SAFETY: sample s belongs to exactly one worker,
                                // so its dX plane has exactly one writer.
                                let dx_plane =
                                    unsafe { dxv.slice_mut(s * sample..(s + 1) * sample) };
                                backward_sample(
                                    &ctx,
                                    wtp,
                                    cache_slice(cache_buf, cache_ok, psz, s),
                                    s,
                                    dys,
                                    &mut cols,
                                    &mut dcols,
                                    dw_loc,
                                    db_loc,
                                    dx_plane,
                                );
                            }
                        } else {
                            // SAFETY: cross-worker reads of the stage-0
                            // partials are ordered by the region barrier;
                            // merge ranges are disjoint per worker.
                            let parts: Vec<&[f32]> = (0..workers)
                                .map(|p| unsafe { dwpv.slice(p * dwlen..(p + 1) * dwlen) })
                                .collect();
                            let r = if wi < merge_ranges.len() {
                                merge_ranges[wi].clone()
                            } else {
                                0..0
                            };
                            // SAFETY: merge ranges are disjoint per worker.
                            let dwm = unsafe { dwv.slice_mut(r.clone()) };
                            for (off, d) in dwm.iter_mut().enumerate() {
                                let i = r.start + off;
                                let mut acc = *d;
                                for p in &parts {
                                    acc += p[i];
                                }
                                *d = acc;
                            }
                            if wi == 0 {
                                // SAFETY: worker 0 is db's only writer in
                                // this stage.
                                let dbm = unsafe { dbv.slice_mut(0..cout) };
                                for p in 0..workers {
                                    // SAFETY: stage-0 writes of the db
                                    // partials are ordered by the barrier;
                                    // nobody writes them in this stage.
                                    let part = unsafe { dbpv.slice(p * cout..(p + 1) * cout) };
                                    for (d, s) in dbm.iter_mut().zip(part) {
                                        *d += s;
                                    }
                                }
                            }
                        }
                    }
                });
            }
            return Ok(());
        }

        // Reference backward (`PHAST_FUSE_BWD=0`): each worker collects
        // private dW/db accumulators over its contiguous sample range
        // (dX planes are disjoint so they are written in place), then the
        // dispatching thread merges the partials serially in worker order.
        let partials = par::parallel_chunks_reduce(dx, sample, tune, |samples, dx_block| {
            let mut cols = if cache_ok { Vec::new() } else { vec![0.0f32; ckk * ohw] };
            let mut dcols = vec![0.0f32; ckk * ohw];
            let mut dw_loc = vec![0.0f32; cout * ckk];
            let mut db_loc = vec![0.0f32; cout];
            for (bi, s) in samples.enumerate() {
                let dys = &dys_all[s * cout * ohw..(s + 1) * cout * ohw];
                backward_sample(
                    &ctx,
                    wtp,
                    cache_slice(cache_buf, cache_ok, psz, s),
                    s,
                    dys,
                    &mut cols,
                    &mut dcols,
                    &mut dw_loc,
                    &mut db_loc,
                    &mut dx_block[bi * sample..(bi + 1) * sample],
                );
            }
            (dw_loc, db_loc)
        });

        // Deterministic merge: partials arrive in worker (= sample) order.
        let dw = wdiff.as_mut_slice();
        let db = bblob[0].diff_mut().as_mut_slice();
        for (dw_loc, db_loc) in partials {
            for (d, s) in dw.iter_mut().zip(&dw_loc) {
                *d += s;
            }
            for (d, s) in db.iter_mut().zip(&db_loc) {
                *d += s;
            }
        }
        Ok(())
    }

    fn params(&self) -> &[Blob] {
        &self.params
    }

    fn params_mut(&mut self) -> &mut [Blob] {
        &mut self.params
    }
}

/// Tiny string hash for per-layer seeds.
fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{close, Rng};
    use crate::proto::LayerType;

    fn conv_cfg(cout: usize, k: usize, s: usize, p: usize) -> LayerConfig {
        LayerConfig {
            name: "c".into(),
            ltype: LayerType::Convolution,
            bottoms: vec!["x".into()],
            tops: vec!["y".into()],
            num_output: cout,
            kernel_size: k,
            stride: s,
            pad: p,
            ..Default::default()
        }
    }

    /// Finite-difference check of dW, db, dX on a tiny conv.
    #[test]
    fn gradients_match_finite_differences() {
        let mut layer = ConvLayer::new(conv_cfg(2, 3, 1, 1), 3).unwrap();
        let in_shape = Shape::nchw(2, 3, 5, 4);
        let out_shape = layer.setup(&[in_shape.clone()]).unwrap().remove(0);

        let mut rng = Rng::new(9);
        let x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));
        let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));

        // loss = <y, dy>; analytic grads:
        let mut y = Tensor::zeros(out_shape.clone());
        layer.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        let mut dx = Tensor::zeros(in_shape.clone());
        layer
            .backward(&[&dy], &[&x], std::slice::from_mut(&mut dx))
            .unwrap();

        let eps = 1e-2f32;
        let loss = |layer: &mut ConvLayer, x: &Tensor| -> f32 {
            let mut y = Tensor::zeros(out_shape.clone());
            layer.forward(&[x], std::slice::from_mut(&mut y)).unwrap();
            y.as_slice().iter().zip(dy.as_slice()).map(|(a, b)| a * b).sum()
        };
        // dX
        for idx in [0usize, 7, 23, in_shape.count() - 1] {
            let mut xp = x.clone();
            xp.as_mut_slice()[idx] += eps;
            let mut xm = x.clone();
            xm.as_mut_slice()[idx] -= eps;
            let num = (loss(&mut layer, &xp) - loss(&mut layer, &xm)) / (2.0 * eps);
            let ana = dx.as_slice()[idx];
            assert!(close(num, ana, 2e-2, 2e-2), "dX[{idx}]: {num} vs {ana}");
        }
        // dW
        for idx in [0usize, 5, 20] {
            let orig = layer.params()[0].data().as_slice()[idx];
            let ana = layer.params()[0].diff().as_slice()[idx];
            layer.params_mut()[0].data_mut().as_mut_slice()[idx] = orig + eps;
            let lp = loss(&mut layer, &x);
            layer.params_mut()[0].data_mut().as_mut_slice()[idx] = orig - eps;
            let lm = loss(&mut layer, &x);
            layer.params_mut()[0].data_mut().as_mut_slice()[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(close(num, ana, 2e-2, 2e-2), "dW[{idx}]: {num} vs {ana}");
        }
    }

    /// Backward with a stale pack cache (different bottoms than the last
    /// forward) must fall back to the recompute path and produce the
    /// gradients of the bottoms it was handed — bitwise equal to a layer
    /// that never cached.
    #[test]
    fn stale_cols_cache_falls_back_to_recompute() {
        let in_shape = Shape::nchw(2, 2, 6, 6);
        let mut rng = Rng::new(31);
        let x1 = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));
        let x2 = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));

        let mut cached = ConvLayer::new(conv_cfg(3, 3, 1, 1), 7).unwrap();
        let out_shape = cached.setup(&[in_shape.clone()]).unwrap().remove(0);
        cached.set_backward_packing(true);
        let mut plain = ConvLayer::new(conv_cfg(3, 3, 1, 1), 7).unwrap();
        plain.setup(&[in_shape.clone()]).unwrap();
        plain.set_backward_packing(false);

        let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));
        let mut y = Tensor::zeros(out_shape.clone());
        // Forward on x1 fills the cache; backward on x2 must ignore it.
        cached.forward(&[&x1], std::slice::from_mut(&mut y)).unwrap();
        plain.forward(&[&x1], std::slice::from_mut(&mut y)).unwrap();
        let mut dx_cached = Tensor::zeros(in_shape.clone());
        let mut dx_plain = Tensor::zeros(in_shape.clone());
        cached.backward(&[&dy], &[&x2], std::slice::from_mut(&mut dx_cached)).unwrap();
        plain.backward(&[&dy], &[&x2], std::slice::from_mut(&mut dx_plain)).unwrap();
        assert_eq!(dx_cached.as_slice(), dx_plain.as_slice(), "stale cache was consumed");
        assert_eq!(
            cached.params()[0].diff().as_slice(),
            plain.params()[0].diff().as_slice(),
            "stale cache perturbed dW"
        );
    }

    /// Rewriting the bottom buffer **in place** (same pointer, new
    /// contents) between forward and backward must also defeat the cache:
    /// the content sentinels in the stamp catch what pointer identity
    /// cannot.
    #[test]
    fn in_place_bottom_rewrite_invalidates_cache() {
        let in_shape = Shape::nchw(2, 2, 6, 6);
        let mut rng = Rng::new(91);
        let mut x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));

        let mut cached = ConvLayer::new(conv_cfg(3, 3, 1, 1), 7).unwrap();
        let out_shape = cached.setup(&[in_shape.clone()]).unwrap().remove(0);
        cached.set_backward_packing(true);
        let mut plain = ConvLayer::new(conv_cfg(3, 3, 1, 1), 7).unwrap();
        plain.setup(&[in_shape.clone()]).unwrap();
        plain.set_backward_packing(false);

        let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));
        let mut y = Tensor::zeros(out_shape.clone());
        cached.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        // Same buffer, new values: every sentinel element moves.
        for v in x.as_mut_slice() {
            *v += 1.0;
        }
        let mut dx_cached = Tensor::zeros(in_shape.clone());
        let mut dx_plain = Tensor::zeros(in_shape.clone());
        cached.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx_cached)).unwrap();
        plain.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx_plain)).unwrap();
        assert_eq!(
            cached.params()[0].diff().as_slice(),
            plain.params()[0].diff().as_slice(),
            "stale in-place cache was consumed for dW"
        );
        assert_eq!(dx_cached.as_slice(), dx_plain.as_slice());
    }

    /// Backward without any prior forward (cache never filled) must work
    /// via the recompute path.
    #[test]
    fn backward_without_forward_recomputes() {
        let in_shape = Shape::nchw(2, 1, 5, 5);
        let mut l = ConvLayer::new(conv_cfg(2, 3, 1, 0), 5).unwrap();
        let out_shape = l.setup(&[in_shape.clone()]).unwrap().remove(0);
        let mut rng = Rng::new(6);
        let x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));
        let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));
        let mut dx = Tensor::zeros(in_shape.clone());
        l.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx)).unwrap();
        assert!(l.params()[0].diff().l2() > 0.0);
    }

    #[test]
    fn rejects_unported_features() {
        let mut cfg = conv_cfg(2, 3, 1, 0);
        cfg.dilation = 2;
        assert!(ConvLayer::new(cfg, 1).is_err());
        let mut cfg = conv_cfg(2, 3, 1, 0);
        cfg.group = 2;
        assert!(ConvLayer::new(cfg, 1).is_err());
        // 3-D input = N-D convolution -> rejected at setup
        let mut l = ConvLayer::new(conv_cfg(2, 3, 1, 0), 1).unwrap();
        assert!(l.setup(&[Shape::new(&[2, 3, 8, 8, 8])]).is_err());
    }

    #[test]
    fn output_shape_lenet_conv1() {
        let mut l = ConvLayer::new(conv_cfg(20, 5, 1, 0), 1).unwrap();
        let tops = l.setup(&[Shape::nchw(64, 1, 28, 28)]).unwrap();
        assert_eq!(tops[0].dims(), &[64, 20, 24, 24]);
    }
}
