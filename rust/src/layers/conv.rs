//! Convolution layer — im2col + GeMM per sample, Caffe's CPU schedule
//! (paper §3.1), parallelized over batch samples.
//!
//! Forward and backward split the batch into contiguous sample ranges,
//! one scoped worker each ([`ops::par`]); every worker owns its own
//! column scratch (Caffe's shared `col_buffer_` becomes per-thread
//! scratch, the refactor batch-parallelism forces).  Backward workers
//! additionally accumulate into private `dW`/`db` buffers that are
//! reduced in worker order afterwards — deterministic for a fixed thread
//! count.  The per-sample GeMMs inside workers stay serial (nested
//! regions collapse).  Knobs: `PHAST_NUM_THREADS` + `PHAST_CONV_GRAIN`
//! (samples per worker).
//!
//! When the net's fusion plan pairs this layer with an adjacent ReLU
//! (`Net::from_config`), `forward_fused_relu` computes the activation
//! inside the same batch-parallel region — conv + bias + ReLU in one
//! dispatch, bitwise-equal to the separate passes.
//!
//! The weight matrix is the GeMM **A** operand of every per-sample
//! product, so the layer keeps it pre-packed in both orientations — W
//! panels for the forward `W · cols` and Wᵀ panels for the backward
//! `Wᵀ · dY` — as [`ops::PackedMat`] caches keyed by the blob's
//! `data_version()`.  The packs are refreshed once on the dispatching
//! thread before each batch-parallel region and shared read-only by
//! every worker, replacing the old engine's per-sample transpose of W in
//! backward (two transposed packs per sample) with one repack per solver
//! step.

use anyhow::{bail, Result};

use crate::ops::im2col::Conv2dGeom;
use crate::ops::{self, gemm::Trans, par, PackSide, PackedMat};
use crate::propcheck::Rng;
use crate::proto::LayerConfig;
use crate::tensor::{Blob, Shape, Tensor};

use super::{xavier_fill, Layer};

/// Minimum samples per worker (`PHAST_CONV_GRAIN` overrides).
static CONV_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_CONV_GRAIN", 1);

pub struct ConvLayer {
    cfg: LayerConfig,
    params: Vec<Blob>, // [weight (Cout, Cin, kh, kw), bias (Cout,)]
    // cached geometry
    cin: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    /// Persistent column scratch (C*kh*kw, OH*OW) for the single-worker
    /// paths (Caffe's `col_buffer_`); parallel workers allocate their own.
    cols: Vec<f32>,
    /// W packed as GeMM A panels (forward), stamped by the weight blob.
    packed_w: PackedMat,
    /// Wᵀ packed as GeMM A panels (backward dcols), stamped likewise.
    packed_wt: PackedMat,
    seed: u64,
}

impl ConvLayer {
    pub fn new(cfg: LayerConfig, seed: u64) -> Result<Self> {
        // The ported subset gate — N-D / dilated / grouped convolution were
        // NOT ported (paper §3.1, Table 1).
        if cfg.dilation != 1 {
            bail!("Unported: dilated convolution (dilation={})", cfg.dilation);
        }
        if cfg.group != 1 {
            bail!("Unported: grouped convolution (group={})", cfg.group);
        }
        Ok(ConvLayer {
            cfg,
            params: vec![],
            cin: 0,
            h: 0,
            w: 0,
            oh: 0,
            ow: 0,
            cols: vec![],
            packed_w: PackedMat::new(PackSide::A),
            packed_wt: PackedMat::new(PackSide::A),
            seed,
        })
    }

    fn geom(&self) -> Conv2dGeom {
        Conv2dGeom {
            kh: self.cfg.kernel_size,
            kw: self.cfg.kernel_size,
            sh: self.cfg.stride,
            sw: self.cfg.stride,
            ph: self.cfg.pad,
            pw: self.cfg.pad,
        }
    }

    fn ckk(&self) -> usize {
        self.cin * self.cfg.kernel_size * self.cfg.kernel_size
    }

    /// Forward body shared by the plain and fused paths.  With
    /// `fused = Some((act, slope))` the leaky-ReLU of each just-computed
    /// output plane is written into `act` inside the **same** parallel
    /// region (one dispatch for conv + bias + activation); the arithmetic
    /// is identical to `forward` followed by `ops::leaky_relu`, so both
    /// paths are bitwise equal.
    fn forward_body(&mut self, x: &Tensor, top: &mut [f32], fused: Option<(&mut [f32], f32)>) {
        // Refresh the shared W pack once, on this thread, before any
        // dispatch; every per-sample GeMM below reads it in place.
        let (cout, ckk) = (self.cfg.num_output, self.ckk());
        let wv = self.params[0].data_version();
        self.packed_w.ensure(self.params[0].data().as_slice(), Trans::No, cout, ckk, wv);
        let ctx = SampleCtx {
            xs: x.as_slice(),
            wpack: &self.packed_w,
            bias: self.params[1].data().as_slice(),
            cin: self.cin,
            h: self.h,
            w: self.w,
            g: self.geom(),
            cout: self.cfg.num_output,
            ohw: self.oh * self.ow,
            ckk: self.ckk(),
            sample: self.cin * self.h * self.w,
        };
        let tune = par::Tuning::new(CONV_GRAIN.get());
        let item = ctx.cout * ctx.ohw;
        let n = top.len() / item;
        let scratch = ctx.ckk * ctx.ohw;

        match fused {
            None => {
                // Single worker: reuse the persistent column scratch — no
                // per-call allocation, the seed's serial cost profile.
                if tune.workers(n) <= 1 {
                    let cols = &mut self.cols;
                    for s in 0..n {
                        run_sample(&ctx, s, cols, &mut top[s * item..(s + 1) * item], None);
                    }
                    return;
                }
                // One contiguous sample range per worker; each worker owns
                // its column scratch, allocated once for its whole range.
                par::parallel_chunks_mut(top, item, tune, |samples, block| {
                    let mut cols = vec![0.0f32; scratch];
                    for (bi, s) in samples.enumerate() {
                        run_sample(&ctx, s, &mut cols, &mut block[bi * item..(bi + 1) * item], None);
                    }
                });
            }
            Some((act, slope)) => {
                debug_assert_eq!(act.len(), top.len());
                if tune.workers(n) <= 1 {
                    let cols = &mut self.cols;
                    for s in 0..n {
                        let (lo, hi) = (s * item, (s + 1) * item);
                        let a = &mut act[lo..hi];
                        run_sample(&ctx, s, cols, &mut top[lo..hi], Some((a, slope)));
                    }
                    return;
                }
                // Same sample partition, two disjoint output streams: the
                // conv top and the fused activation — still one dispatch.
                par::parallel_chunks2_mut(top, item, act, item, tune, |samples, block, ablock| {
                    let mut cols = vec![0.0f32; scratch];
                    for (bi, s) in samples.enumerate() {
                        let (lo, hi) = (bi * item, (bi + 1) * item);
                        let a = &mut ablock[lo..hi];
                        run_sample(&ctx, s, &mut cols, &mut block[lo..hi], Some((a, slope)));
                    }
                });
            }
        }
    }
}

/// Borrowed per-forward invariants for [`run_sample`] (weights, bias,
/// input, geometry) — one struct so the helper stays a plain function
/// with properly universal lifetimes instead of a closure.
struct SampleCtx<'a> {
    xs: &'a [f32],
    /// The shared pre-packed W panels (GeMM A operand), refreshed by the
    /// dispatching thread before the region.
    wpack: &'a PackedMat,
    bias: &'a [f32],
    cin: usize,
    h: usize,
    w: usize,
    g: Conv2dGeom,
    cout: usize,
    ohw: usize,
    ckk: usize,
    sample: usize,
}

/// One sample's im2col + GeMM + bias into `out`, then (fused path only)
/// its leaky-ReLU into `act` — the same element order as the unfused
/// forward followed by `ops::leaky_relu`, hence bitwise-equal.
fn run_sample(
    ctx: &SampleCtx<'_>,
    s: usize,
    cols: &mut [f32],
    out: &mut [f32],
    act: Option<(&mut [f32], f32)>,
) {
    let x = &ctx.xs[s * ctx.sample..(s + 1) * ctx.sample];
    ops::im2col(x, ctx.cin, ctx.h, ctx.w, ctx.g, cols);
    ops::gemm_packed_a(ctx.cout, ctx.ohw, ctx.ckk, 1.0, ctx.wpack, cols, Trans::No, 0.0, out);
    for (c, b) in ctx.bias.iter().enumerate() {
        for v in &mut out[c * ctx.ohw..(c + 1) * ctx.ohw] {
            *v += b;
        }
    }
    if let Some((act_out, slope)) = act {
        for (av, ov) in act_out.iter_mut().zip(out.iter()) {
            *av = if *ov > 0.0 { *ov } else { slope * *ov };
        }
    }
}

impl Layer for ConvLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if bottom_shapes.len() != 1 {
            bail!("Convolution expects 1 bottom");
        }
        let bs = &bottom_shapes[0];
        if bs.ndim() != 4 {
            bail!("Unported: N-D convolution (input is {}-D)", bs.ndim());
        }
        self.cin = bs.channels();
        self.h = bs.height();
        self.w = bs.width();
        let k = self.cfg.kernel_size;
        let gh = ops::conv_geom(self.h, k, self.cfg.stride, self.cfg.pad);
        let gw = ops::conv_geom(self.w, k, self.cfg.stride, self.cfg.pad);
        self.oh = gh.out;
        self.ow = gw.out;
        let cout = self.cfg.num_output;

        if self.params.is_empty() {
            let mut weight = Blob::new(
                format!("{}.w", self.cfg.name),
                Shape::new(&[cout, self.cin, k, k]),
            );
            let mut rng = Rng::new(self.seed ^ fxhash(&self.cfg.name));
            let fan_in = self.cin * k * k;
            xavier_fill(weight.data_mut(), fan_in, &mut rng);
            let bias = Blob::new(format!("{}.b", self.cfg.name), Shape::new(&[cout]));
            self.params = vec![weight, bias];
        }
        self.cols = vec![0.0; self.ckk() * self.oh * self.ow];
        Ok(vec![Shape::nchw(bs.num(), cout, self.oh, self.ow)])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        let x = bottoms[0];
        let top = tops[0].as_mut_slice();
        self.forward_body(x, top, None);
        Ok(())
    }

    fn forward_fused_relu(
        &mut self,
        bottoms: &[&Tensor],
        tops: &mut [Tensor],
        act: &mut Tensor,
        slope: f32,
    ) -> Result<bool> {
        let x = bottoms[0];
        let top = tops[0].as_mut_slice();
        self.forward_body(x, top, Some((act.as_mut_slice(), slope)));
        Ok(true)
    }

    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        let dy = top_diffs[0];
        let x = bottom_datas[0];
        let cout = self.cfg.num_output;
        let (ckk, ohw) = (self.ckk(), self.oh * self.ow);
        let sample = self.cin * self.h * self.w;
        let (cin, h, w, g) = (self.cin, self.h, self.w, self.geom());

        // Refresh the shared Wᵀ panel cache on this thread (a no-op while
        // the solver hasn't moved the weights), then borrow only the
        // diffs — `diff_mut` leaves the data stamp alone, so gradient
        // accumulation never invalidates the packs.
        let wv = self.params[0].data_version();
        self.packed_wt.ensure(self.params[0].data().as_slice(), Trans::Yes, ckk, cout, wv);
        let wtp = &self.packed_wt;
        let (wblob, bblob) = self.params.split_at_mut(1);
        let wdiff = wblob[0].diff_mut();
        let dys_all = dy.as_slice();
        let xs = x.as_slice();
        let dx = bottom_diffs[0].as_mut_slice();
        let tune = par::Tuning::new(CONV_GRAIN.get());

        // Serial path (one worker): accumulate straight into the blob
        // diffs — no local dW/db, no merge pass, matching the seed's
        // serial cost profile.
        let n = dx.len() / sample;
        if tune.workers(n) <= 1 {
            let dw = wdiff.as_mut_slice();
            let db = bblob[0].diff_mut().as_mut_slice();
            let cols = &mut self.cols; // persistent scratch, like the seed
            let mut dcols = vec![0.0f32; ckk * ohw];
            for s in 0..n {
                let dys = &dys_all[s * cout * ohw..(s + 1) * cout * ohw];
                ops::im2col(&xs[s * sample..(s + 1) * sample], cin, h, w, g, cols);
                ops::gemm(Trans::No, Trans::Yes, cout, ckk, ohw, 1.0, dys, cols, 1.0, dw);
                for c in 0..cout {
                    db[c] += dys[c * ohw..(c + 1) * ohw].iter().sum::<f32>();
                }
                ops::gemm_packed_a(ckk, ohw, cout, 1.0, wtp, dys, Trans::No, 0.0, &mut dcols);
                ops::col2im(&dcols, cin, h, w, g, &mut dx[s * sample..(s + 1) * sample]);
            }
            return Ok(());
        }

        // Each worker: private cols/dcols scratch + private dW/db
        // accumulators over its contiguous sample range; dX planes are
        // disjoint so they are written in place.
        let partials = par::parallel_chunks_reduce(dx, sample, tune, |samples, dx_block| {
            let mut cols = vec![0.0f32; ckk * ohw];
            let mut dcols = vec![0.0f32; ckk * ohw];
            let mut dw_loc = vec![0.0f32; cout * ckk];
            let mut db_loc = vec![0.0f32; cout];
            for (bi, s) in samples.enumerate() {
                let dys = &dys_all[s * cout * ohw..(s + 1) * cout * ohw];
                // Recompute the column buffer (Caffe re-runs im2col in
                // backward).
                ops::im2col(&xs[s * sample..(s + 1) * sample], cin, h, w, g, &mut cols);
                // dW += dY_s (Cout, OHW) * cols^T (OHW, CKK)
                ops::gemm(Trans::No, Trans::Yes, cout, ckk, ohw, 1.0, dys, &cols, 1.0, &mut dw_loc);
                // db += row sums of dY_s
                for c in 0..cout {
                    db_loc[c] += dys[c * ohw..(c + 1) * ohw].iter().sum::<f32>();
                }
                // dcols = W^T (CKK, Cout) * dY_s (Cout, OHW), Wᵀ pre-packed
                ops::gemm_packed_a(ckk, ohw, cout, 1.0, wtp, dys, Trans::No, 0.0, &mut dcols);
                ops::col2im(
                    &dcols,
                    cin,
                    h,
                    w,
                    g,
                    &mut dx_block[bi * sample..(bi + 1) * sample],
                );
            }
            (dw_loc, db_loc)
        });

        // Deterministic merge: partials arrive in worker (= sample) order.
        let dw = wdiff.as_mut_slice();
        let db = bblob[0].diff_mut().as_mut_slice();
        for (dw_loc, db_loc) in partials {
            for (d, s) in dw.iter_mut().zip(&dw_loc) {
                *d += s;
            }
            for (d, s) in db.iter_mut().zip(&db_loc) {
                *d += s;
            }
        }
        Ok(())
    }

    fn params(&self) -> &[Blob] {
        &self.params
    }

    fn params_mut(&mut self) -> &mut [Blob] {
        &mut self.params
    }
}

/// Tiny string hash for per-layer seeds.
fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{close, Rng};
    use crate::proto::LayerType;

    fn conv_cfg(cout: usize, k: usize, s: usize, p: usize) -> LayerConfig {
        LayerConfig {
            name: "c".into(),
            ltype: LayerType::Convolution,
            bottoms: vec!["x".into()],
            tops: vec!["y".into()],
            num_output: cout,
            kernel_size: k,
            stride: s,
            pad: p,
            ..Default::default()
        }
    }

    /// Finite-difference check of dW, db, dX on a tiny conv.
    #[test]
    fn gradients_match_finite_differences() {
        let mut layer = ConvLayer::new(conv_cfg(2, 3, 1, 1), 3).unwrap();
        let in_shape = Shape::nchw(2, 3, 5, 4);
        let out_shape = layer.setup(&[in_shape.clone()]).unwrap().remove(0);

        let mut rng = Rng::new(9);
        let x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));
        let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));

        // loss = <y, dy>; analytic grads:
        let mut y = Tensor::zeros(out_shape.clone());
        layer.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        let mut dx = Tensor::zeros(in_shape.clone());
        layer
            .backward(&[&dy], &[&x], std::slice::from_mut(&mut dx))
            .unwrap();

        let eps = 1e-2f32;
        let loss = |layer: &mut ConvLayer, x: &Tensor| -> f32 {
            let mut y = Tensor::zeros(out_shape.clone());
            layer.forward(&[x], std::slice::from_mut(&mut y)).unwrap();
            y.as_slice().iter().zip(dy.as_slice()).map(|(a, b)| a * b).sum()
        };
        // dX
        for idx in [0usize, 7, 23, in_shape.count() - 1] {
            let mut xp = x.clone();
            xp.as_mut_slice()[idx] += eps;
            let mut xm = x.clone();
            xm.as_mut_slice()[idx] -= eps;
            let num = (loss(&mut layer, &xp) - loss(&mut layer, &xm)) / (2.0 * eps);
            let ana = dx.as_slice()[idx];
            assert!(close(num, ana, 2e-2, 2e-2), "dX[{idx}]: {num} vs {ana}");
        }
        // dW
        for idx in [0usize, 5, 20] {
            let orig = layer.params()[0].data().as_slice()[idx];
            let ana = layer.params()[0].diff().as_slice()[idx];
            layer.params_mut()[0].data_mut().as_mut_slice()[idx] = orig + eps;
            let lp = loss(&mut layer, &x);
            layer.params_mut()[0].data_mut().as_mut_slice()[idx] = orig - eps;
            let lm = loss(&mut layer, &x);
            layer.params_mut()[0].data_mut().as_mut_slice()[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(close(num, ana, 2e-2, 2e-2), "dW[{idx}]: {num} vs {ana}");
        }
    }

    #[test]
    fn rejects_unported_features() {
        let mut cfg = conv_cfg(2, 3, 1, 0);
        cfg.dilation = 2;
        assert!(ConvLayer::new(cfg, 1).is_err());
        let mut cfg = conv_cfg(2, 3, 1, 0);
        cfg.group = 2;
        assert!(ConvLayer::new(cfg, 1).is_err());
        // 3-D input = N-D convolution -> rejected at setup
        let mut l = ConvLayer::new(conv_cfg(2, 3, 1, 0), 1).unwrap();
        assert!(l.setup(&[Shape::new(&[2, 3, 8, 8, 8])]).is_err());
    }

    #[test]
    fn output_shape_lenet_conv1() {
        let mut l = ConvLayer::new(conv_cfg(20, 5, 1, 0), 1).unwrap();
        let tops = l.setup(&[Shape::nchw(64, 1, 28, 28)]).unwrap();
        assert_eq!(tops[0].dims(), &[64, 20, 24, 24]);
    }
}
