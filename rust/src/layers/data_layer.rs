//! Data layer: feeds (data, label) batches from the synthetic datasets (or
//! a record file) — the LMDB DataLayer stand-in.

use std::ops::Range;

use anyhow::{bail, Context, Result};

use crate::data::{BatchIterator, Dataset, SyntheticSpec};
use crate::ops::par;
use crate::proto::LayerConfig;
use crate::tensor::{Shape, Tensor};

use super::Layer;

/// Number of synthetic samples generated per dataset (one "epoch" pool).
pub const DEFAULT_TRAIN_SIZE: usize = 2048;

pub struct DataLayer {
    cfg: LayerConfig,
    iter: BatchIterator,
    /// The contiguous slice of each batch this process materializes
    /// (`cfg.shard`, default the whole batch).  The iterator's cursor
    /// always advances by the full batch regardless.
    shard: Range<usize>,
}

/// Resolve `cfg.shard` into the rank's contiguous sample range, per the
/// `ops::par::partition` rules (`None` → the whole batch).
fn shard_range(cfg: &LayerConfig) -> Result<Range<usize>> {
    let (rank, ranks) = cfg.shard.unwrap_or((0, 1));
    if ranks == 0 || rank >= ranks {
        bail!("bad shard ({rank}, {ranks}): rank must be < ranks and ranks > 0");
    }
    if ranks > cfg.batch_size {
        bail!(
            "batch_size {} cannot shard across {ranks} ranks (every rank needs >= 1 sample)",
            cfg.batch_size
        );
    }
    Ok(par::partition(cfg.batch_size, ranks)[rank].clone())
}

impl DataLayer {
    pub fn new(cfg: LayerConfig, seed: u64) -> Result<Self> {
        let ds = if let Some(spec) = SyntheticSpec::from_source(&cfg.source) {
            Dataset::generate(spec, DEFAULT_TRAIN_SIZE, seed)
        } else if cfg.source.ends_with(".pcrf") {
            crate::data::read_records(std::path::Path::new(&cfg.source))
                .with_context(|| format!("loading records from {}", cfg.source))?
        } else {
            bail!("unknown data source '{}'", cfg.source);
        };
        if cfg.tops.len() != 2 {
            bail!("Data layer needs two tops (data, label)");
        }
        let shard = shard_range(&cfg)?;
        let iter = BatchIterator::new(ds, cfg.batch_size, seed ^ 0xF00D);
        Ok(DataLayer { cfg, iter, shard })
    }

    /// Replace the dataset (used by tests and the eval path).
    pub fn with_dataset(cfg: LayerConfig, ds: Dataset, seed: u64) -> Result<Self> {
        let shard = shard_range(&cfg)?;
        let iter = BatchIterator::new(ds, cfg.batch_size, seed ^ 0xF00D);
        Ok(DataLayer { cfg, iter, shard })
    }

    pub fn epoch(&self) -> usize {
        self.iter.epoch()
    }
}

impl Layer for DataLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if !bottom_shapes.is_empty() {
            bail!("Data layer takes no bottoms");
        }
        // Top shapes are the LOCAL shard: downstream layers (and the
        // planner) size everything off the per-rank batch.
        let full = self.iter.batch_shape();
        let d = full.dims();
        Ok(vec![
            Shape::nchw(self.shard.len(), d[1], d[2], d[3]),
            Shape::new(&[self.shard.len()]),
        ])
    }

    fn forward(&mut self, _bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        // Assemble straight into the top blobs: the gather is parallel
        // over samples and skips the intermediate batch tensor + copy
        // the old `next_batch` path paid per iteration.  Only this
        // rank's shard is materialized; the cursor advances globally.
        let (data_top, label_top) = tops.split_at_mut(1);
        self.iter.next_shard_into(
            data_top[0].as_mut_slice(),
            label_top[0].as_mut_slice(),
            self.shard.clone(),
        );
        Ok(())
    }

    fn backward(
        &mut self,
        _top_diffs: &[&Tensor],
        _bottom_datas: &[&Tensor],
        _bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        Ok(())
    }

    fn needs_backward(&self) -> bool {
        false
    }

    fn data_cursor(&self) -> Option<(usize, usize)> {
        Some(self.iter.cursor())
    }

    fn seek_data(&mut self, epoch: usize, pos: usize) {
        self.iter.seek(epoch, pos);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LayerType;

    fn data_cfg() -> LayerConfig {
        LayerConfig {
            name: "data".into(),
            ltype: LayerType::Data,
            tops: vec!["data".into(), "label".into()],
            batch_size: 8,
            source: "synthetic-mnist".into(),
            ..Default::default()
        }
    }

    #[test]
    fn produces_batches() {
        let mut l = DataLayer::new(data_cfg(), 1).unwrap();
        let shapes = l.setup(&[]).unwrap();
        assert_eq!(shapes[0].dims(), &[8, 1, 28, 28]);
        assert_eq!(shapes[1].dims(), &[8]);
        let mut tops = vec![Tensor::zeros(shapes[0].clone()), Tensor::zeros(shapes[1].clone())];
        l.forward(&[], &mut tops).unwrap();
        assert!(tops[0].l2() > 0.0);
        assert!(tops[1].as_slice().iter().all(|&v| (0.0..10.0).contains(&v)));
    }

    #[test]
    fn unknown_source_rejected() {
        let mut cfg = data_cfg();
        cfg.source = "lmdb://nope".into();
        assert!(DataLayer::new(cfg, 1).is_err());
    }

    #[test]
    fn sharded_layers_tile_the_full_batch() {
        // Batch 8 across 3 ranks → local batches 3, 3, 2; the shards
        // concatenate to the unsharded layer's batch, and every rank's
        // cursor advances by the full batch.
        let mut full = DataLayer::new(data_cfg(), 1).unwrap();
        let shapes = full.setup(&[]).unwrap();
        let mut tops = vec![Tensor::zeros(shapes[0].clone()), Tensor::zeros(shapes[1].clone())];
        full.forward(&[], &mut tops).unwrap();

        let mut got_data = Vec::new();
        let mut got_labels = Vec::new();
        for rank in 0..3 {
            let mut cfg = data_cfg();
            cfg.shard = Some((rank, 3));
            let mut l = DataLayer::new(cfg, 1).unwrap();
            let shapes = l.setup(&[]).unwrap();
            let mut t = vec![Tensor::zeros(shapes[0].clone()), Tensor::zeros(shapes[1].clone())];
            l.forward(&[], &mut t).unwrap();
            got_data.extend_from_slice(t[0].as_slice());
            got_labels.extend_from_slice(t[1].as_slice());
            assert_eq!(l.data_cursor(), full.data_cursor());
        }
        assert_eq!(tops[0].as_slice(), &got_data[..]);
        assert_eq!(tops[1].as_slice(), &got_labels[..]);
    }

    #[test]
    fn oversharded_batch_rejected() {
        let mut cfg = data_cfg();
        cfg.shard = Some((0, 9)); // batch 8 cannot feed 9 ranks
        assert!(DataLayer::new(cfg, 1).is_err());
        let mut cfg = data_cfg();
        cfg.shard = Some((3, 3)); // rank out of range
        assert!(DataLayer::new(cfg, 1).is_err());
    }
}
