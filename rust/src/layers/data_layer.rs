//! Data layer: feeds (data, label) batches from the synthetic datasets (or
//! a record file) — the LMDB DataLayer stand-in.

use anyhow::{bail, Context, Result};

use crate::data::{BatchIterator, Dataset, SyntheticSpec};
use crate::proto::LayerConfig;
use crate::tensor::{Shape, Tensor};

use super::Layer;

/// Number of synthetic samples generated per dataset (one "epoch" pool).
pub const DEFAULT_TRAIN_SIZE: usize = 2048;

pub struct DataLayer {
    cfg: LayerConfig,
    iter: BatchIterator,
}

impl DataLayer {
    pub fn new(cfg: LayerConfig, seed: u64) -> Result<Self> {
        let ds = if let Some(spec) = SyntheticSpec::from_source(&cfg.source) {
            Dataset::generate(spec, DEFAULT_TRAIN_SIZE, seed)
        } else if cfg.source.ends_with(".pcrf") {
            crate::data::read_records(std::path::Path::new(&cfg.source))
                .with_context(|| format!("loading records from {}", cfg.source))?
        } else {
            bail!("unknown data source '{}'", cfg.source);
        };
        if cfg.tops.len() != 2 {
            bail!("Data layer needs two tops (data, label)");
        }
        let iter = BatchIterator::new(ds, cfg.batch_size, seed ^ 0xF00D);
        Ok(DataLayer { cfg, iter })
    }

    /// Replace the dataset (used by tests and the eval path).
    pub fn with_dataset(cfg: LayerConfig, ds: Dataset, seed: u64) -> Result<Self> {
        let iter = BatchIterator::new(ds, cfg.batch_size, seed ^ 0xF00D);
        Ok(DataLayer { cfg, iter })
    }

    pub fn epoch(&self) -> usize {
        self.iter.epoch()
    }
}

impl Layer for DataLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if !bottom_shapes.is_empty() {
            bail!("Data layer takes no bottoms");
        }
        Ok(vec![
            self.iter.batch_shape(),
            Shape::new(&[self.iter.batch_size()]),
        ])
    }

    fn forward(&mut self, _bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        // Assemble straight into the top blobs: the gather is parallel
        // over samples and skips the intermediate batch tensor + copy
        // the old `next_batch` path paid per iteration.
        let (data_top, label_top) = tops.split_at_mut(1);
        self.iter.next_batch_into(data_top[0].as_mut_slice(), label_top[0].as_mut_slice());
        Ok(())
    }

    fn backward(
        &mut self,
        _top_diffs: &[&Tensor],
        _bottom_datas: &[&Tensor],
        _bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        Ok(())
    }

    fn needs_backward(&self) -> bool {
        false
    }

    fn data_cursor(&self) -> Option<(usize, usize)> {
        Some(self.iter.cursor())
    }

    fn seek_data(&mut self, epoch: usize, pos: usize) {
        self.iter.seek(epoch, pos);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LayerType;

    fn data_cfg() -> LayerConfig {
        LayerConfig {
            name: "data".into(),
            ltype: LayerType::Data,
            tops: vec!["data".into(), "label".into()],
            batch_size: 8,
            source: "synthetic-mnist".into(),
            ..Default::default()
        }
    }

    #[test]
    fn produces_batches() {
        let mut l = DataLayer::new(data_cfg(), 1).unwrap();
        let shapes = l.setup(&[]).unwrap();
        assert_eq!(shapes[0].dims(), &[8, 1, 28, 28]);
        assert_eq!(shapes[1].dims(), &[8]);
        let mut tops = vec![Tensor::zeros(shapes[0].clone()), Tensor::zeros(shapes[1].clone())];
        l.forward(&[], &mut tops).unwrap();
        assert!(tops[0].l2() > 0.0);
        assert!(tops[1].as_slice().iter().all(|&v| (0.0..10.0).contains(&v)));
    }

    #[test]
    fn unknown_source_rejected() {
        let mut cfg = data_cfg();
        cfg.source = "lmdb://nope".into();
        assert!(DataLayer::new(cfg, 1).is_err());
    }
}
