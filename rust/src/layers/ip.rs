//! InnerProduct (perceptron/dense) layer — paper §3.2, Listings 1.1/1.2.
//!
//! Forward: Y = X * W^T + 1·b^T (the `matrixPlusVectorRows` functor of
//! Listing 1.2 is the bias loop in `forward`).  Backward: three GeMMs, the
//! Caffe everything-is-a-GeMM trick.
//!
//! The weight matrix is constant across iterations (the solver moves it
//! once per step), so the layer keeps it **pre-packed** for the GeMM
//! engine in two orientations — Wᵀ panels for the forward `X · Wᵀ` and W
//! panels for the backward `dY · W` — each cache keyed by the blob's
//! `data_version()` stamp ([`ops::PackedMat`]).  The old engine
//! re-transposed the full W on *every* forward; now a repack happens only
//! when the stamp moves, i.e. once per solver step.

use anyhow::{bail, Result};

use crate::ops::{self, gemm::Trans, par, PackSide, PackedMat};
use crate::propcheck::Rng;
use crate::proto::LayerConfig;
use crate::tensor::{Blob, Shape, Tensor};

use super::{xavier_fill, Layer};

/// Minimum **elements** per worker for the fused bias-add → activation
/// region (`PHAST_BIAS_GRAIN` overrides).  The region is chunked by rows,
/// so the row grain is derived as `ceil(grain / nout)`: with the default
/// (8192, matching `PHAST_ELTWISE_GRAIN`) LeNet's ip1 (64×500) fans out
/// exactly like the unfused elementwise ReLU did, while the ip2 head
/// (64×10) stays serial where dispatch would dominate.
static BIAS_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_BIAS_GRAIN", 8192);

pub struct IpLayer {
    cfg: LayerConfig,
    params: Vec<Blob>, // [weight (Nout, K), bias (Nout,)]
    k: usize,
    seed: u64,
    /// Wᵀ packed as GeMM B panels (forward), stamped by the weight blob.
    packed_wt: PackedMat,
    /// W packed as GeMM B panels (backward dX), stamped likewise.
    packed_w: PackedMat,
}

impl IpLayer {
    pub fn new(cfg: LayerConfig, seed: u64) -> Self {
        IpLayer {
            cfg,
            params: vec![],
            k: 0,
            seed,
            packed_wt: PackedMat::new(PackSide::B),
            packed_w: PackedMat::new(PackSide::B),
        }
    }
}

impl Layer for IpLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if bottom_shapes.len() != 1 {
            bail!("InnerProduct expects 1 bottom");
        }
        let bs = &bottom_shapes[0];
        self.k = bs.count_from(1);
        let nout = self.cfg.num_output;
        if self.params.is_empty() {
            let mut weight =
                Blob::new(format!("{}.w", self.cfg.name), Shape::new(&[nout, self.k]));
            let mut rng = Rng::new(self.seed ^ self.cfg.name.len() as u64 ^ 0x1b);
            xavier_fill(weight.data_mut(), self.k, &mut rng);
            let bias = Blob::new(format!("{}.b", self.cfg.name), Shape::new(&[nout]));
            self.params = vec![weight, bias];
        }
        Ok(vec![Shape::new(&[bs.num(), nout])])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        let x = bottoms[0];
        let n = x.shape().num();
        let nout = self.cfg.num_output;
        // Repack Wᵀ only when the weight blob's stamp moved (solver step).
        let wv = self.params[0].data_version();
        self.packed_wt.ensure(self.params[0].data().as_slice(), Trans::Yes, nout, self.k, wv);
        let b = self.params[1].data().as_slice();
        let y = tops[0].as_mut_slice();
        // Y = X (n, k) * W^T (k, nout), W^T pre-packed
        ops::gemm_packed_b(n, nout, self.k, 1.0, x.as_slice(), Trans::No, &self.packed_wt, 0.0, y);
        // matrixPlusVectorRows
        for r in 0..n {
            for (yv, bv) in y[r * nout..(r + 1) * nout].iter_mut().zip(b) {
                *yv += bv;
            }
        }
        Ok(())
    }

    fn forward_fused_relu(
        &mut self,
        bottoms: &[&Tensor],
        tops: &mut [Tensor],
        act: &mut Tensor,
        slope: f32,
    ) -> Result<bool> {
        let x = bottoms[0];
        let n = x.shape().num();
        let nout = self.cfg.num_output;
        let wv = self.params[0].data_version();
        self.packed_wt.ensure(self.params[0].data().as_slice(), Trans::Yes, nout, self.k, wv);
        let b = self.params[1].data().as_slice();
        let y = tops[0].as_mut_slice();
        ops::gemm_packed_b(n, nout, self.k, 1.0, x.as_slice(), Trans::No, &self.packed_wt, 0.0, y);
        // matrixPlusVectorRows fused with the following ReLU: one region
        // writes both the pre-activation top and the activation, instead
        // of a serial bias sweep plus a separate elementwise region.  The
        // per-element arithmetic matches `forward` + `ops::leaky_relu`
        // bitwise.
        let a = act.as_mut_slice();
        let row_grain = BIAS_GRAIN.get().div_ceil(nout.max(1));
        par::parallel_chunks2_mut(
            y,
            nout,
            a,
            nout,
            par::Tuning::new(row_grain),
            |rows, yb, ab| {
                for (bi, _r) in rows.enumerate() {
                    let yrow = &mut yb[bi * nout..(bi + 1) * nout];
                    let arow = &mut ab[bi * nout..(bi + 1) * nout];
                    for ((yv, bv), av) in yrow.iter_mut().zip(b).zip(arow.iter_mut()) {
                        *yv += bv;
                        *av = if *yv > 0.0 { *yv } else { slope * *yv };
                    }
                }
            },
        );
        Ok(true)
    }

    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        let dy = top_diffs[0];
        let x = bottom_datas[0];
        let n = x.shape().num();
        let nout = self.cfg.num_output;
        // Keep the W panel cache current before borrowing the diffs; note
        // `diff_mut` below deliberately leaves the data stamp alone, so
        // gradient accumulation never invalidates the pack.
        let wv = self.params[0].data_version();
        self.packed_w.ensure(self.params[0].data().as_slice(), Trans::No, self.k, nout, wv);
        let (wblob, bblob) = self.params.split_at_mut(1);
        let dw = wblob[0].diff_mut().as_mut_slice();
        let db = bblob[0].diff_mut().as_mut_slice();
        // dW += dY^T (nout, n) * X (n, k)  — parallel inside gemm
        ops::gemm(Trans::Yes, Trans::No, nout, self.k, n, 1.0, dy.as_slice(), x.as_slice(), 1.0, dw);
        // db += column sums of dY, column-parallel: every column's rows
        // are summed in ascending order regardless of the split, so the
        // result is bitwise equal to the serial row-major sweep at any
        // thread count.  The grain is derived like the fused bias region's
        // (PHAST_BIAS_GRAIN elements per worker), so tiny heads (ip2's
        // 10 columns) stay serial where dispatch would dominate.
        let dys = dy.as_slice();
        let col_grain = BIAS_GRAIN.get().div_ceil(n.max(1));
        par::parallel_chunks_mut(db, 1, par::Tuning::new(col_grain), |cols_r, dbb| {
            for (bi, j) in cols_r.enumerate() {
                let mut acc = dbb[bi];
                for r in 0..n {
                    acc += dys[r * nout + j];
                }
                dbb[bi] = acc;
            }
        });
        // dX = dY (n, nout) * W (nout, k), W pre-packed  — parallel inside gemm
        ops::gemm_packed_b(
            n,
            self.k,
            nout,
            1.0,
            dy.as_slice(),
            Trans::No,
            &self.packed_w,
            0.0,
            bottom_diffs[0].as_mut_slice(),
        );
        Ok(())
    }

    fn params(&self) -> &[Blob] {
        &self.params
    }

    fn params_mut(&mut self) -> &mut [Blob] {
        &mut self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{close, Rng};
    use crate::proto::LayerType;

    fn ip_cfg(nout: usize) -> LayerConfig {
        LayerConfig {
            name: "ip".into(),
            ltype: LayerType::InnerProduct,
            bottoms: vec!["x".into()],
            tops: vec!["y".into()],
            num_output: nout,
            ..Default::default()
        }
    }

    #[test]
    fn forward_matches_manual() {
        let mut l = IpLayer::new(ip_cfg(2), 1);
        l.setup(&[Shape::new(&[1, 3])]).unwrap();
        l.params_mut()[0]
            .data_mut()
            .as_mut_slice()
            .copy_from_slice(&[1., 0., 2., 0., 1., -1.]); // W (2,3)
        l.params_mut()[1].data_mut().as_mut_slice().copy_from_slice(&[0.5, -0.5]);
        let x = Tensor::from_vec(Shape::new(&[1, 3]), vec![1., 2., 3.]);
        let mut y = Tensor::zeros(Shape::new(&[1, 2]));
        l.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        // row0: 1*1 + 0*2 + 2*3 + 0.5 = 7.5 ; row1: 0 + 2 - 3 - 0.5 = -1.5
        assert_eq!(y.as_slice(), &[7.5, -1.5]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut l = IpLayer::new(ip_cfg(4), 7);
        let in_shape = Shape::new(&[3, 5]);
        let out_shape = l.setup(&[in_shape.clone()]).unwrap().remove(0);
        let mut rng = Rng::new(2);
        let x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(15));
        let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(12));

        let mut dx = Tensor::zeros(in_shape.clone());
        let mut y = Tensor::zeros(out_shape.clone());
        l.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        l.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx)).unwrap();

        let loss = |l: &mut IpLayer, x: &Tensor| -> f32 {
            let mut y = Tensor::zeros(out_shape.clone());
            l.forward(&[x], std::slice::from_mut(&mut y)).unwrap();
            y.as_slice().iter().zip(dy.as_slice()).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        for idx in [0usize, 7, 14] {
            let mut xp = x.clone();
            xp.as_mut_slice()[idx] += eps;
            let mut xm = x.clone();
            xm.as_mut_slice()[idx] -= eps;
            let num = (loss(&mut l, &xp) - loss(&mut l, &xm)) / (2.0 * eps);
            assert!(close(num, dx.as_slice()[idx], 2e-2, 2e-2));
        }
        for idx in [0usize, 9, 19] {
            let orig = l.params()[0].data().as_slice()[idx];
            let ana = l.params()[0].diff().as_slice()[idx];
            l.params_mut()[0].data_mut().as_mut_slice()[idx] = orig + eps;
            let lp = loss(&mut l, &x);
            l.params_mut()[0].data_mut().as_mut_slice()[idx] = orig - eps;
            let lm = loss(&mut l, &x);
            l.params_mut()[0].data_mut().as_mut_slice()[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(close(num, ana, 2e-2, 2e-2), "dW[{idx}]");
        }
    }

    #[test]
    fn backward_db_invariant_to_thread_count() {
        // The column-parallel db reduction must match the serial sweep
        // bitwise (each column's rows are summed in ascending order under
        // any split).
        let run = |threads: usize| -> Vec<f32> {
            par::with_threads(threads, || {
                let mut l = IpLayer::new(ip_cfg(5), 3);
                let in_shape = Shape::new(&[7, 4]);
                let out_shape = l.setup(std::slice::from_ref(&in_shape)).unwrap().remove(0);
                let mut rng = Rng::new(55);
                let x = Tensor::from_vec(in_shape.clone(), rng.normal_vec(in_shape.count()));
                let dy = Tensor::from_vec(out_shape.clone(), rng.normal_vec(out_shape.count()));
                let mut dx = Tensor::zeros(in_shape);
                l.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx)).unwrap();
                l.params()[1].diff().as_slice().to_vec()
            })
        };
        let want = run(1);
        for t in [2usize, 5, 16] {
            assert_eq!(want, run(t), "db diverged at {t} threads");
        }
    }

    #[test]
    fn flattens_conv_output() {
        let mut l = IpLayer::new(ip_cfg(500), 1);
        let tops = l.setup(&[Shape::nchw(64, 50, 4, 4)]).unwrap();
        assert_eq!(tops[0].dims(), &[64, 500]);
        assert_eq!(l.params()[0].shape().dims(), &[500, 800]);
    }
}
