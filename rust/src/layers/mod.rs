//! Executors — the paper's second block category: the eight ported layer
//! types, each with a native (baseline Caffe) implementation.  The PHAST
//! domain executes the same layers through AOT artifacts (`phast::`).

mod data_layer;
mod conv;
mod pool;
mod ip;
mod relu;
mod softmax;
mod accuracy;

pub use accuracy::AccuracyLayer;
pub use conv::ConvLayer;
pub use data_layer::DataLayer;
pub use ip::IpLayer;
pub use pool::PoolLayer;
pub(crate) use pool::PoolBwdCtx;
pub use relu::ReluLayer;
pub use softmax::{SoftmaxLayer, SoftmaxLossLayer};

use anyhow::Result;

use crate::proto::{LayerConfig, LayerType};
use crate::tensor::{Blob, Shape, Tensor};

/// The Caffe layer contract, monomorphized to f32 tensors.
///
/// `bottoms`/`tops` are resolved by name in `net::Net`; in-place layers are
/// not supported (the presets are written out-of-place), which keeps the
/// blob store borrow-safe.
///
/// `Send` is a supertrait: layers hold only owned data (weights, scratch,
/// iterator state), and nets must be movable into other threads — the
/// serving engine's batcher owns its models from a spawned thread.
pub trait Layer: Send {
    /// Static configuration (name, type, connectivity, hyper-parameters).
    fn config(&self) -> &LayerConfig;

    /// Shape inference + parameter allocation.  Returns one shape per top.
    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>>;

    /// Forward pass: read `bottoms`, write `tops`.
    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()>;

    /// Backward pass: read `top_diffs` (and `bottom_datas`), write
    /// `bottom_diffs` and accumulate parameter gradients internally.
    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()>;

    /// Fused forward: compute this layer's `tops` and, within the **same**
    /// parallel region(s), write `leaky_relu(tops[0], slope)` into `act`
    /// (the following ReLU layer's top) — the net's bias-add → activation
    /// fusion seam.  The fused path must be bitwise-equal to `forward`
    /// followed by `ops::leaky_relu`.  Layers that cannot fuse return
    /// `Ok(false)` untouched and the net falls back to separate passes.
    fn forward_fused_relu(
        &mut self,
        _bottoms: &[&Tensor],
        _tops: &mut [Tensor],
        _act: &mut Tensor,
        _slope: f32,
    ) -> Result<bool> {
        Ok(false)
    }

    /// Force this layer's backward-fusion mode (fused gradient region vs
    /// the dispatch-then-serial-merge reference), overriding the
    /// process-wide `PHAST_FUSE_BWD` knob.  Both modes are bitwise equal
    /// at a fixed thread count; layers without a fused backward ignore it.
    fn set_backward_fusion(&mut self, _on: bool) {}

    /// Force this layer's backward operand-packing mode (persistent
    /// im2col panel capture vs per-call recompute+pack), overriding the
    /// process-wide `PHAST_CONV_PACK` knob.  Both modes are bitwise
    /// equal; layers without a pack cache ignore it.
    fn set_backward_packing(&mut self, _on: bool) {}

    /// Data-pipeline cursor `(epoch, position-in-epoch)` for layers that
    /// own a restorable input stream (the Data layer); `None` for every
    /// other layer.  Snapshots record these so a resumed run replays the
    /// exact batch sequence an uninterrupted run would see.
    fn data_cursor(&self) -> Option<(usize, usize)> {
        None
    }

    /// Seek the layer's data pipeline to `(epoch, pos)` (see
    /// [`data_cursor`](Layer::data_cursor)); no-op for layers without one.
    fn seek_data(&mut self, _epoch: usize, _pos: usize) {}

    /// Learnable parameter blobs (weight, bias) — empty for stateless layers.
    fn params(&self) -> &[Blob] {
        &[]
    }

    fn params_mut(&mut self) -> &mut [Blob] {
        &mut []
    }

    /// Concrete-type access for planner-driven execution paths that pair
    /// specific layers inside one fused region (the plan's pool→conv
    /// backward node).  Layers that participate return `Some(self)`;
    /// the default keeps every other layer opaque.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }

    /// Mutable counterpart of [`as_any`](Layer::as_any).
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }

    /// Whether this layer produces a loss (drives backward seeding).
    fn is_loss(&self) -> bool {
        false
    }

    /// Whether backward does anything (Accuracy/Data do not).
    fn needs_backward(&self) -> bool {
        true
    }

    fn name(&self) -> &str {
        &self.config().name
    }

    fn ltype(&self) -> LayerType {
        self.config().ltype
    }
}

/// Construct a layer from its config (the Caffe layer factory).
pub fn create_layer(cfg: &LayerConfig, seed: u64) -> Result<Box<dyn Layer>> {
    Ok(match cfg.ltype {
        LayerType::Data => Box::new(DataLayer::new(cfg.clone(), seed)?),
        LayerType::Convolution => Box::new(ConvLayer::new(cfg.clone(), seed)?),
        LayerType::Pooling => Box::new(PoolLayer::new(cfg.clone())),
        LayerType::InnerProduct => Box::new(IpLayer::new(cfg.clone(), seed)),
        LayerType::ReLU => Box::new(ReluLayer::new(cfg.clone())),
        LayerType::SoftMax => Box::new(SoftmaxLayer::new(cfg.clone())),
        LayerType::SoftMaxWithLoss => Box::new(SoftmaxLossLayer::new(cfg.clone())),
        LayerType::Accuracy => Box::new(AccuracyLayer::new(cfg.clone())),
    })
}

/// Xavier/uniform fill (Caffe's `xavier` FillerParameter): U(-a, a) with
/// a = sqrt(3 / fan_in).
pub fn xavier_fill(t: &mut Tensor, fan_in: usize, rng: &mut crate::propcheck::Rng) {
    let a = (3.0f32 / fan_in as f32).sqrt();
    for v in t.as_mut_slice() {
        *v = rng.range_f32(-a, a);
    }
}

/// Convert a float label tensor (Caffe stores labels in f32 blobs) to i32.
pub fn labels_to_i32(labels: &Tensor) -> Vec<i32> {
    labels.as_slice().iter().map(|&v| v as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::Rng;

    #[test]
    fn xavier_fill_in_range() {
        let mut t = Tensor::zeros(Shape::new(&[100]));
        let mut rng = Rng::new(4);
        xavier_fill(&mut t, 300, &mut rng);
        let a = (3.0f32 / 300.0).sqrt();
        assert!(t.as_slice().iter().all(|v| v.abs() <= a));
        assert!(t.l2() > 0.0);
    }

    #[test]
    fn factory_creates_all_types() {
        use crate::proto::presets;
        use crate::proto::NetConfig;
        let net = NetConfig::from_text(presets::LENET_MNIST).unwrap();
        for cfg in &net.layers {
            let layer = create_layer(cfg, 1).unwrap();
            assert_eq!(layer.name(), cfg.name);
        }
    }
}
