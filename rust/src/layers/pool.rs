//! Pooling layer (MAX with argmax routing, AVE with clipped divisor) —
//! Caffe ceil-mode semantics, paper §3.3.
//!
//! Forward and backward run the batched ops (`ops::*pool*_batch`), which
//! parallelize over the N*C (sample, channel) planes via [`crate::ops::par`].

use anyhow::{bail, Result};

use crate::ops::{self, pool::Pool2dGeom};
use crate::proto::{LayerConfig, PoolMethod};
use crate::tensor::{Shape, Tensor};

use super::Layer;

pub struct PoolLayer {
    cfg: LayerConfig,
    c: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    /// Argmax phases recorded in forward, consumed by backward (MAX only).
    arg: Vec<i32>,
}

impl PoolLayer {
    pub fn new(cfg: LayerConfig) -> Self {
        PoolLayer { cfg, c: 0, h: 0, w: 0, oh: 0, ow: 0, arg: vec![] }
    }

    fn geom(&self) -> Pool2dGeom {
        Pool2dGeom {
            kh: self.cfg.kernel_size,
            kw: self.cfg.kernel_size,
            sh: self.cfg.stride,
            sw: self.cfg.stride,
            ph: self.cfg.pad,
            pw: self.cfg.pad,
        }
    }

    /// The recorded argmax phases (exposed for the PHAST parity tests).
    pub fn argmax(&self) -> &[i32] {
        &self.arg
    }

    /// Everything the planner's fused pool→conv backward region needs to
    /// scatter this layer's gradient per (sample, channel) plane: method,
    /// recorded phases, input geometry (= the producing conv's output
    /// grid) and the pooled output grid.
    pub(crate) fn bwd_ctx(&self) -> PoolBwdCtx<'_> {
        PoolBwdCtx {
            method: self.cfg.pool,
            arg: &self.arg,
            c: self.c,
            h: self.h,
            w: self.w,
            oh: self.oh,
            ow: self.ow,
            g: self.geom(),
        }
    }
}

/// Borrowed backward context of a [`PoolLayer`], consumed by the fused
/// pool→conv backward region (`ConvLayer::backward_fused_pool`).
pub(crate) struct PoolBwdCtx<'a> {
    pub method: PoolMethod,
    /// Argmax phases (MAX only; ignored for AVE).
    pub arg: &'a [i32],
    /// Input channels = the conv's output channels.
    pub c: usize,
    /// Input plane height/width = the conv's output grid.
    pub h: usize,
    pub w: usize,
    /// Pooled output grid.
    pub oh: usize,
    pub ow: usize,
    pub g: Pool2dGeom,
}

impl Layer for PoolLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if bottom_shapes.len() != 1 {
            bail!("Pooling expects 1 bottom");
        }
        let bs = &bottom_shapes[0];
        self.c = bs.channels();
        self.h = bs.height();
        self.w = bs.width();
        let gh = ops::pool_geom(self.h, self.cfg.kernel_size, self.cfg.stride, self.cfg.pad);
        let gw = ops::pool_geom(self.w, self.cfg.kernel_size, self.cfg.stride, self.cfg.pad);
        self.oh = gh.out;
        self.ow = gw.out;
        self.arg = vec![0; bs.num() * self.c * self.oh * self.ow];
        Ok(vec![Shape::nchw(bs.num(), self.c, self.oh, self.ow)])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        let x = bottoms[0];
        let n = x.shape().num();
        let g = self.geom();
        let top = tops[0].as_mut_slice();
        match self.cfg.pool {
            PoolMethod::Max => ops::maxpool_batch(
                x.as_slice(),
                n,
                self.c,
                self.h,
                self.w,
                g,
                top,
                &mut self.arg,
            ),
            PoolMethod::Ave => {
                ops::avepool_batch(x.as_slice(), n, self.c, self.h, self.w, g, top)
            }
        }
        Ok(())
    }

    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        _bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        let dy = top_diffs[0];
        let n = dy.shape().num();
        let g = self.geom();
        let dx = bottom_diffs[0].as_mut_slice();
        match self.cfg.pool {
            PoolMethod::Max => ops::maxpool_bwd_batch(
                dy.as_slice(),
                &self.arg,
                n,
                self.c,
                self.h,
                self.w,
                g,
                dx,
            ),
            PoolMethod::Ave => {
                ops::avepool_bwd_batch(dy.as_slice(), n, self.c, self.h, self.w, g, dx)
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LayerType;

    fn pool_cfg(method: PoolMethod, k: usize, s: usize) -> LayerConfig {
        LayerConfig {
            name: "p".into(),
            ltype: LayerType::Pooling,
            bottoms: vec!["x".into()],
            tops: vec!["y".into()],
            kernel_size: k,
            stride: s,
            pool: method,
            ..Default::default()
        }
    }

    #[test]
    fn lenet_pool_shapes() {
        let mut l = PoolLayer::new(pool_cfg(PoolMethod::Max, 2, 2));
        let tops = l.setup(&[Shape::nchw(64, 20, 24, 24)]).unwrap();
        assert_eq!(tops[0].dims(), &[64, 20, 12, 12]);
    }

    #[test]
    fn cifar_ceil_mode_shape() {
        let mut l = PoolLayer::new(pool_cfg(PoolMethod::Ave, 3, 2));
        let tops = l.setup(&[Shape::nchw(4, 32, 16, 16)]).unwrap();
        assert_eq!(tops[0].dims(), &[4, 32, 8, 8]);
    }

    #[test]
    fn max_forward_backward_roundtrip() {
        let mut l = PoolLayer::new(pool_cfg(PoolMethod::Max, 2, 2));
        let in_shape = Shape::nchw(1, 1, 4, 4);
        let out_shape = l.setup(&[in_shape.clone()]).unwrap().remove(0);
        let x = Tensor::from_vec(
            in_shape,
            vec![1., 2., 5., 6., 3., 4., 7., 8., 0., 0., 1., 0., 9., 0., 0., 0.],
        );
        let mut y = Tensor::zeros(out_shape.clone());
        l.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        assert_eq!(y.as_slice(), &[4., 8., 9., 1.]);
        let dy = Tensor::from_vec(out_shape, vec![1., 1., 1., 1.]);
        let mut dx = Tensor::zeros(x.shape().clone());
        l.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx)).unwrap();
        assert_eq!(dx.sum(), 4.0);
        assert_eq!(dx.as_slice()[5], 1.0); // value 4 at (1,1)
    }
}
