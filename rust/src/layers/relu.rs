//! ReLU layer (Caffe's leaky variant via `negative_slope`).  The
//! elementwise map runs chunk-parallel through `ops::leaky_relu` /
//! `ops::leaky_relu_bwd` (see [`crate::ops::par`]).
//!
//! In the forward sweep this layer often does not run at all: when it
//! directly follows a Convolution/InnerProduct layer the net's fusion
//! plan computes the activation inside the producer's parallel region
//! (`Layer::forward_fused_relu`) and skips this layer — its top blob is
//! still fully written, and its backward is unchanged.

use anyhow::Result;

use crate::ops;
use crate::proto::LayerConfig;
use crate::tensor::{Shape, Tensor};

use super::Layer;

pub struct ReluLayer {
    cfg: LayerConfig,
}

impl ReluLayer {
    pub fn new(cfg: LayerConfig) -> Self {
        ReluLayer { cfg }
    }
}

impl Layer for ReluLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        Ok(vec![bottom_shapes[0].clone()])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        ops::leaky_relu(
            bottoms[0].as_slice(),
            self.cfg.negative_slope,
            tops[0].as_mut_slice(),
        );
        Ok(())
    }

    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        ops::leaky_relu_bwd(
            bottom_datas[0].as_slice(),
            top_diffs[0].as_slice(),
            self.cfg.negative_slope,
            bottom_diffs[0].as_mut_slice(),
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LayerType;

    #[test]
    fn forward_and_backward() {
        let cfg = LayerConfig {
            name: "r".into(),
            ltype: LayerType::ReLU,
            negative_slope: 0.25,
            ..Default::default()
        };
        let mut l = ReluLayer::new(cfg);
        let shape = Shape::new(&[1, 4]);
        l.setup(&[shape.clone()]).unwrap();
        let x = Tensor::from_vec(shape.clone(), vec![-4.0, -1.0, 0.0, 2.0]);
        let mut y = Tensor::zeros(shape.clone());
        l.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        assert_eq!(y.as_slice(), &[-1.0, -0.25, 0.0, 2.0]);
        let dy = Tensor::from_vec(shape.clone(), vec![1.0; 4]);
        let mut dx = Tensor::zeros(shape);
        l.backward(&[&dy], &[&x], std::slice::from_mut(&mut dx)).unwrap();
        assert_eq!(dx.as_slice(), &[0.25, 0.25, 0.25, 1.0]);
    }
}
