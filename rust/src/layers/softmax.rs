//! SoftMax and SoftMaxWithLoss layers (paper §3: "maps any set of numbers
//! to probabilities that add up to 1" + the loss variant used in training).
//! The row-wise kernels run row-block-parallel through `ops::softmax` /
//! `ops::softmax_xent_bwd` (see [`crate::ops::par`]); the forward softmax
//! chain (scale → exp+sum → normalize) is fully fused per row inside a
//! single dispatch.

use anyhow::{bail, Result};

use crate::ops;
use crate::proto::LayerConfig;
use crate::tensor::{Shape, Tensor};

use super::{labels_to_i32, Layer};

/// Plain SoftMax over the class axis of (N, C) logits.
pub struct SoftmaxLayer {
    cfg: LayerConfig,
    n: usize,
    c: usize,
    /// Stashed probabilities for the backward pass.
    probs: Vec<f32>,
}

impl SoftmaxLayer {
    pub fn new(cfg: LayerConfig) -> Self {
        SoftmaxLayer { cfg, n: 0, c: 0, probs: vec![] }
    }
}

impl Layer for SoftmaxLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        let bs = &bottom_shapes[0];
        self.n = bs.num();
        self.c = bs.count_from(1);
        self.probs = vec![0.0; self.n * self.c];
        Ok(vec![bs.clone()])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        ops::softmax(bottoms[0].as_slice(), self.n, self.c, tops[0].as_mut_slice());
        self.probs.copy_from_slice(tops[0].as_slice());
        Ok(())
    }

    fn backward(
        &mut self,
        top_diffs: &[&Tensor],
        _bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        // dX_i = p_i * (dY_i - sum_j dY_j p_j)  (softmax Jacobian product)
        let dy = top_diffs[0].as_slice();
        let dx = bottom_diffs[0].as_mut_slice();
        for r in 0..self.n {
            let p = &self.probs[r * self.c..(r + 1) * self.c];
            let dyr = &dy[r * self.c..(r + 1) * self.c];
            let dot: f32 = p.iter().zip(dyr).map(|(a, b)| a * b).sum();
            for j in 0..self.c {
                dx[r * self.c + j] = p[j] * (dyr[j] - dot);
            }
        }
        Ok(())
    }
}

/// SoftMaxWithLoss: softmax + mean cross-entropy against integer labels.
pub struct SoftmaxLossLayer {
    cfg: LayerConfig,
    n: usize,
    c: usize,
    probs: Vec<f32>,
    labels: Vec<i32>,
}

impl SoftmaxLossLayer {
    pub fn new(cfg: LayerConfig) -> Self {
        SoftmaxLossLayer { cfg, n: 0, c: 0, probs: vec![], labels: vec![] }
    }

    /// Probabilities from the last forward (the paper validates ports by
    /// comparing such intermediate matrices).
    pub fn probs(&self) -> &[f32] {
        &self.probs
    }
}

impl Layer for SoftmaxLossLayer {
    fn config(&self) -> &LayerConfig {
        &self.cfg
    }

    fn setup(&mut self, bottom_shapes: &[Shape]) -> Result<Vec<Shape>> {
        if bottom_shapes.len() != 2 {
            bail!("SoftmaxWithLoss expects (logits, labels)");
        }
        let bs = &bottom_shapes[0];
        self.n = bs.num();
        self.c = bs.count_from(1);
        self.probs = vec![0.0; self.n * self.c];
        self.labels = vec![0; self.n];
        Ok(vec![Shape::new(&[1])])
    }

    fn forward(&mut self, bottoms: &[&Tensor], tops: &mut [Tensor]) -> Result<()> {
        self.labels = labels_to_i32(bottoms[1]);
        let loss = ops::softmax_xent(
            bottoms[0].as_slice(),
            &self.labels,
            self.n,
            self.c,
            &mut self.probs,
        );
        tops[0].as_mut_slice()[0] = loss;
        Ok(())
    }

    fn backward(
        &mut self,
        _top_diffs: &[&Tensor],
        _bottom_datas: &[&Tensor],
        bottom_diffs: &mut [Tensor],
    ) -> Result<()> {
        // Loss layers seed the gradient themselves (loss weight 1.0).
        ops::softmax_xent_bwd(
            &self.probs,
            &self.labels,
            self.n,
            self.c,
            bottom_diffs[0].as_mut_slice(),
        );
        Ok(())
    }

    fn is_loss(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::close;
    use crate::proto::LayerType;

    fn cfg(t: LayerType) -> LayerConfig {
        LayerConfig { name: "s".into(), ltype: t, ..Default::default() }
    }

    #[test]
    fn softmax_forward_simplex() {
        let mut l = SoftmaxLayer::new(cfg(LayerType::SoftMax));
        let shape = Shape::new(&[2, 3]);
        l.setup(&[shape.clone()]).unwrap();
        let x = Tensor::from_vec(shape.clone(), vec![1., 2., 3., 0., 0., 0.]);
        let mut y = Tensor::zeros(shape);
        l.forward(&[&x], std::slice::from_mut(&mut y)).unwrap();
        let s0: f32 = y.as_slice()[..3].iter().sum();
        assert!(close(s0, 1.0, 1e-5, 1e-6));
        assert!(close(y.as_slice()[3], 1.0 / 3.0, 1e-5, 1e-6));
    }

    #[test]
    fn loss_layer_uniform_logits() {
        let mut l = SoftmaxLossLayer::new(cfg(LayerType::SoftMaxWithLoss));
        let logits = Shape::new(&[2, 10]);
        let labels = Shape::new(&[2]);
        l.setup(&[logits.clone(), labels.clone()]).unwrap();
        let x = Tensor::zeros(logits.clone());
        let y = Tensor::from_vec(labels, vec![3.0, 7.0]);
        let mut top = Tensor::zeros(Shape::new(&[1]));
        l.forward(&[&x, &y], std::slice::from_mut(&mut top)).unwrap();
        assert!(close(top.as_slice()[0], (10.0f32).ln(), 1e-5, 1e-6));
        // gradient rows sum to 0 and point away from the label
        let mut dx = Tensor::zeros(logits);
        let mut dlbl = Tensor::zeros(Shape::new(&[2]));
        let mut diffs = vec![dx, dlbl];
        l.backward(&[], &[], &mut diffs).unwrap();
        dx = diffs.remove(0);
        dlbl = diffs.remove(0);
        let row: &[f32] = &dx.as_slice()[..10];
        assert!(close(row.iter().sum::<f32>(), 0.0, 1e-6, 1e-6));
        assert!(row[3] < 0.0);
        assert_eq!(dlbl.sum(), 0.0);
        let _ = dlbl;
    }
}
