//! # phast-caffe
//!
//! A single-source deep-learning framework reproducing *"Using PHAST to port
//! Caffe library: First experiences and lessons learned"* (CS.DC 2020).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the ported Caffe
//!   blocks (im2col+GeMM convolution, pooling, InnerProduct, ReLU, SoftMax,
//!   SoftMax-with-loss, Accuracy), written once.
//! * **L2** — JAX graphs (`python/compile/model.py`): LeNet-MNIST and
//!   CIFAR10-quick forward/backward composed from the L1 kernels and
//!   AOT-lowered to HLO text (`make artifacts`).
//! * **L3** — this crate: blobs, layers, nets, the SGD solver, the data
//!   pipeline, the PJRT runtime that executes the artifacts, and the
//!   domain-placement machinery that reproduces the paper's partial-porting
//!   analysis (transfer counting, layout conversion at domain boundaries).
//!
//! The *native* modules ([`ops`], the native paths of [`layers`]) play the
//! role of original Caffe + OpenBLAS — the baseline the paper compares
//! against.  The *ported* path runs the AOT artifacts through [`runtime`]
//! under a per-layer [`phast::Placement`].

pub mod tensor;
pub mod ops;
pub mod propcheck;
pub mod proto;
pub mod data;
pub mod layers;
pub mod net;
pub mod solver;
pub mod runtime;
pub mod phast;
pub mod metrics;
pub mod conformance;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
