//! `repro` — the phast-caffe command line (the `caffe` binary analog).
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! repro train      --net mnist --iters 300 --backend native|partial|fused
//! repro train_dist --net mnist --ranks 4 --iters 100   # elastic data-parallel
//! repro time       --net mnist --reps 30           # per-layer timing
//! repro table1                                     # conformance suite
//! repro table2     --reps 30                       # fwd-bwd comparison
//! repro transfers  --net mnist --reps 5            # §4.3 crossing sweep
//! repro info                                       # platform + catalog
//! ```
//!
//! A process launched with `PHAST_DIST_ROLE=worker` in the environment
//! becomes a dist training worker regardless of arguments (its stdout is
//! the gradient transport — see `runtime::dist`).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use phast_caffe::conformance;
use phast_caffe::experiments::{
    measure_placement, porting_sweep, preset_net, render_table2, render_transfers,
    run_table2, sample_batch,
};
use phast_caffe::phast::{BoundaryOptions, FusedRunner, Placement, PortedNet, PortedSolver};
use phast_caffe::proto::{presets, NetConfig, SolverConfig};
use phast_caffe::runtime::{self, Engine};
use phast_caffe::solver::Solver;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            m.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    m
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

fn usize_flag(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn cmd_info() -> Result<()> {
    let engine = Engine::open_default().context("open artifacts (run `make artifacts`)")?;
    println!("phast-caffe — single-source Caffe reproduction (PHAST paper, CS.DC 2020)");
    println!("PJRT platform : {}", engine.platform());
    println!("artifacts     : {}", engine.manifest().len());
    for n in engine.manifest().names() {
        let spec = engine.manifest().get(n).unwrap();
        println!("  {:28} {:>2} in / {} out", n, spec.ins.len(), spec.outs.len());
    }
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let name = flag(flags, "net", "mnist");
    let backend = flag(flags, "backend", "native");
    let solver_src = presets::solver_by_name(name)
        .ok_or_else(|| anyhow::anyhow!("no preset solver for '{name}'"))?;
    let mut cfg = SolverConfig::from_text(solver_src)?;
    if let Some(it) = flags.get("iters") {
        cfg.max_iter = it.parse()?;
    }
    let iters = cfg.max_iter;
    let display = cfg.display.max(1);
    println!("training {name} for {iters} iterations (backend: {backend})");

    match backend {
        "native" => {
            let net = preset_net(name, cfg.seed)?;
            let mut solver = Solver::new(cfg, net);
            for _ in 0..iters {
                let loss = solver.step()?;
                if solver.iter() % display == 0 {
                    let (tl, ta) = solver.test(2)?;
                    println!(
                        "iter {:>5}  loss {:.4}  lr {:.5}  test-loss {:.4}  test-acc {:.3}",
                        solver.iter(),
                        loss,
                        solver.lr(),
                        tl,
                        ta
                    );
                }
            }
        }
        "partial" | "phast" => {
            let engine = Engine::open_default()?;
            let net_cfg = NetConfig::from_text(presets::net_by_name(name).unwrap())?;
            let placement = if backend == "partial" {
                Placement::paper_partial(&net_cfg)
            } else {
                Placement::phast_all()
            };
            let pnet = PortedNet::new(
                preset_net(name, cfg.seed)?,
                &engine,
                placement,
                BoundaryOptions::default(),
            )?;
            let mut solver = PortedSolver::new(cfg, pnet);
            for _ in 0..iters {
                let loss = solver.step()?;
                if solver.iter() % display == 0 {
                    println!("iter {:>5}  loss {loss:.4}  lr {:.5}", solver.iter(), solver.lr());
                }
            }
            let st = solver.pnet.stats;
            println!(
                "boundary crossings: fwd {} bwd {} ({} KiB relayouted)",
                st.crossings_fwd,
                st.crossings_bwd,
                st.conversion_bytes / 1024
            );
        }
        "fused" => {
            let engine = Engine::open_default()?;
            let mut feeder = preset_net(name, cfg.seed)?;
            let mut fused = FusedRunner::from_net(&engine, &feeder)?;
            for i in 0..iters {
                let (x, labels) = sample_batch(&mut feeder)?;
                let lr = cfg.lr_policy.lr_at(cfg.base_lr, i);
                let loss = fused.step(x, labels, lr)?;
                if (i + 1) % display == 0 {
                    println!("iter {:>5}  loss {loss:.4}  lr {lr:.5}", i + 1);
                }
            }
        }
        other => bail!("unknown backend '{other}' (native|partial|phast|fused)"),
    }
    Ok(())
}

fn cmd_time(flags: &HashMap<String, String>) -> Result<()> {
    // `caffe time` analog: per-layer forward/backward timing.
    let name = flag(flags, "net", "mnist");
    let reps = usize_flag(flags, "reps", 20);
    let mut net = preset_net(name, 1)?;
    for _ in 0..3 {
        net.zero_param_diffs();
        net.forward()?;
        net.backward()?;
    }
    net.metrics.clear();
    for _ in 0..reps {
        net.zero_param_diffs();
        net.forward()?;
        net.backward()?;
    }
    println!("per-layer timings over {reps} iterations ({name}, native):");
    print!("{}", net.metrics.report());
    Ok(())
}

fn cmd_table1() -> Result<()> {
    let engine = Engine::open_default().ok();
    if engine.is_none() {
        println!("(artifacts missing: running without PJRT parity sub-checks)");
    }
    let results = conformance::run_suite(engine.as_ref());
    println!("Table 1 — Caffe tests results for the modified blocks (f32):\n");
    print!("{}", conformance::render_table1(&results));
    println!("\nfailed checks (unimplemented functionality, as in the paper):");
    for r in &results {
        if !r.passed {
            println!("  {:<14} {:<34} {}", r.block, r.name, r.note);
        }
    }
    println!("\npaper: Conv 3/15, Pool 11/11, IP 9/9, SoftMax 4/4, Loss 4/4, Acc 9/12");
    Ok(())
}

fn cmd_table2(flags: &HashMap<String, String>) -> Result<()> {
    let warmup = usize_flag(flags, "warmup", 3);
    let reps = usize_flag(flags, "reps", 20);
    let engine = Engine::open_default()?;
    println!("measuring Table 2 ({reps} reps, {warmup} warmup)...");
    let mnist = run_table2(&engine, "mnist", warmup, reps)?;
    let cifar = run_table2(&engine, "cifar", warmup, reps)?;
    print!("{}", render_table2(&mnist, &cifar));
    Ok(())
}

fn cmd_transfers(flags: &HashMap<String, String>) -> Result<()> {
    let name = flag(flags, "net", "mnist");
    let reps = usize_flag(flags, "reps", 3);
    let engine = Engine::open_default()?;
    println!("§4.3 transfer analysis for {name} (fwd+bwd, per iteration):\n");
    let sweep = porting_sweep(&engine, name, reps)?;
    print!("{}", render_transfers(&sweep));

    let cfg = NetConfig::from_text(presets::net_by_name(name).unwrap())?;
    let with = measure_placement(
        &engine,
        name,
        "paper placement + layout conv",
        Placement::paper_partial(&cfg),
        true,
        reps,
    )?;
    let without = measure_placement(
        &engine,
        name,
        "paper placement, no layout conv",
        Placement::paper_partial(&cfg),
        false,
        reps,
    )?;
    println!("\nlayout-conversion ablation:");
    print!("{}", render_transfers(&[with, without]));
    println!("\npaper estimate: ~10 (MNIST) / ~30 (CIFAR) unnecessary transfers per");
    println!("inference pass at the paper's porting snapshot, doubled by backward.");
    Ok(())
}

fn cmd_verify_plan(flags: &HashMap<String, String>) -> Result<()> {
    // Static plan verification (`net::plan::Plan::verify`) over the
    // named preset(s).  `Net::from_config` already refuses to build a
    // violating plan, so this prints the full per-check report for a
    // healthy net — CI and the golden files in `tests/check.rs` pin it.
    let which = flag(flags, "net", "both");
    let names: Vec<&str> =
        if which == "both" { vec!["mnist", "cifar"] } else { vec![which] };
    let mut total = 0usize;
    for name in names {
        let net = preset_net(name, 1)?;
        let report = net.plan().verify(net.config());
        print!("{}", report.render());
        total += report.violations.len();
    }
    if total > 0 {
        bail!("{total} plan-contract violation(s)");
    }
    Ok(())
}

fn cmd_train_dist(flags: &HashMap<String, String>) -> Result<()> {
    let exe = std::env::current_exe().context("locating current executable")?;
    let dir = flag(flags, "dir", "target/dist-snapshots").to_string();
    let mut cfg = runtime::dist::DistConfig::new(exe, dir);
    cfg.ranks = usize_flag(flags, "ranks", 2);
    cfg.iters = usize_flag(flags, "iters", 20);
    cfg.net = flag(flags, "net", "mnist").to_string();
    cfg.seed = usize_flag(flags, "seed", 42) as u64;
    if let Some(b) = flags.get("batch") {
        cfg.batch = Some(b.parse().context("--batch")?);
    }
    cfg.snapshot_every = usize_flag(flags, "every", 4);
    cfg.keep = usize_flag(flags, "keep", cfg.keep);
    cfg.recover_budget = usize_flag(flags, "budget", cfg.recover_budget);
    println!(
        "dist training {} for {} iterations across {} ranks (dir {:?})",
        cfg.net, cfg.iters, cfg.ranks, cfg.dir
    );
    let summary = runtime::dist::train_dist(cfg)?;
    if let Some(it) = summary.resumed_from {
        println!("resumed from iter {it}");
    }
    println!("ranks={}", summary.ranks);
    println!("recoveries={}", summary.recoveries);
    println!("crc_nacks={} nacks_served={}", summary.crc_nacks, summary.nacks_served);
    println!("final_iter={}", summary.final_iter);
    println!("final_weights_hash={:#010x}", summary.weights_hash);
    Ok(())
}

fn main() -> Result<()> {
    // Worker role check before ANYTHING writes to stdout: a dist
    // worker's stdout carries wire frames, not text.
    runtime::dist::exec_worker_if_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(&flags),
        "train_dist" => cmd_train_dist(&flags),
        "train_worker" => {
            // Manual worker launch (normally selected via PHAST_DIST_ROLE).
            phast_caffe::runtime::dist::worker_main()
        }
        "time" => cmd_time(&flags),
        "table1" => cmd_table1(),
        "table2" => cmd_table2(&flags),
        "transfers" => cmd_transfers(&flags),
        "verify-plan" => cmd_verify_plan(&flags),
        _ => {
            println!(
                "usage: repro <info|train|train_dist|time|table1|table2|transfers|verify-plan>\n\
                 [--net mnist|cifar|both] [--backend native|partial|phast|fused] [--iters N]\n\
                 [--reps N] [--ranks N] [--batch N] [--every N] [--budget N] [--dir PATH]"
            );
            Ok(())
        }
    }
}
