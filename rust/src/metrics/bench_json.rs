//! Merge-update for the hand-rolled `BENCH_*.json` records (the build is
//! dependency-free, so no serde).
//!
//! Benches used to rewrite `BENCH_threads.json` wholesale, so the last
//! bench to run clobbered every other bench's entries.  This module keeps
//! the file an object of **keyed entries**: each bench re-writes only its
//! own top-level keys and preserves the rest, so the dispatch microbench
//! and the fusion bench coexist in one record — which is also what the CI
//! perf gate (`tools/check_bench.sh`) compares against the checked-in
//! `BENCH_baseline.json`.

use std::path::Path;

/// Split a JSON object's top-level `key → raw value` pairs.  The scanner
/// is string- and nesting-aware but deliberately minimal: it targets the
/// machine-written records this crate produces (and any well-formed JSON
/// object); on malformed input it returns the pairs parsed so far.
pub fn top_level_entries(json: &str) -> Vec<(String, String)> {
    let chars: Vec<char> = json.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() && chars[i] != '{' {
        i += 1;
    }
    if i >= chars.len() {
        return out;
    }
    i += 1; // past '{'
    loop {
        while i < chars.len() && (chars[i].is_whitespace() || chars[i] == ',') {
            i += 1;
        }
        if i >= chars.len() || chars[i] == '}' || chars[i] != '"' {
            break;
        }
        let (key, after_key) = match scan_string(&chars, i) {
            Some(v) => v,
            None => break,
        };
        i = after_key;
        while i < chars.len() && chars[i].is_whitespace() {
            i += 1;
        }
        if i >= chars.len() || chars[i] != ':' {
            break;
        }
        i += 1;
        while i < chars.len() && chars[i].is_whitespace() {
            i += 1;
        }
        let start = i;
        let mut depth = 0usize;
        let mut in_str = false;
        let mut esc = false;
        while i < chars.len() {
            let ch = chars[i];
            if in_str {
                if esc {
                    esc = false;
                } else if ch == '\\' {
                    esc = true;
                } else if ch == '"' {
                    in_str = false;
                }
            } else {
                match ch {
                    '"' => in_str = true,
                    '{' | '[' => depth += 1,
                    '}' | ']' => {
                        if depth == 0 {
                            break;
                        }
                        depth -= 1;
                    }
                    ',' if depth == 0 => break,
                    _ => {}
                }
            }
            i += 1;
        }
        let value: String = chars[start..i].iter().collect::<String>().trim_end().to_string();
        out.push((key, value));
    }
    out
}

/// Scan the JSON string literal starting at `chars[at] == '"'`; returns
/// the (unescaped-enough-for-keys) content and the index just past the
/// closing quote.
fn scan_string(chars: &[char], at: usize) -> Option<(String, usize)> {
    debug_assert_eq!(chars.get(at), Some(&'"'));
    let mut s = String::new();
    let mut i = at + 1;
    while i < chars.len() {
        match chars[i] {
            '\\' => {
                // Keys in our records never use escapes; keep the pair raw.
                if i + 1 < chars.len() {
                    s.push(chars[i]);
                    s.push(chars[i + 1]);
                    i += 2;
                } else {
                    return None;
                }
            }
            '"' => return Some((s, i + 1)),
            c => {
                s.push(c);
                i += 1;
            }
        }
    }
    None
}

/// Read the JSON object at `path` (treated as empty when absent or
/// unreadable), set each `(key, raw value)` update — replacing the entry
/// if the key exists, appending otherwise — and write the merged object
/// back.  Raw values must be valid JSON (number, string, array, object).
///
/// The write goes through a temp file + rename so a killed bench can
/// never leave a truncated record behind.  A non-empty existing record
/// that parses to **zero** entries is corrupt: its keyed history would
/// silently vanish under the old clobber-and-continue behaviour, so it
/// is now **renamed aside** (loudly, on stderr) to the first unused of
/// `<path>.corrupt`, `<path>.corrupt-1`, … before the fresh record is
/// written — nothing is ever dropped, including earlier preserved
/// corruptions.  If even the rename fails, the merge errors out instead
/// of overwriting the evidence.
pub fn merge_entries(path: &Path, updates: &[(&str, String)]) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_else(|_| String::from("{}"));
    let mut entries = top_level_entries(&existing);
    let trimmed = existing.trim();
    if entries.is_empty() && !trimmed.is_empty() && trimmed != "{}" {
        // First unused aside name, so repeated corruption never clobbers
        // an earlier preserved record.
        let mut aside = path.with_extension("json.corrupt");
        let mut i = 0;
        while aside.exists() {
            i += 1;
            aside = path.with_extension(format!("json.corrupt-{i}"));
        }
        eprintln!(
            "error: {} held unparseable content; moving it aside to {} and starting a fresh record",
            path.display(),
            aside.display()
        );
        std::fs::rename(path, &aside)?;
    }
    for (key, value) in updates {
        match entries.iter_mut().find(|(k, _)| k == key) {
            Some(entry) => entry.1 = value.clone(),
            None => entries.push((key.to_string(), value.clone())),
        }
    }
    let mut out = String::from("{\n");
    for (i, (k, v)) in entries.iter().enumerate() {
        let comma = if i + 1 < entries.len() { "," } else { "" };
        out.push_str(&format!("  \"{k}\": {v}{comma}\n"));
    }
    out.push_str("}\n");
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, out)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_scalars_arrays_and_objects() {
        let json = r#"{
  "a": 1,
  "b": [ {"x": 1}, {"y": "s,t\"r"} ],
  "c": { "nested": { "deep": [1, 2] } },
  "d": "plain"
}"#;
        let entries = top_level_entries(json);
        let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a", "b", "c", "d"]);
        assert_eq!(entries[0].1, "1");
        assert!(entries[1].1.starts_with('[') && entries[1].1.ends_with(']'));
        assert!(entries[2].1.contains("\"deep\": [1, 2]"));
        assert_eq!(entries[3].1, "\"plain\"");
    }

    #[test]
    fn merge_preserves_other_keys() {
        let dir = std::env::temp_dir().join("phast_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merge.json");
        let _ = std::fs::remove_file(&path);

        merge_entries(&path, &[("first", "{\n    \"v\": 1\n  }".to_string())]).unwrap();
        merge_entries(&path, &[("second", "[1, 2, 3]".to_string())]).unwrap();
        merge_entries(&path, &[("first", "{\n    \"v\": 2\n  }".to_string())]).unwrap();

        let merged = std::fs::read_to_string(&path).unwrap();
        let entries = top_level_entries(&merged);
        let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["first", "second"], "keys lost or reordered: {merged}");
        assert!(entries[0].1.contains("\"v\": 2"), "update not applied: {merged}");
        assert_eq!(entries[1].1, "[1, 2, 3]", "sibling entry clobbered: {merged}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_record_is_renamed_aside_not_clobbered() {
        let dir = std::env::temp_dir().join("phast_bench_json_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merge.json");
        let aside = path.with_extension("json.corrupt");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&aside);

        // A corrupt (non-empty, zero-entry) record must survive as
        // `<path>.corrupt` byte-for-byte, and the merge must still
        // produce a fresh valid record.
        let garbage = "]]]this was someone's bench history[[[";
        std::fs::write(&path, garbage).unwrap();
        merge_entries(&path, &[("fresh", "42".to_string())]).unwrap();
        assert_eq!(
            std::fs::read_to_string(&aside).unwrap(),
            garbage,
            "corrupt history must be preserved aside"
        );
        let merged = std::fs::read_to_string(&path).unwrap();
        let entries = top_level_entries(&merged);
        assert_eq!(entries, vec![("fresh".to_string(), "42".to_string())], "{merged}");

        // A second corruption must not clobber the first preserved file:
        // it lands on the next unused aside name.
        let aside1 = path.with_extension("json.corrupt-1");
        let _ = std::fs::remove_file(&aside1);
        let garbage2 = "different garbage, also worth keeping";
        std::fs::write(&path, garbage2).unwrap();
        merge_entries(&path, &[("fresh", "43".to_string())]).unwrap();
        assert_eq!(std::fs::read_to_string(&aside).unwrap(), garbage, "first aside clobbered");
        assert_eq!(
            std::fs::read_to_string(&aside1).unwrap(),
            garbage2,
            "second corruption must land on the next unused name"
        );

        // An empty or `{}` record is not corrupt: no rename happens.
        let _ = std::fs::remove_file(&aside);
        let _ = std::fs::remove_file(&aside1);
        std::fs::write(&path, "{}").unwrap();
        merge_entries(&path, &[("next", "1".to_string())]).unwrap();
        assert!(!aside.exists(), "a well-formed empty record must not be moved aside");

        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&aside);
    }

    #[test]
    fn empty_or_missing_input_yields_empty() {
        assert!(top_level_entries("").is_empty());
        assert!(top_level_entries("{}").is_empty());
        assert!(top_level_entries("not json").is_empty());
    }
}
