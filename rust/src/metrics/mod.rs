//! Timing and counting instrumentation: per-layer wall-clock stats and the
//! transfer counters the §4.3 reproduction reports.

pub mod bench_json;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Online statistics for a stream of duration samples.
#[derive(Clone, Debug, Default)]
pub struct TimingStat {
    samples_us: Vec<u64>,
}

impl TimingStat {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn total_ms(&self) -> f64 {
        self.samples_us.iter().sum::<u64>() as f64 / 1000.0
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            0.0
        } else {
            self.total_ms() / self.samples_us.len() as f64
        }
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx] as f64 / 1000.0
    }
}

/// Named timers + counters, printed as the `caffe time`-style report.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    timers: BTreeMap<String, TimingStat>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed());
        out
    }

    pub fn record(&mut self, name: &str, d: Duration) {
        self.timers.entry(name.to_string()).or_default().record(d);
    }

    pub fn bump(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn timer(&self, name: &str) -> Option<&TimingStat> {
        self.timers.get(name)
    }

    pub fn timers(&self) -> impl Iterator<Item = (&str, &TimingStat)> {
        self.timers.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Human-readable table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        if !self.timers.is_empty() {
            out.push_str(&format!(
                "{:<28} {:>7} {:>10} {:>10} {:>10}\n",
                "timer", "n", "mean ms", "p50 ms", "p95 ms"
            ));
            for (name, stat) in &self.timers {
                out.push_str(&format!(
                    "{:<28} {:>7} {:>10.3} {:>10.3} {:>10.3}\n",
                    name,
                    stat.count(),
                    stat.mean_ms(),
                    stat.percentile_ms(50.0),
                    stat.percentile_ms(95.0)
                ));
            }
        }
        if !self.counters.is_empty() {
            out.push_str(&format!("{:<28} {:>10}\n", "counter", "value"));
            for (name, v) in &self.counters {
                out.push_str(&format!("{:<28} {:>10}\n", name, v));
            }
        }
        out
    }

    pub fn clear(&mut self) {
        self.timers.clear();
        self.counters.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let mut s = TimingStat::default();
        for ms in [1u64, 2, 3, 4, 100] {
            s.record(Duration::from_millis(ms));
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean_ms() - 22.0).abs() < 0.5);
        assert!(s.percentile_ms(50.0) <= 4.0);
        assert!(s.percentile_ms(95.0) >= 4.0);
    }

    #[test]
    fn counters_and_report() {
        let mut m = Metrics::new();
        m.bump("transfers.h2d", 3);
        m.bump("transfers.h2d", 2);
        m.time("fwd", || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(m.counter("transfers.h2d"), 5);
        let r = m.report();
        assert!(r.contains("transfers.h2d"));
        assert!(r.contains("fwd"));
    }
}
