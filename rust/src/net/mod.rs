//! The Net: a DAG of layers over a named blob store, with forward/backward
//! sweeps and per-layer timing — Caffe's `Net<float>`, Fig. 1 of the paper.
//!
//! Scheduling is decided once, at [`Net::from_config`] time, by the
//! graph-level [`plan::Plan`]: a region-graph IR whose nodes are
//! fused-region descriptions and whose edges are blob dependencies.  By
//! default (`PHAST_PLAN`, on) the forward/backward sweeps walk the
//! plan's schedules — which subsume the pre-planner pairwise fusion and
//! add the fused pool→conv backward region with arena-shared scratch;
//! with the knob off the sweeps run the original hard-coded paths, the
//! bitwise reference every planned schedule is pinned against.

pub mod plan;

use std::collections::HashMap;
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::layers::{create_layer, ConvLayer, Layer, PoolLayer};
use crate::metrics::Metrics;
use crate::proto::{LayerType, NetConfig};
use crate::tensor::{Blob, Shape, Tensor};

pub use plan::Plan;

/// `PHAST_FUSE_LAYERS`, parsed once: `0` disables the elementwise layer
/// fusion plan (bias-add → activation in one region); anything else, or
/// unset, enables it.  [`Net::set_layer_fusion`] overrides per net.
fn layer_fusion_default() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("PHAST_FUSE_LAYERS").map(|v| v.trim() != "0").unwrap_or(true))
}

/// `PHAST_PLAN`, parsed once: `0` or `off` selects the pre-planner
/// hard-coded execution paths (the bitwise reference); anything else, or
/// unset, drives both sweeps through the plan's schedules.
/// [`Net::set_plan`] overrides per net.  The plan itself is always
/// built — the knob only selects which executor walks the net.
fn plan_default() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        std::env::var("PHAST_PLAN")
            .map(|v| !matches!(v.trim(), "0" | "off"))
            .unwrap_or(true)
    })
}

/// A fully set-up network.
pub struct Net {
    config: NetConfig,
    layers: Vec<Box<dyn Layer>>,
    blobs: Vec<Blob>,
    blob_index: HashMap<String, usize>,
    /// Per-layer bottom/top blob indices.
    bottom_ids: Vec<Vec<usize>>,
    top_ids: Vec<Vec<usize>>,
    /// Fusion plan: for layer `li`, the index of the adjacent ReLU layer
    /// whose forward is fused into `li`'s parallel region (the paper's
    /// §4.3 "no artificial interruption across the layers", at the native
    /// level).  Built once in [`Net::from_config`].
    fused_relu: Vec<Option<usize>>,
    /// Runtime toggle for the plan (`PHAST_FUSE_LAYERS`, default on).
    layer_fusion: bool,
    /// The region-graph execution plan (always built; see `plan_on`).
    plan: plan::Plan,
    /// Runtime selector between the planned executors and the
    /// pre-planner reference paths (`PHAST_PLAN`, default on).
    plan_on: bool,
    /// Shared scratch arena the planned backward carves fused-region
    /// worker windows from (one slot per `Plan::arena_slots`).
    arena: plan::ScratchArena,
    /// Inference mode for the serving engine: while set, Data layers are
    /// skipped in the forward sweep so externally-written input blobs
    /// (the request batch) are not overwritten by the synthetic data
    /// pipeline.  Toggled only inside [`Net::forward_infer`].
    infer_skip_data: bool,
    pub metrics: Metrics,
}

impl Net {
    /// [`Net::from_config`] for data-parallel rank `rank` of `ranks`:
    /// every Data layer materializes only its contiguous shard of each
    /// batch (per the `ops::par::partition` rules) while drawing the
    /// full batch's index stream, so all ranks share global cursor
    /// semantics and snapshots stay interchangeable with single-process
    /// runs.  Weight init is seed-driven and batch-independent, so all
    /// ranks start from identical parameters.  `(0, 1)` is byte-identical
    /// to [`Net::from_config`].
    pub fn from_config_sharded(
        mut config: NetConfig,
        seed: u64,
        rank: usize,
        ranks: usize,
    ) -> Result<Net> {
        if ranks == 0 || rank >= ranks {
            anyhow::bail!("bad shard ({rank}, {ranks}): rank must be < ranks and ranks > 0");
        }
        for cfg in &mut config.layers {
            if cfg.ltype == crate::proto::LayerType::Data {
                cfg.shard = Some((rank, ranks));
            }
        }
        Net::from_config(config, seed)
    }

    /// Build + setup from a parsed config.  `seed` drives weight init and
    /// the data pipeline.
    pub fn from_config(config: NetConfig, seed: u64) -> Result<Net> {
        let mut layers = Vec::new();
        let mut blobs: Vec<Blob> = Vec::new();
        let mut blob_index: HashMap<String, usize> = HashMap::new();
        let mut bottom_ids = Vec::new();
        let mut top_ids = Vec::new();

        for cfg in &config.layers {
            let mut layer = create_layer(cfg, seed)?;
            // Resolve bottoms (must already exist).
            let mut bids = Vec::new();
            let mut bshapes = Vec::new();
            for b in &cfg.bottoms {
                let id = *blob_index
                    .get(b)
                    .with_context(|| format!("layer '{}' bottom '{}' undefined", cfg.name, b))?;
                bids.push(id);
                bshapes.push(blobs[id].shape().clone());
            }
            // In-place layers are unsupported (kept out-of-place by design).
            for t in &cfg.tops {
                if cfg.bottoms.contains(t) {
                    bail!("in-place layer '{}' not supported; use distinct top names", cfg.name);
                }
            }
            let tshapes = layer
                .setup(&bshapes)
                .with_context(|| format!("setting up layer '{}'", cfg.name))?;
            if tshapes.len() != cfg.tops.len() {
                bail!(
                    "layer '{}' produced {} tops, config names {}",
                    cfg.name,
                    tshapes.len(),
                    cfg.tops.len()
                );
            }
            let mut tids = Vec::new();
            for (t, shape) in cfg.tops.iter().zip(tshapes) {
                if blob_index.contains_key(t) {
                    bail!("duplicate top blob '{}'", t);
                }
                let id = blobs.len();
                blobs.push(Blob::new(t.clone(), shape));
                blob_index.insert(t.clone(), id);
                tids.push(id);
            }
            bottom_ids.push(bids);
            top_ids.push(tids);
            layers.push(layer);
        }
        // Build the region-graph plan (see `plan::Plan`).  Rule R1 — a
        // Convolution/InnerProduct layer immediately followed by a ReLU
        // that consumes exactly its single top gets the activation
        // computed inside its own forward region — subsumes the old
        // inline detection here and adds the explicit fan-out gate: a
        // producer top consumed by more than one layer never fuses.
        // The `fused_relu` table is derived from the plan so the legacy
        // executor (`PHAST_PLAN=off`) follows the identical pairing.
        let plan = plan::Plan::build(&config, &layers, &blobs, &bottom_ids, &top_ids);
        // Static plan verification (`plan::Plan::verify`): a plan that
        // violates its own contracts — arena interval coloring, the R3
        // fan-out gate, barrier sufficiency, schedule order, skip-node
        // consistency — refuses to construct, so a planner bug surfaces
        // as a build error with the full report, never as a race or a
        // corrupted schedule at execution time.
        let verify = plan.verify(&config);
        if !verify.is_clean() {
            bail!("plan verification failed for net '{}':\n{}", config.name, verify.render());
        }
        let mut fused_relu: Vec<Option<usize>> = vec![None; layers.len()];
        for (li, ri) in plan.fused_relu_pairs() {
            fused_relu[li] = Some(ri);
        }
        let arena = plan::ScratchArena::new(plan.arena_slots());
        Ok(Net {
            config,
            layers,
            blobs,
            blob_index,
            bottom_ids,
            top_ids,
            fused_relu,
            layer_fusion: layer_fusion_default(),
            plan,
            plan_on: plan_default(),
            arena,
            infer_skip_data: false,
            metrics: Metrics::new(),
        })
    }

    /// The region-graph execution plan built at construction time.
    pub fn plan(&self) -> &plan::Plan {
        &self.plan
    }

    /// Mutable access to the plan — the seeded-violation seam
    /// `rust/tests/check.rs` uses to corrupt a verified plan (e.g.
    /// double-book an arena slot) and assert `plan::Plan::verify`
    /// reports the exact site.  Executors never mutate the plan.
    pub fn plan_mut(&mut self) -> &mut plan::Plan {
        &mut self.plan
    }

    /// Select between the planned executors and the pre-planner
    /// reference paths at runtime (overrides `PHAST_PLAN`; both are
    /// bitwise-equal at a fixed thread count — the toggle exists for
    /// A/B benches and the conformance tests).
    pub fn set_plan(&mut self, on: bool) {
        self.plan_on = on;
    }

    /// Whether the planned executors drive the sweeps.
    pub fn plan_enabled(&self) -> bool {
        self.plan_on
    }

    /// Enable/disable the elementwise layer-fusion plan at runtime
    /// (overrides `PHAST_FUSE_LAYERS`; both settings are bitwise-equal,
    /// the toggle exists for A/B benches and the equivalence tests).
    pub fn set_layer_fusion(&mut self, on: bool) {
        self.layer_fusion = on;
    }

    /// Force every layer's backward-fusion mode (fused gradient region vs
    /// dispatch-then-serial-merge reference), overriding `PHAST_FUSE_BWD`.
    /// Both modes are bitwise-equal at a fixed thread count; the toggle
    /// exists for A/B benches and the equivalence tests.
    pub fn set_backward_fusion(&mut self, on: bool) {
        for l in &mut self.layers {
            l.set_backward_fusion(on);
        }
    }

    /// Force every layer's backward operand-packing mode (persistent
    /// im2col panel capture vs per-call recompute+pack), overriding
    /// `PHAST_CONV_PACK`.  Both modes are bitwise-equal.
    pub fn set_backward_packing(&mut self, on: bool) {
        for l in &mut self.layers {
            l.set_backward_packing(on);
        }
    }

    /// The fusion plan as (producer, fused ReLU) layer-index pairs.
    pub fn fusion_plan(&self) -> Vec<(usize, usize)> {
        self.fused_relu
            .iter()
            .enumerate()
            .filter_map(|(li, r)| r.map(|ri| (li, ri)))
            .collect()
    }

    pub fn config(&self) -> &NetConfig {
        &self.config
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, i: usize) -> &dyn Layer {
        self.layers[i].as_ref()
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut (dyn Layer + '_) {
        self.layers[i].as_mut()
    }

    pub fn layer_by_name_mut(&mut self, name: &str) -> Option<&mut Box<dyn Layer>> {
        self.layers.iter_mut().find(|l| l.name() == name)
    }

    /// Blob by name (activations, labels, loss, accuracy).
    pub fn blob(&self, name: &str) -> Option<&Blob> {
        self.blob_index.get(name).map(|&i| &self.blobs[i])
    }

    pub fn blob_mut(&mut self, name: &str) -> Option<&mut Blob> {
        let i = *self.blob_index.get(name)?;
        Some(&mut self.blobs[i])
    }

    pub fn blob_names(&self) -> impl Iterator<Item = &str> {
        self.blobs.iter().map(|b| b.name())
    }

    /// Run one layer's native forward against the blob store.
    pub fn forward_layer(&mut self, li: usize) -> Result<()> {
        // Inference mode (see `forward_infer`): the data pipeline is
        // skipped wholesale so the request tensors written into its top
        // blobs survive the sweep.  Data is never a fusion producer, so
        // guarding this single funnel covers both executors.
        if self.infer_skip_data && self.layers[li].ltype() == LayerType::Data {
            return Ok(());
        }
        // Move tops out to satisfy the borrow checker (no in-place layers).
        let tids = self.top_ids[li].clone();
        let mut tops: Vec<Tensor> = tids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].data_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let bottoms: Vec<&Tensor> =
            self.bottom_ids[li].iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].forward(&bottoms, &mut tops);
        for (&i, t) in tids.iter().zip(tops) {
            *self.blobs[i].data_mut() = t;
        }
        result.with_context(|| format!("forward of layer '{}'", self.layers[li].name()))
    }

    /// Run one layer's native backward against the blob store.
    pub fn backward_layer(&mut self, li: usize) -> Result<()> {
        if !self.layers[li].needs_backward() {
            return Ok(());
        }
        let bids = self.bottom_ids[li].clone();
        let mut bottom_diffs: Vec<Tensor> = bids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].diff_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let top_diffs: Vec<&Tensor> =
            self.top_ids[li].iter().map(|&i| self.blobs[i].diff()).collect();
        let bottom_datas: Vec<&Tensor> =
            bids.iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].backward(&top_diffs, &bottom_datas, &mut bottom_diffs);
        for (&i, t) in bids.iter().zip(bottom_diffs) {
            *self.blobs[i].diff_mut() = t;
        }
        result.with_context(|| format!("backward of layer '{}'", self.layers[li].name()))
    }

    /// Run layer `li`'s forward with the adjacent ReLU layer `ri` fused
    /// into the same parallel region (see the fusion plan in
    /// [`Net::from_config`]).  Returns false when the layer does not
    /// support fusion and the caller must fall back to separate passes.
    fn forward_layer_fused(&mut self, li: usize, ri: usize) -> Result<bool> {
        let slope = self.layers[ri].config().negative_slope;
        let tids = self.top_ids[li].clone();
        let rid = self.top_ids[ri][0];
        let mut tops: Vec<Tensor> = tids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].data_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let mut act =
            std::mem::replace(self.blobs[rid].data_mut(), Tensor::zeros(Shape::new(&[0])));
        let bottoms: Vec<&Tensor> =
            self.bottom_ids[li].iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].forward_fused_relu(&bottoms, &mut tops, &mut act, slope);
        for (&i, t) in tids.iter().zip(tops) {
            *self.blobs[i].data_mut() = t;
        }
        *self.blobs[rid].data_mut() = act;
        result.with_context(|| format!("fused forward of layer '{}'", self.layers[li].name()))
    }

    /// Full forward sweep (records per-layer timings).  Returns the loss if
    /// a loss layer is present.  Fusion-planned (producer, ReLU) pairs run
    /// as one region; the ReLU's timer is recorded as zero so per-layer
    /// reports keep a row per configured layer.  With the plan enabled
    /// the sweep walks the plan's forward schedule; the legacy while-loop
    /// below is the `PHAST_PLAN=off` reference — both paths are
    /// bitwise-equal (the plan's R1 pairs *are* the `fused_relu` table).
    pub fn forward(&mut self) -> Result<Option<f32>> {
        if self.plan_on {
            return self.forward_planned();
        }
        let mut loss = None;
        let mut li = 0;
        while li < self.layers.len() {
            let plan = if self.layer_fusion { self.fused_relu[li] } else { None };
            let t0 = Instant::now();
            let mut fused_ri = None;
            if let Some(ri) = plan {
                if self.forward_layer_fused(li, ri)? {
                    fused_ri = Some(ri);
                }
            }
            if fused_ri.is_none() {
                self.forward_layer(li)?;
            }
            let name = format!("fwd.{}", self.layers[li].name());
            self.metrics.record(&name, t0.elapsed());
            if self.layers[li].is_loss() {
                let tid = self.top_ids[li][0];
                loss = Some(self.blobs[tid].data().as_slice()[0]);
            }
            if let Some(ri) = fused_ri {
                let rname = format!("fwd.{}", self.layers[ri].name());
                self.metrics.record(&rname, std::time::Duration::ZERO);
                li = ri + 1;
            } else {
                li += 1;
            }
        }
        Ok(loss)
    }

    /// Serving forward: one full forward sweep with every Data layer
    /// skipped, so input blobs written by the caller (the serving
    /// engine's request batch) are the sweep's actual inputs.  Everything
    /// else — executor choice, fusion, per-layer timing — is identical to
    /// [`Net::forward`], which keeps served outputs bitwise-comparable to
    /// training-time forwards over the same input blob contents.  Label
    /// blobs keep whatever they hold (zeros at construction), so loss and
    /// accuracy tops are well-defined but meaningless in this mode.
    pub fn forward_infer(&mut self) -> Result<Option<f32>> {
        self.infer_skip_data = true;
        let result = self.forward();
        self.infer_skip_data = false;
        result
    }

    /// Planned forward: walk the plan's forward schedule.  A `FusedRelu`
    /// node decays to two per-layer steps when layer fusion is toggled
    /// off or the producer declines to fuse, so every knob combination
    /// stays bitwise-comparable to the legacy executor.
    fn forward_planned(&mut self) -> Result<Option<f32>> {
        let mut loss = None;
        let steps = self.plan.fwd.clone();
        for step in steps {
            match step {
                plan::FwdStep::FusedRelu(li, ri) if self.layer_fusion => {
                    let t0 = Instant::now();
                    let fused = self.forward_layer_fused(li, ri)?;
                    if !fused {
                        self.forward_layer(li)?;
                    }
                    let name = format!("fwd.{}", self.layers[li].name());
                    self.metrics.record(&name, t0.elapsed());
                    loss = self.loss_of(li).or(loss);
                    if fused {
                        let rname = format!("fwd.{}", self.layers[ri].name());
                        self.metrics.record(&rname, std::time::Duration::ZERO);
                    } else {
                        let t0 = Instant::now();
                        self.forward_layer(ri)?;
                        let rname = format!("fwd.{}", self.layers[ri].name());
                        self.metrics.record(&rname, t0.elapsed());
                        loss = self.loss_of(ri).or(loss);
                    }
                }
                plan::FwdStep::FusedRelu(li, ri) => {
                    for l in [li, ri] {
                        let t0 = Instant::now();
                        self.forward_layer(l)?;
                        let name = format!("fwd.{}", self.layers[l].name());
                        self.metrics.record(&name, t0.elapsed());
                        loss = self.loss_of(l).or(loss);
                    }
                }
                plan::FwdStep::Layer(li) => {
                    let t0 = Instant::now();
                    self.forward_layer(li)?;
                    let name = format!("fwd.{}", self.layers[li].name());
                    self.metrics.record(&name, t0.elapsed());
                    loss = self.loss_of(li).or(loss);
                }
            }
        }
        Ok(loss)
    }

    /// The loss value layer `li` just produced, if it is a loss layer.
    fn loss_of(&self, li: usize) -> Option<f32> {
        if self.layers[li].is_loss() {
            let tid = self.top_ids[li][0];
            Some(self.blobs[tid].data().as_slice()[0])
        } else {
            None
        }
    }

    /// Full backward sweep (loss layers seed their own gradients).  With
    /// the plan enabled the sweep walks the plan's backward schedule
    /// (fused pool→conv nodes run as one region); the plain reverse loop
    /// is the `PHAST_PLAN=off` reference.
    pub fn backward(&mut self) -> Result<()> {
        if self.plan_on {
            return self.backward_planned();
        }
        for li in (0..self.layers.len()).rev() {
            let t0 = Instant::now();
            self.backward_layer(li)?;
            let name = format!("bwd.{}", self.layers[li].name());
            self.metrics.record(&name, t0.elapsed());
        }
        Ok(())
    }

    /// Planned backward: walk the plan's backward schedule.  A
    /// `FusedPoolConv` node decays to the two per-layer steps when the
    /// conv declines the fused region (single worker, or the
    /// backward-fusion knob off) — keeping every knob × thread-count
    /// combination bitwise-equal to the legacy executor.  When the node
    /// does fuse, the pool's timer is recorded as zero (mirroring the
    /// forward fusion convention) so per-layer reports keep their rows.
    fn backward_planned(&mut self) -> Result<()> {
        let steps = self.plan.bwd.clone();
        for step in steps {
            match step {
                plan::BwdStep::Layer(li) => {
                    let t0 = Instant::now();
                    self.backward_layer(li)?;
                    let name = format!("bwd.{}", self.layers[li].name());
                    self.metrics.record(&name, t0.elapsed());
                }
                plan::BwdStep::FusedPoolConv { conv, pool } => {
                    let t0 = Instant::now();
                    let fused = self.backward_fused_pool_conv(conv, pool)?;
                    if fused {
                        let pname = format!("bwd.{}", self.layers[pool].name());
                        self.metrics.record(&pname, std::time::Duration::ZERO);
                        let cname = format!("bwd.{}", self.layers[conv].name());
                        self.metrics.record(&cname, t0.elapsed());
                    } else {
                        for l in [pool, conv] {
                            let t0 = Instant::now();
                            self.backward_layer(l)?;
                            let name = format!("bwd.{}", self.layers[l].name());
                            self.metrics.record(&name, t0.elapsed());
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Run the plan's fused pool→conv backward node: pool scatter, conv
    /// gradient work, and partial merge in one three-stage region, with
    /// per-worker scratch carved from the plan's shared arena slot.
    /// Returns false when the node must decay to separate per-layer
    /// steps (see `ConvLayer::backward_fused_pool`).
    fn backward_fused_pool_conv(&mut self, ci: usize, pi: usize) -> Result<bool> {
        let slot = match self.plan.bwd_arena_slot(ci) {
            Some(s) => s,
            None => return Ok(false),
        };
        let mid_id = self.top_ids[ci][0]; // conv top = pool bottom
        let ptop_id = self.top_ids[pi][0];
        let cb_id = self.bottom_ids[ci][0];
        let mut mid_diff =
            std::mem::replace(self.blobs[mid_id].diff_mut(), Tensor::zeros(Shape::new(&[0])));
        let mut dx =
            std::mem::replace(self.blobs[cb_id].diff_mut(), Tensor::zeros(Shape::new(&[0])));
        let result = {
            let blobs = &self.blobs;
            let layers = &mut self.layers;
            let arena = &mut self.arena;
            let dy_pool = blobs[ptop_id].diff().as_slice();
            let x = blobs[cb_id].data().as_slice();
            let (head, tail) = layers.split_at_mut(pi);
            let conv = head[ci].as_any_mut().and_then(|a| a.downcast_mut::<ConvLayer>());
            let pool = tail[0].as_any().and_then(|a| a.downcast_ref::<PoolLayer>());
            match (conv, pool) {
                (Some(conv), Some(pool)) => {
                    let pg = pool.bwd_ctx();
                    conv.backward_fused_pool(
                        &pg,
                        dy_pool,
                        mid_diff.as_mut_slice(),
                        x,
                        dx.as_mut_slice(),
                        arena.slot_vec_mut(slot),
                    )
                }
                // A non-conv/pool pair can only mean the plan and the
                // layer vec disagree; decay to the per-layer steps.
                _ => Ok(false),
            }
        };
        *self.blobs[mid_id].diff_mut() = mid_diff;
        *self.blobs[cb_id].diff_mut() = dx;
        result.with_context(|| {
            format!(
                "fused backward of '{}'+'{}'",
                self.layers[pi].name(),
                self.layers[ci].name()
            )
        })
    }

    /// Zero all parameter gradients (start of an iteration).
    pub fn zero_param_diffs(&mut self) {
        for l in &mut self.layers {
            for p in l.params_mut() {
                p.zero_diff();
            }
        }
    }

    /// All learnable parameter blobs, in layer order.
    pub fn params_mut(&mut self) -> Vec<&mut Blob> {
        self.layers
            .iter_mut()
            .flat_map(|l| l.params_mut().iter_mut())
            .collect()
    }

    pub fn params(&self) -> Vec<&Blob> {
        self.layers.iter().flat_map(|l| l.params().iter()).collect()
    }

    /// Total learnable parameter count.
    pub fn num_params(&self) -> usize {
        self.params().iter().map(|b| b.count()).sum()
    }

    /// Indices of layers of a given type.
    pub fn layers_of_type(&self, t: LayerType) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].ltype() == t)
            .collect()
    }

    /// Data-pipeline cursors `(epoch, position)` of every restorable
    /// data layer, in layer order — recorded in snapshots so resume can
    /// replay the exact batch sequence.
    pub fn data_cursors(&self) -> Vec<(usize, usize)> {
        self.layers.iter().filter_map(|l| l.data_cursor()).collect()
    }

    /// Seek the restorable data layers to `cursors` (one entry per
    /// cursor-bearing layer, in the same order [`Net::data_cursors`]
    /// reports them).
    pub fn seek_data_cursors(&mut self, cursors: &[(usize, usize)]) -> Result<()> {
        let mut it = cursors.iter();
        for l in &mut self.layers {
            if l.data_cursor().is_some() {
                let &(epoch, pos) = it
                    .next()
                    .context("snapshot has fewer data cursors than the net has data layers")?;
                l.seek_data(epoch, pos);
            }
        }
        if it.next().is_some() {
            bail!("snapshot has more data cursors than the net has data layers");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::presets;

    fn lenet() -> Net {
        let cfg = NetConfig::from_text(presets::LENET_MNIST).unwrap();
        Net::from_config(cfg, 1).unwrap()
    }

    #[test]
    fn setup_wires_blobs() {
        let net = lenet();
        assert_eq!(net.num_layers(), 10);
        assert_eq!(net.blob("conv1").unwrap().shape().dims(), &[64, 20, 24, 24]);
        assert_eq!(net.blob("pool2").unwrap().shape().dims(), &[64, 50, 4, 4]);
        assert_eq!(net.blob("ip2").unwrap().shape().dims(), &[64, 10]);
        assert_eq!(net.blob("loss").unwrap().shape().dims(), &[1]);
        // LeNet's canonical parameter count
        assert_eq!(net.num_params(), 20 * 25 + 20 + 50 * 20 * 25 + 50
                   + 500 * 800 + 500 + 10 * 500 + 10);
    }

    #[test]
    fn forward_produces_finite_loss() {
        let mut net = lenet();
        let loss = net.forward().unwrap().expect("has loss layer");
        assert!(loss.is_finite());
        // Untrained on 10 classes: loss near ln(10)
        assert!((1.5..4.0).contains(&loss), "loss {loss}");
        let acc = net.blob("accuracy").unwrap().data().as_slice()[0];
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn backward_fills_param_grads() {
        let mut net = lenet();
        net.zero_param_diffs();
        net.forward().unwrap();
        net.backward().unwrap();
        for p in net.params() {
            assert!(p.diff().l2() > 0.0, "zero grad for {}", p.name());
        }
    }

    #[test]
    fn forward_deterministic_given_seed() {
        let mut a = lenet();
        let mut b = lenet();
        let la = a.forward().unwrap().unwrap();
        let lb = b.forward().unwrap().unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn cifar_net_builds() {
        let cfg = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        let mut net = Net::from_config(cfg, 2).unwrap();
        assert_eq!(net.blob("pool3").unwrap().shape().dims(), &[64, 64, 4, 4]);
        let loss = net.forward().unwrap().unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn fusion_plan_detects_ip_relu_and_conv_relu_pairs() {
        // LeNet: ip1 (idx 5) is followed by relu1 (idx 6) consuming "ip1".
        let net = lenet();
        assert_eq!(net.fusion_plan(), vec![(5, 6)]);
        // CIFAR-quick: conv2→relu2 and conv3→relu3 are adjacent; relu1
        // follows a Pooling layer, which never fuses.
        let cfg = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        let net = Net::from_config(cfg, 2).unwrap();
        let plan = net.fusion_plan();
        assert_eq!(plan.len(), 2, "plan: {plan:?}");
        for (li, ri) in plan {
            assert_eq!(net.layer(li).ltype(), LayerType::Convolution);
            assert_eq!(net.layer(ri).ltype(), LayerType::ReLU);
            assert_eq!(ri, li + 1);
        }
    }

    #[test]
    fn fused_forward_bitwise_equals_unfused() {
        for preset in [presets::LENET_MNIST, presets::CIFAR10_QUICK] {
            let cfg = NetConfig::from_text(preset).unwrap();
            let mut fused = Net::from_config(cfg.clone(), 7).unwrap();
            fused.set_layer_fusion(true);
            let mut plain = Net::from_config(cfg, 7).unwrap();
            plain.set_layer_fusion(false);
            let lf = fused.forward().unwrap().unwrap();
            let lp = plain.forward().unwrap().unwrap();
            assert_eq!(lf, lp, "loss diverged under layer fusion");
            let names: Vec<String> = fused.blob_names().map(str::to_string).collect();
            for name in names {
                let a = fused.blob(&name).unwrap().data().as_slice();
                let b = plain.blob(&name).unwrap().data().as_slice();
                assert_eq!(a, b, "blob '{name}' diverged under layer fusion");
            }
            // Backward through the fused-forward net must also agree (the
            // ReLU still runs its own backward).
            fused.zero_param_diffs();
            plain.zero_param_diffs();
            fused.backward().unwrap();
            plain.backward().unwrap();
            let nparams = fused.params().len();
            for pi in 0..nparams {
                let a = fused.params()[pi].diff().as_slice().to_vec();
                let b = plain.params()[pi].diff().as_slice().to_vec();
                assert_eq!(a, b, "param grad {pi} diverged under layer fusion");
            }
        }
    }

    #[test]
    fn rejects_undefined_bottom() {
        let src = r#"
            name: "bad"
            layer { name: "ip" type: "InnerProduct" bottom: "ghost" top: "y"
                    inner_product_param { num_output: 4 } }
        "#;
        let cfg = NetConfig::from_text(src).unwrap();
        assert!(Net::from_config(cfg, 1).is_err());
    }
}
