//! The Net: a DAG of layers over a named blob store, with forward/backward
//! sweeps and per-layer timing — Caffe's `Net<float>`, Fig. 1 of the paper.

use std::collections::HashMap;
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::layers::{create_layer, Layer};
use crate::metrics::Metrics;
use crate::proto::{LayerType, NetConfig};
use crate::tensor::{Blob, Shape, Tensor};

/// `PHAST_FUSE_LAYERS`, parsed once: `0` disables the elementwise layer
/// fusion plan (bias-add → activation in one region); anything else, or
/// unset, enables it.  [`Net::set_layer_fusion`] overrides per net.
fn layer_fusion_default() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("PHAST_FUSE_LAYERS").map(|v| v.trim() != "0").unwrap_or(true))
}

/// A fully set-up network.
pub struct Net {
    config: NetConfig,
    layers: Vec<Box<dyn Layer>>,
    blobs: Vec<Blob>,
    blob_index: HashMap<String, usize>,
    /// Per-layer bottom/top blob indices.
    bottom_ids: Vec<Vec<usize>>,
    top_ids: Vec<Vec<usize>>,
    /// Fusion plan: for layer `li`, the index of the adjacent ReLU layer
    /// whose forward is fused into `li`'s parallel region (the paper's
    /// §4.3 "no artificial interruption across the layers", at the native
    /// level).  Built once in [`Net::from_config`].
    fused_relu: Vec<Option<usize>>,
    /// Runtime toggle for the plan (`PHAST_FUSE_LAYERS`, default on).
    layer_fusion: bool,
    pub metrics: Metrics,
}

impl Net {
    /// Build + setup from a parsed config.  `seed` drives weight init and
    /// the data pipeline.
    pub fn from_config(config: NetConfig, seed: u64) -> Result<Net> {
        let mut layers = Vec::new();
        let mut blobs: Vec<Blob> = Vec::new();
        let mut blob_index: HashMap<String, usize> = HashMap::new();
        let mut bottom_ids = Vec::new();
        let mut top_ids = Vec::new();

        for cfg in &config.layers {
            let mut layer = create_layer(cfg, seed)?;
            // Resolve bottoms (must already exist).
            let mut bids = Vec::new();
            let mut bshapes = Vec::new();
            for b in &cfg.bottoms {
                let id = *blob_index
                    .get(b)
                    .with_context(|| format!("layer '{}' bottom '{}' undefined", cfg.name, b))?;
                bids.push(id);
                bshapes.push(blobs[id].shape().clone());
            }
            // In-place layers are unsupported (kept out-of-place by design).
            for t in &cfg.tops {
                if cfg.bottoms.contains(t) {
                    bail!("in-place layer '{}' not supported; use distinct top names", cfg.name);
                }
            }
            let tshapes = layer
                .setup(&bshapes)
                .with_context(|| format!("setting up layer '{}'", cfg.name))?;
            if tshapes.len() != cfg.tops.len() {
                bail!(
                    "layer '{}' produced {} tops, config names {}",
                    cfg.name,
                    tshapes.len(),
                    cfg.tops.len()
                );
            }
            let mut tids = Vec::new();
            for (t, shape) in cfg.tops.iter().zip(tshapes) {
                if blob_index.contains_key(t) {
                    bail!("duplicate top blob '{}'", t);
                }
                let id = blobs.len();
                blobs.push(Blob::new(t.clone(), shape));
                blob_index.insert(t.clone(), id);
                tids.push(id);
            }
            bottom_ids.push(bids);
            top_ids.push(tids);
            layers.push(layer);
        }
        // Fusion plan: a Convolution/InnerProduct layer immediately
        // followed by a ReLU that consumes exactly its single top gets the
        // activation computed inside its own forward region (bias-add →
        // activation, one dispatch).  The ReLU's top blob is still fully
        // written, so downstream consumers and the backward sweep are
        // unaffected, and results are bitwise-equal to the unfused pass.
        let mut fused_relu: Vec<Option<usize>> = vec![None; layers.len()];
        for li in 0..layers.len().saturating_sub(1) {
            let ri = li + 1;
            if !matches!(layers[li].ltype(), LayerType::Convolution | LayerType::InnerProduct) {
                continue;
            }
            if layers[ri].ltype() != LayerType::ReLU {
                continue;
            }
            if config.layers[li].tops.len() == 1
                && config.layers[ri].bottoms.len() == 1
                && config.layers[ri].tops.len() == 1
                && config.layers[ri].bottoms[0] == config.layers[li].tops[0]
            {
                fused_relu[li] = Some(ri);
            }
        }
        Ok(Net {
            config,
            layers,
            blobs,
            blob_index,
            bottom_ids,
            top_ids,
            fused_relu,
            layer_fusion: layer_fusion_default(),
            metrics: Metrics::new(),
        })
    }

    /// Enable/disable the elementwise layer-fusion plan at runtime
    /// (overrides `PHAST_FUSE_LAYERS`; both settings are bitwise-equal,
    /// the toggle exists for A/B benches and the equivalence tests).
    pub fn set_layer_fusion(&mut self, on: bool) {
        self.layer_fusion = on;
    }

    /// Force every layer's backward-fusion mode (fused gradient region vs
    /// dispatch-then-serial-merge reference), overriding `PHAST_FUSE_BWD`.
    /// Both modes are bitwise-equal at a fixed thread count; the toggle
    /// exists for A/B benches and the equivalence tests.
    pub fn set_backward_fusion(&mut self, on: bool) {
        for l in &mut self.layers {
            l.set_backward_fusion(on);
        }
    }

    /// Force every layer's backward operand-packing mode (persistent
    /// im2col panel capture vs per-call recompute+pack), overriding
    /// `PHAST_CONV_PACK`.  Both modes are bitwise-equal.
    pub fn set_backward_packing(&mut self, on: bool) {
        for l in &mut self.layers {
            l.set_backward_packing(on);
        }
    }

    /// The fusion plan as (producer, fused ReLU) layer-index pairs.
    pub fn fusion_plan(&self) -> Vec<(usize, usize)> {
        self.fused_relu
            .iter()
            .enumerate()
            .filter_map(|(li, r)| r.map(|ri| (li, ri)))
            .collect()
    }

    pub fn config(&self) -> &NetConfig {
        &self.config
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, i: usize) -> &dyn Layer {
        self.layers[i].as_ref()
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut (dyn Layer + '_) {
        self.layers[i].as_mut()
    }

    pub fn layer_by_name_mut(&mut self, name: &str) -> Option<&mut Box<dyn Layer>> {
        self.layers.iter_mut().find(|l| l.name() == name)
    }

    /// Blob by name (activations, labels, loss, accuracy).
    pub fn blob(&self, name: &str) -> Option<&Blob> {
        self.blob_index.get(name).map(|&i| &self.blobs[i])
    }

    pub fn blob_mut(&mut self, name: &str) -> Option<&mut Blob> {
        let i = *self.blob_index.get(name)?;
        Some(&mut self.blobs[i])
    }

    pub fn blob_names(&self) -> impl Iterator<Item = &str> {
        self.blobs.iter().map(|b| b.name())
    }

    /// Run one layer's native forward against the blob store.
    pub fn forward_layer(&mut self, li: usize) -> Result<()> {
        // Move tops out to satisfy the borrow checker (no in-place layers).
        let tids = self.top_ids[li].clone();
        let mut tops: Vec<Tensor> = tids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].data_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let bottoms: Vec<&Tensor> =
            self.bottom_ids[li].iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].forward(&bottoms, &mut tops);
        for (&i, t) in tids.iter().zip(tops) {
            *self.blobs[i].data_mut() = t;
        }
        result.with_context(|| format!("forward of layer '{}'", self.layers[li].name()))
    }

    /// Run one layer's native backward against the blob store.
    pub fn backward_layer(&mut self, li: usize) -> Result<()> {
        if !self.layers[li].needs_backward() {
            return Ok(());
        }
        let bids = self.bottom_ids[li].clone();
        let mut bottom_diffs: Vec<Tensor> = bids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].diff_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let top_diffs: Vec<&Tensor> =
            self.top_ids[li].iter().map(|&i| self.blobs[i].diff()).collect();
        let bottom_datas: Vec<&Tensor> =
            bids.iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].backward(&top_diffs, &bottom_datas, &mut bottom_diffs);
        for (&i, t) in bids.iter().zip(bottom_diffs) {
            *self.blobs[i].diff_mut() = t;
        }
        result.with_context(|| format!("backward of layer '{}'", self.layers[li].name()))
    }

    /// Run layer `li`'s forward with the adjacent ReLU layer `ri` fused
    /// into the same parallel region (see the fusion plan in
    /// [`Net::from_config`]).  Returns false when the layer does not
    /// support fusion and the caller must fall back to separate passes.
    fn forward_layer_fused(&mut self, li: usize, ri: usize) -> Result<bool> {
        let slope = self.layers[ri].config().negative_slope;
        let tids = self.top_ids[li].clone();
        let rid = self.top_ids[ri][0];
        let mut tops: Vec<Tensor> = tids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].data_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let mut act =
            std::mem::replace(self.blobs[rid].data_mut(), Tensor::zeros(Shape::new(&[0])));
        let bottoms: Vec<&Tensor> =
            self.bottom_ids[li].iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].forward_fused_relu(&bottoms, &mut tops, &mut act, slope);
        for (&i, t) in tids.iter().zip(tops) {
            *self.blobs[i].data_mut() = t;
        }
        *self.blobs[rid].data_mut() = act;
        result.with_context(|| format!("fused forward of layer '{}'", self.layers[li].name()))
    }

    /// Full forward sweep (records per-layer timings).  Returns the loss if
    /// a loss layer is present.  Fusion-planned (producer, ReLU) pairs run
    /// as one region; the ReLU's timer is recorded as zero so per-layer
    /// reports keep a row per configured layer.
    pub fn forward(&mut self) -> Result<Option<f32>> {
        let mut loss = None;
        let mut li = 0;
        while li < self.layers.len() {
            let plan = if self.layer_fusion { self.fused_relu[li] } else { None };
            let t0 = Instant::now();
            let mut fused_ri = None;
            if let Some(ri) = plan {
                if self.forward_layer_fused(li, ri)? {
                    fused_ri = Some(ri);
                }
            }
            if fused_ri.is_none() {
                self.forward_layer(li)?;
            }
            let name = format!("fwd.{}", self.layers[li].name());
            self.metrics.record(&name, t0.elapsed());
            if self.layers[li].is_loss() {
                let tid = self.top_ids[li][0];
                loss = Some(self.blobs[tid].data().as_slice()[0]);
            }
            if let Some(ri) = fused_ri {
                let rname = format!("fwd.{}", self.layers[ri].name());
                self.metrics.record(&rname, std::time::Duration::ZERO);
                li = ri + 1;
            } else {
                li += 1;
            }
        }
        Ok(loss)
    }

    /// Full backward sweep (loss layers seed their own gradients).
    pub fn backward(&mut self) -> Result<()> {
        for li in (0..self.layers.len()).rev() {
            let t0 = Instant::now();
            self.backward_layer(li)?;
            let name = format!("bwd.{}", self.layers[li].name());
            self.metrics.record(&name, t0.elapsed());
        }
        Ok(())
    }

    /// Zero all parameter gradients (start of an iteration).
    pub fn zero_param_diffs(&mut self) {
        for l in &mut self.layers {
            for p in l.params_mut() {
                p.zero_diff();
            }
        }
    }

    /// All learnable parameter blobs, in layer order.
    pub fn params_mut(&mut self) -> Vec<&mut Blob> {
        self.layers
            .iter_mut()
            .flat_map(|l| l.params_mut().iter_mut())
            .collect()
    }

    pub fn params(&self) -> Vec<&Blob> {
        self.layers.iter().flat_map(|l| l.params().iter()).collect()
    }

    /// Total learnable parameter count.
    pub fn num_params(&self) -> usize {
        self.params().iter().map(|b| b.count()).sum()
    }

    /// Indices of layers of a given type.
    pub fn layers_of_type(&self, t: LayerType) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].ltype() == t)
            .collect()
    }

    /// Data-pipeline cursors `(epoch, position)` of every restorable
    /// data layer, in layer order — recorded in snapshots so resume can
    /// replay the exact batch sequence.
    pub fn data_cursors(&self) -> Vec<(usize, usize)> {
        self.layers.iter().filter_map(|l| l.data_cursor()).collect()
    }

    /// Seek the restorable data layers to `cursors` (one entry per
    /// cursor-bearing layer, in the same order [`Net::data_cursors`]
    /// reports them).
    pub fn seek_data_cursors(&mut self, cursors: &[(usize, usize)]) -> Result<()> {
        let mut it = cursors.iter();
        for l in &mut self.layers {
            if l.data_cursor().is_some() {
                let &(epoch, pos) = it
                    .next()
                    .context("snapshot has fewer data cursors than the net has data layers")?;
                l.seek_data(epoch, pos);
            }
        }
        if it.next().is_some() {
            bail!("snapshot has more data cursors than the net has data layers");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::presets;

    fn lenet() -> Net {
        let cfg = NetConfig::from_text(presets::LENET_MNIST).unwrap();
        Net::from_config(cfg, 1).unwrap()
    }

    #[test]
    fn setup_wires_blobs() {
        let net = lenet();
        assert_eq!(net.num_layers(), 10);
        assert_eq!(net.blob("conv1").unwrap().shape().dims(), &[64, 20, 24, 24]);
        assert_eq!(net.blob("pool2").unwrap().shape().dims(), &[64, 50, 4, 4]);
        assert_eq!(net.blob("ip2").unwrap().shape().dims(), &[64, 10]);
        assert_eq!(net.blob("loss").unwrap().shape().dims(), &[1]);
        // LeNet's canonical parameter count
        assert_eq!(net.num_params(), 20 * 25 + 20 + 50 * 20 * 25 + 50
                   + 500 * 800 + 500 + 10 * 500 + 10);
    }

    #[test]
    fn forward_produces_finite_loss() {
        let mut net = lenet();
        let loss = net.forward().unwrap().expect("has loss layer");
        assert!(loss.is_finite());
        // Untrained on 10 classes: loss near ln(10)
        assert!((1.5..4.0).contains(&loss), "loss {loss}");
        let acc = net.blob("accuracy").unwrap().data().as_slice()[0];
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn backward_fills_param_grads() {
        let mut net = lenet();
        net.zero_param_diffs();
        net.forward().unwrap();
        net.backward().unwrap();
        for p in net.params() {
            assert!(p.diff().l2() > 0.0, "zero grad for {}", p.name());
        }
    }

    #[test]
    fn forward_deterministic_given_seed() {
        let mut a = lenet();
        let mut b = lenet();
        let la = a.forward().unwrap().unwrap();
        let lb = b.forward().unwrap().unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn cifar_net_builds() {
        let cfg = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        let mut net = Net::from_config(cfg, 2).unwrap();
        assert_eq!(net.blob("pool3").unwrap().shape().dims(), &[64, 64, 4, 4]);
        let loss = net.forward().unwrap().unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn fusion_plan_detects_ip_relu_and_conv_relu_pairs() {
        // LeNet: ip1 (idx 5) is followed by relu1 (idx 6) consuming "ip1".
        let net = lenet();
        assert_eq!(net.fusion_plan(), vec![(5, 6)]);
        // CIFAR-quick: conv2→relu2 and conv3→relu3 are adjacent; relu1
        // follows a Pooling layer, which never fuses.
        let cfg = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        let net = Net::from_config(cfg, 2).unwrap();
        let plan = net.fusion_plan();
        assert_eq!(plan.len(), 2, "plan: {plan:?}");
        for (li, ri) in plan {
            assert_eq!(net.layer(li).ltype(), LayerType::Convolution);
            assert_eq!(net.layer(ri).ltype(), LayerType::ReLU);
            assert_eq!(ri, li + 1);
        }
    }

    #[test]
    fn fused_forward_bitwise_equals_unfused() {
        for preset in [presets::LENET_MNIST, presets::CIFAR10_QUICK] {
            let cfg = NetConfig::from_text(preset).unwrap();
            let mut fused = Net::from_config(cfg.clone(), 7).unwrap();
            fused.set_layer_fusion(true);
            let mut plain = Net::from_config(cfg, 7).unwrap();
            plain.set_layer_fusion(false);
            let lf = fused.forward().unwrap().unwrap();
            let lp = plain.forward().unwrap().unwrap();
            assert_eq!(lf, lp, "loss diverged under layer fusion");
            let names: Vec<String> = fused.blob_names().map(str::to_string).collect();
            for name in names {
                let a = fused.blob(&name).unwrap().data().as_slice();
                let b = plain.blob(&name).unwrap().data().as_slice();
                assert_eq!(a, b, "blob '{name}' diverged under layer fusion");
            }
            // Backward through the fused-forward net must also agree (the
            // ReLU still runs its own backward).
            fused.zero_param_diffs();
            plain.zero_param_diffs();
            fused.backward().unwrap();
            plain.backward().unwrap();
            let nparams = fused.params().len();
            for pi in 0..nparams {
                let a = fused.params()[pi].diff().as_slice().to_vec();
                let b = plain.params()[pi].diff().as_slice().to_vec();
                assert_eq!(a, b, "param grad {pi} diverged under layer fusion");
            }
        }
    }

    #[test]
    fn rejects_undefined_bottom() {
        let src = r#"
            name: "bad"
            layer { name: "ip" type: "InnerProduct" bottom: "ghost" top: "y"
                    inner_product_param { num_output: 4 } }
        "#;
        let cfg = NetConfig::from_text(src).unwrap();
        assert!(Net::from_config(cfg, 1).is_err());
    }
}
