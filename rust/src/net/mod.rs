//! The Net: a DAG of layers over a named blob store, with forward/backward
//! sweeps and per-layer timing — Caffe's `Net<float>`, Fig. 1 of the paper.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::layers::{create_layer, Layer};
use crate::metrics::Metrics;
use crate::proto::{LayerType, NetConfig};
use crate::tensor::{Blob, Shape, Tensor};

/// A fully set-up network.
pub struct Net {
    config: NetConfig,
    layers: Vec<Box<dyn Layer>>,
    blobs: Vec<Blob>,
    blob_index: HashMap<String, usize>,
    /// Per-layer bottom/top blob indices.
    bottom_ids: Vec<Vec<usize>>,
    top_ids: Vec<Vec<usize>>,
    pub metrics: Metrics,
}

impl Net {
    /// Build + setup from a parsed config.  `seed` drives weight init and
    /// the data pipeline.
    pub fn from_config(config: NetConfig, seed: u64) -> Result<Net> {
        let mut layers = Vec::new();
        let mut blobs: Vec<Blob> = Vec::new();
        let mut blob_index: HashMap<String, usize> = HashMap::new();
        let mut bottom_ids = Vec::new();
        let mut top_ids = Vec::new();

        for cfg in &config.layers {
            let mut layer = create_layer(cfg, seed)?;
            // Resolve bottoms (must already exist).
            let mut bids = Vec::new();
            let mut bshapes = Vec::new();
            for b in &cfg.bottoms {
                let id = *blob_index
                    .get(b)
                    .with_context(|| format!("layer '{}' bottom '{}' undefined", cfg.name, b))?;
                bids.push(id);
                bshapes.push(blobs[id].shape().clone());
            }
            // In-place layers are unsupported (kept out-of-place by design).
            for t in &cfg.tops {
                if cfg.bottoms.contains(t) {
                    bail!("in-place layer '{}' not supported; use distinct top names", cfg.name);
                }
            }
            let tshapes = layer
                .setup(&bshapes)
                .with_context(|| format!("setting up layer '{}'", cfg.name))?;
            if tshapes.len() != cfg.tops.len() {
                bail!(
                    "layer '{}' produced {} tops, config names {}",
                    cfg.name,
                    tshapes.len(),
                    cfg.tops.len()
                );
            }
            let mut tids = Vec::new();
            for (t, shape) in cfg.tops.iter().zip(tshapes) {
                if blob_index.contains_key(t) {
                    bail!("duplicate top blob '{}'", t);
                }
                let id = blobs.len();
                blobs.push(Blob::new(t.clone(), shape));
                blob_index.insert(t.clone(), id);
                tids.push(id);
            }
            bottom_ids.push(bids);
            top_ids.push(tids);
            layers.push(layer);
        }
        Ok(Net {
            config,
            layers,
            blobs,
            blob_index,
            bottom_ids,
            top_ids,
            metrics: Metrics::new(),
        })
    }

    pub fn config(&self) -> &NetConfig {
        &self.config
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, i: usize) -> &dyn Layer {
        self.layers[i].as_ref()
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut (dyn Layer + '_) {
        self.layers[i].as_mut()
    }

    pub fn layer_by_name_mut(&mut self, name: &str) -> Option<&mut Box<dyn Layer>> {
        self.layers.iter_mut().find(|l| l.name() == name)
    }

    /// Blob by name (activations, labels, loss, accuracy).
    pub fn blob(&self, name: &str) -> Option<&Blob> {
        self.blob_index.get(name).map(|&i| &self.blobs[i])
    }

    pub fn blob_mut(&mut self, name: &str) -> Option<&mut Blob> {
        let i = *self.blob_index.get(name)?;
        Some(&mut self.blobs[i])
    }

    pub fn blob_names(&self) -> impl Iterator<Item = &str> {
        self.blobs.iter().map(|b| b.name())
    }

    /// Run one layer's native forward against the blob store.
    pub fn forward_layer(&mut self, li: usize) -> Result<()> {
        // Move tops out to satisfy the borrow checker (no in-place layers).
        let tids = self.top_ids[li].clone();
        let mut tops: Vec<Tensor> = tids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].data_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let bottoms: Vec<&Tensor> =
            self.bottom_ids[li].iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].forward(&bottoms, &mut tops);
        for (&i, t) in tids.iter().zip(tops) {
            *self.blobs[i].data_mut() = t;
        }
        result.with_context(|| format!("forward of layer '{}'", self.layers[li].name()))
    }

    /// Run one layer's native backward against the blob store.
    pub fn backward_layer(&mut self, li: usize) -> Result<()> {
        if !self.layers[li].needs_backward() {
            return Ok(());
        }
        let bids = self.bottom_ids[li].clone();
        let mut bottom_diffs: Vec<Tensor> = bids
            .iter()
            .map(|&i| std::mem::replace(self.blobs[i].diff_mut(), Tensor::zeros(Shape::new(&[0]))))
            .collect();
        let top_diffs: Vec<&Tensor> =
            self.top_ids[li].iter().map(|&i| self.blobs[i].diff()).collect();
        let bottom_datas: Vec<&Tensor> =
            bids.iter().map(|&i| self.blobs[i].data()).collect();
        let result = self.layers[li].backward(&top_diffs, &bottom_datas, &mut bottom_diffs);
        for (&i, t) in bids.iter().zip(bottom_diffs) {
            *self.blobs[i].diff_mut() = t;
        }
        result.with_context(|| format!("backward of layer '{}'", self.layers[li].name()))
    }

    /// Full forward sweep (records per-layer timings).  Returns the loss if
    /// a loss layer is present.
    pub fn forward(&mut self) -> Result<Option<f32>> {
        let mut loss = None;
        for li in 0..self.layers.len() {
            let t0 = Instant::now();
            self.forward_layer(li)?;
            let name = format!("fwd.{}", self.layers[li].name());
            self.metrics.record(&name, t0.elapsed());
            if self.layers[li].is_loss() {
                let tid = self.top_ids[li][0];
                loss = Some(self.blobs[tid].data().as_slice()[0]);
            }
        }
        Ok(loss)
    }

    /// Full backward sweep (loss layers seed their own gradients).
    pub fn backward(&mut self) -> Result<()> {
        for li in (0..self.layers.len()).rev() {
            let t0 = Instant::now();
            self.backward_layer(li)?;
            let name = format!("bwd.{}", self.layers[li].name());
            self.metrics.record(&name, t0.elapsed());
        }
        Ok(())
    }

    /// Zero all parameter gradients (start of an iteration).
    pub fn zero_param_diffs(&mut self) {
        for l in &mut self.layers {
            for p in l.params_mut() {
                p.zero_diff();
            }
        }
    }

    /// All learnable parameter blobs, in layer order.
    pub fn params_mut(&mut self) -> Vec<&mut Blob> {
        self.layers
            .iter_mut()
            .flat_map(|l| l.params_mut().iter_mut())
            .collect()
    }

    pub fn params(&self) -> Vec<&Blob> {
        self.layers.iter().flat_map(|l| l.params().iter()).collect()
    }

    /// Total learnable parameter count.
    pub fn num_params(&self) -> usize {
        self.params().iter().map(|b| b.count()).sum()
    }

    /// Indices of layers of a given type.
    pub fn layers_of_type(&self, t: LayerType) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].ltype() == t)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::presets;

    fn lenet() -> Net {
        let cfg = NetConfig::from_text(presets::LENET_MNIST).unwrap();
        Net::from_config(cfg, 1).unwrap()
    }

    #[test]
    fn setup_wires_blobs() {
        let net = lenet();
        assert_eq!(net.num_layers(), 10);
        assert_eq!(net.blob("conv1").unwrap().shape().dims(), &[64, 20, 24, 24]);
        assert_eq!(net.blob("pool2").unwrap().shape().dims(), &[64, 50, 4, 4]);
        assert_eq!(net.blob("ip2").unwrap().shape().dims(), &[64, 10]);
        assert_eq!(net.blob("loss").unwrap().shape().dims(), &[1]);
        // LeNet's canonical parameter count
        assert_eq!(net.num_params(), 20 * 25 + 20 + 50 * 20 * 25 + 50
                   + 500 * 800 + 500 + 10 * 500 + 10);
    }

    #[test]
    fn forward_produces_finite_loss() {
        let mut net = lenet();
        let loss = net.forward().unwrap().expect("has loss layer");
        assert!(loss.is_finite());
        // Untrained on 10 classes: loss near ln(10)
        assert!((1.5..4.0).contains(&loss), "loss {loss}");
        let acc = net.blob("accuracy").unwrap().data().as_slice()[0];
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn backward_fills_param_grads() {
        let mut net = lenet();
        net.zero_param_diffs();
        net.forward().unwrap();
        net.backward().unwrap();
        for p in net.params() {
            assert!(p.diff().l2() > 0.0, "zero grad for {}", p.name());
        }
    }

    #[test]
    fn forward_deterministic_given_seed() {
        let mut a = lenet();
        let mut b = lenet();
        let la = a.forward().unwrap().unwrap();
        let lb = b.forward().unwrap().unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn cifar_net_builds() {
        let cfg = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        let mut net = Net::from_config(cfg, 2).unwrap();
        assert_eq!(net.blob("pool3").unwrap().shape().dims(), &[64, 64, 4, 4]);
        let loss = net.forward().unwrap().unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn rejects_undefined_bottom() {
        let src = r#"
            name: "bad"
            layer { name: "ip" type: "InnerProduct" bottom: "ghost" top: "y"
                    inner_product_param { num_output: 4 } }
        "#;
        let cfg = NetConfig::from_text(src).unwrap();
        assert!(Net::from_config(cfg, 1).is_err());
    }
}
