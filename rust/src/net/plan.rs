//! Graph-level execution planner: a region-graph IR built once at
//! [`Net::from_config`](super::Net::from_config) time.
//!
//! Nodes ([`RegionNode`]) are fused-region descriptions — which layers
//! run in the region, its stage list, barrier points, and index space —
//! and edges are blob dependencies (each node's `inputs`/`outputs` name
//! the blobs it reads and writes).  A [`Plan`] carries two schedules
//! (forward [`FwdStep`]s and backward [`BwdStep`]s) that the net's
//! executors walk, plus a scratch model ([`ScratchReq`]) assigning every
//! planner-managed buffer a live range on the unified forward+backward
//! timeline and an arena slot, so buffers with non-overlapping live
//! ranges share storage instead of growing per layer.
//!
//! # Plan rules
//!
//! * **R1 (fused forward activation)** — a Convolution/InnerProduct
//!   layer immediately followed by a ReLU consuming exactly its single
//!   top fuses the activation into the producer's own parallel region
//!   (the pre-planner `Net::from_config` pairing, now a plan rule).
//! * **R2 (fused pool→conv backward)** — a Convolution immediately
//!   followed by a Pooling layer consuming exactly its single top runs
//!   both backwards as **one** three-stage region: pool scatter into the
//!   conv's top diff, per-sample conv gradient work, deterministic
//!   partial merge.
//! * **R3 (no fusion across fan-out)** — both rules require the
//!   producer's top to have exactly **one** consumer.  A top consumed by
//!   more than one layer is a fan-out edge: fusing across it would bake
//!   one consumer's schedule into the producer while other consumers
//!   still need the blob, so the planner models the restriction
//!   explicitly instead of relying on implicit adjacency.
//! * **Skip nodes** — layers whose backward is a no-op (Data, Accuracy)
//!   appear in the backward schedule as zero-region skip nodes so the
//!   timeline still has one position per layer.
//!
//! # Scratch model
//!
//! Two request classes:
//!
//! * `<conv>.panels` — the packed-colsᵀ capture cache
//!   (`PHAST_CONV_PACK`), live from the layer's forward node to its
//!   backward node.  Panel ranges of distinct conv layers nest (an inner
//!   layer's forward→backward span sits inside an outer one's), so they
//!   can never share storage; they are `resident` (layer-owned) slots,
//!   modeled for lifetime accounting and the peak metric.
//! * `<conv>.bwd` — the fused pool→conv backward bundle: per-worker
//!   dW/db partials plus the per-worker column/dcolumn scratch, live
//!   only during that layer's backward node.  These ranges never overlap
//!   across layers, so greedy interval coloring packs them into shared
//!   `arena` slots (one allocation serves every fused conv backward in
//!   the net), and the region carves per-worker windows from the slot
//!   instead of allocating per call.
//!
//! The serial single-worker column buffer (`ConvLayer::cols`) stays out
//! of the model: planned execution falls back to the per-layer reference
//! paths at one worker, where that buffer is the seed's cost profile.
//!
//! [`Plan::describe`] renders all of this as a stable text format pinned
//! by golden files in `tests/plan.rs` — a planner change shows up as a
//! reviewed golden diff, not a silent schedule change.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::layers::Layer;
use crate::ops;
use crate::proto::{LayerType, NetConfig};
use crate::tensor::Blob;

/// One forward schedule entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwdStep {
    /// Run layer `li`'s own forward.
    Layer(usize),
    /// Run layer `li`'s forward with ReLU layer `ri` fused in (rule R1).
    FusedRelu(usize, usize),
}

/// One backward schedule entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BwdStep {
    /// Run layer `li`'s own backward (a no-op for skip layers).
    Layer(usize),
    /// Run pool layer `pi`'s and conv layer `ci`'s backwards as one
    /// three-stage region (rule R2).
    FusedPoolConv { conv: usize, pool: usize },
}

/// Node kinds of the region graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// A single layer's own pass.
    Layer,
    /// A backward no-op (Data/Accuracy): zero regions.
    Skip,
    /// Rule R1: producer + ReLU in the producer's forward region.
    FusedRelu,
    /// Rule R2: pool scatter + conv gradient + merge, one region.
    FusedPoolConv,
}

/// A fused-region description: the layers it runs, its stages and
/// barrier points, the index space the workers partition, and the blobs
/// it reads (`inputs`) and writes (`outputs`) — the graph's edges.
#[derive(Clone, Debug)]
pub struct RegionNode {
    /// Timeline id: `F<i>` for forward nodes, `B<i>` for backward.
    pub id: String,
    pub kind: NodeKind,
    /// Layer indices executed by this node, in execution order.
    pub layers: Vec<usize>,
    /// Human label, e.g. `conv1` or `pool2+conv2`.
    pub label: String,
    /// Blob names read (for backward nodes: `d:<name>` diffs).
    pub inputs: Vec<String>,
    /// Blob names written.
    pub outputs: Vec<String>,
    /// Stage labels inside the fused region (empty for plain nodes —
    /// their internal structure belongs to the layer, not the plan).
    pub stages: Vec<&'static str>,
    /// Stage-barrier crossings inside the region.
    pub barriers: usize,
    /// Index space the region partitions across workers.
    pub index_space: &'static str,
    /// Predicted pool dispatches for this node in the backward sweep at
    /// the parallel width (>= 2 workers, default knobs); `None` for
    /// forward nodes, whose counts are layer-internal.
    pub regions: Option<u64>,
}

/// A planner-managed scratch buffer: symbolic size (a fixed part plus a
/// per-worker part) and an inclusive live range on the unified
/// forward+backward timeline.
#[derive(Clone, Debug)]
pub struct ScratchReq {
    /// `<layer>.panels` or `<layer>.bwd`.
    pub key: String,
    /// Owning layer index.
    pub layer: usize,
    /// Resident (layer-owned, lifetime modeled only) vs arena (shared
    /// slot the planned executor actually carves workers' windows from).
    pub resident: bool,
    /// Slot id within its domain (resident slots are unique per request
    /// by construction; arena slots are shared across disjoint ranges).
    pub slot: usize,
    /// Worker-count-independent float count.
    pub fixed_floats: usize,
    /// Floats per worker (scales with the region's worker count).
    pub per_worker_floats: usize,
    /// Inclusive live range: timeline positions of the first and last
    /// node that touch the buffer.
    pub live: (usize, usize),
}

impl ScratchReq {
    /// Concrete float count at `workers` workers.
    pub fn floats(&self, workers: usize) -> usize {
        self.fixed_floats + self.per_worker_floats * workers
    }
}

/// The shared scratch arena the planned backward carves fused-region
/// worker windows from — one grow-only buffer per arena slot.
pub struct ScratchArena {
    slots: Vec<Vec<f32>>,
}

impl ScratchArena {
    pub fn new(slots: usize) -> ScratchArena {
        ScratchArena { slots: (0..slots).map(|_| Vec::new()).collect() }
    }

    /// The grow-only backing vector of slot `i` (regions resize it to
    /// their need; capacity is never given back, like the per-layer
    /// scratch it replaces).
    pub fn slot_vec_mut(&mut self, i: usize) -> &mut Vec<f32> {
        &mut self.slots[i]
    }

    /// Bytes actually held across all slots (the measured counterpart of
    /// [`Plan::peak_scratch_bytes`]).
    pub fn held_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.capacity() * std::mem::size_of::<f32>()).sum()
    }
}

/// The region-graph plan for one net.
pub struct Plan {
    net: String,
    /// Forward schedule, in execution order.
    pub fwd: Vec<FwdStep>,
    /// Backward schedule, in execution order (reverse layer order).
    pub bwd: Vec<BwdStep>,
    /// Region nodes: all forward nodes, then all backward nodes — index
    /// into this vec is the unified timeline position.
    pub nodes: Vec<RegionNode>,
    /// Number of forward nodes (backward nodes start here).
    pub fwd_nodes: usize,
    /// Scratch requests, in layer order (panels before bundle per layer).
    pub scratch: Vec<ScratchReq>,
    /// Arena slot count (resident slots are layer-owned).
    arena_slots: usize,
    /// conv layer index -> arena slot of its `.bwd` bundle.
    bwd_slot: HashMap<usize, usize>,
}

impl Plan {
    /// Build the plan from the configured, set-up net.
    pub fn build(
        config: &NetConfig,
        layers: &[Box<dyn Layer>],
        blobs: &[Blob],
        bottom_ids: &[Vec<usize>],
        top_ids: &[Vec<usize>],
    ) -> Plan {
        let nl = layers.len();
        // Blob fan-out: how many layers consume each top (rule R3).
        let mut consumers: HashMap<&str, usize> = HashMap::new();
        for lc in &config.layers {
            for b in &lc.bottoms {
                *consumers.entry(b.as_str()).or_insert(0) += 1;
            }
        }
        let single_consumer =
            |top: &str| consumers.get(top).copied().unwrap_or(0) == 1;

        // Rule R1: producer -> ReLU forward fusion (with the fan-out gate).
        let mut fused_relu: Vec<Option<usize>> = vec![None; nl];
        for li in 0..nl.saturating_sub(1) {
            let ri = li + 1;
            if !matches!(layers[li].ltype(), LayerType::Convolution | LayerType::InnerProduct) {
                continue;
            }
            if layers[ri].ltype() != LayerType::ReLU {
                continue;
            }
            if config.layers[li].tops.len() == 1
                && config.layers[ri].bottoms.len() == 1
                && config.layers[ri].tops.len() == 1
                && config.layers[ri].bottoms[0] == config.layers[li].tops[0]
                && single_consumer(&config.layers[li].tops[0])
            {
                fused_relu[li] = Some(ri);
            }
        }

        // Rule R2: conv -> pool backward fusion (same fan-out gate).
        let mut pool_of_conv: Vec<Option<usize>> = vec![None; nl];
        let mut conv_of_pool: Vec<Option<usize>> = vec![None; nl];
        for ci in 0..nl.saturating_sub(1) {
            let pi = ci + 1;
            if layers[ci].ltype() != LayerType::Convolution
                || layers[pi].ltype() != LayerType::Pooling
            {
                continue;
            }
            if config.layers[ci].tops.len() == 1
                && config.layers[pi].bottoms.len() == 1
                && config.layers[pi].tops.len() == 1
                && config.layers[pi].bottoms[0] == config.layers[ci].tops[0]
                && single_consumer(&config.layers[ci].tops[0])
            {
                pool_of_conv[ci] = Some(pi);
                conv_of_pool[pi] = Some(ci);
            }
        }

        // Forward schedule + nodes.
        let mut fwd = Vec::new();
        let mut nodes = Vec::new();
        let mut li = 0;
        while li < nl {
            let id = format!("F{}", fwd.len());
            if let Some(ri) = fused_relu[li] {
                fwd.push(FwdStep::FusedRelu(li, ri));
                let (stages, index_space) = match layers[li].ltype() {
                    // conv + bias + relu in one batch-parallel dispatch
                    LayerType::Convolution => {
                        (vec!["im2col+gemm+bias+relu"], "samples")
                    }
                    // gemm region, then the bias+relu chunk region
                    _ => (vec!["gemm", "bias+relu"], "rows"),
                };
                nodes.push(RegionNode {
                    id,
                    kind: NodeKind::FusedRelu,
                    layers: vec![li, ri],
                    label: format!("{}+{}", config.layers[li].name, config.layers[ri].name),
                    inputs: config.layers[li].bottoms.clone(),
                    outputs: config.layers[li]
                        .tops
                        .iter()
                        .chain(config.layers[ri].tops.iter())
                        .cloned()
                        .collect(),
                    stages,
                    barriers: 0,
                    index_space,
                    regions: None,
                });
                li = ri + 1;
            } else {
                fwd.push(FwdStep::Layer(li));
                nodes.push(RegionNode {
                    id,
                    kind: NodeKind::Layer,
                    layers: vec![li],
                    label: config.layers[li].name.clone(),
                    inputs: config.layers[li].bottoms.clone(),
                    outputs: config.layers[li].tops.clone(),
                    stages: vec![],
                    barriers: 0,
                    index_space: "",
                    regions: None,
                });
                li += 1;
            }
        }
        let fwd_nodes = nodes.len();

        // Backward schedule + nodes (reverse layer order).
        let mut bwd = Vec::new();
        let mut li = nl;
        while li > 0 {
            li -= 1;
            let id = format!("B{}", bwd.len());
            if let Some(ci) = conv_of_pool[li] {
                // One region: pool scatter | conv gradient | merge.  The
                // conv layer (ci = li-1) is consumed by this node.
                bwd.push(BwdStep::FusedPoolConv { conv: ci, pool: li });
                let d = |s: &String| format!("d:{s}");
                nodes.push(RegionNode {
                    id,
                    kind: NodeKind::FusedPoolConv,
                    layers: vec![li, ci],
                    label: format!("{}+{}", config.layers[li].name, config.layers[ci].name),
                    inputs: config.layers[li].tops.iter().map(d).collect(),
                    outputs: config.layers[ci]
                        .tops
                        .iter()
                        .chain(config.layers[ci].bottoms.iter())
                        .map(d)
                        .chain(std::iter::once(format!("dW:{}", config.layers[ci].name)))
                        .collect(),
                    stages: vec!["pool-scatter", "conv-grad", "merge"],
                    barriers: 2,
                    index_space: "workers",
                    regions: Some(1),
                });
                li = ci; // skip the conv (decremented at loop top)
            } else if pool_of_conv[li].is_some() {
                // Unreachable by construction (the pool node above eats
                // its conv), kept for clarity if schedules ever reorder.
                unreachable!("conv {li} fused into a pool node must be skipped");
            } else {
                bwd.push(BwdStep::Layer(li));
                let skip = !layers[li].needs_backward();
                let d = |s: &String| format!("d:{s}");
                nodes.push(RegionNode {
                    id,
                    kind: if skip { NodeKind::Skip } else { NodeKind::Layer },
                    layers: vec![li],
                    label: config.layers[li].name.clone(),
                    inputs: if skip {
                        vec![]
                    } else {
                        config.layers[li].tops.iter().map(d).collect()
                    },
                    outputs: if skip {
                        vec![]
                    } else {
                        config.layers[li].bottoms.iter().map(d).collect()
                    },
                    stages: vec![],
                    barriers: 0,
                    index_space: "",
                    regions: Some(if skip {
                        0
                    } else {
                        let t = blobs[top_ids[li][0]].shape();
                        let b = blobs[bottom_ids[li][0]].shape();
                        let batch = t.num().max(1);
                        backward_regions_of(
                            layers[li].ltype(),
                            batch,
                            t.count() / batch,
                            b.count() / b.num().max(1),
                        )
                    }),
                });
            }
        }

        // Scratch model: timeline position of each layer's fwd/bwd node.
        let node_pos = |layer: usize| -> (usize, usize) {
            let f = nodes[..fwd_nodes]
                .iter()
                .position(|n| n.layers.contains(&layer))
                .expect("every layer has a forward node");
            let b = nodes[fwd_nodes..]
                .iter()
                .position(|n| n.layers.contains(&layer))
                .expect("every layer has a backward node");
            (f, fwd_nodes + b)
        };
        let mut scratch: Vec<ScratchReq> = Vec::new();
        let mut resident_slots = 0usize;
        let mut arena: Vec<Vec<(usize, usize)>> = Vec::new(); // slot -> live ranges
        let mut bwd_slot = HashMap::new();
        for ci in 0..nl {
            if layers[ci].ltype() != LayerType::Convolution {
                continue;
            }
            let name = &config.layers[ci].name;
            let bshape = blobs[bottom_ids[ci][0]].shape();
            let tshape = blobs[top_ids[ci][0]].shape();
            let (n, cout) = (tshape.num(), tshape.channels());
            let ohw = tshape.height() * tshape.width();
            let k = config.layers[ci].kernel_size;
            let ckk = bshape.channels() * k * k;
            let (fpos, bpos) = node_pos(ci);
            // Packed-colsᵀ panels: captured by forward, consumed by this
            // layer's backward — live across everything in between, so
            // panel ranges nest and never share (resident).
            scratch.push(ScratchReq {
                key: format!("{name}.panels"),
                layer: ci,
                resident: true,
                slot: resident_slots,
                fixed_floats: n * ops::packed_b_len(ohw, ckk),
                per_worker_floats: 0,
                live: (fpos, bpos),
            });
            resident_slots += 1;
            // Fused pool→conv backward bundle: per-worker dW/db partials
            // + column/dcolumn scratch, live only in this backward node —
            // greedy interval coloring onto shared arena slots.
            if pool_of_conv[ci].is_some() {
                let live = (bpos, bpos);
                let slot = arena
                    .iter()
                    .position(|ranges| {
                        ranges.iter().all(|&(a, b)| live.1 < a || b < live.0)
                    })
                    .unwrap_or_else(|| {
                        arena.push(Vec::new());
                        arena.len() - 1
                    });
                arena[slot].push(live);
                bwd_slot.insert(ci, slot);
                scratch.push(ScratchReq {
                    key: format!("{name}.bwd"),
                    layer: ci,
                    resident: false,
                    slot,
                    fixed_floats: 0,
                    per_worker_floats: cout * ckk + cout + 2 * ckk * ohw,
                    live,
                });
            }
        }

        Plan {
            net: config.name.clone(),
            fwd,
            bwd,
            nodes,
            fwd_nodes,
            scratch,
            arena_slots: arena.len(),
            bwd_slot,
        }
    }

    /// (producer, fused ReLU) pairs of rule R1 — what the pre-planner
    /// `fused_relu` detection produced, now derived from the plan.
    pub fn fused_relu_pairs(&self) -> Vec<(usize, usize)> {
        self.fwd
            .iter()
            .filter_map(|s| match *s {
                FwdStep::FusedRelu(li, ri) => Some((li, ri)),
                FwdStep::Layer(_) => None,
            })
            .collect()
    }

    /// (pool, conv) pairs of rule R2, in backward execution order.
    pub fn fused_pool_conv_pairs(&self) -> Vec<(usize, usize)> {
        self.bwd
            .iter()
            .filter_map(|s| match *s {
                BwdStep::FusedPoolConv { conv, pool } => Some((pool, conv)),
                BwdStep::Layer(_) => None,
            })
            .collect()
    }

    /// Arena slot of conv layer `ci`'s fused-backward bundle.
    pub fn bwd_arena_slot(&self, ci: usize) -> Option<usize> {
        self.bwd_slot.get(&ci).copied()
    }

    /// Number of shared arena slots the executor must allocate.
    pub fn arena_slots(&self) -> usize {
        self.arena_slots
    }

    /// Predicted pool dispatches for one backward sweep at the parallel
    /// width (every layer at >= 2 workers, default knobs) — the number
    /// `tests/plan.rs` pins against the measured `par::region_count()`.
    pub fn predicted_backward_regions(&self) -> u64 {
        self.nodes[self.fwd_nodes..]
            .iter()
            .map(|n| n.regions.unwrap_or(0))
            .sum()
    }

    /// Peak scratch floats at `workers` workers: every resident buffer
    /// (their live ranges nest) plus the **max** request per shared
    /// arena slot — what planned execution actually holds.
    pub fn peak_scratch_floats(&self, workers: usize) -> usize {
        let resident: usize = self
            .scratch
            .iter()
            .filter(|r| r.resident)
            .map(|r| r.floats(workers))
            .sum();
        let arena: usize = (0..self.arena_slots)
            .map(|s| {
                self.scratch
                    .iter()
                    .filter(|r| !r.resident && r.slot == s)
                    .map(|r| r.floats(workers))
                    .max()
                    .unwrap_or(0)
            })
            .sum();
        resident + arena
    }

    /// The per-layer grow-only total the arena replaces: every request
    /// gets its own buffer (the pre-planner behaviour).
    pub fn grow_only_scratch_floats(&self, workers: usize) -> usize {
        self.scratch.iter().map(|r| r.floats(workers)).sum()
    }

    /// [`Plan::peak_scratch_floats`] in bytes.
    pub fn peak_scratch_bytes(&self, workers: usize) -> usize {
        self.peak_scratch_floats(workers) * std::mem::size_of::<f32>()
    }

    /// [`Plan::grow_only_scratch_floats`] in bytes.
    pub fn grow_only_scratch_bytes(&self, workers: usize) -> usize {
        self.grow_only_scratch_floats(workers) * std::mem::size_of::<f32>()
    }

    /// Timeline id (`F<i>`/`B<i>`) of timeline position `pos`.
    fn pos_id(&self, pos: usize) -> &str {
        &self.nodes[pos].id
    }

    /// Stable text rendering of the plan, pinned by the golden files in
    /// `tests/plan.rs`.  Everything printed is a function of the net
    /// config and blob shapes only — never of thread count, machine, or
    /// knob state — except worker-scaled scratch sizes, which stay
    /// symbolic (`W*<floats>`); the one concrete peak line pins W=4, the
    /// width the benches and region-conformance tests run at.
    pub fn describe(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "plan net={} layers={}", self.net, self.layer_count());
        let _ = writeln!(s, "forward:");
        for n in &self.nodes[..self.fwd_nodes] {
            let _ = write!(
                s,
                "  {} {} {} [{}] -> [{}]",
                n.id,
                kind_str(n.kind),
                n.label,
                n.inputs.join(" "),
                n.outputs.join(" ")
            );
            if !n.stages.is_empty() {
                let _ = write!(
                    s,
                    " index={} stages=[{}] barriers={}",
                    n.index_space,
                    n.stages.join("|"),
                    n.barriers
                );
            }
            let _ = writeln!(s);
        }
        let _ = writeln!(s, "backward:");
        for n in &self.nodes[self.fwd_nodes..] {
            let _ = write!(s, "  {} {} {}", n.id, kind_str(n.kind), n.label);
            if n.kind != NodeKind::Skip {
                let _ = write!(s, " [{}] -> [{}]", n.inputs.join(" "), n.outputs.join(" "));
            }
            if !n.stages.is_empty() {
                let _ = write!(
                    s,
                    " index={} stages=[{}] barriers={}",
                    n.index_space,
                    n.stages.join("|"),
                    n.barriers
                );
            }
            if let Some(r) = n.regions {
                let _ = write!(s, " regions={r}");
            }
            let _ = writeln!(s);
        }
        let _ = writeln!(s, "scratch:");
        for r in &self.scratch {
            let domain = if r.resident {
                format!("resident:r{}", r.slot)
            } else {
                format!("arena:a{}", r.slot)
            };
            let floats = match (r.fixed_floats, r.per_worker_floats) {
                (f, 0) => format!("{f}"),
                (0, w) => format!("W*{w}"),
                (f, w) => format!("{f}+W*{w}"),
            };
            let _ = writeln!(
                s,
                "  {} {} floats={} live={}..{}",
                r.key,
                domain,
                floats,
                self.pos_id(r.live.0),
                self.pos_id(r.live.1)
            );
        }
        let _ = writeln!(
            s,
            "predicted backward regions (threads >= 2): {}",
            self.predicted_backward_regions()
        );
        let _ = writeln!(
            s,
            "peak scratch floats @W=4: {} (grow-only {})",
            self.peak_scratch_floats(4),
            self.grow_only_scratch_floats(4)
        );
        s
    }

    fn layer_count(&self) -> usize {
        self.nodes[..self.fwd_nodes].iter().map(|n| n.layers.len()).sum()
    }

    /// Statically verify the plan against the invariants the executors
    /// rely on (see the module docs and `docs/CHECKING.md`):
    ///
    /// * **P1 arena-disjoint** — no two arena scratch requests sharing a
    ///   slot have overlapping live ranges (the interval coloring's
    ///   correctness condition), and every slot id is in range.
    /// * **P2 fanout-gate** — rule R3 recomputed from the config: every
    ///   fused pair is adjacent, shape-compatible, and fuses across a
    ///   blob with exactly one consumer.
    /// * **P3 barrier-sufficiency** — every staged node declares exactly
    ///   one barrier per cross-worker producer→consumer stage boundary
    ///   (`FusedPoolConv`'s scatter→grad and grad→merge edges; all other
    ///   node kinds have no cross-worker stage edge).
    /// * **P4 schedule-order** — every blob a node reads was produced by
    ///   an earlier node on the same sweep (gradients seeded at the loss
    ///   layers' tops).
    /// * **P5 skip-consistency** — backward skip nodes are exactly the
    ///   no-backward layers (Data/Accuracy), with zero regions and empty
    ///   io; and every layer appears once per sweep (coverage).
    ///
    /// `config` is required because the plan does not retain the blob
    /// fan-out counts rule R3 was decided from; the verifier recomputes
    /// them from the same source.  Run at `Net::from_config` time (a
    /// violating plan refuses to construct) and by `repro verify-plan`.
    pub fn verify(&self, config: &NetConfig) -> VerifyReport {
        let mut report = VerifyReport { net: self.net.clone(), checks: vec![], violations: vec![] };
        self.verify_arena(&mut report);
        self.verify_fanout(config, &mut report);
        self.verify_barriers(&mut report);
        self.verify_schedule_order(config, &mut report);
        self.verify_skip_and_coverage(config, &mut report);
        report
    }

    fn verify_arena(&self, report: &mut VerifyReport) {
        let mut viol = Vec::new();
        let arena: Vec<&ScratchReq> = self.scratch.iter().filter(|r| !r.resident).collect();
        for r in &arena {
            if r.slot >= self.arena_slots {
                viol.push(Violation {
                    check: "arena-disjoint",
                    site: r.key.clone(),
                    detail: format!(
                        "slot a{} out of range (arena has {} slot(s))",
                        r.slot, self.arena_slots
                    ),
                });
            }
        }
        for (i, a) in arena.iter().enumerate() {
            for b in arena.iter().skip(i + 1) {
                if a.slot == b.slot && a.live.0 <= b.live.1 && b.live.0 <= a.live.1 {
                    viol.push(Violation {
                        check: "arena-disjoint",
                        site: format!("{}+{}", a.key, b.key),
                        detail: format!(
                            "both live on slot a{} with overlapping ranges {}..{} and {}..{}",
                            a.slot,
                            self.pos_id(a.live.0),
                            self.pos_id(a.live.1),
                            self.pos_id(b.live.0),
                            self.pos_id(b.live.1)
                        ),
                    });
                }
            }
        }
        let residents = self.scratch.len() - arena.len();
        report.push(
            "arena-disjoint",
            format!(
                "{} arena request(s) on {} slot(s), {} resident",
                arena.len(),
                self.arena_slots,
                residents
            ),
            viol,
        );
    }

    fn verify_fanout(&self, config: &NetConfig, report: &mut VerifyReport) {
        let mut viol = Vec::new();
        // Rule R3's input, recomputed from the same source `build` used.
        let mut consumers: HashMap<&str, usize> = HashMap::new();
        for lc in &config.layers {
            for b in &lc.bottoms {
                *consumers.entry(b.as_str()).or_insert(0) += 1;
            }
        }
        let mut pairs = 0usize;
        for n in &self.nodes {
            let (prod, cons) = match n.kind {
                NodeKind::FusedRelu => (n.layers[0], n.layers[1]),
                // Backward pool+conv node lists [pool, conv]; the fused
                // edge is the conv's top feeding the pool.
                NodeKind::FusedPoolConv => (n.layers[1], n.layers[0]),
                _ => continue,
            };
            pairs += 1;
            let (pc, cc) = (&config.layers[prod], &config.layers[cons]);
            if cons != prod + 1 {
                viol.push(Violation {
                    check: "fanout-gate",
                    site: n.id.clone(),
                    detail: format!(
                        "fused pair {}+{} is not adjacent (layers {} and {})",
                        pc.name, cc.name, prod, cons
                    ),
                });
                continue;
            }
            if pc.tops.len() != 1 || cc.bottoms.len() != 1 || cc.bottoms[0] != pc.tops[0] {
                viol.push(Violation {
                    check: "fanout-gate",
                    site: n.id.clone(),
                    detail: format!(
                        "fused pair {}+{} does not cross a single producer-top edge",
                        pc.name, cc.name
                    ),
                });
                continue;
            }
            let fan = consumers.get(pc.tops[0].as_str()).copied().unwrap_or(0);
            if fan != 1 {
                viol.push(Violation {
                    check: "fanout-gate",
                    site: n.id.clone(),
                    detail: format!(
                        "blob {} has {} consumers but is fused across (rule R3 requires 1)",
                        pc.tops[0], fan
                    ),
                });
            }
        }
        report.push("fanout-gate", format!("{pairs} fused pair(s)"), viol);
    }

    fn verify_barriers(&self, report: &mut VerifyReport) {
        let mut viol = Vec::new();
        let mut staged = 0usize;
        for n in &self.nodes {
            if !n.stages.is_empty() {
                staged += 1;
            }
            // Cross-worker producer→consumer stage boundaries by node
            // kind: FusedPoolConv's scatter fills planes the
            // sample-partitioned gradient stage reads, and its per-worker
            // partials are read across workers by the merge — every
            // consecutive pair needs a barrier.  FusedRelu stage lists
            // are same-partition chains (worker w's bias+relu rows are
            // exactly the rows its gemm stage wrote), and plain nodes
            // keep their structure layer-internal: no cross-worker edge.
            let required = match n.kind {
                NodeKind::FusedPoolConv => n.stages.len().saturating_sub(1),
                _ => 0,
            };
            if n.barriers != required {
                viol.push(Violation {
                    check: "barrier-sufficiency",
                    site: n.id.clone(),
                    detail: format!(
                        "{} node {} declares {} barrier(s) over stages [{}], \
                         cross-worker boundaries require exactly {}",
                        kind_str(n.kind),
                        n.label,
                        n.barriers,
                        n.stages.join("|"),
                        required
                    ),
                });
            }
            if n.kind == NodeKind::FusedPoolConv && n.stages.len() != 3 {
                viol.push(Violation {
                    check: "barrier-sufficiency",
                    site: n.id.clone(),
                    detail: format!(
                        "fused pool→conv backward must be the 3-stage \
                         scatter|grad|merge region, found [{}]",
                        n.stages.join("|")
                    ),
                });
            }
        }
        report.push("barrier-sufficiency", format!("{staged} staged node(s)"), viol);
    }

    fn verify_schedule_order(&self, config: &NetConfig, report: &mut VerifyReport) {
        let mut viol = Vec::new();
        // Forward sweep: every input must be some earlier node's output.
        let mut produced: std::collections::HashSet<&str> = std::collections::HashSet::new();
        for n in &self.nodes[..self.fwd_nodes] {
            for i in &n.inputs {
                if !produced.contains(i.as_str()) {
                    viol.push(Violation {
                        check: "schedule-order",
                        site: n.id.clone(),
                        detail: format!("{} reads blob {} before any node produces it", n.label, i),
                    });
                }
            }
            produced.extend(n.outputs.iter().map(String::as_str));
        }
        let fwd_blobs = produced.len();
        // Backward sweep: gradient flow is seeded at the loss layers'
        // tops (the solver writes those diffs before the sweep starts).
        let seeds: Vec<String> = config
            .layers
            .iter()
            .filter(|lc| lc.ltype == LayerType::SoftMaxWithLoss)
            .flat_map(|lc| lc.tops.iter().map(|t| format!("d:{t}")))
            .collect();
        let mut produced: std::collections::HashSet<&str> =
            seeds.iter().map(String::as_str).collect();
        for n in &self.nodes[self.fwd_nodes..] {
            for i in &n.inputs {
                if !produced.contains(i.as_str()) {
                    viol.push(Violation {
                        check: "schedule-order",
                        site: n.id.clone(),
                        detail: format!(
                            "{} reads gradient {} before any node produces it",
                            n.label, i
                        ),
                    });
                }
            }
            produced.extend(n.outputs.iter().map(String::as_str));
        }
        let bwd_blobs = produced.len();
        report.push(
            "schedule-order",
            format!("{fwd_blobs} forward blob(s), {bwd_blobs} gradient blob(s)"),
            viol,
        );
    }

    fn verify_skip_and_coverage(&self, config: &NetConfig, report: &mut VerifyReport) {
        let mut viol = Vec::new();
        let mut skips = 0usize;
        for n in &self.nodes[self.fwd_nodes..] {
            let no_bwd = n.layers.len() == 1
                && matches!(
                    config.layers[n.layers[0]].ltype,
                    LayerType::Data | LayerType::Accuracy
                );
            if n.kind == NodeKind::Skip {
                skips += 1;
                if !no_bwd {
                    viol.push(Violation {
                        check: "skip-consistency",
                        site: n.id.clone(),
                        detail: format!("{} is a skip node but its layer has a real backward", n.label),
                    });
                }
                if n.regions != Some(0) || !n.inputs.is_empty() || !n.outputs.is_empty() {
                    viol.push(Violation {
                        check: "skip-consistency",
                        site: n.id.clone(),
                        detail: format!(
                            "skip node {} must have zero regions and empty io \
                             (regions={:?}, {} input(s), {} output(s))",
                            n.label,
                            n.regions,
                            n.inputs.len(),
                            n.outputs.len()
                        ),
                    });
                }
            } else if no_bwd {
                viol.push(Violation {
                    check: "skip-consistency",
                    site: n.id.clone(),
                    detail: format!(
                        "{} has no backward (type {:?}) but is not a skip node",
                        n.label,
                        config.layers[n.layers[0]].ltype
                    ),
                });
            }
        }
        // Coverage: every layer exactly once per sweep.
        for (sweep, nodes) in
            [("forward", &self.nodes[..self.fwd_nodes]), ("backward", &self.nodes[self.fwd_nodes..])]
        {
            let mut seen = vec![0usize; config.layers.len()];
            for n in nodes {
                for &li in &n.layers {
                    if li < seen.len() {
                        seen[li] += 1;
                    } else {
                        viol.push(Violation {
                            check: "skip-consistency",
                            site: n.id.clone(),
                            detail: format!("{sweep} node references unknown layer index {li}"),
                        });
                    }
                }
            }
            for (li, &count) in seen.iter().enumerate() {
                if count != 1 {
                    viol.push(Violation {
                        check: "skip-consistency",
                        site: config.layers[li].name.clone(),
                        detail: format!(
                            "layer appears {count} time(s) in the {sweep} sweep (expected 1)"
                        ),
                    });
                }
            }
        }
        report.push(
            "skip-consistency",
            format!("{} skip node(s), {} layer(s) covered", skips, config.layers.len()),
            viol,
        );
    }
}

/// One static plan-contract violation, reported by [`Plan::verify`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Check class: `arena-disjoint`, `fanout-gate`,
    /// `barrier-sufficiency`, `schedule-order` or `skip-consistency`.
    pub check: &'static str,
    /// Node id / scratch key / layer name the violation anchors to.
    pub site: String,
    pub detail: String,
}

/// Machine-readable result of [`Plan::verify`]: one line per check plus
/// one line per violation, rendered in a stable format pinned by the
/// golden files in `tests/check.rs` and printed by `repro verify-plan`.
pub struct VerifyReport {
    pub net: String,
    /// `(check name, summary, violations in this check)` in run order.
    pub checks: Vec<(&'static str, String, usize)>,
    pub violations: Vec<Violation>,
}

impl VerifyReport {
    fn push(&mut self, check: &'static str, summary: String, viol: Vec<Violation>) {
        self.checks.push((check, summary, viol.len()));
        self.violations.extend(viol);
    }

    /// True when every check passed.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Stable text rendering (one `check` line per pass, one `violation`
    /// line per finding, a final count).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "verify net={} checks={}", self.net, self.checks.len());
        for (check, summary, viol) in &self.checks {
            if *viol == 0 {
                let _ = writeln!(s, "check {check}: ok ({summary})");
            } else {
                let _ = writeln!(s, "check {check}: {viol} violation(s) ({summary})");
            }
        }
        for v in &self.violations {
            let _ = writeln!(s, "violation {} site={}: {}", v.check, v.site, v.detail);
        }
        let _ = writeln!(s, "violations: {}", self.violations.len());
        s
    }
}

fn kind_str(k: NodeKind) -> &'static str {
    match k {
        NodeKind::Layer => "layer",
        NodeKind::Skip => "skip",
        NodeKind::FusedRelu => "fused-relu",
        NodeKind::FusedPoolConv => "fused-pool-conv",
    }
}

/// Pool dispatches one layer's backward issues at the parallel width
/// (>= 2 workers, default knobs): the structural counts the conformance
/// tests pin against the measured [`crate::ops::par::region_count`].
/// Conv's fused gradient region is 1 dispatch; pointwise layers, pooling
/// and the loss each issue one chunked region (counted even when the
/// chunking falls back to serial — dispatch accounting is per entry
/// call); InnerProduct issues its `db` chunk plus the `dW`/`dX` GeMMs,
/// which dispatch only above the engine's flops floor
/// ([`ops::gemm::GEMM_PAR_MIN_FLOPS`]) with more than one grain of C
/// rows — tiny heads (CIFAR's 10-way `ip2`) stay serial, and the plan
/// predicts that from the blob shapes.
fn backward_regions_of(lt: LayerType, batch: usize, nout: usize, nin: usize) -> u64 {
    match lt {
        LayerType::Convolution => 1,
        LayerType::InnerProduct => {
            let flops = batch * nout * nin;
            let gemm = |rows: usize| -> u64 {
                u64::from(
                    flops >= ops::gemm::GEMM_PAR_MIN_FLOPS && rows > ops::gemm::gemm_grain(),
                )
            };
            // db chunk + dW GeMM (C rows = nout) + dX GeMM (C rows = batch)
            1 + gemm(nout) + gemm(batch)
        }
        LayerType::Pooling => 1,
        LayerType::ReLU => 1,
        // Standalone SoftMax backward is a serial Jacobian loop.
        LayerType::SoftMax => 0,
        LayerType::SoftMaxWithLoss => 1,
        LayerType::Data | LayerType::Accuracy => 0,
    }
}
