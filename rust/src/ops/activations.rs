//! Elementwise and classification-head ops: leaky ReLU, SoftMax,
//! SoftMax-with-loss, Accuracy — native baseline implementations.
//!
//! The elementwise maps and the row-wise softmax family run through
//! [`ops::par`](super::par): outputs are split into contiguous chunks
//! (elements for ReLU, rows for softmax), one scoped worker per chunk.
//! Every element/row is computed independently with identical ordering,
//! so results are bitwise independent of the thread count.  Knobs:
//! `PHAST_NUM_THREADS` + `PHAST_ELTWISE_GRAIN` / `PHAST_SOFTMAX_GRAIN`.

use super::par;

/// Minimum elements per worker for elementwise maps.
static ELTWISE_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_ELTWISE_GRAIN", 8192);

/// Minimum softmax rows per worker (64 keeps the batch-64 classification
/// head serial — dispatch cost would dominate its few hundred elements).
static SOFTMAX_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_SOFTMAX_GRAIN", 64);

/// Minimum accuracy rows per worker (`PHAST_ACCURACY_GRAIN` overrides).
static ACCURACY_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_ACCURACY_GRAIN", 64);

/// Caffe ReLULayer with `negative_slope` (the paper notes Caffe implements
/// the leaky variant; slope 0 is plain ReLU).
pub fn leaky_relu(x: &[f32], alpha: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    par::parallel_chunks_mut(y, 1, par::Tuning::new(ELTWISE_GRAIN.get()), |range, yb| {
        for (yi, xi) in yb.iter_mut().zip(&x[range]) {
            *yi = if *xi > 0.0 { *xi } else { alpha * *xi };
        }
    });
}

/// dX for leaky ReLU given the forward *input*.
pub fn leaky_relu_bwd(x: &[f32], dy: &[f32], alpha: f32, dx: &mut [f32]) {
    assert_eq!(x.len(), dy.len());
    assert_eq!(x.len(), dx.len());
    par::parallel_chunks_mut(dx, 1, par::Tuning::new(ELTWISE_GRAIN.get()), |range, db| {
        for ((di, xi), gi) in db.iter_mut().zip(&x[range.clone()]).zip(&dy[range]) {
            *di = if *xi > 0.0 { *gi } else { alpha * *gi };
        }
    });
}

/// Row-wise softmax over (n, c) logits, parallel over row blocks.
///
/// The scale (max-shift) → exp+sum → normalize chain is already **fully
/// fused**: each row's three passes run back-to-back inside one
/// `parallel_chunks_mut` region while the row is hot in cache — a single
/// pool dispatch for the whole chain, the head-layer analog of the
/// paper's §4.3 "no artificial interruption" end state.  (A staged
/// `parallel_regions` formulation was measured as strictly worse here:
/// same one dispatch, but extra stage barriers, per-call scratch, and
/// three sweeps over the matrix instead of one.)
pub fn softmax(x: &[f32], n: usize, c: usize, p: &mut [f32]) {
    assert_eq!(x.len(), n * c);
    assert_eq!(p.len(), n * c);
    // Degenerate shapes, explicit (mirrors the GeMM engine's m/n/k == 0
    // handling): an empty batch or zero classes means no rows to map.
    if n == 0 || c == 0 {
        return;
    }
    par::parallel_chunks_mut(p, c, par::Tuning::new(SOFTMAX_GRAIN.get()), |rows, pb| {
        for (bi, r) in rows.enumerate() {
            let row = &x[r * c..(r + 1) * c];
            let out = &mut pb[bi * c..(bi + 1) * c];
            // scale: per-row max (the shift that keeps exp in range)
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            // exp + partition sum
            let mut z = 0.0f32;
            for (o, v) in out.iter_mut().zip(row) {
                *o = (v - m).exp();
                z += *o;
            }
            // normalize
            let inv = 1.0 / z;
            out.iter_mut().for_each(|o| *o *= inv);
        }
    });
}

/// SoftmaxWithLoss forward: mean cross-entropy + probabilities.
///
/// Degenerate shapes are explicit: an empty batch (`n == 0`) has a mean
/// loss of 0 by convention (the old `loss / n` returned NaN), and a
/// single class (`c == 1`) is always predicted perfectly (loss 0).
pub fn softmax_xent(x: &[f32], labels: &[i32], n: usize, c: usize, p: &mut [f32]) -> f32 {
    softmax(x, n, c, p);
    if n == 0 || c == 0 {
        return 0.0;
    }
    let mut loss = 0.0f32;
    for r in 0..n {
        let l = labels[r] as usize;
        assert!(l < c, "label {l} out of range {c}");
        loss -= p[r * c + l].max(f32::MIN_POSITIVE).ln();
    }
    loss / n as f32
}

/// SoftmaxWithLoss backward: (p - onehot) / n, parallel over row blocks.
pub fn softmax_xent_bwd(p: &[f32], labels: &[i32], n: usize, c: usize, dx: &mut [f32]) {
    assert_eq!(p.len(), n * c);
    assert_eq!(dx.len(), n * c);
    // Empty batch or zero classes: nothing to scatter (and `1.0 / n`
    // would be infinite for n == 0).
    if n == 0 || c == 0 {
        return;
    }
    let inv = 1.0 / n as f32;
    par::parallel_chunks_mut(dx, c, par::Tuning::new(SOFTMAX_GRAIN.get()), |rows, db| {
        for (bi, r) in rows.enumerate() {
            let l = labels[r] as usize;
            for j in 0..c {
                let onehot = if j == l { 1.0 } else { 0.0 };
                db[bi * c + j] = (p[r * c + j] - onehot) * inv;
            }
        }
    });
}

/// Top-k accuracy over (n, c) logits.  Caffe's AccuracyLayer counts a hit
/// when fewer than k classes score strictly higher than the label.
///
/// Runs as a parallel tree reduction ([`par::parallel_reduce`]) over row
/// blocks: each worker counts hits in its contiguous range and the
/// integer partials are summed in worker order.  Integer addition is
/// associative, so the result is **exactly** thread-count invariant.
/// Knobs: `PHAST_NUM_THREADS` + `PHAST_ACCURACY_GRAIN` (rows per worker).
pub fn accuracy(x: &[f32], labels: &[i32], n: usize, c: usize, top_k: usize) -> f32 {
    assert_eq!(x.len(), n * c);
    assert_eq!(labels.len(), n);
    // Degenerate shapes, explicit: an empty batch has 0 hits out of 0
    // rows (0.0 by convention, not the old `0 / 0` NaN), and zero
    // classes leave no label to score.
    if n == 0 || c == 0 {
        return 0.0;
    }
    let tune = par::Tuning::new(ACCURACY_GRAIN.get());
    let hits = par::parallel_reduce(
        n,
        tune,
        |rows| {
            let mut h = 0usize;
            for r in rows {
                let row = &x[r * c..(r + 1) * c];
                let l = labels[r] as usize;
                let better = row.iter().filter(|&&v| v > row[l]).count();
                if better < top_k {
                    h += 1;
                }
            }
            h
        },
        |a, b| a + b,
        0usize,
    );
    hits as f32 / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{assert_close, close, forall, Rng};

    #[test]
    fn relu_basic() {
        let x = [-2.0, -0.5, 0.0, 0.5, 2.0];
        let mut y = [0.0; 5];
        leaky_relu(&x, 0.1, &mut y);
        assert_close(&y, &[-0.2, -0.05, 0.0, 0.5, 2.0], 1e-6, 1e-7);
        let mut dx = [0.0; 5];
        leaky_relu_bwd(&x, &[1.0; 5], 0.1, &mut dx);
        assert_close(&dx, &[0.1, 0.1, 0.1, 1.0, 1.0], 1e-6, 1e-7);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        forall("softmax-simplex", 16, |rng: &mut Rng| {
            let n = rng.range(1, 16);
            let c = rng.range(2, 12);
            let x: Vec<f32> = rng.normal_vec(n * c).iter().map(|v| v * 5.0).collect();
            let mut p = vec![0.0f32; n * c];
            softmax(&x, n, c, &mut p);
            for r in 0..n {
                let s: f32 = p[r * c..(r + 1) * c].iter().sum();
                assert!(close(s, 1.0, 1e-5, 1e-5), "row sum {s}");
                assert!(p[r * c..(r + 1) * c].iter().all(|&v| v >= 0.0));
            }
        });
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let x = [1.0, 2.0, 3.0];
        let xs = [101.0, 102.0, 103.0];
        let (mut p1, mut p2) = ([0.0f32; 3], [0.0f32; 3]);
        softmax(&x, 1, 3, &mut p1);
        softmax(&xs, 1, 3, &mut p2);
        assert_close(&p1, &p2, 1e-6, 1e-7);
    }

    #[test]
    fn xent_perfect_prediction_is_zero() {
        let x = [100.0, 0.0, 0.0, 100.0];
        let mut p = [0.0f32; 4];
        let loss = softmax_xent(&x, &[0, 1], 2, 2, &mut p);
        assert!(loss < 1e-6, "{loss}");
    }

    #[test]
    fn xent_uniform_is_log_c() {
        let x = [0.0f32; 10];
        let mut p = [0.0f32; 10];
        let loss = softmax_xent(&x, &[3], 1, 10, &mut p);
        assert!(close(loss, (10.0f32).ln(), 1e-5, 1e-6));
    }

    #[test]
    fn xent_bwd_rows_sum_zero() {
        forall("xent-grad-simplex", 12, |rng: &mut Rng| {
            let n = rng.range(1, 9);
            let c = rng.range(2, 8);
            let x = rng.normal_vec(n * c);
            let labels: Vec<i32> = (0..n).map(|_| rng.range(0, c - 1) as i32).collect();
            let mut p = vec![0.0f32; n * c];
            softmax_xent(&x, &labels, n, c, &mut p);
            let mut dx = vec![0.0f32; n * c];
            softmax_xent_bwd(&p, &labels, n, c, &mut dx);
            for r in 0..n {
                let s: f32 = dx[r * c..(r + 1) * c].iter().sum();
                assert!(s.abs() < 1e-6, "row grad sum {s}");
            }
        });
    }

    #[test]
    fn degenerate_shapes_are_explicit() {
        // Batch 0: no rows, finite outputs (no NaN from the 1/n means),
        // no panics.
        let mut p: Vec<f32> = vec![];
        softmax(&[], 0, 5, &mut p);
        let loss = softmax_xent(&[], &[], 0, 5, &mut p);
        assert_eq!(loss, 0.0, "empty-batch loss must be 0, not NaN");
        let mut dx: Vec<f32> = vec![];
        softmax_xent_bwd(&[], &[], 0, 5, &mut dx);
        assert_eq!(accuracy(&[], &[], 0, 5, 1), 0.0, "empty-batch accuracy must be 0, not NaN");

        // Zero classes: nothing to score, nothing to index.
        softmax(&[], 3, 0, &mut p);
        assert_eq!(softmax_xent(&[], &[0, 0, 0], 3, 0, &mut p), 0.0);
        softmax_xent_bwd(&[], &[0, 0, 0], 3, 0, &mut dx);
        assert_eq!(accuracy(&[], &[0, 0, 0], 3, 0, 1), 0.0);

        // Single class: the softmax simplex is the point {1.0}, the loss
        // is exactly 0, and accuracy is always a hit.
        let x = [3.0f32, -1.0];
        let mut p1 = [0.0f32; 2];
        softmax(&x, 2, 1, &mut p1);
        assert_eq!(p1, [1.0, 1.0]);
        let loss = softmax_xent(&x, &[0, 0], 2, 1, &mut p1);
        assert_eq!(loss, 0.0);
        let mut g1 = [9.0f32; 2];
        softmax_xent_bwd(&p1, &[0, 0], 2, 1, &mut g1);
        assert_eq!(g1, [0.0, 0.0]);
        assert_eq!(accuracy(&x, &[0, 0], 2, 1, 1), 1.0);
    }

    #[test]
    fn accuracy_top1_and_topk() {
        // logits rows: argmax = 2, 0
        let x = [0.1, 0.2, 0.9, 0.8, 0.1, 0.3];
        assert_eq!(accuracy(&x, &[2, 0], 2, 3, 1), 1.0);
        assert_eq!(accuracy(&x, &[0, 0], 2, 3, 1), 0.5);
        // label 1 in row 0 (0.2) is 2nd best -> top-1 misses, top-2 hits
        assert_eq!(accuracy(&x, &[1, 0], 2, 3, 2), 1.0);
        // label 0 in row 0 (0.1) is worst -> top-2 misses, top-3 hits
        assert_eq!(accuracy(&x, &[0, 0], 2, 3, 2), 0.5);
        assert_eq!(accuracy(&x, &[0, 0], 2, 3, 3), 1.0);
    }
}
