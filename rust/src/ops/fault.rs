//! Deterministic fault injection — the test harness for the
//! fault-tolerance layer (crash-safe snapshots, exact resume, the
//! self-healing pool, and the divergence watchdog).
//!
//! Faults are described by the `PHAST_FAULT` environment variable (or
//! scoped programmatically via [`with_faults`]) as a comma-separated list
//! of rules:
//!
//! ```text
//! kind@site[=N | :K]
//! ```
//!
//! * `kind` — what breaks: `worker_panic` (a pool worker panics inside a
//!   parallel region), `io_error` (an injected `std::io` error), `nan`
//!   (the value at the site is replaced with `NaN`), `worker_exit` (the
//!   whole process dies, exit code 86 — dist workers only, see
//!   [`allow_process_exit`]), `msg_drop` / `msg_corrupt` (a dist
//!   transport message is lost / bit-flipped in flight).
//! * `site` — where: `iter` (solver iterations; the event value is the
//!   iteration number), `snapshot_save` / `snapshot_load` (snapshot IO
//!   attempts), `loss` (the solver's per-step loss), `send` / `recv`
//!   (dist transport messages, counted per occurrence on the worker
//!   side).
//! * `=N` — fire exactly once, the first time the site's event value
//!   reaches `N` (for `iter` the value is the iteration number; for
//!   counter sites it is the 1-based occurrence count).
//! * `:K` — fire on every occurrence while the event value is `< K`
//!   (`iter`) or `<= K` (counter sites): "the first K attempts fail".
//! * neither — fire on every occurrence.
//!
//! Examples: `worker_panic@iter=7`, `io_error@snapshot_save:2`,
//! `nan@loss=12`, and composed chaos plans like
//! `worker_exit@iter=7,io_error@snapshot_save` — rules are independent;
//! a single-rule spec parses exactly as before.
//!
//! The plan is **off by default and zero-cost when disabled**: every
//! check first reads one thread-local flag and returns immediately when
//! no plan is installed.  All state is thread-local — rules are armed and
//! consumed on the thread driving training (the dispatching thread), even
//! when the injected panic itself executes inside a pool worker — so
//! concurrently running tests cannot see each other's faults.

use std::cell::{Cell, RefCell};

/// What an injected fault does at its site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FaultKind {
    /// Panic inside a parallel region (in a pool worker when the region
    /// dispatches, on the calling thread when it runs serial).
    WorkerPanic,
    /// Return an injected `std::io::Error` from the site.
    IoError,
    /// Replace the site's value with `f32::NAN`.
    Nan,
    /// Hard-kill the whole process (`exit(86)`, no unwinding, no
    /// cleanup) — a dist-training worker loss.  Only honored in
    /// processes that opted in via [`allow_process_exit`].
    WorkerExit,
    /// Drop a transport message at the site (`send` / `recv`).
    MsgDrop,
    /// Corrupt a transport message's bytes at the site (`send` /
    /// `recv`), to be caught by the frame CRC.
    MsgCorrupt,
}

/// One parsed `kind@site[=N|:K]` rule with its firing state.
#[derive(Clone, Debug)]
struct Rule {
    kind: FaultKind,
    site: String,
    /// `=N`: fire once when the event value first reaches `N`.
    at: Option<u64>,
    /// `:K`: fire while the event value is below/at `K`.
    first: Option<u64>,
    /// Times this rule has fired so far.
    fired: u64,
    /// Occurrences observed at counter sites (1-based).
    seen: u64,
}

impl Rule {
    /// Decide whether the rule fires for `value` (an iteration number or
    /// a 1-based occurrence count) and record the outcome.
    fn fire_at(&mut self, value: u64, inclusive: bool) -> bool {
        let hit = match (self.at, self.first) {
            (Some(n), _) => value == n && self.fired == 0,
            (_, Some(k)) => {
                if inclusive {
                    value <= k
                } else {
                    value < k
                }
            }
            (None, None) => true,
        };
        if hit {
            self.fired += 1;
        }
        hit
    }
}

thread_local! {
    /// Fast-path flag: `true` only while a non-empty plan is installed.
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    /// Whether this thread has initialized its plan from `PHAST_FAULT`.
    static INITIALIZED: Cell<bool> = const { Cell::new(false) };
    /// The installed fault plan, if any.
    static PLAN: RefCell<Vec<Rule>> = const { RefCell::new(Vec::new()) };
    /// A pending worker panic armed by [`begin_iter`], consumed by the
    /// next parallel region (`ops::par`).
    static PANIC_ARMED: Cell<bool> = const { Cell::new(false) };
}

/// Parse one `kind@site[=N|:K]` rule; `Err` carries the reason.
fn parse_rule(spec: &str) -> Result<Rule, String> {
    let (kind_s, rest) = spec
        .split_once('@')
        .ok_or_else(|| format!("'{spec}': expected kind@site"))?;
    let kind = match kind_s.trim() {
        "worker_panic" => FaultKind::WorkerPanic,
        "io_error" => FaultKind::IoError,
        "nan" => FaultKind::Nan,
        "worker_exit" => FaultKind::WorkerExit,
        "msg_drop" => FaultKind::MsgDrop,
        "msg_corrupt" => FaultKind::MsgCorrupt,
        other => return Err(format!("unknown fault kind '{other}'")),
    };
    let (site, at, first) = if let Some((s, n)) = rest.split_once('=') {
        let n: u64 = n
            .trim()
            .parse()
            .map_err(|_| format!("'{spec}': bad =N value"))?;
        (s, Some(n), None)
    } else if let Some((s, k)) = rest.split_once(':') {
        let k: u64 = k
            .trim()
            .parse()
            .map_err(|_| format!("'{spec}': bad :K value"))?;
        (s, None, Some(k))
    } else {
        (rest, None, None)
    };
    let site = site.trim();
    if site.is_empty() {
        return Err(format!("'{spec}': empty site"));
    }
    Ok(Rule {
        kind,
        site: site.to_string(),
        at,
        first,
        fired: 0,
        seen: 0,
    })
}

/// Parse a full comma-separated `PHAST_FAULT` plan, skipping (and
/// reporting) malformed rules.
fn parse_plan(spec: &str) -> Vec<Rule> {
    let mut rules = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match parse_rule(part) {
            Ok(r) => rules.push(r),
            Err(e) => eprintln!("PHAST_FAULT: ignoring malformed rule {e}"),
        }
    }
    rules
}

/// Lazily install this thread's plan from `PHAST_FAULT`, once per thread.
fn ensure_init() {
    if INITIALIZED.with(|c| c.get()) {
        return;
    }
    INITIALIZED.with(|c| c.set(true));
    // LINT-ALLOW: env-read — per-thread fault plan install; cached in
    // thread-locals above rather than a process-wide OnceLock.
    if let Ok(spec) = std::env::var("PHAST_FAULT") {
        let rules = parse_plan(&spec);
        ACTIVE.with(|c| c.set(!rules.is_empty()));
        PLAN.with(|p| *p.borrow_mut() = rules);
    }
}

/// Run `f` over this thread's fault plan.
fn with_plan<R>(f: impl FnOnce(&mut Vec<Rule>) -> R) -> R {
    PLAN.with(|p| f(&mut p.borrow_mut()))
}

/// Whether a fault plan is installed on this thread (the `PHAST_FAULT`
/// env var or a [`with_faults`] scope).
pub fn enabled() -> bool {
    ensure_init();
    ACTIVE.with(|c| c.get())
}

/// Install `spec` as this thread's fault plan for the duration of `f`,
/// then restore the previous plan — the scoped test API ([`PHAST_FAULT`
/// is read once per thread](self), so tests cannot use the env var).
pub fn with_faults<R>(spec: &str, f: impl FnOnce() -> R) -> R {
    struct Restore {
        plan: Vec<Rule>,
        active: bool,
        initialized: bool,
        armed: bool,
    }
    impl Drop for Restore {
        fn drop(&mut self) {
            PLAN.with(|p| *p.borrow_mut() = std::mem::take(&mut self.plan));
            ACTIVE.with(|c| c.set(self.active));
            INITIALIZED.with(|c| c.set(self.initialized));
            PANIC_ARMED.with(|c| c.set(self.armed));
        }
    }
    let rules = parse_plan(spec);
    let restore = Restore {
        plan: PLAN.with(|p| std::mem::replace(&mut p.borrow_mut(), rules)),
        active: ACTIVE.with(|c| c.replace(true)),
        initialized: INITIALIZED.with(|c| c.replace(true)),
        armed: PANIC_ARMED.with(|c| c.replace(false)),
    };
    let out = f();
    drop(restore);
    out
}

/// The exit code an injected `worker_exit` fault dies with — distinctive
/// so a dist coordinator (and CI logs) can tell an injected kill from a
/// genuine crash.
pub const WORKER_EXIT_CODE: i32 = 86;

/// Whether this process may honor `worker_exit` faults (see
/// [`allow_process_exit`]).  Process-global on purpose: exiting is a
/// process-level act, unlike the thread-local fault plans.
static PROCESS_EXIT_ALLOWED: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Opt this process into `worker_exit@iter` faults.  Called by the dist
/// worker entrypoint only — a solver running in a test harness or a
/// coordinator must never have the whole process yanked out from under
/// it by an inherited `PHAST_FAULT`.
pub fn allow_process_exit() {
    PROCESS_EXIT_ALLOWED.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Announce a solver iteration.  Arms a pending worker panic when a
/// `worker_panic@iter` rule fires for `iter`; the panic is consumed by
/// the next parallel region (see `ops::par`).  A `worker_exit@iter` rule
/// firing here kills the process outright (exit code
/// [`WORKER_EXIT_CODE`], no unwinding — the dist-training "kill -9"
/// stand-in) when the process opted in via [`allow_process_exit`].
/// No-op when disabled.
pub fn begin_iter(iter: u64) {
    if !enabled() {
        return;
    }
    let (arm, exit) = with_plan(|rules| {
        let mut arm = false;
        let mut exit = false;
        for r in rules.iter_mut().filter(|r| r.site == "iter") {
            match r.kind {
                FaultKind::WorkerPanic => arm |= r.fire_at(iter, false),
                FaultKind::WorkerExit => exit |= r.fire_at(iter, false),
                _ => {}
            }
        }
        (arm, exit)
    });
    if exit {
        if PROCESS_EXIT_ALLOWED.load(std::sync::atomic::Ordering::Relaxed) {
            eprintln!("PHAST_FAULT: injected worker_exit at iter {iter}: killing process");
            std::process::exit(WORKER_EXIT_CODE);
        }
        eprintln!(
            "PHAST_FAULT: worker_exit@iter fired at iter {iter} but this process did not \
             opt into process exits (dist workers only); ignoring"
        );
    }
    if arm {
        PANIC_ARMED.with(|c| c.set(true));
    }
}

/// What an injected transport fault does to the message at hand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgFault {
    /// Deliver untouched.
    None,
    /// The message vanishes in flight.
    Drop,
    /// The message's bytes are flipped in flight (the frame CRC must
    /// catch it).
    Corrupt,
}

/// Fault check for a transport message site (`send` / `recv`): returns
/// what to do with the current message when a `msg_drop@site` /
/// `msg_corrupt@site` rule fires for this occurrence.  When both kinds
/// fire at once, `Drop` wins.  No-op when disabled.
pub fn check_msg(site: &str) -> MsgFault {
    if !enabled() {
        return MsgFault::None;
    }
    with_plan(|rules| {
        let mut out = MsgFault::None;
        for r in rules
            .iter_mut()
            .filter(|r| matches!(r.kind, FaultKind::MsgDrop | FaultKind::MsgCorrupt))
            .filter(|r| r.site == site)
        {
            r.seen += 1;
            let occurrence = r.seen;
            if r.fire_at(occurrence, true) {
                match r.kind {
                    FaultKind::MsgDrop => out = MsgFault::Drop,
                    FaultKind::MsgCorrupt if out == MsgFault::None => out = MsgFault::Corrupt,
                    _ => {}
                }
            }
        }
        out
    })
}

/// Consume a pending worker panic armed by [`begin_iter`].  Called by
/// the parallel runtime at region entry; one thread-local read when
/// nothing is armed.
pub fn take_worker_panic() -> bool {
    PANIC_ARMED.with(|c| {
        if c.get() {
            c.set(false);
            true
        } else {
            false
        }
    })
}

/// Whether a worker panic is armed but not yet consumed (introspection
/// for tests).
pub fn worker_panic_armed() -> bool {
    PANIC_ARMED.with(|c| c.get())
}

/// Fault check for an IO site (`snapshot_save`, `snapshot_load`):
/// returns the injected error when an `io_error@site` rule fires for
/// this occurrence.  No-op when disabled.
pub fn check_io(site: &str) -> std::io::Result<()> {
    if !enabled() {
        return Ok(());
    }
    let fire = with_plan(|rules| {
        let mut fire = false;
        for r in rules
            .iter_mut()
            .filter(|r| r.kind == FaultKind::IoError && r.site == site)
        {
            r.seen += 1;
            let occurrence = r.seen;
            if r.fire_at(occurrence, true) {
                fire = true;
            }
        }
        fire
    });
    if fire {
        return Err(std::io::Error::other(format!(
            "injected io_error at {site} (PHAST_FAULT)"
        )));
    }
    Ok(())
}

/// Fault check for a value site (`loss`): returns `f32::NAN` in place of
/// `value` when a `nan@site` rule fires for this occurrence.  No-op when
/// disabled.
pub fn corrupt_value(site: &str, value: f32) -> f32 {
    if !enabled() {
        return value;
    }
    let fire = with_plan(|rules| {
        let mut fire = false;
        for r in rules
            .iter_mut()
            .filter(|r| r.kind == FaultKind::Nan && r.site == site)
        {
            r.seen += 1;
            let occurrence = r.seen;
            if r.fire_at(occurrence, true) {
                fire = true;
            }
        }
        fire
    });
    if fire {
        f32::NAN
    } else {
        value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default_and_zero_armed() {
        // No PHAST_FAULT in the test environment: everything passes
        // through untouched.
        assert!(!take_worker_panic());
        assert!(check_io("snapshot_save").is_ok());
        assert_eq!(corrupt_value("loss", 1.5), 1.5);
    }

    #[test]
    fn iter_rule_fires_exactly_once() {
        with_faults("worker_panic@iter=3", || {
            begin_iter(0);
            assert!(!take_worker_panic());
            begin_iter(3);
            assert!(take_worker_panic());
            assert!(!take_worker_panic(), "consumed");
            begin_iter(3); // replay after rollback: must not re-fire
            assert!(!take_worker_panic());
        });
    }

    #[test]
    fn io_rule_first_k_occurrences() {
        with_faults("io_error@snapshot_save:2", || {
            assert!(check_io("snapshot_save").is_err());
            assert!(check_io("snapshot_save").is_err());
            assert!(check_io("snapshot_save").is_ok());
            // other sites untouched
            assert!(check_io("snapshot_load").is_ok());
        });
    }

    #[test]
    fn nan_rule_hits_exact_occurrence() {
        with_faults("nan@loss=2", || {
            assert_eq!(corrupt_value("loss", 0.5), 0.5);
            assert!(corrupt_value("loss", 0.5).is_nan());
            assert_eq!(corrupt_value("loss", 0.5), 0.5);
        });
    }

    #[test]
    fn malformed_rules_are_skipped() {
        with_faults("bogus, worker_panic@, nan@loss=x, io_error@snapshot_load", || {
            // only the well-formed always-fire rule survives
            assert!(check_io("snapshot_load").is_err());
            assert!(!take_worker_panic());
        });
    }

    #[test]
    fn comma_separated_plans_compose_independent_rules() {
        // The ISSUE 9 grammar: several rules in one spec, each keeping
        // its own site, trigger, and firing state.
        with_faults("worker_panic@iter=2,io_error@snapshot_save:1,nan@loss=2", || {
            begin_iter(0);
            assert!(!take_worker_panic());
            assert!(check_io("snapshot_save").is_err()); // :1 → first attempt
            assert!(check_io("snapshot_save").is_ok());
            assert_eq!(corrupt_value("loss", 0.5), 0.5);
            begin_iter(2);
            assert!(take_worker_panic());
            assert!(corrupt_value("loss", 0.5).is_nan());
            begin_iter(2); // =N rules stay fired after replay
            assert!(!take_worker_panic());
        });
    }

    #[test]
    fn single_rule_spec_parses_as_before() {
        // No commas: byte-compatible with the PR 6 single-spec grammar,
        // including surrounding whitespace tolerance.
        with_faults("  io_error@snapshot_load=2  ", || {
            assert!(check_io("snapshot_load").is_ok());
            assert!(check_io("snapshot_load").is_err());
            assert!(check_io("snapshot_load").is_ok());
        });
    }

    #[test]
    fn worker_exit_is_ignored_without_process_opt_in() {
        // This test process never calls allow_process_exit(), so the
        // rule must warn and fall through instead of killing the whole
        // test binary.
        with_faults("worker_exit@iter=1", || {
            begin_iter(1);
            begin_iter(2);
        });
    }

    #[test]
    fn msg_faults_count_occurrences_per_site() {
        with_faults("msg_corrupt@send=2,msg_drop@recv:1", || {
            assert_eq!(check_msg("send"), MsgFault::None);
            assert_eq!(check_msg("send"), MsgFault::Corrupt);
            assert_eq!(check_msg("send"), MsgFault::None);
            assert_eq!(check_msg("recv"), MsgFault::Drop);
            assert_eq!(check_msg("recv"), MsgFault::None);
        });
    }

    #[test]
    fn msg_drop_wins_over_corrupt_on_the_same_message() {
        with_faults("msg_corrupt@send,msg_drop@send", || {
            assert_eq!(check_msg("send"), MsgFault::Drop);
        });
    }

    #[test]
    fn with_faults_restores_previous_plan() {
        with_faults("nan@loss", || {
            assert!(corrupt_value("loss", 1.0).is_nan());
            with_faults("io_error@snapshot_save", || {
                // inner scope replaces the plan entirely
                assert_eq!(corrupt_value("loss", 1.0), 1.0);
                assert!(check_io("snapshot_save").is_err());
            });
            assert!(corrupt_value("loss", 1.0).is_nan());
        });
        assert_eq!(corrupt_value("loss", 1.0), 1.0);
    }
}
