//! Blocked multi-threaded GeMM — the OpenBLAS stand-in of the native
//! baseline (Table 2's "Caffe" rows run multi-threaded OpenBLAS, so the
//! honest reproduction must be multi-core too).
//!
//! `C = alpha * op(A) * op(B) + beta * C`, f32, row-major storage.  The
//! kernel blocks over K and N to keep the B panel in L1/L2 cache and lets
//! LLVM auto-vectorize the inner j-loop (contiguous in both B and C).
//! Transposed operands are handled by packing the transposed panel once —
//! not by strided access in the hot loop.
//!
//! Parallelism ([`ops::par`](super::par)): C is split into contiguous
//! M-row blocks, one pool worker per block; A and the packed B panel
//! are shared read-only.  Because each row of C is computed with the
//! identical k-ordering regardless of the split, the result is bitwise
//! independent of the thread count.  Tuning knobs: `PHAST_NUM_THREADS`
//! and `PHAST_GEMM_GRAIN` (minimum rows per worker).  Small products
//! (`m*n*k < GEMM_PAR_MIN_FLOPS`) and GeMMs issued from inside another
//! parallel region (e.g. per-sample conv GeMMs) stay serial.
//!
//! `gemm_colmajor_b` consumes a column-major B panel, the layout OpenBLAS
//! prefers; the PHAST boundary in `phast::` pays an explicit conversion to
//! call it — reproducing the per-crossing transpose the paper blames for a
//! large share of the partial-port slowdown (§4.3).

use super::par;

/// Operand transposition flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    /// Operand is used as stored (row-major, no transpose).
    No,
    /// Operand is transposed before the product (packed once, not strided).
    Yes,
}

const KC: usize = 256; // K-panel
const NC: usize = 512; // N-panel (fits L1 with KC in L2)

/// Minimum rows of C per worker (`PHAST_GEMM_GRAIN` overrides).
static GEMM_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_GEMM_GRAIN", 8);

/// Below this many multiply-adds the spawn cost beats the speedup.
const GEMM_PAR_MIN_FLOPS: usize = 1 << 17;

/// C(m,n) = alpha * op(A)(m,k) * op(B)(k,n) + beta * C.
///
/// `a` is (m,k) row-major if `ta == No`, else (k,m); likewise for `b`.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert_eq!(c.len(), m * n, "C size");
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");

    if beta == 0.0 {
        c.iter_mut().for_each(|v| *v = 0.0);
    } else if beta != 1.0 {
        c.iter_mut().for_each(|v| *v *= beta);
    }

    // Pack transposed operands once so the kernel always reads row-major
    // (m,k) x (k,n).
    let a_packed;
    let a_rm: &[f32] = match ta {
        Trans::No => a,
        Trans::Yes => {
            a_packed = transpose(a, k, m);
            &a_packed
        }
    };
    let b_packed;
    let b_rm: &[f32] = match tb {
        Trans::No => b,
        Trans::Yes => {
            b_packed = transpose(b, n, k);
            &b_packed
        }
    };

    // One contiguous M-row block of C per worker; each block runs the
    // identical blocked i-k-j kernel, so any thread count produces the
    // same bits.
    let tune = par::Tuning::new(GEMM_GRAIN.get());
    if m * n * k >= GEMM_PAR_MIN_FLOPS && tune.workers(m) > 1 {
        par::parallel_chunks_mut(c, n, tune, |rows, c_block| {
            gemm_rows(a_rm, b_rm, alpha, rows.start, k, n, c_block);
        });
    } else {
        gemm_rows(a_rm, b_rm, alpha, 0, k, n, c);
    }
}

/// Blocked i-k-j microkernel over the row block `c_block`, which holds
/// `c_block.len() / n` consecutive rows of C starting at `row0`.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a_rm: &[f32],
    b_rm: &[f32],
    alpha: f32,
    row0: usize,
    k: usize,
    n: usize,
    c_block: &mut [f32],
) {
    let rows = c_block.len() / n.max(1);
    for kb in (0..k).step_by(KC) {
        let kmax = (kb + KC).min(k);
        for nb in (0..n).step_by(NC) {
            let nmax = (nb + NC).min(n);
            for bi in 0..rows {
                let i = row0 + bi;
                let arow = &a_rm[i * k..(i + 1) * k];
                let crow = &mut c_block[bi * n + nb..bi * n + nmax];
                let mut kk = kb;
                while kk + 4 <= kmax {
                    let (a0, a1, a2, a3) = (
                        alpha * arow[kk],
                        alpha * arow[kk + 1],
                        alpha * arow[kk + 2],
                        alpha * arow[kk + 3],
                    );
                    let b0 = &b_rm[kk * n + nb..kk * n + nmax];
                    let b1 = &b_rm[(kk + 1) * n + nb..(kk + 1) * n + nmax];
                    let b2 = &b_rm[(kk + 2) * n + nb..(kk + 2) * n + nmax];
                    let b3 = &b_rm[(kk + 3) * n + nb..(kk + 3) * n + nmax];
                    for j in 0..crow.len() {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    kk += 4;
                }
                while kk < kmax {
                    let av = alpha * arow[kk];
                    let brow = &b_rm[kk * n + nb..kk * n + nmax];
                    for j in 0..crow.len() {
                        crow[j] += av * brow[j];
                    }
                    kk += 1;
                }
            }
        }
    }
}

/// GeMM whose B operand is stored **column-major** (OpenBLAS-friendly).
/// C(m,n) += A(m,k) * B_cm(k,n), with `b_cm[j*k + l] = B[l][j]`.
pub fn gemm_colmajor_b(m: usize, n: usize, k: usize, a: &[f32], b_cm: &[f32], c: &mut [f32]) {
    assert_eq!(b_cm.len(), k * n);
    // A column-major B is exactly a row-major (n,k) matrix = B^T.
    gemm(Trans::No, Trans::Yes, m, n, k, 1.0, a, b_cm, 0.0, c);
}

/// Row-major transpose: input is (r, c), output (c, r).
pub fn transpose(x: &[f32], r: usize, c: usize) -> Vec<f32> {
    assert_eq!(x.len(), r * c);
    let mut out = vec![0.0f32; r * c];
    // Tile for cache friendliness.
    const T: usize = 32;
    for i0 in (0..r).step_by(T) {
        for j0 in (0..c).step_by(T) {
            for i in i0..(i0 + T).min(r) {
                for j in j0..(j0 + T).min(c) {
                    out[j * r + i] = x[i * c + j];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{assert_close, forall, Rng};

    fn naive(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for l in 0..k {
                    let av = match ta {
                        Trans::No => a[i * k + l],
                        Trans::Yes => a[l * m + i],
                    };
                    let bv = match tb {
                        Trans::No => b[l * n + j],
                        Trans::Yes => b[j * k + l],
                    };
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_all_transposes() {
        forall("gemm-vs-naive", 24, |rng: &mut Rng| {
            let m = rng.range(1, 33);
            let n = rng.range(1, 33);
            let k = rng.range(1, 65);
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            for (ta, tb) in [
                (Trans::No, Trans::No),
                (Trans::Yes, Trans::No),
                (Trans::No, Trans::Yes),
                (Trans::Yes, Trans::Yes),
            ] {
                let mut c = vec![0.0f32; m * n];
                gemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                let want = naive(ta, tb, m, n, k, &a, &b);
                assert_close(&c, &want, 1e-4, 1e-4);
            }
        });
    }

    #[test]
    fn alpha_beta() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![10.0, 10.0, 10.0, 10.0];
        gemm(Trans::No, Trans::No, 2, 2, 2, 2.0, &a, &b, 0.5, &mut c);
        assert_eq!(c, vec![7.0, 9.0, 11.0, 13.0]);
    }

    #[test]
    fn colmajor_b_equals_transposed() {
        forall("gemm-colmajor", 12, |rng: &mut Rng| {
            let m = rng.range(1, 17);
            let n = rng.range(1, 17);
            let k = rng.range(1, 33);
            let a = rng.normal_vec(m * k);
            let b_rm = rng.normal_vec(k * n); // (k, n) row-major
            let b_cm = transpose(&b_rm, k, n); // (n, k) = column-major B
            let mut c1 = vec![0.0f32; m * n];
            gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b_rm, 0.0, &mut c1);
            let mut c2 = vec![0.0f32; m * n];
            gemm_colmajor_b(m, n, k, &a, &b_cm, &mut c2);
            assert_close(&c1, &c2, 1e-4, 1e-4);
        });
    }

    #[test]
    fn transpose_roundtrip() {
        forall("transpose", 8, |rng: &mut Rng| {
            let r = rng.range(1, 50);
            let c = rng.range(1, 50);
            let x = rng.normal_vec(r * c);
            let t = transpose(&x, r, c);
            let back = transpose(&t, c, r);
            assert_eq!(x, back);
        });
    }
}
