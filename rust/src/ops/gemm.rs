//! Packed register-tiled GeMM — the OpenBLAS stand-in of the native
//! baseline (Table 2's "Caffe" rows run multi-threaded OpenBLAS, so the
//! honest reproduction must close the gap the same way vendor BLAS does:
//! explicit panel packing plus a register-resident microkernel).
//!
//! `C = alpha * op(A) * op(B) + beta * C`, f32, row-major storage.
//!
//! # Engine shape (BLIS-style)
//!
//! * **Microkernel** — an [`MR`]×[`NR`] tile of C is accumulated in a
//!   register block across a whole K panel and written to C exactly once
//!   per panel; the first K panel folds the `beta` scale into that write,
//!   so there is no separate beta sweep over C.
//! * **Packing** — A is packed into `MR`-row micro-panels and B into
//!   `NR`-column micro-panels (k-major within a panel), so the
//!   microkernel only ever reads unit-stride memory.  Transposed operands
//!   are packed straight from their strided layout — never materialized
//!   as a full transposed copy.  Pack scratch lives in reusable
//!   thread-local buffers ([`par::with_pack_buf_a`] /
//!   [`par::with_pack_buf_b`]): no per-call allocations.
//! * **Blocking** — `MC`/`KC`/`NC` cache-blocking knobs, overridable via
//!   `PHAST_GEMM_MC` / `PHAST_GEMM_KC` / `PHAST_GEMM_NC` (read once,
//!   like every other PHAST knob; see [`blocking`]).
//! * **Persistent weight packing** — [`PackedMat`] keeps an operand
//!   packed across calls, keyed by a version stamp
//!   (`Blob::data_version`): layers cache their constant weight panels
//!   and repack only when the solver actually updates the weights,
//!   instead of re-transposing W on every forward/backward
//!   ([`gemm_packed_a`] / [`gemm_packed_b`]).  [`repack_count`] exposes a
//!   per-thread repack tally (the `packs_per_forward` bench metric).
//!
//! # Parallelism and determinism
//!
//! The [`ops::par`](super::par) contract is unchanged: C splits into
//! contiguous M-row blocks, one pool worker per block, A and the packed
//! B panels shared read-only.  Every row of C is accumulated with the
//! identical K ordering (K panels ascending, k ascending within a panel,
//! one register accumulator per row) no matter which worker owns it or
//! where tile boundaries fall, so results are **bitwise independent of
//! the thread count**.  Small products (`m*n*k < GEMM_PAR_MIN_FLOPS`)
//! and GeMMs issued from inside another parallel region (per-sample conv
//! GeMMs) stay serial.  Tuning knobs: `PHAST_NUM_THREADS`,
//! `PHAST_GEMM_GRAIN` (minimum rows per worker), and the blocking knobs
//! above.
//!
//! `gemm_colmajor_b` consumes a column-major B panel, the layout OpenBLAS
//! prefers; the PHAST boundary in `phast::` pays an explicit conversion to
//! call it — reproducing the per-crossing transpose the paper blames for a
//! large share of the partial-port slowdown (§4.3).  The old
//! transpose-then-sweep engine survives as [`gemm_unpacked`], the
//! comparison baseline for `benches/gemm.rs` (like `parallel_for_spawn`
//! for the pool).

use std::cell::Cell;
use std::ops::Range;

use super::par;

/// Operand transposition flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    /// Operand is used as stored (row-major, no transpose).
    No,
    /// Operand is transposed before the product (packed from its strided
    /// layout — never materialized as a full transposed copy).
    Yes,
}

/// Microkernel tile height: rows of C accumulated per register block.
pub const MR: usize = 4;
/// Microkernel tile width: columns of C accumulated per register block.
pub const NR: usize = 16;

/// Minimum rows of C per worker (`PHAST_GEMM_GRAIN` overrides).
static GEMM_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_GEMM_GRAIN", 8);
/// M cache-block: rows of A kept hot per packed block (`PHAST_GEMM_MC`).
static GEMM_MC: par::GrainKnob = par::GrainKnob::new("PHAST_GEMM_MC", 64);
/// K cache-block: depth of one packed panel (`PHAST_GEMM_KC`).
static GEMM_KC: par::GrainKnob = par::GrainKnob::new("PHAST_GEMM_KC", 256);
/// N cache-block: B columns swept per A block (`PHAST_GEMM_NC`).
static GEMM_NC: par::GrainKnob = par::GrainKnob::new("PHAST_GEMM_NC", 512);

/// Below this many multiply-adds the dispatch cost beats the speedup.
/// Public so the execution planner can predict which GeMMs dispatch a
/// parallel region (`net::plan`'s backward region counts).
pub const GEMM_PAR_MIN_FLOPS: usize = 1 << 17;

/// The resolved row grain (`PHAST_GEMM_GRAIN`, default 8) — exposed for
/// the planner's dispatch prediction alongside [`GEMM_PAR_MIN_FLOPS`].
pub fn gemm_grain() -> usize {
    GEMM_GRAIN.get()
}

thread_local! {
    /// Packing events ([`PackedMat::ensure`] misses) on this thread.
    static REPACKS: Cell<u64> = const { Cell::new(0) };
}

/// Number of [`PackedMat`] repacks performed by the calling thread so
/// far.  Monotonic per thread (packing always happens on the thread that
/// calls `ensure`, i.e. before any parallel dispatch), so benches and
/// tests can diff it around a region without cross-test interference:
/// `packs_per_forward` must be 0 on repeated forwards with frozen
/// weights.
pub fn repack_count() -> u64 {
    REPACKS.with(Cell::get)
}

/// Cache-blocking parameters, resolved once from the env knobs.
#[derive(Clone, Copy, Debug)]
struct Blocking {
    mc: usize,
    kc: usize,
    nc: usize,
}

fn blocking_params() -> Blocking {
    Blocking {
        // MC and NC are rounded down to whole micro-tiles so cache blocks
        // never split a micro-panel.
        mc: (GEMM_MC.get() / MR * MR).max(MR),
        kc: GEMM_KC.get().max(1),
        nc: (GEMM_NC.get() / NR * NR).max(NR),
    }
}

/// The resolved `(MC, KC, NC)` cache-blocking triple after the
/// `PHAST_GEMM_MC`/`PHAST_GEMM_KC`/`PHAST_GEMM_NC` env overrides (MC and
/// NC rounded to whole `MR`/`NR` micro-tiles).  Blocking never changes
/// results — only which K ordering is *shared* by every row (KC) and how
/// panels are traversed (MC/NC) — so the knobs are safe to sweep.
pub fn blocking() -> (usize, usize, usize) {
    let b = blocking_params();
    (b.mc, b.kc, b.nc)
}

/// Read-only view of `op(X)`: logical `(rows, cols)` over row-major
/// storage, transposed when `trans` (stored `(cols, rows)`).
#[derive(Clone, Copy)]
struct View<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
    trans: bool,
}

impl View<'_> {
    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        if self.trans {
            self.data[c * self.rows + r]
        } else {
            self.data[r * self.cols + c]
        }
    }
}

// ---------------------------------------------------------------------------
// Panel packing.
// ---------------------------------------------------------------------------
//
// Full-operand pack layout (shared by the on-the-fly path and PackedMat):
// K panels of height `kc` in ascending order; within a K panel, MR-row
// (A) or NR-column (B) micro-panels in ascending order; within a
// micro-panel, k-major (`buf[p * MR + r]` / `buf[p * NR + j]`), zero-
// padded to the full MR/NR width at the ragged edge.  Because every K
// panel except the last has exactly `kc_blk` rows, the offset of K panel
// `p0` is simply `p0 * panels * width`.

/// One MR-row micro-panel of op(A): rows `rbase..rbase+MR` (zero-padded
/// past `a.rows`), cols `p0..p0+kc`, stored k-major into `dst`.
fn pack_a_panel(a: View<'_>, rbase: usize, p0: usize, kc: usize, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), kc * MR);
    let rmax = MR.min(a.rows.saturating_sub(rbase));
    for p in 0..kc {
        let d = &mut dst[p * MR..(p + 1) * MR];
        for (r, dv) in d[..rmax].iter_mut().enumerate() {
            *dv = a.at(rbase + r, p0 + p);
        }
        for dv in &mut d[rmax..] {
            *dv = 0.0;
        }
    }
}

/// One NR-column micro-panel of op(B): rows `p0..p0+kc`, cols
/// `cbase..cbase+NR` (zero-padded past `b.cols`), stored k-major.
fn pack_b_panel(b: View<'_>, p0: usize, kc: usize, cbase: usize, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), kc * NR);
    let cmax = NR.min(b.cols.saturating_sub(cbase));
    for p in 0..kc {
        let d = &mut dst[p * NR..(p + 1) * NR];
        for (j, dv) in d[..cmax].iter_mut().enumerate() {
            *dv = b.at(p0 + p, cbase + j);
        }
        for dv in &mut d[cmax..] {
            *dv = 0.0;
        }
    }
}

/// Pack all of op(A) (`m` × `k`) into the full-operand layout.
fn pack_a_full(a: View<'_>, m: usize, k: usize, kc_blk: usize, buf: &mut [f32]) {
    let rt = m.div_ceil(MR);
    let mut p0 = 0;
    while p0 < k {
        let kc = kc_blk.min(k - p0);
        let koff = p0 * rt * MR;
        for t in 0..rt {
            pack_a_panel(a, t * MR, p0, kc, &mut buf[koff + t * kc * MR..koff + (t + 1) * kc * MR]);
        }
        p0 += kc;
    }
}

/// Pack all of op(B) (`k` × `n`) into the full-operand layout.
fn pack_b_full(b: View<'_>, k: usize, n: usize, kc_blk: usize, buf: &mut [f32]) {
    let ct = n.div_ceil(NR);
    let mut p0 = 0;
    while p0 < k {
        let kc = kc_blk.min(k - p0);
        let koff = p0 * ct * NR;
        for t in 0..ct {
            pack_b_panel(b, p0, kc, t * NR, &mut buf[koff + t * kc * NR..koff + (t + 1) * kc * NR]);
        }
        p0 += kc;
    }
}

// ---------------------------------------------------------------------------
// Microkernel + tile writeback.
// ---------------------------------------------------------------------------

/// Accumulate one MR×NR tile over a K panel of depth `kc`: `acc` lives in
/// registers for the whole panel (the point of the engine) and is only
/// spilled by [`write_tile`].  Per accumulator slot the k ordering is
/// ascending — identical for every row and every tile, which is what
/// keeps results bitwise independent of the M partition.
#[inline(always)]
fn microkernel(apanel: &[f32], bpanel: &[f32], kc: usize, acc: &mut [[f32; NR]; MR]) {
    *acc = [[0.0; NR]; MR];
    for p in 0..kc {
        let a = &apanel[p * MR..(p + 1) * MR];
        let b = &bpanel[p * NR..(p + 1) * NR];
        for (ar, accrow) in a.iter().zip(acc.iter_mut()) {
            for (av, bv) in accrow.iter_mut().zip(b) {
                *av += *ar * *bv;
            }
        }
    }
}

/// How a K panel's contribution lands in C.
#[derive(Clone, Copy)]
enum CMode {
    /// First K panel, `beta == 0`: overwrite (C may hold garbage/NaN).
    Store,
    /// First K panel, `beta != 0`: `c = beta * c + alpha * acc` — the
    /// beta sweep folded into the first panel's writeback.
    Scale(f32),
    /// Later K panels: accumulate.
    Accum,
}

/// Spill `acc` rows `rlo..rhi` / cols `cbase..cbase+ccnt` into the
/// worker's C block (whose first row is absolute row `block_row0`).
#[allow(clippy::too_many_arguments)]
fn write_tile(
    acc: &[[f32; NR]; MR],
    c_block: &mut [f32],
    n: usize,
    block_row0: usize,
    tile_row0: usize,
    rlo: usize,
    rhi: usize,
    cbase: usize,
    ccnt: usize,
    alpha: f32,
    mode: CMode,
) {
    for r in rlo..rhi {
        let row = tile_row0 + r - block_row0;
        let start = row * n + cbase;
        let crow = &mut c_block[start..start + ccnt];
        let arow = &acc[r][..ccnt];
        match mode {
            CMode::Store => {
                for (cv, av) in crow.iter_mut().zip(arow) {
                    *cv = alpha * *av;
                }
            }
            CMode::Scale(beta) => {
                for (cv, av) in crow.iter_mut().zip(arow) {
                    *cv = beta * *cv + alpha * *av;
                }
            }
            CMode::Accum => {
                for (cv, av) in crow.iter_mut().zip(arow) {
                    *cv += alpha * *av;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Row-block kernel (one worker's share).
// ---------------------------------------------------------------------------

/// Where the packed A panels for a row block come from.
#[derive(Clone, Copy)]
enum ASource<'a> {
    /// Pack on the fly from the raw operand into the worker's
    /// thread-local buffer, one MC block at a time (micro-tile grid
    /// anchored at each block's first row).
    Raw(View<'a>),
    /// Pre-packed full operand ([`PackedMat`]) on the global MR grid;
    /// tiles straddling a worker boundary are computed in full but only
    /// the worker's own rows are written (per-row math is position-
    /// independent, so this costs a few duplicate flops, never changes a
    /// result).
    Packed(&'a [f32]),
}

/// Compute rows `rows` of C (the worker's contiguous block `c_block`)
/// against the fully packed B in `bpack`.
#[allow(clippy::too_many_arguments)]
fn run_rows(
    rows: Range<usize>,
    c_block: &mut [f32],
    a: ASource<'_>,
    bpack: &[f32],
    m: usize,
    n: usize,
    k: usize,
    blk: Blocking,
    alpha: f32,
    beta: f32,
) {
    debug_assert!(!rows.is_empty());
    debug_assert_eq!(c_block.len(), rows.len() * n);
    let ct = n.div_ceil(NR);
    let nc_panels = (blk.nc / NR).max(1);
    let mut acc = [[0.0f32; NR]; MR];
    let mut p0 = 0;
    while p0 < k {
        let kc = blk.kc.min(k - p0);
        let mode = if p0 == 0 {
            if beta == 0.0 {
                CMode::Store
            } else {
                CMode::Scale(beta)
            }
        } else {
            CMode::Accum
        };
        let b_koff = p0 * ct * NR;
        match a {
            ASource::Raw(av) => {
                par::with_pack_buf_a(blk.mc.div_ceil(MR) * MR * kc, |abuf| {
                    let mut i0 = rows.start;
                    while i0 < rows.end {
                        let mc = blk.mc.min(rows.end - i0);
                        let tiles = mc.div_ceil(MR);
                        for t in 0..tiles {
                            pack_a_panel(
                                av,
                                i0 + t * MR,
                                p0,
                                kc,
                                &mut abuf[t * kc * MR..(t + 1) * kc * MR],
                            );
                        }
                        let mut tc0 = 0;
                        while tc0 < ct {
                            let tcnt = nc_panels.min(ct - tc0);
                            for tc in tc0..tc0 + tcnt {
                                let bpanel =
                                    &bpack[b_koff + tc * kc * NR..b_koff + (tc + 1) * kc * NR];
                                let cbase = tc * NR;
                                let ccnt = NR.min(n - cbase);
                                for t in 0..tiles {
                                    microkernel(
                                        &abuf[t * kc * MR..(t + 1) * kc * MR],
                                        bpanel,
                                        kc,
                                        &mut acc,
                                    );
                                    let tile_row0 = i0 + t * MR;
                                    let rhi = MR.min(rows.end - tile_row0);
                                    write_tile(
                                        &acc, c_block, n, rows.start, tile_row0, 0, rhi, cbase,
                                        ccnt, alpha, mode,
                                    );
                                }
                            }
                            tc0 += tcnt;
                        }
                        i0 += mc;
                    }
                });
            }
            ASource::Packed(pbuf) => {
                let rt = m.div_ceil(MR);
                let a_koff = p0 * rt * MR;
                let rt_lo = rows.start / MR;
                let rt_hi = (rows.end - 1) / MR;
                let mc_tiles = (blk.mc / MR).max(1);
                let mut tg = rt_lo;
                while tg <= rt_hi {
                    let tg_end = (tg + mc_tiles - 1).min(rt_hi);
                    let mut tc0 = 0;
                    while tc0 < ct {
                        let tcnt = nc_panels.min(ct - tc0);
                        for tc in tc0..tc0 + tcnt {
                            let bpanel = &bpack[b_koff + tc * kc * NR..b_koff + (tc + 1) * kc * NR];
                            let cbase = tc * NR;
                            let ccnt = NR.min(n - cbase);
                            for t in tg..=tg_end {
                                microkernel(
                                    &pbuf[a_koff + t * kc * MR..a_koff + (t + 1) * kc * MR],
                                    bpanel,
                                    kc,
                                    &mut acc,
                                );
                                let tile_row0 = t * MR;
                                let rlo = rows.start.saturating_sub(tile_row0);
                                let rhi = MR.min(rows.end - tile_row0);
                                write_tile(
                                    &acc, c_block, n, rows.start, tile_row0, rlo, rhi, cbase,
                                    ccnt, alpha, mode,
                                );
                            }
                        }
                        tc0 += tcnt;
                    }
                    tg = tg_end + 1;
                }
            }
        }
        p0 += kc;
    }
}

/// Serial-or-parallel dispatch over contiguous M-row blocks of C (the
/// unchanged `ops::par` contract — see the module docs).
#[allow(clippy::too_many_arguments)]
fn dispatch(
    a: ASource<'_>,
    bpack: &[f32],
    m: usize,
    n: usize,
    k: usize,
    blk: Blocking,
    alpha: f32,
    beta: f32,
    c: &mut [f32],
) {
    let tune = par::Tuning::new(GEMM_GRAIN.get());
    if m * n * k >= GEMM_PAR_MIN_FLOPS && tune.workers(m) > 1 {
        par::parallel_chunks_mut(c, n, tune, |rows, c_block| {
            run_rows(rows, c_block, a, bpack, m, n, k, blk, alpha, beta);
        });
    } else {
        run_rows(0..m, c, a, bpack, m, n, k, blk, alpha, beta);
    }
}

/// Handle the degenerate shapes explicitly (the old `gemm_rows` silently
/// computed 0 rows via `len / n.max(1)` for `n == 0`).  Returns `true`
/// when the call is fully handled: `m == 0` / `n == 0` leave the empty C
/// untouched; `k == 0` means no product terms exist, so C is only scaled
/// by `beta` (zeroed for `beta == 0`, Caffe/BLAS semantics).
fn degenerate(m: usize, n: usize, k: usize, beta: f32, c: &mut [f32]) -> bool {
    if m == 0 || n == 0 {
        return true;
    }
    if k == 0 {
        if beta == 0.0 {
            c.iter_mut().for_each(|v| *v = 0.0);
        } else if beta != 1.0 {
            c.iter_mut().for_each(|v| *v *= beta);
        }
        return true;
    }
    false
}

// ---------------------------------------------------------------------------
// Public entry points.
// ---------------------------------------------------------------------------

/// C(m,n) = alpha * op(A)(m,k) * op(B)(k,n) + beta * C.
///
/// `a` is (m,k) row-major if `ta == No`, else (k,m); likewise for `b`.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert_eq!(c.len(), m * n, "C size");
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    if degenerate(m, n, k, beta, c) {
        return;
    }
    let av = View { data: a, rows: m, cols: k, trans: matches!(ta, Trans::Yes) };
    let bv = View { data: b, rows: k, cols: n, trans: matches!(tb, Trans::Yes) };
    let blk = blocking_params();
    let ct = n.div_ceil(NR);
    par::with_pack_buf_b(k * ct * NR, |bbuf| {
        pack_b_full(bv, k, n, blk.kc, bbuf);
        dispatch(ASource::Raw(av), bbuf, m, n, k, blk, alpha, beta, c);
    });
}

/// [`gemm`] with a pre-packed B operand: C = alpha * op(A) * B̂ + beta * C
/// where `pb` holds op(B) packed by [`PackedMat::ensure`] (side
/// [`PackSide::B`], dims `(k, n)`).  Skips all B packing — the
/// `InnerProductLayer` weight path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_b(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    pb: &PackedMat,
    beta: f32,
    c: &mut [f32],
) {
    assert_eq!(c.len(), m * n, "C size");
    assert_eq!(a.len(), m * k, "A size");
    assert!(matches!(pb.side, PackSide::B), "PackedMat packed for the wrong side");
    assert!(pb.stamp.is_some(), "PackedMat::ensure never called");
    assert_eq!((pb.k, pb.dim), (k, n), "PackedMat B dims");
    if degenerate(m, n, k, beta, c) {
        return;
    }
    let blk = blocking_params();
    debug_assert_eq!(pb.kc, blk.kc, "KC changed after packing");
    let av = View { data: a, rows: m, cols: k, trans: matches!(ta, Trans::Yes) };
    let ct = n.div_ceil(NR);
    dispatch(ASource::Raw(av), &pb.buf[..k * ct * NR], m, n, k, blk, alpha, beta, c);
}

/// [`gemm`] with a pre-packed A operand: C = alpha * Â * op(B) + beta * C
/// where `pa` holds op(A) packed by [`PackedMat::ensure`] (side
/// [`PackSide::A`], dims `(m, k)`).  Skips all A packing — the
/// `ConvLayer` weight path (per-sample GeMMs reuse one shared pack).
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_a(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    pa: &PackedMat,
    b: &[f32],
    tb: Trans,
    beta: f32,
    c: &mut [f32],
) {
    assert_eq!(c.len(), m * n, "C size");
    assert_eq!(b.len(), k * n, "B size");
    assert!(matches!(pa.side, PackSide::A), "PackedMat packed for the wrong side");
    assert!(pa.stamp.is_some(), "PackedMat::ensure never called");
    assert_eq!((pa.dim, pa.k), (m, k), "PackedMat A dims");
    if degenerate(m, n, k, beta, c) {
        return;
    }
    let blk = blocking_params();
    debug_assert_eq!(pa.kc, blk.kc, "KC changed after packing");
    let bv = View { data: b, rows: k, cols: n, trans: matches!(tb, Trans::Yes) };
    let ct = n.div_ceil(NR);
    let rt = m.div_ceil(MR);
    par::with_pack_buf_b(k * ct * NR, |bbuf| {
        pack_b_full(bv, k, n, blk.kc, bbuf);
        dispatch(ASource::Packed(&pa.buf[..k * rt * MR]), bbuf, m, n, k, blk, alpha, beta, c);
    });
}

/// Floats needed to hold op(B) (`k` × `n`) in the packed panel layout
/// ([`pack_b_slice`] / [`gemm_packed_b_slice`]): `NR`-column micro-panels,
/// zero-padded at the ragged edge.
pub fn packed_b_len(k: usize, n: usize) -> usize {
    k * n.div_ceil(NR) * NR
}

/// Pack op(B) (logical `(k, n)`; stored `(n, k)` row-major when
/// `trans == Yes`) into `dst` (length [`packed_b_len`]`(k, n)`), using the
/// process-wide KC blocking — **byte-identical** to the panels the raw
/// [`gemm`] entry point packs into its thread-local scratch, so a product
/// fed through [`gemm_packed_b_slice`] is bitwise-equal to the on-the-fly
/// path.  Unlike [`PackedMat::ensure`] this performs no version-stamp
/// bookkeeping and does **not** count toward [`repack_count`]: it is the
/// primitive for caller-managed pack caches whose source data changes
/// every iteration (e.g. the conv layer's per-sample im2col panels,
/// captured during forward for the backward `dW` product).
pub fn pack_b_slice(src: &[f32], trans: Trans, k: usize, n: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), k * n, "pack_b_slice source size");
    assert_eq!(dst.len(), packed_b_len(k, n), "pack_b_slice destination size");
    let view = View { data: src, rows: k, cols: n, trans: matches!(trans, Trans::Yes) };
    pack_b_full(view, k, n, blocking_params().kc, dst);
}

/// [`gemm`] whose B operand is a caller-held pre-packed panel slice
/// (filled by [`pack_b_slice`] with the same `(k, n)`): C = alpha *
/// op(A) * B̂ + beta * C.  Skips all B packing without the [`PackedMat`]
/// stamp machinery — the conv backward's persistent im2col-pack path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_b_slice(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    bpack: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert_eq!(c.len(), m * n, "C size");
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(bpack.len(), packed_b_len(k, n), "packed B size");
    if degenerate(m, n, k, beta, c) {
        return;
    }
    let blk = blocking_params();
    let av = View { data: a, rows: m, cols: k, trans: matches!(ta, Trans::Yes) };
    dispatch(ASource::Raw(av), bpack, m, n, k, blk, alpha, beta, c);
}

/// Which operand slot a [`PackedMat`] is packed for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackSide {
    /// The left operand: `MR`-row micro-panels ([`gemm_packed_a`]).
    A,
    /// The right operand: `NR`-column micro-panels ([`gemm_packed_b`]).
    B,
}

/// A persistently packed GeMM operand with a version stamp.
///
/// Layers own one per (weight, orientation) pair: [`PackedMat::ensure`]
/// is called with the source slice and the owning
/// blob's `data_version()` before every use, and repacks **only when the
/// stamp moved** (the solver updated the weights) or the shape/transpose
/// changed.  The buffer is grown in place and never shrunk, so a cache
/// hit is a handful of integer compares — turning the per-iteration
/// weight transpose of the old engine into a once-per-solver-step cost
/// shared by forward and backward.
#[derive(Clone, Debug)]
pub struct PackedMat {
    side: PackSide,
    trans: Trans,
    /// Panel-axis extent: `m` for side A, `n` for side B.
    dim: usize,
    k: usize,
    kc: usize,
    stamp: Option<u64>,
    buf: Vec<f32>,
}

impl PackedMat {
    /// An empty (never packed) handle for the given operand side.
    pub fn new(side: PackSide) -> PackedMat {
        PackedMat { side, trans: Trans::No, dim: 0, k: 0, kc: 0, stamp: None, buf: Vec::new() }
    }

    /// True once [`PackedMat::ensure`] has packed something.
    pub fn is_packed(&self) -> bool {
        self.stamp.is_some()
    }

    /// Make the pack current for `src` at `version`: a no-op when the
    /// stamp and shape already match (the hot path), a full repack
    /// otherwise.  For side A, `src` is op(A) with logical dims
    /// `(dim, k)` (stored transposed when `trans == Yes`); for side B,
    /// op(B) with logical dims `(k, dim)`.  Returns `true` when a repack
    /// happened (also counted in [`repack_count`]).
    pub fn ensure(
        &mut self,
        src: &[f32],
        trans: Trans,
        dim: usize,
        k: usize,
        version: u64,
    ) -> bool {
        if self.stamp == Some(version) && (self.dim, self.k, self.trans) == (dim, k, trans) {
            return false;
        }
        assert_eq!(src.len(), dim * k, "PackedMat source size");
        let blk = blocking_params();
        self.trans = trans;
        self.dim = dim;
        self.k = k;
        self.kc = blk.kc;
        let trans_flag = matches!(trans, Trans::Yes);
        match self.side {
            PackSide::A => {
                let need = k * dim.div_ceil(MR) * MR;
                if self.buf.len() < need {
                    self.buf.resize(need, 0.0);
                }
                let view = View { data: src, rows: dim, cols: k, trans: trans_flag };
                pack_a_full(view, dim, k, blk.kc, &mut self.buf[..need]);
            }
            PackSide::B => {
                let need = k * dim.div_ceil(NR) * NR;
                if self.buf.len() < need {
                    self.buf.resize(need, 0.0);
                }
                let view = View { data: src, rows: k, cols: dim, trans: trans_flag };
                pack_b_full(view, k, dim, blk.kc, &mut self.buf[..need]);
            }
        }
        self.stamp = Some(version);
        REPACKS.with(|c| c.set(c.get() + 1));
        true
    }
}

/// GeMM whose B operand is stored **column-major** (OpenBLAS-friendly).
/// C(m,n) += A(m,k) * B_cm(k,n), with `b_cm[j*k + l] = B[l][j]`.
/// The packed engine consumes the column-major panel directly (a
/// column-major B is exactly a row-major (n,k) matrix = Bᵀ) — no
/// intermediate transposed copy is materialized on the native side; the
/// conversion the `phast::` boundary pays on top of this call is the
/// *deliberate* §4.3 reproduction, not an engine cost.
pub fn gemm_colmajor_b(m: usize, n: usize, k: usize, a: &[f32], b_cm: &[f32], c: &mut [f32]) {
    assert_eq!(b_cm.len(), k * n);
    gemm(Trans::No, Trans::Yes, m, n, k, 1.0, a, b_cm, 0.0, c);
}

/// Row-major transpose: input is (r, c), output (c, r).
pub fn transpose(x: &[f32], r: usize, c: usize) -> Vec<f32> {
    assert_eq!(x.len(), r * c);
    let mut out = vec![0.0f32; r * c];
    // Tile for cache friendliness.
    const T: usize = 32;
    for i0 in (0..r).step_by(T) {
        for j0 in (0..c).step_by(T) {
            for i in i0..(i0 + T).min(r) {
                for j in j0..(j0 + T).min(c) {
                    out[j * r + i] = x[i * c + j];
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// The pre-packing engine, kept as the bench baseline.
// ---------------------------------------------------------------------------

/// The PR 1–3 era engine: full-operand transpose for `Trans::Yes`
/// (allocated per call), a separate beta sweep over C, and a blocked
/// i-k-j kernel with no register tiling.  Kept **only** as the
/// comparison baseline for `benches/gemm.rs` (the same role
/// `parallel_for_spawn` plays for the pool); no layer calls this.
#[allow(clippy::too_many_arguments)]
pub fn gemm_unpacked(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert_eq!(c.len(), m * n, "C size");
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");

    if beta == 0.0 {
        c.iter_mut().for_each(|v| *v = 0.0);
    } else if beta != 1.0 {
        c.iter_mut().for_each(|v| *v *= beta);
    }
    // Degenerate shapes: nothing to accumulate (and `gemm_rows` would
    // divide by n) — the beta sweep above already produced the answer.
    if m == 0 || n == 0 || k == 0 {
        return;
    }

    // Pack transposed operands once so the kernel always reads row-major
    // (m,k) x (k,n).
    let a_packed;
    let a_rm: &[f32] = match ta {
        Trans::No => a,
        Trans::Yes => {
            a_packed = transpose(a, k, m);
            &a_packed
        }
    };
    let b_packed;
    let b_rm: &[f32] = match tb {
        Trans::No => b,
        Trans::Yes => {
            b_packed = transpose(b, n, k);
            &b_packed
        }
    };

    let tune = par::Tuning::new(GEMM_GRAIN.get());
    if m * n * k >= GEMM_PAR_MIN_FLOPS && tune.workers(m) > 1 {
        par::parallel_chunks_mut(c, n, tune, |rows, c_block| {
            gemm_rows(a_rm, b_rm, alpha, rows.start, k, n, c_block);
        });
    } else {
        gemm_rows(a_rm, b_rm, alpha, 0, k, n, c);
    }
}

/// Blocked i-k-j kernel over the row block `c_block`, which holds
/// `c_block.len() / n` consecutive rows of C starting at `row0`.
/// Callers guarantee `n > 0` (degenerate shapes return early above).
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a_rm: &[f32],
    b_rm: &[f32],
    alpha: f32,
    row0: usize,
    k: usize,
    n: usize,
    c_block: &mut [f32],
) {
    const KC: usize = 256;
    const NC: usize = 512;
    debug_assert!(n > 0, "degenerate n must be handled by the caller");
    let rows = c_block.len() / n;
    for kb in (0..k).step_by(KC) {
        let kmax = (kb + KC).min(k);
        for nb in (0..n).step_by(NC) {
            let nmax = (nb + NC).min(n);
            for bi in 0..rows {
                let i = row0 + bi;
                let arow = &a_rm[i * k..(i + 1) * k];
                let crow = &mut c_block[bi * n + nb..bi * n + nmax];
                let mut kk = kb;
                while kk + 4 <= kmax {
                    let (a0, a1, a2, a3) = (
                        alpha * arow[kk],
                        alpha * arow[kk + 1],
                        alpha * arow[kk + 2],
                        alpha * arow[kk + 3],
                    );
                    let b0 = &b_rm[kk * n + nb..kk * n + nmax];
                    let b1 = &b_rm[(kk + 1) * n + nb..(kk + 1) * n + nmax];
                    let b2 = &b_rm[(kk + 2) * n + nb..(kk + 2) * n + nmax];
                    let b3 = &b_rm[(kk + 3) * n + nb..(kk + 3) * n + nmax];
                    for j in 0..crow.len() {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    kk += 4;
                }
                while kk < kmax {
                    let av = alpha * arow[kk];
                    let brow = &b_rm[kk * n + nb..kk * n + nmax];
                    for j in 0..crow.len() {
                        crow[j] += av * brow[j];
                    }
                    kk += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{assert_close, forall, Rng};

    fn naive(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for l in 0..k {
                    let av = match ta {
                        Trans::No => a[i * k + l],
                        Trans::Yes => a[l * m + i],
                    };
                    let bv = match tb {
                        Trans::No => b[l * n + j],
                        Trans::Yes => b[j * k + l],
                    };
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    const ALL_TRANS: [(Trans, Trans); 4] = [
        (Trans::No, Trans::No),
        (Trans::Yes, Trans::No),
        (Trans::No, Trans::Yes),
        (Trans::Yes, Trans::Yes),
    ];

    #[test]
    fn matches_naive_all_transposes() {
        forall("gemm-vs-naive", 24, |rng: &mut Rng| {
            let m = rng.range(1, 33);
            let n = rng.range(1, 33);
            let k = rng.range(1, 65);
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            for (ta, tb) in ALL_TRANS {
                let mut c = vec![0.0f32; m * n];
                gemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                let want = naive(ta, tb, m, n, k, &a, &b);
                assert_close(&c, &want, 1e-4, 1e-4);
            }
        });
    }

    /// Shapes straddling every microkernel and blocking edge: m around
    /// MR, n around NR, k around KC — ragged tiles, padded panels, and
    /// multi-K-panel accumulation all get hit, in all four transpose
    /// combos, against the triple-loop reference, at every supported
    /// thread width (edge shapes stay serial by the flop threshold, so
    /// the sweep additionally pins that the serial/parallel split never
    /// changes an answer).
    #[test]
    fn matches_naive_at_tile_and_panel_edges() {
        let (_, kc, _) = blocking();
        let ms = [1, MR - 1, MR, MR + 1, 2 * MR + 1];
        let ns = [1, NR - 1, NR, NR + 1, 2 * NR + 3];
        let ks = [1, 2, kc - 1, kc, kc + 1];
        let mut rng = Rng::new(0xedfe);
        for &m in &ms {
            for &n in &ns {
                for &k in &ks {
                    let a = rng.normal_vec(m * k);
                    let b = rng.normal_vec(k * n);
                    for (ta, tb) in ALL_TRANS {
                        let mut want = naive(ta, tb, m, n, k, &a, &b);
                        want.iter_mut().for_each(|v| *v += 0.5 * 0.25);
                        let mut serial = Vec::new();
                        for (i, threads) in [1usize, 2, 5, 16].into_iter().enumerate() {
                            let mut c = vec![0.25f32; m * n];
                            par::with_threads(threads, || {
                                gemm(ta, tb, m, n, k, 1.0, &a, &b, 0.5, &mut c);
                            });
                            assert_close(&c, &want, 1e-3, 1e-4);
                            if i == 0 {
                                serial = c;
                            } else {
                                assert_eq!(serial, c, "edge shape diverged at {threads} threads");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_explicit() {
        // m == 0 / n == 0: empty C, nothing to do (and nothing to panic on).
        let mut empty: Vec<f32> = vec![];
        gemm(Trans::No, Trans::No, 0, 5, 3, 1.0, &[], &[0.0; 15], 1.0, &mut empty);
        gemm(Trans::No, Trans::No, 4, 0, 3, 1.0, &[0.0; 12], &[], 1.0, &mut empty);
        gemm_unpacked(Trans::No, Trans::No, 0, 5, 3, 1.0, &[], &[0.0; 15], 1.0, &mut empty);
        gemm_unpacked(Trans::No, Trans::No, 4, 0, 3, 1.0, &[0.0; 12], &[], 1.0, &mut empty);
        // k == 0: C = beta * C for both engines.
        for beta in [0.0f32, 0.5, 1.0] {
            let mut c1 = vec![2.0f32; 6];
            gemm(Trans::No, Trans::No, 2, 3, 0, 1.0, &[], &[], beta, &mut c1);
            let mut c2 = vec![2.0f32; 6];
            gemm_unpacked(Trans::No, Trans::No, 2, 3, 0, 1.0, &[], &[], beta, &mut c2);
            assert_eq!(c1, vec![2.0 * beta; 6]);
            assert_eq!(c1, c2);
        }
    }

    #[test]
    fn alpha_beta() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![10.0, 10.0, 10.0, 10.0];
        gemm(Trans::No, Trans::No, 2, 2, 2, 2.0, &a, &b, 0.5, &mut c);
        assert_eq!(c, vec![7.0, 9.0, 11.0, 13.0]);
    }

    #[test]
    fn unpacked_baseline_matches_packed() {
        forall("gemm-unpacked-vs-packed", 12, |rng: &mut Rng| {
            let m = rng.range(1, 40);
            let n = rng.range(1, 40);
            let k = rng.range(1, 70);
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            for (ta, tb) in ALL_TRANS {
                let mut c1 = vec![1.0f32; m * n];
                gemm(ta, tb, m, n, k, 0.5, &a, &b, 2.0, &mut c1);
                let mut c2 = vec![1.0f32; m * n];
                gemm_unpacked(ta, tb, m, n, k, 0.5, &a, &b, 2.0, &mut c2);
                assert_close(&c1, &c2, 1e-3, 1e-4);
            }
        });
    }

    #[test]
    fn packed_b_matches_raw_bitwise() {
        forall("gemm-packed-b", 12, |rng: &mut Rng| {
            let m = rng.range(1, 20);
            let n = rng.range(1, 40);
            let k = rng.range(1, 40);
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            for tb in [Trans::No, Trans::Yes] {
                let mut want = vec![0.0f32; m * n];
                gemm(Trans::No, tb, m, n, k, 1.0, &a, &b, 0.0, &mut want);
                let mut pb = PackedMat::new(PackSide::B);
                assert!(pb.ensure(&b, tb, n, k, 7));
                let mut got = vec![0.0f32; m * n];
                gemm_packed_b(m, n, k, 1.0, &a, Trans::No, &pb, 0.0, &mut got);
                // Same packed layout, same per-row k order: bitwise equal.
                assert_eq!(want, got);
            }
        });
    }

    #[test]
    fn packed_a_matches_raw_bitwise() {
        forall("gemm-packed-a", 12, |rng: &mut Rng| {
            let m = rng.range(1, 40);
            let n = rng.range(1, 40);
            let k = rng.range(1, 40);
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            for ta in [Trans::No, Trans::Yes] {
                let mut want = vec![0.0f32; m * n];
                gemm(ta, Trans::No, m, n, k, 1.0, &a, &b, 0.0, &mut want);
                let mut pa = PackedMat::new(PackSide::A);
                assert!(pa.ensure(&a, ta, m, k, 3));
                let mut got = vec![0.0f32; m * n];
                gemm_packed_a(m, n, k, 1.0, &pa, &b, Trans::No, 0.0, &mut got);
                assert_eq!(want, got);
            }
        });
    }

    #[test]
    fn packed_b_slice_matches_raw_bitwise() {
        forall("gemm-packed-b-slice", 12, |rng: &mut Rng| {
            let m = rng.range(1, 20);
            let n = rng.range(1, 40);
            let k = rng.range(1, 40);
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            for tb in [Trans::No, Trans::Yes] {
                let mut want = vec![0.5f32; m * n];
                gemm(Trans::No, tb, m, n, k, 1.0, &a, &b, 2.0, &mut want);
                let mut bpack = vec![0.0f32; packed_b_len(k, n)];
                pack_b_slice(&b, tb, k, n, &mut bpack);
                let mut got = vec![0.5f32; m * n];
                gemm_packed_b_slice(m, n, k, 1.0, &a, Trans::No, &bpack, 2.0, &mut got);
                // Identical panel bytes, identical per-row K order.
                assert_eq!(want, got);
            }
        });
    }

    #[test]
    fn pack_b_slice_does_not_count_as_repack() {
        let b = vec![1.0f32; 6 * 8];
        let mut dst = vec![0.0f32; packed_b_len(6, 8)];
        let c0 = repack_count();
        pack_b_slice(&b, Trans::No, 6, 8, &mut dst);
        assert_eq!(repack_count(), c0, "caller-managed packs must not move the repack metric");
    }

    #[test]
    fn packed_mat_repacks_only_on_version_change() {
        let src = vec![1.0f32; 6 * 8];
        let mut p = PackedMat::new(PackSide::B);
        let c0 = repack_count();
        assert!(p.ensure(&src, Trans::No, 8, 6, 1), "first ensure must pack");
        assert!(!p.ensure(&src, Trans::No, 8, 6, 1), "same stamp must hit the cache");
        assert!(!p.ensure(&src, Trans::No, 8, 6, 1));
        assert_eq!(repack_count() - c0, 1, "cache hits must not repack");
        assert!(p.ensure(&src, Trans::No, 8, 6, 2), "stamp move must repack");
        assert_eq!(repack_count() - c0, 2);
        // Shape or orientation changes repack even at the same stamp.
        assert!(p.ensure(&src, Trans::Yes, 6, 8, 2));
        assert_eq!(repack_count() - c0, 3);
    }

    #[test]
    fn colmajor_b_equals_transposed() {
        forall("gemm-colmajor", 12, |rng: &mut Rng| {
            let m = rng.range(1, 17);
            let n = rng.range(1, 17);
            let k = rng.range(1, 33);
            let a = rng.normal_vec(m * k);
            let b_rm = rng.normal_vec(k * n); // (k, n) row-major
            let b_cm = transpose(&b_rm, k, n); // (n, k) = column-major B
            let mut c1 = vec![0.0f32; m * n];
            gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b_rm, 0.0, &mut c1);
            let mut c2 = vec![0.0f32; m * n];
            gemm_colmajor_b(m, n, k, &a, &b_cm, &mut c2);
            assert_close(&c1, &c2, 1e-4, 1e-4);
        });
    }

    #[test]
    fn transpose_roundtrip() {
        forall("transpose", 8, |rng: &mut Rng| {
            let r = rng.range(1, 50);
            let c = rng.range(1, 50);
            let x = rng.normal_vec(r * c);
            let t = transpose(&x, r, c);
            let back = transpose(&t, c, r);
            assert_eq!(x, back);
        });
    }
}
