//! Sliding-window geometry — one definition shared by conv and pool layers,
//! mirroring `python/compile/kernels/common.py` exactly (conv uses floor
//! mode, Caffe pooling uses ceil mode with the border clip).

/// Geometry of one spatial axis of a sliding-window op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowGeom {
    /// Input extent along this axis.
    pub size: usize,
    /// Zero padding on each side.
    pub pad: usize,
    /// Window extent.
    pub kernel: usize,
    /// Window step.
    pub stride: usize,
    /// Number of window positions.
    pub out: usize,
}

/// Caffe convolution geometry (floor mode).
pub fn conv_geom(size: usize, kernel: usize, stride: usize, pad: usize) -> WindowGeom {
    assert!(size + 2 * pad >= kernel, "convolution output collapsed");
    let out = (size + 2 * pad - kernel) / stride + 1;
    WindowGeom { size, pad, kernel, stride, out }
}

/// Caffe pooling geometry (ceil mode + border clip: the last window must
/// start strictly inside `size + pad`).
pub fn pool_geom(size: usize, kernel: usize, stride: usize, pad: usize) -> WindowGeom {
    let padded = size + 2 * pad;
    assert!(padded >= kernel, "pooling output collapsed");
    let mut out = (padded - kernel).div_ceil(stride) + 1;
    if pad > 0 && (out - 1) * stride >= size + pad {
        out -= 1;
    }
    WindowGeom { size, pad, kernel, stride, out }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_conv_shapes() {
        assert_eq!(conv_geom(28, 5, 1, 0).out, 24);
        assert_eq!(conv_geom(12, 5, 1, 0).out, 8);
        assert_eq!(conv_geom(32, 5, 1, 2).out, 32);
    }

    #[test]
    fn caffe_pool_ceil_mode() {
        assert_eq!(pool_geom(24, 2, 2, 0).out, 12);
        assert_eq!(pool_geom(32, 3, 2, 0).out, 16); // cifar10-quick pool1
        assert_eq!(pool_geom(16, 3, 2, 0).out, 8);
        assert_eq!(pool_geom(8, 3, 2, 0).out, 4);
    }

    #[test]
    fn pool_border_clip_with_pad() {
        // ceil((7 + 2 - 3)/2)+1 = 4; last window starts at 3*2-1 = 5 < 7+1,
        // no clip.
        assert_eq!(pool_geom(7, 3, 2, 1).out, 4);
        // ceil((4 + 2 - 3)/2)+1 = 3; window 2 starts at 2*2-1 = 3 < 4+1 ok;
        // window check: (3-1)*2 = 4 >= 4+1? no -> stays 3.
        assert_eq!(pool_geom(4, 3, 2, 1).out, 3);
    }

    #[test]
    #[should_panic]
    fn collapsed_conv_panics() {
        conv_geom(2, 5, 1, 0);
    }
}
