//! im2col / col2im — Caffe's convolution lowering (paper §3.1, Figs. 2–3).
//!
//! This is the faithful port of Caffe's "penta-loop", restructured the way
//! the paper describes: merged loops parameterized so every output element
//! is independent.  Layout matches Caffe and the Pallas kernels exactly:
//! `cols[(c*kh + i)*kw + j][oh*OW + ow]`.
//!
//! Both directions are parallel **over channels** through
//! [`ops::par`](super::par): channel `c` owns the `kh*kw` consecutive
//! rows of `cols` (im2col) or its own `(H, W)` image plane (col2im), so
//! per-worker outputs are disjoint contiguous blocks and results are
//! bitwise independent of the thread count.  Knobs: `PHAST_NUM_THREADS` +
//! `PHAST_IM2COL_GRAIN` (channels per worker).  Calls issued from inside
//! another parallel region — the common case, per-sample lowering inside
//! the batch-parallel convolution — collapse to the serial path with no
//! dispatch overhead.

use super::geometry::conv_geom;
use super::par;

/// Minimum channels per worker (`PHAST_IM2COL_GRAIN` overrides).
static IM2COL_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_IM2COL_GRAIN", 1);

/// Parameters of a 2-D sliding window (kernel/stride/pad per axis).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dGeom {
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Vertical stride.
    pub sh: usize,
    /// Horizontal stride.
    pub sw: usize,
    /// Vertical (top/bottom) zero padding.
    pub ph: usize,
    /// Horizontal (left/right) zero padding.
    pub pw: usize,
}

/// Lower one channel plane: writes the channel's `kh*kw` rows of `cols`.
fn im2col_channel(
    img: &[f32],
    h: usize,
    w: usize,
    g: Conv2dGeom,
    oh: usize,
    ow: usize,
    rows: &mut [f32],
) {
    let mut row = 0usize;
    for i in 0..g.kh {
        for j in 0..g.kw {
            let dst = &mut rows[row * oh * ow..(row + 1) * oh * ow];
            for oy in 0..oh {
                let iy = (oy * g.sh + i) as isize - g.ph as isize;
                let drow = &mut dst[oy * ow..(oy + 1) * ow];
                if iy < 0 || iy as usize >= h {
                    drow.iter_mut().for_each(|v| *v = 0.0);
                    continue;
                }
                let src = &img[iy as usize * w..(iy as usize + 1) * w];
                for (ox, d) in drow.iter_mut().enumerate() {
                    let ix = (ox * g.sw + j) as isize - g.pw as isize;
                    *d = if ix < 0 || ix as usize >= w {
                        0.0
                    } else {
                        src[ix as usize]
                    };
                }
            }
            row += 1;
        }
    }
}

/// One sample: `x` is (C, H, W) row-major; writes (C*kh*kw, OH*OW) into
/// `cols` (must be pre-sized).  Parallel over channels when called at top
/// level; serial when nested inside another parallel region.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    g: Conv2dGeom,
    cols: &mut [f32],
) {
    let gh = conv_geom(h, g.kh, g.sh, g.ph);
    let gw = conv_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(x.len(), c * h * w);
    assert_eq!(cols.len(), c * g.kh * g.kw * oh * ow);

    // Each channel owns kh*kw consecutive rows of `cols` — a contiguous
    // block, so the channel axis maps straight onto parallel_chunks_mut.
    let chan_rows = g.kh * g.kw * oh * ow;
    let tune = par::Tuning::new(IM2COL_GRAIN.get());
    par::parallel_chunks_mut(cols, chan_rows, tune, |chans, block| {
        for (bi, ch) in chans.enumerate() {
            let img = &x[ch * h * w..(ch + 1) * h * w];
            im2col_channel(img, h, w, g, oh, ow, &mut block[bi * chan_rows..(bi + 1) * chan_rows]);
        }
    });
}

/// Scatter-add one channel's `kh*kw` rows of `cols` back into its (H, W)
/// plane.  The plane must already be zeroed.
fn col2im_channel(
    rows: &[f32],
    h: usize,
    w: usize,
    g: Conv2dGeom,
    oh: usize,
    ow: usize,
    img: &mut [f32],
) {
    let mut row = 0usize;
    for i in 0..g.kh {
        for j in 0..g.kw {
            let src = &rows[row * oh * ow..(row + 1) * oh * ow];
            for oy in 0..oh {
                let iy = (oy * g.sh + i) as isize - g.ph as isize;
                if iy < 0 || iy as usize >= h {
                    continue;
                }
                let dst = &mut img[iy as usize * w..(iy as usize + 1) * w];
                let srow = &src[oy * ow..(oy + 1) * ow];
                for (ox, s) in srow.iter().enumerate() {
                    let ix = (ox * g.sw + j) as isize - g.pw as isize;
                    if ix >= 0 && (ix as usize) < w {
                        dst[ix as usize] += s;
                    }
                }
            }
            row += 1;
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add `cols` back into (C, H, W).
/// `x` is zeroed first (Caffe `caffe_set` then `col2im_cpu`).  Parallel
/// over channels — every channel scatters only into its own image plane,
/// so no two workers touch the same output element.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    cols: &[f32],
    c: usize,
    h: usize,
    w: usize,
    g: Conv2dGeom,
    x: &mut [f32],
) {
    let gh = conv_geom(h, g.kh, g.sh, g.ph);
    let gw = conv_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(x.len(), c * h * w);
    assert_eq!(cols.len(), c * g.kh * g.kw * oh * ow);

    let chan_rows = g.kh * g.kw * oh * ow;
    let plane = h * w;
    let tune = par::Tuning::new(IM2COL_GRAIN.get());
    par::parallel_chunks_mut(x, plane, tune, |chans, block| {
        block.iter_mut().for_each(|v| *v = 0.0);
        for (bi, ch) in chans.enumerate() {
            let rows = &cols[ch * chan_rows..(ch + 1) * chan_rows];
            col2im_channel(rows, h, w, g, oh, ow, &mut block[bi * plane..(bi + 1) * plane]);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{close, forall, Rng};

    fn geom(k: usize, s: usize, p: usize) -> Conv2dGeom {
        Conv2dGeom { kh: k, kw: k, sh: s, sw: s, ph: p, pw: p }
    }

    /// The worked example of paper Fig. 2/3: 2x2 filter, stride 1, pad 0
    /// over a 3x4 input.
    #[test]
    fn figure2_example() {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let g = geom(2, 1, 0);
        let mut cols = vec![0.0f32; 4 * 6];
        im2col(&x, 1, 3, 4, g, &mut cols);
        #[rustfmt::skip]
        let want = [
            0., 1., 2., 4., 5., 6.,
            1., 2., 3., 5., 6., 7.,
            4., 5., 6., 8., 9., 10.,
            5., 6., 7., 9., 10., 11.,
        ];
        assert_eq!(cols, want);
    }

    #[test]
    fn padding_zero_fills() {
        let x = vec![1.0f32; 4]; // 1x2x2
        let g = geom(3, 1, 1);
        let mut cols = vec![0.0f32; 9 * 4];
        im2col(&x, 1, 2, 2, g, &mut cols);
        // centre tap sees all ones
        let centre = &cols[4 * 4..5 * 4];
        assert_eq!(centre, &[1.0, 1.0, 1.0, 1.0]);
        // top-left tap only overlaps input at output (1,1)
        let tl = &cols[0..4];
        assert_eq!(tl, &[0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn adjointness() {
        // <im2col(x), y> == <x, col2im(y)> for random shapes.
        forall("im2col-adjoint", 20, |rng: &mut Rng| {
            let c = rng.range(1, 4);
            let h = rng.range(4, 12);
            let w = rng.range(4, 12);
            let k = rng.range(1, 3.min(h).min(w));
            let s = rng.range(1, 3);
            let p = rng.range(0, k - 1);
            let g = geom(k, s, p);
            let gh = conv_geom(h, k, s, p);
            let gw = conv_geom(w, k, s, p);
            let x = rng.normal_vec(c * h * w);
            let mut cols = vec![0.0f32; c * k * k * gh.out * gw.out];
            im2col(&x, c, h, w, g, &mut cols);
            let y = rng.normal_vec(cols.len());
            let lhs: f32 = cols.iter().zip(&y).map(|(a, b)| a * b).sum();
            let mut back = vec![0.0f32; x.len()];
            col2im(&y, c, h, w, g, &mut back);
            let rhs: f32 = x.iter().zip(&back).map(|(a, b)| a * b).sum();
            assert!(close(lhs, rhs, 1e-3, 1e-3), "{lhs} vs {rhs}");
        });
    }

    #[test]
    fn col2im_counts_window_coverage() {
        // col2im(ones) == number of windows covering each input pixel.
        let g = geom(2, 1, 0);
        let cols = vec![1.0f32; 4 * 6];
        let mut x = vec![0.0f32; 12];
        col2im(&cols, 1, 3, 4, g, &mut x);
        #[rustfmt::skip]
        let want = [
            1., 2., 2., 1.,
            2., 4., 4., 2.,
            1., 2., 2., 1.,
        ];
        assert_eq!(x, want);
    }
}
