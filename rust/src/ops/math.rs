//! BLAS level-1 helpers the solver and layers use (Caffe `caffe_axpy` etc.).

/// y += alpha * x.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// y = alpha * x + beta * y (Caffe `caffe_cpu_axpby`).
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi = alpha * xi + beta * *yi;
    }
}

/// x *= alpha.
pub fn scal(alpha: f32, x: &mut [f32]) {
    x.iter_mut().for_each(|v| *v *= alpha);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn axpby_works() {
        let mut y = vec![1.0, 2.0];
        axpby(2.0, &[3.0, 4.0], 0.5, &mut y);
        assert_eq!(y, vec![6.5, 9.0]);
    }

    #[test]
    fn scal_works() {
        let mut x = vec![2.0, -4.0];
        scal(0.5, &mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }
}
