//! BLAS level-1 helpers the solver and layers use (Caffe `caffe_axpy`,
//! `caffe_cpu_axpby`, `caffe_scal`).
//!
//! All three run chunk-parallel through [`ops::par`](super::par): the
//! output vector is split into contiguous element blocks, one per worker,
//! with the input read from the matching range.  Every element is updated
//! independently with identical per-element arithmetic under any split,
//! so results are **bitwise independent of the thread count** — which is
//! what lets the solver's SGD update route through these without
//! perturbing training trajectories.  Knobs: `PHAST_NUM_THREADS` +
//! `PHAST_AXPY_GRAIN` (minimum elements per worker; the default keeps
//! small bias/FC blobs serial, where dispatch would dominate).

use super::par;

/// Minimum elements per worker (`PHAST_AXPY_GRAIN` overrides).  Shared by
/// the whole level-1 family — the ops are memory-bound with identical
/// per-element cost.
static AXPY_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_AXPY_GRAIN", 16384);

/// y += alpha * x.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    par::parallel_chunks_mut(y, 1, par::Tuning::new(AXPY_GRAIN.get()), |range, yb| {
        for (yi, xi) in yb.iter_mut().zip(&x[range]) {
            *yi += alpha * xi;
        }
    });
}

/// y = alpha * x + beta * y (Caffe `caffe_cpu_axpby`).
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    par::parallel_chunks_mut(y, 1, par::Tuning::new(AXPY_GRAIN.get()), |range, yb| {
        for (yi, xi) in yb.iter_mut().zip(&x[range]) {
            *yi = alpha * xi + beta * *yi;
        }
    });
}

/// x *= alpha.
pub fn scal(alpha: f32, x: &mut [f32]) {
    par::parallel_chunks_mut(x, 1, par::Tuning::new(AXPY_GRAIN.get()), |_, xb| {
        xb.iter_mut().for_each(|v| *v *= alpha);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::par;

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn axpby_works() {
        let mut y = vec![1.0, 2.0];
        axpby(2.0, &[3.0, 4.0], 0.5, &mut y);
        assert_eq!(y, vec![6.5, 9.0]);
    }

    #[test]
    fn scal_works() {
        let mut x = vec![2.0, -4.0];
        scal(0.5, &mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        use crate::propcheck::Rng;
        let mut rng = Rng::new(41);
        // Longer than the grain so the parallel path actually splits.
        let n = 100_000;
        let x = rng.normal_vec(n);
        let y0 = rng.normal_vec(n);

        let mut want = y0.clone();
        par::with_threads(1, || axpy(0.3, &x, &mut want));
        par::with_threads(1, || axpby(1.7, &x, -0.4, &mut want));
        par::with_threads(1, || scal(0.9, &mut want));

        for t in [2usize, 5, 16] {
            let mut got = y0.clone();
            par::with_threads(t, || axpy(0.3, &x, &mut got));
            par::with_threads(t, || axpby(1.7, &x, -0.4, &mut got));
            par::with_threads(t, || scal(0.9, &mut got));
            assert_eq!(want, got, "level-1 family diverged at {t} threads");
        }
    }
}
