//! BLAS level-1 helpers the solver and layers use (Caffe `caffe_axpy`,
//! `caffe_cpu_axpby`, `caffe_scal`).
//!
//! All three run chunk-parallel through [`ops::par`](super::par): the
//! output vector is split into contiguous element blocks, one per worker,
//! with the input read from the matching range.  Every element is updated
//! independently with identical per-element arithmetic under any split,
//! so results are **bitwise independent of the thread count** — which is
//! what lets the solver's SGD update route through these without
//! perturbing training trajectories.  Knobs: `PHAST_NUM_THREADS` +
//! `PHAST_AXPY_GRAIN` (minimum elements per worker; the default keeps
//! small bias/FC blobs serial, where dispatch would dominate).

use super::par;

/// Minimum elements per worker (`PHAST_AXPY_GRAIN` overrides).  Shared by
/// the whole level-1 family — the ops are memory-bound with identical
/// per-element cost.
static AXPY_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_AXPY_GRAIN", 16384);

/// y += alpha * x.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    par::parallel_chunks_mut(y, 1, par::Tuning::new(AXPY_GRAIN.get()), |range, yb| {
        for (yi, xi) in yb.iter_mut().zip(&x[range]) {
            *yi += alpha * xi;
        }
    });
}

/// y = alpha * x + beta * y (Caffe `caffe_cpu_axpby`).
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    par::parallel_chunks_mut(y, 1, par::Tuning::new(AXPY_GRAIN.get()), |range, yb| {
        for (yi, xi) in yb.iter_mut().zip(&x[range]) {
            *yi = alpha * xi + beta * *yi;
        }
    });
}

/// x *= alpha.
pub fn scal(alpha: f32, x: &mut [f32]) {
    par::parallel_chunks_mut(x, 1, par::Tuning::new(AXPY_GRAIN.get()), |_, xb| {
        xb.iter_mut().for_each(|v| *v *= alpha);
    });
}

/// The three SGD stage bodies over one element block — shared by the
/// per-blob and flattened fused updates so both are, by construction,
/// the same arithmetic as the unfused [`axpy`]/[`axpby`]/[`axpy`] chain:
///
/// ```text
/// stage 0:  g += decay * w          (axpy:  regularize)
/// stage 1:  h  = lr * g + momentum*h (axpby: momentum)
/// stage 2:  w -= h                  (axpy:  Blob::Update)
/// ```
///
/// Every element is updated independently with identical per-element
/// arithmetic under any split, so fused results are **bitwise equal** to
/// the unfused three-dispatch sequence at every thread count.
#[inline]
fn sgd_stage(
    stage: usize,
    w: &mut [f32],
    g: &mut [f32],
    h: &mut [f32],
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    match stage {
        0 => {
            for (gi, wi) in g.iter_mut().zip(w.iter()) {
                *gi += decay * *wi;
            }
        }
        1 => {
            for (hi, gi) in h.iter_mut().zip(g.iter()) {
                *hi = lr * *gi + momentum * *hi;
            }
        }
        _ => {
            for (wi, hi) in w.iter_mut().zip(h.iter()) {
                *wi -= *hi;
            }
        }
    }
}

/// Fused momentum-SGD update for one parameter blob: the solver's three
/// BLAS-1 regions collapse into **one** dispatch of a three-stage fused
/// region ([`par::parallel_regions`]).  `g` holds the gradient on entry
/// and the *regularized* gradient on exit (Caffe semantics, identical to
/// the unfused path).  Knobs: `PHAST_NUM_THREADS` + `PHAST_AXPY_GRAIN`.
pub fn sgd_update_fused(
    w: &mut [f32],
    g: &mut [f32],
    hist: &mut [f32],
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    sgd_update_fused_impl(w, g, hist, lr, momentum, decay, false);
}

/// [`sgd_update_fused`] through [`par::parallel_regions_unsynced`]: the
/// three stages are element-local (each touches only the worker's own
/// range), so the inter-stage barrier is provably unnecessary and this
/// is **bitwise equal** to the barrier path at every thread count — it
/// only skips `2` barrier crossings per blob.  The barrier path stays
/// the reference (`PHAST_FUSE_UNSYNC=0` restores it process-wide).
pub fn sgd_update_fused_unsynced(
    w: &mut [f32],
    g: &mut [f32],
    hist: &mut [f32],
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    sgd_update_fused_impl(w, g, hist, lr, momentum, decay, true);
}

fn sgd_update_fused_impl(
    w: &mut [f32],
    g: &mut [f32],
    hist: &mut [f32],
    lr: f32,
    momentum: f32,
    decay: f32,
    unsync: bool,
) {
    let n = w.len();
    assert_eq!(g.len(), n);
    assert_eq!(hist.len(), n);
    let tune = par::Tuning::new(AXPY_GRAIN.get());
    let wv = par::FusedSlice::new(w);
    let gv = par::FusedSlice::new(g);
    let hv = par::FusedSlice::new(hist);
    // SAFETY: every stage re-slices the worker's own partition range, so
    // concurrent views are disjoint (the fused-region contract).  No
    // stage reads outside its own range, which is also what licenses the
    // barrier-free variant.
    let body = |stage: usize, r: std::ops::Range<usize>| unsafe {
        sgd_stage(
            stage,
            wv.slice_mut(r.clone()),
            gv.slice_mut(r.clone()),
            hv.slice_mut(r),
            lr,
            momentum,
            decay,
        );
    };
    par::check::label_region(|| "sgd.update".to_string());
    if unsync {
        par::parallel_regions_unsynced(n, 3, tune, body);
    } else {
        par::parallel_regions(n, 3, tune, body);
    }
}

/// One parameter blob's `(weights, gradient, history)` slices for the
/// flattened whole-step fused update.
pub type SgdParamView<'a> = (&'a mut [f32], &'a mut [f32], &'a mut [f32]);

/// Whole-step fused momentum-SGD over a *flattened view* of all parameter
/// blobs: **one** dispatch for the entire solver step (`PHAST_FUSE_STEP`).
/// Workers partition the concatenated element space, so one worker's range
/// may span several blobs; the per-element arithmetic is the same three
/// stage bodies as [`sgd_update_fused`], hence bitwise equal to both the
/// per-blob fused path and the unfused three-call sequence.
pub fn sgd_update_fused_flat(params: Vec<SgdParamView<'_>>, lr: f32, momentum: f32, decay: f32) {
    sgd_update_fused_flat_impl(params, lr, momentum, decay, false);
}

/// [`sgd_update_fused_flat`] through [`par::parallel_regions_unsynced`]:
/// a worker's global range maps to the same segment-local ranges in every
/// stage and no stage reads outside them, so the chain is pointwise and
/// bitwise equal to the barrier path (see [`sgd_update_fused_unsynced`]).
pub fn sgd_update_fused_flat_unsynced(
    params: Vec<SgdParamView<'_>>,
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    sgd_update_fused_flat_impl(params, lr, momentum, decay, true);
}

fn sgd_update_fused_flat_impl(
    params: Vec<SgdParamView<'_>>,
    lr: f32,
    momentum: f32,
    decay: f32,
    unsync: bool,
) {
    struct Seg<'a> {
        start: usize,
        end: usize,
        w: par::FusedSlice<'a, f32>,
        g: par::FusedSlice<'a, f32>,
        h: par::FusedSlice<'a, f32>,
    }
    let mut segs: Vec<Seg<'_>> = Vec::with_capacity(params.len());
    let mut total = 0usize;
    for (w, g, h) in params {
        let n = w.len();
        assert_eq!(g.len(), n);
        assert_eq!(h.len(), n);
        segs.push(Seg {
            start: total,
            end: total + n,
            w: par::FusedSlice::new(w),
            g: par::FusedSlice::new(g),
            h: par::FusedSlice::new(h),
        });
        total += n;
    }
    let tune = par::Tuning::new(AXPY_GRAIN.get());
    let body = |stage: usize, r: std::ops::Range<usize>| {
        for seg in &segs {
            let lo = r.start.max(seg.start);
            let hi = r.end.min(seg.end);
            if lo >= hi {
                continue;
            }
            let local = lo - seg.start..hi - seg.start;
            // SAFETY: disjoint global ranges map to disjoint local ranges
            // within each segment (the fused-region contract).
            unsafe {
                sgd_stage(
                    stage,
                    seg.w.slice_mut(local.clone()),
                    seg.g.slice_mut(local.clone()),
                    seg.h.slice_mut(local),
                    lr,
                    momentum,
                    decay,
                );
            }
        }
    };
    par::check::label_region(|| "sgd.step.flat".to_string());
    if unsync {
        par::parallel_regions_unsynced(total, 3, tune, body);
    } else {
        par::parallel_regions(total, 3, tune, body);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::par;

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn axpby_works() {
        let mut y = vec![1.0, 2.0];
        axpby(2.0, &[3.0, 4.0], 0.5, &mut y);
        assert_eq!(y, vec![6.5, 9.0]);
    }

    #[test]
    fn scal_works() {
        let mut x = vec![2.0, -4.0];
        scal(0.5, &mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }

    #[test]
    fn fused_sgd_matches_unfused_three_call_sequence_bitwise() {
        use crate::propcheck::Rng;
        let mut rng = Rng::new(97);
        let n = 60_000; // longer than the grain so the region really splits
        let w0 = rng.normal_vec(n);
        let g0 = rng.normal_vec(n);
        let h0 = rng.normal_vec(n);
        let (lr, momentum, decay) = (0.01f32, 0.9f32, 0.0005f32);

        // Unfused reference: the exact three-call sequence, serial.
        let (mut w_ref, mut g_ref, mut h_ref) = (w0.clone(), g0.clone(), h0.clone());
        par::with_threads(1, || {
            let w_snapshot = w_ref.clone();
            axpy(decay, &w_snapshot, &mut g_ref);
            axpby(lr, &g_ref, momentum, &mut h_ref);
            axpy(-1.0, &h_ref, &mut w_ref);
        });

        for t in [1usize, 2, 5, 16] {
            let (mut w, mut g, mut h) = (w0.clone(), g0.clone(), h0.clone());
            par::with_threads(t, || sgd_update_fused(&mut w, &mut g, &mut h, lr, momentum, decay));
            assert_eq!(w_ref, w, "fused weights diverged at {t} threads");
            assert_eq!(g_ref, g, "fused (regularized) grads diverged at {t} threads");
            assert_eq!(h_ref, h, "fused history diverged at {t} threads");

            // Flat view over three unequal segments of the same data must
            // also match bitwise (worker ranges cross segment boundaries).
            let (mut wf, mut gf, mut hf) = (w0.clone(), g0.clone(), h0.clone());
            let cut1 = 17; // tiny head segment
            let cut2 = n / 2 + 13;
            par::with_threads(t, || {
                let (wa, wrest) = wf.split_at_mut(cut1);
                let (wb, wc) = wrest.split_at_mut(cut2 - cut1);
                let (ga, grest) = gf.split_at_mut(cut1);
                let (gb, gc) = grest.split_at_mut(cut2 - cut1);
                let (ha, hrest) = hf.split_at_mut(cut1);
                let (hb, hc) = hrest.split_at_mut(cut2 - cut1);
                sgd_update_fused_flat(
                    vec![(wa, ga, ha), (wb, gb, hb), (wc, gc, hc)],
                    lr,
                    momentum,
                    decay,
                );
            });
            assert_eq!(w_ref, wf, "flat weights diverged at {t} threads");
            assert_eq!(g_ref, gf, "flat grads diverged at {t} threads");
            assert_eq!(h_ref, hf, "flat history diverged at {t} threads");
        }
    }

    #[test]
    fn unsynced_sgd_matches_barrier_path_bitwise() {
        use crate::propcheck::Rng;
        let mut rng = Rng::new(131);
        let n = 60_000; // longer than the grain so the region really splits
        let w0 = rng.normal_vec(n);
        let g0 = rng.normal_vec(n);
        let h0 = rng.normal_vec(n);
        let (lr, momentum, decay) = (0.01f32, 0.9f32, 0.0005f32);
        for t in [1usize, 2, 5, 16] {
            let (mut w_bar, mut g_bar, mut h_bar) = (w0.clone(), g0.clone(), h0.clone());
            par::with_threads(t, || {
                sgd_update_fused(&mut w_bar, &mut g_bar, &mut h_bar, lr, momentum, decay);
            });
            let (mut w, mut g, mut h) = (w0.clone(), g0.clone(), h0.clone());
            par::with_threads(t, || {
                sgd_update_fused_unsynced(&mut w, &mut g, &mut h, lr, momentum, decay);
            });
            assert_eq!(w_bar, w, "unsynced weights diverged at {t} threads");
            assert_eq!(g_bar, g, "unsynced grads diverged at {t} threads");
            assert_eq!(h_bar, h, "unsynced history diverged at {t} threads");

            let (mut wf, mut gf, mut hf) = (w0.clone(), g0.clone(), h0.clone());
            let cut = n / 3 + 11;
            par::with_threads(t, || {
                let (wa, wb) = wf.split_at_mut(cut);
                let (ga, gb) = gf.split_at_mut(cut);
                let (ha, hb) = hf.split_at_mut(cut);
                let views = vec![(wa, ga, ha), (wb, gb, hb)];
                sgd_update_fused_flat_unsynced(views, lr, momentum, decay);
            });
            assert_eq!(w_bar, wf, "unsynced flat weights diverged at {t} threads");
            assert_eq!(h_bar, hf, "unsynced flat history diverged at {t} threads");
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        use crate::propcheck::Rng;
        let mut rng = Rng::new(41);
        // Longer than the grain so the parallel path actually splits.
        let n = 100_000;
        let x = rng.normal_vec(n);
        let y0 = rng.normal_vec(n);

        let mut want = y0.clone();
        par::with_threads(1, || axpy(0.3, &x, &mut want));
        par::with_threads(1, || axpby(1.7, &x, -0.4, &mut want));
        par::with_threads(1, || scal(0.9, &mut want));

        for t in [2usize, 5, 16] {
            let mut got = y0.clone();
            par::with_threads(t, || axpy(0.3, &x, &mut got));
            par::with_threads(t, || axpby(1.7, &x, -0.4, &mut got));
            par::with_threads(t, || scal(0.9, &mut got));
            assert_eq!(want, got, "level-1 family diverged at {t} threads");
        }
    }
}
