//! Native CPU math — the "original Caffe + OpenBLAS" baseline the paper
//! compares its PHAST port against (Table 2's `Caffe` rows).
//!
//! Everything here is an independent, hand-written Rust implementation of
//! the same Caffe semantics the Pallas kernels implement; the integration
//! tests close the triangle natively-computed == PJRT-computed == pure-jnp
//! oracle.
//!
//! Since the paper's baseline is *multi-threaded* Caffe+OpenBLAS, every
//! hot kernel (GeMM, im2col/col2im, batched pooling, elementwise/softmax,
//! the accuracy reduction, and the solver's BLAS-1 family) runs over the
//! [`par`] persistent-worker-pool runtime, tuned PHAST-style via
//! `PHAST_NUM_THREADS` and per-kernel `PHAST_*_GRAIN` knobs — see
//! `docs/PARALLEL_RUNTIME.md` for the full knob table and tuning guide.
//! The GeMM is a BLIS-style packed register-tiled engine
//! ([`gemm`](mod@gemm)) with persistent weight packing ([`PackedMat`])
//! so layers never re-transpose constant weights per iteration.
#![warn(missing_docs)]

pub mod fault;
pub mod geometry;
pub mod par;
pub mod gemm;
pub mod im2col;
pub mod pool;
pub mod activations;
pub mod math;

pub use geometry::{conv_geom, pool_geom, WindowGeom};
pub use gemm::{
    gemm, gemm_colmajor_b, gemm_packed_a, gemm_packed_b, gemm_packed_b_slice, pack_b_slice,
    packed_b_len, PackSide, PackedMat, Trans,
};
pub use im2col::{col2im, im2col};
pub use pool::{
    avepool, avepool_batch, avepool_bwd, avepool_bwd_batch, avepool_bwd_plane, maxpool,
    maxpool_batch, maxpool_bwd, maxpool_bwd_batch, maxpool_bwd_plane,
};
pub use activations::{
    accuracy, leaky_relu, leaky_relu_bwd, softmax, softmax_xent, softmax_xent_bwd,
};
pub use math::{
    axpy, axpby, scal, sgd_update_fused, sgd_update_fused_flat, sgd_update_fused_flat_unsynced,
    sgd_update_fused_unsynced,
};
