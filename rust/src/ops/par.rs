//! `ops::par` — dependency-free chunked parallel runtime for the native
//! baseline (scoped threads, no rayon/crossbeam).
//!
//! The paper's native comparison point (Table 2's "Caffe" rows) is Caffe +
//! **multi-threaded** OpenBLAS; PHAST itself (Peccerillo & Bartolini, TPDS
//! 2018) sells "write once, tune with minimal source changes" through two
//! per-kernel knobs: thread count and block (grain) size.  This module
//! reproduces exactly that tuning surface for the native Rust kernels:
//!
//! * **thread count** — `std::thread::available_parallelism()` by default,
//!   overridable process-wide via the `PHAST_NUM_THREADS` environment
//!   variable or [`set_num_threads`], and per-call-tree via
//!   [`with_threads`] (the analog of PHAST's per-kernel thread setting —
//!   used by the tuning benches and the serial/parallel property tests);
//! * **grain size** — each kernel owns a [`GrainKnob`] (its per-kernel
//!   block-size macro), overridable via `PHAST_<KERNEL>_GRAIN` env vars.
//!
//! Work is split into *contiguous* index ranges, one per worker, so every
//! mutable output is partitioned into disjoint slices (`split_at_mut`) —
//! no locks, no atomics on the data path, and bitwise-deterministic
//! results for a fixed thread count (partials are merged in worker order).
//!
//! Nested parallel regions serialize automatically: workers set a
//! thread-local flag, and any parallel entry point called from inside a
//! worker falls back to the serial path (e.g. the per-sample GeMMs inside
//! a batch-parallel convolution do not oversubscribe the machine).

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

thread_local! {
    /// Per-call-tree thread override (0 = none); see [`with_threads`].
    static THREAD_OVERRIDE: Cell<usize> = const { Cell::new(0) };
    /// Set inside worker threads so nested parallel calls run serial.
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

/// Process-wide configured thread count (0 = not yet resolved).
static CONFIGURED_THREADS: AtomicUsize = AtomicUsize::new(0);

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The thread count parallel kernels will use when called from this
/// thread: `with_threads` override, else `PHAST_NUM_THREADS`, else
/// `available_parallelism()`.
pub fn num_threads() -> usize {
    let over = THREAD_OVERRIDE.with(Cell::get);
    if over > 0 {
        return over;
    }
    let cached = CONFIGURED_THREADS.load(Ordering::Relaxed);
    if cached > 0 {
        return cached;
    }
    let resolved = std::env::var("PHAST_NUM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(hardware_threads);
    CONFIGURED_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Set the process-wide thread count (PHAST's global tuning knob).
pub fn set_num_threads(n: usize) {
    CONFIGURED_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// True while executing inside a parallel worker (nested regions serialize).
pub fn in_parallel() -> bool {
    IN_PARALLEL.with(Cell::get)
}

/// Run `f` with the thread count forced to `n` on this call tree only —
/// the per-kernel thread knob.  Restores the previous setting on exit
/// (including on panic), so property tests can interleave serial and
/// parallel runs safely.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = THREAD_OVERRIDE.with(|c| {
        let prev = c.get();
        c.set(n.max(1));
        Restore(prev)
    });
    f()
}

/// A per-kernel grain-size knob with an environment override, resolved
/// once (the PHAST "block size macro" analog).
pub struct GrainKnob {
    env: &'static str,
    default: usize,
    cached: AtomicUsize,
}

impl GrainKnob {
    pub const fn new(env: &'static str, default: usize) -> GrainKnob {
        GrainKnob { env, default, cached: AtomicUsize::new(0) }
    }

    pub fn get(&self) -> usize {
        let cached = self.cached.load(Ordering::Relaxed);
        if cached > 0 {
            return cached;
        }
        let resolved = std::env::var(self.env)
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(self.default);
        self.cached.store(resolved, Ordering::Relaxed);
        resolved
    }
}

/// Per-call tuning: thread budget + minimum items per worker.
#[derive(Clone, Copy, Debug)]
pub struct Tuning {
    pub threads: usize,
    pub grain: usize,
}

impl Tuning {
    /// Snapshot the current thread setting with the given grain.
    pub fn new(grain: usize) -> Tuning {
        Tuning { threads: num_threads(), grain: grain.max(1) }
    }

    /// How many workers `n` items warrant: capped by the thread budget,
    /// by `ceil(n / grain)`, and forced to 1 inside a parallel region.
    pub fn workers(&self, n: usize) -> usize {
        if n == 0 || self.threads <= 1 || in_parallel() {
            return 1;
        }
        let by_grain = (n + self.grain - 1) / self.grain;
        self.threads.min(by_grain).max(1)
    }
}

/// Split `0..n` into `parts` contiguous near-equal ranges.
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let (base, rem) = (n / parts, n % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f` once per worker over disjoint contiguous sub-ranges of `0..n`.
/// Serial (caller thread, no spawn) when one worker suffices.
pub fn parallel_for(n: usize, tune: Tuning, f: impl Fn(Range<usize>) + Sync) {
    if tune.workers(n) <= 1 {
        if n > 0 {
            f(0..n);
        }
        return;
    }
    std::thread::scope(|s| {
        for r in partition(n, tune.workers(n)) {
            let f = &f;
            s.spawn(move || {
                IN_PARALLEL.with(|c| c.set(true));
                f(r)
            });
        }
    });
}

/// Map disjoint ranges of `0..n` through `map` and fold the per-worker
/// results **in worker order** (deterministic for a fixed thread count).
pub fn parallel_reduce<A: Send>(
    n: usize,
    tune: Tuning,
    map: impl Fn(Range<usize>) -> A + Sync,
    mut fold: impl FnMut(A, A) -> A,
    init: A,
) -> A {
    if tune.workers(n) <= 1 {
        return if n == 0 { init } else { fold(init, map(0..n)) };
    }
    let partials = std::thread::scope(|s| {
        let handles: Vec<_> = partition(n, tune.workers(n))
            .into_iter()
            .map(|r| {
                let map = &map;
                s.spawn(move || {
                    IN_PARALLEL.with(|c| c.set(true));
                    map(r)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<A>>()
    });
    partials.into_iter().fold(init, fold)
}

/// Partition `data` (a packed array of `n = data.len() / item_len` items)
/// into per-worker contiguous blocks; `f(items, block)` gets the item
/// range and the matching mutable sub-slice.  One call per worker, so `f`
/// can allocate per-thread scratch once.
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    item_len: usize,
    tune: Tuning,
    f: impl Fn(Range<usize>, &mut [T]) + Sync,
) {
    assert!(item_len > 0, "item_len must be positive");
    assert_eq!(data.len() % item_len, 0, "data not a whole number of items");
    let n = data.len() / item_len;
    let workers = tune.workers(n);
    if workers <= 1 {
        if n > 0 {
            f(0..n, data);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = data;
        for r in partition(n, workers) {
            let take = r.len() * item_len;
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let f = &f;
            s.spawn(move || {
                IN_PARALLEL.with(|c| c.set(true));
                f(r, head)
            });
        }
    });
}

/// Like [`parallel_chunks_mut`] over two parallel arrays with their own
/// item sizes (e.g. pooling's value and argmax outputs).
pub fn parallel_chunks2_mut<T: Send, U: Send>(
    a: &mut [T],
    a_item: usize,
    b: &mut [U],
    b_item: usize,
    tune: Tuning,
    f: impl Fn(Range<usize>, &mut [T], &mut [U]) + Sync,
) {
    assert!(a_item > 0 && b_item > 0, "item lengths must be positive");
    assert_eq!(a.len() % a_item, 0, "a not a whole number of items");
    assert_eq!(b.len() % b_item, 0, "b not a whole number of items");
    let n = a.len() / a_item;
    assert_eq!(b.len() / b_item, n, "a and b disagree on item count");
    let workers = tune.workers(n);
    if workers <= 1 {
        if n > 0 {
            f(0..n, a, b);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest_a = a;
        let mut rest_b = b;
        for r in partition(n, workers) {
            let (head_a, tail_a) = std::mem::take(&mut rest_a).split_at_mut(r.len() * a_item);
            let (head_b, tail_b) = std::mem::take(&mut rest_b).split_at_mut(r.len() * b_item);
            rest_a = tail_a;
            rest_b = tail_b;
            let f = &f;
            s.spawn(move || {
                IN_PARALLEL.with(|c| c.set(true));
                f(r, head_a, head_b)
            });
        }
    });
}

/// [`parallel_chunks_mut`] that additionally collects a per-worker
/// accumulator (e.g. a local `dW`/`db`), returned **in worker order** so
/// the caller's serial merge is deterministic for a fixed thread count.
pub fn parallel_chunks_reduce<T: Send, A: Send>(
    data: &mut [T],
    item_len: usize,
    tune: Tuning,
    f: impl Fn(Range<usize>, &mut [T]) -> A + Sync,
) -> Vec<A> {
    assert!(item_len > 0, "item_len must be positive");
    assert_eq!(data.len() % item_len, 0, "data not a whole number of items");
    let n = data.len() / item_len;
    let workers = tune.workers(n);
    if workers <= 1 {
        if n == 0 {
            return vec![];
        }
        return vec![f(0..n, data)];
    }
    std::thread::scope(|s| {
        let mut rest = data;
        let handles: Vec<_> = partition(n, workers)
            .into_iter()
            .map(|r| {
                let take = r.len() * item_len;
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                let f = &f;
                s.spawn(move || {
                    IN_PARALLEL.with(|c| c.set(true));
                    f(r, head)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn partition_covers_exactly() {
        for n in [0usize, 1, 2, 7, 64, 65] {
            for p in [1usize, 2, 3, 8, 100] {
                let ranges = partition(n, p);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, n);
                // near-equal: sizes differ by at most one
                if !ranges.is_empty() {
                    let min = ranges.iter().map(|r| r.len()).min().unwrap();
                    let max = ranges.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1, "n={n} p={p}: {min}..{max}");
                }
            }
        }
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        with_threads(4, || {
            parallel_for(n, Tuning::new(1), |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_mut_writes_disjoint_blocks() {
        let mut data = vec![0usize; 12 * 3];
        with_threads(5, || {
            parallel_chunks_mut(&mut data, 3, Tuning::new(1), |items, block| {
                for (bi, item) in items.enumerate() {
                    for k in 0..3 {
                        block[bi * 3 + k] = item * 10 + k;
                    }
                }
            });
        });
        for item in 0..12 {
            for k in 0..3 {
                assert_eq!(data[item * 3 + k], item * 10 + k);
            }
        }
    }

    #[test]
    fn chunks2_mut_blocks_stay_aligned() {
        let mut a = vec![0usize; 10 * 2];
        let mut b = vec![0usize; 10 * 5];
        with_threads(3, || {
            parallel_chunks2_mut(&mut a, 2, &mut b, 5, Tuning::new(1), |items, ab, bb| {
                for (bi, item) in items.enumerate() {
                    ab[bi * 2] = item;
                    bb[bi * 5] = item;
                }
            });
        });
        for item in 0..10 {
            assert_eq!(a[item * 2], item);
            assert_eq!(b[item * 5], item);
        }
    }

    #[test]
    fn reduce_matches_serial_sum() {
        let n = 4321u64;
        let want: u64 = (0..n).sum();
        for t in [1usize, 2, 4, 7] {
            let got = with_threads(t, || {
                parallel_reduce(
                    n as usize,
                    Tuning::new(16),
                    |r| r.map(|i| i as u64).sum::<u64>(),
                    |a, b| a + b,
                    0u64,
                )
            });
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn chunks_reduce_returns_worker_order() {
        let mut data = vec![0u8; 100];
        let parts = with_threads(4, || {
            parallel_chunks_reduce(&mut data, 1, Tuning::new(1), |r, _| r.start)
        });
        let mut sorted = parts.clone();
        sorted.sort_unstable();
        assert_eq!(parts, sorted, "partials must arrive in worker order");
        assert_eq!(parts.len(), 4);
    }

    #[test]
    fn nested_regions_serialize() {
        let spawned_nested = AtomicU64::new(0);
        with_threads(4, || {
            parallel_for(8, Tuning::new(1), |_| {
                assert!(in_parallel());
                // a nested parallel call must collapse to one worker
                let tune = Tuning::new(1);
                assert_eq!(tune.workers(100), 1);
                parallel_for(10, tune, |r| {
                    if r.len() < 10 {
                        spawned_nested.fetch_add(1, Ordering::Relaxed);
                    }
                });
            });
        });
        assert_eq!(spawned_nested.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn with_threads_restores_on_exit() {
        let outer = num_threads();
        with_threads(3, || assert_eq!(num_threads(), 3));
        assert_eq!(num_threads(), outer);
        // restored even across a panic
        let _ = std::panic::catch_unwind(|| with_threads(5, || panic!("boom")));
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn workers_respect_grain() {
        with_threads(8, || {
            let t = Tuning::new(32);
            assert_eq!(t.workers(0), 1);
            assert_eq!(t.workers(31), 1);
            assert_eq!(t.workers(64), 2);
            assert_eq!(t.workers(10_000), 8);
        });
    }
}
