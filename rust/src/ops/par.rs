//! `ops::par` — dependency-free chunked parallel runtime for the native
//! baseline (persistent worker pool, no rayon/crossbeam).
//!
//! The paper's native comparison point (Table 2's "Caffe" rows) is Caffe +
//! **multi-threaded** OpenBLAS; PHAST itself (Peccerillo & Bartolini, TPDS
//! 2018) sells "write once, tune with minimal source changes" through two
//! per-kernel knobs: thread count and block (grain) size.  This module
//! reproduces exactly that tuning surface for the native Rust kernels:
//!
//! * **thread count** — `std::thread::available_parallelism()` by default,
//!   overridable process-wide via the `PHAST_NUM_THREADS` environment
//!   variable or [`set_num_threads`], and per-call-tree via
//!   [`with_threads`] (the analog of PHAST's per-kernel thread setting —
//!   used by the tuning benches and the serial/parallel property tests).
//!   Precedence: [`with_threads`] > [`set_num_threads`] >
//!   `PHAST_NUM_THREADS` > `available_parallelism()`.  The environment is
//!   read **once** (cached in a `OnceLock`), never per call.
//! * **grain size** — each kernel owns a [`GrainKnob`] (its per-kernel
//!   block-size macro), overridable via `PHAST_<KERNEL>_GRAIN` env vars,
//!   likewise parsed once and cached.
//!
//! # Execution model: persistent pool, not per-call spawn
//!
//! Earlier revisions spawned scoped threads on every parallel call
//! (~tens of µs each), which dominates the many-small-op regime (the
//! CIFAR-quick head layers).  Dispatch now goes through a process-wide
//! pool of **parked workers**, created lazily on the first parallel call
//! and grown on demand up to the largest worker count ever requested
//! (never shrunk, never re-created):
//!
//! * each worker owns an `mpsc` channel and blocks in `recv()` between
//!   jobs — zero CPU while parked;
//! * a parallel region lifetime-erases its closure, sends one job per
//!   helper worker, runs **worker 0 itself** (so `k` workers cost `k - 1`
//!   handoffs), and blocks on a latch until every helper has finished —
//!   which is what makes the borrow erasure sound: no job can outlive
//!   the dispatching call;
//! * worker panics are caught, carried through the latch, and re-raised
//!   on the dispatching thread;
//! * teardown is trivial by construction: workers hold no task state
//!   between jobs (every dispatch joins synchronously before returning),
//!   so process exit simply reclaims threads parked in `recv()`.
//!
//! Work is split into *contiguous* index ranges, one per worker, so every
//! mutable output is partitioned into disjoint slices (`split_at_mut`) —
//! no locks on the data path, and bitwise-deterministic results for a
//! fixed thread count (partials are merged in worker order).
//!
//! Nested parallel regions serialize automatically: pool workers (and the
//! dispatching thread while it runs its own share) set a thread-local
//! flag, and any parallel entry point called from inside a worker falls
//! back to the serial path (e.g. the per-sample GeMMs inside a
//! batch-parallel convolution do not oversubscribe the machine).
//!
//! # Fused multi-stage regions
//!
//! With dispatch in the ~µs range the next overhead tier is the *pass
//! structure*: chains of dependent small ops (the solver's three BLAS-1
//! calls per blob, bias-add → activation) each paying their own region.
//! [`parallel_regions`] / [`FusedRegion`]
//! run such a chain as **one** dispatch: every stage iterates the same
//! deterministic contiguous partition (worker `w` keeps its range across
//! stages), with a poison-aware barrier between dependent stages — so
//! fused results are bitwise-equal to the unfused sequence, panics still
//! propagate, and nested fusion still serializes.  [`region_count`]
//! exposes the per-thread region tally the `fusion` bench uses to show
//! the 3→1 collapse.
//!
//! See `docs/PARALLEL_RUNTIME.md` for the architecture write-up, the full
//! knob table, and a tuning walkthrough.

/// The `PHAST_CHECK` access sanitizer: records `FusedSlice` accesses per
/// worker/stage in checked mode and validates the fused-region contracts
/// after each region (see `docs/CHECKING.md`).
#[path = "par_check.rs"]
pub mod check;

use std::any::Any;
use std::cell::{Cell, RefCell};
use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex, OnceLock};

thread_local! {
    /// Per-call-tree thread override (0 = none); see [`with_threads`].
    static THREAD_OVERRIDE: Cell<usize> = const { Cell::new(0) };
    /// Set inside worker threads so nested parallel calls run serial.
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

/// Thread count set by [`set_num_threads`] (0 = not set).
static THREAD_SETTING: AtomicUsize = AtomicUsize::new(0);

/// `PHAST_NUM_THREADS` / `available_parallelism()`, parsed exactly once.
static ENV_THREADS: OnceLock<usize> = OnceLock::new();

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn env_threads() -> usize {
    *ENV_THREADS.get_or_init(|| {
        std::env::var("PHAST_NUM_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(hardware_threads)
    })
}

/// The thread count parallel kernels will use when called from this
/// thread.  Resolution order: [`with_threads`] override, else
/// [`set_num_threads`], else `PHAST_NUM_THREADS` (read once), else
/// `available_parallelism()`.
pub fn num_threads() -> usize {
    let over = THREAD_OVERRIDE.with(Cell::get);
    if over > 0 {
        return over;
    }
    let set = THREAD_SETTING.load(Ordering::Relaxed);
    if set > 0 {
        return set;
    }
    env_threads()
}

/// Set the process-wide thread count (PHAST's global tuning knob).
/// Takes precedence over `PHAST_NUM_THREADS`; a live [`with_threads`]
/// override still wins on its call tree.
pub fn set_num_threads(n: usize) {
    THREAD_SETTING.store(n.max(1), Ordering::Relaxed);
}

/// True while executing inside a parallel worker (nested regions serialize).
pub fn in_parallel() -> bool {
    IN_PARALLEL.with(Cell::get)
}

/// Run `f` with the thread count forced to `n` on this call tree only —
/// the per-kernel thread knob.  Restores the previous setting on exit
/// (including on panic), so property tests can interleave serial and
/// parallel runs safely.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = THREAD_OVERRIDE.with(|c| {
        let prev = c.get();
        c.set(n.max(1));
        Restore(prev)
    });
    f()
}

/// A per-kernel grain-size knob with an environment override, resolved
/// once (the PHAST "block size macro" analog).
pub struct GrainKnob {
    env: &'static str,
    default: usize,
    cached: OnceLock<usize>,
}

impl GrainKnob {
    /// A knob read from `env`, falling back to `default`.
    pub const fn new(env: &'static str, default: usize) -> GrainKnob {
        GrainKnob { env, default, cached: OnceLock::new() }
    }

    /// The resolved grain: the env override if set to a positive integer,
    /// else the compiled-in default.  The environment is consulted only on
    /// the first call; the result is cached for the process lifetime.
    pub fn get(&self) -> usize {
        *self.cached.get_or_init(|| {
            std::env::var(self.env)
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or(self.default)
        })
    }
}

/// Per-call tuning: thread budget + minimum items per worker.
#[derive(Clone, Copy, Debug)]
pub struct Tuning {
    /// Thread budget for this call (snapshot of [`num_threads`]).
    pub threads: usize,
    /// Minimum items per worker (the kernel's grain).
    pub grain: usize,
}

impl Tuning {
    /// Snapshot the current thread setting with the given grain.
    pub fn new(grain: usize) -> Tuning {
        Tuning { threads: num_threads(), grain: grain.max(1) }
    }

    /// How many workers `n` items warrant: capped by the thread budget,
    /// by `ceil(n / grain)`, and forced to 1 inside a parallel region.
    pub fn workers(&self, n: usize) -> usize {
        if n == 0 || self.threads <= 1 || in_parallel() {
            return 1;
        }
        let by_grain = (n + self.grain - 1) / self.grain;
        self.threads.min(by_grain).max(1)
    }
}

thread_local! {
    /// Top-level parallel-region entries issued from this thread — the
    /// fusion metric.  Every call into a public chunked entry point
    /// ([`parallel_for`], [`parallel_chunks_mut`], …, [`parallel_regions`])
    /// counts **once**, whether it dispatched to the pool or ran serial on
    /// the caller; nested calls (which collapse to serial inside a worker)
    /// do not count.  A fused multi-stage region is one entry regardless of
    /// its stage count, which is exactly what the `fusion` bench records:
    /// the solver's three BLAS-1 regions per blob become one.  Thread-local
    /// (regions are always noted on the dispatching thread) so concurrent
    /// callers never perturb each other's measurements.
    static REGIONS: Cell<u64> = const { Cell::new(0) };
}

/// Number of top-level parallel-region entries issued from the calling
/// thread so far: every public chunked entry point counts once per call
/// (serial fallback included; nested calls excluded), and a fused
/// multi-stage region counts once regardless of stage count.  Monotonic
/// per thread; benches diff it around a step to count regions per step.
pub fn region_count() -> u64 {
    REGIONS.with(Cell::get)
}

/// Count one region entry unless we are nested inside a worker.
///
/// Also the serial-mode seam for injected worker panics: at one thread no
/// region ever dispatches to the pool, so an armed
/// `PHAST_FAULT=worker_panic` fires here, on the calling thread, at the
/// next region entry (the parallel case fires inside a pool worker — see
/// [`run_workers`]).  Costs one thread-local read when nothing is armed.
fn note_region() {
    if !in_parallel() {
        REGIONS.with(|c| c.set(c.get() + 1));
        if super::fault::worker_panic_armed() && num_threads() <= 1 {
            super::fault::take_worker_panic();
            panic!("injected worker_panic (PHAST_FAULT)");
        }
    }
}

/// Split `0..n` into `parts` contiguous near-equal ranges.
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let (base, rem) = (n / parts, n % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

// ---------------------------------------------------------------------------
// Reusable per-thread pack buffers (the GeMM packing scratch).
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread A-panel pack scratch for the GeMM engine — grown on
    /// demand, never shrunk, reused across every GeMM call this thread
    /// issues (pool workers each own one, so panel packing never
    /// allocates on the hot path).
    static PACK_A_BUF: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Per-thread B-panel pack scratch (same lifecycle as `PACK_A_BUF`).
    static PACK_B_BUF: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

fn with_buf<R>(
    cell: &'static std::thread::LocalKey<RefCell<Vec<f32>>>,
    len: usize,
    f: impl FnOnce(&mut [f32]) -> R,
) -> R {
    cell.with(|c| {
        let mut buf = c.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Run `f` with this thread's reusable A-panel pack buffer, grown to at
/// least `len` floats (contents unspecified — the caller packs before
/// reading).  Re-entrant use of the *same* buffer on one thread panics
/// (`RefCell`); the GeMM engine borrows the A buffer only inside a
/// worker's row sweep and the B buffer only around a whole call, so the
/// two never collide.
pub fn with_pack_buf_a<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    with_buf(&PACK_A_BUF, len, f)
}

/// Run `f` with this thread's reusable B-panel pack buffer (see
/// [`with_pack_buf_a`]).  The dispatching thread packs B once, then
/// shares the filled buffer read-only with every pool worker for the
/// duration of the parallel region — sound because the region joins
/// before this call returns.
pub fn with_pack_buf_b<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    with_buf(&PACK_B_BUF, len, f)
}

// ---------------------------------------------------------------------------
// The persistent worker pool.
// ---------------------------------------------------------------------------

/// Completion latch one parallel region waits on: counts helper workers
/// still running and carries the first worker panic back to the caller.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn Any + Send>>,
}

impl Latch {
    fn new(remaining: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining, panic: None }),
            done: Condvar::new(),
        }
    }

    /// One helper finished (optionally with a panic payload).
    fn arrive(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        if st.panic.is_none() {
            st.panic = panic;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every helper has arrived; returns the first panic.
    fn wait(&self) -> Option<Box<dyn Any + Send>> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.panic.take()
    }
}

/// One unit of work handed to a parked worker: a lifetime-erased closure
/// plus the latch of the dispatching parallel region.
///
/// Soundness: the dispatching call blocks in [`Latch::wait`] until every
/// job has arrived, so `data` (a borrow of the caller's stack closure)
/// and `latch` never dangle while a worker can still dereference them.
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    latch: *const Latch,
    index: usize,
}

// SAFETY: the raw pointers are only dereferenced while the dispatching
// frame is alive (see struct docs); the pointee closure is `Sync`.
unsafe impl Send for Job {}

unsafe fn call_closure<F: Fn(usize) + Sync>(data: *const (), index: usize) {
    let f = &*(data as *const F);
    f(index);
}

/// The no-op job body used by [`pool_heal`]'s liveness pings.
unsafe fn noop_call(_data: *const (), _index: usize) {}

fn worker_loop(rx: Receiver<Job>) {
    // Pool workers only ever run inside a parallel region: nested
    // parallel entry points they hit must collapse to serial.
    IN_PARALLEL.with(|c| c.set(true));
    while let Ok(job) = rx.recv() {
        // A null data pointer is the exit sentinel ([`kill_pool_workers`]):
        // drop the receiver *first* so that once the killer's latch
        // releases, sends into this slot fail deterministically.
        if job.data.is_null() {
            let latch = job.latch;
            drop(rx);
            // SAFETY: the killer is parked in `Latch::wait` until we
            // arrive, keeping the latch alive.
            unsafe { (*latch).arrive(None) };
            return;
        }
        // SAFETY: see `Job` — the dispatcher is parked in `Latch::wait`
        // until we arrive below, keeping both pointees alive.
        let latch = unsafe { &*job.latch };
        // SAFETY: same argument — `data` stays valid until we arrive.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, job.index) }));
        latch.arrive(result.err());
    }
}

/// Spawn one parked pool worker and return its job channel.
fn spawn_worker(id: usize) -> Sender<Job> {
    let (tx, rx) = channel::<Job>();
    std::thread::Builder::new()
        .name(format!("phast-par-{id}"))
        .spawn(move || worker_loop(rx))
        .expect("failed to spawn pool worker");
    tx
}

/// The process-wide pool: one channel per parked worker, grown on demand
/// and reused by every subsequent parallel call (never torn down early —
/// threads parked in `recv()` are reclaimed by process exit).
///
/// A job carries its logical worker index, so *any* pool thread can run
/// *any* job; dispatch rotates the starting worker (`rr`) so concurrent
/// top-level regions from different caller threads spread across the
/// pool instead of all queueing on workers `0..helpers`.
struct Pool {
    senders: Mutex<Vec<Sender<Job>>>,
    spawned: AtomicUsize,
    rr: AtomicUsize,
    /// Workers respawned after dying (self-healing metric; the slot count
    /// [`pool_size`] reports is unaffected by respawns).
    respawned: AtomicUsize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

impl Pool {
    fn global() -> &'static Pool {
        POOL.get_or_init(|| Pool {
            senders: Mutex::new(Vec::new()),
            spawned: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            respawned: AtomicUsize::new(0),
        })
    }

    /// Hand `job(i)` for `i in 0..helpers` to `helpers` distinct workers
    /// (round-robin over the whole pool), spawning any that do not exist
    /// yet.  A dead slot (worker lost to [`kill_pool_workers`] or an
    /// escaped panic) is respawned in place and the job re-sent: dispatch
    /// always leaves the pool in a dispatchable state instead of wedging.
    fn dispatch(
        &self,
        helpers: usize,
        data: *const (),
        call: unsafe fn(*const (), usize),
        latch: *const Latch,
    ) {
        let mut senders = self.senders.lock().unwrap();
        while senders.len() < helpers {
            let id = senders.len();
            senders.push(spawn_worker(id));
            self.spawned.fetch_add(1, Ordering::Relaxed);
        }
        let total = senders.len();
        let start = self.rr.fetch_add(helpers, Ordering::Relaxed);
        for i in 0..helpers {
            // The job carries logical worker index i + 1 (the dispatching
            // thread itself is worker 0); which pool thread runs it does
            // not affect the result, only load spread.
            let slot = (start + i) % total;
            let mut job = Job { data, call, latch, index: i + 1 };
            loop {
                match senders[slot].send(job) {
                    Ok(()) => break,
                    Err(std::sync::mpsc::SendError(returned)) => {
                        senders[slot] = spawn_worker(slot);
                        self.respawned.fetch_add(1, Ordering::Relaxed);
                        job = returned;
                    }
                }
            }
        }
    }
}

/// Number of pool threads spawned so far in this process (introspection
/// for the reuse tests and the tuning docs; 0 before the first parallel
/// dispatch).
pub fn pool_size() -> usize {
    match POOL.get() {
        Some(p) => p.spawned.load(Ordering::Relaxed),
        None => 0,
    }
}

/// Number of pool workers respawned after death (the self-healing
/// metric: 0 in a process that never lost a worker).  Respawns replace a
/// dead slot in place, so [`pool_size`] is unaffected.
pub fn pool_respawns() -> usize {
    match POOL.get() {
        Some(p) => p.respawned.load(Ordering::Relaxed),
        None => 0,
    }
}

/// Verify every pool worker is alive (one no-op ping per slot) and
/// respawn any dead ones.  Recovery paths (e.g. the train driver after a
/// caught worker panic) call this to restore the pool to a dispatchable
/// state eagerly instead of paying the heal on the next dispatch.
/// Returns the number of workers respawned.
pub fn pool_heal() -> usize {
    let Some(pool) = POOL.get() else { return 0 };
    let mut senders = pool.senders.lock().unwrap();
    let mut healed = 0;
    for id in 0..senders.len() {
        let latch = Latch::new(1);
        let job = Job { data: &() as *const (), call: noop_call, latch: &latch, index: 0 };
        match senders[id].send(job) {
            Ok(()) => {
                let _ = latch.wait();
            }
            Err(_) => {
                senders[id] = spawn_worker(id);
                pool.respawned.fetch_add(1, Ordering::Relaxed);
                healed += 1;
            }
        }
    }
    healed
}

/// Fault-injection hook: make up to `n` pool workers exit their loops
/// (dead channels), as if lost to an escaped panic — the failure mode
/// the self-healing dispatch and [`pool_heal`] recover from.  Blocks
/// until the targeted workers have actually exited, so a subsequent
/// send into their slots fails deterministically.  Returns how many
/// workers were killed.
pub fn kill_pool_workers(n: usize) -> usize {
    let Some(pool) = POOL.get() else { return 0 };
    let targets: Vec<Sender<Job>> = {
        let senders = pool.senders.lock().unwrap();
        senders.iter().take(n).cloned().collect()
    };
    if targets.is_empty() {
        return 0;
    }
    let latch = Latch::new(targets.len());
    let mut killed = 0;
    for tx in &targets {
        let job = Job { data: std::ptr::null(), call: noop_call, latch: &latch, index: 0 };
        match tx.send(job) {
            Ok(()) => killed += 1,
            // Already dead: arrive on its behalf so the latch releases.
            Err(_) => latch.arrive(None),
        }
    }
    let _ = latch.wait();
    killed
}

/// Run `f(worker_index)` for every index in `0..workers`: indices
/// `1..workers` on parked pool workers, index 0 on the calling thread.
/// Returns only after all indices have finished; re-raises the caller's
/// own panic first, then the first worker panic.
///
/// Consumes a pending injected worker panic (`PHAST_FAULT=worker_panic`,
/// armed via [`super::fault::begin_iter`]): the designated last worker
/// panics instead of running its share, exercising the real pool panic
/// path (latch carry, barrier poison, dispatcher re-raise).
fn run_workers<F: Fn(usize) + Sync>(workers: usize, f: F) {
    if super::fault::take_worker_panic() {
        let target = workers.saturating_sub(1);
        return run_workers_impl(workers, move |w| {
            if w == target {
                panic!("injected worker_panic (PHAST_FAULT)");
            }
            f(w)
        });
    }
    run_workers_impl(workers, f)
}

fn run_workers_impl<F: Fn(usize) + Sync>(workers: usize, f: F) {
    if workers <= 1 {
        f(0);
        return;
    }
    let latch = Latch::new(workers - 1);
    let data = &f as *const F as *const ();
    Pool::global().dispatch(workers - 1, data, call_closure::<F>, &latch);
    // The dispatching thread doubles as worker 0; while it runs its
    // share it counts as "inside a parallel region".
    let was = IN_PARALLEL.with(|c| c.replace(true));
    let own = catch_unwind(AssertUnwindSafe(|| f(0)));
    IN_PARALLEL.with(|c| c.set(was));
    // Always join before returning: the helpers borrow `f` and `latch`.
    let helper_panic = latch.wait();
    if let Err(p) = own {
        resume_unwind(p);
    }
    if let Some(p) = helper_panic {
        resume_unwind(p);
    }
}

// ---------------------------------------------------------------------------
// Chunked entry points (the public API the kernels call).
// ---------------------------------------------------------------------------

/// A once-filled hand-off slot: one worker's item range + output block.
type BlockSlot<'s, T> = Mutex<Option<(Range<usize>, &'s mut [T])>>;

/// Run `f` once per worker over disjoint contiguous sub-ranges of `0..n`.
/// Serial (caller thread, no dispatch) when one worker suffices.
pub fn parallel_for(n: usize, tune: Tuning, f: impl Fn(Range<usize>) + Sync) {
    note_region();
    let workers = tune.workers(n);
    if workers <= 1 {
        check::consume_label();
        if n > 0 {
            // Serial fallback (possibly nested inside a checked region):
            // one thread, sequential — nothing to race; keep any enclosing
            // region's log free of this region's internal accesses.
            let _quiet = check::suspend();
            f(0..n);
        }
        return;
    }
    let ranges = partition(n, workers);
    let ctx = check::begin(check::RegionMode::Synced, 1, n, &ranges);
    run_workers(ranges.len(), |w| {
        let _rec = check::enter_worker(ctx.as_ref(), w);
        f(ranges[w].clone())
    });
    check::validate(ctx);
}

/// Map disjoint ranges of `0..n` through `map` and fold the per-worker
/// results **in worker order** (deterministic for a fixed thread count;
/// bitwise thread-count-invariant only when `fold` is associative over
/// the mapped values, e.g. integer sums).
pub fn parallel_reduce<A: Send>(
    n: usize,
    tune: Tuning,
    map: impl Fn(Range<usize>) -> A + Sync,
    mut fold: impl FnMut(A, A) -> A,
    init: A,
) -> A {
    note_region();
    let workers = tune.workers(n);
    if workers <= 1 {
        return if n == 0 { init } else { fold(init, map(0..n)) };
    }
    let ranges = partition(n, workers);
    let slots: Vec<Mutex<Option<A>>> = ranges.iter().map(|_| Mutex::new(None)).collect();
    run_workers(ranges.len(), |w| {
        *slots[w].lock().unwrap() = Some(map(ranges[w].clone()));
    });
    slots
        .into_iter()
        .fold(init, |acc, slot| fold(acc, slot.into_inner().unwrap().unwrap()))
}

/// Partition `data` (a packed array of `n = data.len() / item_len` items)
/// into per-worker contiguous blocks; `f(items, block)` gets the item
/// range and the matching mutable sub-slice.  One call per worker, so `f`
/// can allocate per-thread scratch once.
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    item_len: usize,
    tune: Tuning,
    f: impl Fn(Range<usize>, &mut [T]) + Sync,
) {
    note_region();
    assert!(item_len > 0, "item_len must be positive");
    assert_eq!(data.len() % item_len, 0, "data not a whole number of items");
    let n = data.len() / item_len;
    let workers = tune.workers(n);
    if workers <= 1 {
        if n > 0 {
            f(0..n, data);
        }
        return;
    }
    // Split the output into disjoint blocks up front; each worker takes
    // exactly its own slot (a once-filled Mutex, uncontended by design).
    let ranges = partition(n, workers);
    let mut blocks: Vec<BlockSlot<'_, T>> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    for r in &ranges {
        let take = r.len() * item_len;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        rest = tail;
        blocks.push(Mutex::new(Some((r.clone(), head))));
    }
    run_workers(blocks.len(), |w| {
        let (r, block) = blocks[w].lock().unwrap().take().unwrap();
        f(r, block);
    });
}

/// Like [`parallel_chunks_mut`] over two parallel arrays with their own
/// item sizes (e.g. pooling's value and argmax outputs).
pub fn parallel_chunks2_mut<T: Send, U: Send>(
    a: &mut [T],
    a_item: usize,
    b: &mut [U],
    b_item: usize,
    tune: Tuning,
    f: impl Fn(Range<usize>, &mut [T], &mut [U]) + Sync,
) {
    note_region();
    assert!(a_item > 0 && b_item > 0, "item lengths must be positive");
    assert_eq!(a.len() % a_item, 0, "a not a whole number of items");
    assert_eq!(b.len() % b_item, 0, "b not a whole number of items");
    let n = a.len() / a_item;
    assert_eq!(b.len() / b_item, n, "a and b disagree on item count");
    let workers = tune.workers(n);
    if workers <= 1 {
        if n > 0 {
            f(0..n, a, b);
        }
        return;
    }
    let ranges = partition(n, workers);
    type Slot2<'s, T, U> = Mutex<Option<(Range<usize>, &'s mut [T], &'s mut [U])>>;
    let mut blocks: Vec<Slot2<'_, T, U>> = Vec::with_capacity(ranges.len());
    let mut rest_a = a;
    let mut rest_b = b;
    for r in &ranges {
        let (head_a, tail_a) = std::mem::take(&mut rest_a).split_at_mut(r.len() * a_item);
        let (head_b, tail_b) = std::mem::take(&mut rest_b).split_at_mut(r.len() * b_item);
        rest_a = tail_a;
        rest_b = tail_b;
        blocks.push(Mutex::new(Some((r.clone(), head_a, head_b))));
    }
    run_workers(blocks.len(), |w| {
        let (r, block_a, block_b) = blocks[w].lock().unwrap().take().unwrap();
        f(r, block_a, block_b);
    });
}

/// [`parallel_chunks_mut`] that additionally collects a per-worker
/// accumulator (e.g. a local `dW`/`db`), returned **in worker order** so
/// the caller's serial merge is deterministic for a fixed thread count.
pub fn parallel_chunks_reduce<T: Send, A: Send>(
    data: &mut [T],
    item_len: usize,
    tune: Tuning,
    f: impl Fn(Range<usize>, &mut [T]) -> A + Sync,
) -> Vec<A> {
    note_region();
    assert!(item_len > 0, "item_len must be positive");
    assert_eq!(data.len() % item_len, 0, "data not a whole number of items");
    let n = data.len() / item_len;
    let workers = tune.workers(n);
    if workers <= 1 {
        if n == 0 {
            return vec![];
        }
        return vec![f(0..n, data)];
    }
    let ranges = partition(n, workers);
    let mut blocks: Vec<BlockSlot<'_, T>> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    for r in &ranges {
        let take = r.len() * item_len;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        rest = tail;
        blocks.push(Mutex::new(Some((r.clone(), head))));
    }
    let results: Vec<Mutex<Option<A>>> = ranges.iter().map(|_| Mutex::new(None)).collect();
    run_workers(blocks.len(), |w| {
        let (r, block) = blocks[w].lock().unwrap().take().unwrap();
        *results[w].lock().unwrap() = Some(f(r, block));
    });
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().unwrap())
        .collect()
}

// ---------------------------------------------------------------------------
// Fused multi-stage regions: one dispatch, several dependent passes.
// ---------------------------------------------------------------------------

/// A mutable slice shared across the stages of one fused region.
///
/// Fused stages are `Fn` closures shared by every worker, so they cannot
/// capture `&mut` slices directly; `FusedSlice` erases the borrow into a
/// raw pointer that workers re-slice per stage.  The fused-region contract
/// makes this sound: every stage of a region is called with the **same**
/// deterministic contiguous partition, so worker `w` touches the same
/// index range in every stage, and a barrier separates consecutive stages.
pub struct FusedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is delegated to the unsafe `slice`/`slice_mut` methods,
// whose contract (disjoint ranges across concurrent workers, cross-range
// reads only across a stage barrier) is exactly the data-race freedom
// argument; `T: Send + Sync` keeps the underlying element type shareable.
unsafe impl<T: Send + Sync> Sync for FusedSlice<'_, T> {}
// SAFETY: the view is just a pointer + length over data the borrow keeps
// alive for 'a; sending it to a pool worker is no different from sending
// the `&mut [T]` it was built from.
unsafe impl<T: Send> Send for FusedSlice<'_, T> {}

impl<'a, T> FusedSlice<'a, T> {
    /// Wrap a mutable slice for use inside fused stages.  The borrow is
    /// held for `'a`, so the caller cannot touch `data` until the region
    /// (and this view) is gone.
    pub fn new(data: &'a mut [T]) -> FusedSlice<'a, T> {
        FusedSlice { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    ///
    /// Within one fused region, ranges passed to `slice_mut` by
    /// *concurrently executing* stage closures must be disjoint — in
    /// practice: derive the range from the stage's own partition range
    /// (identically in every stage), never from another worker's.
    /// `range` must lie within `0..self.len()`.
    // The &self -> &mut window is the whole point of the type: disjoint
    // per-worker windows of one buffer, guarded by the contract above.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        check::record(self.ptr as usize, self.len, &range, true);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.len())
    }

    /// Shared view of `range`.
    ///
    /// # Safety
    ///
    /// No concurrently executing stage closure may hold a mutable view
    /// overlapping `range`.  Reading another worker's range is sound only
    /// when a stage barrier separates the write from this read (the
    /// barrier establishes the happens-before edge).  `range` must lie
    /// within `0..self.len()`.
    pub unsafe fn slice(&self, range: Range<usize>) -> &[T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        check::record(self.ptr as usize, self.len, &range, false);
        std::slice::from_raw_parts(self.ptr.add(range.start), range.len())
    }
}

/// Reusable barrier separating the stages of a fused region, poisoned by
/// a panicking worker so the surviving workers wake and bail out instead
/// of deadlocking at the next stage boundary.
struct StageBarrier {
    state: Mutex<StageBarrierState>,
    cv: Condvar,
}

struct StageBarrierState {
    arrived: usize,
    total: usize,
    generation: u64,
    poisoned: bool,
}

impl StageBarrier {
    fn new(total: usize) -> StageBarrier {
        StageBarrier {
            state: Mutex::new(StageBarrierState {
                arrived: 0,
                total,
                generation: 0,
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until all workers arrive.  Returns `false` when the barrier
    /// was poisoned (a sibling worker panicked): the caller must run no
    /// further stages.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.poisoned {
            return false;
        }
        st.arrived += 1;
        if st.arrived == st.total {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return true;
        }
        let gen = st.generation;
        while st.generation == gen && !st.poisoned {
            st = self.cv.wait(st).unwrap();
        }
        !st.poisoned
    }

    /// Mark the region failed and wake every waiter.
    fn poison(&self) {
        let mut st = self.state.lock().unwrap();
        st.poisoned = true;
        self.cv.notify_all();
    }
}

/// Poisons the barrier if dropped during unwinding — armed around each
/// worker's stage loop, disarmed (forgotten) on normal completion.
struct PoisonOnUnwind<'b>(&'b StageBarrier);

impl Drop for PoisonOnUnwind<'_> {
    fn drop(&mut self) {
        self.0.poison();
    }
}

/// Run `stages` dependent passes over the same chunked index space in
/// **one** pool dispatch: `f(stage, range)` is called for `stage` in
/// `0..stages`, every stage over the same deterministic contiguous
/// partition of `0..n` (worker `w` gets the same range in every stage).
/// A barrier separates consecutive stages, so stage `s + 1` may read
/// anything stage `s` wrote — including other workers' ranges.
///
/// This is the fusion seam the per-layer pass structure collapses into:
/// where the unfused code issued one dispatch per BLAS call or per
/// elementwise map, a fused region pays one dispatch for the whole chain
/// and keeps results bitwise-equal to the unfused path (same partition,
/// same per-element arithmetic — see `docs/PARALLEL_RUNTIME.md`).
///
/// Panics in any stage propagate to the caller; sibling workers parked at
/// a stage boundary are woken through the poisoned barrier and skip their
/// remaining stages.  Nested calls (from inside another region) run all
/// stages sequentially on the calling worker.
pub fn parallel_regions(
    n: usize,
    stages: usize,
    tune: Tuning,
    f: impl Fn(usize, Range<usize>) + Sync,
) {
    note_region();
    if stages == 0 || n == 0 {
        return;
    }
    let workers = tune.workers(n);
    if workers <= 1 {
        check::consume_label();
        // Serial fallback: one thread runs the stages in order — nothing
        // to race; suspend any enclosing region's recording (see
        // `parallel_for`).
        let _quiet = check::suspend();
        for s in 0..stages {
            f(s, 0..n);
        }
        return;
    }
    let ranges = partition(n, workers);
    let ctx = check::begin(check::RegionMode::Synced, stages, n, &ranges);
    let barrier = StageBarrier::new(ranges.len());
    run_workers(ranges.len(), |w| {
        let _rec = check::enter_worker(ctx.as_ref(), w);
        let guard = PoisonOnUnwind(&barrier);
        for s in 0..stages {
            if s > 0 && !barrier.wait() {
                break;
            }
            check::set_stage(s);
            f(s, ranges[w].clone());
        }
        std::mem::forget(guard);
    });
    check::validate(ctx);
}

/// [`parallel_regions`] **without** the inter-stage barrier: worker `w`
/// runs every stage back-to-back over its own range, in one dispatch.
///
/// The saved cost is exactly `stages - 1` barrier crossings per region
/// (the `stage_barrier` entry `benches/fusion.rs` records measures that
/// price).  In exchange the cross-worker happens-before edge between
/// stages is gone, so this variant is only correct for **pointwise**
/// stage chains: every stage must read and write *only* the worker's own
/// partition range (the SGD regularize → momentum → update chain is the
/// canonical example — each stage is element-local, so worker `w` never
/// needs another worker's stage-`s` results).  A chain where stage
/// `s + 1` reads outside its own range (anything [`FusedSlice::slice`]'s
/// cross-range contract would cover) **must** stay on
/// [`parallel_regions`]; using this variant there is a data race.
///
/// Because each worker's per-element arithmetic and stage order are
/// unchanged, results for a contract-respecting chain are **bitwise
/// equal** to the barrier path at every thread count.  Counts as one
/// region in [`region_count`]; panics propagate through the pool latch
/// as usual (no barrier exists to poison); nested calls serialize.
pub fn parallel_regions_unsynced(
    n: usize,
    stages: usize,
    tune: Tuning,
    f: impl Fn(usize, Range<usize>) + Sync,
) {
    note_region();
    if stages == 0 || n == 0 {
        return;
    }
    let workers = tune.workers(n);
    if workers <= 1 {
        check::consume_label();
        // Serial fallback: see `parallel_for`.
        let _quiet = check::suspend();
        for s in 0..stages {
            f(s, 0..n);
        }
        return;
    }
    let ranges = partition(n, workers);
    let ctx = check::begin(check::RegionMode::Unsynced, stages, n, &ranges);
    run_workers(ranges.len(), |w| {
        let _rec = check::enter_worker(ctx.as_ref(), w);
        for s in 0..stages {
            check::set_stage(s);
            f(s, ranges[w].clone());
        }
    });
    check::validate(ctx);
}

/// Builder over [`parallel_regions`] for call sites whose stages are
/// heterogeneous closures: chain [`stage`](FusedRegion::stage) calls and
/// [`run`](FusedRegion::run) the whole sequence as one dispatch.
///
/// ```
/// # use phast_caffe::ops::par::{FusedRegion, FusedSlice, Tuning};
/// let mut data = vec![1.0f32; 64];
/// let view = FusedSlice::new(&mut data);
/// FusedRegion::new(64, Tuning::new(1))
///     .stage(|r| unsafe { view.slice_mut(r).iter_mut().for_each(|v| *v += 1.0) })
///     .stage(|r| unsafe { view.slice_mut(r).iter_mut().for_each(|v| *v *= 2.0) })
///     .run();
/// drop(view);
/// assert_eq!(data[0], 4.0);
/// ```
pub struct FusedRegion<'a> {
    n: usize,
    tune: Tuning,
    stages: Vec<Box<dyn Fn(Range<usize>) + Sync + 'a>>,
}

impl<'a> FusedRegion<'a> {
    /// A fused region over the index space `0..n` with the given tuning.
    pub fn new(n: usize, tune: Tuning) -> FusedRegion<'a> {
        FusedRegion { n, tune, stages: Vec::new() }
    }

    /// Append a stage; it runs after a barrier behind the previous stage.
    pub fn stage(mut self, f: impl Fn(Range<usize>) + Sync + 'a) -> FusedRegion<'a> {
        self.stages.push(Box::new(f));
        self
    }

    /// Execute all stages in one dispatch (see [`parallel_regions`]).
    pub fn run(self) {
        let stages = self.stages;
        parallel_regions(self.n, stages.len(), self.tune, |s, r| (stages[s])(r));
    }

    /// Execute all stages in one dispatch with **no** inter-stage barrier
    /// — only sound for pointwise stage chains; see
    /// [`parallel_regions_unsynced`] for the contract.
    pub fn run_unsynced(self) {
        let stages = self.stages;
        parallel_regions_unsynced(self.n, stages.len(), self.tune, |s, r| (stages[s])(r));
    }
}

/// The pre-pool dispatch: spawn one scoped thread per worker range, every
/// call.  Kept **only** as the overhead baseline for the pool-vs-spawn
/// microbench in `benches/threads_scaling.rs`; no kernel calls this.
pub fn parallel_for_spawn(n: usize, tune: Tuning, f: impl Fn(Range<usize>) + Sync) {
    note_region();
    let workers = tune.workers(n);
    if workers <= 1 {
        if n > 0 {
            f(0..n);
        }
        return;
    }
    std::thread::scope(|s| {
        for r in partition(n, workers) {
            let f = &f;
            s.spawn(move || {
                IN_PARALLEL.with(|c| c.set(true));
                f(r)
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn partition_covers_exactly() {
        for n in [0usize, 1, 2, 7, 64, 65] {
            for p in [1usize, 2, 3, 8, 100] {
                let ranges = partition(n, p);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, n);
                // near-equal: sizes differ by at most one
                if !ranges.is_empty() {
                    let min = ranges.iter().map(|r| r.len()).min().unwrap();
                    let max = ranges.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1, "n={n} p={p}: {min}..{max}");
                }
            }
        }
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        with_threads(4, || {
            parallel_for(n, Tuning::new(1), |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_mut_writes_disjoint_blocks() {
        let mut data = vec![0usize; 12 * 3];
        with_threads(5, || {
            parallel_chunks_mut(&mut data, 3, Tuning::new(1), |items, block| {
                for (bi, item) in items.enumerate() {
                    for k in 0..3 {
                        block[bi * 3 + k] = item * 10 + k;
                    }
                }
            });
        });
        for item in 0..12 {
            for k in 0..3 {
                assert_eq!(data[item * 3 + k], item * 10 + k);
            }
        }
    }

    #[test]
    fn chunks2_mut_blocks_stay_aligned() {
        let mut a = vec![0usize; 10 * 2];
        let mut b = vec![0usize; 10 * 5];
        with_threads(3, || {
            parallel_chunks2_mut(&mut a, 2, &mut b, 5, Tuning::new(1), |items, ab, bb| {
                for (bi, item) in items.enumerate() {
                    ab[bi * 2] = item;
                    bb[bi * 5] = item;
                }
            });
        });
        for item in 0..10 {
            assert_eq!(a[item * 2], item);
            assert_eq!(b[item * 5], item);
        }
    }

    #[test]
    fn reduce_matches_serial_sum() {
        let n = 4321u64;
        let want: u64 = (0..n).sum();
        for t in [1usize, 2, 4, 7] {
            let got = with_threads(t, || {
                parallel_reduce(
                    n as usize,
                    Tuning::new(16),
                    |r| r.map(|i| i as u64).sum::<u64>(),
                    |a, b| a + b,
                    0u64,
                )
            });
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn chunks_reduce_returns_worker_order() {
        let mut data = vec![0u8; 100];
        let parts = with_threads(4, || {
            parallel_chunks_reduce(&mut data, 1, Tuning::new(1), |r, _| r.start)
        });
        let mut sorted = parts.clone();
        sorted.sort_unstable();
        assert_eq!(parts, sorted, "partials must arrive in worker order");
        assert_eq!(parts.len(), 4);
    }

    #[test]
    fn nested_regions_serialize() {
        let spawned_nested = AtomicU64::new(0);
        with_threads(4, || {
            parallel_for(8, Tuning::new(1), |_| {
                assert!(in_parallel());
                // a nested parallel call must collapse to one worker
                let tune = Tuning::new(1);
                assert_eq!(tune.workers(100), 1);
                parallel_for(10, tune, |r| {
                    if r.len() < 10 {
                        spawned_nested.fetch_add(1, Ordering::Relaxed);
                    }
                });
            });
        });
        assert_eq!(spawned_nested.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn with_threads_restores_on_exit() {
        let outer = num_threads();
        with_threads(3, || assert_eq!(num_threads(), 3));
        assert_eq!(num_threads(), outer);
        // restored even across a panic
        let _ = std::panic::catch_unwind(|| with_threads(5, || panic!("boom")));
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn workers_respect_grain() {
        with_threads(8, || {
            let t = Tuning::new(32);
            assert_eq!(t.workers(0), 1);
            assert_eq!(t.workers(31), 1);
            assert_eq!(t.workers(64), 2);
            assert_eq!(t.workers(10_000), 8);
        });
    }

    #[test]
    fn pool_reuses_workers_across_calls() {
        // Warm the pool beyond any other test's thread demand in this
        // binary — explicit `with_threads` callers use at most 16, and
        // un-wrapped callers default to `hardware_threads()` — so
        // concurrently running tests cannot grow it between our
        // measurements.
        let warm = hardware_threads().max(16) + 8;
        with_threads(warm, || parallel_for(warm * 4, Tuning::new(1), |_| {}));
        let warmed = pool_size();
        assert!(warmed >= warm - 1, "pool did not grow to demand: {warmed} < {}", warm - 1);
        // Hammer it at several worker counts: no further growth.
        for _ in 0..100 {
            with_threads(4, || parallel_for(64, Tuning::new(1), |_| {}));
            with_threads(warm, || parallel_for(warm * 4, Tuning::new(1), |_| {}));
        }
        assert_eq!(pool_size(), warmed, "pool grew on reuse");
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_threads(4, || {
                parallel_for(8, Tuning::new(1), |r| {
                    if r.contains(&7) {
                        panic!("kernel panic in worker");
                    }
                });
            });
        }));
        assert!(boom.is_err(), "worker panic must reach the dispatcher");
        // The pool must still work after a panic.
        let hits = AtomicUsize::new(0);
        with_threads(4, || {
            parallel_for(8, Tuning::new(1), |r| {
                hits.fetch_add(r.len(), Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn fused_stages_see_prior_stage_writes_across_workers() {
        // Stage 1 reads the mirror of what stage 0 wrote — including slots
        // owned by *other* workers — so this only passes if the barrier
        // actually separates the stages.
        let n = 257;
        let mut a = vec![0usize; n];
        let mut b = vec![0usize; n];
        {
            let av = FusedSlice::new(&mut a);
            let bv = FusedSlice::new(&mut b);
            with_threads(5, || {
                // SAFETY: stage 0 writes only the worker's own `a` range;
                // stage 1 writes its own `b` range and reads `a` across the
                // stage barrier — exactly the FusedSlice contract.
                parallel_regions(n, 2, Tuning::new(1), |stage, r| unsafe {
                    match stage {
                        0 => {
                            let ab = av.slice_mut(r.clone());
                            for (slot, i) in ab.iter_mut().zip(r) {
                                *slot = i * 3 + 1;
                            }
                        }
                        _ => {
                            let bb = bv.slice_mut(r.clone());
                            for (slot, i) in bb.iter_mut().zip(r) {
                                // cross-worker read: the mirrored index
                                *slot = av.slice(n - 1 - i..n - i)[0];
                            }
                        }
                    }
                });
            });
        }
        for (i, &slot) in b.iter().enumerate() {
            assert_eq!(slot, (n - 1 - i) * 3 + 1, "slot {i}");
        }
    }

    #[test]
    fn unsynced_region_matches_barrier_region_on_pointwise_chain() {
        // A pointwise 3-stage chain (each stage touches only its own
        // range) must produce identical results with and without the
        // inter-stage barrier, count as one region, and keep per-worker
        // stage order.
        let n = 10_007;
        let mut barrier = vec![1.0f32; n];
        let mut unsync = vec![1.0f32; n];
        with_threads(5, || {
            {
                let v = FusedSlice::new(&mut barrier);
                // SAFETY: pointwise — every stage touches only the
                // worker's own range.
                parallel_regions(n, 3, Tuning::new(1), |s, r| unsafe {
                    let b = v.slice_mut(r);
                    match s {
                        0 => b.iter_mut().for_each(|x| *x += 3.0),
                        1 => b.iter_mut().for_each(|x| *x *= 0.5),
                        _ => b.iter_mut().for_each(|x| *x -= 1.0),
                    }
                });
            }
            let before = region_count();
            {
                let v = FusedSlice::new(&mut unsync);
                // SAFETY: pointwise — every stage touches only the
                // worker's own range, so no barrier is needed.
                parallel_regions_unsynced(n, 3, Tuning::new(1), |s, r| unsafe {
                    let b = v.slice_mut(r);
                    match s {
                        0 => b.iter_mut().for_each(|x| *x += 3.0),
                        1 => b.iter_mut().for_each(|x| *x *= 0.5),
                        _ => b.iter_mut().for_each(|x| *x -= 1.0),
                    }
                });
            }
            assert_eq!(region_count() - before, 1, "unsynced region must count once");
        });
        assert_eq!(barrier, unsync, "unsynced pointwise chain diverged from the barrier path");
    }

    #[test]
    fn unsynced_region_panic_propagates_and_pool_survives() {
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_threads(4, || {
                parallel_regions_unsynced(16, 3, Tuning::new(1), |stage, r| {
                    if stage == 2 && r.contains(&15) {
                        panic!("unsynced stage panic");
                    }
                });
            });
        }));
        assert!(boom.is_err(), "unsynced stage panic must reach the dispatcher");
        let hits = AtomicUsize::new(0);
        with_threads(4, || {
            parallel_regions_unsynced(16, 2, Tuning::new(1), |_, r| {
                hits.fetch_add(r.len(), Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn nested_unsynced_region_serializes() {
        let stage_runs = AtomicU64::new(0);
        with_threads(4, || {
            parallel_for(4, Tuning::new(1), |_| {
                assert!(in_parallel());
                parallel_regions_unsynced(100, 2, Tuning::new(1), |_, r| {
                    assert_eq!(r, 0..100, "nested unsynced stage must see the full range");
                    stage_runs.fetch_add(1, Ordering::Relaxed);
                });
            });
        });
        assert_eq!(stage_runs.load(Ordering::Relaxed), 4 * 2);
    }

    #[test]
    fn fused_region_builder_runs_stages_in_order() {
        let mut data = vec![1.0f32; 100];
        {
            let view = FusedSlice::new(&mut data);
            with_threads(4, || {
                FusedRegion::new(100, Tuning::new(1))
                    // SAFETY: pointwise — each stage writes only its own range.
                    .stage(|r| unsafe {
                        view.slice_mut(r).iter_mut().for_each(|v| *v += 2.0);
                    })
                    // SAFETY: pointwise — each stage writes only its own range.
                    .stage(|r| unsafe {
                        view.slice_mut(r).iter_mut().for_each(|v| *v *= 10.0);
                    })
                    .run();
            });
        }
        assert!(data.iter().all(|&v| v == 30.0), "stage order violated");
    }

    #[test]
    fn fused_region_builder_unsynced_matches_barrier_on_pointwise_chain() {
        // The builder's barrier-free execution path: a pointwise chain
        // must produce the same result as `run()`, per worker in stage
        // order, counting as one region.
        let mut data = vec![1.0f32; 100];
        {
            let view = FusedSlice::new(&mut data);
            with_threads(4, || {
                let before = region_count();
                FusedRegion::new(100, Tuning::new(1))
                    // SAFETY: pointwise — each stage writes only its own range.
                    .stage(|r| unsafe {
                        view.slice_mut(r).iter_mut().for_each(|v| *v += 2.0);
                    })
                    // SAFETY: pointwise — each stage writes only its own range.
                    .stage(|r| unsafe {
                        view.slice_mut(r).iter_mut().for_each(|v| *v *= 10.0);
                    })
                    .run_unsynced();
                assert_eq!(region_count() - before, 1, "unsynced builder must count once");
            });
        }
        assert!(data.iter().all(|&v| v == 30.0), "unsynced stage order violated");
    }

    #[test]
    fn fused_region_counts_as_one_region() {
        with_threads(4, || {
            // Warm so the comparison below is not polluted by pool growth.
            parallel_regions(64, 3, Tuning::new(1), |_, _| {});
            let before = region_count();
            parallel_regions(64, 3, Tuning::new(1), |_, _| {});
            assert_eq!(region_count() - before, 1, "fused region must count once");
            let before = region_count();
            parallel_for(64, Tuning::new(1), |_| {});
            parallel_for(64, Tuning::new(1), |_| {});
            parallel_for(64, Tuning::new(1), |_| {});
            assert_eq!(region_count() - before, 3, "unfused calls count per call");
        });
    }

    #[test]
    fn fused_mid_stage_panic_propagates_and_pool_survives() {
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_threads(4, || {
                parallel_regions(16, 3, Tuning::new(1), |stage, r| {
                    if stage == 1 && r.contains(&0) {
                        panic!("mid-sequence stage panic");
                    }
                });
            });
        }));
        assert!(boom.is_err(), "stage panic must reach the dispatcher");
        // Workers parked at the stage barrier were woken via poisoning and
        // the pool still works.
        let hits = AtomicUsize::new(0);
        with_threads(4, || {
            parallel_regions(16, 2, Tuning::new(1), |_, r| {
                hits.fetch_add(r.len(), Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn nested_fused_region_serializes() {
        let stage_runs = AtomicUsize::new(0);
        with_threads(4, || {
            parallel_for(8, Tuning::new(1), |_| {
                assert!(in_parallel());
                // Nested fusion must collapse to the serial path: every
                // stage runs exactly once over the full range, in order.
                let order = Mutex::new(Vec::new());
                parallel_regions(100, 3, Tuning::new(1), |stage, r| {
                    assert_eq!(r, 0..100, "nested stage must see the full range");
                    order.lock().unwrap().push(stage);
                    stage_runs.fetch_add(1, Ordering::Relaxed);
                });
                assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
            });
        });
        assert_eq!(stage_runs.load(Ordering::Relaxed), 8 * 3);
    }

    #[test]
    fn spawn_baseline_matches_pool_results() {
        let n = 500;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        with_threads(4, || {
            parallel_for_spawn(n, Tuning::new(1), |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
