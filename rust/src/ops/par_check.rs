//! `ops::par::check` — the fused-region access sanitizer (`PHAST_CHECK`).
//!
//! The fused-region model in [`ops::par`](super) rests on contracts the
//! type system cannot see: [`FusedSlice`](super::FusedSlice) erases a
//! `&mut [T]` into a raw pointer that stage closures re-slice, and the
//! comments on `slice`/`slice_mut` (plus the pointwise-chain rule on
//! [`parallel_regions_unsynced`](super::parallel_regions_unsynced)) are
//! all that separates the fused kernels from a data race.  This module
//! turns those comments into machine-checked assertions:
//!
//! * In **checked mode** (`PHAST_CHECK=1`, or a process-local
//!   [`set_override`]), every region dispatched by
//!   [`parallel_for`](super::parallel_for),
//!   [`parallel_regions`](super::parallel_regions) or
//!   [`parallel_regions_unsynced`](super::parallel_regions_unsynced)
//!   installs a per-worker access log; `FusedSlice::slice`/`slice_mut`
//!   record `(worker, stage, range, read|write)` events into the log of
//!   the worker that issued them.
//! * After the region joins, a validator asserts the documented
//!   contracts and **panics with full region/stage/range context** on
//!   the first violation:
//!   - **C1 (synced regions)** — within one stage, ranges written by
//!     concurrently executing workers must be disjoint, and no worker
//!     may read a range another worker writes in the same stage.
//!     Cross-*stage* overlap is legal: the stage barrier provides the
//!     happens-before edge (`FusedSlice::slice`'s cross-range rule).
//!   - **C2 (unsynced regions)** — no barrier exists, so the whole
//!     region is one conflict class: any cross-worker overlap involving
//!     a write, in *any* pair of stages, is a violation.  This subsumes
//!     the pointwise-chain rule ("touch only your own range") while
//!     still admitting contracts like the flat SGD step, whose
//!     segment-local ranges are disjoint across workers without being
//!     the worker's literal partition range.
//!
//! Callers may name the next region via [`label_region`] so violations
//! point at the layer/kernel that issued the region, not just a buffer
//! address.
//!
//! **Zero-cost when off**: with checking disabled no context is ever
//! installed, so the per-access hook is a single thread-local null
//! check, and the per-region hook is one cached-knob load.  The
//! `check_overhead` entry in `benches/fusion.rs` gates this (region
//! delta exactly 0, off-mode timing within noise of the unchecked
//! reference).
//!
//! The checker detects *overlap between recorded ranges*, not memory
//! errors in general: accesses that bypass `FusedSlice` (plain
//! `split_at_mut` chunking, GeMM-internal slices) are already safe by
//! construction and are not logged.  See `docs/CHECKING.md` for the
//! contract catalogue and violation taxonomy.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// How the region synchronizes its stages — decides the conflict class
/// granularity (per stage vs whole region).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionMode {
    /// Stage barriers between consecutive stages ([`super::parallel_for`]
    /// counts as a single-stage synced region).
    Synced,
    /// No inter-stage barriers ([`super::parallel_regions_unsynced`]).
    Unsynced,
}

impl RegionMode {
    fn as_str(self) -> &'static str {
        match self {
            RegionMode::Synced => "synced",
            RegionMode::Unsynced => "unsynced",
        }
    }
}

/// Process-local override of the `PHAST_CHECK` knob: 0 = follow the
/// environment, 1 = forced on, 2 = forced off.  The test suite and the
/// `check_overhead` bench flip this at runtime; the environment knob
/// itself is read once.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn env_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| match std::env::var("PHAST_CHECK") {
        Ok(v) => !matches!(v.trim(), "" | "0" | "off"),
        Err(_) => false,
    })
}

/// Whether checked mode is active: [`set_override`] wins, else the
/// `PHAST_CHECK` environment knob (parsed once; `1`/anything truthy
/// enables, `0`/`off`/empty disables).
pub fn enabled() -> bool {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => env_enabled(),
    }
}

/// Force checked mode on (`Some(true)`), off (`Some(false)`), or back to
/// the environment knob (`None`) for the whole process.  Used by the
/// seeded-violation tests and the overhead bench; production code uses
/// the `PHAST_CHECK` environment knob.
pub fn set_override(on: Option<bool>) {
    OVERRIDE.store(
        match on {
            Some(true) => 1,
            Some(false) => 2,
            None => 0,
        },
        Ordering::Relaxed,
    );
}

/// One recorded `FusedSlice` access.
#[derive(Clone, Copy, Debug)]
struct Access {
    /// Buffer identity: the view's base pointer.
    buf: usize,
    /// Buffer length in elements (diagnostics only).
    buf_len: usize,
    stage: usize,
    start: usize,
    end: usize,
    write: bool,
}

/// The per-region recording context, created by the dispatcher before
/// the workers run and validated after they join.  Lives on the
/// dispatching frame; workers hold a raw pointer to it only while the
/// dispatcher is parked in the region's latch (the same soundness
/// argument as `par::Job`).
pub(super) struct RegionCtx {
    label: String,
    mode: RegionMode,
    stages: usize,
    n: usize,
    ranges: Vec<Range<usize>>,
    /// One log per worker; each slot is touched by exactly one worker
    /// thread during the region, so the mutexes are uncontended.
    logs: Vec<Mutex<Vec<Access>>>,
}

thread_local! {
    /// The region context this thread currently records into (null when
    /// not inside a checked region) — the per-access fast-path gate.
    static ACTIVE: Cell<*const RegionCtx> = const { Cell::new(std::ptr::null()) };
    /// Logical worker index of this thread within the active region.
    static WORKER: Cell<usize> = const { Cell::new(0) };
    /// Stage the active region is currently executing on this thread.
    static STAGE: Cell<usize> = const { Cell::new(0) };
    /// Label armed by [`label_region`] for the next region entry.
    static NEXT_LABEL: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Name the next parallel region issued from this thread, so a contract
/// violation reports the issuing kernel (e.g. `conv2.bwd+pool`) instead
/// of only a buffer address.  The closure runs only in checked mode, so
/// unlabelled production paths pay one cached-knob load.
pub fn label_region(label: impl FnOnce() -> String) {
    if !enabled() {
        return;
    }
    NEXT_LABEL.with(|l| *l.borrow_mut() = Some(label()));
}

/// Open a recording context for a region about to dispatch `ranges`
/// (one per worker).  Returns `None` when checking is off — the entire
/// checked path hangs off this one test.
pub(super) fn begin(
    mode: RegionMode,
    stages: usize,
    n: usize,
    ranges: &[Range<usize>],
) -> Option<RegionCtx> {
    if !enabled() {
        return None;
    }
    let label = NEXT_LABEL
        .with(|l| l.borrow_mut().take())
        .unwrap_or_else(|| "<unlabelled>".to_string());
    Some(RegionCtx {
        label,
        mode,
        stages,
        n,
        ranges: ranges.to_vec(),
        logs: ranges.iter().map(|_| Mutex::new(Vec::new())).collect(),
    })
}

/// Drop a pending label without opening a context — the serial fallback
/// (one worker: no concurrency, nothing to check) still consumes its
/// label so it cannot leak onto an unrelated later region.
pub(super) fn consume_label() {
    if !enabled() {
        return;
    }
    NEXT_LABEL.with(|l| l.borrow_mut().take());
}

/// Restores this thread's recording state when a worker leaves a region
/// (normally or by unwinding — pool workers are reused, so a stale
/// context pointer must never survive the region).
pub(super) struct WorkerGuard {
    prev: *const RegionCtx,
    prev_worker: usize,
    prev_stage: usize,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        ACTIVE.with(|c| c.set(self.prev));
        WORKER.with(|c| c.set(self.prev_worker));
        STAGE.with(|c| c.set(self.prev_stage));
    }
}

/// Install `ctx` as this thread's recording target for worker `w`.
/// No-op (and no guard) when the region is unchecked.
pub(super) fn enter_worker(ctx: Option<&RegionCtx>, w: usize) -> Option<WorkerGuard> {
    let ctx = ctx?;
    let guard = WorkerGuard {
        prev: ACTIVE.with(Cell::get),
        prev_worker: WORKER.with(Cell::get),
        prev_stage: STAGE.with(Cell::get),
    };
    ACTIVE.with(|c| c.set(ctx as *const RegionCtx));
    WORKER.with(|c| c.set(w));
    STAGE.with(|c| c.set(0));
    Some(guard)
}

/// Mark the stage this thread is about to execute (events recorded
/// after this call carry stage `s`).  Cheap no-op outside a checked
/// region.
pub(super) fn set_stage(s: usize) {
    if ACTIVE.with(Cell::get).is_null() {
        return;
    }
    STAGE.with(|c| c.set(s));
}

/// Suspends recording on this thread for the guard's lifetime — used by
/// the serial fallbacks of nested regions, whose accesses belong to the
/// nested (serial, race-free) region and would otherwise be
/// misattributed to the enclosing checked region's current stage.
pub(super) struct SuspendGuard {
    prev: *const RegionCtx,
}

impl Drop for SuspendGuard {
    fn drop(&mut self) {
        ACTIVE.with(|c| c.set(self.prev));
    }
}

pub(super) fn suspend() -> Option<SuspendGuard> {
    let prev = ACTIVE.with(Cell::get);
    if prev.is_null() {
        return None;
    }
    ACTIVE.with(|c| c.set(std::ptr::null()));
    Some(SuspendGuard { prev })
}

/// Record one `FusedSlice` access issued by the calling worker.  The
/// fast path (checking off, or thread outside a checked region) is a
/// single thread-local null check.
pub(super) fn record(buf: usize, buf_len: usize, range: &Range<usize>, write: bool) {
    let ctx = ACTIVE.with(Cell::get);
    if ctx.is_null() {
        return;
    }
    if range.start >= range.end {
        return; // empty access: nothing to race on
    }
    // SAFETY: a non-null ACTIVE pointer is installed only between
    // `enter_worker` and its guard's drop, both of which happen while
    // the dispatching frame that owns the context is parked in the
    // region's latch — so the pointee outlives every recording call.
    let ctx = unsafe { &*ctx };
    let w = WORKER.with(Cell::get);
    let s = STAGE.with(Cell::get);
    ctx.logs[w].lock().unwrap().push(Access {
        buf,
        buf_len,
        stage: s,
        start: range.start,
        end: range.end,
        write,
    });
}

/// Per-worker access lists for one `(buffer, conflict class)` group,
/// sorted by start offset for the linear sweep in [`intersect`].
#[derive(Default)]
struct WorkerAccesses {
    writes: Vec<Access>,
    reads: Vec<Access>,
}

/// First overlapping pair between two start-sorted interval lists
/// (classic two-pointer sweep — linear, not quadratic, in the event
/// count; conv backward logs hundreds of windows per stage).
fn intersect(a: &[Access], b: &[Access]) -> Option<(Access, Access)> {
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        if x.start < y.end && y.start < x.end {
            return Some((x, y));
        }
        if x.end <= y.end {
            i += 1;
        } else {
            j += 1;
        }
    }
    None
}

fn access_str(a: &Access) -> String {
    format!(
        "{} {}..{} in stage {}",
        if a.write { "wrote" } else { "read" },
        a.start,
        a.end,
        a.stage
    )
}

/// Validate a completed region's access log and panic with full
/// region/stage/range context on the first contract violation.  No-op
/// for `None` (unchecked region).
pub(super) fn validate(ctx: Option<RegionCtx>) {
    let Some(ctx) = ctx else { return };
    let workers = ctx.logs.len();
    // Group events by (buffer, conflict class): per stage for synced
    // regions (the barrier orders cross-stage accesses), the whole
    // region for unsynced ones (no barrier, no ordering).
    let mut groups: HashMap<(usize, usize), Vec<WorkerAccesses>> = HashMap::new();
    for (w, log) in ctx.logs.iter().enumerate() {
        for a in log.lock().unwrap().iter() {
            let class = match ctx.mode {
                RegionMode::Synced => a.stage,
                RegionMode::Unsynced => 0,
            };
            let group = groups
                .entry((a.buf, class))
                .or_insert_with(|| (0..workers).map(|_| WorkerAccesses::default()).collect());
            if a.write {
                group[w].writes.push(*a);
            } else {
                group[w].reads.push(*a);
            }
        }
    }
    for ((buf, class), group) in &mut groups {
        for wa in group.iter_mut() {
            wa.writes.sort_by_key(|a| a.start);
            wa.reads.sort_by_key(|a| a.start);
        }
        for w1 in 0..workers {
            for w2 in (w1 + 1)..workers {
                // write/write, then each direction of write/read.
                let conflict = intersect(&group[w1].writes, &group[w2].writes)
                    .or_else(|| intersect(&group[w1].writes, &group[w2].reads))
                    .or_else(|| {
                        intersect(&group[w2].writes, &group[w1].reads).map(|(b, a)| (a, b))
                    });
                if let Some((a, b)) = conflict {
                    let rule = match ctx.mode {
                        RegionMode::Synced => {
                            "synced-region contract: concurrent same-stage accesses with a \
                             write must be disjoint (cross-range reads need a stage barrier)"
                        }
                        RegionMode::Unsynced => {
                            "unsynced-region contract: a barrier-free chain must be \
                             race-free across workers (pointwise: touch only your own range)"
                        }
                    };
                    panic!(
                        "PHAST_CHECK violation in region '{}' ({}, {} stage(s), n={}): \
                         buffer {:#x} (len {}), conflict class {}: worker {} {} overlapping \
                         worker {} ({}); worker {} owns {:?}, worker {} owns {:?}; {}",
                        ctx.label,
                        ctx.mode.as_str(),
                        ctx.stages,
                        ctx.n,
                        buf,
                        a.buf_len,
                        class,
                        w1,
                        access_str(&a),
                        w2,
                        access_str(&b),
                        w1,
                        ctx.ranges.get(w1).cloned().unwrap_or(0..0),
                        w2,
                        ctx.ranges.get(w2).cloned().unwrap_or(0..0),
                        rule,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc(start: usize, end: usize, write: bool) -> Access {
        Access { buf: 0, buf_len: 100, stage: 0, start, end, write }
    }

    #[test]
    fn intersect_finds_first_overlap() {
        let a = vec![acc(0, 4, true), acc(10, 14, true)];
        let b = vec![acc(4, 10, true), acc(12, 13, true)];
        let hit = intersect(&a, &b).expect("overlap");
        assert_eq!((hit.0.start, hit.1.start), (10, 12));
        assert!(intersect(&a, &[acc(4, 10, true), acc(14, 20, true)]).is_none());
        assert!(intersect(&[], &b).is_none());
    }

    #[test]
    fn override_wins_over_env() {
        set_override(Some(true));
        assert!(enabled());
        set_override(Some(false));
        assert!(!enabled());
        set_override(None);
    }
}
