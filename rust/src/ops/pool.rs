//! Max / average pooling, Caffe semantics (ceil mode, border clip), native
//! baseline implementations.
//!
//! Max pooling records the *window phase* (i*kw + j) of the winner — the
//! same encoding as the Pallas kernel — so argmax tensors are directly
//! comparable across domains in the parity tests.
//!
//! Every output channel plane is independent, so the `*_batch` variants
//! parallelize over (sample, channel) pairs through
//! [`ops::par`](super::par): contiguous blocks of channel planes per
//! scoped worker, input shared read-only.  Per-channel ordering is
//! identical under any split, so results are bitwise independent of the
//! thread count.  Knobs: `PHAST_NUM_THREADS` + `PHAST_POOL_GRAIN`
//! (channel planes per worker).  The single-sample functions remain the
//! serial reference the property tests compare against.

use super::geometry::pool_geom;
use super::par;

/// Minimum (sample, channel) planes per worker (`PHAST_POOL_GRAIN`).
static POOL_GRAIN: par::GrainKnob = par::GrainKnob::new("PHAST_POOL_GRAIN", 4);

/// Pooling window parameters (square semantics per axis).
#[derive(Clone, Copy, Debug)]
pub struct Pool2dGeom {
    /// Window height.
    pub kh: usize,
    /// Window width.
    pub kw: usize,
    /// Vertical stride.
    pub sh: usize,
    /// Horizontal stride.
    pub sw: usize,
    /// Vertical (top/bottom) padding.
    pub ph: usize,
    /// Horizontal (left/right) padding.
    pub pw: usize,
}

/// One channel plane (H,W) -> (vals, argmax-phase) of shape (OH, OW).
#[allow(clippy::too_many_arguments)]
fn maxpool_channel(
    img: &[f32],
    h: usize,
    w: usize,
    g: Pool2dGeom,
    oh: usize,
    ow: usize,
    out: &mut [f32],
    arg: &mut [i32],
) {
    for oy in 0..oh {
        for ox in 0..ow {
            let mut best = f32::NEG_INFINITY;
            let mut phase = 0i32;
            for i in 0..g.kh {
                let iy = (oy * g.sh + i) as isize - g.ph as isize;
                if iy < 0 || iy as usize >= h {
                    continue;
                }
                for j in 0..g.kw {
                    let ix = (ox * g.sw + j) as isize - g.pw as isize;
                    if ix < 0 || ix as usize >= w {
                        continue;
                    }
                    let v = img[iy as usize * w + ix as usize];
                    if v > best {
                        best = v;
                        phase = (i * g.kw + j) as i32;
                    }
                }
            }
            out[oy * ow + ox] = best;
            arg[oy * ow + ox] = phase;
        }
    }
}

/// One sample (C,H,W) -> (vals, argmax-phase) of shape (C, OH, OW) —
/// the serial reference.
pub fn maxpool(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    g: Pool2dGeom,
    out: &mut [f32],
    arg: &mut [i32],
) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(out.len(), c * oh * ow);
    assert_eq!(arg.len(), out.len());

    for ch in 0..c {
        let img = &x[ch * h * w..(ch + 1) * h * w];
        maxpool_channel(
            img,
            h,
            w,
            g,
            oh,
            ow,
            &mut out[ch * oh * ow..(ch + 1) * oh * ow],
            &mut arg[ch * oh * ow..(ch + 1) * oh * ow],
        );
    }
}

/// Whole batch (N,C,H,W), parallel over the N*C channel planes.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_batch(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    g: Pool2dGeom,
    out: &mut [f32],
    arg: &mut [i32],
) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(x.len(), n * c * h * w);
    assert_eq!(out.len(), n * c * oh * ow);
    assert_eq!(arg.len(), out.len());
    // Degenerate batch, explicit (mirrors the GeMM engine's m/n/k == 0
    // handling): zero planes means nothing to pool.  The chunked
    // dispatch below would also no-op on the empty outputs (the item
    // length is a plane, `oh*ow`, which geometry keeps positive); the
    // early return makes the contract visible instead of implicit.
    if n * c == 0 {
        return;
    }
    let tune = par::Tuning::new(POOL_GRAIN.get());
    par::parallel_chunks2_mut(out, oh * ow, arg, oh * ow, tune, |planes, ob, ab| {
        for (bi, plane) in planes.enumerate() {
            maxpool_channel(
                &x[plane * h * w..(plane + 1) * h * w],
                h,
                w,
                g,
                oh,
                ow,
                &mut ob[bi * oh * ow..(bi + 1) * oh * ow],
                &mut ab[bi * oh * ow..(bi + 1) * oh * ow],
            );
        }
    });
}

/// Scatter one channel plane's pooled gradients through its phases.
/// Zeroes the `dx` plane first.
#[allow(clippy::too_many_arguments)]
fn maxpool_bwd_channel(
    dy: &[f32],
    arg: &[i32],
    h: usize,
    w: usize,
    g: Pool2dGeom,
    oh: usize,
    ow: usize,
    dx: &mut [f32],
) {
    dx.iter_mut().for_each(|v| *v = 0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let idx = oy * ow + ox;
            let phase = arg[idx] as usize;
            let (i, j) = (phase / g.kw, phase % g.kw);
            let iy = (oy * g.sh + i) as isize - g.ph as isize;
            let ix = (ox * g.sw + j) as isize - g.pw as isize;
            debug_assert!(iy >= 0 && ix >= 0);
            dx[iy as usize * w + ix as usize] += dy[idx];
        }
    }
}

/// Public per-plane entry of the max-pool gradient scatter: zeroes the
/// `dx` plane, then routes `dy` through the recorded phases — for fused
/// regions that interleave the scatter of individual (sample, channel)
/// planes with a consumer's gradient work (the planner's pool→conv
/// backward node), where the batch-level dispatch above would nest.
/// Identical arithmetic to the plane loop inside
/// [`maxpool_bwd_batch`], so any partition of planes is bitwise-equal.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_bwd_plane(
    dy: &[f32],
    arg: &[i32],
    h: usize,
    w: usize,
    g: Pool2dGeom,
    oh: usize,
    ow: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), oh * ow);
    debug_assert_eq!(arg.len(), oh * ow);
    debug_assert_eq!(dx.len(), h * w);
    maxpool_bwd_channel(dy, arg, h, w, g, oh, ow, dx);
}

/// Route pooled gradients back through the recorded argmax phases —
/// the serial per-sample reference.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_bwd(
    dy: &[f32],
    arg: &[i32],
    c: usize,
    h: usize,
    w: usize,
    g: Pool2dGeom,
    dx: &mut [f32],
) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(dx.len(), c * h * w);

    for ch in 0..c {
        maxpool_bwd_channel(
            &dy[ch * oh * ow..(ch + 1) * oh * ow],
            &arg[ch * oh * ow..(ch + 1) * oh * ow],
            h,
            w,
            g,
            oh,
            ow,
            &mut dx[ch * h * w..(ch + 1) * h * w],
        );
    }
}

/// Whole-batch max-pool backward, parallel over the N*C `dx` planes.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_bwd_batch(
    dy: &[f32],
    arg: &[i32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    g: Pool2dGeom,
    dx: &mut [f32],
) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(dy.len(), n * c * oh * ow);
    assert_eq!(arg.len(), dy.len());
    assert_eq!(dx.len(), n * c * h * w);
    // Zero planes: no windows routed anything, so there is no gradient
    // to scatter (explicit; the empty dispatch would also no-op).
    if n * c == 0 {
        return;
    }
    let tune = par::Tuning::new(POOL_GRAIN.get());
    par::parallel_chunks_mut(dx, h * w, tune, |planes, db| {
        for (bi, plane) in planes.enumerate() {
            maxpool_bwd_channel(
                &dy[plane * oh * ow..(plane + 1) * oh * ow],
                &arg[plane * oh * ow..(plane + 1) * oh * ow],
                h,
                w,
                g,
                oh,
                ow,
                &mut db[bi * h * w..(bi + 1) * h * w],
            );
        }
    });
}

/// Caffe AVE-pool divisor: window area clipped to the padded canvas.
fn ave_div(oy: usize, ox: usize, h: usize, w: usize, g: Pool2dGeom) -> f32 {
    let hs = (oy * g.sh) as isize - g.ph as isize;
    let he = (hs + g.kh as isize).min((h + g.ph) as isize);
    let hs = hs.max(-(g.ph as isize));
    let ws = (ox * g.sw) as isize - g.pw as isize;
    let we = (ws + g.kw as isize).min((w + g.pw) as isize);
    let ws = ws.max(-(g.pw as isize));
    ((he - hs) * (we - ws)) as f32
}

/// Average-pool one channel plane.
fn avepool_channel(
    img: &[f32],
    h: usize,
    w: usize,
    g: Pool2dGeom,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0.0f32;
            for i in 0..g.kh {
                let iy = (oy * g.sh + i) as isize - g.ph as isize;
                if iy < 0 || iy as usize >= h {
                    continue;
                }
                for j in 0..g.kw {
                    let ix = (ox * g.sw + j) as isize - g.pw as isize;
                    if ix < 0 || ix as usize >= w {
                        continue;
                    }
                    acc += img[iy as usize * w + ix as usize];
                }
            }
            out[oy * ow + ox] = acc / ave_div(oy, ox, h, w, g);
        }
    }
}

/// Average pooling: sum of real elements / clipped window area — the
/// serial per-sample reference.
pub fn avepool(x: &[f32], c: usize, h: usize, w: usize, g: Pool2dGeom, out: &mut [f32]) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(out.len(), c * oh * ow);

    for ch in 0..c {
        avepool_channel(
            &x[ch * h * w..(ch + 1) * h * w],
            h,
            w,
            g,
            oh,
            ow,
            &mut out[ch * oh * ow..(ch + 1) * oh * ow],
        );
    }
}

/// Whole-batch average pooling, parallel over the N*C channel planes.
#[allow(clippy::too_many_arguments)]
pub fn avepool_batch(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    g: Pool2dGeom,
    out: &mut [f32],
) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(x.len(), n * c * h * w);
    assert_eq!(out.len(), n * c * oh * ow);
    // Zero planes: nothing to pool (see `maxpool_batch`).
    if n * c == 0 {
        return;
    }
    let tune = par::Tuning::new(POOL_GRAIN.get());
    par::parallel_chunks_mut(out, oh * ow, tune, |planes, ob| {
        for (bi, plane) in planes.enumerate() {
            avepool_channel(
                &x[plane * h * w..(plane + 1) * h * w],
                h,
                w,
                g,
                oh,
                ow,
                &mut ob[bi * oh * ow..(bi + 1) * oh * ow],
            );
        }
    });
}

/// Backward of [`avepool`] for one channel plane (zeroes the plane first).
fn avepool_bwd_channel(
    dy: &[f32],
    h: usize,
    w: usize,
    g: Pool2dGeom,
    oh: usize,
    ow: usize,
    dx: &mut [f32],
) {
    dx.iter_mut().for_each(|v| *v = 0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let gshare = dy[oy * ow + ox] / ave_div(oy, ox, h, w, g);
            for i in 0..g.kh {
                let iy = (oy * g.sh + i) as isize - g.ph as isize;
                if iy < 0 || iy as usize >= h {
                    continue;
                }
                for j in 0..g.kw {
                    let ix = (ox * g.sw + j) as isize - g.pw as isize;
                    if ix < 0 || ix as usize >= w {
                        continue;
                    }
                    dx[iy as usize * w + ix as usize] += gshare;
                }
            }
        }
    }
}

/// Public per-plane entry of the average-pool gradient spread (see
/// [`maxpool_bwd_plane`] for the fused-region rationale).
#[allow(clippy::too_many_arguments)]
pub fn avepool_bwd_plane(
    dy: &[f32],
    h: usize,
    w: usize,
    g: Pool2dGeom,
    oh: usize,
    ow: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), oh * ow);
    debug_assert_eq!(dx.len(), h * w);
    avepool_bwd_channel(dy, h, w, g, oh, ow, dx);
}

/// Backward of [`avepool`] — the serial per-sample reference.
pub fn avepool_bwd(dy: &[f32], c: usize, h: usize, w: usize, g: Pool2dGeom, dx: &mut [f32]) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(dx.len(), c * h * w);

    for ch in 0..c {
        avepool_bwd_channel(
            &dy[ch * oh * ow..(ch + 1) * oh * ow],
            h,
            w,
            g,
            oh,
            ow,
            &mut dx[ch * h * w..(ch + 1) * h * w],
        );
    }
}

/// Whole-batch average-pool backward, parallel over the N*C `dx` planes.
#[allow(clippy::too_many_arguments)]
pub fn avepool_bwd_batch(
    dy: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    g: Pool2dGeom,
    dx: &mut [f32],
) {
    let gh = pool_geom(h, g.kh, g.sh, g.ph);
    let gw = pool_geom(w, g.kw, g.sw, g.pw);
    let (oh, ow) = (gh.out, gw.out);
    assert_eq!(dy.len(), n * c * oh * ow);
    assert_eq!(dx.len(), n * c * h * w);
    // Zero planes: no gradient to spread (see `maxpool_bwd_batch`).
    if n * c == 0 {
        return;
    }
    let tune = par::Tuning::new(POOL_GRAIN.get());
    par::parallel_chunks_mut(dx, h * w, tune, |planes, db| {
        for (bi, plane) in planes.enumerate() {
            avepool_bwd_channel(
                &dy[plane * oh * ow..(plane + 1) * oh * ow],
                h,
                w,
                g,
                oh,
                ow,
                &mut db[bi * h * w..(bi + 1) * h * w],
            );
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{close, forall, Rng};

    fn geom(k: usize, s: usize, p: usize) -> Pool2dGeom {
        Pool2dGeom { kh: k, kw: k, sh: s, sw: s, ph: p, pw: p }
    }

    #[test]
    fn maxpool_2x2() {
        #[rustfmt::skip]
        let x = [
            1., 2., 5., 6.,
            3., 4., 7., 8.,
            0., 0., 1., 0.,
            9., 0., 0., 0.,
        ];
        let mut out = vec![0.0f32; 4];
        let mut arg = vec![0i32; 4];
        maxpool(&x, 1, 4, 4, geom(2, 2, 0), &mut out, &mut arg);
        assert_eq!(out, vec![4., 8., 9., 1.]);
        assert_eq!(arg, vec![3, 3, 2, 0]);
    }

    #[test]
    fn maxpool_bwd_routes_to_winner() {
        let x = [1., 2., 3., 4.];
        let mut out = vec![0.0f32; 1];
        let mut arg = vec![0i32; 1];
        maxpool(&x, 1, 2, 2, geom(2, 2, 0), &mut out, &mut arg);
        let mut dx = vec![0.0f32; 4];
        maxpool_bwd(&[5.0], &arg, 1, 2, 2, geom(2, 2, 0), &mut dx);
        assert_eq!(dx, vec![0., 0., 0., 5.]);
    }

    #[test]
    fn avepool_simple() {
        let x = [1., 2., 3., 4.];
        let mut out = vec![0.0f32; 1];
        avepool(&x, 1, 2, 2, geom(2, 2, 0), &mut out);
        assert_eq!(out, vec![2.5]);
    }

    #[test]
    fn avepool_gradient_conserved_when_unclipped() {
        // Without clipping (stride == kernel, no pad), sum(dx) == sum(dy).
        forall("avepool-conserve", 10, |rng: &mut Rng| {
            let c = rng.range(1, 3);
            let k = rng.range(1, 3);
            let oh = rng.range(1, 5);
            let h = oh * k;
            let dy = rng.normal_vec(c * oh * oh);
            let mut dx = vec![0.0f32; c * h * h];
            avepool_bwd(&dy, c, h, h, geom(k, k, 0), &mut dx);
            let sdx: f32 = dx.iter().sum();
            let sdy: f32 = dy.iter().sum();
            assert!(close(sdx, sdy, 1e-4, 1e-4), "{sdx} vs {sdy}");
        });
    }

    #[test]
    fn max_bwd_total_equals_dy_total() {
        forall("maxpool-conserve", 10, |rng: &mut Rng| {
            let c = rng.range(1, 3);
            let h = rng.range(4, 10);
            let k = rng.range(2, 3);
            let s = k; // non-overlapping
            let g = geom(k, s, 0);
            let gh = pool_geom(h, k, s, 0);
            let x = rng.normal_vec(c * h * h);
            let mut out = vec![0.0f32; c * gh.out * gh.out];
            let mut arg = vec![0i32; out.len()];
            maxpool(&x, c, h, h, g, &mut out, &mut arg);
            let dy = rng.normal_vec(out.len());
            let mut dx = vec![0.0f32; x.len()];
            maxpool_bwd(&dy, &arg, c, h, h, g, &mut dx);
            let sdx: f32 = dx.iter().sum();
            let sdy: f32 = dy.iter().sum();
            assert!(close(sdx, sdy, 1e-4, 1e-4));
        });
    }

    #[test]
    fn degenerate_batches_are_explicit() {
        // Zero planes (batch 0 or zero channels): every batch op must be
        // a no-op, never a panic from a zero-length dispatch item.
        let g = geom(2, 2, 0);
        let mut out: Vec<f32> = vec![];
        let mut arg: Vec<i32> = vec![];
        let mut dx: Vec<f32> = vec![];
        for (n, c) in [(0usize, 3usize), (3, 0), (0, 0)] {
            maxpool_batch(&[], n, c, 4, 4, g, &mut out, &mut arg);
            maxpool_bwd_batch(&[], &[], n, c, 4, 4, g, &mut dx);
            avepool_batch(&[], n, c, 4, 4, g, &mut out);
            avepool_bwd_batch(&[], n, c, 4, 4, g, &mut dx);
        }
        // A degenerate batch around a live one: the live samples still
        // compute (the early return is n*c == 0 only).
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut out1 = vec![0.0f32; 1];
        let mut arg1 = vec![0i32; 1];
        maxpool_batch(&x, 1, 1, 2, 2, g, &mut out1, &mut arg1);
        assert_eq!(out1, vec![4.0]);
    }

    #[test]
    fn cifar_pool_output_16() {
        let g = geom(3, 2, 0);
        let x = vec![1.0f32; 32 * 32];
        let gh = pool_geom(32, 3, 2, 0);
        assert_eq!(gh.out, 16);
        let mut out = vec![0.0f32; 16 * 16];
        let mut arg = vec![0i32; 256];
        maxpool(&x, 1, 32, 32, g, &mut out, &mut arg);
        assert!(out.iter().all(|&v| v == 1.0));
    }
}
