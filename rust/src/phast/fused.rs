//! Whole-net fused execution — the paper's predicted end state (§4.3):
//! every layer ported, "inference/back-propagation activities mainly run
//! without artificial interruption across the layers and unneeded data
//! transfers".
//!
//! One artifact per operation class: `{tag}.step` (fwd+bwd+SGD),
//! `{tag}.grads` (fwd+bwd), `{tag}.eval` (loss+accuracy+probs),
//! `{tag}.infer` (probs).  Parameters and momentum live as host tensors
//! recycled between calls; the only per-step traffic is the batch in and
//! the loss out plus the parameter round-trip of a single executable —
//! no per-layer hops.

use anyhow::{bail, Context, Result};

use crate::net::Net;
use crate::runtime::{Engine, Value};
use crate::tensor::{IntTensor, Tensor};

use super::ported::net_tag;

/// Driver for the fused artifacts of one net.
pub struct FusedRunner<'e> {
    engine: &'e Engine,
    tag: String,
    params: Vec<Tensor>,
    vels: Vec<Tensor>,
}

impl<'e> FusedRunner<'e> {
    /// Initialize from a native net's parameters (guarantees the fused and
    /// native runs start from identical weights).
    pub fn from_net(engine: &'e Engine, net: &Net) -> Result<FusedRunner<'e>> {
        let tag = net_tag(&net.config().name)?.to_string();
        let params: Vec<Tensor> = net.params().iter().map(|b| b.data().clone()).collect();
        Self::new(engine, &tag, params)
    }

    pub fn new(engine: &'e Engine, tag: &str, params: Vec<Tensor>) -> Result<FusedRunner<'e>> {
        let spec = engine.spec(&format!("{tag}.step"))?;
        let expected = (spec.ins.len() - 3) / 2;
        if params.len() != expected {
            bail!("{tag}.step expects {expected} params, got {}", params.len());
        }
        let vels = params
            .iter()
            .map(|p| Tensor::zeros(p.shape().clone()))
            .collect();
        Ok(FusedRunner { engine, tag: tag.to_string(), params, vels })
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    /// Copy current parameters back into a native net (for validation).
    pub fn sync_to_net(&self, net: &mut Net) {
        for (blob, p) in net.params_mut().into_iter().zip(&self.params) {
            blob.data_mut().as_mut_slice().copy_from_slice(p.as_slice());
        }
    }

    /// One fused training step; returns the loss.
    pub fn step(&mut self, x: Tensor, labels: IntTensor, lr: f32) -> Result<f32> {
        let mut args = Vec::with_capacity(3 + 2 * self.params.len());
        args.push(Value::F32(x));
        args.push(Value::I32(labels));
        args.push(Value::Scalar(lr));
        for p in &self.params {
            args.push(Value::F32(p.clone()));
        }
        for v in &self.vels {
            args.push(Value::F32(v.clone()));
        }
        let mut out = self.engine.run(&format!("{}.step", self.tag), &args)?;
        // outputs: loss, new params..., new velocities...
        let n = self.params.len();
        if out.len() != 1 + 2 * n {
            bail!("fused step returned {} outputs", out.len());
        }
        let vels: Vec<Tensor> = out
            .drain(1 + n..)
            .map(|v| v.into_f32())
            .collect::<Result<_>>()?;
        let params: Vec<Tensor> = out
            .drain(1..)
            .map(|v| v.into_f32())
            .collect::<Result<_>>()?;
        // Restore original (possibly 4-D) shapes.
        self.params = params
            .into_iter()
            .zip(&self.params)
            .map(|(t, old)| t.reshaped(old.shape().clone()))
            .collect();
        self.vels = vels
            .into_iter()
            .zip(&self.vels)
            .map(|(t, old)| t.reshaped(old.shape().clone()))
            .collect();
        let loss = out.pop().context("missing loss output")?.into_f32()?;
        Ok(loss.as_slice()[0])
    }

    /// Fused forward+backward without the update (Table 2's measured op).
    pub fn grads(&self, x: Tensor, labels: IntTensor) -> Result<(f32, Vec<Tensor>)> {
        let mut args = Vec::with_capacity(2 + self.params.len());
        args.push(Value::F32(x));
        args.push(Value::I32(labels));
        for p in &self.params {
            args.push(Value::F32(p.clone()));
        }
        let mut out = self.engine.run(&format!("{}.grads", self.tag), &args)?;
        let grads: Vec<Tensor> = out
            .drain(1..)
            .map(|v| v.into_f32())
            .collect::<Result<_>>()?;
        let loss = out.pop().context("missing loss")?.into_f32()?;
        Ok((loss.as_slice()[0], grads))
    }

    /// Fused evaluation: (loss, accuracy, probs).
    pub fn eval(&self, x: Tensor, labels: IntTensor) -> Result<(f32, f32, Tensor)> {
        let mut args = Vec::with_capacity(2 + self.params.len());
        args.push(Value::F32(x));
        args.push(Value::I32(labels));
        for p in &self.params {
            args.push(Value::F32(p.clone()));
        }
        let mut out = self.engine.run(&format!("{}.eval", self.tag), &args)?;
        let probs = out.pop().context("probs")?.into_f32()?;
        let acc = out.pop().context("acc")?.into_f32()?;
        let loss = out.pop().context("loss")?.into_f32()?;
        Ok((loss.as_slice()[0], acc.as_slice()[0], probs))
    }

    /// Fused inference: class probabilities.
    pub fn infer(&self, x: Tensor) -> Result<Tensor> {
        let mut args = Vec::with_capacity(1 + self.params.len());
        args.push(Value::F32(x));
        for p in &self.params {
            args.push(Value::F32(p.clone()));
        }
        let mut out = self.engine.run(&format!("{}.infer", self.tag), &args)?;
        out.pop().context("probs")?.into_f32()
    }
}
