//! The PHAST domain — the paper's contribution, reconstructed.
//!
//! A [`Placement`] assigns every layer to the **native** domain (the
//! original-Caffe baseline in `ops`/`layers`) or the **PHAST** domain (the
//! single-source AOT kernels executed through `runtime::Engine`).  The
//! [`PortedNet`] runs a net under a placement, detecting every
//! domain-boundary crossing, counting it, and optionally paying the
//! row-major <-> column-major layout conversion the paper blames for the
//! largest share of the partial-port slowdown (§4.3).
//!
//! [`FusedRunner`] is the paper's predicted end state: the entire
//! forward+backward(+SGD) step as one artifact, no intermediate crossings.

mod placement;
mod ported;
mod fused;

pub use fused::FusedRunner;
pub use placement::{Domain, Placement};
pub use ported::{BoundaryOptions, BoundaryStats, PortedNet, PortedSolver};
