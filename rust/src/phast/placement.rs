//! Per-layer domain assignment.

use std::collections::HashMap;

use crate::proto::{LayerType, NetConfig};

/// Which implementation executes a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Original-Caffe baseline (rust `ops`/`layers`).
    Native,
    /// Single-source AOT kernels via PJRT.
    Phast,
}

/// Layer-name -> domain map with a default.
#[derive(Clone, Debug)]
pub struct Placement {
    map: HashMap<String, Domain>,
    default: Domain,
}

impl Placement {
    /// Everything native — original Caffe (Table 2's `Caffe` rows).
    pub fn native_all() -> Placement {
        Placement { map: HashMap::new(), default: Domain::Native }
    }

    /// Everything ported (layer-by-layer artifacts; the crossings vanish
    /// *semantically* but each layer still dispatches one executable).
    pub fn phast_all() -> Placement {
        Placement { map: HashMap::new(), default: Domain::Phast }
    }

    /// The paper's snapshot (§3, §4.3): Convolution, Pooling and
    /// InnerProduct ported ("the heaviest layers ... have been already
    /// ported"); ReLU, SoftMax(/Loss), Accuracy and the data path still
    /// original.  This is the configuration behind Table 2's
    /// `Caffe (PHAST)` rows.
    pub fn paper_partial(cfg: &NetConfig) -> Placement {
        let mut map = HashMap::new();
        for l in &cfg.layers {
            let d = match l.ltype {
                LayerType::Convolution | LayerType::Pooling | LayerType::InnerProduct => {
                    Domain::Phast
                }
                _ => Domain::Native,
            };
            map.insert(l.name.clone(), d);
        }
        Placement { map, default: Domain::Native }
    }

    /// Port exactly the named layers.
    pub fn ported_set(names: &[&str]) -> Placement {
        let mut map = HashMap::new();
        for n in names {
            map.insert((*n).to_string(), Domain::Phast);
        }
        Placement { map, default: Domain::Native }
    }

    pub fn set(&mut self, layer: &str, d: Domain) {
        self.map.insert(layer.to_string(), d);
    }

    /// Domain of `layer`.  Data layers always run natively (the framework's
    /// ingest path was not ported in the paper either).
    pub fn domain(&self, layer: &str, ltype: LayerType) -> Domain {
        if ltype == LayerType::Data {
            return Domain::Native;
        }
        *self.map.get(layer).unwrap_or(&self.default)
    }

    /// Number of explicitly ported layers (for reports).
    pub fn ported_count(&self, cfg: &NetConfig) -> usize {
        cfg.layers
            .iter()
            .filter(|l| self.domain(&l.name, l.ltype) == Domain::Phast)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{presets, NetConfig};

    #[test]
    fn paper_partial_ports_heavy_layers() {
        let cfg = NetConfig::from_text(presets::LENET_MNIST).unwrap();
        let p = Placement::paper_partial(&cfg);
        assert_eq!(p.domain("conv1", LayerType::Convolution), Domain::Phast);
        assert_eq!(p.domain("pool2", LayerType::Pooling), Domain::Phast);
        assert_eq!(p.domain("ip1", LayerType::InnerProduct), Domain::Phast);
        assert_eq!(p.domain("relu1", LayerType::ReLU), Domain::Native);
        assert_eq!(p.domain("loss", LayerType::SoftMaxWithLoss), Domain::Native);
        assert_eq!(p.ported_count(&cfg), 6); // 2 conv + 2 pool + 2 ip
    }

    #[test]
    fn data_layer_always_native() {
        let p = Placement::phast_all();
        assert_eq!(p.domain("data", LayerType::Data), Domain::Native);
        assert_eq!(p.domain("conv1", LayerType::Convolution), Domain::Phast);
    }

    #[test]
    fn cifar_partial_count() {
        let cfg = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        let p = Placement::paper_partial(&cfg);
        assert_eq!(p.ported_count(&cfg), 8); // 3 conv + 3 pool + 2 ip
    }
}
