//! Mixed-domain net execution — the paper's partially-ported Caffe.
//!
//! Each layer runs in its placed domain; every time a blob produced in one
//! domain is consumed in the other, a **boundary crossing** is recorded and
//! (optionally) the row-major <-> column-major **layout conversion** is
//! physically paid (the paper: "they require also an additional copy
//! host-side per transfer as to transpose the memory layout", §4.3).
//!
//! Note the asymmetry this module deliberately preserves: the *native*
//! GeMM engine (`ops::gemm`, incl. `gemm_colmajor_b`) packs transposed
//! operands straight from their strided layout and caches constant
//! weight packs across calls, so it pays **no** per-call transpose — the
//! relayout cost measured here exists only at the ported-domain boundary,
//! which is exactly the paper's §4.3 claim being reproduced.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::Net;
use crate::ops::gemm::transpose;
use crate::proto::{LayerType, PoolMethod, SolverConfig};
use crate::runtime::{Engine, Value};
use crate::solver::apply_sgd_update;
use crate::tensor::{IntTensor, Shape, Tensor};

use super::placement::{Domain, Placement};

/// Boundary behaviour knobs (the §4.3 ablation axes).
#[derive(Clone, Copy, Debug)]
pub struct BoundaryOptions {
    /// Pay the host-side relayout copy at each crossing.
    pub layout_conversion: bool,
}

impl Default for BoundaryOptions {
    fn default() -> Self {
        BoundaryOptions { layout_conversion: true }
    }
}

/// Accumulated boundary accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct BoundaryStats {
    /// Semantic domain-boundary crossings (the paper's "unnecessary
    /// transfers"): blobs produced in one domain, consumed in the other.
    pub crossings: u64,
    pub crossings_fwd: u64,
    pub crossings_bwd: u64,
    /// Bytes relayouted at crossings.
    pub conversion_bytes: u64,
    /// Wall time spent in the relayout copies.
    pub conversion_time: Duration,
}

/// A net executing under a [`Placement`].
pub struct PortedNet<'e> {
    pub net: Net,
    engine: &'e Engine,
    tag: String,
    placement: Placement,
    opts: BoundaryOptions,
    /// Freshest-copy domain per blob name (data and diff tracked apart).
    data_domain: HashMap<String, Domain>,
    diff_domain: HashMap<String, Domain>,
    /// Phast-side stashes.
    argmax: HashMap<String, IntTensor>,
    probs: HashMap<String, Tensor>,
    labels_cache: HashMap<String, IntTensor>,
    pub stats: BoundaryStats,
}

/// Artifact tag for a net name ("lenet-mnist" -> "mnist").
pub fn net_tag(name: &str) -> Result<&'static str> {
    match name {
        "lenet-mnist" => Ok("mnist"),
        "cifar10-quick" => Ok("cifar"),
        other => bail!("no artifact catalog for net '{other}'"),
    }
}

impl<'e> PortedNet<'e> {
    pub fn new(net: Net, engine: &'e Engine, placement: Placement,
               opts: BoundaryOptions) -> Result<PortedNet<'e>> {
        let tag = net_tag(&net.config().name)?.to_string();
        Ok(PortedNet {
            net,
            engine,
            tag,
            placement,
            opts,
            data_domain: HashMap::new(),
            diff_domain: HashMap::new(),
            argmax: HashMap::new(),
            probs: HashMap::new(),
            labels_cache: HashMap::new(),
            stats: BoundaryStats::default(),
        })
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }

    pub fn reset_stats(&mut self) {
        self.stats = BoundaryStats::default();
        self.engine.reset_stats();
    }

    /// Pay the relayout copy for one tensor (transpose out and back — the
    /// host-side format adaptation at a domain boundary).
    fn pay_conversion(&mut self, name: &str, fwd: bool) {
        self.stats.crossings += 1;
        if fwd {
            self.stats.crossings_fwd += 1;
        } else {
            self.stats.crossings_bwd += 1;
        }
        if !self.opts.layout_conversion {
            return;
        }
        let Some(blob) = self.net.blob_mut(name) else { return };
        let t = if fwd { blob.data_mut() } else { blob.diff_mut() };
        if t.len() < 2 {
            return;
        }
        let rows = t.shape().num().max(1);
        let cols = t.len() / rows;
        if rows * cols != t.len() {
            return;
        }
        let t0 = Instant::now();
        let tr = transpose(t.as_slice(), rows, cols);
        let back = transpose(&tr, cols, rows);
        t.as_mut_slice().copy_from_slice(&back);
        self.stats.conversion_bytes += (t.len() * 4) as u64;
        self.stats.conversion_time += t0.elapsed();
    }

    fn data_domain_of(&self, name: &str) -> Domain {
        *self.data_domain.get(name).unwrap_or(&Domain::Native)
    }

    fn diff_domain_of(&self, name: &str) -> Domain {
        *self.diff_domain.get(name).unwrap_or(&Domain::Native)
    }

    fn cross_data_if_needed(&mut self, name: &str, target: Domain) {
        if self.data_domain_of(name) != target {
            self.pay_conversion(name, true);
            self.data_domain.insert(name.to_string(), target);
        }
    }

    fn cross_diff_if_needed(&mut self, name: &str, target: Domain) {
        if self.diff_domain_of(name) != target {
            self.pay_conversion(name, false);
            self.diff_domain.insert(name.to_string(), target);
        }
    }

    fn blob_data(&self, name: &str) -> Result<Tensor> {
        Ok(self
            .net
            .blob(name)
            .with_context(|| format!("blob '{name}'"))?
            .data()
            .clone())
    }

    fn blob_diff(&self, name: &str) -> Result<Tensor> {
        Ok(self
            .net
            .blob(name)
            .with_context(|| format!("blob '{name}'"))?
            .diff()
            .clone())
    }

    /// i32 view of a float label blob, converted at most once per batch:
    /// the cache holds the conversion until a native layer (the data
    /// layer) rewrites the blob, so the loss and accuracy heads — and the
    /// backward pass — share one conversion instead of re-running it per
    /// consumer per iteration.
    fn labels_i32(&mut self, name: &str) -> Result<IntTensor> {
        if let Some(it) = self.labels_cache.get(name) {
            return Ok(it.clone());
        }
        let t = self.blob_data(name)?;
        let v: Vec<i32> = t.as_slice().iter().map(|&x| x as i32).collect();
        let it = IntTensor::from_vec(Shape::new(&[t.len()]), v);
        self.labels_cache.insert(name.to_string(), it.clone());
        Ok(it)
    }

    fn flat2d(&self, t: Tensor) -> Tensor {
        let s = t.shape().flatten_2d();
        t.reshaped(s)
    }

    fn param_value(&self, li: usize, pi: usize) -> Value {
        Value::F32(self.net.layer(li).params()[pi].data().clone())
    }

    /// Store artifact output into a blob's data, reshaped to the blob.
    fn store_data(&mut self, name: &str, v: Value) -> Result<()> {
        let t = v.into_f32()?;
        let blob = self.net.blob_mut(name).with_context(|| format!("blob '{name}'"))?;
        let shape = blob.shape().clone();
        *blob.data_mut() = t.reshaped(shape);
        self.data_domain.insert(name.to_string(), Domain::Phast);
        Ok(())
    }

    fn store_diff(&mut self, name: &str, v: Value) -> Result<()> {
        let t = v.into_f32()?;
        let blob = self.net.blob_mut(name).with_context(|| format!("blob '{name}'"))?;
        let shape = blob.shape().clone();
        *blob.diff_mut() = t.reshaped(shape);
        self.diff_domain.insert(name.to_string(), Domain::Phast);
        Ok(())
    }

    /// Accumulate artifact (dw, db) into a layer's parameter diffs.
    fn accumulate_param_grads(&mut self, li: usize, dw: Value, db: Value) -> Result<()> {
        let dw = dw.into_f32()?;
        let db = db.into_f32()?;
        let params = self.net.layer_mut(li).params_mut();
        for (dst, src) in params[0].diff_mut().as_mut_slice().iter_mut().zip(dw.as_slice()) {
            *dst += src;
        }
        for (dst, src) in params[1].diff_mut().as_mut_slice().iter_mut().zip(db.as_slice()) {
            *dst += src;
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Forward
    // -----------------------------------------------------------------

    fn forward_layer_phast(&mut self, li: usize) -> Result<()> {
        let cfg = self.net.layer(li).config().clone();
        for b in &cfg.bottoms {
            self.cross_data_if_needed(b, Domain::Phast);
        }
        let art = format!("{}.{}.fwd", self.tag, cfg.name);
        match cfg.ltype {
            LayerType::Convolution => {
                let x = self.blob_data(&cfg.bottoms[0])?;
                let (w, b) = (self.param_value(li, 0), self.param_value(li, 1));
                let out = self.engine.run(&art, &[Value::F32(x), w, b])?;
                self.store_data(&cfg.tops[0], out.into_iter().next().unwrap())?;
            }
            LayerType::Pooling => {
                let x = self.blob_data(&cfg.bottoms[0])?;
                let mut out = self.engine.run(&art, &[Value::F32(x)])?;
                match cfg.pool {
                    PoolMethod::Max => {
                        let arg = out.pop().unwrap().into_i32()?;
                        let y = out.pop().unwrap();
                        self.store_data(&cfg.tops[0], y)?;
                        self.argmax.insert(cfg.name.clone(), arg);
                    }
                    PoolMethod::Ave => {
                        self.store_data(&cfg.tops[0], out.pop().unwrap())?;
                    }
                }
            }
            LayerType::InnerProduct => {
                let x = self.flat2d(self.blob_data(&cfg.bottoms[0])?);
                let (w, b) = (self.param_value(li, 0), self.param_value(li, 1));
                let out = self.engine.run(&art, &[Value::F32(x), w, b])?;
                self.store_data(&cfg.tops[0], out.into_iter().next().unwrap())?;
            }
            LayerType::ReLU => {
                let x = self.blob_data(&cfg.bottoms[0])?;
                let out = self.engine.run(&art, &[Value::F32(x)])?;
                self.store_data(&cfg.tops[0], out.into_iter().next().unwrap())?;
            }
            LayerType::SoftMaxWithLoss => {
                let x = self.flat2d(self.blob_data(&cfg.bottoms[0])?);
                let labels = self.labels_i32(&cfg.bottoms[1])?;
                let mut out = self.engine.run(&art, &[Value::F32(x), Value::I32(labels)])?;
                let probs = out.pop().unwrap().into_f32()?;
                let loss = out.pop().unwrap();
                self.store_data(&cfg.tops[0], loss)?;
                self.probs.insert(cfg.name.clone(), probs);
            }
            LayerType::Accuracy => {
                let x = self.flat2d(self.blob_data(&cfg.bottoms[0])?);
                let labels = self.labels_i32(&cfg.bottoms[1])?;
                let out = self.engine.run(&art, &[Value::F32(x), Value::I32(labels)])?;
                self.store_data(&cfg.tops[0], out.into_iter().next().unwrap())?;
            }
            LayerType::SoftMax => {
                let x = self.flat2d(self.blob_data(&cfg.bottoms[0])?);
                let out = self.engine.run(&art, &[Value::F32(x)])?;
                self.store_data(&cfg.tops[0], out.into_iter().next().unwrap())?;
            }
            LayerType::Data => unreachable!("data layers are always native"),
        }
        Ok(())
    }

    /// Full forward sweep under the placement; returns loss if present.
    pub fn forward(&mut self) -> Result<Option<f32>> {
        let mut loss = None;
        for li in 0..self.net.num_layers() {
            let cfg = self.net.layer(li).config();
            let (name, ltype) = (cfg.name.clone(), cfg.ltype);
            let tops = cfg.tops.clone();
            let bottoms = cfg.bottoms.clone();
            match self.placement.domain(&name, ltype) {
                Domain::Native => {
                    for b in &bottoms {
                        self.cross_data_if_needed(b, Domain::Native);
                    }
                    self.net.forward_layer(li)?;
                    for t in &tops {
                        self.data_domain.insert(t.clone(), Domain::Native);
                        // A native layer rewrote this blob (e.g. the data
                        // layer produced a fresh batch): drop any stale
                        // i32 label conversion.
                        self.labels_cache.remove(t);
                    }
                }
                Domain::Phast => self.forward_layer_phast(li)?,
            }
            if ltype == LayerType::SoftMaxWithLoss {
                loss = Some(self.net.blob(&tops[0]).unwrap().data().as_slice()[0]);
            }
        }
        Ok(loss)
    }

    // -----------------------------------------------------------------
    // Backward
    // -----------------------------------------------------------------

    fn backward_layer_phast(&mut self, li: usize) -> Result<()> {
        let cfg = self.net.layer(li).config().clone();
        for t in &cfg.tops {
            self.cross_diff_if_needed(t, Domain::Phast);
        }
        let art = format!("{}.{}.bwd", self.tag, cfg.name);
        match cfg.ltype {
            LayerType::Convolution => {
                let x = self.blob_data(&cfg.bottoms[0])?;
                let w = self.param_value(li, 0);
                let dy = self.blob_diff(&cfg.tops[0])?;
                let mut out = self.engine.run(&art, &[Value::F32(x), w, Value::F32(dy)])?;
                let db = out.pop().unwrap();
                let dw = out.pop().unwrap();
                let dx = out.pop().unwrap();
                self.store_diff(&cfg.bottoms[0], dx)?;
                self.accumulate_param_grads(li, dw, db)?;
            }
            LayerType::InnerProduct => {
                let x = self.flat2d(self.blob_data(&cfg.bottoms[0])?);
                let w = self.param_value(li, 0);
                let dy = self.blob_diff(&cfg.tops[0])?;
                let mut out = self.engine.run(&art, &[Value::F32(x), w, Value::F32(dy)])?;
                let db = out.pop().unwrap();
                let dw = out.pop().unwrap();
                let dx = out.pop().unwrap();
                self.store_diff(&cfg.bottoms[0], dx)?;
                self.accumulate_param_grads(li, dw, db)?;
            }
            LayerType::Pooling => {
                let dy = self.blob_diff(&cfg.tops[0])?;
                let out = match cfg.pool {
                    PoolMethod::Max => {
                        let arg = self
                            .argmax
                            .get(&cfg.name)
                            .with_context(|| format!("no argmax stash for '{}'", cfg.name))?
                            .clone();
                        self.engine.run(&art, &[Value::F32(dy), Value::I32(arg)])?
                    }
                    PoolMethod::Ave => self.engine.run(&art, &[Value::F32(dy)])?,
                };
                self.store_diff(&cfg.bottoms[0], out.into_iter().next().unwrap())?;
            }
            LayerType::ReLU => {
                let x = self.blob_data(&cfg.bottoms[0])?;
                let dy = self.blob_diff(&cfg.tops[0])?;
                let out = self.engine.run(&art, &[Value::F32(x), Value::F32(dy)])?;
                self.store_diff(&cfg.bottoms[0], out.into_iter().next().unwrap())?;
            }
            LayerType::SoftMaxWithLoss => {
                let probs = self
                    .probs
                    .get(&cfg.name)
                    .with_context(|| format!("no probs stash for '{}'", cfg.name))?
                    .clone();
                let labels = self.labels_i32(&cfg.bottoms[1])?;
                let out = self
                    .engine
                    .run(&art, &[Value::F32(probs), Value::I32(labels)])?;
                self.store_diff(&cfg.bottoms[0], out.into_iter().next().unwrap())?;
            }
            LayerType::Accuracy | LayerType::SoftMax | LayerType::Data => {}
        }
        Ok(())
    }

    /// Full backward sweep under the placement.
    pub fn backward(&mut self) -> Result<()> {
        for li in (0..self.net.num_layers()).rev() {
            let cfg = self.net.layer(li).config();
            let (name, ltype) = (cfg.name.clone(), cfg.ltype);
            if ltype == LayerType::Data || ltype == LayerType::Accuracy {
                continue;
            }
            let tops = cfg.tops.clone();
            let bottoms = cfg.bottoms.clone();
            match self.placement.domain(&name, ltype) {
                Domain::Native => {
                    for t in &tops {
                        self.cross_diff_if_needed(t, Domain::Native);
                    }
                    self.net.backward_layer(li)?;
                    for b in &bottoms {
                        self.diff_domain.insert(b.clone(), Domain::Native);
                    }
                }
                Domain::Phast => self.backward_layer_phast(li)?,
            }
        }
        Ok(())
    }

    /// Forward + backward — the quantity Table 2 measures.
    pub fn forward_backward(&mut self) -> Result<f32> {
        self.net.zero_param_diffs();
        let loss = self.forward()?.unwrap_or(0.0);
        self.backward()?;
        Ok(loss)
    }
}

/// SGD solver over a [`PortedNet`] (same math as `solver::Solver`).
pub struct PortedSolver<'e> {
    pub config: SolverConfig,
    pub pnet: PortedNet<'e>,
    history: Vec<Vec<f32>>,
    iter: usize,
}

impl<'e> PortedSolver<'e> {
    pub fn new(config: SolverConfig, mut pnet: PortedNet<'e>) -> PortedSolver<'e> {
        let history = pnet
            .net
            .params_mut()
            .iter()
            .map(|p| vec![0.0f32; p.count()])
            .collect();
        PortedSolver { config, pnet, history, iter: 0 }
    }

    pub fn iter(&self) -> usize {
        self.iter
    }

    pub fn lr(&self) -> f32 {
        self.config.lr_policy.lr_at(self.config.base_lr, self.iter)
    }

    pub fn step(&mut self) -> Result<f32> {
        let loss = self.pnet.forward_backward()?;
        let lr = self.lr();
        apply_sgd_update(
            self.pnet.net.params_mut(),
            &mut self.history,
            lr,
            self.config.momentum,
            self.config.weight_decay,
        );
        self.iter += 1;
        Ok(loss)
    }
}
