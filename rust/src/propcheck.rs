//! Minimal property-testing toolkit (proptest is not available offline).
//!
//! A deterministic xorshift64* PRNG plus a `forall` driver: every property
//! runs over `cases` seeded inputs; on failure the seed is printed so the
//! case can be replayed exactly.  Shrinking is intentionally omitted — the
//! generated cases are small enough to eyeball.

/// xorshift64* — tiny, fast, good enough statistical quality for tests and
/// synthetic data generation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_mul(2685821657736338717).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vec of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }
}

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case + 1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("propcheck '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Relative-tolerance float comparison for test assertions.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Assert two slices are elementwise close; reports the first offender.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            close(*x, *y, rtol, atol),
            "mismatch at {i}: {x} vs {y} (rtol={rtol}, atol={atol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            let r = rng.range(3, 9);
            assert!((3..=9).contains(&r));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut rng = Rng::new(11);
        let xs = rng.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall("count", 17, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
