//! Typed views over the parsed prototxt: net and solver configurations.

use anyhow::{bail, Context, Result};

use super::parse::{parse, Message, Value};

/// Layer kinds of the ported subset (paper §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerType {
    Data,
    Convolution,
    Pooling,
    InnerProduct,
    ReLU,
    SoftMax,
    SoftMaxWithLoss,
    Accuracy,
}

impl LayerType {
    pub fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "Data" => LayerType::Data,
            "Convolution" => LayerType::Convolution,
            "Pooling" => LayerType::Pooling,
            "InnerProduct" => LayerType::InnerProduct,
            "ReLU" => LayerType::ReLU,
            "Softmax" | "SoftMax" => LayerType::SoftMax,
            "SoftmaxWithLoss" | "SoftMaxWithLoss" => LayerType::SoftMaxWithLoss,
            "Accuracy" => LayerType::Accuracy,
            other => bail!("unsupported layer type '{other}' (not in the ported subset)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LayerType::Data => "Data",
            LayerType::Convolution => "Convolution",
            LayerType::Pooling => "Pooling",
            LayerType::InnerProduct => "InnerProduct",
            LayerType::ReLU => "ReLU",
            LayerType::SoftMax => "Softmax",
            LayerType::SoftMaxWithLoss => "SoftmaxWithLoss",
            LayerType::Accuracy => "Accuracy",
        }
    }
}

/// Pooling reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolMethod {
    Max,
    Ave,
}

/// One layer block from the net prototxt.
#[derive(Clone, Debug)]
pub struct LayerConfig {
    pub name: String,
    pub ltype: LayerType,
    pub bottoms: Vec<String>,
    pub tops: Vec<String>,
    /// Convolution/InnerProduct.
    pub num_output: usize,
    pub kernel_size: usize,
    pub stride: usize,
    pub pad: usize,
    /// Unported Caffe conv features, kept so the conformance suite can ask
    /// for them and be refused (Table 1).
    pub dilation: usize,
    pub group: usize,
    /// Pooling.
    pub pool: PoolMethod,
    /// ReLU.
    pub negative_slope: f32,
    /// Accuracy.
    pub top_k: usize,
    /// Data.
    pub batch_size: usize,
    pub source: String,
    /// Data-parallel shard `(rank, ranks)`: the layer draws the full
    /// `batch_size` index stream (global cursor semantics — snapshots
    /// stay interchangeable with single-process runs) but materializes
    /// only its own contiguous slice of each batch, per the
    /// `ops::par::partition` rules.  `None` (the prototxt default —
    /// there is no text syntax for it) means the whole batch;
    /// `Some((0, 1))` is byte-identical to `None`.  Set by
    /// `Net::from_config_sharded`.
    pub shard: Option<(usize, usize)>,
}

impl Default for LayerConfig {
    fn default() -> Self {
        LayerConfig {
            name: String::new(),
            ltype: LayerType::Data,
            bottoms: vec![],
            tops: vec![],
            num_output: 0,
            kernel_size: 1,
            stride: 1,
            pad: 0,
            dilation: 1,
            group: 1,
            pool: PoolMethod::Max,
            negative_slope: 0.0,
            top_k: 1,
            batch_size: 64,
            source: String::new(),
            shard: None,
        }
    }
}

impl LayerConfig {
    fn from_msg(m: &Message) -> Result<Self> {
        let mut lc = LayerConfig {
            name: m.str_field("name").context("layer missing name")?.to_string(),
            ltype: LayerType::from_str(m.str_field("type").context("layer missing type")?)?,
            bottoms: m.get_all("bottom").filter_map(Value::as_str).map(String::from).collect(),
            tops: m.get_all("top").filter_map(Value::as_str).map(String::from).collect(),
            ..Default::default()
        };
        // Caffe nests these in *_param blocks; accept both nested and flat.
        let sub = ["convolution_param", "pooling_param", "inner_product_param",
                   "relu_param", "accuracy_param", "data_param"]
            .iter()
            .filter_map(|k| m.get(k).and_then(Value::as_msg))
            .collect::<Vec<_>>();
        let lookup_num = |key: &str| -> Option<f64> {
            m.num_field(key).or_else(|| sub.iter().find_map(|s| s.num_field(key)))
        };
        let lookup_str = |key: &str| -> Option<&str> {
            m.str_field(key).or_else(|| sub.iter().find_map(|s| s.str_field(key)))
        };
        if let Some(v) = lookup_num("num_output") {
            lc.num_output = v as usize;
        }
        if let Some(v) = lookup_num("kernel_size") {
            lc.kernel_size = v as usize;
        }
        if let Some(v) = lookup_num("stride") {
            lc.stride = v as usize;
        }
        if let Some(v) = lookup_num("pad") {
            lc.pad = v as usize;
        }
        if let Some(v) = lookup_num("dilation") {
            lc.dilation = v as usize;
        }
        if let Some(v) = lookup_num("group") {
            lc.group = v as usize;
        }
        if let Some(v) = lookup_num("negative_slope") {
            lc.negative_slope = v as f32;
        }
        if let Some(v) = lookup_num("top_k") {
            lc.top_k = v as usize;
        }
        if let Some(v) = lookup_num("batch_size") {
            lc.batch_size = v as usize;
        }
        if let Some(s) = lookup_str("pool") {
            lc.pool = match s {
                "MAX" | "max" => PoolMethod::Max,
                "AVE" | "ave" => PoolMethod::Ave,
                other => bail!("unsupported pool method '{other}'"),
            };
        }
        if let Some(s) = lookup_str("source") {
            lc.source = s.to_string();
        }
        Ok(lc)
    }
}

/// Whole-net configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub name: String,
    pub layers: Vec<LayerConfig>,
}

impl NetConfig {
    pub fn from_text(src: &str) -> Result<Self> {
        let m = parse(src)?;
        let name = m.str_field("name").unwrap_or("net").to_string();
        let mut layers = Vec::new();
        for v in m.get_all("layer") {
            let lm = v.as_msg().context("layer must be a block")?;
            layers.push(LayerConfig::from_msg(lm)?);
        }
        if layers.is_empty() {
            bail!("net '{name}' has no layers");
        }
        Ok(NetConfig { name, layers })
    }

    pub fn layer(&self, name: &str) -> Option<&LayerConfig> {
        self.layers.iter().find(|l| l.name == name)
    }
}

/// Learning-rate schedule (Caffe `lr_policy`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrPolicy {
    Fixed,
    /// base_lr * gamma^(iter / step)
    Step { gamma: f32, step: usize },
    /// base_lr * (1 + gamma * iter)^(-power)  — LeNet's schedule.
    Inv { gamma: f32, power: f32 },
}

impl LrPolicy {
    pub fn lr_at(&self, base_lr: f32, iter: usize) -> f32 {
        match self {
            LrPolicy::Fixed => base_lr,
            LrPolicy::Step { gamma, step } => {
                base_lr * gamma.powi((iter / step.max(&1)) as i32)
            }
            LrPolicy::Inv { gamma, power } => {
                base_lr * (1.0 + gamma * iter as f32).powf(-power)
            }
        }
    }
}

/// Solver configuration (Caffe SGDSolver subset).
#[derive(Clone, Debug)]
pub struct SolverConfig {
    pub net: String,
    pub base_lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub lr_policy: LrPolicy,
    pub max_iter: usize,
    pub test_interval: usize,
    pub test_iter: usize,
    pub display: usize,
    pub snapshot: usize,
    pub snapshot_prefix: String,
    pub seed: u64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            net: String::new(),
            base_lr: 0.01,
            momentum: 0.9,
            weight_decay: 0.0005,
            lr_policy: LrPolicy::Fixed,
            max_iter: 100,
            test_interval: 0,
            test_iter: 10,
            display: 20,
            snapshot: 0,
            snapshot_prefix: "snapshot".into(),
            seed: 1,
        }
    }
}

impl SolverConfig {
    pub fn from_text(src: &str) -> Result<Self> {
        let m = parse(src)?;
        let mut sc = SolverConfig {
            net: m.str_field("net").unwrap_or_default().to_string(),
            ..Default::default()
        };
        if let Some(v) = m.num_field("base_lr") {
            sc.base_lr = v as f32;
        }
        if let Some(v) = m.num_field("momentum") {
            sc.momentum = v as f32;
        }
        if let Some(v) = m.num_field("weight_decay") {
            sc.weight_decay = v as f32;
        }
        if let Some(v) = m.usize_field("max_iter") {
            sc.max_iter = v;
        }
        if let Some(v) = m.usize_field("test_interval") {
            sc.test_interval = v;
        }
        if let Some(v) = m.usize_field("test_iter") {
            sc.test_iter = v;
        }
        if let Some(v) = m.usize_field("display") {
            sc.display = v;
        }
        if let Some(v) = m.usize_field("snapshot") {
            sc.snapshot = v;
        }
        if let Some(s) = m.str_field("snapshot_prefix") {
            sc.snapshot_prefix = s.to_string();
        }
        if let Some(v) = m.num_field("random_seed") {
            sc.seed = v as u64;
        }
        let gamma = m.num_field("gamma").unwrap_or(0.0) as f32;
        let power = m.num_field("power").unwrap_or(1.0) as f32;
        let step = m.usize_field("stepsize").unwrap_or(1);
        sc.lr_policy = match m.str_field("lr_policy").unwrap_or("fixed") {
            "fixed" => LrPolicy::Fixed,
            "step" => LrPolicy::Step { gamma, step },
            "inv" => LrPolicy::Inv { gamma, power },
            other => bail!("unsupported lr_policy '{other}'"),
        };
        Ok(sc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::presets;

    #[test]
    fn parses_lenet_preset() {
        let net = NetConfig::from_text(presets::LENET_MNIST).unwrap();
        assert_eq!(net.name, "lenet-mnist");
        assert_eq!(net.layers.len(), 10); // data..accuracy
        let conv1 = net.layer("conv1").unwrap();
        assert_eq!(conv1.ltype, LayerType::Convolution);
        assert_eq!(conv1.num_output, 20);
        assert_eq!(conv1.kernel_size, 5);
        let pool2 = net.layer("pool2").unwrap();
        assert_eq!(pool2.pool, PoolMethod::Max);
    }

    #[test]
    fn parses_cifar_preset() {
        let net = NetConfig::from_text(presets::CIFAR10_QUICK).unwrap();
        assert_eq!(net.name, "cifar10-quick");
        let convs = net.layers.iter()
            .filter(|l| l.ltype == LayerType::Convolution).count();
        let pools = net.layers.iter()
            .filter(|l| l.ltype == LayerType::Pooling).count();
        let ips = net.layers.iter()
            .filter(|l| l.ltype == LayerType::InnerProduct).count();
        // paper: "8 layers (3 Convolutions, 3 Poolings, and 2 InnerProducts)"
        assert_eq!((convs, pools, ips), (3, 3, 2));
        assert_eq!(net.layer("pool2").unwrap().pool, PoolMethod::Ave);
    }

    #[test]
    fn solver_config_lenet() {
        let sc = SolverConfig::from_text(presets::LENET_SOLVER).unwrap();
        assert_eq!(sc.base_lr, 0.01);
        assert_eq!(sc.momentum, 0.9);
        assert!(matches!(sc.lr_policy, LrPolicy::Inv { .. }));
        // Caffe's inv policy decays monotonically
        let l0 = sc.lr_policy.lr_at(sc.base_lr, 0);
        let l100 = sc.lr_policy.lr_at(sc.base_lr, 100);
        assert!(l100 < l0);
        assert_eq!(l0, 0.01);
    }

    #[test]
    fn lr_policies() {
        assert_eq!(LrPolicy::Fixed.lr_at(0.1, 500), 0.1);
        let step = LrPolicy::Step { gamma: 0.5, step: 10 };
        assert_eq!(step.lr_at(1.0, 0), 1.0);
        assert_eq!(step.lr_at(1.0, 10), 0.5);
        assert_eq!(step.lr_at(1.0, 25), 0.25);
    }

    #[test]
    fn rejects_unknown_layer_type() {
        let src = r#"name: "x" layer { name: "l" type: "LSTM" }"#;
        assert!(NetConfig::from_text(src).is_err());
    }
}
