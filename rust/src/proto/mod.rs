//! Prototxt-style configuration — parser + typed net/solver configs.
//!
//! Caffe describes nets and solvers in protobuf text format; this module
//! implements a compatible-enough subset (`key: value` scalars, repeated
//! keys, nested `key { ... }` blocks, `#` comments) without a protobuf
//! dependency, plus the typed views the framework consumes.

mod parse;
mod config;
pub mod presets;

pub use parse::{parse, Message, Value};
pub use config::{LayerConfig, LayerType, NetConfig, PoolMethod, SolverConfig, LrPolicy};
