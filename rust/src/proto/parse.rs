//! Minimal protobuf-text parser: scalars, repeated fields, nested messages.

use anyhow::{bail, Context, Result};

/// A parsed field value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Msg(Message),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_msg(&self) -> Option<&Message> {
        match self {
            Value::Msg(m) => Some(m),
            _ => None,
        }
    }
}

/// Ordered multi-map of fields.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Message {
    pub fields: Vec<(String, Value)>,
}

impl Message {
    /// First value for `key`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// All values for `key` (repeated fields like `top`).
    pub fn get_all<'a>(&'a self, key: &'a str) -> impl Iterator<Item = &'a Value> {
        self.fields.iter().filter(move |(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn num_field(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_num)
    }

    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.num_field(key).map(|n| n as usize)
    }
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
}

#[derive(Debug, PartialEq)]
enum Tok {
    Ident(String),
    Str(String),
    Num(f64),
    Colon,
    LBrace,
    RBrace,
    Eof,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1 }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len() {
            match self.src[self.pos] {
                b'\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                b' ' | b'\t' | b'\r' => self.pos += 1,
                b'#' => {
                    while self.pos < self.src.len() && self.src[self.pos] != b'\n' {
                        self.pos += 1;
                    }
                }
                _ => break,
            }
        }
    }

    fn next(&mut self) -> Result<Tok> {
        self.skip_ws();
        if self.pos >= self.src.len() {
            return Ok(Tok::Eof);
        }
        let c = self.src[self.pos];
        match c {
            b':' => {
                self.pos += 1;
                Ok(Tok::Colon)
            }
            b'{' => {
                self.pos += 1;
                Ok(Tok::LBrace)
            }
            b'}' => {
                self.pos += 1;
                Ok(Tok::RBrace)
            }
            b'"' => {
                self.pos += 1;
                let start = self.pos;
                while self.pos < self.src.len() && self.src[self.pos] != b'"' {
                    self.pos += 1;
                }
                if self.pos >= self.src.len() {
                    bail!("unterminated string at line {}", self.line);
                }
                let s = std::str::from_utf8(&self.src[start..self.pos])?.to_string();
                self.pos += 1;
                Ok(Tok::Str(s))
            }
            b'-' | b'+' | b'0'..=b'9' | b'.' => {
                let start = self.pos;
                self.pos += 1;
                while self.pos < self.src.len()
                    && matches!(self.src[self.pos],
                                b'0'..=b'9' | b'.' | b'e' | b'E' | b'-' | b'+')
                {
                    self.pos += 1;
                }
                let s = std::str::from_utf8(&self.src[start..self.pos])?;
                Ok(Tok::Num(s.parse().with_context(|| {
                    format!("bad number '{s}' at line {}", self.line)
                })?))
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let start = self.pos;
                while self.pos < self.src.len()
                    && (self.src[self.pos].is_ascii_alphanumeric()
                        || self.src[self.pos] == b'_')
                {
                    self.pos += 1;
                }
                Ok(Tok::Ident(
                    std::str::from_utf8(&self.src[start..self.pos])?.to_string(),
                ))
            }
            _ => bail!("unexpected character '{}' at line {}", c as char, self.line),
        }
    }
}

fn parse_message(lx: &mut Lexer<'_>, top_level: bool) -> Result<Message> {
    let mut msg = Message::default();
    loop {
        let tok = lx.next()?;
        match tok {
            Tok::Eof => {
                if !top_level {
                    bail!("unexpected EOF inside block at line {}", lx.line);
                }
                return Ok(msg);
            }
            Tok::RBrace => {
                if top_level {
                    bail!("unmatched '}}' at line {}", lx.line);
                }
                return Ok(msg);
            }
            Tok::Ident(key) => {
                let next = lx.next()?;
                match next {
                    Tok::Colon => {
                        let val = match lx.next()? {
                            Tok::Str(s) => Value::Str(s),
                            Tok::Num(n) => Value::Num(n),
                            Tok::Ident(w) if w == "true" => Value::Bool(true),
                            Tok::Ident(w) if w == "false" => Value::Bool(false),
                            // bare enum identifiers (e.g. `pool: MAX`)
                            Tok::Ident(w) => Value::Str(w),
                            other => bail!(
                                "expected value after '{key}:' at line {}, got {other:?}",
                                lx.line
                            ),
                        };
                        msg.fields.push((key, val));
                    }
                    Tok::LBrace => {
                        let inner = parse_message(lx, false)?;
                        msg.fields.push((key, Value::Msg(inner)));
                    }
                    other => bail!(
                        "expected ':' or '{{' after '{key}' at line {}, got {other:?}",
                        lx.line
                    ),
                }
            }
            other => bail!("unexpected token {other:?} at line {}", lx.line),
        }
    }
}

/// Parse protobuf-text into a [`Message`].
pub fn parse(src: &str) -> Result<Message> {
    parse_message(&mut Lexer::new(src), true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_strings() {
        let m = parse(r#"name: "lenet" base_lr: 0.01 max_iter: 300 debug: true"#).unwrap();
        assert_eq!(m.str_field("name"), Some("lenet"));
        assert_eq!(m.num_field("base_lr"), Some(0.01));
        assert_eq!(m.usize_field("max_iter"), Some(300));
        assert_eq!(m.get("debug"), Some(&Value::Bool(true)));
    }

    #[test]
    fn nested_and_repeated() {
        let m = parse(
            r#"
            layer { name: "a" top: "x" top: "y" }
            layer { name: "b" bottom: "x" }
            "#,
        )
        .unwrap();
        let layers: Vec<_> = m.get_all("layer").collect();
        assert_eq!(layers.len(), 2);
        let a = layers[0].as_msg().unwrap();
        let tops: Vec<_> = a.get_all("top").filter_map(Value::as_str).collect();
        assert_eq!(tops, vec!["x", "y"]);
    }

    #[test]
    fn comments_ignored() {
        let m = parse("# header\nname: \"x\" # trailing\n").unwrap();
        assert_eq!(m.str_field("name"), Some("x"));
    }

    #[test]
    fn bare_enum_identifier() {
        let m = parse("pool: MAX").unwrap();
        assert_eq!(m.str_field("pool"), Some("MAX"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("layer {").is_err());
        assert!(parse("}").is_err());
        assert!(parse("x: @").is_err());
    }
}
