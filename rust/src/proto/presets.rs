//! Built-in net/solver definitions — the two networks of the paper's
//! evaluation (§4.2), transcribed from Caffe's examples/mnist and
//! examples/cifar10 prototxts with the layer counts the paper quotes:
//! LeNet-MNIST = 2 conv + 2 pool + 2 ip; CIFAR10-quick = 3 conv + 3 pool +
//! 2 ip, plus SoftmaxWithLoss, Accuracy and ReLU in both.

/// LeNet for (synthetic) MNIST, Caffe examples/mnist/lenet_train_test.
pub const LENET_MNIST: &str = r#"
name: "lenet-mnist"
layer {
  name: "data"
  type: "Data"
  top: "data"
  top: "label"
  data_param { source: "synthetic-mnist" batch_size: 64 }
}
layer {
  name: "conv1"
  type: "Convolution"
  bottom: "data"
  top: "conv1"
  convolution_param { num_output: 20 kernel_size: 5 stride: 1 }
}
layer {
  name: "pool1"
  type: "Pooling"
  bottom: "conv1"
  top: "pool1"
  pooling_param { pool: MAX kernel_size: 2 stride: 2 }
}
layer {
  name: "conv2"
  type: "Convolution"
  bottom: "pool1"
  top: "conv2"
  convolution_param { num_output: 50 kernel_size: 5 stride: 1 }
}
layer {
  name: "pool2"
  type: "Pooling"
  bottom: "conv2"
  top: "pool2"
  pooling_param { pool: MAX kernel_size: 2 stride: 2 }
}
layer {
  name: "ip1"
  type: "InnerProduct"
  bottom: "pool2"
  top: "ip1"
  inner_product_param { num_output: 500 }
}
layer {
  name: "relu1"
  type: "ReLU"
  bottom: "ip1"
  top: "relu1"
}
layer {
  name: "ip2"
  type: "InnerProduct"
  bottom: "relu1"
  top: "ip2"
  inner_product_param { num_output: 10 }
}
layer {
  name: "loss"
  type: "SoftmaxWithLoss"
  bottom: "ip2"
  bottom: "label"
  top: "loss"
}
layer {
  name: "accuracy"
  type: "Accuracy"
  bottom: "ip2"
  bottom: "label"
  top: "accuracy"
}
"#;

/// cifar10_quick, Caffe examples/cifar10.
pub const CIFAR10_QUICK: &str = r#"
name: "cifar10-quick"
layer {
  name: "data"
  type: "Data"
  top: "data"
  top: "label"
  data_param { source: "synthetic-cifar10" batch_size: 64 }
}
layer {
  name: "conv1"
  type: "Convolution"
  bottom: "data"
  top: "conv1"
  convolution_param { num_output: 32 kernel_size: 5 stride: 1 pad: 2 }
}
layer {
  name: "pool1"
  type: "Pooling"
  bottom: "conv1"
  top: "pool1"
  pooling_param { pool: MAX kernel_size: 3 stride: 2 }
}
layer {
  name: "relu1"
  type: "ReLU"
  bottom: "pool1"
  top: "relu1"
}
layer {
  name: "conv2"
  type: "Convolution"
  bottom: "relu1"
  top: "conv2"
  convolution_param { num_output: 32 kernel_size: 5 stride: 1 pad: 2 }
}
layer {
  name: "relu2"
  type: "ReLU"
  bottom: "conv2"
  top: "relu2"
}
layer {
  name: "pool2"
  type: "Pooling"
  bottom: "relu2"
  top: "pool2"
  pooling_param { pool: AVE kernel_size: 3 stride: 2 }
}
layer {
  name: "conv3"
  type: "Convolution"
  bottom: "pool2"
  top: "conv3"
  convolution_param { num_output: 64 kernel_size: 5 stride: 1 pad: 2 }
}
layer {
  name: "relu3"
  type: "ReLU"
  bottom: "conv3"
  top: "relu3"
}
layer {
  name: "pool3"
  type: "Pooling"
  bottom: "relu3"
  top: "pool3"
  pooling_param { pool: AVE kernel_size: 3 stride: 2 }
}
layer {
  name: "ip1"
  type: "InnerProduct"
  bottom: "pool3"
  top: "ip1"
  inner_product_param { num_output: 64 }
}
layer {
  name: "ip2"
  type: "InnerProduct"
  bottom: "ip1"
  top: "ip2"
  inner_product_param { num_output: 10 }
}
layer {
  name: "loss"
  type: "SoftmaxWithLoss"
  bottom: "ip2"
  bottom: "label"
  top: "loss"
}
layer {
  name: "accuracy"
  type: "Accuracy"
  bottom: "ip2"
  bottom: "label"
  top: "accuracy"
}
"#;

/// LeNet solver (Caffe examples/mnist/lenet_solver.prototxt, shortened run).
pub const LENET_SOLVER: &str = r#"
net: "lenet-mnist"
base_lr: 0.01
momentum: 0.9
weight_decay: 0.0005
lr_policy: "inv"
gamma: 0.0001
power: 0.75
display: 50
max_iter: 300
test_interval: 100
test_iter: 4
snapshot: 0
snapshot_prefix: "snapshots/lenet"
random_seed: 1
"#;

/// cifar10_quick solver (fixed lr phase 1).
pub const CIFAR_SOLVER: &str = r#"
net: "cifar10-quick"
base_lr: 0.001
momentum: 0.9
weight_decay: 0.004
lr_policy: "fixed"
display: 50
max_iter: 200
test_interval: 100
test_iter: 4
snapshot: 0
snapshot_prefix: "snapshots/cifar"
random_seed: 1
"#;

/// Look up a preset net by name.
pub fn net_by_name(name: &str) -> Option<&'static str> {
    match name {
        "lenet-mnist" | "mnist" => Some(LENET_MNIST),
        "cifar10-quick" | "cifar" => Some(CIFAR10_QUICK),
        _ => None,
    }
}

/// Look up a preset solver by net name.
pub fn solver_by_name(name: &str) -> Option<&'static str> {
    match name {
        "lenet-mnist" | "mnist" => Some(LENET_SOLVER),
        "cifar10-quick" | "cifar" => Some(CIFAR_SOLVER),
        _ => None,
    }
}
