//! The dist coordinator: spawns one worker process per rank, collects
//! per-rank gradients each iteration, reduces them in **fixed rank
//! order** (bitwise-reproducible for a given rank count), broadcasts
//! the reduced gradient, and supervises membership.
//!
//! # Elasticity
//!
//! Worker loss is detected three ways: the per-child reader thread
//! hits EOF on the worker's stdout, a write to the worker's stdin
//! breaks, or the heartbeat timer fires and `try_wait` reaps the
//! child.  Recovery is **rollback-all**: survivors are told to reload
//! the newest valid snapshot from the shared checkpoint directory, the
//! lost rank is respawned (resuming from that same snapshot), and
//! training re-runs from the snapshot's iteration.  Because every rank
//! applies identical reduced gradients from identical state, the
//! re-run is bitwise-equal to an undisturbed run — rolling back is
//! re-execution, not approximation.  Each recovery consumes one unit
//! of `recover_budget`; exhausting it aborts loudly with full context
//! rather than looping forever against a persistent failure.
//!
//! Respawned workers never inherit `PHAST_FAULT` — an injected
//! `worker_exit@iter=N` would otherwise re-fire on every replay of
//! iteration `N` and recovery could never converge.

use std::collections::{HashMap, HashSet};
use std::io::{BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::wire::{self, FrameIn, Msg};
use super::{env_var, ENV_ROLE};

/// Coordinator-side configuration for one dist training run.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Binary to re-exec for workers (usually `std::env::current_exe()`).
    pub worker_exe: PathBuf,
    /// Arguments passed to the worker binary (the role is selected via
    /// `PHAST_DIST_ROLE`, so this is usually empty).
    pub worker_args: Vec<String>,
    /// Extra environment for workers (e.g. pinning `PHAST_NUM_THREADS`).
    pub worker_env: Vec<(String, String)>,
    /// Number of worker ranks.
    pub ranks: usize,
    /// Train through iteration `iters - 1`.
    pub iters: usize,
    /// Preset net name (`mnist`, `cifar`).
    pub net: String,
    pub seed: u64,
    /// Global batch override (`None` = the preset's batch size).
    pub batch: Option<usize>,
    /// Shared checkpoint directory.
    pub dir: PathBuf,
    /// Checkpoint every N iterations (plus iteration 0 and the final
    /// iteration; 0 = only those two).
    pub snapshot_every: usize,
    /// Snapshot retention (0 = keep all).
    pub keep: usize,
    /// Worker losses tolerated before aborting (`PHAST_DIST_BUDGET`).
    pub recover_budget: usize,
    /// Liveness-poll interval while waiting on worker frames
    /// (`PHAST_DIST_HEARTBEAT_MS`).
    pub heartbeat_ms: u64,
    /// Rank that receives `PHAST_FAULT` on its **initial** spawn
    /// (`PHAST_DIST_FAULT_RANK`, clamped to the rank count).  All other
    /// ranks, and every respawn, get the variable scrubbed.
    pub fault_rank: usize,
    /// The fault plan for the fault rank (defaults to the coordinator's
    /// own `PHAST_FAULT`, forwarding the CI chaos knob).
    pub fault_spec: Option<String>,
}

fn env_u64(name: &str, default: u64) -> u64 {
    env_var(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl DistConfig {
    /// Defaults plus the coordinator-side `PHAST_DIST_*` env knobs.
    pub fn new(worker_exe: impl Into<PathBuf>, dir: impl Into<PathBuf>) -> DistConfig {
        DistConfig {
            worker_exe: worker_exe.into(),
            worker_args: Vec::new(),
            worker_env: Vec::new(),
            ranks: 2,
            iters: 10,
            net: "mnist".into(),
            seed: 42,
            batch: None,
            dir: dir.into(),
            snapshot_every: 4,
            keep: 0,
            recover_budget: env_u64(super::ENV_BUDGET, 2) as usize,
            heartbeat_ms: env_u64(super::ENV_HEARTBEAT_MS, 5000),
            fault_rank: env_u64(super::ENV_FAULT_RANK, 1) as usize,
            fault_spec: env_var("PHAST_FAULT"),
        }
    }
}

/// What one coordinated run did, for logs / benches / assertions.
#[derive(Clone, Debug)]
pub struct DistSummary {
    pub ranks: usize,
    pub final_iter: u64,
    /// CRC-32 of the final parameter bytes, cross-checked identical on
    /// every rank.
    pub weights_hash: u32,
    /// Rollback-all recoveries performed (worker losses + watchdog).
    pub recoveries: usize,
    /// Nack frames the coordinator sent: CRC failures it detected plus
    /// heartbeat retransmission requests for frames that never arrived.
    pub crc_nacks: u64,
    /// Retransmissions served in response to worker Nacks (their recv
    /// side saw a corrupt or injected-dropped frame).
    pub nacks_served: u64,
    /// Iteration the run resumed from, when the checkpoint dir already
    /// held a valid snapshot (the coordinator-restart path).
    pub resumed_from: Option<u64>,
}

/// Events funneled from the per-child reader threads.  `gen` stamps
/// which incarnation of the rank produced the event, so frames from a
/// dead child can never be attributed to its replacement.
enum Event {
    Frame(usize, u64, FrameIn),
    Eof(usize, u64),
}

/// One worker process slot (index in `Coordinator::slots` == rank).
struct Slot {
    gen: u64,
    child: Child,
    stdin: BufWriter<ChildStdin>,
    /// Clean bytes of the last protocol frame sent to this rank, for
    /// Nack-triggered retransmission.
    last_sent: Vec<u8>,
    alive: bool,
}

fn spawn_reader(rank: usize, gen: u64, stdout: std::process::ChildStdout, tx: Sender<Event>) {
    // LINT-ALLOW: thread-spawn — blocking pipe reader per worker rank;
    // not region work, so it must not occupy a PHAST pool worker.
    std::thread::Builder::new()
        .name(format!("dist-read-{rank}"))
        .spawn(move || {
            let mut r = BufReader::new(stdout);
            loop {
                match wire::read_frame(&mut r) {
                    Ok(f) => {
                        if tx.send(Event::Frame(rank, gen, f)).is_err() {
                            return; // coordinator gone
                        }
                    }
                    Err(_) => {
                        // EOF or desync: either way this incarnation is
                        // unusable — report and stop reading.
                        let _ = tx.send(Event::Eof(rank, gen));
                        return;
                    }
                }
            }
        })
        .expect("spawning dist reader thread");
}

pub struct Coordinator {
    cfg: DistConfig,
    slots: Vec<Slot>,
    tx: Sender<Event>,
    rx: Receiver<Event>,
    recoveries: usize,
    crc_nacks: u64,
    nacks_served: u64,
}

/// Run one elastic data-parallel training job to completion.
pub fn train_dist(cfg: DistConfig) -> Result<DistSummary> {
    Coordinator::launch(cfg)?.run()
}

impl Coordinator {
    fn launch(cfg: DistConfig) -> Result<Coordinator> {
        if cfg.ranks == 0 {
            bail!("dist: ranks must be >= 1");
        }
        if cfg.iters == 0 {
            bail!("dist: iters must be >= 1");
        }
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating checkpoint dir {:?}", cfg.dir))?;
        let (tx, rx) = channel();
        let mut c = Coordinator {
            cfg,
            slots: Vec::new(),
            tx,
            rx,
            recoveries: 0,
            crc_nacks: 0,
            nacks_served: 0,
        };
        let fault_rank = c.cfg.fault_rank.min(c.cfg.ranks - 1);
        for rank in 0..c.cfg.ranks {
            let with_fault = rank == fault_rank && c.cfg.fault_spec.is_some();
            let slot = c.spawn_worker(rank, 0, with_fault)?;
            c.slots.push(slot);
        }
        Ok(c)
    }

    fn spawn_worker(&self, rank: usize, gen: u64, with_fault: bool) -> Result<Slot> {
        let mut cmd = Command::new(&self.cfg.worker_exe);
        cmd.args(&self.cfg.worker_args)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped()) // stderr inherited: worker logs flow through
            .env(ENV_ROLE, "worker")
            .env(super::ENV_RANK, rank.to_string())
            .env(super::ENV_RANKS, self.cfg.ranks.to_string())
            .env(super::ENV_NET, &self.cfg.net)
            .env(super::ENV_SEED, self.cfg.seed.to_string())
            .env(super::ENV_ITERS, self.cfg.iters.to_string())
            .env(super::ENV_DIR, &self.cfg.dir)
            .env(super::ENV_EVERY, self.cfg.snapshot_every.to_string())
            .env(super::ENV_KEEP, self.cfg.keep.to_string());
        if let Some(b) = self.cfg.batch {
            cmd.env(super::ENV_BATCH, b.to_string());
        }
        for (k, v) in &self.cfg.worker_env {
            cmd.env(k, v);
        }
        if crate::ops::par::check::enabled() {
            // A checked coordinator runs checked workers: the sanitizer
            // must see the whole distributed step, not just this process.
            cmd.env("PHAST_CHECK", "1");
        }
        match (&self.cfg.fault_spec, with_fault) {
            (Some(spec), true) => {
                cmd.env("PHAST_FAULT", spec);
            }
            _ => {
                // Scrub inherited chaos: only the designated rank's
                // initial incarnation runs the fault plan.
                cmd.env_remove("PHAST_FAULT");
            }
        }
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawning worker rank {rank} ({:?})", self.cfg.worker_exe))?;
        let stdout = child.stdout.take().expect("piped stdout");
        spawn_reader(rank, gen, stdout, self.tx.clone());
        let stdin = BufWriter::new(child.stdin.take().expect("piped stdin"));
        eprintln!("dist: spawned rank {rank} (gen {gen}, pid {})", child.id());
        Ok(Slot { gen, child, stdin, last_sent: Vec::new(), alive: true })
    }

    fn live_ranks(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&r| self.slots[r].alive).collect()
    }

    /// Is (rank, gen) the current live incarnation?
    fn is_current(&self, rank: usize, gen: u64) -> bool {
        self.slots[rank].alive && self.slots[rank].gen == gen
    }

    /// Mark (rank, gen) dead; `true` if this is news (a live rank's
    /// current incarnation just died).
    fn mark_dead(&mut self, rank: usize, gen: u64) -> bool {
        if self.is_current(rank, gen) {
            self.slots[rank].alive = false;
            eprintln!("dist: lost rank {rank} (gen {gen})");
            true
        } else {
            false
        }
    }

    /// Send `msg` to `rank`, remembering it for retransmission.
    /// `false` means the write broke — the rank is dead.
    fn send_to(&mut self, rank: usize, msg: &Msg) -> bool {
        let bytes = wire::encode(msg);
        self.slots[rank].last_sent.clone_from(&bytes);
        self.write_to(rank, &bytes)
    }

    /// Retransmit the last protocol frame sent to `rank` (Nack service).
    fn resend_to(&mut self, rank: usize) -> bool {
        self.nacks_served += 1;
        let bytes = std::mem::take(&mut self.slots[rank].last_sent);
        let ok = self.write_to(rank, &bytes);
        self.slots[rank].last_sent = bytes;
        ok
    }

    /// Nack `rank`: ask it to retransmit its last frame.  Raw write —
    /// Nacks never replace `last_sent`.
    fn nack(&mut self, rank: usize) -> bool {
        self.crc_nacks += 1;
        let bytes = wire::encode(&Msg::Nack);
        self.write_to(rank, &bytes)
    }

    fn write_to(&mut self, rank: usize, bytes: &[u8]) -> bool {
        let slot = &mut self.slots[rank];
        if !slot.alive {
            return false;
        }
        let ok = slot.stdin.write_all(bytes).and_then(|_| slot.stdin.flush()).is_ok();
        if !ok {
            slot.alive = false;
            eprintln!("dist: lost rank {rank} (stdin broke)");
        }
        ok
    }

    /// Wait for the next reader-thread event.  `None` = heartbeat
    /// expired with every child still running (the caller decides
    /// whether to re-Nack laggards); a child reaped by the liveness
    /// poll is synthesized into an `Eof`.
    fn next_event(&mut self) -> Result<Option<Event>> {
        match self.rx.recv_timeout(Duration::from_millis(self.cfg.heartbeat_ms)) {
            Ok(e) => Ok(Some(e)),
            Err(RecvTimeoutError::Timeout) => {
                for rank in 0..self.slots.len() {
                    let slot = &mut self.slots[rank];
                    if slot.alive {
                        if let Ok(Some(status)) = slot.child.try_wait() {
                            eprintln!("dist: heartbeat found rank {rank} exited ({status})");
                            return Ok(Some(Event::Eof(rank, slot.gen)));
                        }
                    }
                }
                Ok(None)
            }
            Err(RecvTimeoutError::Disconnected) => bail!("dist: all reader threads gone"),
        }
    }

    fn kill_all(&mut self) {
        for rank in 0..self.slots.len() {
            let slot = &mut self.slots[rank];
            let _ = slot.child.kill();
            let _ = slot.child.wait();
            slot.alive = false;
        }
    }

    fn run(&mut self) -> Result<DistSummary> {
        let abort_iter: Option<u64> = env_var(super::ENV_ABORT_ITER).and_then(|v| v.parse().ok());
        let total = self.cfg.iters as u64;

        // ---- handshake: collect Hello from every rank -------------------
        let mut hello: HashMap<usize, (u64, bool)> = HashMap::new();
        while hello.len() < self.cfg.ranks {
            match self.next_event()? {
                Some(Event::Frame(r, gen, f)) if self.is_current(r, gen) => match f {
                    FrameIn::Msg(Msg::Hello { rank, resumed_iter, resumed }) => {
                        if rank as usize != r {
                            bail!("dist: rank {r} introduced itself as {rank}");
                        }
                        hello.insert(r, (resumed_iter, resumed));
                    }
                    FrameIn::Corrupt => {
                        if !self.nack(r) {
                            bail!("dist: rank {r} died during startup");
                        }
                    }
                    FrameIn::Msg(m) => bail!("dist: unexpected {m:?} from rank {r} before Hello"),
                },
                Some(Event::Frame(..)) => {}
                Some(Event::Eof(r, gen)) => {
                    if self.mark_dead(r, gen) {
                        self.kill_all();
                        bail!(
                            "dist: rank {r} died during startup (before training began); \
                             check its stderr above"
                        );
                    }
                }
                None => {}
            }
        }
        let (start_iter, resumed) = hello[&0];
        for (&r, &(it, _)) in &hello {
            if it != start_iter {
                self.kill_all();
                bail!(
                    "dist: ranks disagree on resume point (rank 0 at {start_iter}, \
                     rank {r} at {it}); checkpoint dir {:?} is inconsistent",
                    self.cfg.dir
                );
            }
        }
        let resumed_from = if resumed { Some(start_iter) } else { None };
        if let Some(it) = resumed_from {
            eprintln!("dist: resuming all {} ranks from iter {it}", self.cfg.ranks);
        }

        // ---- Start: rank 0 owns the iteration-0 checkpoint on a fresh
        // run, giving recovery its rollback floor before any step runs.
        for r in 0..self.cfg.ranks {
            if !self.send_to(r, &Msg::Start { ckpt0: r == 0 && !resumed }) {
                self.kill_all();
                bail!("dist: rank {r} died before Start");
            }
        }

        // ---- training ---------------------------------------------------
        let mut iter = start_iter;
        let final_hash;
        'run: loop {
            while iter < total {
                let grads = match self.collect_grads(iter)? {
                    Some(g) => g,
                    None => {
                        iter = self.recover()?;
                        continue;
                    }
                };
                if abort_iter == Some(iter) {
                    eprintln!(
                        "dist: injected coordinator abort at iter {iter} ({})",
                        super::ENV_ABORT_ITER
                    );
                    std::process::exit(3);
                }

                // Deterministic reduction: ascending rank order, each
                // gradient scaled by its batch share.  At ranks=1 the
                // share is exactly 1.0f32 and the multiply is an IEEE
                // identity — single-rank dist is bitwise single-process.
                let order: Vec<usize> = {
                    let mut o: Vec<usize> = grads.keys().copied().collect();
                    o.sort_unstable();
                    o
                };
                let n = grads[&order[0]].grad.len();
                for &r in &order {
                    if grads[&r].grad.len() != n {
                        self.kill_all();
                        bail!(
                            "dist: rank {r} sent {} gradient elements, rank {} sent {n}",
                            grads[&r].grad.len(),
                            order[0]
                        );
                    }
                }
                let mut reduced = vec![0.0f32; n];
                let mut loss = 0.0f32;
                for (k, &r) in order.iter().enumerate() {
                    let g = &grads[&r];
                    if k == 0 {
                        for (out, &v) in reduced.iter_mut().zip(&g.grad) {
                            *out = v * g.weight;
                        }
                        loss = g.loss * g.weight;
                    } else {
                        for (out, &v) in reduced.iter_mut().zip(&g.grad) {
                            *out += v * g.weight;
                        }
                        loss += g.loss * g.weight;
                    }
                }

                // Divergence watchdog (mirrors TrainDriver): a NaN loss
                // is unrecoverable forward state — roll everyone back.
                if !loss.is_finite() {
                    eprintln!("dist: non-finite reduced loss at iter {iter}; rolling back");
                    iter = self.recover()?;
                    continue;
                }

                let done = iter + 1;
                let every = self.cfg.snapshot_every as u64;
                let ckpt = done == total || (every > 0 && done % every == 0);
                // Exactly one rank persists each checkpoint: the lowest
                // live rank (rank 0 unless it died this run).
                let owner = *order.first().expect("nonempty order");
                let mut lost = false;
                for &r in &order {
                    let msg = Msg::Reduced {
                        iter,
                        loss,
                        ckpt: ckpt && r == owner,
                        grad: reduced.clone(),
                    };
                    if !self.send_to(r, &msg) {
                        lost = true;
                    }
                }
                if lost {
                    iter = self.recover()?;
                    continue;
                }
                iter = done;
            }

            // ---- finalize: every rank reports its weights hash ----------
            match self.collect_done(total)? {
                Some(hashes) => {
                    let h0 = hashes[&0];
                    for (&r, &h) in &hashes {
                        if h != h0 {
                            self.kill_all();
                            bail!(
                                "dist: weights diverged — rank 0 hash {h0:#010x}, \
                                 rank {r} hash {h:#010x}"
                            );
                        }
                    }
                    final_hash = h0;
                    break 'run;
                }
                None => {
                    iter = self.recover()?;
                    continue 'run;
                }
            }
        }

        // ---- shutdown ---------------------------------------------------
        for r in self.live_ranks() {
            let _ = self.send_to(r, &Msg::Shutdown);
        }
        for slot in &mut self.slots {
            let _ = slot.child.wait();
            slot.alive = false;
        }
        Ok(DistSummary {
            ranks: self.cfg.ranks,
            final_iter: total,
            weights_hash: final_hash,
            recoveries: self.recoveries,
            crc_nacks: self.crc_nacks,
            nacks_served: self.nacks_served,
            resumed_from,
        })
    }

    /// Collect `Grad{iter}` from every live rank.  `None` = a rank died
    /// (or a write broke) and the caller must recover.
    fn collect_grads(&mut self, iter: u64) -> Result<Option<HashMap<usize, GradIn>>> {
        let mut grads: HashMap<usize, GradIn> = HashMap::new();
        loop {
            let need: Vec<usize> =
                self.live_ranks().into_iter().filter(|r| !grads.contains_key(r)).collect();
            if need.is_empty() {
                return Ok(Some(grads));
            }
            match self.next_event()? {
                Some(Event::Frame(r, gen, f)) if self.is_current(r, gen) => match f {
                    FrameIn::Corrupt => {
                        if !self.nack(r) {
                            return Ok(None);
                        }
                    }
                    FrameIn::Msg(Msg::Grad { iter: gi, weight, loss, grad }) if gi == iter => {
                        // Duplicates (heartbeat re-Nack racing a slow
                        // frame) overwrite with identical bytes.
                        grads.insert(r, GradIn { weight, loss, grad });
                    }
                    FrameIn::Msg(Msg::Grad { iter: gi, .. }) => {
                        // A stale gradient from before a rollback; the
                        // rank will re-send for the current iteration.
                        eprintln!("dist: discarding stale Grad iter {gi} from rank {r}");
                    }
                    FrameIn::Msg(Msg::CkptDone { iter: ci }) => {
                        eprintln!("dist: rank {r} checkpointed iter {ci}");
                    }
                    FrameIn::Msg(Msg::Nack) => {
                        if !self.resend_to(r) {
                            return Ok(None);
                        }
                    }
                    FrameIn::Msg(m) => {
                        self.kill_all();
                        bail!("dist: unexpected {m:?} from rank {r} while collecting iter {iter}");
                    }
                },
                Some(Event::Frame(..)) => {}
                Some(Event::Eof(r, gen)) => {
                    if self.mark_dead(r, gen) {
                        return Ok(None);
                    }
                }
                None => {
                    // Heartbeat with every child alive but frames
                    // missing: a frame may have been dropped in flight —
                    // ask the laggards to retransmit.
                    eprintln!("dist: heartbeat — re-Nacking ranks {need:?} for iter {iter}");
                    for r in need {
                        if !self.nack(r) {
                            return Ok(None);
                        }
                    }
                }
            }
        }
    }

    /// Collect `Done{total}` from every live rank.  `None` = recover.
    fn collect_done(&mut self, total: u64) -> Result<Option<HashMap<usize, u32>>> {
        let mut done: HashMap<usize, u32> = HashMap::new();
        loop {
            let need: Vec<usize> =
                self.live_ranks().into_iter().filter(|r| !done.contains_key(r)).collect();
            if need.is_empty() {
                return Ok(Some(done));
            }
            match self.next_event()? {
                Some(Event::Frame(r, gen, f)) if self.is_current(r, gen) => match f {
                    FrameIn::Corrupt => {
                        if !self.nack(r) {
                            return Ok(None);
                        }
                    }
                    FrameIn::Msg(Msg::Done { iter, weights_hash }) => {
                        if iter != total {
                            self.kill_all();
                            bail!("dist: rank {r} finished at iter {iter}, expected {total}");
                        }
                        done.insert(r, weights_hash);
                    }
                    FrameIn::Msg(Msg::CkptDone { .. }) => {}
                    FrameIn::Msg(Msg::Nack) => {
                        if !self.resend_to(r) {
                            return Ok(None);
                        }
                    }
                    FrameIn::Msg(m) => {
                        self.kill_all();
                        bail!("dist: unexpected {m:?} from rank {r} during finalize");
                    }
                },
                Some(Event::Frame(..)) => {}
                Some(Event::Eof(r, gen)) => {
                    if self.mark_dead(r, gen) {
                        return Ok(None);
                    }
                }
                None => {
                    for r in need {
                        if !self.nack(r) {
                            return Ok(None);
                        }
                    }
                }
            }
        }
    }

    /// Rollback-all recovery.  Returns the iteration training resumes
    /// from.  Consumes recovery budget; additional losses *during*
    /// recovery restart the attempt (each consuming budget), so a
    /// persistently dying rank aborts instead of looping forever.
    fn recover(&mut self) -> Result<u64> {
        'attempt: loop {
            self.recoveries += 1;
            if self.recoveries > self.cfg.recover_budget {
                let dead: Vec<usize> =
                    (0..self.slots.len()).filter(|&r| !self.slots[r].alive).collect();
                self.kill_all();
                bail!(
                    "dist: recovery budget exhausted ({} allowed): ranks {dead:?} kept \
                     dying; snapshots in {:?}; raise {} or fix the underlying crash",
                    self.cfg.recover_budget,
                    self.cfg.dir,
                    super::ENV_BUDGET
                );
            }
            eprintln!(
                "dist: recovery {}/{} — rolling back all ranks",
                self.recoveries, self.cfg.recover_budget
            );

            // Absorb anything already in flight; more deaths just mark
            // slots dead (they are respawned below either way).
            while let Ok(e) = self.rx.try_recv() {
                if let Event::Eof(r, gen) = e {
                    self.mark_dead(r, gen);
                }
            }

            // 1. Survivors reload the newest valid snapshot.
            for r in self.live_ranks() {
                if !self.send_to(r, &Msg::Rollback) {
                    continue 'attempt;
                }
            }
            let mut waiting: HashSet<usize> = self.live_ranks().into_iter().collect();
            let mut target: Option<u64> = None;
            while !waiting.is_empty() {
                match self.next_event()? {
                    Some(Event::Frame(r, gen, f)) if self.is_current(r, gen) => match f {
                        FrameIn::Corrupt => {
                            if !self.nack(r) {
                                continue 'attempt;
                            }
                        }
                        FrameIn::Msg(Msg::RolledBack { iter }) => {
                            if let Some(t) = target {
                                if t != iter {
                                    self.kill_all();
                                    bail!(
                                        "dist: ranks rolled back to different iterations \
                                         ({t} vs {iter}); checkpoint dir {:?} is inconsistent",
                                        self.cfg.dir
                                    );
                                }
                            }
                            target = Some(iter);
                            waiting.remove(&r);
                        }
                        FrameIn::Msg(Msg::Nack) => {
                            if !self.resend_to(r) {
                                continue 'attempt;
                            }
                        }
                        // Grad/CkptDone/Done frames that crossed paths
                        // with the Rollback: superseded, drop them.
                        FrameIn::Msg(_) => {}
                    },
                    Some(Event::Frame(..)) => {}
                    Some(Event::Eof(r, gen)) => {
                        if self.mark_dead(r, gen) {
                            continue 'attempt;
                        }
                    }
                    None => {}
                }
            }

            // 2. Respawn lost ranks (never with the fault plan).
            let dead: Vec<usize> =
                (0..self.slots.len()).filter(|&r| !self.slots[r].alive).collect();
            for &r in &dead {
                let _ = self.slots[r].child.kill();
                let _ = self.slots[r].child.wait(); // reap the corpse
                let gen = self.slots[r].gen + 1;
                let slot = self.spawn_worker(r, gen, false)?;
                self.slots[r] = slot;
            }

            // 3. Respawned ranks resume from the same snapshot.
            let mut waiting: HashSet<usize> = dead.iter().copied().collect();
            while !waiting.is_empty() {
                match self.next_event()? {
                    Some(Event::Frame(r, gen, f)) if self.is_current(r, gen) => match f {
                        FrameIn::Corrupt => {
                            if !self.nack(r) {
                                continue 'attempt;
                            }
                        }
                        FrameIn::Msg(Msg::Hello { resumed_iter, resumed, .. }) => {
                            if !resumed {
                                self.kill_all();
                                bail!(
                                    "dist: respawned rank {r} found no snapshot in {:?} — \
                                     cannot rejoin deterministically",
                                    self.cfg.dir
                                );
                            }
                            match target {
                                Some(t) if t != resumed_iter => {
                                    self.kill_all();
                                    bail!(
                                        "dist: respawned rank {r} resumed at {resumed_iter} \
                                         but survivors rolled back to {t}"
                                    );
                                }
                                _ => target = Some(resumed_iter),
                            }
                            waiting.remove(&r);
                        }
                        FrameIn::Msg(Msg::Nack) => {
                            if !self.resend_to(r) {
                                continue 'attempt;
                            }
                        }
                        FrameIn::Msg(_) => {}
                    },
                    Some(Event::Frame(..)) => {}
                    Some(Event::Eof(r, gen)) => {
                        if self.mark_dead(r, gen) {
                            continue 'attempt;
                        }
                    }
                    None => {}
                }
            }

            // 4. Respawned ranks get their Start (no initial checkpoint:
            // the rollback floor already exists).
            for &r in &dead {
                if !self.send_to(r, &Msg::Start { ckpt0: false }) {
                    continue 'attempt;
                }
            }

            let t = target.expect("at least one rank reported its rollback point");
            eprintln!("dist: recovered — all ranks at iter {t}, resuming");
            return Ok(t);
        }
    }
}

/// A rank's gradient contribution for one iteration.
struct GradIn {
    weight: f32,
    loss: f32,
    grad: Vec<f32>,
}

impl Drop for Coordinator {
    /// Never leave orphan workers behind, whatever path run() exits by.
    fn drop(&mut self) {
        self.kill_all();
    }
}
