//! `runtime::dist` — elastic data-parallel training on one host.
//!
//! A **coordinator** process spawns N **worker** processes (re-execs of
//! the current binary, selected by `PHAST_DIST_ROLE=worker`).  Each
//! worker trains the same net on its own deterministic contiguous shard
//! of every batch (via [`Net::from_config_sharded`](crate::net::Net) —
//! the [`ops::par::partition`](crate::ops::par::partition) split, so
//! one rank is bitwise-identical to a single-process run).  Every
//! iteration the workers' gradients are all-reduced through a
//! [`Transport`](transport::Transport) — the first backend frames
//! messages over the worker's stdin/stdout with CRC-32 detection and
//! Nack-based retransmission — in **fixed rank order**, so the reduced
//! gradient is bitwise-reproducible at a fixed rank count, and every
//! rank applies the identical SGD step.
//!
//! **Elasticity**: the coordinator heartbeats its workers; when one
//! dies (crash, `kill -9`, or `PHAST_FAULT=worker_exit@iter=N`), the
//! survivors roll back to the newest valid snapshot in the shared
//! checkpoint directory, the lost rank is respawned from it, and
//! training re-runs — final weights bitwise-equal to an undisturbed
//! run.  A bounded recovery budget turns persistent failure into a
//! loud abort instead of an infinite heal loop.  See
//! `docs/FAULT_TOLERANCE.md` for the membership protocol, the
//! determinism contract, and the recovery state machine.
//!
//! # Environment knobs
//!
//! Coordinator-read: `PHAST_DIST_HEARTBEAT_MS`, `PHAST_DIST_BUDGET`,
//! `PHAST_DIST_FAULT_RANK`, `PHAST_DIST_ABORT_ITER` (test-only injected
//! coordinator crash).  Worker-read (set by the coordinator on spawn):
//! `PHAST_DIST_ROLE`, `PHAST_DIST_RANK`, `PHAST_DIST_RANKS`,
//! `PHAST_DIST_NET`, `PHAST_DIST_SEED`, `PHAST_DIST_ITERS`,
//! `PHAST_DIST_BATCH`, `PHAST_DIST_DIR`, `PHAST_DIST_EVERY`,
//! `PHAST_DIST_KEEP`.

pub mod coordinator;
pub mod transport;
pub mod wire;
pub mod worker;

pub use coordinator::{train_dist, DistConfig, DistSummary};
pub use transport::{PipeTransport, Transport};
pub use wire::Msg;
pub use worker::{worker_main, WorkerSpec};

use anyhow::{bail, Result};

use crate::solver::{crc32, Solver};

/// Role selector: a process spawned with this set to `worker` is a dist
/// worker whose stdout is the transport.
pub const ENV_ROLE: &str = "PHAST_DIST_ROLE";
pub const ENV_RANK: &str = "PHAST_DIST_RANK";
pub const ENV_RANKS: &str = "PHAST_DIST_RANKS";
pub const ENV_NET: &str = "PHAST_DIST_NET";
pub const ENV_SEED: &str = "PHAST_DIST_SEED";
pub const ENV_ITERS: &str = "PHAST_DIST_ITERS";
pub const ENV_BATCH: &str = "PHAST_DIST_BATCH";
pub const ENV_DIR: &str = "PHAST_DIST_DIR";
pub const ENV_EVERY: &str = "PHAST_DIST_EVERY";
pub const ENV_KEEP: &str = "PHAST_DIST_KEEP";
/// Coordinator liveness-poll interval in milliseconds (default 5000).
pub const ENV_HEARTBEAT_MS: &str = "PHAST_DIST_HEARTBEAT_MS";
/// Worker losses tolerated before the coordinator aborts (default 2).
pub const ENV_BUDGET: &str = "PHAST_DIST_BUDGET";
/// Rank whose initial spawn inherits `PHAST_FAULT` (default 1).
pub const ENV_FAULT_RANK: &str = "PHAST_DIST_FAULT_RANK";
/// Test knob: the coordinator kills itself (exit 3) after collecting
/// gradients for this iteration — the coordinator-restart chaos case.
pub const ENV_ABORT_ITER: &str = "PHAST_DIST_ABORT_ITER";

/// Non-empty environment variable, if set.
pub(crate) fn env_var(name: &str) -> Option<String> {
    match std::env::var(name) {
        Ok(v) if !v.is_empty() => Some(v),
        _ => None,
    }
}

/// If this process was spawned as a dist worker
/// (`PHAST_DIST_ROLE=worker`), run the worker loop and exit — never
/// returns in that case.  Call first thing in `main` of any binary
/// used as a `worker_exe` (the CLI, examples, benches, test binaries),
/// **before** anything writes to stdout: the worker's stdout is the
/// wire.
pub fn exec_worker_if_env() {
    if env_var(ENV_ROLE).as_deref() == Some("worker") {
        match worker::worker_main() {
            Ok(()) => std::process::exit(0),
            Err(e) => {
                eprintln!("dist worker failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

/// Flatten all parameter gradients into one contiguous vector, in
/// `Net::params` order — the canonical reduction layout.
pub fn flatten_diffs(solver: &Solver) -> Vec<f32> {
    let params = solver.net.params();
    let total: usize = params.iter().map(|p| p.count()).sum();
    let mut out = Vec::with_capacity(total);
    for p in params {
        out.extend_from_slice(p.diff().as_slice());
    }
    out
}

/// Overwrite all parameter gradients from a flattened vector (the
/// inverse of [`flatten_diffs`]).
pub fn scatter_diffs(solver: &mut Solver, flat: &[f32]) -> Result<()> {
    let mut params = solver.net.params_mut();
    let total: usize = params.iter().map(|p| p.count()).sum();
    if flat.len() != total {
        bail!("reduced gradient has {} elements, net has {total} parameters", flat.len());
    }
    let mut off = 0;
    for p in params.iter_mut() {
        let n = p.count();
        p.diff_mut().as_mut_slice().copy_from_slice(&flat[off..off + n]);
        off += n;
    }
    Ok(())
}

/// CRC-32 over all parameter bytes (little-endian f32s, `Net::params`
/// order) — the cross-rank weight-equality fingerprint.
pub fn weights_hash(solver: &Solver) -> u32 {
    let mut bytes = Vec::new();
    for p in solver.net.params() {
        for v in p.data().as_slice() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    crc32(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Net;
    use crate::proto::{presets, NetConfig, SolverConfig};

    fn tiny_solver() -> Solver {
        let mut ncfg = NetConfig::from_text(presets::net_by_name("mnist").unwrap()).unwrap();
        for l in &mut ncfg.layers {
            if l.ltype == crate::proto::LayerType::Data {
                l.batch_size = 4;
            }
        }
        let net = Net::from_config(ncfg, 7).unwrap();
        let mut scfg = SolverConfig::from_text(presets::solver_by_name("mnist").unwrap()).unwrap();
        scfg.display = 0;
        Solver::new(scfg, net)
    }

    #[test]
    fn flatten_scatter_roundtrips_diffs() {
        let mut s = tiny_solver();
        crate::ops::par::with_threads(1, || s.forward_backward()).unwrap();
        let flat = flatten_diffs(&s);
        let total: usize = s.net.params().iter().map(|p| p.count()).sum();
        assert_eq!(flat.len(), total);
        assert!(flat.iter().any(|&v| v != 0.0), "backward produced gradients");

        let doubled: Vec<f32> = flat.iter().map(|v| v * 2.0).collect();
        scatter_diffs(&mut s, &doubled).unwrap();
        let back = flatten_diffs(&s);
        let want: Vec<u32> = doubled.iter().map(|v| v.to_bits()).collect();
        let have: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
        assert_eq!(want, have);

        assert!(scatter_diffs(&mut s, &flat[1..]).is_err(), "length mismatch rejected");
    }

    #[test]
    fn weights_hash_tracks_parameter_changes() {
        let mut s = tiny_solver();
        let h0 = weights_hash(&s);
        assert_eq!(h0, weights_hash(&s), "hash is stable");
        crate::ops::par::with_threads(1, || s.step()).unwrap();
        assert_ne!(h0, weights_hash(&s), "a step changes the weights");
    }

    #[test]
    fn worker_spec_roundtrips_reduction_weights() {
        // The weights every peer computes for a 3-rank split of batch 8
        // sum to exactly 1.0 here (6/8 + ... no: 3/8 + 3/8 + 2/8) and
        // match the partition the data layer shards by.
        let parts = crate::ops::par::partition(8, 3);
        let weights: Vec<f32> = parts.iter().map(|r| r.len() as f32 / 8.0).collect();
        assert_eq!(weights, vec![3.0 / 8.0, 3.0 / 8.0, 2.0 / 8.0]);
        // Single-rank weight is the exact IEEE identity multiplier.
        assert_eq!(crate::ops::par::partition(8, 1)[0].len() as f32 / 8.0, 1.0);
    }
}
