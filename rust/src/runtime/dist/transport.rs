//! Message transport for `runtime::dist`: a [`Transport`] trait so the
//! worker loop and tests are backend-agnostic, plus the first backend —
//! [`PipeTransport`], CRC-framed messages over a byte stream pair
//! (workers run it over their own stdin/stdout, which the coordinator
//! holds the other ends of).
//!
//! Reliability model: each side keeps the encoded bytes of the last
//! protocol frame it sent.  A receiver that sees a CRC failure answers
//! [`Msg::Nack`]; the peer retransmits the stored frame verbatim.  Nack
//! frames themselves are fire-and-forget (never stored, never faulted)
//! so a corrupted Nack cannot livelock the link — the coordinator's
//! heartbeat re-Nacks anything still missing.
//!
//! Fault injection (worker side only): `PHAST_FAULT=msg_drop@send`,
//! `msg_corrupt@send`, `msg_drop@recv`, `msg_corrupt@recv` — see
//! [`ops::fault`](crate::ops::fault).  A send-drop vanishes the frame,
//! a send-corrupt flips a CRC bit on the wire; recv faults pretend the
//! incoming frame failed its CRC.  All of them must be healed by the
//! Nack path, never surface as wrong gradients.

use std::io::{BufReader, BufWriter, Read, Write};

use anyhow::Result;

use crate::ops::fault::{self, MsgFault};

use super::wire::{self, FrameIn, Msg};

/// A reliable, ordered message channel to the peer (coordinator from a
/// worker's point of view).  Implementations must deliver messages
/// intact and in order — [`PipeTransport`] gets there with CRC + Nack
/// retransmission.
pub trait Transport {
    fn send(&mut self, msg: &Msg) -> Result<()>;
    fn recv(&mut self) -> Result<Msg>;
}

/// [`Transport`] over a read/write byte-stream pair using the
/// [`wire`] framing.
pub struct PipeTransport<R: Read, W: Write> {
    r: BufReader<R>,
    w: BufWriter<W>,
    /// Clean bytes of the last protocol frame sent; retransmitted
    /// verbatim when the peer Nacks.
    last_sent: Vec<u8>,
    crc_nacks: u64,
}

impl<R: Read, W: Write> PipeTransport<R, W> {
    pub fn new(r: R, w: W) -> Self {
        PipeTransport {
            r: BufReader::new(r),
            w: BufWriter::new(w),
            last_sent: Vec::new(),
            crc_nacks: 0,
        }
    }

    /// How many incoming frames failed their CRC (each one was Nacked).
    pub fn crc_nacks(&self) -> u64 {
        self.crc_nacks
    }

    fn write_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.w.write_all(bytes)?;
        self.w.flush()?;
        Ok(())
    }
}

impl<R: Read, W: Write> Transport for PipeTransport<R, W> {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let bytes = wire::encode(msg);
        // Store the CLEAN copy first: an injected drop/corrupt below is
        // healed by the peer Nacking and us resending these bytes.
        self.last_sent.clone_from(&bytes);
        match fault::check_msg("send") {
            MsgFault::Drop => {
                eprintln!("[fault] dropping outbound frame ({} bytes)", bytes.len());
                Ok(())
            }
            MsgFault::Corrupt => {
                eprintln!("[fault] corrupting outbound frame ({} bytes)", bytes.len());
                let mut evil = bytes;
                wire::corrupt_frame(&mut evil);
                self.write_raw(&evil)
            }
            MsgFault::None => self.write_raw(&bytes),
        }
    }

    fn recv(&mut self) -> Result<Msg> {
        loop {
            let mut frame = wire::read_frame(&mut self.r)?;
            if frame != FrameIn::Corrupt {
                match fault::check_msg("recv") {
                    MsgFault::None => {}
                    MsgFault::Drop | MsgFault::Corrupt => {
                        // Either way the frame is unusable; Nacking it
                        // makes the drop deterministic to recover.
                        eprintln!("[fault] rejecting inbound frame as corrupt");
                        frame = FrameIn::Corrupt;
                    }
                }
            }
            match frame {
                FrameIn::Corrupt => {
                    self.crc_nacks += 1;
                    // Raw write: Nacks are not protocol frames — they
                    // never replace last_sent and skip the fault hooks.
                    let nack = wire::encode(&Msg::Nack);
                    self.write_raw(&nack)?;
                }
                FrameIn::Msg(Msg::Nack) => {
                    let bytes = std::mem::take(&mut self.last_sent);
                    self.write_raw(&bytes)?;
                    self.last_sent = bytes;
                }
                FrameIn::Msg(m) => return Ok(m),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn stream(msgs: &[Msg]) -> Cursor<Vec<u8>> {
        let mut bytes = Vec::new();
        for m in msgs {
            bytes.extend_from_slice(&wire::encode(m));
        }
        Cursor::new(bytes)
    }

    fn decode_all(bytes: &[u8]) -> Vec<FrameIn> {
        let mut cur = Cursor::new(bytes.to_vec());
        let mut out = Vec::new();
        while (cur.position() as usize) < bytes.len() {
            out.push(wire::read_frame(&mut cur).unwrap());
        }
        out
    }

    #[test]
    fn recv_returns_messages_in_order() {
        let inbound = stream(&[Msg::Start { ckpt0: false }, Msg::Shutdown]);
        let mut t = PipeTransport::new(inbound, Vec::new());
        assert_eq!(t.recv().unwrap(), Msg::Start { ckpt0: false });
        assert_eq!(t.recv().unwrap(), Msg::Shutdown);
        assert_eq!(t.crc_nacks(), 0);
    }

    #[test]
    fn corrupt_inbound_frame_is_nacked_and_skipped() {
        let mut bytes = wire::encode(&Msg::CkptDone { iter: 3 });
        wire::corrupt_frame(&mut bytes);
        bytes.extend_from_slice(&wire::encode(&Msg::Shutdown));
        let mut t = PipeTransport::new(Cursor::new(bytes), Vec::new());
        // The corrupt frame is never surfaced; recv skips to the next
        // good one after answering with a Nack.
        assert_eq!(t.recv().unwrap(), Msg::Shutdown);
        assert_eq!(t.crc_nacks(), 1);
        assert_eq!(decode_all(t.w.get_ref()), vec![FrameIn::Msg(Msg::Nack)]);
    }

    #[test]
    fn nack_triggers_verbatim_retransmission() {
        let msg = Msg::Grad { iter: 7, weight: 0.5, loss: 1.25, grad: vec![1.0, 2.0, 3.0] };
        let inbound = stream(&[Msg::Nack, Msg::Shutdown]);
        let mut t = PipeTransport::new(inbound, Vec::new());
        t.send(&msg).unwrap();
        // Peer Nacks; recv services the retransmission transparently
        // and returns the next real message.
        assert_eq!(t.recv().unwrap(), Msg::Shutdown);
        let written = decode_all(t.w.get_ref());
        assert_eq!(written, vec![FrameIn::Msg(msg.clone()), FrameIn::Msg(msg)]);
    }

    #[test]
    fn injected_send_corruption_is_on_the_wire_but_recoverable() {
        let msg = Msg::Done { iter: 9, weights_hash: 42 };
        crate::ops::fault::with_faults("msg_corrupt@send=1", || {
            let inbound = stream(&[Msg::Nack, Msg::Shutdown]);
            let mut t = PipeTransport::new(inbound, Vec::new());
            t.send(&msg).unwrap();
            assert_eq!(t.recv().unwrap(), Msg::Shutdown);
            let written = decode_all(t.w.get_ref());
            // First copy corrupted by the fault, retransmission clean.
            assert_eq!(written, vec![FrameIn::Corrupt, FrameIn::Msg(msg.clone())]);
        });
    }

    #[test]
    fn injected_send_drop_leaves_retransmission_only() {
        let msg = Msg::RolledBack { iter: 2 };
        crate::ops::fault::with_faults("msg_drop@send=1", || {
            let inbound = stream(&[Msg::Nack, Msg::Shutdown]);
            let mut t = PipeTransport::new(inbound, Vec::new());
            t.send(&msg).unwrap();
            assert_eq!(t.recv().unwrap(), Msg::Shutdown);
            assert_eq!(decode_all(t.w.get_ref()), vec![FrameIn::Msg(msg)]);
        });
    }

    #[test]
    fn injected_recv_fault_nacks_a_clean_frame() {
        crate::ops::fault::with_faults("msg_corrupt@recv=1", || {
            let inbound = stream(&[Msg::Rollback, Msg::Rollback]);
            let mut t = PipeTransport::new(inbound, Vec::new());
            // First copy rejected by the injected fault, second accepted.
            assert_eq!(t.recv().unwrap(), Msg::Rollback);
            assert_eq!(t.crc_nacks(), 1);
            assert_eq!(decode_all(t.w.get_ref()), vec![FrameIn::Msg(Msg::Nack)]);
        });
    }
}
