//! Pipe-framed wire protocol for data-parallel training
//! (`runtime::dist`): length-prefixed frames with a CRC-32 trailer, so
//! a corrupted message is detected and retransmitted instead of
//! silently applied to the model.
//!
//! # Frame layout (little-endian)
//!
//! ```text
//! "PDW1" | kind: u32 | payload_len: u64 | payload | crc32(kind|len|payload)
//! ```
//!
//! The CRC covers everything after the magic.  A frame whose CRC does
//! not match decodes as [`FrameIn::Corrupt`] — the receiver answers
//! with [`Msg::Nack`] and the sender retransmits its last frame (each
//! side has at most one protocol frame in flight per direction, so
//! "resend the last frame" is always the right recovery).  A corrupted
//! *header* cannot be resynchronized over a byte stream; it surfaces as
//! a bad magic and tears the connection down loudly, which the
//! coordinator treats like a worker loss.

use anyhow::{bail, Context, Result};

use crate::solver::crc32;

/// Frame magic: phast dist wire v1.
pub const MAGIC: &[u8; 4] = b"PDW1";

/// Hard ceiling on payload size (guards against reading gigabytes on a
/// garbled length field that still passed the magic check).
pub const MAX_PAYLOAD: usize = 1 << 31;

/// One protocol message.  The gradient payloads are raw f32 slices —
/// the flattened parameter diffs in `Net::params` order, which is also
/// the deterministic reduction order.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker → coordinator, once at startup: the rank came up and
    /// holds solver state at `resumed_iter` (`resumed` says whether
    /// that state came from a snapshot or fresh init).
    Hello { rank: u32, resumed_iter: u64, resumed: bool },
    /// Coordinator → worker: begin training.  `ckpt0` asks this rank
    /// (the checkpoint owner on a fresh start) to persist the initial
    /// state first, so recovery always has a rollback floor.
    Start { ckpt0: bool },
    /// Worker → coordinator: this rank's flattened parameter diffs for
    /// `iter`, pre-weighted by nothing — `weight` is the rank's batch
    /// share `local_batch / global_batch`, applied by the coordinator
    /// in fixed rank order.
    Grad { iter: u64, weight: f32, loss: f32, grad: Vec<f32> },
    /// Coordinator → worker: the reduced gradient for `iter`; every
    /// rank applies the identical SGD step from it.  `ckpt` asks this
    /// rank (exactly one per checkpoint) to persist a snapshot after
    /// applying.
    Reduced { iter: u64, loss: f32, ckpt: bool, grad: Vec<f32> },
    /// Worker → coordinator: the checkpoint requested via `Start` /
    /// `Reduced` is durable, holding state at `iter`.
    CkptDone { iter: u64 },
    /// Coordinator → worker: discard in-flight work and reload the
    /// newest valid snapshot (a rank was lost).
    Rollback,
    /// Worker → coordinator: rollback complete, now at `iter`.
    RolledBack { iter: u64 },
    /// Worker → coordinator: reached the final iteration; the CRC-32
    /// of the parameter bytes is `weights_hash` (the coordinator
    /// cross-checks all ranks).
    Done { iter: u64, weights_hash: u32 },
    /// Coordinator → worker: exit cleanly.
    Shutdown,
    /// Either direction: the last frame you sent arrived corrupted (or
    /// never arrived) — retransmit it.
    Nack,
}

/// What [`read_frame`] produced: a decoded message, or a frame whose
/// CRC failed (recoverable via [`Msg::Nack`]).
#[derive(Debug, Clone, PartialEq)]
pub enum FrameIn {
    Msg(Msg),
    Corrupt,
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    let start = out.len();
    out.resize(start + xs.len() * 4, 0);
    for (chunk, v) in out[start..].chunks_exact_mut(4).zip(xs) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

fn kind_of(msg: &Msg) -> u32 {
    match msg {
        Msg::Hello { .. } => 1,
        Msg::Start { .. } => 2,
        Msg::Grad { .. } => 3,
        Msg::Reduced { .. } => 4,
        Msg::CkptDone { .. } => 5,
        Msg::Rollback => 6,
        Msg::RolledBack { .. } => 7,
        Msg::Done { .. } => 8,
        Msg::Shutdown => 9,
        Msg::Nack => 10,
    }
}

fn encode_payload(msg: &Msg) -> Vec<u8> {
    let mut p = Vec::new();
    match msg {
        Msg::Hello { rank, resumed_iter, resumed } => {
            push_u32(&mut p, *rank);
            push_u64(&mut p, *resumed_iter);
            p.push(u8::from(*resumed));
        }
        Msg::Start { ckpt0 } => p.push(u8::from(*ckpt0)),
        Msg::Grad { iter, weight, loss, grad } => {
            push_u64(&mut p, *iter);
            push_f32(&mut p, *weight);
            push_f32(&mut p, *loss);
            push_f32s(&mut p, grad);
        }
        Msg::Reduced { iter, loss, ckpt, grad } => {
            push_u64(&mut p, *iter);
            push_f32(&mut p, *loss);
            p.push(u8::from(*ckpt));
            push_f32s(&mut p, grad);
        }
        Msg::CkptDone { iter } | Msg::RolledBack { iter } => push_u64(&mut p, *iter),
        Msg::Done { iter, weights_hash } => {
            push_u64(&mut p, *iter);
            push_u32(&mut p, *weights_hash);
        }
        Msg::Rollback | Msg::Shutdown | Msg::Nack => {}
    }
    p
}

/// Encode `msg` as one complete frame (magic + header + payload + CRC).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let payload = encode_payload(msg);
    let mut out = Vec::with_capacity(20 + payload.len());
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, kind_of(msg));
    push_u64(&mut out, payload.len() as u64);
    out.extend_from_slice(&payload);
    let crc = crc32(&out[4..]);
    push_u32(&mut out, crc);
    out
}

/// Flip one bit of a frame's CRC trailer in place — the fault
/// injector's "corrupted in flight" transform.  Framing (magic, kind,
/// length) is preserved, so the receiver stays synchronized and the
/// corruption is guaranteed to surface as a CRC mismatch.
pub fn corrupt_frame(frame: &mut [u8]) {
    let last = frame.len() - 1;
    frame[last] ^= 0x01;
}

/// Bounds-checked little-endian payload reader.
struct Rd<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.b.len() - self.p {
            bail!("truncated payload: wanted {n} bytes at offset {}, have {}", self.p, self.b.len());
        }
        let out = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// The rest of the payload as f32s (gradient tails).
    fn rest_f32s(&mut self) -> Result<Vec<f32>> {
        let b = &self.b[self.p..];
        self.p = self.b.len();
        if b.len() % 4 != 0 {
            bail!("gradient tail length {} is not a multiple of 4", b.len());
        }
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Decode a CRC-verified payload.  Errors here mean a protocol bug or
/// version skew, not line noise — they are fatal, unlike CRC failures.
fn decode_payload(kind: u32, payload: &[u8]) -> Result<Msg> {
    let mut r = Rd { b: payload, p: 0 };
    let msg = match kind {
        1 => Msg::Hello {
            rank: r.u32()?,
            resumed_iter: r.u64()?,
            resumed: r.u8()? != 0,
        },
        2 => Msg::Start { ckpt0: r.u8()? != 0 },
        3 => Msg::Grad {
            iter: r.u64()?,
            weight: r.f32()?,
            loss: r.f32()?,
            grad: r.rest_f32s()?,
        },
        4 => Msg::Reduced {
            iter: r.u64()?,
            loss: r.f32()?,
            ckpt: r.u8()? != 0,
            grad: r.rest_f32s()?,
        },
        5 => Msg::CkptDone { iter: r.u64()? },
        6 => Msg::Rollback,
        7 => Msg::RolledBack { iter: r.u64()? },
        8 => Msg::Done { iter: r.u64()?, weights_hash: r.u32()? },
        9 => Msg::Shutdown,
        10 => Msg::Nack,
        k => bail!("unknown frame kind {k}"),
    };
    if r.p != payload.len() {
        bail!("frame kind {kind} has {} trailing payload bytes", payload.len() - r.p);
    }
    Ok(msg)
}

/// Read one frame from `r`.  IO errors (including EOF — the peer went
/// away) and desynchronization are `Err`; a frame that arrived but
/// failed its CRC is `Ok(FrameIn::Corrupt)`.
pub fn read_frame(r: &mut impl std::io::Read) -> Result<FrameIn> {
    let mut head = [0u8; 16];
    r.read_exact(&mut head).context("reading frame header (peer closed?)")?;
    if &head[..4] != MAGIC {
        bail!("transport desynchronized: bad frame magic {:?}", &head[..4]);
    }
    let kind = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    let len = u64::from_le_bytes([
        head[8], head[9], head[10], head[11], head[12], head[13], head[14], head[15],
    ]) as usize;
    if len > MAX_PAYLOAD {
        bail!("implausible frame payload length {len}");
    }
    // CRC input is kind|len|payload: reuse the header tail as its prefix.
    let mut buf = vec![0u8; 12 + len];
    buf[..12].copy_from_slice(&head[4..16]);
    r.read_exact(&mut buf[12..]).context("reading frame payload")?;
    let mut crcb = [0u8; 4];
    r.read_exact(&mut crcb).context("reading frame CRC")?;
    let want = u32::from_le_bytes(crcb);
    if crc32(&buf) != want {
        return Ok(FrameIn::Corrupt);
    }
    Ok(FrameIn::Msg(decode_payload(kind, &buf[12..])?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let frame = encode(&msg);
        let mut cur = std::io::Cursor::new(frame);
        match read_frame(&mut cur).unwrap() {
            FrameIn::Msg(got) => assert_eq!(got, msg),
            FrameIn::Corrupt => panic!("clean frame read as corrupt"),
        }
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Msg::Hello { rank: 3, resumed_iter: 17, resumed: true });
        roundtrip(Msg::Start { ckpt0: true });
        roundtrip(Msg::Grad { iter: 5, weight: 0.25, loss: 1.5, grad: vec![1.0, -2.5, 0.0] });
        roundtrip(Msg::Reduced { iter: 5, loss: 0.75, ckpt: false, grad: vec![f32::MIN, f32::MAX] });
        roundtrip(Msg::CkptDone { iter: 8 });
        roundtrip(Msg::Rollback);
        roundtrip(Msg::RolledBack { iter: 4 });
        roundtrip(Msg::Done { iter: 12, weights_hash: 0xDEAD_BEEF });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Nack);
        roundtrip(Msg::Grad { iter: 0, weight: 1.0, loss: 0.0, grad: vec![] });
    }

    #[test]
    fn gradient_bytes_survive_bitwise() {
        // NaNs and signed zeros must cross the wire bit-exactly.
        let grad = vec![f32::NAN, -0.0, 1.0e-45, f32::INFINITY];
        let frame = encode(&Msg::Grad { iter: 1, weight: 0.5, loss: 0.0, grad: grad.clone() });
        let mut cur = std::io::Cursor::new(frame);
        let FrameIn::Msg(Msg::Grad { grad: got, .. }) = read_frame(&mut cur).unwrap() else {
            panic!("bad decode");
        };
        let want: Vec<u32> = grad.iter().map(|v| v.to_bits()).collect();
        let have: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(want, have);
    }

    #[test]
    fn corrupt_frame_is_detected_not_decoded() {
        let mut frame = encode(&Msg::Grad { iter: 2, weight: 1.0, loss: 3.0, grad: vec![9.0; 16] });
        corrupt_frame(&mut frame);
        let mut cur = std::io::Cursor::new(frame);
        assert_eq!(read_frame(&mut cur).unwrap(), FrameIn::Corrupt);
    }

    #[test]
    fn payload_corruption_is_detected_too() {
        let mut frame = encode(&Msg::Grad { iter: 2, weight: 1.0, loss: 3.0, grad: vec![9.0; 16] });
        let mid = 16 + (frame.len() - 20) / 2; // inside the payload
        frame[mid] ^= 0x80;
        let mut cur = std::io::Cursor::new(frame);
        assert_eq!(read_frame(&mut cur).unwrap(), FrameIn::Corrupt);
    }

    #[test]
    fn eof_and_bad_magic_are_fatal() {
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).is_err());

        let mut frame = encode(&Msg::Nack);
        frame[0] = b'X';
        let mut cur = std::io::Cursor::new(frame);
        let err = read_frame(&mut cur).unwrap_err();
        assert!(format!("{err:#}").contains("desynchronized"), "{err:#}");
    }

    #[test]
    fn frames_parse_back_to_back_from_one_stream() {
        let mut stream = Vec::new();
        stream.extend_from_slice(&encode(&Msg::Hello { rank: 0, resumed_iter: 0, resumed: false }));
        stream.extend_from_slice(&encode(&Msg::Start { ckpt0: true }));
        stream.extend_from_slice(&encode(&Msg::Shutdown));
        let mut cur = std::io::Cursor::new(stream);
        assert!(matches!(read_frame(&mut cur).unwrap(), FrameIn::Msg(Msg::Hello { .. })));
        assert!(matches!(read_frame(&mut cur).unwrap(), FrameIn::Msg(Msg::Start { ckpt0: true })));
        assert_eq!(read_frame(&mut cur).unwrap(), FrameIn::Msg(Msg::Shutdown));
    }
}
