//! The dist worker: one training rank, spawned by the coordinator as a
//! re-exec of the current binary with `PHAST_DIST_ROLE=worker`.
//!
//! The worker's **stdout is the transport** — every byte written there
//! must be a wire frame, so all human-readable logging goes to stderr
//! (which the child inherits from the coordinator).  The coordinator
//! holds the other ends of the pipes; stdin EOF means the coordinator
//! is gone and the worker exits.
//!
//! Per iteration the worker runs forward + fused backward on its own
//! contiguous shard of the batch (`Net::from_config_sharded`), ships
//! the flattened parameter diffs up as a `Grad`, waits for the
//! `Reduced` gradient, and applies the identical SGD step every other
//! rank applies.  On `Rollback` it reloads the newest valid snapshot
//! from the shared checkpoint directory and reports where it landed;
//! the coordinator guarantees all ranks land on the same iteration
//! before training resumes.

use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use crate::net::Net;
use crate::ops::{fault, par};
use crate::proto::{presets, LayerType, NetConfig, SolverConfig};
use crate::solver::{find_latest_valid, save_checkpoint, Solver};

use super::transport::{PipeTransport, Transport};
use super::wire::Msg;
use super::{env_var, flatten_diffs, scatter_diffs, weights_hash};

/// Everything a worker needs, decoded from the `PHAST_DIST_*`
/// environment the coordinator set on spawn.
pub struct WorkerSpec {
    pub rank: usize,
    pub ranks: usize,
    /// Preset net name (`mnist`, `cifar`, ...).
    pub net: String,
    pub seed: u64,
    /// Train through iteration `iters - 1`.
    pub iters: usize,
    /// Global batch override (`None` = the preset's batch size).
    pub batch: Option<usize>,
    /// Shared checkpoint directory (all ranks, one host).
    pub dir: PathBuf,
    /// Checkpoint every N iterations (0 = only the initial + final).
    pub every: usize,
    /// Snapshot retention (0 = keep all).
    pub keep: usize,
}

fn env_usize(name: &str) -> Result<usize> {
    env_var(name)
        .with_context(|| format!("dist worker: missing env {name}"))?
        .parse()
        .with_context(|| format!("dist worker: bad {name}"))
}

impl WorkerSpec {
    /// Decode the spec from the environment the coordinator set.
    pub fn from_env() -> Result<WorkerSpec> {
        Ok(WorkerSpec {
            rank: env_usize(super::ENV_RANK)?,
            ranks: env_usize(super::ENV_RANKS)?,
            net: env_var(super::ENV_NET).context("dist worker: missing net")?,
            seed: env_usize(super::ENV_SEED)? as u64,
            iters: env_usize(super::ENV_ITERS)?,
            batch: match env_var(super::ENV_BATCH) {
                Some(b) => Some(b.parse().context("dist worker: bad batch")?),
                None => None,
            },
            dir: PathBuf::from(env_var(super::ENV_DIR).context("dist worker: missing dir")?),
            every: env_usize(super::ENV_EVERY)?,
            keep: env_usize(super::ENV_KEEP)?,
        })
    }
}

/// Build this rank's sharded solver.  Returns the solver plus the
/// rank's reduction weight `local_batch / global_batch` — the exact
/// f32 every peer computes for this rank, since the coordinator's
/// fixed-order weighted sum must be reproducible.
pub fn build_solver(spec: &WorkerSpec) -> Result<(Solver, f32)> {
    let net_text = presets::net_by_name(&spec.net)
        .ok_or_else(|| anyhow!("unknown preset net '{}'", spec.net))?;
    let solver_text = presets::solver_by_name(&spec.net)
        .ok_or_else(|| anyhow!("unknown preset solver '{}'", spec.net))?;
    let mut ncfg = NetConfig::from_text(net_text)?;
    if let Some(b) = spec.batch {
        for l in &mut ncfg.layers {
            if l.ltype == LayerType::Data {
                l.batch_size = b;
            }
        }
    }
    let global_batch = ncfg
        .layers
        .iter()
        .find(|l| l.ltype == LayerType::Data)
        .map(|l| l.batch_size)
        .context("preset net has no Data layer")?;
    let net = Net::from_config_sharded(ncfg, spec.seed, spec.rank, spec.ranks)?;
    let mut scfg = SolverConfig::from_text(solver_text)?;
    scfg.display = 0; // per-iter prints belong to the coordinator
    let local = par::partition(global_batch, spec.ranks)[spec.rank].len();
    let weight = local as f32 / global_batch as f32;
    Ok((Solver::new(scfg, net), weight))
}

/// Reload the newest valid snapshot and trim the stat log back to it.
/// Bails when no snapshot exists — the coordinator always checkpoints
/// iteration 0 before the first step, so this means the checkpoint
/// directory itself is gone or fully corrupted.
fn rollback(solver: &mut Solver, spec: &WorkerSpec) -> Result<u64> {
    let path = find_latest_valid(solver, &spec.dir)?.ok_or_else(|| {
        anyhow!(
            "rank {}: rollback requested but no valid snapshot in {:?}",
            spec.rank,
            spec.dir
        )
    })?;
    let it = solver.iter();
    solver.log.retain(|e| e.iter < it);
    eprintln!("dist rank {}: rolled back to {:?} (iter {})", spec.rank, path, it);
    Ok(it as u64)
}

/// The worker entrypoint: handshake, train, checkpoint on request,
/// roll back on request, report the final weights hash.  Every exit
/// path other than `Shutdown`/EOF is an error the process dies loudly
/// on — the coordinator treats the death as a rank loss.
pub fn worker_main() -> Result<()> {
    let spec = WorkerSpec::from_env()?;
    // Only worker processes honor worker_exit faults: the coordinator
    // (and any test harness) may carry the same PHAST_FAULT env.
    fault::allow_process_exit();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut t = PipeTransport::new(stdin.lock(), stdout.lock());
    run(&spec, &mut t)
}

/// The worker protocol loop over an arbitrary [`Transport`] (split from
/// [`worker_main`] so tests can drive it over in-memory channels).
pub fn run(spec: &WorkerSpec, t: &mut impl Transport) -> Result<()> {
    let (mut solver, weight) = build_solver(spec)?;
    let resumed_path =
        find_latest_valid(&mut solver, &spec.dir).context("scanning snapshots at startup")?;
    if let Some(p) = &resumed_path {
        eprintln!("dist rank {}: resumed from {:?} at iter {}", spec.rank, p, solver.iter());
    }
    t.send(&Msg::Hello {
        rank: spec.rank as u32,
        resumed_iter: solver.iter() as u64,
        resumed: resumed_path.is_some(),
    })?;
    loop {
        match t.recv().context("awaiting Start")? {
            Msg::Start { ckpt0 } => {
                if ckpt0 {
                    save_checkpoint(&mut solver, &spec.dir, spec.keep)
                        .context("initial checkpoint")?;
                    t.send(&Msg::CkptDone { iter: solver.iter() as u64 })?;
                }
                break;
            }
            // A recovery that raced our spawn: comply and keep waiting.
            Msg::Rollback => {
                let at = rollback(&mut solver, spec)?;
                t.send(&Msg::RolledBack { iter: at })?;
            }
            Msg::Shutdown => return Ok(()),
            m => bail!("rank {}: unexpected {m:?} before Start", spec.rank),
        }
    }

    let total = spec.iters as u64;
    'training: loop {
        while (solver.iter() as u64) < total {
            let iter = solver.iter() as u64;
            let loss = solver.forward_backward()?;
            t.send(&Msg::Grad { iter, weight, loss, grad: flatten_diffs(&solver) })?;
            let (rloss, ckpt, rgrad) = loop {
                match t.recv().context("awaiting Reduced")? {
                    Msg::Reduced { iter: ri, loss, ckpt, grad } if ri == iter => {
                        break (loss, ckpt, grad)
                    }
                    Msg::Reduced { iter: ri, .. } => {
                        bail!("rank {}: Reduced for iter {ri}, expected {iter}", spec.rank)
                    }
                    Msg::Rollback => {
                        let at = rollback(&mut solver, spec)?;
                        t.send(&Msg::RolledBack { iter: at })?;
                        continue 'training;
                    }
                    Msg::Shutdown => return Ok(()),
                    m => bail!("rank {}: unexpected {m:?} awaiting Reduced", spec.rank),
                }
            };
            scatter_diffs(&mut solver, &rgrad)?;
            solver.apply_step(rloss);
            if ckpt {
                save_checkpoint(&mut solver, &spec.dir, spec.keep).with_context(|| {
                    format!("rank {} checkpoint at iter {}", spec.rank, solver.iter())
                })?;
                t.send(&Msg::CkptDone { iter: solver.iter() as u64 })?;
            }
        }
        t.send(&Msg::Done { iter: solver.iter() as u64, weights_hash: weights_hash(&solver) })?;
        // A peer loss after we finished still rolls us back: the
        // coordinator re-runs the tail so every rank ends identical.
        match t.recv().context("awaiting Shutdown")? {
            Msg::Shutdown => return Ok(()),
            Msg::Rollback => {
                let at = rollback(&mut solver, spec)?;
                t.send(&Msg::RolledBack { iter: at })?;
                continue 'training;
            }
            m => bail!("rank {}: unexpected {m:?} after Done", spec.rank),
        }
    }
}
