//! The execution engine: compile-on-demand cache of PJRT executables plus
//! typed host<->device value marshalling with byte accounting.

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::tensor::{IntTensor, Shape, Tensor};

use super::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// A host value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
    /// f32 scalar (e.g. the learning rate input of the fused step).
    Scalar(f32),
}

impl Value {
    pub fn bytes(&self) -> usize {
        match self {
            Value::F32(t) => t.len() * 4,
            Value::I32(t) => t.len() * 4,
            Value::Scalar(_) => 4,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 tensor, got {other:?}"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 tensor, got {other:?}"),
        }
    }

    pub fn into_i32(self) -> Result<IntTensor> {
        match self {
            Value::I32(t) => Ok(t),
            other => bail!("expected i32 tensor, got {other:?}"),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        match self {
            Value::F32(t) => spec.dtype == DType::F32 && t.shape().dims() == spec.dims,
            Value::I32(t) => spec.dtype == DType::I32 && t.shape().dims() == spec.dims,
            Value::Scalar(_) => spec.dtype == DType::F32 && spec.dims.is_empty(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Value::F32(t) => {
                let lit = xla::Literal::vec1(t.as_slice());
                if t.shape().ndim() == 1 {
                    lit
                } else {
                    lit.reshape(&t.shape().dims_i64())?
                }
            }
            Value::I32(t) => {
                let lit = xla::Literal::vec1(t.as_slice());
                if t.shape().ndim() == 1 {
                    lit
                } else {
                    lit.reshape(&t.shape().dims_i64())?
                }
            }
            Value::Scalar(v) => xla::Literal::scalar(*v),
        })
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
        let shape = Shape::new(&spec.dims);
        Ok(match spec.dtype {
            DType::F32 => Value::F32(Tensor::from_vec(shape, lit.to_vec::<f32>()?)),
            DType::I32 => Value::I32(IntTensor::from_vec(shape, lit.to_vec::<i32>()?)),
        })
    }
}

/// Cumulative transfer statistics (physical host<->device traffic).
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferStats {
    pub h2d_count: u64,
    pub h2d_bytes: u64,
    pub d2h_count: u64,
    pub d2h_bytes: u64,
    pub executions: u64,
}

/// PJRT engine: one CPU client + a compile cache over the manifest catalog.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: std::path::PathBuf,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<TransferStats>,
    /// Wall time spent inside PJRT execute calls.
    exec_time: RefCell<std::time::Duration>,
}

impl Engine {
    /// Open the engine over an artifacts directory (`manifest.txt` inside).
    pub fn open(dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(TransferStats::default()),
            exec_time: RefCell::new(std::time::Duration::ZERO),
        })
    }

    /// Open over the default artifacts directory.
    pub fn open_default() -> Result<Engine> {
        Self::open(&super::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest (run `make artifacts`)"))
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.spec(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("PJRT compile of '{name}'"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (warmup; keeps compile time out of
    /// the benchmarks).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute `name` with host `args`; returns host outputs.
    ///
    /// Every call pays one H2D transfer per argument and one D2H for the
    /// result tuple — the physical cost of hopping between the native and
    /// PHAST domains that the paper's §4.3 analyses.
    pub fn run(&self, name: &str, args: &[Value]) -> Result<Vec<Value>> {
        let spec = self.spec(name)?.clone();
        if args.len() != spec.ins.len() {
            bail!("'{name}' expects {} args, got {}", spec.ins.len(), args.len());
        }
        for (i, (a, s)) in args.iter().zip(&spec.ins).enumerate() {
            if !a.matches(s) {
                bail!("'{name}' arg {i}: value does not match spec {s:?}");
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        {
            let mut st = self.stats.borrow_mut();
            st.h2d_count += args.len() as u64;
            st.h2d_bytes += args.iter().map(|a| a.bytes() as u64).sum::<u64>();
            st.executions += 1;
        }
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        *self.exec_time.borrow_mut() += t0.elapsed();
        let mut tuple = result.to_tuple()?;
        if tuple.len() != spec.outs.len() {
            bail!("'{name}' returned {} outputs, manifest says {}", tuple.len(), spec.outs.len());
        }
        {
            let mut st = self.stats.borrow_mut();
            st.d2h_count += tuple.len() as u64;
            st.d2h_bytes += spec.outs.iter().map(|o| o.bytes() as u64).sum::<u64>();
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for (lit, ospec) in tuple.iter_mut().zip(&spec.outs) {
            outs.push(Value::from_literal(lit, ospec)?);
        }
        Ok(outs)
    }

    pub fn stats(&self) -> TransferStats {
        *self.stats.borrow()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = TransferStats::default();
        *self.exec_time.borrow_mut() = std::time::Duration::ZERO;
    }

    pub fn exec_time(&self) -> std::time::Duration {
        *self.exec_time.borrow()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts`; they are skipped (not failed)
    /// when the catalog is absent so `cargo test` works pre-build.
    fn engine() -> Option<Engine> {
        Engine::open_default().ok()
    }

    #[test]
    fn relu_artifact_round_trip() {
        let Some(eng) = engine() else { return };
        let spec = eng.spec("mnist.relu1.fwd").unwrap().clone();
        let n = spec.ins[0].count();
        let xs: Vec<f32> = (0..n).map(|i| i as f32 - (n / 2) as f32).collect();
        let x = Tensor::from_vec(Shape::new(&spec.ins[0].dims), xs.clone());
        let out = eng.run("mnist.relu1.fwd", &[Value::F32(x)]).unwrap();
        let y = out[0].as_f32().unwrap();
        for (xi, yi) in xs.iter().zip(y.as_slice()) {
            assert_eq!(*yi, xi.max(0.0));
        }
        let st = eng.stats();
        assert_eq!(st.executions, 1);
        assert_eq!(st.h2d_bytes, (n * 4) as u64);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(eng) = engine() else { return };
        let bad = Tensor::zeros(Shape::new(&[2, 2]));
        assert!(eng.run("mnist.relu1.fwd", &[Value::F32(bad)]).is_err());
        assert!(eng.run("mnist.relu1.fwd", &[]).is_err());
        assert!(eng.run("no.such.artifact", &[]).is_err());
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(eng) = engine() else { return };
        let a = eng.executable("mnist.accuracy.fwd").unwrap();
        let b = eng.executable("mnist.accuracy.fwd").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
    }
}
