//! Parser for `artifacts/manifest.txt` — the contract between the Python
//! compile path and the Rust runtime.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of an artifact argument/result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype '{other}' in manifest"),
        })
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.count() * 4
    }
}

/// One AOT-compiled executable: its file and I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub ins: Vec<TensorSpec>,
    pub outs: Vec<TensorSpec>,
}

/// The whole artifact catalog.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    specs: HashMap<String, ArtifactSpec>,
    order: Vec<String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kw = parts.next().unwrap();
            let ctx = || format!("manifest line {}", lineno + 1);
            match kw {
                "artifact" => {
                    if cur.is_some() {
                        bail!("{}: nested artifact", ctx());
                    }
                    cur = Some(ArtifactSpec {
                        name: parts.next().with_context(ctx)?.to_string(),
                        file: String::new(),
                        ins: vec![],
                        outs: vec![],
                    });
                }
                "file" => {
                    cur.as_mut().with_context(ctx)?.file =
                        parts.next().with_context(ctx)?.to_string();
                }
                "in" | "out" => {
                    let dtype = DType::parse(parts.next().with_context(ctx)?)?;
                    let dims_str = parts.next().with_context(ctx)?;
                    let dims = if dims_str == "scalar" {
                        vec![]
                    } else {
                        dims_str
                            .split(',')
                            .map(|d| d.parse::<usize>().map_err(anyhow::Error::from))
                            .collect::<Result<Vec<_>>>()
                            .with_context(ctx)?
                    };
                    let spec = TensorSpec { dtype, dims };
                    let art = cur.as_mut().with_context(ctx)?;
                    if kw == "in" {
                        art.ins.push(spec);
                    } else {
                        art.outs.push(spec);
                    }
                }
                "end" => {
                    let art = cur.take().with_context(ctx)?;
                    if art.file.is_empty() {
                        bail!("{}: artifact '{}' missing file", ctx(), art.name);
                    }
                    m.order.push(art.name.clone());
                    m.specs.insert(art.name.clone(), art);
                }
                other => bail!("{}: unknown keyword '{other}'", ctx()),
            }
        }
        if cur.is_some() {
            bail!("manifest truncated (missing final 'end')");
        }
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        Manifest::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.order.iter().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
artifact mnist.conv1.fwd
file mnist.conv1.fwd.hlo.txt
in f32 64,1,28,28
in f32 20,1,5,5
in f32 20
out f32 64,20,24,24
end
artifact mnist.step
file mnist.step.hlo.txt
in f32 64,1,28,28
in i32 64
in f32 scalar
out f32 1
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let c = m.get("mnist.conv1.fwd").unwrap();
        assert_eq!(c.ins.len(), 3);
        assert_eq!(c.ins[0].dims, vec![64, 1, 28, 28]);
        assert_eq!(c.outs[0].bytes(), 64 * 20 * 24 * 24 * 4);
        let s = m.get("mnist.step").unwrap();
        assert_eq!(s.ins[1].dtype, DType::I32);
        assert_eq!(s.ins[2].dims, Vec::<usize>::new());
        assert_eq!(s.ins[2].count(), 1);
    }

    #[test]
    fn rejects_truncation_and_garbage() {
        assert!(Manifest::parse("artifact x\nfile f\n").is_err());
        assert!(Manifest::parse("bogus line\n").is_err());
        assert!(Manifest::parse("artifact x\nin f64 3\nend\n").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        let path = crate::runtime::artifacts_dir().join("manifest.txt");
        if let Ok(m) = Manifest::load(&path) {
            assert!(m.get("mnist.step").is_some());
            assert!(m.get("cifar.step").is_some());
            assert!(m.len() >= 40, "expected full catalog, got {}", m.len());
        }
    }
}
