//! Runtime layer: PJRT artifact execution and the native serving engine.
//!
//! The PJRT half loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them through the `xla` crate's PJRT
//! CPU client.  This is the only place the crate touches XLA; Python never
//! runs here.  Interchange is HLO *text* (see aot.py's module docs for why
//! the serialized-proto path is a dead end with xla_extension 0.5.1).
//!
//! The serving half ([`serve`], [`queue`]) is an async-style inference
//! front end over the native stack: bounded intake queue, deadline-aware
//! dynamic batching, snapshot-backed model registry with hot reload, and
//! zero-copy response views.  See `docs/SERVING.md`.
//!
//! The training half ([`dist`]) is elastic data-parallel multi-process
//! training: a coordinator all-reduces per-rank gradients in fixed rank
//! order and self-heals worker losses by rolling every rank back to the
//! newest shared snapshot.  See `docs/FAULT_TOLERANCE.md`.

mod manifest;
mod engine;
pub mod dist;
pub mod queue;
pub mod serve;

pub use dist::{train_dist, DistConfig, DistSummary, Transport};
pub use engine::{Engine, Value};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use queue::{BoundedQueue, PopOutcome, PushError};
pub use serve::{
    Model, ModelRegistry, Pending, Response, ServeConfig, ServeEngine, ServeError, ServeStats,
    SubmitError,
};

use std::path::PathBuf;

/// Default artifacts directory, relative to the crate root (overridable via
/// the `PHAST_ARTIFACTS` environment variable).
pub fn artifacts_dir() -> PathBuf {
    // LINT-ALLOW: env-read — path lookup, re-read per call so tests
    // can repoint the artifacts dir; not a cached tuning knob.
    if let Ok(p) = std::env::var("PHAST_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
