//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them through the `xla` crate's PJRT
//! CPU client.  This is the only place the crate touches XLA; Python never
//! runs here.
//!
//! Interchange is HLO *text* (see aot.py's module docs for why the
//! serialized-proto path is a dead end with xla_extension 0.5.1).

mod manifest;
mod engine;

pub use engine::{Engine, Value};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

use std::path::PathBuf;

/// Default artifacts directory, relative to the crate root (overridable via
/// the `PHAST_ARTIFACTS` environment variable).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PHAST_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
