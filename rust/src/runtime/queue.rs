//! Bounded MPSC intake queue for the serving engine.
//!
//! Producers are client threads calling [`crate::runtime::ServeEngine::submit`];
//! the single consumer is the engine's batcher thread.  The queue is
//! deliberately **non-blocking on the producer side**: when it is full,
//! [`BoundedQueue::push`] returns the item back immediately
//! ([`PushError::Full`]) so callers see backpressure as an error they can
//! retry or shed, instead of stalling request threads behind a slow model
//! (`PHAST_SERVE_QUEUE` sizes the buffer).  The consumer side blocks —
//! that is where the deadline-aware batching happens
//! ([`BoundedQueue::pop_if_before`]).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a [`BoundedQueue::push`] was rejected.  The item is handed back so
/// the caller can retry or report it without cloning.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue holds `capacity` items: backpressure.
    Full(T),
    /// [`BoundedQueue::close`] was called; no further items are accepted.
    Closed(T),
}

/// Outcome of a deadline-bounded consumer pop ([`BoundedQueue::pop_if_before`]).
#[derive(Debug)]
pub enum PopOutcome<T> {
    /// The head item satisfied the fit predicate and was dequeued.
    Item(T),
    /// A head item exists but the fit predicate rejected it (e.g. it
    /// would overflow the batch being assembled); it stays queued.
    DoesNotFit,
    /// The deadline passed with the queue empty.
    Deadline,
    /// The queue is closed and drained.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer single-consumer queue (mutex + condvar; the
/// crate is dependency-free by design, see `ops::par`).
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue without blocking.  Errors carry the item back.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Stop accepting items and wake the consumer so it can drain and exit.
    /// Already-queued items remain poppable.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// Blocking pop: waits for an item without a deadline.  Returns `None`
    /// only when the queue is closed **and** drained — the batcher's loop
    /// condition.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Deadline-bounded conditional pop, the batching primitive: dequeue
    /// the head item iff `fits` accepts it, waiting until `deadline` for
    /// one to arrive.  A head item that does not fit is **left queued**
    /// ([`PopOutcome::DoesNotFit`]) so the batcher can flush the batch it
    /// is assembling and pick the item up in the next round.
    pub fn pop_if_before<F>(&self, deadline: Instant, fits: F) -> PopOutcome<T>
    where
        F: Fn(&T) -> bool,
    {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(head) = g.items.front() {
                if fits(head) {
                    return PopOutcome::Item(g.items.pop_front().unwrap());
                }
                return PopOutcome::DoesNotFit;
            }
            if g.closed {
                return PopOutcome::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopOutcome::Deadline;
            }
            let (guard, _timeout) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn push_pop_fifo() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
    }

    #[test]
    fn full_queue_rejects_with_backpressure() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(PushError::Full(v)) => assert_eq!(v, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        // Draining one slot re-opens the queue.
        assert_eq!(q.pop_blocking(), Some(1));
        q.push(3).unwrap();
    }

    #[test]
    fn closed_queue_rejects_push_and_drains() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        match q.push(8) {
            Err(PushError::Closed(v)) => assert_eq!(v, 8),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(q.pop_blocking(), Some(7));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn pop_if_before_times_out_when_empty() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let deadline = Instant::now() + Duration::from_millis(5);
        match q.pop_if_before(deadline, |_| true) {
            PopOutcome::Deadline => {}
            other => panic!("expected Deadline, got {other:?}"),
        }
    }

    #[test]
    fn pop_if_before_leaves_unfitting_head_queued() {
        let q = BoundedQueue::new(4);
        q.push(10).unwrap();
        let deadline = Instant::now() + Duration::from_millis(50);
        match q.pop_if_before(deadline, |&v| v < 10) {
            PopOutcome::DoesNotFit => {}
            other => panic!("expected DoesNotFit, got {other:?}"),
        }
        assert_eq!(q.len(), 1, "rejected head must stay queued");
        match q.pop_if_before(deadline, |&v| v >= 10) {
            PopOutcome::Item(v) => assert_eq!(v, 10),
            other => panic!("expected Item, got {other:?}"),
        }
    }

    #[test]
    fn min_capacity_is_one() {
        let q = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.push(1).unwrap();
        assert!(matches!(q.push(2), Err(PushError::Full(2))));
    }
}
