//! Async-style inference engine: bounded intake queue, deadline-aware
//! dynamic batching, forward-only planned nets over the shared persistent
//! worker pool, and zero-copy response views into the batch output blob.
//!
//! The pipeline is `submit → [BoundedQueue] → batcher thread → planned
//! forward → response views`.  Client threads enqueue [`Request`]s and
//! block (if they choose) on a [`Pending`] handle; a single batcher
//! thread coalesces queued requests until the batch is full
//! (`PHAST_SERVE_BATCH`) or the oldest request has waited
//! `PHAST_SERVE_DELAY_US`, runs **one** forward sweep for the whole
//! batch, and hands each client a [`Response`] view into the shared
//! output tensor — the batch output is never copied per request.
//! With `PHAST_SERVE_TIMEOUT_US` set, each request also carries a
//! resolve-by deadline: a request the batcher cannot serve in time
//! (queue backlog, or a model wedged behind a long lock hold) resolves
//! to [`ServeError::Timeout`] instead of riding a late batch.
//!
//! Serving reuses the training stack unchanged: [`Model`] wraps a
//! [`Solver`] so v2 `.pcss` checkpoints load through the exact
//! crash-safety path (`find_latest_valid`), and the forward sweep is
//! [`Net::forward_infer`] — the planned executor with the Data layers
//! skipped.  Weights are frozen between reloads, so the GeMM engine's
//! `PackedMat` caches stay valid across batches: after the first
//! (warm-up) batch of each loaded model, serving performs **zero**
//! repacks (`packs_per_forward == 0`, tracked by
//! [`ServeStats::steady_repacks`] and pinned by the tests and the
//! `serving` bench).
//!
//! Hot reload is batch-granular: [`ModelRegistry::reload`] loads the
//! newest valid snapshot into a **fresh** net and atomically swaps the
//! registry slot.  A batch in flight keeps the `Arc` it grabbed at batch
//! start and finishes on the old weights; the next batch picks up the
//! new model.  See `docs/SERVING.md` for the full walkthrough.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::queue::{BoundedQueue, PopOutcome, PushError};
use crate::net::Net;
use crate::ops::gemm;
use crate::ops::par;
use crate::proto::{presets, LayerType, NetConfig, SolverConfig};
use crate::solver::{find_latest_valid, Solver};
use crate::tensor::Tensor;

/// Serving knobs, in the style of `solver::DriverConfig`: read from the
/// environment once at [`ServeConfig::from_env`] (i.e. per engine
/// construction, not per request).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max samples coalesced into one forward sweep (`PHAST_SERVE_BATCH`,
    /// default 8).  Clamped to the model's net batch dimension at
    /// [`ServeEngine::start`].
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a partial
    /// batch is flushed (`PHAST_SERVE_DELAY_US`, default 2000).  The
    /// deadline is anchored at the request's *enqueue* time, so queue
    /// backlog counts against it.
    pub max_delay_us: u64,
    /// Intake queue capacity in requests (`PHAST_SERVE_QUEUE`, default
    /// 256).  A full queue rejects `submit` with
    /// [`SubmitError::QueueFull`] — backpressure, never blocking.
    pub queue_cap: usize,
    /// Per-request deadline (`PHAST_SERVE_TIMEOUT_US`, default 0 =
    /// disabled).  A request still unanswered this long after `submit`
    /// resolves to [`ServeError::Timeout`] instead of riding a late
    /// batch — the client has given up; burning a forward row on it (or
    /// worse, delivering into a freed handle's void) helps nobody.
    pub timeout_us: u64,
    /// Worker-pool width override for the batcher thread (tests and
    /// benches pin widths with it; `None` inherits `PHAST_NUM_THREADS`).
    /// Not an env knob.
    pub threads: Option<usize>,
}

impl ServeConfig {
    pub fn from_env() -> ServeConfig {
        fn num(var: &str, default: usize) -> usize {
            std::env::var(var).ok().and_then(|v| v.trim().parse().ok()).unwrap_or(default)
        }
        ServeConfig {
            max_batch: num("PHAST_SERVE_BATCH", 8).max(1),
            max_delay_us: num("PHAST_SERVE_DELAY_US", 2000) as u64,
            queue_cap: num("PHAST_SERVE_QUEUE", 256).max(1),
            timeout_us: num("PHAST_SERVE_TIMEOUT_US", 0) as u64,
            threads: None,
        }
    }
}

/// A servable net: a [`Solver`]-wrapped forward-only net plus the blob
/// names the engine reads and writes.  Wrapping the solver (rather than
/// a bare net) is what lets registry entries load v2 `.pcss`
/// checkpoints through the same validated path training resumes from.
pub struct Model {
    solver: Solver,
    input: String,
    output: String,
    batch: usize,
    sample_in: usize,
    sample_out: usize,
}

impl Model {
    /// Build a forward-only model from prototxt sources, with every Data
    /// layer's `batch_size` overridden to `batch` (the serving batch is
    /// a deployment choice, not a training-config one).
    pub fn from_config_text(
        net_text: &str,
        solver_text: &str,
        seed: u64,
        batch: usize,
        input: &str,
        output: &str,
    ) -> Result<Model> {
        if batch == 0 {
            bail!("serving batch must be >= 1");
        }
        let mut ncfg = NetConfig::from_text(net_text)?;
        for l in &mut ncfg.layers {
            if l.ltype == LayerType::Data {
                l.batch_size = batch;
            }
        }
        let net = Net::from_config(ncfg, seed)?;
        let in_count = net
            .blob(input)
            .with_context(|| format!("serving input blob '{input}' not in net"))?
            .count();
        let out_count = net
            .blob(output)
            .with_context(|| format!("serving output blob '{output}' not in net"))?
            .count();
        if in_count % batch != 0 || out_count % batch != 0 {
            bail!("blob counts ({in_count} in / {out_count} out) not divisible by batch {batch}");
        }
        let mut scfg = SolverConfig::from_text(solver_text)?;
        scfg.display = 0;
        Ok(Model {
            solver: Solver::new(scfg, net),
            input: input.to_string(),
            output: output.to_string(),
            batch,
            sample_in: in_count / batch,
            sample_out: out_count / batch,
        })
    }

    /// LeNet-MNIST at the given serving batch: input blob `data`
    /// (1×28×28 per sample), output blob `ip2` (10 logits per sample).
    pub fn lenet(batch: usize, seed: u64) -> Result<Model> {
        let (net, solver) = (presets::LENET_MNIST, presets::LENET_SOLVER);
        Model::from_config_text(net, solver, seed, batch, "data", "ip2")
    }

    /// Load the newest **valid** snapshot in `dir` into this model
    /// (weights, momentum, iteration, cursors — the full v2 image via
    /// `solver::find_latest_valid`).  Returns the path loaded, `None`
    /// when the directory holds no loadable snapshot.
    pub fn load_latest(&mut self, dir: &Path) -> Result<Option<PathBuf>> {
        find_latest_valid(&mut self.solver, dir)
    }

    /// Floats per request sample in the input blob.
    pub fn sample_in(&self) -> usize {
        self.sample_in
    }

    /// Floats per sample in the output blob (e.g. 10 LeNet logits).
    pub fn sample_out(&self) -> usize {
        self.sample_out
    }

    /// The net's batch dimension (upper bound for any served batch).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The wrapped solver (weights, net, history) — the serving tests
    /// use it to derive reference outputs and to author checkpoints.
    pub fn solver_mut(&mut self) -> &mut Solver {
        &mut self.solver
    }

    /// Run one forward-only sweep over `rows` samples (`samples` is their
    /// concatenation), padding the rest of the batch with zeros, and
    /// return the **whole** output tensor by moving it out of the blob
    /// store (a fresh zero tensor takes its place, so the next sweep is
    /// undisturbed).  Per-sample rows are arithmetic-independent in every
    /// layer (per-sample conv/pool, per-row GeMM and softmax), so row `i`
    /// of the result is bitwise the same whether the sample shares the
    /// batch with others, with zero padding, or runs alone — the
    /// property the serving acceptance tests pin.
    pub fn forward_batch(&mut self, samples: &[f32], rows: usize) -> Result<Tensor> {
        if rows == 0 || rows > self.batch {
            bail!("forward_batch rows {} out of range 1..={}", rows, self.batch);
        }
        if samples.len() != rows * self.sample_in {
            bail!(
                "forward_batch got {} floats for {} rows of {}",
                samples.len(),
                rows,
                self.sample_in
            );
        }
        {
            let blob = self
                .solver
                .net
                .blob_mut(&self.input)
                .with_context(|| format!("input blob '{}' vanished", self.input))?;
            let data = blob.data_mut().as_mut_slice();
            data[..samples.len()].copy_from_slice(samples);
            data[samples.len()..].fill(0.0);
        }
        self.solver.net.forward_infer()?;
        let blob = self
            .solver
            .net
            .blob_mut(&self.output)
            .with_context(|| format!("output blob '{}' vanished", self.output))?;
        let shape = blob.shape().clone();
        Ok(std::mem::replace(blob.data_mut(), Tensor::zeros(shape)))
    }
}

type ModelFactory = Arc<dyn Fn() -> Result<Model> + Send + Sync>;

struct RegEntry {
    model: Arc<Mutex<Model>>,
    /// Snapshot directory watched by [`ModelRegistry::reload`]; `None`
    /// for fixed entries (reload is a no-op).
    dir: Option<PathBuf>,
    /// The snapshot file the live model was loaded from.
    loaded: Option<PathBuf>,
    factory: Option<ModelFactory>,
}

/// Registry of servable models keyed by name, each backed by a snapshot
/// directory.  [`ModelRegistry::reload`] is the hot-reload path: it
/// builds a **fresh** model (fresh net, fresh `PackedMat` caches), loads
/// the newest valid `.pcss` into it, and atomically swaps the slot — an
/// in-flight batch keeps the old `Arc` and finishes on the old weights.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Mutex<HashMap<String, RegEntry>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register a snapshot-backed model: `factory` builds the net (and is
    /// re-invoked on every reload), `dir` is scanned for the newest valid
    /// checkpoint.  Returns the snapshot path loaded now, `None` when the
    /// directory holds none yet (the model serves its seed weights).
    pub fn register<F>(&self, name: &str, dir: &Path, factory: F) -> Result<Option<PathBuf>>
    where
        F: Fn() -> Result<Model> + Send + Sync + 'static,
    {
        let mut model = factory().with_context(|| format!("building model '{name}'"))?;
        let loaded = model
            .load_latest(dir)
            .with_context(|| format!("loading '{name}' from {dir:?}"))?;
        self.entries.lock().unwrap().insert(
            name.to_string(),
            RegEntry {
                model: Arc::new(Mutex::new(model)),
                dir: Some(dir.to_path_buf()),
                loaded: loaded.clone(),
                factory: Some(Arc::new(factory)),
            },
        );
        Ok(loaded)
    }

    /// Register a model with no snapshot directory (seed or caller-set
    /// weights; [`ModelRegistry::reload`] leaves it untouched).
    pub fn register_fixed(&self, name: &str, model: Model) {
        self.entries.lock().unwrap().insert(
            name.to_string(),
            RegEntry { model: Arc::new(Mutex::new(model)), dir: None, loaded: None, factory: None },
        );
    }

    /// The live model for `name`.  The returned `Arc` stays valid across
    /// reloads — that is exactly the in-flight-batch guarantee.
    pub fn current(&self, name: &str) -> Option<Arc<Mutex<Model>>> {
        self.entries.lock().unwrap().get(name).map(|e| Arc::clone(&e.model))
    }

    /// The snapshot file the live model was loaded from, if any.
    pub fn loaded_snapshot(&self, name: &str) -> Option<PathBuf> {
        self.entries.lock().unwrap().get(name).and_then(|e| e.loaded.clone())
    }

    /// Hot reload: rebuild the model via its factory, load the newest
    /// valid snapshot from the watched directory, and — iff that snapshot
    /// differs from the one currently serving — atomically swap the slot.
    /// Returns the newly loaded path, or `None` when nothing changed (or
    /// the entry is fixed).  The swap is the only mutation: readers that
    /// already hold the old `Arc` are never disturbed.
    pub fn reload(&self, name: &str) -> Result<Option<PathBuf>> {
        // Build outside the registry lock: a fresh net + checkpoint load
        // is milliseconds of work, and `current()` must not stall behind it.
        let (dir, factory) = {
            let entries = self.entries.lock().unwrap();
            let e = entries.get(name).with_context(|| format!("no model '{name}'"))?;
            match (&e.dir, &e.factory) {
                (Some(d), Some(f)) => (d.clone(), Arc::clone(f)),
                _ => return Ok(None),
            }
        };
        let mut fresh = factory().with_context(|| format!("rebuilding model '{name}'"))?;
        let loaded = fresh
            .load_latest(&dir)
            .with_context(|| format!("reloading '{name}' from {dir:?}"))?;
        let mut entries = self.entries.lock().unwrap();
        let e = entries.get_mut(name).with_context(|| format!("model '{name}' vanished"))?;
        if loaded.is_none() || loaded == e.loaded {
            return Ok(None);
        }
        e.model = Arc::new(Mutex::new(fresh));
        e.loaded = loaded.clone();
        Ok(loaded)
    }
}

/// Why [`ServeEngine::submit`] rejected a request.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The intake queue is at `PHAST_SERVE_QUEUE` capacity: shed or retry.
    QueueFull,
    /// The engine is shutting down.
    Closed,
    /// The request carries more samples than `max_batch` — it could never
    /// be scheduled, so it is rejected up front.
    TooLarge { rows: usize, max_batch: usize },
    /// The payload is not a positive whole number of input samples.
    BadLength { len: usize, sample_in: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "serve queue full (PHAST_SERVE_QUEUE)"),
            SubmitError::Closed => write!(f, "serve engine closed"),
            SubmitError::TooLarge { rows, max_batch } => {
                write!(f, "request of {rows} samples exceeds max_batch {max_batch}")
            }
            SubmitError::BadLength { len, sample_in } => {
                write!(f, "payload of {len} floats is not a positive multiple of {sample_in}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why a **queued** request resolved without a [`Response`] (submission
/// itself failed with [`SubmitError`] instead).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's `PHAST_SERVE_TIMEOUT_US` deadline passed before a
    /// batch could serve it (queue backlog or a wedged model).
    Timeout { waited_us: u64 },
    /// The forward sweep (or model resolution) failed.
    Engine(String),
    /// The engine shut down with the request still queued.
    Dropped,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Timeout { waited_us } => {
                write!(f, "request timed out after {waited_us}us (PHAST_SERVE_TIMEOUT_US)")
            }
            ServeError::Engine(msg) => write!(f, "serve error: {msg}"),
            ServeError::Dropped => write!(f, "serve engine dropped the request (shutdown)"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One queued inference request (internal to the engine).
struct Request {
    samples: Vec<f32>,
    rows: usize,
    tx: mpsc::Sender<Result<Response, ServeError>>,
    enqueued: Instant,
    /// Resolve-by deadline (`None` = no timeout configured).
    deadline: Option<Instant>,
}

/// Zero-copy view of one request's rows in a batch output tensor.  Every
/// response of a batch shares the same `Arc<Tensor>` — dropping them all
/// frees the batch output; none of them copies it.
#[derive(Clone)]
pub struct Response {
    batch: Arc<Tensor>,
    row0: usize,
    rows: usize,
    width: usize,
    latency: Duration,
}

impl Response {
    /// This request's output rows, contiguous in the shared batch tensor.
    pub fn scores(&self) -> &[f32] {
        &self.batch.as_slice()[self.row0 * self.width..(self.row0 + self.rows) * self.width]
    }

    /// Output row for sample `i` of the request.
    pub fn sample_scores(&self, i: usize) -> &[f32] {
        assert!(i < self.rows, "sample {i} out of {}", self.rows);
        let start = (self.row0 + i) * self.width;
        &self.batch.as_slice()[start..start + self.width]
    }

    /// Index of the max score of sample `i` (first-wins on ties, so the
    /// result is a pure function of the scores).
    pub fn argmax(&self, i: usize) -> usize {
        let row = self.sample_scores(i);
        let mut best = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        best
    }

    /// Samples in this response.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Floats per sample row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Enqueue-to-response wall time (queue wait + batching delay +
    /// forward), the quantity the serving bench reports percentiles of.
    pub fn latency(&self) -> Duration {
        self.latency
    }
}

/// Client-side handle for a submitted request.
pub struct Pending {
    rx: mpsc::Receiver<Result<Response, ServeError>>,
}

impl Pending {
    /// Block until the batch containing this request completes (or its
    /// deadline expires / the engine fails it — the typed [`ServeError`]
    /// says which; `?` still converts to `anyhow::Error` at call sites).
    pub fn wait(self) -> Result<Response, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Dropped),
        }
    }
}

#[derive(Default)]
struct StatsInner {
    batches: AtomicU64,
    requests: AtomicU64,
    rows: AtomicU64,
    steady_repacks: AtomicU64,
    timeouts: AtomicU64,
}

/// Engine counters (monotonic since [`ServeEngine::start`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeStats {
    /// Forward sweeps run (one per flushed batch; an idle deadline never
    /// counts — empty flushes do not exist).
    pub batches: u64,
    /// Requests answered (each may carry several sample rows).
    pub requests: u64,
    /// Sample rows served across all batches.
    pub rows: u64,
    /// `PackedMat` repacks observed in every batch **after** the first
    /// one of each loaded model generation.  Frozen serving weights must
    /// keep this at 0 — the serving face of `packs_per_forward == 0`.
    pub steady_repacks: u64,
    /// Requests rejected with [`ServeError::Timeout`] (deadline passed
    /// in the queue or waiting on the model lock).  Disjoint from
    /// `requests`, which counts answered requests only.
    pub timeouts: u64,
}

/// The engine: an intake queue plus one batcher thread driving a
/// registry-resident model.  Dropping the engine closes the queue,
/// drains in-flight work, and joins the thread.
pub struct ServeEngine {
    queue: Arc<BoundedQueue<Request>>,
    batcher: Option<JoinHandle<()>>,
    stats: Arc<StatsInner>,
    max_batch: usize,
    sample_in: usize,
    /// Per-request deadline span (`None` = timeouts disabled).
    timeout: Option<Duration>,
}

impl ServeEngine {
    /// Start serving `model` (a [`ModelRegistry`] key).  The effective
    /// `max_batch` is `cfg.max_batch` clamped to the model's net batch.
    pub fn start(
        registry: Arc<ModelRegistry>,
        model: &str,
        cfg: ServeConfig,
    ) -> Result<ServeEngine> {
        let entry = registry.current(model).with_context(|| format!("no model '{model}'"))?;
        let (sample_in, net_batch) = {
            let m = entry.lock().unwrap();
            (m.sample_in(), m.batch())
        };
        drop(entry);
        let max_batch = cfg.max_batch.clamp(1, net_batch);
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let stats = Arc::new(StatsInner::default());
        let delay = Duration::from_micros(cfg.max_delay_us);
        let timeout = (cfg.timeout_us > 0).then(|| Duration::from_micros(cfg.timeout_us));
        let threads = cfg.threads;
        let batcher = {
            let queue = Arc::clone(&queue);
            let stats = Arc::clone(&stats);
            let name = model.to_string();
            // LINT-ALLOW: thread-spawn — long-lived batcher loop; the
            // PHAST pool only runs bounded region jobs.
            std::thread::Builder::new()
                .name("phast-serve".into())
                .spawn(move || {
                    let run = || batcher_loop(&queue, &registry, &name, max_batch, delay, &stats);
                    match threads {
                        // `with_threads` is thread-local: applying it here
                        // pins the pool width for every forward this
                        // batcher dispatches, without touching the process
                        // default other clients see.
                        Some(t) => par::with_threads(t, run),
                        None => run(),
                    }
                })
                .context("spawning the serve batcher thread")?
        };
        Ok(ServeEngine { queue, batcher: Some(batcher), stats, max_batch, sample_in, timeout })
    }

    /// Enqueue `samples` (one or more concatenated input rows) for the
    /// next batch.  Returns immediately: the [`Pending`] resolves when
    /// the batch containing the request completes.  Errors are the
    /// admission checks — nothing is queued on `Err`.
    pub fn submit(&self, samples: Vec<f32>) -> Result<Pending, SubmitError> {
        if samples.is_empty() || samples.len() % self.sample_in != 0 {
            return Err(SubmitError::BadLength { len: samples.len(), sample_in: self.sample_in });
        }
        let rows = samples.len() / self.sample_in;
        if rows > self.max_batch {
            return Err(SubmitError::TooLarge { rows, max_batch: self.max_batch });
        }
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let req = Request {
            samples,
            rows,
            tx,
            enqueued: now,
            deadline: self.timeout.map(|t| now + t),
        };
        match self.queue.push(req) {
            Ok(()) => Ok(Pending { rx }),
            Err(PushError::Full(_)) => Err(SubmitError::QueueFull),
            Err(PushError::Closed(_)) => Err(SubmitError::Closed),
        }
    }

    /// Effective max samples per batch (knob clamped to the net batch).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Requests currently waiting in the intake queue (excludes the
    /// batch being assembled or executed by the batcher).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Floats per input sample row the served model expects.
    pub fn sample_in(&self) -> usize {
        self.sample_in
    }

    /// Current engine counters.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            batches: self.stats.batches.load(Ordering::Relaxed),
            requests: self.stats.requests.load(Ordering::Relaxed),
            rows: self.stats.rows.load(Ordering::Relaxed),
            steady_repacks: self.stats.steady_repacks.load(Ordering::Relaxed),
            timeouts: self.stats.timeouts.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting requests, serve what is queued, and join the
    /// batcher.  Idempotent; also invoked by `Drop`.
    pub fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The batcher: block for the first request, coalesce until the batch is
/// full or the first request's deadline passes, run one forward, fan the
/// output views back out.  Exits when the queue is closed and drained.
fn batcher_loop(
    queue: &BoundedQueue<Request>,
    registry: &ModelRegistry,
    name: &str,
    max_batch: usize,
    delay: Duration,
    stats: &StatsInner,
) {
    // The model generation whose warm-up batch (the one allowed to pack
    // weights) already ran; repacks in later batches of the same
    // generation count as steady-state violations.
    let mut warmed: Option<*const Mutex<Model>> = None;
    loop {
        let first = match queue.pop_blocking() {
            Some(r) => r,
            None => return,
        };
        // Already expired in the queue?  Reject without anchoring a
        // batch on it (the post-lock sweep below would catch it too,
        // but this path never touches the model).
        if let Some(d) = first.deadline {
            let now = Instant::now();
            if d <= now {
                stats.timeouts.fetch_add(1, Ordering::Relaxed);
                let waited_us = now.duration_since(first.enqueued).as_micros() as u64;
                let _ = first.tx.send(Err(ServeError::Timeout { waited_us }));
                continue;
            }
        }
        // Deadline anchored at the oldest request's enqueue time: if the
        // queue is backed up past the delay already, flush immediately.
        let deadline = first.enqueued + delay;
        let mut rows = first.rows;
        let mut reqs = vec![first];
        while rows < max_batch {
            match queue.pop_if_before(deadline, |r| rows + r.rows <= max_batch) {
                PopOutcome::Item(r) => {
                    rows += r.rows;
                    reqs.push(r);
                }
                // DoesNotFit: the head request belongs to the next batch.
                // Deadline/Closed: flush what we have.
                _ => break,
            }
        }

        // Batch-granular model resolution: this Arc is held for the whole
        // batch, so a concurrent hot reload cannot change weights under a
        // running forward.
        let model = match registry.current(name) {
            Some(m) => m,
            None => {
                for r in &reqs {
                    let _ = r.tx.send(Err(ServeError::Engine(format!(
                        "model '{name}' unregistered"
                    ))));
                }
                continue;
            }
        };
        let (result, width, reqs, rows) = {
            let mut m = model.lock().unwrap();
            // Expiry sweep AFTER the lock is ours: time spent blocked on
            // a wedged model counts against each request's deadline, and
            // an expired request must not ride the (now late) batch.
            let now = Instant::now();
            let mut live = Vec::with_capacity(reqs.len());
            for r in reqs {
                match r.deadline {
                    Some(d) if d <= now => {
                        stats.timeouts.fetch_add(1, Ordering::Relaxed);
                        let waited_us = now.duration_since(r.enqueued).as_micros() as u64;
                        let _ = r.tx.send(Err(ServeError::Timeout { waited_us }));
                    }
                    _ => live.push(r),
                }
            }
            if live.is_empty() {
                continue; // whole batch expired; nothing to forward
            }
            let rows: usize = live.iter().map(|r| r.rows).sum();
            let mut samples = Vec::with_capacity(live.iter().map(|r| r.samples.len()).sum());
            for r in &live {
                samples.extend_from_slice(&r.samples);
            }
            // Packing happens on the dispatching thread (this one), so
            // the thread-local repack counter isolates this batch's packs
            // from any other pool client in the process.
            let packs_before = gemm::repack_count();
            let out = m.forward_batch(&samples, rows);
            let packs = gemm::repack_count() - packs_before;
            match warmed {
                Some(p) if p == Arc::as_ptr(&model) => {
                    stats.steady_repacks.fetch_add(packs, Ordering::Relaxed);
                }
                _ => warmed = Some(Arc::as_ptr(&model)),
            }
            (out, m.sample_out(), live, rows)
        };
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        stats.rows.fetch_add(rows as u64, Ordering::Relaxed);
        match result {
            Ok(out) => {
                let batch = Arc::new(out);
                let done = Instant::now();
                let mut row0 = 0;
                for r in &reqs {
                    let resp = Response {
                        batch: Arc::clone(&batch),
                        row0,
                        rows: r.rows,
                        width,
                        latency: done.duration_since(r.enqueued),
                    };
                    row0 += r.rows;
                    // A client that dropped its Pending is not an error.
                    let _ = r.tx.send(Ok(resp));
                }
            }
            Err(e) => {
                let err = ServeError::Engine(format!("{e:#}"));
                for r in &reqs {
                    let _ = r.tx.send(Err(err.clone()));
                }
            }
        }
    }
}
