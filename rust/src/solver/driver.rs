//! `TrainDriver` — the fault-tolerant training loop around
//! [`Solver::solve`]'s step cycle: periodic crash-safe checkpoints, exact
//! resume from the newest valid snapshot, worker-panic recovery through
//! the self-healing pool, and a divergence watchdog that rolls non-finite
//! losses back to the last good snapshot under a bounded retry budget.
//!
//! The contract (verified in `tests/training_e2e.rs` and CI): a run that
//! crashes — or recovers in-process — and resumes from a snapshot
//! finishes **bitwise-identical** to an uninterrupted run at the same
//! thread count, because a snapshot captures everything the trajectory
//! depends on: parameters, momentum history, the iteration counter, and
//! the data-pipeline cursors.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::ops::par;

use super::{snapshot, Solver};

/// Policy knobs for [`TrainDriver`]; [`DriverConfig::from_env`] reads the
/// `PHAST_SNAPSHOT_*` environment (see `docs/FAULT_TOLERANCE.md`).
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// Snapshot every N completed iterations (0 disables checkpointing,
    /// and with it rollback recovery).  Default 50.
    pub snapshot_every: usize,
    /// Keep the newest K checkpoints (0 = keep all).  Default 3.
    pub keep: usize,
    /// Checkpoint directory.
    pub dir: PathBuf,
    /// How many rollbacks (worker panic or non-finite loss) the driver
    /// absorbs before aborting with full context.  Default 2.
    pub recover_budget: usize,
}

impl DriverConfig {
    /// Defaults with an explicit checkpoint directory.
    pub fn new(dir: impl Into<PathBuf>) -> DriverConfig {
        DriverConfig { snapshot_every: 50, keep: 3, dir: dir.into(), recover_budget: 2 }
    }

    /// Read the `PHAST_SNAPSHOT_EVERY` / `PHAST_SNAPSHOT_KEEP` /
    /// `PHAST_SNAPSHOT_DIR` knobs, falling back to `default_dir` and the
    /// [`DriverConfig::new`] defaults.
    pub fn from_env(default_dir: &str) -> DriverConfig {
        fn num(var: &str, default: usize) -> usize {
            std::env::var(var)
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .unwrap_or(default)
        }
        // LINT-ALLOW: env-read — driver config is sampled once per
        // `from_env` call so restarted drivers see updated values.
        let dir = std::env::var("PHAST_SNAPSHOT_DIR").unwrap_or_else(|_| default_dir.to_string());
        DriverConfig {
            snapshot_every: num("PHAST_SNAPSHOT_EVERY", 50),
            keep: num("PHAST_SNAPSHOT_KEEP", 3),
            dir: PathBuf::from(dir),
            recover_budget: 2,
        }
    }
}

/// Render a caught panic payload for error context.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Crash-safe wrapper around a [`Solver`]: see the module docs.
pub struct TrainDriver {
    /// The wrapped solver (public so callers can inspect weights/losses).
    pub solver: Solver,
    cfg: DriverConfig,
    rollbacks: usize,
}

impl TrainDriver {
    /// Wrap `solver` under `cfg`'s checkpoint/recovery policy.
    pub fn new(solver: Solver, cfg: DriverConfig) -> TrainDriver {
        TrainDriver { solver, cfg, rollbacks: 0 }
    }

    /// Rollbacks absorbed so far (worker panics + divergence).
    pub fn rollbacks(&self) -> usize {
        self.rollbacks
    }

    /// The checkpoint directory in use.
    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    /// Resume from the newest valid snapshot in the checkpoint directory,
    /// if one exists: corrupt or truncated candidates are skipped loudly
    /// and the next older one is tried.  Returns the path restored from,
    /// or `None` for a fresh start.
    pub fn resume(&mut self) -> Result<Option<PathBuf>> {
        snapshot::find_latest_valid(&mut self.solver, &self.cfg.dir)
    }

    /// Save a rotated checkpoint at the current iteration.
    pub fn checkpoint(&mut self) -> Result<PathBuf> {
        snapshot::save_checkpoint(&mut self.solver, &self.cfg.dir, self.cfg.keep)
    }

    /// Roll back to the newest valid snapshot after `cause`, consuming
    /// one unit of the recovery budget; aborts with full context when the
    /// budget is exhausted or no snapshot can be loaded.
    fn rollback(&mut self, cause: &str) -> Result<()> {
        if self.rollbacks >= self.cfg.recover_budget {
            bail!(
                "training aborted: {cause}; recovery budget exhausted \
                 ({} rollback(s) used of {}), snapshots in {:?}",
                self.rollbacks,
                self.cfg.recover_budget,
                self.cfg.dir
            );
        }
        self.rollbacks += 1;
        let loaded = snapshot::find_latest_valid(&mut self.solver, &self.cfg.dir)
            .with_context(|| format!("rolling back after: {cause}"))?;
        let Some(path) = loaded else {
            bail!("training aborted: {cause}; no valid snapshot in {:?} to roll back to", self.cfg.dir);
        };
        // Drop log entries past the restored iteration so the replayed
        // stretch does not appear twice in the loss curve.
        let restored = self.solver.iter();
        self.solver.log.retain(|e| e.iter < restored);
        eprintln!(
            "WARNING: recovered from {cause}: rolled back to {path:?} (iter {restored}, \
             rollback {}/{})",
            self.rollbacks, self.cfg.recover_budget
        );
        Ok(())
    }

    /// Train until the solver reaches `total_iters`, checkpointing every
    /// `snapshot_every` completed iterations (plus once at iteration 0,
    /// so rollback always has a floor, and once at the end).
    ///
    /// Recovery: a worker panic during a step is caught, the pool is
    /// verified/healed ([`par::pool_heal`]), and the run rolls back to
    /// the newest valid snapshot; a non-finite loss triggers the same
    /// rollback.  Either path consumes recovery budget and the run aborts
    /// with full context when it is exhausted.
    pub fn run(&mut self, total_iters: usize) -> Result<()> {
        let snapshotting = self.cfg.snapshot_every > 0;
        if snapshotting && self.solver.iter() == 0 {
            self.checkpoint().context("initial checkpoint")?;
        }
        while self.solver.iter() < total_iters {
            let at = self.solver.iter();
            let outcome = catch_unwind(AssertUnwindSafe(|| self.solver.step()));
            match outcome {
                Err(payload) => {
                    let healed = par::pool_heal();
                    let msg = panic_message(payload.as_ref());
                    if !snapshotting {
                        bail!(
                            "worker panic at iter {at} ({msg}); checkpointing disabled, \
                             cannot roll back (pool healed: {healed} worker(s) respawned)"
                        );
                    }
                    self.rollback(&format!(
                        "worker panic at iter {at} ({msg}); pool healed \
                         ({healed} worker(s) respawned)"
                    ))?;
                }
                Ok(Err(e)) => {
                    return Err(e.context(format!("solver step failed at iter {at}")));
                }
                Ok(Ok(loss)) if !loss.is_finite() => {
                    if !snapshotting {
                        bail!(
                            "non-finite loss {loss} at iter {at}; checkpointing disabled, \
                             cannot roll back"
                        );
                    }
                    self.rollback(&format!("non-finite loss {loss} at iter {at}"))?;
                }
                Ok(Ok(_)) => {
                    let done = self.solver.iter();
                    if snapshotting
                        && (done % self.cfg.snapshot_every == 0 || done == total_iters)
                    {
                        self.checkpoint()
                            .with_context(|| format!("checkpoint at iter {done}"))?;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Net;
    use crate::ops::fault;
    use crate::proto::{presets, NetConfig, SolverConfig};

    fn solver() -> Solver {
        let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER).unwrap();
        cfg.display = 0;
        let net =
            Net::from_config(NetConfig::from_text(presets::LENET_MNIST).unwrap(), 7).unwrap();
        Solver::new(cfg, net)
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("phast_caffe_driver_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn final_weights(s: &Solver) -> Vec<f32> {
        s.net
            .params()
            .into_iter()
            .flat_map(|p| p.data().as_slice().to_vec())
            .collect()
    }

    #[test]
    fn driver_trains_and_checkpoints() {
        let dir = fresh_dir("plain");
        let mut cfg = DriverConfig::new(&dir);
        cfg.snapshot_every = 3;
        cfg.keep = 2;
        let mut d = TrainDriver::new(solver(), cfg);
        d.run(7).unwrap();
        assert_eq!(d.solver.iter(), 7);
        // iter 0 + 3 + 6 + final 7, pruned to the newest 2.
        let latest = std::fs::read_to_string(dir.join("LATEST")).unwrap();
        assert_eq!(latest.trim(), "snap_00000007.pcss");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nan_watchdog_rolls_back_and_matches_reference() {
        let dir_ref = fresh_dir("nanref");
        let mut cfg = DriverConfig::new(&dir_ref);
        cfg.snapshot_every = 4;
        let mut reference = TrainDriver::new(solver(), cfg.clone());
        reference.run(10).unwrap();

        let dir_f = fresh_dir("nanrun");
        cfg.dir.clone_from(&dir_f);
        let mut faulty = TrainDriver::new(solver(), cfg);
        fault::with_faults("nan@loss=7", || faulty.run(10)).unwrap();
        assert_eq!(faulty.rollbacks(), 1);
        assert_eq!(final_weights(&reference.solver), final_weights(&faulty.solver));
        assert_eq!(
            reference.solver.log.iter().map(|e| e.loss).collect::<Vec<_>>(),
            faulty.solver.log.iter().map(|e| e.loss).collect::<Vec<_>>(),
        );
        std::fs::remove_dir_all(&dir_ref).ok();
        std::fs::remove_dir_all(&dir_f).ok();
    }

    #[test]
    fn persistent_divergence_exhausts_budget_with_context() {
        let dir = fresh_dir("budget");
        let mut cfg = DriverConfig::new(&dir);
        cfg.snapshot_every = 2;
        cfg.recover_budget = 2;
        let mut d = TrainDriver::new(solver(), cfg);
        // Every loss is NaN: rollback can never help.
        let err = fault::with_faults("nan@loss", || d.run(8)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("recovery budget exhausted"), "{msg}");
        assert!(msg.contains("non-finite loss"), "{msg}");
        assert_eq!(d.rollbacks(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_env_defaults() {
        // No PHAST_SNAPSHOT_* in the test environment.
        let cfg = DriverConfig::from_env("snapdir");
        assert_eq!(cfg.snapshot_every, 50);
        assert_eq!(cfg.keep, 3);
        assert_eq!(cfg.dir, PathBuf::from("snapdir"));
    }
}
