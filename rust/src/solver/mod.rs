//! SGD solver — Caffe's `SGDSolver`: momentum, weight decay, lr policies,
//! test intervals, snapshots.  Drives either the native net or (through
//! `phast::PortedNet`) the partially/fully ported ones.

mod driver;
mod snapshot;

pub use driver::{DriverConfig, TrainDriver};
pub use snapshot::{
    crc32, find_latest_valid, load_snapshot, save_checkpoint, save_snapshot, snapshot_path,
};

use std::sync::OnceLock;

use anyhow::Result;

use crate::net::Net;
use crate::ops;
use crate::proto::SolverConfig;

/// How the solver issues its SGD update regions — the `PHAST_FUSE_STEP`
/// knob.  All three modes are **bitwise equal** (same per-element
/// arithmetic, element-independent), so the knob only moves dispatch
/// count, never the training trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepFusion {
    /// Three BLAS-1 dispatches per blob (`axpy`/`axpby`/`axpy`) — the
    /// pre-fusion reference path (`PHAST_FUSE_STEP=0`).
    Unfused,
    /// One fused three-stage dispatch per blob (the default).
    PerBlob,
    /// One fused dispatch for the whole step over a flattened view of all
    /// parameter blobs (`PHAST_FUSE_STEP=1`).
    Flat,
}

/// `PHAST_FUSE_STEP`, parsed once: `0`/`off`/`unfused` → [`StepFusion::Unfused`],
/// `1`/`all`/`step`/`flat` → [`StepFusion::Flat`], anything else (including
/// unset or `blob`) → [`StepFusion::PerBlob`].
pub fn step_fusion() -> StepFusion {
    static MODE: OnceLock<StepFusion> = OnceLock::new();
    *MODE.get_or_init(|| match std::env::var("PHAST_FUSE_STEP") {
        Ok(v) => match v.trim() {
            "0" | "off" | "unfused" => StepFusion::Unfused,
            "1" | "all" | "step" | "flat" => StepFusion::Flat,
            _ => StepFusion::PerBlob,
        },
        Err(_) => StepFusion::PerBlob,
    })
}

/// Whether the fused SGD stages are separated by barriers — the
/// `PHAST_FUSE_UNSYNC` knob.  The SGD chain is element-local in every
/// stage, so both settings are **bitwise equal** at every thread count;
/// the knob only removes two barrier crossings per fused region (the
/// `stage_barrier` price `benches/fusion.rs` measures).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepSync {
    /// Inter-stage barriers, [`ops::sgd_update_fused`] — the reference
    /// path (`PHAST_FUSE_UNSYNC=0`).
    Barrier,
    /// Barrier-free, [`ops::sgd_update_fused_unsynced`] (the default):
    /// sound because every SGD stage touches only the worker's own range.
    Unsynced,
}

/// `PHAST_FUSE_UNSYNC`, parsed once: `0`/`off`/`barrier` →
/// [`StepSync::Barrier`], anything else (including unset) →
/// [`StepSync::Unsynced`].  Only consulted for the fused modes; the
/// unfused reference has no stages to (un)synchronize.
pub fn step_sync() -> StepSync {
    static MODE: OnceLock<StepSync> = OnceLock::new();
    *MODE.get_or_init(|| match std::env::var("PHAST_FUSE_UNSYNC") {
        Ok(v) => match v.trim() {
            "0" | "off" | "barrier" => StepSync::Barrier,
            _ => StepSync::Unsynced,
        },
        Err(_) => StepSync::Unsynced,
    })
}

/// Training history entry.
#[derive(Clone, Copy, Debug)]
pub struct IterStat {
    pub iter: usize,
    pub loss: f32,
    pub lr: f32,
}

/// Caffe SGDSolver over a native [`Net`].
pub struct Solver {
    pub config: SolverConfig,
    pub net: Net,
    /// Momentum buffers, one per parameter blob.
    history: Vec<Vec<f32>>,
    iter: usize,
    pub log: Vec<IterStat>,
    /// Per-solver override of the process-wide [`step_fusion`] mode
    /// (benches and the fused-vs-unfused property tests set this).
    step_fusion: Option<StepFusion>,
    /// Per-solver override of the process-wide [`step_sync`] mode
    /// (the unsynced-vs-barrier property tests set this).
    step_sync: Option<StepSync>,
}

impl Solver {
    pub fn new(config: SolverConfig, mut net: Net) -> Solver {
        let history = net
            .params_mut()
            .iter()
            .map(|p| vec![0.0f32; p.count()])
            .collect();
        Solver { config, net, history, iter: 0, log: vec![], step_fusion: None, step_sync: None }
    }

    /// Force this solver's SGD-update fusion mode, overriding the
    /// process-wide `PHAST_FUSE_STEP` knob (all modes are bitwise equal).
    pub fn set_step_fusion(&mut self, mode: StepFusion) {
        self.step_fusion = Some(mode);
    }

    /// Force this solver's fused-stage synchronization mode, overriding
    /// the process-wide `PHAST_FUSE_UNSYNC` knob (both modes are bitwise
    /// equal — the SGD stages are element-local).
    pub fn set_step_sync(&mut self, sync: StepSync) {
        self.step_sync = Some(sync);
    }

    pub fn iter(&self) -> usize {
        self.iter
    }

    /// Current learning rate under the configured policy.
    pub fn lr(&self) -> f32 {
        self.config.lr_policy.lr_at(self.config.base_lr, self.iter)
    }

    /// One iteration: forward, backward, SGD update.  Returns the loss.
    pub fn step(&mut self) -> Result<f32> {
        let loss = self.forward_backward()?;
        self.apply_step(loss);
        Ok(loss)
    }

    /// The gradient half of [`Solver::step`]: forward + backward at the
    /// current iteration, leaving the parameter diffs populated and the
    /// update **not yet applied**.  This is the seam data-parallel
    /// training reduces across (`runtime::dist`): ranks exchange diffs
    /// here, then each applies the identical [`Solver::apply_step`].
    pub fn forward_backward(&mut self) -> Result<f32> {
        ops::fault::begin_iter(self.iter as u64);
        self.net.zero_param_diffs();
        let loss = self.net.forward()?.unwrap_or(0.0);
        let loss = ops::fault::corrupt_value("loss", loss);
        self.net.backward()?;
        Ok(loss)
    }

    /// The update half of [`Solver::step`]: apply the SGD update from the
    /// current parameter diffs, log `loss` for this iteration, and
    /// advance the iteration counter.  `step()` is exactly
    /// `forward_backward()` followed by `apply_step(loss)`.
    pub fn apply_step(&mut self, loss: f32) {
        self.apply_update();
        let lr = self.lr();
        self.log.push(IterStat { iter: self.iter, loss, lr });
        self.iter += 1;
    }

    fn apply_update(&mut self) {
        let lr = self.lr();
        let momentum = self.config.momentum;
        let decay = self.config.weight_decay;
        let mode = self.step_fusion.unwrap_or_else(step_fusion);
        let sync = self.step_sync.unwrap_or_else(step_sync);
        apply_sgd_update_sync(
            self.net.params_mut(),
            &mut self.history,
            lr,
            momentum,
            decay,
            mode,
            sync,
        );
    }

    /// Run `n` iterations, printing progress every `display` steps.
    pub fn solve(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            let loss = self.step()?;
            if self.config.display > 0 && self.iter % self.config.display == 0 {
                println!("iter {} loss {:.4} lr {:.5}", self.iter, loss, self.lr());
            }
        }
        Ok(())
    }

    /// Mean loss/accuracy over `test_iter` fresh batches (Caffe TEST phase).
    pub fn test(&mut self, test_iter: usize) -> Result<(f32, f32)> {
        let mut loss_acc = 0.0f32;
        let mut acc_acc = 0.0f32;
        for _ in 0..test_iter {
            let loss = self.net.forward()?.unwrap_or(0.0);
            loss_acc += loss;
            if let Some(b) = self.net.blob("accuracy") {
                acc_acc += b.data().as_slice()[0];
            }
        }
        Ok((loss_acc / test_iter as f32, acc_acc / test_iter as f32))
    }

    /// Direct access to momentum history (snapshots).
    pub fn history(&self) -> &[Vec<f32>] {
        &self.history
    }

    pub fn history_mut(&mut self) -> &mut Vec<Vec<f32>> {
        &mut self.history
    }

    pub fn set_iter(&mut self, it: usize) {
        self.iter = it;
    }
}

/// Caffe's momentum-SGD update, shared by the native [`Solver`] and the
/// ported solver in `phast::`:
///   v = momentum * v + lr * (grad + weight_decay * w);  w -= v
/// (identical to the fused artifact's update — see model.make_step_fn).
///
/// Implemented as Caffe `SGDSolver`'s exact BLAS call sequence per
/// parameter blob, each call chunk-parallel over the blob through
/// [`ops::axpy`]/[`ops::axpby`]:
///
/// ```text
/// diff += decay * data           // caffe_axpy   (L2 regularization)
/// hist  = lr * diff + momentum * hist  // caffe_cpu_axpby
/// data -= hist                   // caffe_axpy   (Blob::Update)
/// ```
///
/// Element-wise arithmetic is identical to the fused scalar loop
/// ([`apply_sgd_update_slices`], the serial reference), and the chunked
/// kernels are bitwise thread-count invariant, so training trajectories
/// do not depend on `PHAST_NUM_THREADS`.  Note Caffe semantics: the blob
/// `diff` holds the *regularized* gradient after this call.
///
/// Region structure follows the process-wide [`step_fusion`] mode
/// (`PHAST_FUSE_STEP`): by default each blob's three BLAS-1 calls run as
/// **one** fused three-stage dispatch ([`ops::sgd_update_fused`]); see
/// [`apply_sgd_update_mode`] to force a mode explicitly.
pub fn apply_sgd_update(
    params: Vec<&mut crate::tensor::Blob>,
    history: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    apply_sgd_update_mode(params, history, lr, momentum, decay, step_fusion());
}

/// [`apply_sgd_update`] with an explicit fusion mode.  All modes are
/// bitwise equal at every thread count; they differ only in how many
/// parallel regions the step issues (3 per blob / 1 per blob / 1 total).
/// Stage synchronization follows the process-wide [`step_sync`] knob.
pub fn apply_sgd_update_mode(
    params: Vec<&mut crate::tensor::Blob>,
    history: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    decay: f32,
    mode: StepFusion,
) {
    apply_sgd_update_sync(params, history, lr, momentum, decay, mode, step_sync());
}

/// [`apply_sgd_update_mode`] with an explicit stage-synchronization mode
/// (barrier vs unsynced — bitwise equal, see [`StepSync`]).  `sync` is
/// ignored by [`StepFusion::Unfused`], which has no fused stages.
pub fn apply_sgd_update_sync(
    params: Vec<&mut crate::tensor::Blob>,
    history: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    decay: f32,
    mode: StepFusion,
    sync: StepSync,
) {
    match mode {
        StepFusion::Unfused => {
            for (p, hist) in params.into_iter().zip(history.iter_mut()) {
                let (data, diff) = p.data_mut_and_diff_mut();
                let w = data.as_mut_slice();
                let g = diff.as_mut_slice();
                ops::axpy(decay, w, g);
                ops::axpby(lr, g, momentum, hist);
                ops::axpy(-1.0, hist, w);
            }
        }
        StepFusion::PerBlob => {
            let update: fn(&mut [f32], &mut [f32], &mut [f32], f32, f32, f32) = match sync {
                StepSync::Barrier => ops::sgd_update_fused,
                StepSync::Unsynced => ops::sgd_update_fused_unsynced,
            };
            for (p, hist) in params.into_iter().zip(history.iter_mut()) {
                let (data, diff) = p.data_mut_and_diff_mut();
                update(data.as_mut_slice(), diff.as_mut_slice(), hist, lr, momentum, decay);
            }
        }
        StepFusion::Flat => {
            let mut views: Vec<ops::math::SgdParamView<'_>> = Vec::with_capacity(params.len());
            for (p, hist) in params.into_iter().zip(history.iter_mut()) {
                let (data, diff) = p.data_mut_and_diff_mut();
                views.push((data.as_mut_slice(), diff.as_mut_slice(), hist.as_mut_slice()));
            }
            match sync {
                StepSync::Barrier => ops::sgd_update_fused_flat(views, lr, momentum, decay),
                StepSync::Unsynced => {
                    ops::sgd_update_fused_flat_unsynced(views, lr, momentum, decay)
                }
            }
        }
    }
}

/// Slice-level SGD update (testable without blobs): same math as
/// [`apply_sgd_update`] for one parameter.
pub fn apply_sgd_update_slices(
    w: &mut [f32],
    g: &[f32],
    hist: &mut [f32],
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    for i in 0..w.len() {
        let grad = g[i] + decay * w[i];
        let v = momentum * hist[i] + lr * grad;
        hist[i] = v;
        w[i] -= v;
    }
}

/// Smoothed loss trace (Caffe-style display smoothing) for reports.
pub fn smooth_losses(log: &[IterStat], window: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(log.len());
    for i in 0..log.len() {
        let lo = i.saturating_sub(window - 1);
        let s: f32 = log[lo..=i].iter().map(|e| e.loss).sum();
        out.push(s / (i - lo + 1) as f32);
    }
    out
}

/// Exercise the solver math without a net: one manual SGD step.
pub fn sgd_update_reference(
    w: &mut [f32],
    g: &[f32],
    hist: &mut [f32],
    lr: f32,
    momentum: f32,
    decay: f32,
) {
    for i in 0..w.len() {
        let grad = g[i] + decay * w[i];
        let v = momentum * hist[i] + lr * grad;
        hist[i] = v;
        w[i] -= v;
    }
    let _ = ops::axpy; // keep ops linked for doc example parity
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Net;
    use crate::proto::{presets, LrPolicy, NetConfig, SolverConfig};

    fn mini_solver(max_iter: usize) -> Solver {
        let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER).unwrap();
        cfg.max_iter = max_iter;
        cfg.display = 0;
        let net = Net::from_config(
            NetConfig::from_text(presets::LENET_MNIST).unwrap(),
            1,
        )
        .unwrap();
        Solver::new(cfg, net)
    }

    #[test]
    fn loss_decreases_over_training() {
        let mut s = mini_solver(30);
        let mut losses = vec![];
        for _ in 0..30 {
            losses.push(s.step().unwrap());
        }
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[25..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head,
            "loss did not decrease: head {head:.3} tail {tail:.3}"
        );
    }

    #[test]
    fn lr_policy_inv_decays() {
        let s = mini_solver(1);
        let lr0 = s.config.lr_policy.lr_at(s.config.base_lr, 0);
        let lr1k = s.config.lr_policy.lr_at(s.config.base_lr, 1000);
        assert!(lr1k < lr0);
    }

    #[test]
    fn sgd_reference_matches_momentum_math() {
        let mut w = vec![1.0f32];
        let mut h = vec![0.0f32];
        sgd_update_reference(&mut w, &[0.5], &mut h, 0.1, 0.9, 0.0);
        assert!((w[0] - 0.95).abs() < 1e-6);
        assert!((h[0] - 0.05).abs() < 1e-6);
        sgd_update_reference(&mut w, &[0.5], &mut h, 0.1, 0.9, 0.0);
        // v = 0.9*0.05 + 0.1*0.5 = 0.095
        assert!((h[0] - 0.095).abs() < 1e-6);
    }

    #[test]
    fn fixed_policy_is_constant() {
        assert_eq!(LrPolicy::Fixed.lr_at(0.3, 12345), 0.3);
    }

    #[test]
    fn smoothing_works() {
        let log: Vec<IterStat> = (0..4)
            .map(|i| IterStat { iter: i, loss: i as f32, lr: 0.1 })
            .collect();
        let sm = smooth_losses(&log, 2);
        assert_eq!(sm, vec![0.0, 0.5, 1.5, 2.5]);
    }
}
