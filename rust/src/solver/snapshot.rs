//! Snapshot save/restore — Caffe's `.caffemodel`/`.solverstate` analog in
//! one little-endian binary file: params + momentum history + iteration.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Solver;

const MAGIC: &[u8; 4] = b"PCSS";
const VERSION: u32 = 1;

/// Serialize solver state (params, momentum, iter) to `path`.
pub fn save_snapshot(solver: &mut Solver, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let iter = solver.iter();
    let hist_flat: Vec<Vec<f32>> = solver.history().to_vec();
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(iter as u64).to_le_bytes())?;
    let params = solver.net.params_mut();
    w.write_all(&(params.len() as u32).to_le_bytes())?;
    for (p, h) in params.iter().zip(&hist_flat) {
        let name = p.name().as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(p.count() as u64).to_le_bytes())?;
        for v in p.data().as_slice() {
            w.write_all(&v.to_le_bytes())?;
        }
        for v in h {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Restore solver state saved by [`save_snapshot`].  Parameter names and
/// sizes must match the current net.
pub fn load_snapshot(solver: &mut Solver, path: &Path) -> Result<()> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut m4 = [0u8; 4];
    r.read_exact(&mut m4)?;
    if &m4 != MAGIC {
        bail!("{path:?} is not a phast-caffe snapshot");
    }
    r.read_exact(&mut m4)?;
    if u32::from_le_bytes(m4) != VERSION {
        bail!("unsupported snapshot version");
    }
    let mut u8buf = [0u8; 8];
    r.read_exact(&mut u8buf)?;
    let iter = u64::from_le_bytes(u8buf) as usize;
    r.read_exact(&mut m4)?;
    let nparams = u32::from_le_bytes(m4) as usize;

    // Collect into temporaries first to avoid holding borrows.
    let mut entries: Vec<(String, Vec<f32>, Vec<f32>)> = Vec::with_capacity(nparams);
    for _ in 0..nparams {
        r.read_exact(&mut m4)?;
        let nlen = u32::from_le_bytes(m4) as usize;
        let mut nbuf = vec![0u8; nlen];
        r.read_exact(&mut nbuf)?;
        let name = String::from_utf8(nbuf)?;
        r.read_exact(&mut u8buf)?;
        let count = u64::from_le_bytes(u8buf) as usize;
        let mut data = vec![0f32; count];
        let mut hist = vec![0f32; count];
        let mut fbuf = vec![0u8; count * 4];
        r.read_exact(&mut fbuf)?;
        for (d, ch) in data.iter_mut().zip(fbuf.chunks_exact(4)) {
            *d = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        r.read_exact(&mut fbuf)?;
        for (d, ch) in hist.iter_mut().zip(fbuf.chunks_exact(4)) {
            *d = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        entries.push((name, data, hist));
    }

    {
        let params = solver.net.params_mut();
        if params.len() != entries.len() {
            bail!(
                "snapshot has {} params, net has {}",
                entries.len(),
                params.len()
            );
        }
        for (p, (name, data, _)) in params.into_iter().zip(&entries) {
            if p.name() != name {
                bail!("param name mismatch: snapshot '{}' vs net '{}'", name, p.name());
            }
            if p.count() != data.len() {
                bail!("param '{}' size mismatch", name);
            }
            p.data_mut().as_mut_slice().copy_from_slice(data);
        }
    }
    {
        let hist = solver.history_mut();
        for (h, (_, _, hdata)) in hist.iter_mut().zip(&entries) {
            h.copy_from_slice(hdata);
        }
    }
    solver.set_iter(iter);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Net;
    use crate::proto::{presets, NetConfig, SolverConfig};

    fn solver() -> Solver {
        let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER).unwrap();
        cfg.display = 0;
        let net =
            Net::from_config(NetConfig::from_text(presets::LENET_MNIST).unwrap(), 1).unwrap();
        Solver::new(cfg, net)
    }

    #[test]
    fn snapshot_roundtrip_resumes_identically() {
        let dir = std::env::temp_dir().join("phast_caffe_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.pcss");

        let mut a = solver();
        for _ in 0..3 {
            a.step().unwrap();
        }
        save_snapshot(&mut a, &path).unwrap();
        let params_at_save: Vec<Vec<f32>> = a
            .net
            .params_mut()
            .iter()
            .map(|p| p.data().as_slice().to_vec())
            .collect();
        let hist_at_save = a.history().to_vec();
        a.step().unwrap(); // mutate further; snapshot must be unaffected

        let mut b = solver();
        load_snapshot(&mut b, &path).unwrap();
        assert_eq!(b.iter(), 3);
        for (p, want) in b.net.params_mut().iter().zip(&params_at_save) {
            assert_eq!(p.data().as_slice(), want.as_slice());
        }
        for (h, want) in b.history().iter().zip(&hist_at_save) {
            assert_eq!(h, want);
        }
        // And training can resume.
        let lb = b.step().unwrap();
        assert!(lb.is_finite());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_foreign_file() {
        let dir = std::env::temp_dir().join("phast_caffe_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bogus.pcss");
        std::fs::write(&path, b"nope").unwrap();
        let mut s = solver();
        assert!(load_snapshot(&mut s, &path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
