//! Crash-safe snapshot save/restore — Caffe's `.caffemodel`/`.solverstate`
//! analog, hardened for fault-tolerant training (see
//! `docs/FAULT_TOLERANCE.md`).
//!
//! # Format v2
//!
//! One little-endian binary file:
//!
//! ```text
//! "PCSS" | version: u32 = 2
//! section META: tag "META" | payload_len u64 | payload | crc32 u32
//!   payload: iter u64 | ncursors u32 | ncursors × (epoch u64, pos u64)
//! section PARM: tag "PARM" | payload_len u64 | payload | crc32 u32
//!   payload: nparams u32 | per param:
//!     name_len u32 | name | count u64 | count × f32 data | count × f32 hist
//! ```
//!
//! Every section payload carries a CRC-32 (IEEE), so truncation and
//! bit-rot are detected loudly instead of loading silently corrupted
//! weights.  The META cursors are the data-pipeline positions
//! ([`crate::net::Net::data_cursors`]) that make resume **exact**: a
//! restored run replays the same batch sequence an uninterrupted run
//! would have seen.  Version-1 files (no sections, no CRC, no cursors)
//! still load.
//!
//! # Durability
//!
//! [`save_snapshot`] writes a temp file in the target directory, flushes
//! and fsyncs it, atomically renames over the destination, then fsyncs
//! the **parent directory** — without the directory fsync the rename
//! itself can be lost on power failure, resurrecting the old file.  A
//! crash at any point leaves either the old snapshot or the new one,
//! never a torn file.  Transient IO errors are retried with exponential
//! backoff (`PHAST_SNAPSHOT_RETRY` attempts, default 3).
//! [`save_checkpoint`] layers rotation on top: `snap_<iter>.pcss`
//! naming, a `LATEST` pointer file (written through the same
//! temp + fsync + rename + dir-fsync sequence), and keep-last-K pruning.
//! [`find_latest_valid`] walks the directory newest-first and skips
//! corrupt or truncated snapshots loudly.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use crate::ops::fault;

use super::Solver;

const MAGIC: &[u8; 4] = b"PCSS";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;
const TAG_META: &[u8; 4] = b"META";
const TAG_PARM: &[u8; 4] = b"PARM";

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// Append `xs` to `out` as little-endian bytes — one buffer extension per
/// slice, not one write per value.
fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    let start = out.len();
    out.resize(start + xs.len() * 4, 0);
    for (chunk, v) in out[start..].chunks_exact_mut(4).zip(xs) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

/// Append one `tag | len | payload | crc32` section.
fn push_section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
}

/// Serialize the solver (params, momentum, iter, data cursors) as a v2
/// snapshot byte image.
fn serialize_v2(solver: &mut Solver) -> Vec<u8> {
    let iter = solver.iter() as u64;
    let cursors = solver.net.data_cursors();
    let hist: Vec<Vec<f32>> = solver.history().to_vec();

    let mut meta = Vec::with_capacity(12 + cursors.len() * 16);
    meta.extend_from_slice(&iter.to_le_bytes());
    meta.extend_from_slice(&(cursors.len() as u32).to_le_bytes());
    for &(epoch, pos) in &cursors {
        meta.extend_from_slice(&(epoch as u64).to_le_bytes());
        meta.extend_from_slice(&(pos as u64).to_le_bytes());
    }

    let params = solver.net.params_mut();
    let payload_guess: usize = params.iter().map(|p| 24 + p.count() * 8).sum();
    let mut parm = Vec::with_capacity(4 + payload_guess);
    parm.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (p, h) in params.iter().zip(&hist) {
        let name = p.name().as_bytes();
        parm.extend_from_slice(&(name.len() as u32).to_le_bytes());
        parm.extend_from_slice(name);
        parm.extend_from_slice(&(p.count() as u64).to_le_bytes());
        push_f32s(&mut parm, p.data().as_slice());
        push_f32s(&mut parm, h);
    }

    let mut out = Vec::with_capacity(8 + meta.len() + parm.len() + 32);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION_V2.to_le_bytes());
    push_section(&mut out, TAG_META, &meta);
    push_section(&mut out, TAG_PARM, &parm);
    out
}

/// `PHAST_SNAPSHOT_RETRY`: total save attempts before giving up
/// (default 3, minimum 1).  Read per call so tests and long-running
/// drivers see updates.
fn snapshot_retries() -> usize {
    // LINT-ALLOW: env-read — deliberately re-read per call (see above).
    std::env::var("PHAST_SNAPSHOT_RETRY")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(3)
}

/// Fsync the directory itself, so a rename that just landed in it
/// survives power loss.  Fsyncing the renamed *file* is not enough: the
/// new directory entry lives in the directory's own blocks, and until
/// those reach disk the rename can silently roll back to the old file.
fn sync_dir(dir: &Path) -> Result<()> {
    if dir.as_os_str().is_empty() {
        return Ok(());
    }
    let d = File::open(dir).with_context(|| format!("open dir {dir:?} for fsync"))?;
    d.sync_all().with_context(|| format!("fsync dir {dir:?}"))
}

/// One crash-safe save attempt: temp file + flush + fsync + atomic
/// rename + parent-directory fsync.  A crash at any point leaves either
/// the old snapshot or the new one — never a torn file at `path` — and
/// once this returns the rename itself is durable.
fn try_save(bytes: &[u8], path: &Path) -> Result<()> {
    fault::check_io("snapshot_save").context("snapshot save IO")?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("create dir {dir:?}"))?;
        }
    }
    let tmp = path.with_extension("pcss.tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        f.write_all(bytes).with_context(|| format!("write {tmp:?}"))?;
        f.flush()?;
        f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    if let Some(dir) = path.parent() {
        sync_dir(dir)?;
    }
    Ok(())
}

/// Durably replace `path` with `contents`: temp file + fsync + atomic
/// rename + parent-directory fsync — the same sequence snapshots use,
/// for small metadata files like the `LATEST` pointer.
fn write_durable(path: &Path, contents: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        f.write_all(contents).with_context(|| format!("write {tmp:?}"))?;
        f.flush()?;
        f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    if let Some(dir) = path.parent() {
        sync_dir(dir)?;
    }
    Ok(())
}

/// Serialize solver state (params, momentum, iter, data cursors) to
/// `path` in format v2: atomically (temp + fsync + rename) and with
/// bounded retry-with-backoff on transient IO errors.
pub fn save_snapshot(solver: &mut Solver, path: &Path) -> Result<()> {
    let bytes = serialize_v2(solver);
    let attempts = snapshot_retries();
    let mut delay = std::time::Duration::from_millis(5);
    for attempt in 1..=attempts {
        match try_save(&bytes, path) {
            Ok(()) => return Ok(()),
            Err(e) if attempt < attempts => {
                eprintln!(
                    "WARNING: snapshot save attempt {attempt}/{attempts} to {path:?} \
                     failed ({e:#}); retrying in {delay:?}"
                );
                std::thread::sleep(delay);
                delay *= 2;
            }
            Err(e) => {
                return Err(e.context(format!(
                    "saving snapshot to {path:?} failed after {attempts} attempt(s)"
                )))
            }
        }
    }
    unreachable!("retry loop returns on success or final error");
}

/// Checkpoint path for an iteration: `dir/snap_<iter, zero-padded>.pcss`
/// (zero-padding keeps lexicographic order == iteration order).
pub fn snapshot_path(dir: &Path, iter: usize) -> PathBuf {
    dir.join(format!("snap_{iter:08}.pcss"))
}

/// List `snap_*.pcss` files in `dir`, sorted oldest-first (empty when
/// the directory does not exist).
fn list_snapshots(dir: &Path) -> Vec<PathBuf> {
    let Ok(rd) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut snaps: Vec<PathBuf> = rd
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("snap_") && n.ends_with(".pcss"))
        })
        .collect();
    snaps.sort();
    snaps
}

/// Save a rotated checkpoint for the solver's current iteration into
/// `dir`: [`save_snapshot`] to `snap_<iter>.pcss`, update the `LATEST`
/// pointer file, and prune all but the newest `keep` snapshots
/// (`keep == 0` keeps everything).  Returns the checkpoint path.
pub fn save_checkpoint(solver: &mut Solver, dir: &Path, keep: usize) -> Result<PathBuf> {
    let path = snapshot_path(dir, solver.iter());
    save_snapshot(solver, &path)?;
    let name = path.file_name().expect("snapshot path has a file name");
    write_durable(
        &dir.join("LATEST"),
        format!("{}\n", name.to_string_lossy()).as_bytes(),
    )
    .with_context(|| format!("writing LATEST pointer in {dir:?}"))?;
    if keep > 0 {
        let snaps = list_snapshots(dir);
        for old in snaps.iter().take(snaps.len().saturating_sub(keep)) {
            if let Err(e) = std::fs::remove_file(old) {
                // Pruning is best-effort: a failed unlink must not abort
                // training after a durable snapshot already landed.
                eprintln!("WARNING: could not prune old snapshot {old:?}: {e}");
            }
        }
    }
    Ok(path)
}

/// Bounds-checked little-endian reader over a snapshot byte image: every
/// decode error is a contextual `Err`, never a panic.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.pos {
            bail!(
                "truncated snapshot: wanted {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let nbytes = count
            .checked_mul(4)
            .with_context(|| format!("implausible f32 count {count} (corrupt length field)"))?;
        let b = self.take(nbytes)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read one `tag | len | payload | crc` section, verifying the tag
    /// and the payload CRC.
    fn section(&mut self, tag: &[u8; 4]) -> Result<Reader<'a>> {
        let got = self.take(4)?;
        if got != tag.as_slice() {
            bail!(
                "expected {} section, found {:?}",
                String::from_utf8_lossy(tag),
                String::from_utf8_lossy(got)
            );
        }
        let len = self.u64()? as usize;
        let payload = self
            .take(len)
            .with_context(|| format!("{} section body", String::from_utf8_lossy(tag)))?;
        let want = self.u32()?;
        let have = crc32(payload);
        if have != want {
            bail!(
                "CRC mismatch in {} section: stored {want:#010x}, computed {have:#010x} \
                 (snapshot is corrupt)",
                String::from_utf8_lossy(tag)
            );
        }
        Ok(Reader { buf: payload, pos: 0 })
    }
}

/// A fully parsed snapshot, not yet applied to any solver.
struct Parsed {
    iter: usize,
    cursors: Vec<(usize, usize)>,
    /// (name, data, momentum history) per parameter blob.
    entries: Vec<(String, Vec<f32>, Vec<f32>)>,
}

/// Parse the shared per-param entry list (identical layout in v1 and the
/// v2 PARM payload).
fn parse_entries(r: &mut Reader<'_>) -> Result<Vec<(String, Vec<f32>, Vec<f32>)>> {
    let nparams = r.u32()? as usize;
    let mut entries = Vec::with_capacity(nparams.min(1024));
    for i in 0..nparams {
        let nlen = r.u32()? as usize;
        let nbuf = r.take(nlen).with_context(|| format!("param {i} name"))?;
        let name = String::from_utf8(nbuf.to_vec())
            .with_context(|| format!("param {i} name is not UTF-8"))?;
        let count = r.u64()? as usize;
        let data = r.f32s(count).with_context(|| format!("param '{name}' data"))?;
        let hist = r.f32s(count).with_context(|| format!("param '{name}' history"))?;
        entries.push((name, data, hist));
    }
    Ok(entries)
}

fn parse_snapshot(bytes: &[u8], path: &Path) -> Result<Parsed> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let magic = r.take(4)?;
    if magic != MAGIC.as_slice() {
        bail!("{path:?} is not a phast-caffe snapshot");
    }
    let version = r.u32()?;
    match version {
        VERSION_V1 => {
            // v1: unsectioned, unchecksummed, no data cursors.
            let iter = r.u64()? as usize;
            let entries = parse_entries(&mut r)?;
            Ok(Parsed { iter, cursors: Vec::new(), entries })
        }
        VERSION_V2 => {
            let mut meta = r.section(TAG_META)?;
            let iter = meta.u64()? as usize;
            let ncursors = meta.u32()? as usize;
            let mut cursors = Vec::with_capacity(ncursors.min(64));
            for _ in 0..ncursors {
                let epoch = meta.u64()? as usize;
                let pos = meta.u64()? as usize;
                cursors.push((epoch, pos));
            }
            let mut parm = r.section(TAG_PARM)?;
            let entries = parse_entries(&mut parm)?;
            Ok(Parsed { iter, cursors, entries })
        }
        v => bail!("unsupported snapshot version {v} (this build reads 1 and 2)"),
    }
}

/// Restore solver state saved by [`save_snapshot`] (format v2, with the
/// data-pipeline cursors) or by the v1 writer (params + history + iter
/// only).  Parameter names and sizes must match the current net; the
/// whole file is parsed and validated **before** any solver state is
/// mutated, so a corrupt snapshot never leaves a partial load behind.
pub fn load_snapshot(solver: &mut Solver, path: &Path) -> Result<()> {
    fault::check_io("snapshot_load").context("snapshot load IO")?;
    let bytes = std::fs::read(path).with_context(|| format!("open {path:?}"))?;
    let parsed =
        parse_snapshot(&bytes, path).with_context(|| format!("parsing snapshot {path:?}"))?;

    // Validate everything against the net before touching any state.
    if !parsed.cursors.is_empty() {
        let ndata = solver.net.data_cursors().len();
        if parsed.cursors.len() != ndata {
            bail!(
                "snapshot has {} data cursor(s), net has {} data layer(s)",
                parsed.cursors.len(),
                ndata
            );
        }
    }
    {
        let params = solver.net.params_mut();
        if params.len() != parsed.entries.len() {
            bail!(
                "snapshot has {} params, net has {}",
                parsed.entries.len(),
                params.len()
            );
        }
        for (p, (name, data, _)) in params.iter().zip(&parsed.entries) {
            if p.name() != name {
                bail!("param name mismatch: snapshot '{}' vs net '{}'", name, p.name());
            }
            if p.count() != data.len() {
                bail!(
                    "param '{}' size mismatch: snapshot {} vs net {}",
                    name,
                    data.len(),
                    p.count()
                );
            }
        }
    }

    // All checks passed: apply atomically from the parsed image.
    {
        let params = solver.net.params_mut();
        for (p, (_, data, _)) in params.into_iter().zip(&parsed.entries) {
            p.data_mut().as_mut_slice().copy_from_slice(data);
        }
    }
    {
        let hist = solver.history_mut();
        for (h, (_, _, hdata)) in hist.iter_mut().zip(&parsed.entries) {
            h.copy_from_slice(hdata);
        }
    }
    if !parsed.cursors.is_empty() {
        solver.net.seek_data_cursors(&parsed.cursors)?;
    }
    solver.set_iter(parsed.iter);
    Ok(())
}

/// Discover and load the newest **valid** snapshot in `dir`: candidates
/// are tried newest-first, and corrupt/truncated/mismatched ones are
/// skipped loudly (a warning per skip).  Returns the path loaded, or
/// `None` when the directory holds no loadable snapshot (including when
/// it does not exist yet).
pub fn find_latest_valid(solver: &mut Solver, dir: &Path) -> Result<Option<PathBuf>> {
    let snaps = list_snapshots(dir);
    for path in snaps.iter().rev() {
        match load_snapshot(solver, path) {
            Ok(()) => return Ok(Some(path.clone())),
            Err(e) => {
                eprintln!("WARNING: skipping invalid snapshot {path:?}: {e:#}");
            }
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Net;
    use crate::proto::{presets, NetConfig, SolverConfig};

    fn solver() -> Solver {
        let mut cfg = SolverConfig::from_text(presets::LENET_SOLVER).unwrap();
        cfg.display = 0;
        let net =
            Net::from_config(NetConfig::from_text(presets::LENET_MNIST).unwrap(), 1).unwrap();
        Solver::new(cfg, net)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("phast_caffe_snap_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// The legacy v1 writer, kept verbatim for back-compat testing.
    fn write_v1(solver: &mut Solver, path: &Path) {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION_V1.to_le_bytes());
        out.extend_from_slice(&(solver.iter() as u64).to_le_bytes());
        let hist = solver.history().to_vec();
        let params = solver.net.params_mut();
        out.extend_from_slice(&(params.len() as u32).to_le_bytes());
        for (p, h) in params.iter().zip(&hist) {
            let name = p.name().as_bytes();
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name);
            out.extend_from_slice(&(p.count() as u64).to_le_bytes());
            push_f32s(&mut out, p.data().as_slice());
            push_f32s(&mut out, h);
        }
        std::fs::write(path, out).unwrap();
    }

    #[test]
    fn snapshot_roundtrip_resumes_identically() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("state.pcss");

        let mut a = solver();
        for _ in 0..3 {
            a.step().unwrap();
        }
        save_snapshot(&mut a, &path).unwrap();
        let params_at_save: Vec<Vec<f32>> = a
            .net
            .params_mut()
            .iter()
            .map(|p| p.data().as_slice().to_vec())
            .collect();
        let hist_at_save = a.history().to_vec();
        let cursors_at_save = a.net.data_cursors();
        a.step().unwrap(); // mutate further; snapshot must be unaffected

        let mut b = solver();
        load_snapshot(&mut b, &path).unwrap();
        assert_eq!(b.iter(), 3);
        assert_eq!(b.net.data_cursors(), cursors_at_save);
        for (p, want) in b.net.params_mut().iter().zip(&params_at_save) {
            assert_eq!(p.data().as_slice(), want.as_slice());
        }
        for (h, want) in b.history().iter().zip(&hist_at_save) {
            assert_eq!(h, want);
        }
        // And training can resume.
        let lb = b.step().unwrap();
        assert!(lb.is_finite());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_foreign_file() {
        let dir = tmp_dir("foreign");
        let path = dir.join("bogus.pcss");
        std::fs::write(&path, b"nope").unwrap();
        let mut s = solver();
        assert!(load_snapshot(&mut s, &path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_snapshot_still_loads() {
        let dir = tmp_dir("v1");
        let path = dir.join("legacy.pcss");
        let mut a = solver();
        for _ in 0..2 {
            a.step().unwrap();
        }
        write_v1(&mut a, &path);
        let want: Vec<Vec<f32>> = a
            .net
            .params_mut()
            .iter()
            .map(|p| p.data().as_slice().to_vec())
            .collect();
        let mut b = solver();
        load_snapshot(&mut b, &path).unwrap();
        assert_eq!(b.iter(), 2);
        for (p, w) in b.net.params_mut().iter().zip(&want) {
            assert_eq!(p.data().as_slice(), w.as_slice());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_files_fail_loudly_never_panic() {
        let dir = tmp_dir("trunc");
        let path = dir.join("full.pcss");
        let mut a = solver();
        a.step().unwrap();
        save_snapshot(&mut a, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let cut_path = dir.join("cut.pcss");
        // Cut at a spread of offsets including every structural boundary
        // early in the file.
        let mut cuts: Vec<usize> = (0..64).collect();
        let step = (bytes.len() / 13).max(1);
        cuts.extend((64..bytes.len()).step_by(step));
        cuts.push(bytes.len() - 1);
        for cut in cuts {
            std::fs::write(&cut_path, &bytes[..cut]).unwrap();
            let mut s = solver();
            let err = load_snapshot(&mut s, &cut_path)
                .expect_err(&format!("truncation at {cut} must fail"));
            // Contextual: the error chain names the file.
            assert!(format!("{err:#}").contains("cut.pcss"), "error lacks context: {err:#}");
        }
        std::fs::remove_file(&cut_path).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bit_flips_are_detected() {
        let dir = tmp_dir("flip");
        let path = dir.join("full.pcss");
        let mut a = solver();
        a.step().unwrap();
        save_snapshot(&mut a, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let flip_path = dir.join("flip.pcss");
        let step = (bytes.len() / 29).max(1);
        for off in (0..bytes.len()).step_by(step) {
            let mut corrupted = bytes.clone();
            corrupted[off] ^= 0x40;
            std::fs::write(&flip_path, &corrupted).unwrap();
            let mut s = solver();
            assert!(
                load_snapshot(&mut s, &flip_path).is_err(),
                "bit flip at offset {off} loaded silently"
            );
        }
        std::fs::remove_file(&flip_path).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let dir = tmp_dir("magic");
        let path = dir.join("full.pcss");
        let mut a = solver();
        save_snapshot(&mut a, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        let p = dir.join("wm.pcss");
        std::fs::write(&p, &wrong_magic).unwrap();
        let err = load_snapshot(&mut solver(), &p).unwrap_err();
        assert!(format!("{err:#}").contains("not a phast-caffe snapshot"), "{err:#}");

        let mut wrong_version = bytes.clone();
        wrong_version[4] = 99;
        std::fs::write(&p, &wrong_version).unwrap();
        let err = load_snapshot(&mut solver(), &p).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported snapshot version"), "{err:#}");
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn name_and_size_mismatch_rejected_without_partial_load() {
        // A CIFAR snapshot must not load into a LeNet solver — and the
        // LeNet solver's params must be untouched after the failed load.
        let dir = tmp_dir("mismatch");
        let path = dir.join("cifar.pcss");
        let mut cfg = SolverConfig::from_text(presets::CIFAR_SOLVER).unwrap();
        cfg.display = 0;
        let net =
            Net::from_config(NetConfig::from_text(presets::CIFAR10_QUICK).unwrap(), 1).unwrap();
        let mut cifar = Solver::new(cfg, net);
        save_snapshot(&mut cifar, &path).unwrap();

        let mut lenet = solver();
        let before: Vec<Vec<f32>> = lenet
            .net
            .params_mut()
            .iter()
            .map(|p| p.data().as_slice().to_vec())
            .collect();
        let iter_before = lenet.iter();
        assert!(load_snapshot(&mut lenet, &path).is_err());
        let after: Vec<Vec<f32>> = lenet
            .net
            .params_mut()
            .iter()
            .map(|p| p.data().as_slice().to_vec())
            .collect();
        assert_eq!(before, after, "failed load mutated solver params");
        assert_eq!(lenet.iter(), iter_before);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_over_existing_snapshot() {
        let dir = tmp_dir("atomic");
        let path = dir.join("state.pcss");
        let mut a = solver();
        a.step().unwrap();
        save_snapshot(&mut a, &path).unwrap();
        let first = std::fs::read(&path).unwrap();
        a.step().unwrap();
        save_snapshot(&mut a, &path).unwrap();
        let second = std::fs::read(&path).unwrap();
        assert_ne!(first, second, "second save must replace the first");
        // No temp-file litter after a successful save.
        assert!(!path.with_extension("pcss.tmp").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn io_error_injection_exhausts_retries_then_succeeds() {
        let dir = tmp_dir("retry");
        let path = dir.join("state.pcss");
        let mut a = solver();
        // Default budget is 3 attempts: the first two injected failures
        // are retried away.
        fault::with_faults("io_error@snapshot_save:2", || {
            save_snapshot(&mut a, &path).unwrap();
        });
        assert!(path.exists());
        // 3+ injected failures exhaust the budget and surface the error.
        let path2 = dir.join("state2.pcss");
        fault::with_faults("io_error@snapshot_save:99", || {
            let err = save_snapshot(&mut a, &path2).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("injected io_error"), "{msg}");
            assert!(msg.contains("attempt"), "{msg}");
        });
        assert!(!path2.exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_rotation_keeps_last_k_and_latest_pointer() {
        let dir = tmp_dir("rotate");
        // Fresh dir per run: clear stale snaps from previous test runs.
        for p in list_snapshots(&dir) {
            std::fs::remove_file(p).ok();
        }
        let mut a = solver();
        for want_iter in [1usize, 2, 3, 4, 5] {
            a.step().unwrap();
            save_checkpoint(&mut a, &dir, 3).unwrap();
            assert_eq!(a.iter(), want_iter);
        }
        let snaps = list_snapshots(&dir);
        assert_eq!(snaps.len(), 3, "rotation keeps exactly K: {snaps:?}");
        let names: Vec<String> = snaps
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, ["snap_00000003.pcss", "snap_00000004.pcss", "snap_00000005.pcss"]);
        let latest = std::fs::read_to_string(dir.join("LATEST")).unwrap();
        assert_eq!(latest.trim(), "snap_00000005.pcss");
        // The durable LATEST write leaves no temp litter behind.
        assert!(!dir.join("LATEST.tmp").exists());
    }

    #[test]
    fn find_latest_valid_skips_corrupt_newest() {
        let dir = tmp_dir("fallback");
        for p in list_snapshots(&dir) {
            std::fs::remove_file(p).ok();
        }
        let mut a = solver();
        a.step().unwrap();
        save_checkpoint(&mut a, &dir, 0).unwrap(); // snap_00000001
        let good_params: Vec<Vec<f32>> = a
            .net
            .params_mut()
            .iter()
            .map(|p| p.data().as_slice().to_vec())
            .collect();
        a.step().unwrap();
        let newest = save_checkpoint(&mut a, &dir, 0).unwrap(); // snap_00000002
        // Corrupt the newest in the middle of the PARM payload.
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();

        let mut b = solver();
        let loaded = find_latest_valid(&mut b, &dir).unwrap();
        assert_eq!(
            loaded.as_deref().and_then(|p| p.file_name()),
            Some(std::ffi::OsStr::new("snap_00000001.pcss")),
            "must fall back to the previous valid snapshot"
        );
        assert_eq!(b.iter(), 1);
        for (p, w) in b.net.params_mut().iter().zip(&good_params) {
            assert_eq!(p.data().as_slice(), w.as_slice());
        }
        // Empty/missing dir: no snapshot, no error.
        let mut c = solver();
        assert!(find_latest_valid(&mut c, &dir.join("does_not_exist")).unwrap().is_none());
    }
}
