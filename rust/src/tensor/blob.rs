//! Caffe's `Blob`: a named container holding `data` and `diff`, plus the
//! synchronization head state the PHAST domain machinery tracks.

use super::{Shape, Tensor};

/// Which copy of a blob is freshest — Caffe's `SyncedMemory::head()`.
///
/// In original Caffe this tracks host vs CUDA device; here "device" is the
/// PHAST/PJRT domain.  Every transition *into* or *out of* `DeviceAhead`
/// costs a transfer, and the paper's §4.3 argues those transfers (plus the
/// layout conversion bolted onto each) dominate the partial-port slowdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncState {
    /// Never touched a device.
    HostOnly,
    /// Host copy newer than device copy.
    HostAhead,
    /// Device copy newer than host copy.
    DeviceAhead,
    /// Both copies identical.
    Synced,
}

/// Named data+diff pair (Caffe `Blob<float>`).
#[derive(Clone, Debug)]
pub struct Blob {
    name: String,
    data: Tensor,
    diff: Tensor,
    state: SyncState,
    /// Monotonic mutation counter for `data`: bumped by every mutable
    /// access path ([`data_mut`](Blob::data_mut),
    /// [`data_mut_and_diff_mut`](Blob::data_mut_and_diff_mut),
    /// [`update`](Blob::update), and count-changing
    /// [`reshape`](Blob::reshape) — a same-count reshape preserves the
    /// buffer and deliberately does not bump).  The GeMM
    /// engine's `PackedMat` caches stamp this value when they pack a
    /// weight matrix and repack only when it moves — which for parameter
    /// blobs is once per solver step, not once per forward.
    version: u64,
}

impl Blob {
    pub fn new(name: impl Into<String>, shape: Shape) -> Self {
        Blob {
            name: name.into(),
            data: Tensor::zeros(shape.clone()),
            diff: Tensor::zeros(shape),
            state: SyncState::HostOnly,
            version: 0,
        }
    }

    pub fn from_data(name: impl Into<String>, data: Tensor) -> Self {
        let diff = Tensor::zeros(data.shape().clone());
        Blob { name: name.into(), data, diff, state: SyncState::HostOnly, version: 0 }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn shape(&self) -> &Shape {
        self.data.shape()
    }

    pub fn count(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &Tensor {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut Tensor {
        self.version += 1;
        &mut self.data
    }

    /// Current `data` mutation stamp (see the `version` field docs).
    /// Conservative by design: every *potentially* mutating access bumps
    /// it, so a matching stamp guarantees unchanged data, while an
    /// unnecessary bump costs at most one spurious repack.
    pub fn data_version(&self) -> u64 {
        self.version
    }

    pub fn diff(&self) -> &Tensor {
        &self.diff
    }

    pub fn diff_mut(&mut self) -> &mut Tensor {
        &mut self.diff
    }

    /// Split borrow: read-only `data` alongside mutable `diff`.  Backward
    /// passes need the weights while accumulating their gradient; this
    /// keeps that borrow-safe without cloning the weight tensor per call.
    pub fn data_and_diff_mut(&mut self) -> (&Tensor, &mut Tensor) {
        (&self.data, &mut self.diff)
    }

    /// Split borrow with both sides mutable: the solver's SGD update
    /// folds weight decay into `diff` (Caffe regularizes in place) while
    /// also writing the updated weights into `data`.
    pub fn data_mut_and_diff_mut(&mut self) -> (&mut Tensor, &mut Tensor) {
        self.version += 1;
        (&mut self.data, &mut self.diff)
    }

    pub fn state(&self) -> SyncState {
        self.state
    }

    pub fn set_state(&mut self, s: SyncState) {
        self.state = s;
    }

    /// Caffe `Blob::Reshape` — keeps contents when the count is unchanged,
    /// reallocates otherwise.
    ///
    /// The `data_version` stamp is bumped **only** when the underlying
    /// buffer is replaced: a same-count reshape preserves every value, so
    /// the stamp stays put and `PackedMat` weight caches keyed on it are
    /// not needlessly invalidated (a pack is additionally keyed on its
    /// logical dims, so a consumer that derives different dims from the
    /// new shape still repacks).
    pub fn reshape(&mut self, shape: Shape) {
        if shape.count() == self.data.len() {
            self.data.reshape_in_place(shape.clone());
            self.diff.reshape_in_place(shape);
        } else {
            self.version += 1;
            self.data = Tensor::zeros(shape.clone());
            self.diff = Tensor::zeros(shape);
        }
    }

    /// `W -= lr * dW` is done by the solver; this is Caffe's `Blob::Update`
    /// primitive `data -= diff`.
    pub fn update(&mut self) {
        self.version += 1;
        for (d, g) in self.data.as_mut_slice().iter_mut().zip(self.diff.as_slice()) {
            *d -= g;
        }
    }

    /// Zero the gradient accumulator.
    pub fn zero_diff(&mut self) {
        self.diff.zero();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_blob_is_zeroed() {
        let b = Blob::new("x", Shape::nchw(1, 2, 3, 4));
        assert_eq!(b.count(), 24);
        assert_eq!(b.data().sum(), 0.0);
        assert_eq!(b.state(), SyncState::HostOnly);
    }

    #[test]
    fn update_subtracts_diff() {
        let mut b = Blob::new("w", Shape::new(&[3]));
        b.data_mut().as_mut_slice().copy_from_slice(&[1.0, 2.0, 3.0]);
        b.diff_mut().as_mut_slice().copy_from_slice(&[0.5, 0.5, 0.5]);
        b.update();
        assert_eq!(b.data().as_slice(), &[0.5, 1.5, 2.5]);
    }

    #[test]
    fn data_version_tracks_mutation_paths() {
        let mut b = Blob::new("w", Shape::new(&[2]));
        let v0 = b.data_version();
        let _ = b.data();
        let _ = b.diff_mut();
        b.zero_diff();
        assert_eq!(b.data_version(), v0, "read-only / diff-only access must not bump");
        b.data_mut();
        assert_eq!(b.data_version(), v0 + 1);
        b.data_mut_and_diff_mut();
        b.update();
        // A same-count reshape preserves the buffer, so it deliberately
        // does NOT bump (was `v0 + 4` when reshape bumped unconditionally,
        // which needlessly invalidated PackedMat weight caches).
        b.reshape(Shape::new(&[2]));
        assert_eq!(b.data_version(), v0 + 3, "buffer-preserving reshape must not bump");
        // A count-changing reshape replaces the buffer and must bump.
        b.reshape(Shape::new(&[5]));
        assert_eq!(b.data_version(), v0 + 4, "every data-replacing path must bump");
    }

    #[test]
    fn reshape_same_count_preserves() {
        let mut b = Blob::new("x", Shape::new(&[2, 3]));
        b.data_mut().as_mut_slice()[0] = 7.0;
        b.reshape(Shape::new(&[3, 2]));
        assert_eq!(b.data().as_slice()[0], 7.0);
        b.reshape(Shape::new(&[5]));
        assert_eq!(b.count(), 5);
        assert_eq!(b.data().sum(), 0.0);
    }
}
