//! Containers — the paper's first block category.
//!
//! [`Tensor`] is a dense row-major f32 array; [`Blob`] pairs the two vectors
//! Caffe keeps per blob (`data` + `diff`); [`SyncState`] tracks which domain
//! (host / PHAST device) holds the freshest copy, which is the substrate the
//! transfer accounting of `phast::` builds on (paper §4.3).

mod shape;
mod blob;

pub use shape::Shape;
pub use blob::{Blob, SyncState};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: Shape) -> Self {
        let n = shape.count();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Tensor filled with `v`.
    pub fn full(shape: Shape, v: f32) -> Self {
        let n = shape.count();
        Tensor { shape, data: vec![v; n] }
    }

    /// Build from parts; `data.len()` must equal `shape.count()`.
    pub fn from_vec(shape: Shape, data: Vec<f32>) -> Self {
        assert_eq!(shape.count(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Reinterpret under a new shape with the same element count.
    pub fn reshaped(mut self, shape: Shape) -> Self {
        assert_eq!(shape.count(), self.data.len());
        self.shape = shape;
        self
    }

    /// Change this tensor's shape in place (must preserve element count —
    /// Caffe's `Blob::Reshape` with equal count).
    pub fn reshape_in_place(&mut self, shape: Shape) {
        assert_eq!(shape.count(), self.data.len());
        self.shape = shape;
    }

    /// Fill with zeros (Caffe `caffe_set(0)` between iterations).
    pub fn zero(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// L2 norm of the data — handy for debugging/regression checks.
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Maximum absolute difference vs another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Transpose a 2-D tensor (used at PHAST domain boundaries to convert
    /// between the row-major ported containers and the column-major panels
    /// the native OpenBLAS-style GeMM consumes — the layout-conversion cost
    /// the paper singles out in §4.3).
    pub fn transposed_2d(&self) -> Tensor {
        assert_eq!(self.shape.ndim(), 2, "transpose needs a matrix");
        let (r, c) = (self.shape.dim(0), self.shape.dim(1));
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(Shape::new(&[c, r]), out)
    }
}

/// Dense row-major i32 tensor (labels, pooling argmax phases).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Shape,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn zeros(shape: Shape) -> Self {
        let n = shape.count();
        IntTensor { shape, data: vec![0; n] }
    }

    pub fn from_vec(shape: Shape, data: Vec<i32>) -> Self {
        assert_eq!(shape.count(), data.len());
        IntTensor { shape, data }
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[i32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_full() {
        let t = Tensor::zeros(Shape::new(&[2, 3]));
        assert_eq!(t.len(), 6);
        assert_eq!(t.sum(), 0.0);
        let f = Tensor::full(Shape::new(&[4]), 2.5);
        assert_eq!(f.sum(), 10.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(Shape::new(&[2, 3]), vec![1., 2., 3., 4., 5., 6.]);
        let r = t.clone().reshaped(Shape::new(&[3, 2]));
        assert_eq!(r.as_slice(), t.as_slice());
    }

    #[test]
    #[should_panic]
    fn reshape_count_mismatch_panics() {
        let t = Tensor::zeros(Shape::new(&[2, 3]));
        let _ = t.reshaped(Shape::new(&[7]));
    }

    #[test]
    fn transpose_2d_roundtrip() {
        let t = Tensor::from_vec(Shape::new(&[2, 3]), vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transposed_2d();
        assert_eq!(tt.shape().dims(), &[3, 2]);
        assert_eq!(tt.as_slice(), &[1., 4., 2., 5., 3., 6.]);
        assert_eq!(tt.transposed_2d(), t);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::from_vec(Shape::new(&[3]), vec![1., 2., 3.]);
        let b = Tensor::from_vec(Shape::new(&[3]), vec![1., 2.5, 2.]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
